"""Heterogeneous-bandwidth topology design (paper §IV-B / §VI-A2–4):

  1. node-level heterogeneity 3:…:1 (Fig. 2) via Algorithm 1 + hetero ADMM,
  2. intra-server PIX/NODE/SYS tree (Fig. 4),
  3. inter-server BCube(4,2) switch ports (Fig. 6),
  4. our TPU adaptation: 2-pod boundary constraints (DESIGN.md §7).

    PYTHONPATH=src python examples/heterogeneous_bcube.py
"""
import numpy as np

from repro.core import (
    BATopoConfig,
    TopologyRequest,
    bcube_constraints,
    intra_server_constraints,
    pod_boundary_constraints,
    solve_topology,
)
from repro.core.allocation import allocate_edge_capacity
from repro.core.consensus import simulate_consensus, time_to_error
from repro.core.graph import all_edges, edge_index

CFG = BATopoConfig(sa_iters=600)


def _sel(topo):
    eidx = edge_index(topo.n)
    sel = np.zeros(len(all_edges(topo.n)), dtype=bool)
    for e in topo.edges:
        sel[eidx[tuple(sorted(e))]] = True
    return sel


def b_min_of(topo, cs):
    sel = _sel(topo)
    bw = np.asarray(cs.edge_bandwidth(sel))[sel]
    return float(bw.min())


print("=== 1. node-level heterogeneity (Algorithm 1), n=16, b = 3:…:1 ===")
b = np.array([9.76] * 8 + [3.25] * 8)
alloc = allocate_edge_capacity(b, r=32)
print(f"  allocation e={alloc.e.tolist()}  b_unit={alloc.b_unit:.2f} GB/s")
topo = solve_topology(TopologyRequest(n=16, r=32, scenario="node",
                                      node_bandwidths=b), cfg=CFG).topology
print(f"  BA-Topo: edges={len(topo.edges)} r_asym={topo.r_asym():.3f} "
      f"b_unit={topo.meta.get('b_unit'):.2f}")

print("\n=== 2. intra-server PIX/NODE/SYS tree (Fig. 3), n=8 ===")
cs = intra_server_constraints(8)
topo = solve_topology(TopologyRequest(n=8, r=12, scenario="constraint",
                                      cs=cs), cfg=CFG).topology
print(f"  BA-Topo: edges={len(topo.edges)} r_asym={topo.r_asym():.3f} "
      f"b_min={b_min_of(topo, cs):.2f} GB/s  feasible={cs.feasible(_sel(topo))}")

print("\n=== 3. inter-server BCube(p=4, k=2), n=16, port ratio 1:2 ===")
cs = bcube_constraints(p=4, k=2)
topo = solve_topology(TopologyRequest(n=16, r=48, scenario="constraint",
                                      cs=cs), cfg=CFG).topology
tr = simulate_consensus(topo, iters=300, b_min=b_min_of(topo, cs))
print(f"  BA-Topo: edges={len(topo.edges)} r_asym={topo.r_asym():.3f} "
      f"t(err≤1e-4)={time_to_error(tr):.0f}ms")

print("\n=== 4. TPU 2-pod boundary (DESIGN.md §7 adaptation), n=32 ===")
cs = pod_boundary_constraints(32, pods=2, dci_cap_total=4)
topo = solve_topology(TopologyRequest(n=32, r=64, scenario="constraint",
                                      cs=cs), cfg=CFG).topology
cross = sum(1 for i, j in topo.edges if (i < 16) != (j < 16))
print(f"  BA-Topo: edges={len(topo.edges)} r_asym={topo.r_asym():.3f} "
      f"cross-pod edges={cross} (DCI cap 4)")
print("heterogeneous scenarios OK")
