"""End-to-end DSGD training driver (deliverable (b)): trains a ~100M-param
LM (smollm-135m family at trimmed depth for CPU wall-clock) for a few hundred
steps with BA-Topo gossip, logging loss + consensus error, checkpointing and
restoring, and comparing against the all-reduce baseline.

    PYTHONPATH=src python examples/dsgd_end_to_end.py            # full (~100M)
    PYTHONPATH=src python examples/dsgd_end_to_end.py --small    # CI-sized
"""
import argparse
import tempfile
from dataclasses import replace

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, reduced_for_smoke
from repro.data import DataConfig, synthetic_lm_batch
from repro.dsgd import allreduce_train_step, dsgd_train_step, init_dsgd_state
from repro.launch.steps import topology_for
from repro.models.transformer import param_count
from repro.optim import sgd_momentum

ap = argparse.ArgumentParser()
ap.add_argument("--small", action="store_true")
ap.add_argument("--steps", type=int, default=None)
ap.add_argument("--workers", type=int, default=8)
args = ap.parse_args()

if args.small:
    cfg = reduced_for_smoke(get_arch("smollm-135m"))
    steps, batch, seq = args.steps or 30, 2, 32
else:
    # smollm-135m at 8 layers (of 30): ~98M params — "train a ~100M model"
    # at a wall-clock a CPU container can actually sustain for 200+ steps.
    cfg = replace(get_arch("smollm-135m"), num_layers=8, dtype="float32")
    steps, batch, seq = args.steps or 200, 2, 64

n = args.workers
topo = topology_for(n, kind="ba")
opt_init, opt_update = sgd_momentum(lr=0.05, momentum=0.9, weight_decay=1e-4)

state = init_dsgd_state(jax.random.PRNGKey(0), cfg, n, opt_init)
n_params = param_count(jax.tree.map(lambda x: x[0], state.params))
print(f"model={cfg.name} ({n_params / 1e6:.1f}M params) workers={n} "
      f"topology={topo.name} r_asym={topo.r_asym():.3f}")

dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, batch_size=batch)
step_ba = dsgd_train_step(cfg, topo, opt_update)
step_ar = allreduce_train_step(cfg, n, opt_update)

with tempfile.TemporaryDirectory() as ckdir:
    mgr = CheckpointManager(ckdir, keep=2)
    first_losses = {}
    for name, step_fn in [("ba-topo gossip", step_ba), ("all-reduce", step_ar)]:
        st = init_dsgd_state(jax.random.PRNGKey(0), cfg, n, opt_init)
        hist = []
        for s in range(steps):
            per = [synthetic_lm_batch(dc, s, node=i) for i in range(n)]
            b = {k: jnp.stack([x[k] for x in per]) for k in per[0]}
            st, m = step_fn(st, b)
            hist.append(float(m["loss"]))
            if s % max(steps // 10, 1) == 0:
                print(f"  [{name}] step {s:>4}  loss {m['loss']:.4f}  "
                      f"consensus_err {float(m['consensus_err']):.3e}")
            if name.startswith("ba") and s == steps // 2:
                mgr.save(st, s)
        first_losses[name] = hist
        print(f"  [{name}] final loss {hist[-1]:.4f} "
              f"(drop {hist[0] - hist[-1]:+.3f})")

    # restore mid-run checkpoint and confirm it resumes
    st0 = init_dsgd_state(jax.random.PRNGKey(0), cfg, n, opt_init)
    restored, at = mgr.restore(st0)
    per = [synthetic_lm_batch(dc, at + 1, node=i) for i in range(n)]
    b = {k: jnp.stack([x[k] for x in per]) for k in per[0]}
    _, m = step_ba(restored, b)
    print(f"resumed from step {at}: loss {float(m['loss']):.4f} (finite: "
          f"{np.isfinite(float(m['loss']))})")

ba, ar = first_losses["ba-topo gossip"], first_losses["all-reduce"]
assert ba[-1] < ba[0], "DSGD loss must decrease"
print(f"\nBA-Topo gossip end loss {ba[-1]:.4f} vs all-reduce {ar[-1]:.4f} "
      f"(gap {abs(ba[-1] - ar[-1]):.4f}) — partial averaging tracks exact "
      "averaging while moving deg/n of the bytes per sync.")
print("end-to-end DSGD OK")
