"""Batched serving example: prefill + KV-cache decode on three architecture
families (dense GQA, SSM, hybrid), greedy and sampled.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np

import jax

from repro.configs import get_arch, reduced_for_smoke
from repro.models import transformer
from repro.serve import ServeConfig, ServingEngine

for arch, note in [("qwen1.5-0.5b", "dense GQA + QKV bias"),
                   ("mamba2-780m", "attention-free SSD"),
                   ("zamba2-2.7b", "Mamba2 + shared attention")]:
    cfg = reduced_for_smoke(get_arch(arch))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(batch_size=4, cache_len=96, max_new_tokens=24,
                       temperature=0.7)
    engine = ServingEngine(cfg, params, scfg, eos_id=-1)
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (4, 16)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(prompts, seed=0)
    dt = time.time() - t0
    assert out.shape == (4, 24) and (out >= 0).all()
    print(f"{arch:>14} [{note}]: {out.size} tokens in {dt:.1f}s — "
          f"req0 → {out[0, :10].tolist()}…")

print("batched serving OK")
