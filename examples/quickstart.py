"""Quickstart: design a BA-Topo, inspect it, and gossip with it.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end to end on n = 16 workers:
  1. optimize the topology under an edge budget (Eq. 9 → Algorithm 2),
  2. compare its consensus speed against ring / exponential (Fig. 1),
  3. compile the topology into a TPU collective schedule and verify the
     ppermute rounds reproduce x ← W x exactly.
"""
import numpy as np

from repro.core import BATopoConfig, TopologyRequest, make_baseline, solve_topology
from repro.core.bandwidth import homo_edge_bandwidth, min_edge_bandwidth
from repro.core.consensus import simulate_consensus, time_to_error
from repro.core.graph import weight_matrix_from_weights
from repro.dsgd import bytes_per_sync, reconstruct_weight_matrix, schedule_from_topology

N, R = 16, 32

print(f"=== 1. BA-Topo for n={N}, edge budget r={R} (paper Eq. 9) ===")
res = solve_topology(TopologyRequest(n=N, r=R, scenario="homo"),
                     cfg=BATopoConfig(sa_iters=800))
topo = res.topology
print(f"  edges={len(topo.edges)}  r_asym={topo.r_asym():.4f} "
      "(paper Table I @ n=16: 0.52)")
print(f"  selected_from={topo.meta.get('selected_from')}  "
      f"tier={res.quality_tier}")

print("\n=== 2. consensus speed vs baselines (paper Fig. 1) ===")
for t in [topo, make_baseline("exponential", N), make_baseline("ring", N)]:
    b_min = min_edge_bandwidth(homo_edge_bandwidth(t))
    tr = simulate_consensus(t, iters=400, b_min=b_min)
    print(f"  {t.name:>24}: edges={len(t.edges):>3} r_asym={t.r_asym():.3f} "
          f"t_iter={tr.t_iter_ms:.1f}ms  t(err≤1e-4)={time_to_error(tr):.0f}ms")

print("\n=== 3. TPU collective schedule (gossip as ppermute rounds) ===")
sched = schedule_from_topology(topo)
W = weight_matrix_from_weights(N, topo.edges, topo.g)
assert np.allclose(reconstruct_weight_matrix(sched), W, atol=1e-12)
traffic = bytes_per_sync(sched, param_bytes=4 * 135_000_000)  # a 135M f32 model
print(f"  {sched.rounds} matching rounds (max degree "
      f"{int(sched.degrees.max())}); schedule reproduces W exactly")
print(f"  gossip bytes/worker: {traffic['per_worker_max'] / 1e6:.0f} MB vs "
      f"all-reduce {traffic['allreduce_per_worker'] / 1e6:.0f} MB")
print("\nquickstart OK")
