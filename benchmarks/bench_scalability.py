"""Scalability across node counts — paper Table I, plus large-n constraint
scenarios on the fast solver stack and the multi-device partition compare.

Asymptotic convergence factor + convergence time (consensus error ≤ 1e-4)
for exponential vs U-EquiStatic vs BA-Topo, with BA-Topo's edge budget at
half the exponential graph's degree sum (the paper's sparsity protocol).

``--scenarios`` additionally runs the four heterogeneous constraint
scenarios (node-level, intra-server n=8, BCube, pod-boundary) at
``--scenario-nodes`` through the device-resident scan driver with the fast
solver stack (inexact CG + fp32, DESIGN.md §9) — no host-side
per-iteration syncs, which is what makes n = 256/512 tractable.

``--partition-nodes`` runs the tracked sharded-ADMM compare (DESIGN.md §13):
for each n it solves the same homogeneous instance on (a) the single-device
fast stack with eigh, (b) single-device with Newton–Schulz (the measured
eigh↔NS crossover data), and (c) the edge-partitioned ``core.shard`` path
across ``--partition-devices`` devices, then emits a compare row with the
sharded-vs-single speedup and the best-candidate ``r_asym`` parity drift.
If the current process has fewer devices it re-execs itself in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the flag must
precede the first jax init, which importing this module already did).

  PYTHONPATH=src python -m benchmarks.bench_scalability --nodes 4,8,16,32,64
  PYTHONPATH=src python -m benchmarks.bench_scalability --nodes "" \
      --scenarios node,intra,bcube,pod --scenario-nodes 256
  PYTHONPATH=src python -m benchmarks.bench_scalability --nodes "" \
      --partition-nodes 256,512,1024
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core import make_baseline
from repro.core.admm import ADMMConfig, HeterogeneousADMM
from repro.core.consensus import simulate_consensus, time_to_error

from .common import ba_topo, edge_b_min

#: Newton–Schulz sign iterations for the tracked large-n rows: the parity
#: tests bound the projection error at 16 iterations well below the support
#: decision the pipeline consumes; 30 (the engine default) doubles the
#: matmul cost without moving the rounded support on these instances.
PARTITION_PSD_ITERS = 16


def run(nodes: list[int], iters: int, sa_iters: int, seed: int,
        restarts: int = 1) -> list[dict]:
    rows = []
    for n in nodes:
        expo = make_baseline("exponential", n)
        # paper: Σdeg(BA) = ½ Σdeg(exp); undirected edge count = Σdeg/2
        r_budget = max(len(expo.edges) // 2, n)
        try:
            equi = make_baseline("equistatic", n,
                                 M=max(1, int(np.ceil(np.log2(n)) // 2)))
        except Exception:
            equi = None
        t0 = time.time()
        # restarts > 1 run as ONE batched, vmapped ADMM device call
        ba = ba_topo(n, r_budget, "homo", seed=seed, sa_iters=sa_iters,
                     restarts=restarts)
        solve_s = time.time() - t0
        for topo, label in [(expo, "exponential"), (equi, "u-equistatic"),
                            (ba, "ba-topo")]:
            if topo is None:
                continue
            b_min = edge_b_min(topo, "homo")
            tr = simulate_consensus(topo, iters=iters, b_min=b_min, seed=seed)
            rows.append({
                "n": n, "topology": label, "edges": len(topo.edges),
                "r_asym": round(float(topo.r_asym()), 3),
                "t_converge_ms": round(time_to_error(tr, 1e-4), 1),
                "solve_s": round(solve_s, 1) if label == "ba-topo" else None,
            })
        print(f"  n={n} done ({solve_s:.1f}s ADMM)")
    return rows


def _scenario_instance(scenario: str, n: int):
    """(cs, n_eff, r) for one constraint scenario at target size n."""
    from repro.core.constraints import (bcube_constraints,
                                        intra_server_constraints,
                                        node_level_constraints,
                                        pod_boundary_constraints)

    if scenario == "node":
        cs = node_level_constraints(n, np.full(n, 4), np.full(n, 9.76))
        return cs, n, 2 * n
    if scenario == "intra":  # the paper's 8-GPU server — n fixed by Fig. 3
        return intra_server_constraints(), 8, 12
    if scenario == "bcube":
        # exact (p, k) factorization with p^k == n when one exists — the
        # paper's p=4 preferred (256 → BCube(4,4)), else the smallest
        # fitting p (512 → BCube(2,9)); otherwise the nearest power of 4,
        # loudly
        for p in (4, 2, 3, 5, 6, 7, 8):
            k = round(np.log(n) / np.log(p))
            if k >= 1 and p ** k == n:
                break
        else:
            p, k = 4, max(1, round(np.log(n) / np.log(4)))
            print(f"  [bcube] no p^k == {n} for p ≤ 8; "
                  f"running BCube({p},{k}) with n={p**k} instead")
        n_eff = p ** k
        # level-0 at the paper's PIX rate, switch levels at the SYS rate
        bw = tuple(4.88 if lay == 0 else 9.76 for lay in range(k))
        return bcube_constraints(p, k, layer_bw=bw), n_eff, 2 * n_eff
    if scenario == "pod":
        cs = pod_boundary_constraints(n, pods=max(2, n // 128),
                                      dci_cap_total=max(8, n // 16))
        return cs, n, 2 * n
    raise ValueError(f"unknown scenario {scenario!r}")


def run_scenarios(scenarios: list[str], n_target: int, admm_iters: int,
                  seed: int) -> list[dict]:
    """Large-n heterogeneous solves on the scan driver + fast solver stack.

    One warm start per scenario (greedy feasible graph — SA is host-side
    O(iters·n³) and not what this benchmark measures), one scan-compiled
    device call per solve; compile and steady-state times are reported
    separately."""
    from repro.core.api import _greedy_constraint_graph
    from repro.core.graph import all_edges, edge_index

    rows = []
    for scenario in scenarios:
        cs, n, r = _scenario_instance(scenario, n_target)
        rng = np.random.default_rng(seed)
        t0 = time.time()
        edges0 = _greedy_constraint_graph(n, r, cs, rng)
        t_warm = time.time() - t0
        eidx = edge_index(n)
        m = len(all_edges(n))
        g0 = np.zeros(m)
        for e in edges0:
            g0[eidx[e]] = 1.0 / max(len(edges0), 1)
        z0 = (g0 > 0).astype(np.float64)
        cfg = ADMMConfig(max_iters=admm_iters,
                         check_every=min(20, admm_iters),
                         precond="jacobi", cg_inexact=True, dtype="float32")
        solver = HeterogeneousADMM(
            n, r, np.asarray(cs.M, np.float64), np.asarray(cs.e_cap, np.float64),
            cfg, equality=cs.equality, edge_ok=np.asarray(cs.edge_ok))
        t0 = time.time()
        res = solver.solve(g0=g0, z0=z0, lam0=0.3)  # compile + run
        t_first = time.time() - t0
        t0 = time.time()
        res = solver.solve(g0=g0, z0=z0, lam0=0.3)
        t_solve = time.time() - t0
        rows.append({
            "scenario": cs.name, "n": n, "r": r, "q": int(cs.q),
            "warm_start_s": round(t_warm, 2),
            "compile_s": round(t_first - t_solve, 2),
            "solve_s": round(t_solve, 2),
            "ms_per_iter": round(t_solve / max(res.iters, 1) * 1e3, 1),
            "admm_iters": res.iters,
            "cg_per_step": round(res.cg_iters / max(res.iters, 1), 1),
            "residual": float(res.residual),
            "z_edges": int(res.z.sum()) if res.z is not None else None,
        })
        print("  " + json.dumps(rows[-1]))
    return rows


def _partition_warm_start(n: int, r: int, seed: int):
    """(g0, lam0) structured warm start — greedy balanced-degree graph with
    Metropolis weights (SA is host-side O(iters·n³), not measured here)."""
    from repro.core.api import _homo_degree_targets, _pack_warm
    from repro.core.anneal import greedy_degree_graph

    rng = np.random.default_rng(seed)
    edges0 = greedy_degree_graph(n, _homo_degree_targets(n, r), rng, None)
    g0, _, lam0 = _pack_warm(n, edges0)
    return g0, lam0


def _candidate_r_asym(n: int, res, r: int) -> float:
    """ρ_asym of the rounded candidate a solve produces: top-r support →
    Metropolis weights → Lanczos spectral gap (no polish — the drift metric
    compares SOLVER outputs, and polish would mask small support flips)."""
    from repro.core.api import extract_support
    from repro.core.graph import Topology, all_edges, is_connected
    from repro.core.weights import metropolis_weights

    sel = extract_support(n, np.asarray(res.g) + np.asarray(res.g_raw), r,
                          tol=1e-6)
    edges_full = all_edges(n)
    edges = [edges_full[l] for l in np.nonzero(sel)[0]]
    if not edges or not is_connected(n, edges):
        return 1.0
    return float(Topology(n, edges, metropolis_weights(n, edges)).r_asym())


def run_partition_compare(nodes: list[int], admm_iters: int, seed: int,
                          ndev: int) -> list[dict]:
    """Single-device vs edge-sharded solves of one homogeneous instance per n.

    Three solve rows per n — (partition, psd_backend) ∈ {(none, eigh),
    (none, newton_schulz), (edges, newton_schulz)} on the fp32 inexact-CG
    stack — plus a compare row carrying ``ns_vs_eigh`` (the measured eigh↔NS
    crossover backing ``engine.NS_MIN_N``), ``speedup_sharded`` (sharded vs
    the best single-device row; ≈ 1/ndev · ideal on a single physical core,
    see DESIGN.md §13), and the ``r_asym`` drift of the rounded candidates.
    ``eps=0`` pins the iteration count so ms_per_iter is load-comparable.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.engine import (ADMMConfig, init_state, make_homo_spec,
                                   solve_spec)
    from repro.core.shard import solve_spec_sharded

    assert jax.device_count() >= ndev, (jax.device_count(), ndev)
    rows = []
    for n in nodes:
        r = 2 * n
        t0 = time.time()
        g0, lam0 = _partition_warm_start(n, r, seed)
        t_warm = time.time() - t0

        def solve_with(psd_backend: str, sharded: bool) -> dict:
            cfg = ADMMConfig(max_iters=admm_iters,
                             check_every=min(10, admm_iters), eps=0.0,
                             cg_inexact=True, dtype="float32",
                             psd_backend=psd_backend,
                             psd_iters=PARTITION_PSD_ITERS)
            spec = make_homo_spec(n, r, cfg)
            st = init_state(spec, jnp.asarray(g0), lam0)
            if sharded:
                def run():
                    return solve_spec_sharded(spec, st, cfg, ndev=ndev)
            else:
                def run():
                    return solve_spec(spec, st, cfg)
            t0 = time.time()
            res = run()  # compile + run
            t_first = time.time() - t0
            t0 = time.time()
            res = run()
            t_solve = time.time() - t0
            return {
                "bench": "scalability", "mode": "solve", "n": n, "r": r,
                "partition": "edges" if sharded else "none",
                "devices": ndev if sharded else 1,
                "psd_backend": psd_backend, "dtype": "float32",
                "cg_inexact": True, "psd_iters": PARTITION_PSD_ITERS,
                "warm_start_s": round(t_warm, 2),
                "compile_s": round(max(t_first - t_solve, 0.0), 2),
                "solve_s": round(t_solve, 2),
                "ms_per_iter": round(t_solve / max(res.iters, 1) * 1e3, 1),
                "admm_iters": res.iters,
                "cg_per_step": round(res.cg_iters / max(res.iters, 1), 1),
                "residual": float(res.residual),
                "r_asym": round(_candidate_r_asym(n, res, r), 6),
            }

        single_eigh = solve_with("eigh", sharded=False)
        single_ns = solve_with("newton_schulz", sharded=False)
        sharded_ns = solve_with("newton_schulz", sharded=True)
        best_single = min(single_eigh, single_ns, key=lambda d: d["solve_s"])
        compare = {
            "bench": "scalability", "mode": "compare", "n": n, "r": r,
            "devices": ndev, "dtype": "float32",
            "single_ms_per_iter": best_single["ms_per_iter"],
            "sharded_ms_per_iter": sharded_ns["ms_per_iter"],
            "speedup_sharded": round(
                best_single["solve_s"] / sharded_ns["solve_s"], 3),
            "ns_vs_eigh": round(
                single_eigh["solve_s"] / single_ns["solve_s"], 3),
            "r_asym_drift": round(
                abs(best_single["r_asym"] - sharded_ns["r_asym"]), 6),
        }
        rows += [single_eigh, single_ns, sharded_ns, compare]
        for row in rows[-4:]:
            print("  " + json.dumps(row))
    return rows


def _partition_compare_subprocess(nodes: list[int], admm_iters: int,
                                  seed: int, ndev: int) -> list[dict]:
    """Re-exec this benchmark with N simulated host devices.

    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` only takes effect
    before the first jax initialization, which importing this module already
    triggered — so the multi-device run needs a fresh interpreter.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "partition.json")
        cmd = [sys.executable, "-m", "benchmarks.bench_scalability",
               "--nodes", "", "--partition-nodes",
               ",".join(str(n) for n in nodes),
               "--partition-iters", str(admm_iters),
               "--partition-devices", str(ndev),
               "--seed", str(seed), "--json-out", out]
        subprocess.run(cmd, check=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
        with open(out) as f:
            return json.load(f)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", default="4,8,16,32,64")
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--sa-iters", type=int, default=600)
    ap.add_argument("--restarts", type=int, default=1,
                    help="ADMM restarts, solved batched on device when > 1")
    ap.add_argument("--scenarios", default="",
                    help="comma-separated constraint scenarios "
                         "(node,intra,bcube,pod) to solve at --scenario-nodes")
    ap.add_argument("--scenario-nodes", type=int, default=256)
    ap.add_argument("--admm-iters", type=int, default=40,
                    help="ADMM iterations for the --scenarios solves")
    ap.add_argument("--partition-nodes", default="",
                    help="comma-separated node counts for the sharded-ADMM "
                         "compare (e.g. 256,512,1024); spawns an "
                         "8-simulated-device subprocess when needed")
    ap.add_argument("--partition-iters", type=int, default=20,
                    help="ADMM iterations for the --partition-nodes solves")
    ap.add_argument("--partition-devices", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    nodes = [int(x) for x in args.nodes.split(",") if x]

    rows = []
    if nodes:
        print("== scalability (paper Table I) ==")
        rows = run(nodes, args.iters, args.sa_iters, args.seed, args.restarts)
        print(f"{'n':>5} {'topology':>14} {'edges':>6} {'r_asym':>7} {'t_conv_ms':>10}")
        for r in rows:
            print(f"{r['n']:>5} {r['topology']:>14} {r['edges']:>6} "
                  f"{r['r_asym']:>7} {r['t_converge_ms']:>10}")

    if args.scenarios:
        print(f"== constraint scenarios at n={args.scenario_nodes} "
              "(scan driver, fast solver stack) ==")
        rows += run_scenarios([s for s in args.scenarios.split(",") if s],
                              args.scenario_nodes, args.admm_iters, args.seed)

    if args.partition_nodes:
        pnodes = [int(x) for x in args.partition_nodes.split(",") if x]
        ndev = args.partition_devices
        import jax

        if jax.device_count() >= ndev:
            print(f"== sharded-ADMM partition compare ({ndev} devices) ==")
            rows += run_partition_compare(pnodes, args.partition_iters,
                                          args.seed, ndev)
        else:
            print(f"== sharded-ADMM partition compare "
                  f"(subprocess, {ndev} simulated devices) ==")
            rows += _partition_compare_subprocess(pnodes, args.partition_iters,
                                                  args.seed, ndev)

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
