"""Scalability across node counts — paper Table I.

Asymptotic convergence factor + convergence time (consensus error ≤ 1e-4)
for exponential vs U-EquiStatic vs BA-Topo, with BA-Topo's edge budget at
half the exponential graph's degree sum (the paper's sparsity protocol).

  PYTHONPATH=src python -m benchmarks.bench_scalability --nodes 4,8,16,32,64
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import make_baseline
from repro.core.consensus import simulate_consensus, time_to_error

from .common import ba_topo, edge_b_min


def run(nodes: list[int], iters: int, sa_iters: int, seed: int,
        restarts: int = 1) -> list[dict]:
    rows = []
    for n in nodes:
        expo = make_baseline("exponential", n)
        # paper: Σdeg(BA) = ½ Σdeg(exp); undirected edge count = Σdeg/2
        r_budget = max(len(expo.edges) // 2, n)
        try:
            equi = make_baseline("equistatic", n,
                                 M=max(1, int(np.ceil(np.log2(n)) // 2)))
        except Exception:
            equi = None
        t0 = time.time()
        # restarts > 1 run as ONE batched, vmapped ADMM device call
        ba = ba_topo(n, r_budget, "homo", seed=seed, sa_iters=sa_iters,
                     restarts=restarts)
        solve_s = time.time() - t0
        for topo, label in [(expo, "exponential"), (equi, "u-equistatic"),
                            (ba, "ba-topo")]:
            if topo is None:
                continue
            b_min = edge_b_min(topo, "homo")
            tr = simulate_consensus(topo, iters=iters, b_min=b_min, seed=seed)
            rows.append({
                "n": n, "topology": label, "edges": len(topo.edges),
                "r_asym": round(float(topo.r_asym()), 3),
                "t_converge_ms": round(time_to_error(tr, 1e-4), 1),
                "solve_s": round(solve_s, 1) if label == "ba-topo" else None,
            })
        print(f"  n={n} done ({solve_s:.1f}s ADMM)")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", default="4,8,16,32,64")
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--sa-iters", type=int, default=600)
    ap.add_argument("--restarts", type=int, default=1,
                    help="ADMM restarts, solved batched on device when > 1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    nodes = [int(x) for x in args.nodes.split(",")]

    print("== scalability (paper Table I) ==")
    rows = run(nodes, args.iters, args.sa_iters, args.seed, args.restarts)
    print(f"{'n':>5} {'topology':>14} {'edges':>6} {'r_asym':>7} {'t_conv_ms':>10}")
    for r in rows:
        print(f"{r['n']:>5} {r['topology']:>14} {r['edges']:>6} "
              f"{r['r_asym']:>7} {r['t_converge_ms']:>10}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
