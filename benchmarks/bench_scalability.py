"""Scalability across node counts — paper Table I, plus large-n constraint
scenarios on the fast solver stack.

Asymptotic convergence factor + convergence time (consensus error ≤ 1e-4)
for exponential vs U-EquiStatic vs BA-Topo, with BA-Topo's edge budget at
half the exponential graph's degree sum (the paper's sparsity protocol).

``--scenarios`` additionally runs the four heterogeneous constraint
scenarios (node-level, intra-server n=8, BCube, pod-boundary) at
``--scenario-nodes`` through the device-resident scan driver with the fast
solver stack (inexact CG + fp32, DESIGN.md §9) — no host-side
per-iteration syncs, which is what makes n = 256/512 tractable.

  PYTHONPATH=src python -m benchmarks.bench_scalability --nodes 4,8,16,32,64
  PYTHONPATH=src python -m benchmarks.bench_scalability --nodes "" \
      --scenarios node,intra,bcube,pod --scenario-nodes 256
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import make_baseline
from repro.core.admm import ADMMConfig, HeterogeneousADMM
from repro.core.consensus import simulate_consensus, time_to_error

from .common import ba_topo, edge_b_min


def run(nodes: list[int], iters: int, sa_iters: int, seed: int,
        restarts: int = 1) -> list[dict]:
    rows = []
    for n in nodes:
        expo = make_baseline("exponential", n)
        # paper: Σdeg(BA) = ½ Σdeg(exp); undirected edge count = Σdeg/2
        r_budget = max(len(expo.edges) // 2, n)
        try:
            equi = make_baseline("equistatic", n,
                                 M=max(1, int(np.ceil(np.log2(n)) // 2)))
        except Exception:
            equi = None
        t0 = time.time()
        # restarts > 1 run as ONE batched, vmapped ADMM device call
        ba = ba_topo(n, r_budget, "homo", seed=seed, sa_iters=sa_iters,
                     restarts=restarts)
        solve_s = time.time() - t0
        for topo, label in [(expo, "exponential"), (equi, "u-equistatic"),
                            (ba, "ba-topo")]:
            if topo is None:
                continue
            b_min = edge_b_min(topo, "homo")
            tr = simulate_consensus(topo, iters=iters, b_min=b_min, seed=seed)
            rows.append({
                "n": n, "topology": label, "edges": len(topo.edges),
                "r_asym": round(float(topo.r_asym()), 3),
                "t_converge_ms": round(time_to_error(tr, 1e-4), 1),
                "solve_s": round(solve_s, 1) if label == "ba-topo" else None,
            })
        print(f"  n={n} done ({solve_s:.1f}s ADMM)")
    return rows


def _scenario_instance(scenario: str, n: int):
    """(cs, n_eff, r) for one constraint scenario at target size n."""
    from repro.core.constraints import (bcube_constraints,
                                        intra_server_constraints,
                                        node_level_constraints,
                                        pod_boundary_constraints)

    if scenario == "node":
        cs = node_level_constraints(n, np.full(n, 4), np.full(n, 9.76))
        return cs, n, 2 * n
    if scenario == "intra":  # the paper's 8-GPU server — n fixed by Fig. 3
        return intra_server_constraints(), 8, 12
    if scenario == "bcube":
        # exact (p, k) factorization with p^k == n when one exists — the
        # paper's p=4 preferred (256 → BCube(4,4)), else the smallest
        # fitting p (512 → BCube(2,9)); otherwise the nearest power of 4,
        # loudly
        for p in (4, 2, 3, 5, 6, 7, 8):
            k = round(np.log(n) / np.log(p))
            if k >= 1 and p ** k == n:
                break
        else:
            p, k = 4, max(1, round(np.log(n) / np.log(4)))
            print(f"  [bcube] no p^k == {n} for p ≤ 8; "
                  f"running BCube({p},{k}) with n={p**k} instead")
        n_eff = p ** k
        # level-0 at the paper's PIX rate, switch levels at the SYS rate
        bw = tuple(4.88 if lay == 0 else 9.76 for lay in range(k))
        return bcube_constraints(p, k, layer_bw=bw), n_eff, 2 * n_eff
    if scenario == "pod":
        cs = pod_boundary_constraints(n, pods=max(2, n // 128),
                                      dci_cap_total=max(8, n // 16))
        return cs, n, 2 * n
    raise ValueError(f"unknown scenario {scenario!r}")


def run_scenarios(scenarios: list[str], n_target: int, admm_iters: int,
                  seed: int) -> list[dict]:
    """Large-n heterogeneous solves on the scan driver + fast solver stack.

    One warm start per scenario (greedy feasible graph — SA is host-side
    O(iters·n³) and not what this benchmark measures), one scan-compiled
    device call per solve; compile and steady-state times are reported
    separately."""
    from repro.core.api import _greedy_constraint_graph
    from repro.core.graph import all_edges, edge_index

    rows = []
    for scenario in scenarios:
        cs, n, r = _scenario_instance(scenario, n_target)
        rng = np.random.default_rng(seed)
        t0 = time.time()
        edges0 = _greedy_constraint_graph(n, r, cs, rng)
        t_warm = time.time() - t0
        eidx = edge_index(n)
        m = len(all_edges(n))
        g0 = np.zeros(m)
        for e in edges0:
            g0[eidx[e]] = 1.0 / max(len(edges0), 1)
        z0 = (g0 > 0).astype(np.float64)
        cfg = ADMMConfig(max_iters=admm_iters,
                         check_every=min(20, admm_iters),
                         precond="jacobi", cg_inexact=True, dtype="float32")
        solver = HeterogeneousADMM(
            n, r, np.asarray(cs.M, np.float64), np.asarray(cs.e_cap, np.float64),
            cfg, equality=cs.equality, edge_ok=np.asarray(cs.edge_ok))
        t0 = time.time()
        res = solver.solve(g0=g0, z0=z0, lam0=0.3)  # compile + run
        t_first = time.time() - t0
        t0 = time.time()
        res = solver.solve(g0=g0, z0=z0, lam0=0.3)
        t_solve = time.time() - t0
        rows.append({
            "scenario": cs.name, "n": n, "r": r, "q": int(cs.q),
            "warm_start_s": round(t_warm, 2),
            "compile_s": round(t_first - t_solve, 2),
            "solve_s": round(t_solve, 2),
            "ms_per_iter": round(t_solve / max(res.iters, 1) * 1e3, 1),
            "admm_iters": res.iters,
            "cg_per_step": round(res.cg_iters / max(res.iters, 1), 1),
            "residual": float(res.residual),
            "z_edges": int(res.z.sum()) if res.z is not None else None,
        })
        print("  " + json.dumps(rows[-1]))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", default="4,8,16,32,64")
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--sa-iters", type=int, default=600)
    ap.add_argument("--restarts", type=int, default=1,
                    help="ADMM restarts, solved batched on device when > 1")
    ap.add_argument("--scenarios", default="",
                    help="comma-separated constraint scenarios "
                         "(node,intra,bcube,pod) to solve at --scenario-nodes")
    ap.add_argument("--scenario-nodes", type=int, default=256)
    ap.add_argument("--admm-iters", type=int, default=40,
                    help="ADMM iterations for the --scenarios solves")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    nodes = [int(x) for x in args.nodes.split(",") if x]

    rows = []
    if nodes:
        print("== scalability (paper Table I) ==")
        rows = run(nodes, args.iters, args.sa_iters, args.seed, args.restarts)
        print(f"{'n':>5} {'topology':>14} {'edges':>6} {'r_asym':>7} {'t_conv_ms':>10}")
        for r in rows:
            print(f"{r['n']:>5} {r['topology']:>14} {r['edges']:>6} "
                  f"{r['r_asym']:>7} {r['t_converge_ms']:>10}")

    if args.scenarios:
        print(f"== constraint scenarios at n={args.scenario_nodes} "
              "(scan driver, fast solver stack) ==")
        rows += run_scenarios([s for s in args.scenarios.split(",") if s],
                              args.scenario_nodes, args.admm_iters, args.seed)

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
