"""Consensus speed vs wall-clock across topologies — paper Figs 1, 2, 4, 6.

The whole baseline set (plus the BA-Topo budgets) is evaluated in ONE
batched device dispatch: ``simulate_consensus_batched`` vmaps the consensus
scan over the stacked weight matrices (``--engine host`` keeps the serial
per-topology path as the parity oracle).

  PYTHONPATH=src python -m benchmarks.bench_consensus --scenario homo
  PYTHONPATH=src python -m benchmarks.bench_consensus --scenario node
  PYTHONPATH=src python -m benchmarks.bench_consensus --scenario intra --n 8
  PYTHONPATH=src python -m benchmarks.bench_consensus --scenario bcube
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import bcube_constraints, intra_server_constraints
from repro.core.consensus import (
    simulate_consensus,
    simulate_consensus_batched,
    time_to_error,
)

from .common import NODE_BW_16, ba_topo, edge_b_min, paper_baselines


def run(scenario: str, n: int, iters: int, sa_iters: int, seed: int,
        engine: str = "batched") -> list[dict]:
    cs = None
    node_bw = None
    if scenario == "node":
        node_bw = NODE_BW_16[:n] if n <= 16 else np.array(
            [9.76] * (n // 2) + [3.25] * (n - n // 2))
    elif scenario == "intra":
        cs = intra_server_constraints(n)
    elif scenario == "bcube":
        p = int(round(np.sqrt(n)))
        cs = bcube_constraints(p=p, k=2)

    topos = paper_baselines(n, scenario)
    # BA-Topo at the paper's edge budgets for each figure
    budgets = {"homo": (16, 24, 32), "node": (16, 32, 48),
               "intra": (8, 12, 16), "bcube": (24, 48)}[scenario]
    for r in budgets:
        try:
            t = ba_topo(n, r, scenario, node_bw=node_bw, cs=cs,
                        seed=seed, sa_iters=sa_iters)
            t.meta["label"] = f"ba-topo(r={len(t.edges)})"
            topos.append(t)
        except ValueError as e:
            print(f"  [warn] ba-topo r={r}: {e}")

    b_mins = [edge_b_min(t, scenario, node_bw=node_bw, cs=cs) for t in topos]
    if engine == "batched":
        traces = simulate_consensus_batched(topos, iters=iters, seed=seed,
                                            b_mins=b_mins)
    else:
        traces = [simulate_consensus(t, iters=iters, b_min=bm, seed=seed)
                  for t, bm in zip(topos, b_mins)]

    rows = []
    for topo, b_min, trace in zip(topos, b_mins, traces):
        rows.append({
            "topology": topo.meta.get("label", topo.name),
            "edges": len(topo.edges),
            "r_asym": round(float(topo.r_asym()), 4),
            "b_min": round(b_min, 3),
            "t_iter_ms": round(trace.t_iter_ms, 3),
            "t_converge_ms": round(time_to_error(trace, 1e-4), 1),
            "err@50iters": float(trace.errors[min(50, iters)] / trace.errors[0]),
        })
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="homo",
                    choices=["homo", "node", "intra", "bcube"])
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--iters", type=int, default=400)
    ap.add_argument("--sa-iters", type=int, default=800)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="batched", choices=["batched", "host"],
                    help="batched = one vmapped dispatch for the whole set "
                         "(default); host = serial per-topology scans")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    n = args.n or (8 if args.scenario == "intra" else 16)

    print(f"== consensus speed, scenario={args.scenario}, n={n} "
          f"(paper Fig {'1' if args.scenario == 'homo' else '2' if args.scenario == 'node' else '4' if args.scenario == 'intra' else '6'}) ==")
    rows = run(args.scenario, n, args.iters, args.sa_iters, args.seed,
               engine=args.engine)
    hdr = ["topology", "edges", "r_asym", "b_min", "t_iter_ms", "t_converge_ms"]
    print(" | ".join(f"{h:>22}" for h in hdr))
    for row in sorted(rows, key=lambda r: r["t_converge_ms"]):
        print(" | ".join(f"{str(row[h]):>22}" for h in hdr))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
