"""Beyond-paper: elastic real-model training — static incumbent vs the
elastic runtime (watchdog + freeze/renorm + live re-optimization) under
churn, packet loss, stragglers and a NIC collapse (DESIGN.md §16).

Unlike bench_chaos (simulated softmax workers), this drives the REAL model
zoo path: the reduced smollm config trains over the stacked n-worker gossip
loop with the fault tensors applied inside one jitted elastic step. One
tracked scenario (node-hetero n=8, mid-run NIC collapse + one churn window
+ packet loss + stragglers) enters two runs sharing ONE compiled step:

  static:   classic BSP on the incumbent — every round waits out the
            slowest straggler, the topology rides out the drift unchanged;
  elastic:  the watchdog drops modeled stragglers at the deadline, the
            DriftDetector fires at the collapse, the ADMM re-solves
            warm-started and the new graph hot-swaps in (no retrace).

Both runs pay the Eq. 34 modeled round clock (per-node latencies from
``node_step_latency_ms``); the tracked headline is ``reopt_gain`` = static
time-to-target-loss / elastic time-to-target-loss. Two correctness columns
ride along, gated strictly by ``check_regression``:

  elastic_parity_drift  max |loss gap| of the fault-free elastic step vs
                        the plain ``dsgd_train_step`` — must be exactly 0.0
                        (the elastic path IS the trainer when nothing fails);
  resume_exactness      a mid-run checkpoint (pytree + elastic extras) is
                        restored into a fresh runtime and replayed — the
                        loss tail must match the uninterrupted run bitwise.

  PYTHONPATH=src python -m benchmarks.bench_elastic
  PYTHONPATH=src python -m benchmarks.bench_elastic --steps 48 --json-out rows.json
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, reduced_for_smoke
from repro.data import DataConfig, synthetic_lm_batch
from repro.dsgd import (
    ElasticRuntime,
    ElasticSpec,
    drift_profile,
    dsgd_train_step,
    init_dsgd_state,
    make_chaos,
    make_elastic_train_step,
    no_chaos,
)
from repro.optim import sgd_momentum

from .common import ba_topo


def build_chaos(steps: int, n: int, drift_step: int, bw0: np.ndarray, args):
    churn = []
    if args.churn_node >= 0:
        t1 = min(drift_step + max(steps // 4, 2), steps)
        churn = [(args.churn_node, drift_step, t1)]
    prof = drift_profile(steps, n, drift_step, bw0,
                         args.slow_nodes, args.slow_bw)
    return make_chaos(steps, n, seed=args.seed, churn=churn,
                      p_drop=args.p_drop, straggler_prob=args.straggler_prob,
                      straggler_mult=args.straggler_mult, bandwidth=prof)


def make_batch(dc, step: int, n: int):
    per = [synthetic_lm_batch(dc, step, node=i) for i in range(n)]
    return {k: jnp.stack([b[k] for b in per]) for k in per[0]}


def run_elastic(cfg, spec, topo, opt_update, step_fn, state0, dc, steps,
                *, seed, save_at=None, mgr=None):
    """One elastic run; returns (losses (steps,), round_ms (steps,), es)."""
    rt = ElasticRuntime(cfg, spec, topo, opt_update, step_fn=step_fn)
    es = rt.make_state(topo, seed=seed)
    state = state0
    losses, round_ms = [], []
    for s in range(steps):
        batch = make_batch(dc, es.data_step, spec.chaos.n)
        state, m, rep = rt.round(state, es, batch)
        losses.append(np.asarray(m["loss"]))
        round_ms.append(rep.round_ms)
        if mgr is not None and save_at is not None and s == save_at:
            mgr.save(state, int(state.step), extra=rt.to_extras(es))
    return np.stack(losses), np.asarray(round_ms), es, state


def t_target_s(losses: np.ndarray, round_ms: np.ndarray,
               target: float) -> float:
    """Modeled seconds until the loss first reaches ``target``."""
    cum = np.cumsum(round_ms)
    hit = np.nonzero(losses <= target)[0]
    return float(cum[int(hit[0])] / 1e3) if hit.size else float("inf")


def parity_drift(cfg, topo, opt_update, step_fn, state0, dc, n: int,
                 steps: int) -> float:
    """Max |loss gap| of the fault-free elastic step vs dsgd_train_step
    over ``steps`` rounds (bit-exactness ⇒ exactly 0.0)."""
    legacy = dsgd_train_step(cfg, topo, opt_update)
    spec = ElasticSpec(chaos=no_chaos(steps, n), reopt=False)
    rt = ElasticRuntime(cfg, spec, topo, opt_update, step_fn=step_fn)
    es = rt.make_state(topo)
    s1 = s2 = state0
    drift = 0.0
    for s in range(steps):
        batch = make_batch(dc, s, n)
        s1, m1 = legacy(s1, batch)
        s2, m2, _ = rt.round(s2, es, batch)
        drift = max(drift, abs(float(m1["loss"]) - float(m2["loss"])))
    return drift


def resume_exactness(cfg, spec, topo, opt_update, step_fn, state0, dc,
                     steps: int, save_at: int, seed: int,
                     ref_losses: np.ndarray) -> bool:
    """Save at ``save_at``, restore into a FRESH runtime, replay to the end
    — the loss tail must match the uninterrupted run bitwise."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        run_elastic(cfg, spec, topo, opt_update, step_fn, state0, dc,
                    save_at + 1, seed=seed, save_at=save_at, mgr=mgr)
        rt = ElasticRuntime(cfg, spec, topo, opt_update, step_fn=step_fn)
        state, rstep, extras = mgr.restore(state0, with_extra=True)
        if state is None:
            return False
        es = rt.from_extras(extras, name=topo.name)
        for s in range(int(rstep), steps):
            batch = make_batch(dc, es.data_step, spec.chaos.n)
            state, m, _ = rt.round(state, es, batch)
            if np.asarray(m["loss"]).tobytes() != ref_losses[s].tobytes():
                return False
    return True


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--r", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--drift-frac", type=float, default=0.25)
    ap.add_argument("--slow-nodes", type=int, default=2,
                    help="nodes whose NICs collapse at the drift step")
    ap.add_argument("--slow-bw", type=float, default=1.0)
    ap.add_argument("--churn-node", type=int, default=5,
                    help="node that churns out at the drift step (-1: none)")
    ap.add_argument("--p-drop", type=float, default=0.03)
    ap.add_argument("--straggler-prob", type=float, default=0.1)
    ap.add_argument("--straggler-mult", type=float, default=4.0)
    ap.add_argument("--deadline-factor", type=float, default=2.0)
    ap.add_argument("--parity-steps", type=int, default=4)
    ap.add_argument("--resume-save-frac", type=float, default=0.5)
    ap.add_argument("--sa-iters", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    n, steps = args.n, args.steps
    cfg = reduced_for_smoke(get_arch(args.arch))
    bw0 = np.array([9.76] * (n // 2) + [3.25] * (n - n // 2))
    drift_step = max(int(steps * args.drift_frac), 1)
    print(f"== elastic: static BSP vs elastic runtime, real model "
          f"{cfg.name} n={n} r={args.r} steps={steps} ==")

    t0 = time.time()
    topo = ba_topo(n, args.r, "node", node_bw=bw0, seed=args.seed,
                   sa_iters=args.sa_iters)
    topo_s = round(time.time() - t0, 3)

    opt_init, opt_update = sgd_momentum(args.lr)
    state0 = init_dsgd_state(jax.random.PRNGKey(args.seed), cfg, n, opt_init)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch, seed=args.seed,
                    frontend_tokens=cfg.frontend_tokens, d_model=cfg.d_model)
    step_fn = make_elastic_train_step(cfg, opt_update)

    chaos = build_chaos(steps, n, drift_step, bw0, args)
    static_spec = ElasticSpec(chaos=chaos, drop_stragglers=False, reopt=False,
                              deadline_factor=args.deadline_factor)
    elastic_spec = ElasticSpec(chaos=chaos, drop_stragglers=True, reopt=True,
                               deadline_factor=args.deadline_factor)

    t0 = time.time()
    st_loss, st_ms, st_es, _ = run_elastic(cfg, static_spec, topo, opt_update,
                                           step_fn, state0, dc, steps,
                                           seed=args.seed)
    el_loss, el_ms, el_es, _ = run_elastic(cfg, elastic_spec, topo, opt_update,
                                           step_fn, state0, dc, steps,
                                           seed=args.seed)
    train_s = round(time.time() - t0, 3)

    target = float(max(st_loss[-1], el_loss[-1]))
    t_static = t_target_s(st_loss, st_ms, target)
    t_elastic = t_target_s(el_loss, el_ms, target)

    t0 = time.time()
    pdrift = parity_drift(cfg, topo, opt_update, step_fn, state0, dc, n,
                          args.parity_steps)
    parity_s = round(time.time() - t0, 3)

    t0 = time.time()
    save_at = max(int(steps * args.resume_save_frac), 1)
    exact = resume_exactness(cfg, elastic_spec, topo, opt_update, step_fn,
                             state0, dc, steps, save_at, args.seed, el_loss)
    resume_s = round(time.time() - t0, 3)

    reopt_events = [e for e in el_es.events if e["event"] == "reopt"]
    rows = [
        {"bench": "elastic", "scenario": "nic-collapse", "n": n,
         "mode": "static", "final_loss": round(float(st_loss[-1]), 4),
         "total_modeled_s": round(float(st_ms.sum() / 1e3), 2),
         "t_target_s": round(t_static, 2)},
        {"bench": "elastic", "scenario": "nic-collapse", "n": n,
         "mode": "elastic", "final_loss": round(float(el_loss[-1]), 4),
         "total_modeled_s": round(float(el_ms.sum() / 1e3), 2),
         "t_target_s": round(t_elastic, 2),
         "dropped_rounds": el_es.dropped_rounds, "drops": el_es.drops,
         "reopts": el_es.reopts, "adopted": el_es.adopted},
    ]
    summary = {
        "bench": "elastic", "scenario": "nic-collapse", "n": n,
        "arch": cfg.name, "steps": steps, "drift_step": drift_step,
        "reopts": el_es.reopts, "adopted": el_es.adopted,
        "time_to_reopt_s": round(sum(e["time_to_reopt_s"]
                                     for e in reopt_events), 3)
        if reopt_events else None,
        "static_t_target_s": round(t_static, 2),
        "elastic_t_target_s": round(t_elastic, 2),
        "elastic_parity_drift": pdrift,
        "resume_exactness": bool(exact),
        "topo_s": topo_s, "train_s": train_s,
        "total_s": round(train_s + parity_s + resume_s, 3),
    }
    if np.isfinite(t_static) and np.isfinite(t_elastic) and t_elastic > 0:
        summary["reopt_gain"] = round(t_static / t_elastic, 3)
    rows.append(summary)

    hdr = ["mode", "final_loss", "t_target_s", "total_modeled_s"]
    print(" | ".join(f"{h:>16}" for h in hdr))
    for row in rows[:2]:
        print(" | ".join(f"{str(row.get(h)):>16}" for h in hdr))
    keys = ["static_t_target_s", "elastic_t_target_s", "reopts", "adopted",
            "elastic_parity_drift", "resume_exactness"]
    if "reopt_gain" in summary:
        keys.append("reopt_gain")
    print("  " + json.dumps({k: summary[k] for k in keys}))

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
