"""Deprecation lint: no in-repo caller may use the shimmed old entrypoints.

``optimize_topology`` / ``sweep_topologies`` survive as thin
DeprecationWarning shims for external callers (DESIGN.md §17), but the
repo itself must be fully migrated to ``TopologyRequest`` +
``solve_topology`` / ``solve_topologies``. This walks every Python file
under src/, benchmarks/ and examples/ and fails on any *call* of a
shimmed name. Excluded: tests/ (they pin the shims' behavior on purpose)
and the module that defines the shims.

  PYTHONPATH=src python -m benchmarks.check_deprecations
"""
from __future__ import annotations

import ast
import os
import sys

DEPRECATED = {"optimize_topology", "sweep_topologies"}
ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SCAN_DIRS = ("src", "benchmarks", "examples")
#: the shims live here — their own bodies call the real implementations
EXCLUDE = {os.path.join("src", "repro", "core", "api.py")}


def deprecated_calls(path: str) -> list[tuple[int, str]]:
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = None
        if isinstance(fn, ast.Name):
            name = fn.id
        elif isinstance(fn, ast.Attribute):
            name = fn.attr
        if name in DEPRECATED:
            hits.append((node.lineno, name))
    return hits


def main(argv=None) -> int:
    failures = []
    for d in SCAN_DIRS:
        base = os.path.join(ROOT, d)
        for dirpath, _dirnames, filenames in os.walk(base):
            for fname in sorted(filenames):
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, ROOT)
                if rel in EXCLUDE:
                    continue
                for lineno, name in deprecated_calls(path):
                    failures.append(f"{rel}:{lineno}: call of deprecated "
                                    f"{name}() — use TopologyRequest + "
                                    "solve_topology/solve_topologies")
    print(f"check_deprecations: scanned {'/'.join(SCAN_DIRS)}, "
          f"{len(failures)} violation(s)")
    for fail in failures:
        print("  FAIL " + fail)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
