"""Shared benchmark helpers: baseline topology sets per paper scenario."""
from __future__ import annotations

import numpy as np

from repro.core import (
    BATopoConfig,
    bcube_constraints,
    intra_server_constraints,
    make_baseline,
    optimize_topology,
)
from repro.core.bandwidth import (
    PaperConstants,
    homo_edge_bandwidth,
    min_edge_bandwidth,
    node_hetero_edge_bandwidth,
)
from repro.core.graph import Topology

PC = PaperConstants()

# §VI-A2: 3:3:…:1:1 node bandwidth ratios, 9.76 / 3.25 GB/s
NODE_BW_16 = np.array([9.76] * 8 + [3.25] * 8)


def paper_baselines(n: int, scenario: str) -> list[Topology]:
    """The comparison set of Figs 1/2/4/6: ring, 2D grid, 2D torus,
    exponential, U-EquiStatic."""
    out = [make_baseline("ring", n), make_baseline("exponential", n)]
    if int(np.sqrt(n)) ** 2 == n:
        out.insert(1, make_baseline("grid", n))
        out.insert(2, make_baseline("torus", n))
    for M in (2, 3):
        try:
            t = make_baseline("equistatic", n, M=M)
            t.meta["label"] = f"u-equistatic(r={len(t.edges)})"
            out.append(t)
        except ValueError:
            pass  # EquiStatic is only defined for n where a valid M-decomposition exists
    return out


def edge_b_min(topo: Topology, scenario: str, node_bw: np.ndarray | None = None,
               cs=None) -> float:
    """Minimum per-edge bandwidth under the scenario's sharing rule."""
    if scenario == "node":
        bw = node_hetero_edge_bandwidth(topo, node_bw)
    elif scenario in ("intra", "bcube") and cs is not None:
        from repro.core.graph import all_edges, edge_index
        eidx = edge_index(topo.n)
        sel = np.zeros(len(all_edges(topo.n)), dtype=bool)
        for e in topo.edges:
            sel[eidx[tuple(sorted(e))]] = True
        full = np.asarray(cs.edge_bandwidth(sel))
        bw = full[sel]
    else:
        bw = homo_edge_bandwidth(topo)
    return min_edge_bandwidth(np.asarray(bw))


def ba_topo(n: int, r: int, scenario: str = "homo", *, node_bw=None, cs=None,
            seed: int = 0, sa_iters: int = 800, restarts: int = 1) -> Topology:
    cfg = BATopoConfig(seed=seed, sa_iters=sa_iters, restarts=restarts)
    if scenario == "homo":
        return optimize_topology(n, r, "homo", cfg=cfg)
    if scenario == "node":
        return optimize_topology(n, r, "node", node_bandwidths=node_bw, cfg=cfg)
    return optimize_topology(n, r, "constraint", cs=cs, cfg=cfg)
