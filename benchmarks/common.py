"""Shared benchmark helpers: baseline topology sets per paper scenario."""
from __future__ import annotations

import numpy as np

from repro.core import (
    BATopoConfig,
    TopologyRequest,
    bcube_constraints,
    intra_server_constraints,
    make_baseline,
    solve_topology,
)
from repro.core.bandwidth import (
    PaperConstants,
    homo_edge_bandwidth,
    min_edge_bandwidth,
    node_hetero_edge_bandwidth,
    t_iter,
)
from repro.core.graph import Topology

PC = PaperConstants()

# §VI-A2: 3:3:…:1:1 node bandwidth ratios, 9.76 / 3.25 GB/s
NODE_BW_16 = np.array([9.76] * 8 + [3.25] * 8)


def paper_baselines(n: int, scenario: str) -> list[Topology]:
    """The comparison set of Figs 1/2/4/6: ring, 2D grid, 2D torus,
    exponential, U-EquiStatic."""
    out = [make_baseline("ring", n), make_baseline("exponential", n)]
    if int(np.sqrt(n)) ** 2 == n:
        out.insert(1, make_baseline("grid", n))
        out.insert(2, make_baseline("torus", n))
    for M in (2, 3):
        try:
            t = make_baseline("equistatic", n, M=M)
            t.meta["label"] = f"u-equistatic(r={len(t.edges)})"
            out.append(t)
        except ValueError:
            pass  # EquiStatic is only defined for n where a valid M-decomposition exists
    return out


def constraint_edge_bandwidths(n: int, edges, cs) -> np.ndarray:
    """Per-edge bandwidths of a selected edge set under a shared-medium
    ConstraintSet — the medium is divided among the SELECTED edges only, so
    the same helper serves the full static set and a single matching."""
    from repro.core.graph import all_edges, edge_index
    eidx = edge_index(n)
    sel = np.zeros(len(all_edges(n)), dtype=bool)
    for e in edges:
        sel[eidx[tuple(sorted(e))]] = True
    return np.asarray(cs.edge_bandwidth(sel))[sel]


def edge_b_min(topo: Topology, scenario: str, node_bw: np.ndarray | None = None,
               cs=None) -> float:
    """Minimum per-edge bandwidth under the scenario's sharing rule."""
    if scenario == "node":
        bw = node_hetero_edge_bandwidth(topo, node_bw)
    elif scenario in ("intra", "bcube") and cs is not None:
        bw = constraint_edge_bandwidths(topo.n, topo.edges, cs)
    else:
        bw = homo_edge_bandwidth(topo)
    return min_edge_bandwidth(np.asarray(bw))


def ba_topo(n: int, r: int, scenario: str = "homo", *, node_bw=None, cs=None,
            seed: int = 0, sa_iters: int = 800, restarts: int = 1) -> Topology:
    cfg = BATopoConfig(seed=seed, sa_iters=sa_iters, restarts=restarts)
    if scenario == "homo":
        req = TopologyRequest(n=n, r=r, scenario="homo")
    elif scenario == "node":
        req = TopologyRequest(n=n, r=r, scenario="node", node_bandwidths=node_bw)
    else:
        req = TopologyRequest(n=n, r=r, scenario="constraint", cs=cs)
    return solve_topology(req, cfg=cfg).topology


#: §VI-B edge-budget grids per scenario (bench_training_time's Table II sets).
SCENARIO_BUDGETS = {"homo": (16, 24, 32), "node": (16, 32, 48),
                    "intra": (8, 12, 16), "bcube": (24, 48)}


def scenario_inputs(scenario: str, n: int):
    """(node_bw, cs) for a scenario — the hetero inputs of §VI-A2/A3."""
    node_bw = NODE_BW_16[:n] if scenario == "node" else None
    cs = None
    if scenario == "intra":
        cs = intra_server_constraints(n)
    elif scenario == "bcube":
        cs = bcube_constraints(p=int(round(np.sqrt(n))), k=2)
    return node_bw, cs


def scenario_topologies(n: int, scenario: str, sa_iters: int, seed: int):
    """The full §VI comparison set for a scenario: paper baselines + BA-Topo
    at the scenario's edge budgets (9 topologies for homo n=16 — the ISSUE-5
    tracked point). Returns (topos, node_bw, cs)."""
    node_bw, cs = scenario_inputs(scenario, n)
    topos = paper_baselines(n, scenario)
    for r in SCENARIO_BUDGETS[scenario]:
        try:
            t = ba_topo(n, r, scenario, node_bw=node_bw, cs=cs, seed=seed,
                        sa_iters=sa_iters)
            t.meta["label"] = f"ba-topo(r={len(t.edges)})"
            topos.append(t)
        except ValueError as e:
            print(f"  [warn] ba-topo r={r}: {e}")
    return topos, node_bw, cs


def chaos_step_times(topo: Topology, chaos, const: PaperConstants = PC,
                     start: int = 0, stop: int | None = None) -> np.ndarray:
    """Per-step modeled wall time (ms) of a topology under a ChaosSpec —
    the Eq. 34/35 clock extended with straggler delays and effective B(t).

    Step t: an edge is active iff both endpoints are alive; its bandwidth is
    the degree-shared ``min(B_i(t)/d_i, B_j(t)/d_j)`` with the *static*
    degrees (ports are provisioned for the full graph, a neighbor's death
    does not re-cable the node). Comm time is Eq. 34 at the min active-edge
    bandwidth; the step then waits for the slowest *alive* participant:

        step_ms(t) = (b_avail / b_min(t) × t_comm + t_comp) × max straggler.

    Link drops (``chaos.link_up``) do NOT stretch the clock: a lost gossip
    payload costs accuracy (the training-math side), not time — the step's
    exchange window elapses either way. Returns ms for steps
    ``start ≤ t < stop`` (default: the whole spec).
    """
    from repro.core.graph import degrees

    stop = chaos.steps if stop is None else stop
    n = topo.n
    d = np.maximum(degrees(n, topo.edges).astype(np.float64), 1.0)
    ei = np.array([i for i, _ in topo.edges], dtype=np.int64)
    ej = np.array([j for _, j in topo.edges], dtype=np.int64)
    out = np.empty(stop - start)
    for k, t in enumerate(range(start, stop)):
        alive = chaos.alive[t]
        bw = np.asarray(chaos.bandwidth[t], dtype=np.float64)
        comm = 0.0
        if ei.size:
            act = (alive[ei] > 0) & (alive[ej] > 0)
            if act.any():
                b_edge = np.minimum(bw[ei] / d[ei], bw[ej] / d[ej])[act]
                comm = t_iter(float(b_edge.min()), const)
        slow = chaos.straggler[t][alive > 0]
        mult = float(slow.max()) if slow.size else 1.0
        out[k] = (comm + const.t_comp_ms) * mult
    return out


def dynamic_step_times(topo: Topology, schedules, scenario: str,
                       node_bw: np.ndarray | None = None, cs=None,
                       const: PaperConstants = PC) -> np.ndarray:
    """Per-matching modeled comm times (ms) of a round-robin cycle (Eq. 34).

    In round c only the matching's edges are active, so every node talks to
    ≤1 peer and an edge gets the FULL node bandwidth — min(b_i, b_j) instead
    of the degree-shared min(b_i/d_i, b_j/d_j) (homo/node scenarios). For
    shared-medium constraint scenarios the medium is re-divided among the
    matching's edges only (``cs.edge_bandwidth`` on the matching selection).
    Returns (R,) ms — step t of the cycle costs ``times[t % R]``.
    """
    n = topo.n
    times = np.empty(len(schedules))
    for c, sched in enumerate(schedules):
        edges = [(s, d) for perm in sched.perms for (s, d) in perm if s < d]
        if not edges:
            times[c] = 0.0
            continue
        if scenario == "node":
            b = np.asarray(node_bw, dtype=np.float64)
            b_min = min(min(b[i], b[j]) for i, j in edges)
        elif scenario in ("intra", "bcube") and cs is not None:
            b_min = float(constraint_edge_bandwidths(n, edges, cs).min())
        else:
            b_min = const.b_avail
        times[c] = t_iter(b_min, const)
    return times
