"""Pallas kernel validation + micro-timing vs the pure-jnp oracles.

On this CPU container the kernels execute in interpret mode (correctness);
the BlockSpec tiling is the TPU deployment artifact. Reports max|err| vs
ref.py and per-call wall time (interpret-mode timing is NOT TPU perf —
recorded only to catch pathological regressions).

  PYTHONPATH=src python -m benchmarks.bench_kernels
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e3


def bench_gossip_mix(rows: list) -> None:
    from repro.kernels.gossip_mix import ops, ref
    key = jax.random.PRNGKey(0)
    for shape, deg in [((1024,), 2), ((4096, 384), 4), ((1000, 131), 3)]:
        x = jax.random.normal(key, shape)
        nbrs = jax.random.normal(jax.random.PRNGKey(1), (deg,) + shape)
        w = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (deg + 1,)))
        w = w / w.sum()
        out_k = ops.gossip_mix(x, nbrs, w, use_kernel=True)
        out_r = ref.gossip_mix(x, nbrs, w)
        err = float(jnp.max(jnp.abs(out_k - out_r)))
        rows.append({"kernel": "gossip_mix", "shape": str(shape), "deg": deg,
                     "max_err": err,
                     "ms_kernel": round(_time(lambda: ops.gossip_mix(x, nbrs, w)), 2),
                     "ms_ref": round(_time(lambda: ref.gossip_mix(x, nbrs, w)), 2)})


def bench_gossip_mix_batched(rows: list) -> None:
    """All-workers batched gossip (one dispatch per leaf) vs the per-row
    dispatch loop and the dense-W matmul, on real topology W matrices."""
    from repro.core import make_baseline
    from repro.dsgd.gossip import (gossip_sim_tree, gossip_sim_tree_rowloop,
                                   padded_neighbors)
    for name, n, shape in [("ring", 16, (4096,)), ("exponential", 16, (512, 64))]:
        topo = make_baseline(name, n)
        W = jnp.asarray(topo.W, jnp.float32)
        nbr = padded_neighbors(W)
        tree = {"p": jax.random.normal(jax.random.PRNGKey(0), (n,) + shape)}
        out_b = gossip_sim_tree(tree, W, use_kernel=True, nbr=nbr)["p"]
        out_r = gossip_sim_tree_rowloop(tree, W)["p"]
        err = float(jnp.max(jnp.abs(out_b - out_r)))
        rows.append({
            "kernel": "gossip_mix_batched", "shape": f"{name}_n{n}_{shape}",
            "deg": int(nbr[0].shape[1]), "max_err": err,
            "ms_kernel": round(_time(
                lambda: gossip_sim_tree(tree, W, use_kernel=True, nbr=nbr)["p"]), 2),
            "ms_ref": round(_time(
                lambda: gossip_sim_tree_rowloop(tree, W)["p"]), 2)})


def bench_decode_attention(rows: list) -> None:
    from repro.kernels.decode_attention import ops, ref
    key = jax.random.PRNGKey(0)
    for (B, C, Hkv, g, hd) in [(2, 512, 2, 2, 64), (4, 1024, 4, 1, 128)]:
        q = jax.random.normal(key, (B, Hkv * g, hd))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, C, Hkv, hd))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, C, Hkv, hd))
        valid = jnp.arange(C) < (C // 2)
        out_k = ops.decode_attention(q, k, v, valid)
        out_r = ref.decode_attention(q, k, v, valid)
        err = float(jnp.max(jnp.abs(out_k - out_r)))
        rows.append({"kernel": "decode_attention", "shape": f"B{B}_C{C}_H{Hkv}x{g}_d{hd}",
                     "max_err": err,
                     "ms_kernel": round(_time(lambda: ops.decode_attention(q, k, v, valid)), 2),
                     "ms_ref": round(_time(lambda: ref.decode_attention(q, k, v, valid)), 2)})


def bench_ssd_scan(rows: list) -> None:
    from repro.kernels.ssd_scan import ops, ref
    key = jax.random.PRNGKey(0)
    for (B, nc, Q, H, P, N) in [(2, 2, 64, 4, 32, 32), (1, 4, 128, 8, 64, 64)]:
        xc = jax.random.normal(key, (B, nc, Q, H, P)) * 0.3
        dtc = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, nc, Q, H)))
        A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
        la = jnp.cumsum(A[None, None, None, :] * dtc, axis=2)
        Bc = jax.random.normal(jax.random.PRNGKey(3), (B, nc, Q, N)) * 0.3
        Cc = jax.random.normal(jax.random.PRNGKey(4), (B, nc, Q, N)) * 0.3
        yk, sk = ops.ssd_intra_chunk(xc, dtc, la, Bc, Cc)
        yr, sr = ref.ssd_intra_chunk(xc, dtc, la, Bc, Cc)
        err = max(float(jnp.max(jnp.abs(yk - yr))), float(jnp.max(jnp.abs(sk - sr))))
        rows.append({"kernel": "ssd_scan", "shape": f"B{B}_c{nc}x{Q}_H{H}_P{P}_N{N}",
                     "max_err": err,
                     "ms_kernel": round(_time(lambda: ops.ssd_intra_chunk(xc, dtc, la, Bc, Cc)), 2),
                     "ms_ref": round(_time(lambda: ref.ssd_intra_chunk(xc, dtc, la, Bc, Cc)), 2)})


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    rows: list = []
    print("== Pallas kernels vs jnp oracles (interpret mode) ==")
    bench_gossip_mix(rows)
    bench_gossip_mix_batched(rows)
    bench_decode_attention(rows)
    bench_ssd_scan(rows)
    bad = [r for r in rows if r["max_err"] > 2e-2]
    for r in rows:
        print("  " + json.dumps(r))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)
    if bad:
        raise SystemExit(f"kernel mismatch: {bad}")
    print("all kernels match their oracles.")


if __name__ == "__main__":
    main()
