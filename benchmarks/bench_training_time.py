"""DSGD time-to-accuracy across topologies — paper Table II / Figs 7–10.

Offline stand-in for CIFAR-10 + ResNet-18 (no dataset/GPU in the container):
a Gaussian-mixture classification task + 2-layer MLP trained with REAL DSGD
(the same gossip math as the production runtime), with wall-clock modeled by
the paper's Eq. 35 from its measured constants (t_comm = 5.01 ms,
t_comp = 15.21 ms). The paper's headline — BA-Topo reaches the accuracy
target in less modeled time than ring/grid/torus/exponential/equistatic —
is reproduced if the speedup column is > 1 for the best BA row.

  PYTHONPATH=src python -m benchmarks.bench_training_time --scenario homo
"""
from __future__ import annotations

import argparse
import json

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import intra_server_constraints, bcube_constraints
from repro.core.bandwidth import PaperConstants, t_epoch
from repro.core.graph import weight_matrix_from_weights
from repro.data import class_balanced_partition, make_classification_data
from repro.dsgd.gossip import gossip_sim_tree

from .common import NODE_BW_16, ba_topo, edge_b_min, paper_baselines

PC = PaperConstants()


def _init_mlp(key, dim: int, hidden: int, classes: int) -> dict:
    k1, k2 = jax.random.split(key)
    s1 = 1.0 / np.sqrt(dim)
    s2 = 1.0 / np.sqrt(hidden)
    return {"w1": jax.random.uniform(k1, (dim, hidden), minval=-s1, maxval=s1),
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.uniform(k2, (hidden, classes), minval=-s2, maxval=s2),
            "b2": jnp.zeros((classes,))}


def _logits(p, x):
    return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def _loss(p, x, y):
    lp = jax.nn.log_softmax(_logits(p, x))
    return -jnp.mean(jnp.take_along_axis(lp, y[:, None], axis=1))


def dsgd_accuracy_curve(topo, X, y, parts, Xte, yte, *, epochs: int, batch: int,
                        lr: float, momentum: float, seed: int):
    """Real DSGD on the stacked-worker layout; returns accuracy per epoch."""
    n = topo.n
    W = jnp.asarray(weight_matrix_from_weights(n, topo.edges, topo.g), jnp.float32)
    key = jax.random.PRNGKey(seed)
    p0 = _init_mlp(key, X.shape[1], 128, int(y.max()) + 1)
    params = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), p0)
    mom = jax.tree.map(jnp.zeros_like, params)

    grad_fn = jax.vmap(jax.grad(_loss))

    @jax.jit
    def step(params, mom, xb, yb):
        g = grad_fn(params, xb, yb)
        mom = jax.tree.map(lambda m, gg: momentum * m + gg, mom, g)
        params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        params = gossip_sim_tree(params, W)
        return params, mom

    @jax.jit
    def accuracy(params):
        mean = jax.tree.map(lambda a: a.mean(axis=0), params)
        pred = jnp.argmax(_logits(mean, Xte), axis=1)
        return jnp.mean(pred == yte)

    per = min(len(p) for p in parts)
    iters = per // batch
    accs = []
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        orders = [rng.permutation(p)[: iters * batch] for p in parts]
        for it in range(iters):
            xb = jnp.stack([X[o[it * batch:(it + 1) * batch]] for o in orders])
            yb = jnp.stack([y[o[it * batch:(it + 1) * batch]] for o in orders])
            params, mom = step(params, mom, xb, yb)
        accs.append(float(accuracy(params)))
    return np.asarray(accs), iters


def run(scenario: str, n: int, epochs: int, target: float, sa_iters: int,
        seed: int) -> list[dict]:
    cs = None
    node_bw = None
    if scenario == "node":
        node_bw = NODE_BW_16[:n]
    elif scenario == "intra":
        cs = intra_server_constraints(n)
    elif scenario == "bcube":
        cs = bcube_constraints(p=int(round(np.sqrt(n))), k=2)

    X, y = make_classification_data(num_classes=10, dim=64,
                                    samples_per_class=400, seed=seed)
    Xte, yte = make_classification_data(num_classes=10, dim=64,
                                        samples_per_class=64, seed=seed,
                                        noise_seed=seed + 10_001)
    parts = class_balanced_partition(y, n, seed=seed)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    Xtej, ytej = jnp.asarray(Xte), jnp.asarray(yte)

    topos = paper_baselines(n, scenario)
    budgets = {"homo": (16, 24, 32), "node": (16, 32, 48),
               "intra": (8, 12, 16), "bcube": (24, 48)}[scenario]
    for r in budgets:
        try:
            t = ba_topo(n, r, scenario, node_bw=node_bw, cs=cs, seed=seed,
                        sa_iters=sa_iters)
            t.meta["label"] = f"ba-topo(r={len(t.edges)})"
            topos.append(t)
        except Exception as e:
            print(f"  [warn] ba-topo r={r}: {e}")

    rows = []
    for topo in topos:
        accs, iters = dsgd_accuracy_curve(
            topo, Xj, yj, parts, Xtej, ytej, epochs=epochs, batch=32,
            lr=0.05, momentum=0.9, seed=seed)
        b_min = edge_b_min(topo, scenario, node_bw=node_bw, cs=cs)
        epoch_ms = t_epoch(b_min, iters, PC)
        hit = np.nonzero(accs >= target)[0]
        rows.append({
            "topology": topo.meta.get("label", topo.name),
            "edges": len(topo.edges), "r_asym": round(float(topo.r_asym()), 3),
            "b_min": round(b_min, 2), "epoch_ms": round(epoch_ms, 1),
            "final_acc": round(float(accs[-1]), 4),
            "t_target_s": round(float((hit[0] + 1) * epoch_ms / 1e3), 2)
            if hit.size else float("inf"),
        })
    best_ba = min((r["t_target_s"] for r in rows if "ba-topo" in r["topology"]),
                  default=float("inf"))
    best_other = min((r["t_target_s"] for r in rows
                      if "ba-topo" not in r["topology"]), default=float("inf"))
    for r in rows:
        r["speedup_vs_best_baseline"] = round(best_other / r["t_target_s"], 2) \
            if np.isfinite(r["t_target_s"]) else 0.0
    print(f"  BA-Topo best {best_ba}s vs best baseline {best_other}s → "
          f"speedup {best_other / best_ba if np.isfinite(best_ba) else 0:.2f}×")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="homo",
                    choices=["homo", "node", "intra", "bcube"])
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--target", type=float, default=0.8)
    ap.add_argument("--sa-iters", type=int, default=600)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    n = args.n or (8 if args.scenario == "intra" else 16)

    print(f"== DSGD time-to-accuracy, scenario={args.scenario}, n={n} "
          f"(paper Table II) ==")
    rows = run(args.scenario, n, args.epochs, args.target, args.sa_iters,
               args.seed)
    hdr = ["topology", "edges", "r_asym", "b_min", "epoch_ms", "final_acc",
           "t_target_s", "speedup_vs_best_baseline"]
    print(" | ".join(f"{h:>18}" for h in hdr))
    for row in sorted(rows, key=lambda r: r["t_target_s"]):
        print(" | ".join(f"{str(row[h]):>18}" for h in hdr))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
