"""DSGD time-to-accuracy across topologies — paper Table II / Figs 7–10.

Offline stand-in for CIFAR-10 + ResNet-18 (no dataset/GPU in the container):
a Gaussian-mixture classification task + 2-layer MLP trained with REAL DSGD
(the same gossip math as the production runtime), with wall-clock modeled by
the paper's Eq. 35 from its measured constants (t_comm = 5.01 ms,
t_comp = 15.21 ms). The paper's headline — BA-Topo reaches the accuracy
target in less modeled time than ring/grid/torus/exponential/equistatic —
is reproduced if the speedup column is > 1 for the best BA row.

Engines (``repro.dsgd.sim``, DESIGN.md §11):
  scan  (default) one batched device call: the epoch loop is a jitted
        ``lax.scan`` with on-device batch gathers, vmapped across the whole
        stacked-topology set.
  host  the seed per-iteration host loop (one step dispatch + ``jnp.stack``
        per iteration, serial per topology) — fallback and parity oracle.
  both  run host then scan on the SAME data/topologies and emit a compare
        row (speedup, final-accuracy drift, ranking match).

Gossip uses ``Topology.W`` (not ``weight_matrix_from_weights``), so
W-override topologies — the directed exponential graph — mix with their
actual weight matrix instead of silently degenerating to W = I.

  PYTHONPATH=src python -m benchmarks.bench_training_time --scenario homo
  PYTHONPATH=src python -m benchmarks.bench_training_time --engine both --json-out rows.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax.numpy as jnp

from repro.core.bandwidth import PaperConstants, t_epoch
from repro.data import class_balanced_partition, make_classification_data
from repro.dsgd.sim import DSGDSimConfig, accuracy_curve_host, accuracy_curves

from .common import edge_b_min, scenario_topologies

PC = PaperConstants()


def build_setup(scenario: str, n: int, sa_iters: int, seed: int, prof: dict):
    """Data + topology set shared by every engine; phases recorded in prof."""
    t0 = time.time()
    X, y = make_classification_data(num_classes=10, dim=64,
                                    samples_per_class=400, seed=seed)
    Xte, yte = make_classification_data(num_classes=10, dim=64,
                                        samples_per_class=64, seed=seed,
                                        noise_seed=seed + 10_001)
    parts = class_balanced_partition(y, n, seed=seed)
    data = (jnp.asarray(X), jnp.asarray(y), parts,
            jnp.asarray(Xte), jnp.asarray(yte))
    prof["data_s"] = round(time.time() - t0, 3)

    t0 = time.time()
    topos, node_bw, cs = scenario_topologies(n, scenario, sa_iters, seed)
    prof["topo_s"] = round(time.time() - t0, 3)
    return data, topos, node_bw, cs


def train_curves(engine: str, topos, data, epochs: int, seed: int, prof: dict):
    """Accuracy curves (T, epochs) for every topology under one engine."""
    Xj, yj, parts, Xtej, ytej = data
    cfg = DSGDSimConfig(epochs=epochs, batch=32, lr=0.05, momentum=0.9,
                        seed=seed)
    t0 = time.time()
    if engine == "scan":
        Ws = jnp.stack([jnp.asarray(t.W, jnp.float32) for t in topos])
        accs, iters = accuracy_curves(Ws, Xj, yj, parts, Xtej, ytej, cfg)
        accs = np.asarray(accs)
    elif engine == "host":
        curves = [accuracy_curve_host(jnp.asarray(t.W, jnp.float32),
                                      Xj, yj, parts, Xtej, ytej, cfg)
                  for t in topos]
        accs = np.stack([c[0] for c in curves])
        iters = curves[0][1]
    else:
        raise ValueError(f"unknown engine {engine!r}")
    prof["train_s"] = round(time.time() - t0, 3)
    return accs, iters


def run(scenario: str, n: int, epochs: int, target: float, sa_iters: int,
        seed: int, engine: str = "scan", profile: dict | None = None,
        _setup=None) -> list[dict]:
    prof = {} if profile is None else profile
    if _setup is None:
        _setup = build_setup(scenario, n, sa_iters, seed, prof)
    data, topos, node_bw, cs = _setup

    accs, iters = train_curves(engine, topos, data, epochs, seed, prof)

    rows = []
    for k, topo in enumerate(topos):
        b_min = edge_b_min(topo, scenario, node_bw=node_bw, cs=cs)
        epoch_ms = t_epoch(b_min, iters, PC)
        a = accs[k]
        hit = np.nonzero(a >= target)[0]
        rows.append({
            "topology": topo.meta.get("label", topo.name),
            "engine": engine,
            "edges": len(topo.edges), "r_asym": round(float(topo.r_asym()), 3),
            "b_min": round(b_min, 2), "epoch_ms": round(epoch_ms, 1),
            "final_acc": round(float(a[-1]), 4),
            "t_target_s": round(float((hit[0] + 1) * epoch_ms / 1e3), 2)
            if hit.size else float("inf"),
        })
    best_ba, best_other = _best_times(rows)
    for r in rows:
        r["speedup_vs_best_baseline"] = round(best_other / r["t_target_s"], 2) \
            if np.isfinite(r["t_target_s"]) else 0.0
    print(f"  [{engine}] BA-Topo best {best_ba}s vs best baseline "
          f"{best_other}s → speedup "
          f"{best_other / best_ba if np.isfinite(best_ba) else 0:.2f}×")
    return rows


def _best_times(rows: list[dict]) -> tuple[float, float]:
    """(best BA-Topo, best baseline) modeled time-to-accuracy over a row set."""
    best_ba = min((r["t_target_s"] for r in rows if "ba-topo" in r["topology"]),
                  default=float("inf"))
    best_other = min((r["t_target_s"] for r in rows
                      if "ba-topo" not in r["topology"]), default=float("inf"))
    return best_ba, best_other


def _fin(x: float) -> float | None:
    return round(float(x), 3) if np.isfinite(x) else None


def _summary_row(scenario: str, n: int, epochs: int, engine: str,
                 rows: list[dict], prof: dict, n_topos: int) -> dict:
    best_ba, best_other = _best_times(rows)
    total = prof.get("data_s", 0.0) + prof.get("topo_s", 0.0) + prof["train_s"]
    return {"bench": "training", "scenario": scenario, "n": n,
            "epochs": epochs, "engine": engine, "topologies": n_topos,
            "data_s": prof.get("data_s"), "topo_s": prof.get("topo_s"),
            "train_s": prof["train_s"], "total_s": round(total, 3),
            "best_ba_t_s": _fin(best_ba),
            "best_baseline_t_s": _fin(best_other),
            "paper_speedup": _fin(best_other / best_ba)
            if np.isfinite(best_ba) else None}


def compare_row(scenario: str, n: int, epochs: int,
                host: tuple[list[dict], dict],
                scan: tuple[list[dict], dict]) -> dict:
    """scan-vs-host acceptance row: wall-clock speedup, final-accuracy drift
    vs the oracle, and whether the modeled time-to-accuracy ranking agrees."""
    (h_rows, h_sum), (s_rows, s_sum) = host, scan
    drift = max(abs(h["final_acc"] - s["final_acc"])
                for h, s in zip(h_rows, s_rows))
    rank = lambda rows: [r["topology"] for r in
                         sorted(rows, key=lambda r: (r["t_target_s"], r["topology"]))]
    return {"bench": "training", "scenario": scenario, "n": n,
            "epochs": epochs, "engine": "scan-vs-host",
            "train_speedup": round(h_sum["train_s"] / max(s_sum["train_s"], 1e-9), 2),
            "total_speedup": round(h_sum["total_s"] / max(s_sum["total_s"], 1e-9), 2),
            "max_final_acc_drift": round(drift, 6),
            "ranking_match": rank(h_rows) == rank(s_rows)}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="homo",
                    choices=["homo", "node", "intra", "bcube"])
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--target", type=float, default=0.8)
    ap.add_argument("--sa-iters", type=int, default=600)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="scan", choices=["scan", "host", "both"],
                    help="scan = device-resident vmapped engine (default); "
                         "host = seed per-iteration loop (parity oracle); "
                         "both = run host then scan + a compare row")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    n = args.n or (8 if args.scenario == "intra" else 16)

    print(f"== DSGD time-to-accuracy, scenario={args.scenario}, n={n} "
          "(paper Table II) ==")
    prof_setup: dict = {}
    setup = build_setup(args.scenario, n, args.sa_iters, args.seed, prof_setup)
    engines = ["host", "scan"] if args.engine == "both" else [args.engine]

    all_rows: list[dict] = []
    per_engine: dict[str, tuple[list[dict], dict]] = {}
    hdr = ["topology", "edges", "r_asym", "b_min", "epoch_ms", "final_acc",
           "t_target_s", "speedup_vs_best_baseline"]
    for engine in engines:
        prof = dict(prof_setup)
        rows = run(args.scenario, n, args.epochs, args.target, args.sa_iters,
                   args.seed, engine=engine, profile=prof, _setup=setup)
        srow = _summary_row(args.scenario, n, args.epochs, engine, rows, prof,
                            len(setup[1]))
        per_engine[engine] = (rows, srow)
        all_rows += rows + [srow]
        print(f"  -- engine={engine}: train {prof['train_s']}s "
              f"(data {prof['data_s']}s, topo {prof['topo_s']}s) --")
        print(" | ".join(f"{h:>18}" for h in hdr))
        for row in sorted(rows, key=lambda r: r["t_target_s"]):
            print(" | ".join(f"{str(row[h]):>18}" for h in hdr))

    if args.engine == "both":
        crow = compare_row(args.scenario, n, args.epochs,
                           per_engine["host"], per_engine["scan"])
        all_rows.append(crow)
        print("  " + json.dumps(crow))

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(all_rows, f, indent=1)


if __name__ == "__main__":
    main()
