"""Anytime-pipeline benchmark: time-to-first-usable vs the barrier (DESIGN §17).

Two tracked comparisons at the ISSUE-3 acceptance point (n=64, 4 restarts):

  mode "first"   how long until the AnytimeSolver publishes its FIRST
                 release-valid incumbent (polled via ``next_improvement``)
                 vs the phase-barriered pipeline's total wall time — the
                 barrier produces nothing until everything finished, the
                 anytime path has a usable (classic-tier) topology almost
                 immediately. Also checks the UNBUDGETED anytime result's
                 r_asym drift against the barrier arm (must be ~0: the
                 unbudgeted stage graph replays the barrier bit-for-bit).

  mode "budget"  quality-vs-budget curve: solve the same request under
                 wall-clock budgets (default 50/200/1000 ms) and report
                 the incumbent's r_asym / quality tier / release validity.

Both engines are timed warm (compilation cached by problem shape — the
warmup solve touches every device stage either arm uses).

  PYTHONPATH=src python -m benchmarks.bench_anytime --nodes 64 --restarts 4
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import BATopoConfig, TopologyRequest, check_invariants, solve_topology
from repro.core.anytime import AnytimeSolver

DEFAULT_BUDGETS = (50.0, 200.0, 1000.0)


def _cfg(restarts: int, sa_iters: int, polish_iters: int,
         seed: int) -> BATopoConfig:
    # the shipped device pipeline defaults — same arm bench_pipeline tracks
    return BATopoConfig(sa_iters=sa_iters, polish_iters=polish_iters,
                        restarts=restarts, seed=seed)


def bench_first(n: int, r: int, cfg: BATopoConfig) -> dict:
    """Barrier total vs anytime time-to-first-valid-incumbent (warm)."""
    # warm every compile both arms touch, then time both arms fresh —
    # the barrier batches restarts (batch-R shapes) while the anytime
    # path solves restart-by-restart (batch-1 shapes), so each arm has
    # its own jit cache entries and each needs its own warmup drain
    solve_topology(TopologyRequest(n=n, r=r, scenario="homo"),
                   cfg=cfg, engine="barrier")
    AnytimeSolver(TopologyRequest(n=n, r=r, scenario="homo"), cfg).solve()

    t0 = time.perf_counter()
    barrier = solve_topology(TopologyRequest(n=n, r=r, scenario="homo"),
                             cfg=cfg, engine="barrier")
    barrier_ms = (time.perf_counter() - t0) * 1e3

    solver = AnytimeSolver(TopologyRequest(n=n, r=r, scenario="homo"), cfg)
    first = solver.next_improvement()
    first_ms = first.elapsed_ms if first is not None else float("inf")
    while solver.next_improvement() is not None:
        pass
    final = solver.result()

    drift = abs(float(final.r_asym) - float(barrier.r_asym))
    return {"bench": "anytime", "mode": "first", "n": n, "r": r,
            "scenario": "homo", "restarts": cfg.restarts,
            "sa_iters": cfg.sa_iters, "polish_iters": cfg.polish_iters,
            "barrier_total_ms": round(barrier_ms, 1),
            "anytime_first_ms": round(first_ms, 1),
            "anytime_total_ms": round(final.elapsed_ms, 1),
            "first_tier": first.quality_tier if first is not None else None,
            "first_r_asym": (round(float(first.r_asym), 6)
                             if first is not None else None),
            "first_speedup": round(barrier_ms / max(first_ms, 1e-6), 1),
            "final_r_asym": round(float(final.r_asym), 6),
            "barrier_r_asym": round(float(barrier.r_asym), 6),
            "anytime_final_drift": round(drift, 6),
            "improvements": final.improvements,
            "complete": bool(final.complete)}


def bench_budget(n: int, r: int, cfg: BATopoConfig, budget_ms: float) -> dict:
    """Quality at a wall-clock budget (warm caches assumed)."""
    res = solve_topology(TopologyRequest(n=n, r=r, scenario="homo"),
                         cfg=cfg, budget_ms=budget_ms)
    topo = res.topology
    valid = topo is not None and check_invariants(topo) is None
    return {"bench": "anytime", "mode": "budget", "n": n, "r": r,
            "scenario": "homo", "restarts": cfg.restarts,
            "budget_ms": budget_ms,
            "elapsed_ms": round(res.elapsed_ms, 1),
            "r_asym": round(float(res.r_asym), 6),
            "quality_tier": res.quality_tier,
            "improvements": res.improvements,
            "complete": bool(res.complete),
            "valid": bool(valid)}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", default="64",
                    help="comma-separated node counts (r = 2n each)")
    ap.add_argument("--restarts", type=int, default=4)
    ap.add_argument("--sa-iters", type=int, default=1500)
    ap.add_argument("--polish-iters", type=int, default=500)
    ap.add_argument("--budgets", default=None,
                    help="comma-separated budget_ms values "
                         "(default 50,200,1000)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    budgets = ([float(b) for b in args.budgets.split(",") if b]
               if args.budgets else list(DEFAULT_BUDGETS))
    cfg = _cfg(args.restarts, args.sa_iters, args.polish_iters, args.seed)

    print("== anytime pipeline: first-incumbent latency + quality-vs-budget ==")
    rows = []
    for n in [int(x) for x in args.nodes.split(",") if x]:
        r = 2 * n
        try:
            row = bench_first(n, r, cfg)
        except Exception as e:
            row = {"bench": "anytime", "mode": "first", "n": n,
                   "error": str(e)}
        rows.append(row)
        print("  " + json.dumps(row))
        try:
            # budgeted solves stream SA in chunks — a jit shape the
            # unbudgeted arms never touch; warm it before timing
            solve_topology(TopologyRequest(n=n, r=r, scenario="homo"),
                           cfg=cfg, budget_ms=budgets[0] if budgets else 50.0)
        except Exception:
            pass
        for budget in budgets:
            try:
                row = bench_budget(n, r, cfg, budget)
            except Exception as e:
                row = {"bench": "anytime", "mode": "budget", "n": n,
                       "budget_ms": budget, "error": str(e)}
            rows.append(row)
            print("  " + json.dumps(row))

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)

    failures = [r for r in rows if "error" in r]
    if failures:  # keep the CI smoke step a real gate
        raise SystemExit(f"{len(failures)} benchmark row(s) errored")


if __name__ == "__main__":
    main()
