"""ADMM solver scalability (§V-C): wall time + quality vs node count, and
paper-faithful BiCGSTAB+ILU X-step vs the matrix-free Schur-complement CG
(beyond-paper; DESIGN.md §6).

  PYTHONPATH=src python -m benchmarks.bench_admm --nodes 8,16,32,64
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.admm import ADMMConfig, HomogeneousADMM
from repro.core.api import extract_support, repair_selection
from repro.core.graph import all_edges, weight_matrix_from_weights, r_asym
from repro.core.weights import metropolis_weights, polish_weights


def solve_once(n: int, r: int, solver_kind: str, iters: int, seed: int) -> dict:
    cfg = ADMMConfig(max_iters=iters, solver=solver_kind)  # noqa: repeated for clarity
    solver = HomogeneousADMM(n, r, cfg)
    rng = np.random.default_rng(seed)
    m = len(all_edges(n))
    g0 = np.zeros(m)
    g0[rng.choice(m, size=min(r, m), replace=False)] = 1.0 / max(r, 1)
    t0 = time.time()
    res = solver.solve(g0=g0, lam0=0.3)
    dt = time.time() - t0
    sel = extract_support(n, res.g + res.g_raw, r, 1e-6)
    sel = repair_selection(n, sel, res.g + res.g_raw, None)
    edges = [e for e, s in zip(all_edges(n), sel) if s]
    g = polish_weights(n, edges, metropolis_weights(n, edges), iters=300) \
        if edges else np.zeros(0)
    W = weight_matrix_from_weights(n, edges, g)
    return {"n": n, "r": r, "solver": solver_kind, "solve_s": round(dt, 2),
            "admm_iters": res.iters, "residual": float(res.residual),
            "r_asym": round(float(r_asym(W)), 4) if edges else 1.0}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", default="8,16,32")
    ap.add_argument("--iters", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    print("== ADMM solver scalability (§V-C) ==")
    rows = []
    for n in [int(x) for x in args.nodes.split(",")]:
        for kind in ("kkt_bicgstab_ilu", "schur_cg"):
            try:
                row = solve_once(n, 2 * n, kind, args.iters, args.seed)
            except Exception as e:
                row = {"n": n, "solver": kind, "error": str(e)}
            rows.append(row)
            print("  " + json.dumps(row))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
