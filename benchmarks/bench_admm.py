"""ADMM solver engine benchmark (§V-C): wall time + quality across

  - X-step backends: paper-faithful BiCGSTAB+ILU vs matrix-free
    Schur-complement CG (beyond-paper; DESIGN.md §3),
  - drivers: the seed per-iteration host loop vs the device-resident
    scan-compiled driver (DESIGN.md §4),
  - batched restarts: ``solve_batched`` over K warm starts vs the same K
    restarts solved sequentially.

Timing modes (reported per row in ``timing``):
  - the seed driver is timed as the seed shipped it — the step is jitted
    per solve (the seed jitted per solver *instance*, so every benchmark
    solve and every optimize_topology restart recompiled);
  - the scan driver is timed warm — its compilation is keyed on the
    ProblemSpec structure and cached across solves, which is the point;
  - ``--steady-state`` additionally times the python loop with a shared
    jit cache, isolating pure per-iteration dispatch/sync overhead.

  PYTHONPATH=src python -m benchmarks.bench_admm --nodes 8,16,32 --batch 4
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import engine as E
from repro.core.admm import ADMMConfig, HomogeneousADMM
from repro.core.api import extract_support, repair_selection
from repro.core.graph import all_edges, weight_matrix_from_weights, r_asym
from repro.core.weights import metropolis_weights, polish_weights


def _warm_starts(n: int, r: int, batch: int, seed: int):
    m = len(all_edges(n))
    rng = np.random.default_rng(seed)
    g0s = np.zeros((batch, m))
    for b in range(batch):
        g0s[b, rng.choice(m, size=min(r, m), replace=False)] = 1.0 / max(r, 1)
    lam0s = np.full(batch, 0.3)
    return g0s, lam0s


def _postprocess(n: int, r: int, res) -> float:
    sel = extract_support(n, res.g + res.g_raw, r, 1e-6)
    sel = repair_selection(n, sel, res.g + res.g_raw, None)
    edges = [e for e, s in zip(all_edges(n), sel) if s]
    if not edges:
        return 1.0
    g = polish_weights(n, edges, metropolis_weights(n, edges), iters=300)
    return float(r_asym(weight_matrix_from_weights(n, edges, g)))


def _row_perf(row: dict, cfg: ADMMConfig, dt: float, res) -> dict:
    """Uniform machine-readable perf fields (tracked across PRs via
    ``benchmarks.run --json``): per-iteration wall time, CG iterations per
    ADMM step, solver-stack configuration, final quality."""
    iters = max(res.iters, 1)
    row.update({
        "psd_backend": cfg.psd_backend, "dtype": cfg.dtype,
        "precond": cfg.precond, "cg_inexact": cfg.cg_inexact,
        "ms_per_iter": round(dt / iters * 1e3, 3),
        "cg_per_step": round(res.cg_iters / iters, 2),
        "admm_iters": res.iters, "residual": float(res.residual),
    })
    return row


def solve_once(n: int, r: int, solver_kind: str, driver: str, iters: int,
               seed: int, steady_state: bool = False) -> dict:
    cfg = ADMMConfig(max_iters=iters, solver=solver_kind, driver=driver)
    solver = HomogeneousADMM(n, r, cfg)
    g0s, lam0s = _warm_starts(n, r, 1, seed)
    g0, lam0 = g0s[0], float(lam0s[0])

    if driver == "scan":
        solver.solve(g0=g0, lam0=lam0)  # compile once; cached across solves
        timing = "warm (compile cached across solves)"
        t0 = time.time()
        res = solver.solve(g0=g0, lam0=lam0)
        dt = time.time() - t0
    elif driver == "python" and solver_kind != "kkt_bicgstab_ilu":
        state = solver.init_state(g0, lam0)
        if steady_state:
            E.solve_python(solver.spec, state, cfg, reuse_jit=True)  # warm
            timing = "steady-state (shared jit)"
            t0 = time.time()
            res = E.solve_python(solver.spec, state, cfg, reuse_jit=True)
            dt = time.time() - t0
        else:
            # seed cost structure: the seed jitted per solver instance,
            # so every solve recompiled
            timing = "per-solve jit (seed behaviour)"
            t0 = time.time()
            res = E.solve_python(solver.spec, state, cfg, reuse_jit=False)
            dt = time.time() - t0
    else:
        # ILU backend: factorization happens per solver, as in the seed
        timing = "per-solve setup (seed behaviour)"
        t0 = time.time()
        res = solver.solve(g0=g0, lam0=lam0)
        dt = time.time() - t0

    row = {"n": n, "r": r, "solver": solver_kind, "driver": driver,
           "timing": timing, "solve_s": round(dt, 3),
           "r_asym": round(_postprocess(n, r, res), 4)}
    return _row_perf(row, cfg, dt, res)


def bench_fast(n: int, r: int, iters: int, seed: int) -> dict:
    """Acceptance comparison (ISSUE 2): steady-state per-iteration time of
    the fast solver stack (Jacobi+inexact CG, fp32 loop) vs the PR-1 engine.

    The PR-1 engine is reconstructed exactly: exact fp64 CG to ``cg_tol``
    with no preconditioner, ``eigh`` projections, and the seed's scatter-add
    ``L(g)`` (a spec without the packed-index map falls back to it). Both
    run the warm scan driver, so the delta is purely the solver stack.
    Also reports the fused-gather exact fp64 path (the new default) and the
    r_asym drift of the fast path vs the fp64 exact path.

    Uses the API pipeline's structured warm start (greedy degree graph +
    Metropolis weights) rather than the random-support warm start of the
    other rows: from a good basin both precisions converge to the same
    support, so the drift check is meaningful (with random warm starts the
    nonconvex iteration limit-cycles and ANY bit-level difference — even
    between two exact fp64 backends — diverges the trajectories;
    DESIGN.md §4/§9).
    """
    from repro.core.anneal import greedy_degree_graph
    from repro.core.graph import edge_index

    rng = np.random.default_rng(seed)
    edges0 = greedy_degree_graph(n, np.full(n, max(2 * r // n, 2)), rng)
    eidx = edge_index(n)
    g0 = np.zeros(len(all_edges(n)))
    gm = metropolis_weights(n, edges0)
    for k, e in enumerate(edges0):
        g0[eidx[e]] = gm[k]
    lam0 = 0.3

    def timed(cfg, spec_patch=None):
        solver = HomogeneousADMM(n, r, cfg)
        spec = solver.spec if spec_patch is None else solver.spec.replace(**spec_patch)
        state = E.init_state(spec, g0, lam0)
        E.solve_spec(spec, state, cfg)  # compile
        t0 = time.time()
        res = E.solve_spec(spec, state, cfg)
        return time.time() - t0, res

    pr1_cfg = ADMMConfig(max_iters=iters, precond="none")
    t_pr1, res_pr1 = timed(pr1_cfg, spec_patch={"lidx": None})
    exact_cfg = ADMMConfig(max_iters=iters, precond="none")
    t_exact, res_exact = timed(exact_cfg)
    fast_cfg = ADMMConfig(max_iters=iters, precond="jacobi", cg_inexact=True,
                          dtype="float32")
    t_fast, res_fast = timed(fast_cfg)

    r_exact = _postprocess(n, r, res_exact)
    r_fast = _postprocess(n, r, res_fast)
    # per-iteration ratios: eps-based early stopping can give the compared
    # runs different iteration counts, so total-wall-time ratios would
    # conflate convergence speed with per-iteration cost
    ms_pr1 = t_pr1 / max(res_pr1.iters, 1) * 1e3
    ms_exact = t_exact / max(res_exact.iters, 1) * 1e3
    ms_fast = t_fast / max(res_fast.iters, 1) * 1e3
    row = {"n": n, "r": r, "solver": "schur_cg", "driver": "scan",
           "timing": "fast-compare (steady state)",
           "pr1_ms_per_iter": round(ms_pr1, 3),
           "exact_ms_per_iter": round(ms_exact, 3),
           "speedup_vs_pr1": round(ms_pr1 / max(ms_fast, 1e-9), 2),
           "speedup_vs_exact": round(ms_exact / max(ms_fast, 1e-9), 2),
           "r_asym": round(r_fast, 4), "r_asym_exact": round(r_exact, 4),
           "r_asym_drift": abs(r_fast - r_exact)}
    return _row_perf(row, fast_cfg, t_fast, res_fast)


def bench_batched(n: int, r: int, batch: int, iters: int, seed: int) -> dict:
    """solve_batched over ``batch`` restarts vs the same restarts solved
    sequentially — by the seed driver (per-solve jit, the seed's restart
    loop rebuilt the solver each time) and by the scan driver (warm)."""
    g0s, lam0s = _warm_starts(n, r, batch, seed)
    scan_solver = HomogeneousADMM(n, r, ADMMConfig(max_iters=iters))
    seed_cfg = ADMMConfig(max_iters=iters, driver="python")
    seed_solver = HomogeneousADMM(n, r, seed_cfg)

    scan_solver.solve_batched(g0s, lam0s)  # compile
    t0 = time.time()
    batched = scan_solver.solve_batched(g0s, lam0s)
    t_batched = time.time() - t0

    # the seed's restart loop rebuilt the solver (and its jit) per restart
    t0 = time.time()
    serial = [E.solve_python(seed_solver.spec,
                             seed_solver.init_state(g0s[b], float(lam0s[b])),
                             seed_cfg, reuse_jit=False)
              for b in range(batch)]
    t_serial_seed = time.time() - t0

    scan_solver.solve(g0=g0s[0], lam0=lam0s[0])  # compile (unbatched shape)
    t0 = time.time()
    for b in range(batch):
        scan_solver.solve(g0=g0s[b], lam0=lam0s[b])
    t_serial_scan = time.time() - t0

    best_batched = min(_postprocess(n, r, res) for res in batched)
    best_serial = min(_postprocess(n, r, res) for res in serial)
    return {"n": n, "r": r, "batch": batch,
            "batched_s": round(t_batched, 3),
            "serial_seed_s": round(t_serial_seed, 3),
            "serial_scan_s": round(t_serial_scan, 3),
            "speedup_vs_seed": round(t_serial_seed / max(t_batched, 1e-9), 2),
            "speedup_vs_scan": round(t_serial_scan / max(t_batched, 1e-9), 2),
            "r_asym_batched": round(best_batched, 4),
            "r_asym_serial": round(best_serial, 4)}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", default="8,16,32")
    ap.add_argument("--iters", type=int, default=150)
    ap.add_argument("--solvers", default="kkt_bicgstab_ilu,schur_cg")
    ap.add_argument("--drivers", default="python,scan",
                    help="seed per-iteration loop (python) and/or scan")
    ap.add_argument("--batch", type=int, default=0,
                    help="also run the batched-restarts benchmark with this batch size")
    ap.add_argument("--fast-nodes", default="",
                    help="comma-separated node counts for the fast-compare rows "
                         "(Jacobi+inexact+fp32 vs the PR-1 engine, steady state)")
    ap.add_argument("--steady-state", action="store_true",
                    help="time the python driver with a shared jit cache "
                         "instead of the seed's per-solve jit")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    drivers = [d for d in args.drivers.split(",") if d]
    print("== ADMM solver engine (§V-C): backends × drivers ==")
    rows = []
    for n in [int(x) for x in args.nodes.split(",") if x]:
        r = 2 * n
        for kind in args.solvers.split(","):
            per_driver = {}
            for driver in (drivers if kind != "kkt_bicgstab_ilu" else ["python"]):
                try:
                    row = solve_once(n, r, kind, driver, args.iters, args.seed,
                                     steady_state=args.steady_state)
                    per_driver[driver] = row["solve_s"]
                except Exception as e:
                    row = {"n": n, "solver": kind, "driver": driver, "error": str(e)}
                rows.append(row)
                print("  " + json.dumps(row))
            if "python" in per_driver and "scan" in per_driver:
                sp = per_driver["python"] / max(per_driver["scan"], 1e-9)
                baseline = ("steady-state python loop" if args.steady_state
                            else "seed driver")
                key = ("scan_speedup_vs_steady" if args.steady_state
                       else "scan_speedup_vs_seed")
                rows.append({"n": n, "solver": kind, key: round(sp, 2)})
                print(f"  -> n={n} {kind}: scan is {sp:.2f}x the {baseline}")

    if args.fast_nodes:
        print("== fast solver stack vs PR-1 engine (steady state / iter) ==")
        for n in [int(x) for x in args.fast_nodes.split(",") if x]:
            try:
                row = bench_fast(n, 2 * n, args.iters, args.seed)
            except Exception as e:
                row = {"n": n, "timing": "fast-compare", "error": str(e)}
            rows.append(row)
            print("  " + json.dumps(row))

    if args.batch > 1:
        print(f"== batched restarts (B={args.batch}) vs sequential solves ==")
        for n in [int(x) for x in args.nodes.split(",") if x]:
            try:
                row = bench_batched(n, 2 * n, args.batch, args.iters, args.seed)
            except Exception as e:
                row = {"n": n, "batch": args.batch, "error": str(e)}
            rows.append(row)
            print("  " + json.dumps(row))

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)

    failures = [r for r in rows if "error" in r]
    if failures:  # keep the CI smoke step a real gate
        raise SystemExit(f"{len(failures)} benchmark row(s) errored")


if __name__ == "__main__":
    main()
