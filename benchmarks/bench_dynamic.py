"""Beyond-paper: time-varying (round-robin matching) gossip vs static BA-Topo.

Evaluates, under the paper's own bandwidth model (§VI):
  static:       every step applies full W — per-node sends = deg(i),
                per-edge bandwidth b/deg (homogeneous sharing rule),
                consensus factor r_asym(W) per step;
  round-robin:  one matching per step — ≤1 send/node, per-edge bandwidth = b
                (node's full bandwidth), contraction ρ(ΠW_c)^(1/R) per step.

Reports modeled time to consensus 1e-4 for both. The paper's §VII names
dynamic topologies as future work; this is the natural TPU-native variant
(each matching is ONE collective-permute).

  PYTHONPATH=src python -m benchmarks.bench_dynamic
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.bandwidth import PaperConstants, t_iter
from repro.dsgd.dynamic import cycle_contraction, cycle_weight_matrices, round_robin_schedules
from repro.launch.steps import topology_for

PC = PaperConstants()


def simulate(Ws: list[np.ndarray], iters: int, seed: int = 0) -> np.ndarray:
    n = Ws[0].shape[0]
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 16))
    errs = [np.linalg.norm(x - x.mean(0))]
    for k in range(iters):
        x = Ws[k % len(Ws)] @ x
        errs.append(np.linalg.norm(x - x.mean(0)))
    return np.asarray(errs)


def run(n: int, r: int, seed: int) -> dict:
    topo = topology_for(n, kind="ba", r=r, seed=seed)
    from repro.core.graph import weight_matrix_from_weights
    from repro.core.bandwidth import homo_edge_bandwidth, min_edge_bandwidth

    W = weight_matrix_from_weights(n, topo.edges, topo.g)
    scheds = round_robin_schedules(topo)
    R = len(scheds)

    # static: b_min under degree sharing
    b_min_static = min_edge_bandwidth(homo_edge_bandwidth(topo))
    t_static = t_iter(b_min_static, PC)
    # round-robin: each node talks to ≤1 peer per step → full bandwidth
    t_rr = t_iter(PC.b_avail, PC)

    errs_static = simulate([W], 400)
    errs_rr = simulate(cycle_weight_matrices(scheds), 400 * R)

    def t_to(errs, per_ms):
        rel = errs / errs[0]
        hit = np.nonzero(rel <= 1e-4)[0]
        return float(hit[0] * per_ms) if hit.size else float("inf")

    rho_static = float(np.max(np.abs(np.linalg.eigvals(W - np.ones((n, n)) / n))))
    return {
        "n": n, "r": len(topo.edges), "rounds": R,
        "r_asym_static": round(rho_static, 4),
        "cycle_contraction": round(cycle_contraction(scheds), 4),
        "per_step_ms": {"static": round(t_static, 2), "round_robin": round(t_rr, 2)},
        "t_consensus_ms": {"static": round(t_to(errs_static, t_static), 1),
                           "round_robin": round(t_to(errs_rr, t_rr), 1)},
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--r", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    rows = []
    for n in (args.n,) if args.n else (8, 16, 32):
        row = run(n, args.r, args.seed)
        rows.append(row)
        print(json.dumps(row))
        ts = row["t_consensus_ms"]
        if np.isfinite(ts["round_robin"]) and ts["round_robin"] < ts["static"]:
            print(f"  → round-robin reaches consensus "
                  f"{ts['static'] / ts['round_robin']:.2f}× faster under Eq. 34")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
