"""Beyond-paper: time-varying (round-robin matching) gossip vs static, on the
device-resident cross-product engine (DESIGN.md §12).

For every topology of the scenario's §VI comparison set (paper baselines +
BA-Topo budgets — 9 topologies for homo n=16), two runs enter ONE vmapped
dispatch: the static topology (length-1 cycle, full W every step) and its
round-robin matching decomposition (cycle tensor, one matching per step).
Under the paper's Eq. 34 time model:

  static:       per-node sends = deg(i), per-edge bandwidth b/deg
                (degree-sharing rule), consensus factor r_asym(W) per step;
  round-robin:  ≤1 send/node — a matching edge gets the FULL node bandwidth
                min(b_i, b_j) (constraint scenarios re-divide the medium
                among the matching's edges), contraction ρ(ΠW_c)^(1/R).

Sections: consensus (modeled time to 1e-4) and DSGD time-to-accuracy
(``--train-epochs``, the Table-II protocol with the per-step comm time
cycling over the matchings). ``--engine host`` runs the per-iteration host
loops (parity oracles); ``--engine both`` adds a scan-vs-host compare row —
the tracked perf row of BENCH_admm.json.

  PYTHONPATH=src python -m benchmarks.bench_dynamic
  PYTHONPATH=src python -m benchmarks.bench_dynamic --engine both --json-out rows.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax.numpy as jnp

from repro.core.bandwidth import PaperConstants, t_iter
from repro.data import class_balanced_partition, make_classification_data
from repro.dsgd.dynamic import (
    cycle_contraction,
    cycle_weight_matrices,
    round_robin_schedules,
    static_cycle,
)
from repro.dsgd.sim import (
    CommSpec,
    DSGDSimConfig,
    accuracy_curve_host_cross,
    consensus_curve_host_cross,
    consensus_curves_cross,
    train_curves_cross,
)

from .common import dynamic_step_times, edge_b_min, scenario_topologies

PC = PaperConstants()
DENSE = CommSpec()


def build_runs(topos, scenario, node_bw, cs):
    """One run dict per (topology, mode): cycle tensor + per-step comm times.

    Directed baselines (the exponential graph's W override) have no symmetric
    matching decomposition — they appear in static mode only.
    """
    runs = []
    for topo in topos:
        label = topo.meta.get("label", topo.name)
        b_min = edge_b_min(topo, scenario, node_bw=node_bw, cs=cs)
        runs.append({
            "topology": label, "mode": "static",
            "cycle": static_cycle(topo.W), "rounds": 1,
            "step_ms": np.array([t_iter(b_min, PC)]),
            "contraction_per_step": float(topo.r_asym()),
        })
        if topo.meta.get("directed"):
            continue
        scheds = round_robin_schedules(topo)
        rho_cycle = cycle_contraction(scheds)
        runs.append({
            "topology": label, "mode": "round_robin",
            "cycle": np.stack(cycle_weight_matrices(scheds)),
            "rounds": len(scheds),
            "step_ms": dynamic_step_times(topo, scheds, scenario,
                                          node_bw=node_bw, cs=cs),
            "contraction_per_step": rho_cycle ** (1.0 / len(scheds)),
        })
    return runs


def _t_to(errs: np.ndarray, step_ms: np.ndarray, target: float) -> float:
    """Modeled ms until the relative consensus error reaches the target;
    per-step cost cycles over the matching times."""
    rel = errs / errs[0]
    hit = np.nonzero(rel <= target)[0]
    if not hit.size:
        return float("inf")
    k = int(hit[0])                               # error after k steps
    if k == 0:
        return 0.0
    return float(step_ms[np.arange(k) % len(step_ms)].sum())


def consensus_section(runs, engine, n, iters, target, seed, prof):
    """Consensus curves for all runs; fills t_consensus_ms per run."""
    rng = np.random.default_rng(seed)
    x0 = rng.normal(size=(n, 16))
    t0 = time.time()
    if engine == "scan":
        errs = consensus_curves_cross([r["cycle"] for r in runs],
                                      np.ones(len(runs)), DENSE, x0, iters,
                                      seed=seed)
    else:
        errs = np.stack([consensus_curve_host_cross(r["cycle"], 1.0, DENSE,
                                                    x0, iters, seed=seed)
                         for r in runs])
    prof["consensus_s"] = round(time.time() - t0, 3)
    out = []
    for r, e in zip(runs, errs):
        row = {"topology": r["topology"], "mode": r["mode"],
               "rounds": r["rounds"], "engine": engine,
               "contraction_per_step": round(r["contraction_per_step"], 4),
               "per_step_ms": round(float(np.mean(r["step_ms"])), 3),
               "t_consensus_ms": round(_t_to(e, r["step_ms"], target), 1)}
        out.append(row)
    return out, errs


def training_section(runs, engine, data, epochs, target_acc, seed, prof):
    """DSGD time-to-accuracy (Table-II protocol) for all runs."""
    X, y, parts, Xte, yte = data
    cfg = DSGDSimConfig(epochs=epochs, batch=32, lr=0.05, momentum=0.9,
                        seed=seed)
    t0 = time.time()
    if engine == "scan":
        accs, iters = train_curves_cross([r["cycle"] for r in runs],
                                         np.ones(len(runs)), DENSE,
                                         X, y, parts, Xte, yte, cfg)
        accs = np.asarray(accs)
    else:
        curves = [accuracy_curve_host_cross(r["cycle"], 1.0, DENSE,
                                            X, y, parts, Xte, yte, cfg)
                  for r in runs]
        accs = np.stack([c[0] for c in curves])
        iters = curves[0][1]
    prof["train_s"] = round(time.time() - t0, 3)

    out = []
    for r, a in zip(runs, accs):
        # per-step comm cycles over the matchings; compute is per iteration
        steps = epochs * iters
        per_step = r["step_ms"][np.arange(steps) % len(r["step_ms"])] \
            + PC.t_comp_ms
        cum = np.cumsum(per_step)
        hit = np.nonzero(a >= target_acc)[0]
        t_target = float(cum[(hit[0] + 1) * iters - 1] / 1e3) \
            if hit.size else float("inf")
        out.append({"topology": r["topology"], "mode": r["mode"],
                    "engine": engine, "final_acc": round(float(a[-1]), 4),
                    "epoch_ms": round(float(per_step[:iters].sum()), 1),
                    "t_target_s": round(t_target, 2)
                    if np.isfinite(t_target) else float("inf")})
    return out, accs


def _best(rows, mode, key):
    vals = [r[key] for r in rows if r["mode"] == mode and np.isfinite(r[key])]
    return round(min(vals), 3) if vals else None


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="homo",
                    choices=["homo", "node", "intra", "bcube"])
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--iters", type=int, default=250,
                    help="consensus iteration budget per static step; the "
                         "shared budget is iters × max cycle length")
    ap.add_argument("--target", type=float, default=1e-4)
    ap.add_argument("--train-epochs", type=int, default=6,
                    help="DSGD time-to-accuracy epochs (0 disables)")
    ap.add_argument("--target-acc", type=float, default=0.8)
    ap.add_argument("--sa-iters", type=int, default=600)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="scan",
                    choices=["scan", "host", "both"],
                    help="scan = one vmapped device dispatch per section; "
                         "host = per-iteration loops (parity oracle); "
                         "both = host then scan + a compare row")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    print(f"== dynamic round-robin vs static gossip, scenario={args.scenario} "
          f"n={args.n} (engine={args.engine}) ==")
    t0 = time.time()
    topos, node_bw, cs = scenario_topologies(args.n, args.scenario,
                                             args.sa_iters, args.seed)
    runs = build_runs(topos, args.scenario, node_bw, cs)
    topo_s = round(time.time() - t0, 3)
    iters = args.iters * max(r["rounds"] for r in runs)

    data = None
    if args.train_epochs > 0:
        X, y = make_classification_data(num_classes=10, dim=64,
                                        samples_per_class=400, seed=args.seed)
        Xte, yte = make_classification_data(num_classes=10, dim=64,
                                            samples_per_class=64,
                                            seed=args.seed,
                                            noise_seed=args.seed + 10_001)
        parts = class_balanced_partition(y, args.n, seed=args.seed)
        data = (jnp.asarray(X), jnp.asarray(y), parts,
                jnp.asarray(Xte), jnp.asarray(yte))

    engines = ["host", "scan"] if args.engine == "both" else [args.engine]
    all_rows: list[dict] = []
    per_engine: dict[str, dict] = {}
    for engine in engines:
        prof = {"topo_s": topo_s, "train_s": 0.0}
        crows, errs = consensus_section(runs, engine, args.n, iters,
                                        args.target, args.seed, prof)
        trows, taccs = ([], None)
        if data is not None:
            trows, taccs = training_section(runs, engine, data,
                                            args.train_epochs,
                                            args.target_acc, args.seed, prof)
        by_key = {(t["topology"], t["mode"]): t for t in trows}
        for row in crows:
            row.update({k: v for k, v in
                        by_key.get((row["topology"], row["mode"]), {}).items()
                        if k in ("final_acc", "epoch_ms", "t_target_s")})
        summary = {
            "bench": "dynamic", "scenario": args.scenario, "n": args.n,
            "engine": engine, "runs": len(runs), "iters": iters,
            "train_epochs": args.train_epochs,
            "consensus_s": prof["consensus_s"], "train_s": prof["train_s"],
            "total_s": round(prof["consensus_s"] + prof["train_s"], 3),
            "best_static_t_consensus_ms": _best(crows, "static",
                                                "t_consensus_ms"),
            "best_rr_t_consensus_ms": _best(crows, "round_robin",
                                            "t_consensus_ms"),
        }
        if summary["best_rr_t_consensus_ms"] \
                and summary["best_static_t_consensus_ms"]:
            summary["rr_consensus_gain"] = round(
                summary["best_static_t_consensus_ms"]
                / summary["best_rr_t_consensus_ms"], 2)
        if trows:
            summary["best_static_t_target_s"] = _best(trows, "static",
                                                      "t_target_s")
            summary["best_rr_t_target_s"] = _best(trows, "round_robin",
                                                  "t_target_s")
        per_engine[engine] = {"rows": crows, "errs": errs, "accs": taccs,
                              "summary": summary}
        all_rows += crows + [summary]
        hdr = ["topology", "mode", "rounds", "contraction_per_step",
               "per_step_ms", "t_consensus_ms"] \
            + (["final_acc", "t_target_s"] if trows else [])
        print(f"  -- engine={engine}: consensus {prof['consensus_s']}s, "
              f"train {prof['train_s']}s --")
        print(" | ".join(f"{h:>20}" for h in hdr))
        for row in crows:
            print(" | ".join(f"{str(row.get(h)):>20}" for h in hdr))

    if args.engine == "both":
        h, s = per_engine["host"], per_engine["scan"]
        e0 = h["errs"][:, :1]
        drift = float(np.max(np.abs(h["errs"] - s["errs"]) / e0))
        crow = {"bench": "dynamic", "scenario": args.scenario, "n": args.n,
                "engine": "scan-vs-host",
                "speedup": round(h["summary"]["total_s"]
                                 / max(s["summary"]["total_s"], 1e-9), 2),
                "consensus_speedup": round(
                    h["summary"]["consensus_s"]
                    / max(s["summary"]["consensus_s"], 1e-9), 2),
                "max_rel_curve_drift": float(f"{drift:.3g}")}
        if h["accs"] is not None:
            crow["train_speedup"] = round(
                h["summary"]["train_s"] / max(s["summary"]["train_s"], 1e-9), 2)
            crow["max_final_acc_drift"] = round(
                float(np.max(np.abs(h["accs"][:, -1] - s["accs"][:, -1]))), 6)
        all_rows.append(crow)
        print("  " + json.dumps(crow))

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(all_rows, f, indent=1)


if __name__ == "__main__":
    main()
