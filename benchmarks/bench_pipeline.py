"""End-to-end ``optimize_topology`` pipeline benchmark (DESIGN.md §10).

Compares the device-resident outer pipeline (batched SA warm starts,
vmapped scan-compiled weight polish, Lanczos spectral evaluation) against
the PR-2 host pipeline (per-restart Python SA + serial host polish — the
``warmstart="host"``/``polish="host"`` parity oracle), reporting a
per-phase wall-time breakdown:

  warm start / ADMM / round+repair / polish / eval

The device row is timed warm (its compilations are keyed on problem shape
and cached across solves, which is the point); the host pipeline has no
device-side outer phases to warm up — its ADMM scan driver shares the
already-warm jit cache, so the comparison isolates the outer pipeline.

  PYTHONPATH=src python -m benchmarks.bench_pipeline --nodes 64 --restarts 4
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import ADMMConfig, BATopoConfig, TopologyRequest, solve_topology

PHASES = ("warm_s", "admm_s", "round_s", "polish_s", "eval_s")


def _solve_homo(n: int, r: int, cfg: BATopoConfig, prof: dict | None = None):
    """One phase-barriered solve (this benchmark measures exactly the
    barrier pipeline, so it pins ``engine="barrier"``)."""
    return solve_topology(TopologyRequest(n=n, r=r, scenario="homo"),
                          cfg=cfg, profile=prof, engine="barrier").topology


def _cfg(mode: str, restarts: int, sa_iters: int, polish_iters: int,
         admm_iters: int, seed: int) -> BATopoConfig:
    if mode == "device":
        # the PR-3 pipeline exactly as shipped: BATopoConfig defaults
        # (device SA + device polish + the pipeline-default ADMM stack)
        return BATopoConfig(sa_iters=sa_iters, polish_iters=polish_iters,
                            restarts=restarts, seed=seed)
    # the PR-2 baseline pipeline: host SA + host polish + the exact
    # paper-faithful solver defaults (fp64, cg_tol-exact CG, --admm-iters)
    return BATopoConfig(admm=ADMMConfig(max_iters=admm_iters),
                        sa_iters=sa_iters, polish_iters=polish_iters,
                        restarts=restarts, seed=seed,
                        warmstart="host", polish="host")


def warm_caches(n: int, r: int, restarts: int, sa_iters: int,
                polish_iters: int, admm_iters: int, seed: int) -> None:
    """Compile every device-side stage both rows touch before timing
    EITHER mode, so neither row is billed for one-off jit compiles:
    the device row's SA scan / batched ADMM / polish vmap, and the host
    row's batched ADMM shape (exact fp64 at --admm-iters — ``max_iters``
    and the spec dtype are jit cache keys, so it compiles separately)."""
    cfg = _cfg("device", restarts, sa_iters, polish_iters, admm_iters, seed)
    _solve_homo(n, r, cfg)
    # host warm start/polish (no jit of their own) at token iteration
    # counts, so this warms ONLY the host row's ADMM shape — device-mode
    # SA/polish here would trace fresh iters-keyed variants for nothing
    host_admm = BATopoConfig(admm=ADMMConfig(max_iters=admm_iters),
                             sa_iters=10, polish_iters=10,
                             restarts=restarts, seed=seed,
                             warmstart="host", polish="host")
    _solve_homo(n, r, host_admm)


def run_pipeline(n: int, r: int, mode: str, restarts: int, sa_iters: int,
                 polish_iters: int, admm_iters: int, seed: int) -> dict:
    cfg = _cfg(mode, restarts, sa_iters, polish_iters, admm_iters, seed)
    prof: dict = {}
    t0 = time.time()
    topo = _solve_homo(n, r, cfg, prof)
    total = time.time() - t0
    row = {"bench": "pipeline", "n": n, "r": r, "scenario": "homo",
           "pipeline": mode, "restarts": restarts, "sa_iters": sa_iters,
           "polish_iters": polish_iters,
           "admm_iters": cfg.admm.max_iters, "admm_dtype": cfg.admm.dtype,
           "admm_cg_inexact": cfg.admm.cg_inexact,
           "total_s": round(total, 3),
           "r_asym": round(float(topo.meta["r_asym"]), 6),
           "selected_from": topo.meta.get("selected_from")}
    for k in PHASES:
        row[k] = round(prof.get(k, 0.0), 3)
    largest = max(PHASES, key=lambda k: row[k])
    row["largest_phase"] = largest.removesuffix("_s")
    return row


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", default="64",
                    help="comma-separated node counts (r = 2n each)")
    ap.add_argument("--restarts", type=int, default=4)
    ap.add_argument("--sa-iters", type=int, default=1500)
    ap.add_argument("--polish-iters", type=int, default=500)
    ap.add_argument("--admm-iters", type=int, default=1500,
                    help="ADMM budget of the HOST baseline row only — the "
                         "device row always runs the shipped pipeline "
                         "default stack (see api._pipeline_admm_default)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    print("== optimize_topology outer pipeline: device vs host phases ==")
    rows = []
    for n in [int(x) for x in args.nodes.split(",") if x]:
        r = 2 * n
        per_mode = {}
        try:
            warm_caches(n, r, args.restarts, args.sa_iters,
                        args.polish_iters, args.admm_iters, args.seed)
        except Exception as e:
            rows.append({"bench": "pipeline", "n": n, "pipeline": "warmup",
                         "error": str(e)})
            print("  " + json.dumps(rows[-1]))
            continue
        for mode in ("host", "device"):
            try:
                row = run_pipeline(n, r, mode, args.restarts, args.sa_iters,
                                   args.polish_iters, args.admm_iters,
                                   args.seed)
                per_mode[mode] = row
            except Exception as e:
                row = {"bench": "pipeline", "n": n, "pipeline": mode,
                       "error": str(e)}
            rows.append(row)
            print("  " + json.dumps(row))
        if "host" in per_mode and "device" in per_mode:
            h, d = per_mode["host"], per_mode["device"]
            cmp_row = {
                "bench": "pipeline", "n": n, "r": r,
                "pipeline": "device-vs-host",
                "restarts": args.restarts,
                "speedup": round(h["total_s"] / max(d["total_s"], 1e-9), 2),
                "warm_speedup": round(h["warm_s"] / max(d["warm_s"], 1e-9), 2),
                "r_asym_device": d["r_asym"], "r_asym_host": h["r_asym"],
                "r_asym_drift": round(abs(d["r_asym"] - h["r_asym"]), 6),
                "device_largest_phase": d["largest_phase"],
            }
            rows.append(cmp_row)
            print("  " + json.dumps(cmp_row))

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)

    failures = [r for r in rows if "error" in r]
    if failures:  # keep the CI smoke step a real gate
        raise SystemExit(f"{len(failures)} benchmark row(s) errored")


if __name__ == "__main__":
    main()
