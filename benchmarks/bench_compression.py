"""Beyond-paper: CHOCO compressed gossip × BA-Topo, on the device-resident
cross-product engine (DESIGN.md §12).

Measures consensus error vs TRANSMITTED BYTES (the quantity the paper's
bandwidth model turns into time) for the scenario's full §VI comparison set
(9 topologies for homo n=16) × {dense, top-k, random-k} × a γ grid — each
compressor family is ONE vmapped dispatch over its (topology, γ) cross
product. Dense gossip moves d floats per edge per iteration; CHOCO moves
ω·d, so modeled per-iteration time (Eq. 34) scales by ω. The top-k family
also runs the round-robin DYNAMIC cycles (compressed × time-varying — the
full cross product: per-step matrix gathered by step index, matching edges
at full node bandwidth scaled by ω).

``--engine host`` replays the per-iteration host loop (one step dispatch +
``float()`` sync per iteration, early-stopped at the target — the seed bench
behaviour) as the parity oracle; ``--engine both`` adds the scan-vs-host
compare row tracked in BENCH_admm.json.

  PYTHONPATH=src python -m benchmarks.bench_compression
  PYTHONPATH=src python -m benchmarks.bench_compression --engine both --json-out rows.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.bandwidth import PaperConstants, t_iter
from repro.dsgd.compression import choco_gamma
from repro.dsgd.dynamic import (
    cycle_weight_matrices,
    round_robin_schedules,
    static_cycle,
)
from repro.dsgd.sim import (
    CommSpec,
    consensus_curve_host_cross,
    consensus_curves_cross,
)

from .common import dynamic_step_times, edge_b_min, scenario_topologies

PC = PaperConstants()

#: The compressor families of the cross product. Dense is the γ=1 reference;
#: top-10% additionally runs the round-robin dynamic cycles.
FAMILIES = [
    (CommSpec(), ("static",)),
    (CommSpec("top_k", 0.25), ("static",)),
    (CommSpec("top_k", 0.10), ("static", "round_robin")),
    (CommSpec("random_k", 0.10), ("static",)),
]


def gamma_grid(spec: CommSpec, topo, lam2: float) -> list[float]:
    """Candidate γ per (compressor, topology): the CHOCO theory value plus a
    line grid — the theory bound γ = δ/(8+δ) is very loose in practice."""
    if not spec.choco:
        return [1.0]
    return [choco_gamma(topo, lam2), 0.2, 0.4, 0.6, 0.8]


def build_runs(topos, scenario, node_bw, cs):
    """One run dict per (topology, mode, compressor, γ), grouped by family."""
    lam2s, cycles_rr, steps_rr = {}, {}, {}
    for topo in topos:
        W = np.asarray(topo.W, dtype=np.float64)
        lam2s[topo.name] = 1.0 - float(
            np.sort(np.abs(np.linalg.eigvals(W)))[-2])
        if not topo.meta.get("directed"):
            scheds = round_robin_schedules(topo)
            cycles_rr[topo.name] = np.stack(cycle_weight_matrices(scheds))
            steps_rr[topo.name] = dynamic_step_times(
                topo, scheds, scenario, node_bw=node_bw, cs=cs)

    families = []
    for spec, modes in FAMILIES:
        runs = []
        for topo in topos:
            label = topo.meta.get("label", topo.name)
            b_min = edge_b_min(topo, scenario, node_bw=node_bw, cs=cs)
            for mode in modes:
                if mode == "round_robin" and topo.name not in cycles_rr:
                    continue
                if mode == "static":
                    cycle = static_cycle(topo.W)
                    step_ms = np.array([t_iter(b_min, PC)])
                    sends = 2.0 * len(topo.edges) / topo.n   # mean deg
                else:
                    cycle = cycles_rr[topo.name]
                    step_ms = steps_rr[topo.name]
                    sends = 1.0                              # ≤1 send/node
                for g in gamma_grid(spec, topo, lam2s[topo.name]):
                    runs.append({"topology": label, "mode": mode,
                                 "gamma": float(g), "cycle": cycle,
                                 "step_ms": step_ms, "sends": sends})
        families.append((spec, runs))
    return families


def _iters_to(errs: np.ndarray, target: float) -> int | None:
    hit = np.nonzero(errs / errs[0] <= target)[0]
    return int(hit[0]) if hit.size else None


def run_family(spec, runs, engine, x0, iters, target, seed, dim):
    """All runs of one compressor family; returns (per-best rows, curves)."""
    if engine == "scan":
        errs = consensus_curves_cross([r["cycle"] for r in runs],
                                      [r["gamma"] for r in runs],
                                      spec, x0, iters, seed=seed)
    else:
        # seed behaviour: serial loops, early-stopped at the target
        errs = np.full((len(runs), iters + 1), np.nan)
        for b, r in enumerate(runs):
            e = consensus_curve_host_cross(r["cycle"], r["gamma"], spec, x0,
                                           iters, seed=seed, stop_rel=target)
            errs[b, :len(e)] = e
    rows = {}
    for r, e in zip(runs, errs):
        it = _iters_to(e[~np.isnan(e)], target)
        key = (r["topology"], r["mode"])
        if key in rows and not (it is not None
                                and (rows[key]["iters_to_target"] is None
                                     or it < rows[key]["iters_to_target"])):
            continue
        step_ms = r["step_ms"]
        # per-step comm cycles over the matchings (same rule as
        # bench_dynamic), scaled by the transmitted fraction ω
        t_ms = float(step_ms[np.arange(it) % len(step_ms)].sum()
                     * spec.ratio) if it is not None else float("inf")
        rows[key] = {
            "topology": r["topology"], "mode": r["mode"],
            "compressor": spec.name, "ratio": spec.ratio, "engine": engine,
            "gamma": round(r["gamma"], 3), "iters_to_target": it,
            "bytes_per_edge_iter": round(spec.ratio * dim * 4),
            "t_consensus_ms": round(t_ms, 1)
            if np.isfinite(t_ms) else float("inf"),
            "bytes_to_target_node": round(it * spec.ratio * dim * 4
                                          * r["sends"])
            if it is not None else None,
        }
    return list(rows.values()), errs


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="homo",
                    choices=["homo", "node", "intra", "bcube"])
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "float64"],
                    help="gossip payload dtype (float32 = what DSGD params "
                         "actually move; the time/bytes model is dtype-free)")
    ap.add_argument("--target", type=float, default=1e-3)
    ap.add_argument("--sa-iters", type=int, default=600)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="scan",
                    choices=["scan", "host", "both"],
                    help="scan = one vmapped dispatch per compressor family; "
                         "host = per-iteration loop (parity oracle); "
                         "both = host then scan + a compare row")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    print(f"== CHOCO compressed gossip × BA-Topo, scenario={args.scenario} "
          f"n={args.n} dim={args.dim} (engine={args.engine}) ==")
    topos, node_bw, cs = scenario_topologies(args.n, args.scenario,
                                             args.sa_iters, args.seed)
    families = build_runs(topos, args.scenario, node_bw, cs)
    n_runs = sum(len(r) for _, r in families)
    rng = np.random.default_rng(args.seed)
    x0 = rng.normal(size=(args.n, args.dim)).astype(args.dtype)

    engines = ["host", "scan"] if args.engine == "both" else [args.engine]
    all_rows: list[dict] = []
    per_engine: dict[str, dict] = {}
    hdr = ["topology", "mode", "compressor", "gamma", "iters_to_target",
           "t_consensus_ms", "bytes_to_target_node"]
    for engine in engines:
        t0 = time.time()
        rows, curves = [], []
        for spec, runs in families:
            frows, errs = run_family(spec, runs, engine, x0, args.iters,
                                     args.target, args.seed, args.dim)
            rows += frows
            curves.append(errs)
        wall = round(time.time() - t0, 3)
        dense_best = min((r["t_consensus_ms"] for r in rows
                          if r["compressor"] == "dense"
                          and np.isfinite(r["t_consensus_ms"])),
                         default=float("inf"))
        comp_best = min((r for r in rows if r["compressor"] != "dense"
                         and np.isfinite(r["t_consensus_ms"])),
                        key=lambda r: r["t_consensus_ms"], default=None)
        summary = {"bench": "compression", "scenario": args.scenario,
                   "n": args.n, "dim": args.dim, "engine": engine,
                   "runs": n_runs, "iters": args.iters, "total_s": wall,
                   "best_dense_t_ms": round(dense_best, 1),
                   "best_compressed_t_ms":
                       comp_best["t_consensus_ms"] if comp_best else None,
                   "best_compressed":
                       f"{comp_best['compressor']}/{comp_best['mode']}"
                       if comp_best else None}
        if comp_best:
            summary["compressed_gain"] = round(
                dense_best / comp_best["t_consensus_ms"], 2)
        per_engine[engine] = {"rows": rows, "curves": curves,
                              "summary": summary}
        all_rows += rows + [summary]
        print(f"  -- engine={engine}: {wall}s, {n_runs} runs --")
        print(" | ".join(f"{h:>20}" for h in hdr))
        for row in sorted(rows, key=lambda r: (r["topology"], r["mode"],
                                               r["compressor"])):
            print(" | ".join(f"{str(row.get(h)):>20}" for h in hdr))

    if args.engine == "both":
        h, s = per_engine["host"], per_engine["scan"]
        drift = 0.0
        for eh, es in zip(h["curves"], s["curves"]):
            e0 = eh[:, :1]
            # host stops early at the target; γ-divergent runs (rel error
            # blowing past 1e2) amplify op-fusion ULPs chaotically and carry
            # no information — parity is judged on the stable prefix
            m = ~np.isnan(eh) & (eh <= 1e2 * e0)
            drift = max(drift, float(np.max(
                np.abs(np.where(m, eh, 0.0) - np.where(m, es, 0.0))
                / e0)))
        crow = {"bench": "compression", "scenario": args.scenario,
                "n": args.n, "engine": "scan-vs-host",
                "speedup": round(h["summary"]["total_s"]
                                 / max(s["summary"]["total_s"], 1e-9), 2),
                "max_rel_curve_drift": float(f"{drift:.3g}")}
        all_rows.append(crow)
        print("  " + json.dumps(crow))

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(all_rows, f, indent=1)


if __name__ == "__main__":
    main()
