"""Beyond-paper: CHOCO compressed gossip × BA-Topo.

Measures consensus error vs TRANSMITTED BYTES (the quantity the paper's
bandwidth model turns into time): dense gossip moves d floats per edge per
iteration; CHOCO with top-k moves ω·d. Reports modeled time to consensus
1e-3 under Eq. 34 with per-iteration time scaled by ω.

  PYTHONPATH=src python -m benchmarks.bench_compression
"""
from __future__ import annotations

import argparse
import json

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.bandwidth import PaperConstants, t_iter
from repro.core.bandwidth import homo_edge_bandwidth, min_edge_bandwidth
from repro.core.graph import weight_matrix_from_weights
from repro.dsgd.compression import (
    choco_gamma,
    choco_gossip_init,
    choco_gossip_step,
    identity_compressor,
    top_k_compressor,
)
from repro.launch.steps import topology_for

PC = PaperConstants()


def run(n: int, r: int, dim: int, iters: int, target: float, seed: int) -> list[dict]:
    topo = topology_for(n, kind="ba", r=r, seed=seed)
    W = jnp.asarray(weight_matrix_from_weights(n, topo.edges, topo.g), jnp.float32)
    lam2 = 1.0 - float(np.sort(np.abs(np.linalg.eigvals(np.asarray(W))))[-2])
    b_min = min_edge_bandwidth(homo_edge_bandwidth(topo))
    t_dense_ms = t_iter(b_min, PC)

    x0 = jax.random.normal(jax.random.PRNGKey(seed), (n, dim))
    target_abs = target * float(jnp.linalg.norm(x0 - x0.mean(0)))

    def iters_to(comp, gamma):
        state = choco_gossip_init(x0)
        key = jax.random.PRNGKey(seed + 1)
        for k in range(iters):
            key, sub = jax.random.split(key)
            state = choco_gossip_step(state, W, comp, gamma, sub)
            if float(jnp.linalg.norm(state.x - state.x.mean(0))) <= target_abs:
                return k + 1
        return None

    rows = []
    for comp in (identity_compressor(), top_k_compressor(0.25),
                 top_k_compressor(0.10)):
        if comp.ratio == 1.0:
            best_g, best_it = 1.0, iters_to(comp, 1.0)
        else:
            # γ line search: the theory bound γ=δ/(8+δ) is very loose here
            best_g, best_it = None, None
            for g in (choco_gamma(topo, lam2), 0.2, 0.4, 0.6, 0.8):
                it = iters_to(comp, g)
                if it is not None and (best_it is None or it < best_it):
                    best_g, best_it = g, it
        per_iter_ms = t_dense_ms * comp.ratio
        rows.append({
            "compressor": comp.name, "ratio": comp.ratio,
            "gamma": round(best_g, 3) if best_g else None,
            "iters_to_target": best_it,
            "bytes_per_edge_iter": round(comp.ratio * dim * 4),
            "t_consensus_ms": round(best_it * per_iter_ms, 1) if best_it else float("inf"),
        })
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--r", type=int, default=32)
    ap.add_argument("--dim", type=int, default=2048)
    ap.add_argument("--iters", type=int, default=3000)
    ap.add_argument("--target", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    print(f"== CHOCO compressed gossip on BA-Topo(n={args.n}, r={args.r}) ==")
    rows = run(args.n, args.r, args.dim, args.iters, args.target, args.seed)
    for row in rows:
        print("  " + json.dumps(row))
    dense = rows[0]["t_consensus_ms"]
    best = min(rows, key=lambda r: r["t_consensus_ms"])
    if best["compressor"] != "dense" and np.isfinite(best["t_consensus_ms"]):
        print(f"  → {best['compressor']} reaches consensus "
              f"{dense / best['t_consensus_ms']:.2f}× faster in modeled time")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
