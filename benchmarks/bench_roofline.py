"""Roofline table from the dry-run artifacts (deliverable (g)).

Reads benchmarks/artifacts/dryrun_*.json (written by repro.launch.dryrun)
and prints the three-term roofline per (arch × shape × mesh) with the
dominant bottleneck and the MODEL_FLOPS/analytic-FLOPs useful ratio.

  PYTHONPATH=src python -m benchmarks.bench_roofline
  PYTHONPATH=src python -m benchmarks.bench_roofline --mesh 2x16x16
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")


def load(mesh: str | None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "dryrun_*.json"))):
        tag = os.path.basename(path)[len("dryrun_"):-len(".json")]
        if mesh and not tag.startswith(mesh):
            continue
        with open(path) as f:
            recs.extend(json.load(f))
    return recs


def fmt_row(r: dict) -> str:
    if "skipped" in r:
        return (f"{r['arch']:>22} {r['shape']:>12} {r['mesh']:>8} "
                f"{'—':>10} {'—':>10} {'—':>10} {'skip':>10}  {r['skipped']}")
    if "error" in r:
        return (f"{r['arch']:>22} {r['shape']:>12} {r['mesh']:>8} "
                f"{'—':>10} {'—':>10} {'—':>10} {'FAIL':>10}  {r['error'][:60]}")
    return (f"{r['arch']:>22} {r['shape']:>12} {r['mesh']:>8} "
            f"{r['compute_s']:>10.2e} {r['memory_s']:>10.2e} "
            f"{r['collective_s']:>10.2e} {r['dominant']:>10} "
            f"useful={r['useful_ratio']:.2f} hbm/dev={r.get('hbm_per_device_gb', '—')}GB")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    recs = load(args.mesh)
    if not recs:
        print("no dry-run artifacts found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun")
        return
    print(f"{'arch':>22} {'shape':>12} {'mesh':>8} {'compute_s':>10} "
          f"{'memory_s':>10} {'collect_s':>10} {'dominant':>10}")
    for r in recs:
        print(fmt_row(r))
    ok = [r for r in recs if "compute_s" in r]
    if ok:
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"\n{len(ok)} compiled combos; dominant-term histogram: {doms}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(recs, f, indent=1)


if __name__ == "__main__":
    main()
