"""Perf-regression gate: fresh `run.py --json` rows vs the committed baseline.

BENCH_admm.json is the perf-trajectory file committed across PRs; CI used to
upload fresh rows as artifacts without ever checking them. This gate loads
both files, matches rows by their identity fields (bench/n/solver/driver/
engine/…), and fails when a tracked metric regresses beyond its tolerance
band:

  - absolute timings (ms_per_iter, solve_s, total_s, …) may drift a lot
    between machines (the baseline was measured on the committing dev's box,
    CI runners vary ~2-3×), so they get a WIDE band: fresh ≤ base × tol-time.
  - speedup ratios (scan vs seed, device vs host, scan vs host) are
    machine-relative and therefore the real gate: fresh ≥ base / tol-ratio.
  - parity drifts (r_asym_drift, max_final_acc_drift, max_rel_curve_drift)
    must stay inside max(base × tol-ratio, floor) — an engine that silently
    diverges from its oracle fails even if it got faster.
  - boolean parity flags (ranking_match) must not flip to False.

Baseline rows with no fresh counterpart fail the gate (a tracked benchmark
silently dropped is itself a regression); fresh rows with no baseline are
reported but pass (new benchmarks land before their first committed rows).

  PYTHONPATH=src python -m benchmarks.run --json fresh.json
  PYTHONPATH=src python -m benchmarks.check_regression --fresh fresh.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: Fields that identify a row (subset present varies by bench).
ID_FIELDS = ("bench", "n", "r", "solver", "driver", "timing", "scenario",
             "engine", "pipeline", "psd_backend", "dtype", "precond",
             "cg_inexact", "restarts", "epochs", "train_epochs", "dim",
             "runs", "iters", "topologies", "compressor", "mode",
             "partition", "devices", "budget_ms")

#: Metric → direction. "time" = lower is better, wide band (machine speed);
#: "ratio" = higher is better, tight band (machine-relative speedups);
#: "drift" = lower is better, tight band with an absolute floor.
METRICS = {
    "ms_per_iter": "time", "solve_s": "time", "pr1_ms_per_iter": "time",
    "exact_ms_per_iter": "time", "total_s": "time", "train_s": "time",
    "consensus_s": "time", "data_s": "time", "topo_s": "time",
    "warm_s": "time", "admm_s": "time", "polish_s": "time", "eval_s": "time",
    "round_s": "time",
    "scan_speedup_vs_seed": "ratio", "speedup_vs_pr1": "ratio",
    "speedup_vs_exact": "ratio", "speedup": "ratio", "warm_speedup": "ratio",
    "train_speedup": "ratio", "total_speedup": "ratio",
    "consensus_speedup": "ratio",
    "speedup_sharded": "ratio", "ns_vs_eigh": "ratio",
    "reopt_gain": "ratio", "time_to_reopt_s": "time",
    "cold_ms": "time", "hit_p50_ms": "time", "p50_ms": "time",
    "p99_ms": "time", "cache_speedup": "ratio", "cache_hit_rate": "ratio",
    "anytime_first_ms": "time", "first_speedup": "ratio",
    "r_asym_drift": "drift", "max_final_acc_drift": "drift",
    "max_rel_curve_drift": "drift", "degraded_frac": "drift",
    "elastic_parity_drift": "drift", "anytime_final_drift": "drift",
}

#: Absolute floors below which drift comparisons are noise (the curve floor
#: covers f32-payload fusion noise over hundreds of gossip iterations; real
#: engine/oracle divergence shows up orders of magnitude above it).
DRIFT_FLOORS = {"r_asym_drift": 5e-3, "max_final_acc_drift": 0.02,
                "max_rel_curve_drift": 1e-4,
                # the seeded fault mix injects faults by RNG roll, so the
                # degraded fraction wobbles a little run to run
                "degraded_frac": 0.15,
                # the fault-free elastic step is the plain trainer bit-exactly
                # — NO floor: any nonzero loss gap is a real divergence
                "elastic_parity_drift": 0.0,
                # ISSUE-10 acceptance band: the unbudgeted anytime result
                # must track the barrier pipeline to ≤ 1e-3 in r_asym
                "anytime_final_drift": 1e-3}

# ("complete" is deliberately NOT gated: whether a budgeted solve finished
# inside its wall-clock budget is machine-speed-dependent; "valid" is not —
# an anytime result must be release-valid at ANY budget.)
BOOL_FLAGS = ("ranking_match", "all_valid", "resume_exactness", "valid")


def row_key(row: dict) -> tuple:
    return tuple((k, row[k]) for k in ID_FIELDS if k in row)


def check_row(base: dict, fresh: dict, tol_time: float,
              tol_ratio: float) -> list[str]:
    problems = []
    for metric, kind in METRICS.items():
        if metric not in base or metric not in fresh:
            continue
        b, f = base[metric], fresh[metric]
        if b is None or f is None:
            continue
        if kind == "time" and f > b * tol_time:
            problems.append(f"{metric}: {f} > baseline {b} × {tol_time}")
        elif kind == "ratio" and f < b / tol_ratio:
            problems.append(f"{metric}: {f} < baseline {b} / {tol_ratio}")
        elif kind == "drift":
            limit = max(b * tol_ratio, DRIFT_FLOORS.get(metric, 0.0))
            if f > limit:
                problems.append(f"{metric}: {f} > max(baseline {b} × "
                                f"{tol_ratio}, floor {DRIFT_FLOORS.get(metric)})")
    for flag in BOOL_FLAGS:
        if base.get(flag) is True and fresh.get(flag) is False:
            problems.append(f"{flag}: flipped True → False")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True,
                    help="rows from a fresh `benchmarks.run --json` run")
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(__file__), "..",
                                         "BENCH_admm.json"),
                    help="committed baseline (default: repo BENCH_admm.json)")
    ap.add_argument("--tol-time", type=float, default=5.0,
                    help="absolute-timing band: fresh ≤ base × tol "
                         "(wide — CI runners vary)")
    ap.add_argument("--tol-ratio", type=float, default=2.0,
                    help="speedup/drift band: speedups ≥ base / tol, "
                         "drifts ≤ base × tol (machine-relative)")
    ap.add_argument("--only-bench", default=None,
                    help="comma-separated bench names: gate ONLY baseline "
                         "rows whose 'bench' field is in this set (used by "
                         "the dedicated sharded-smoke CI step)")
    ap.add_argument("--skip-bench", default=None,
                    help="comma-separated bench names to EXCLUDE from the "
                         "gate (the main CI gate skips 'scalability' — its "
                         "rows come from a separate multi-device step, not "
                         "from `run --json`)")
    ap.add_argument("--max-n", type=int, default=None,
                    help="ignore baseline rows with n larger than this "
                         "(CI smoke runs the small-n subset of a bench)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    only = set(args.only_bench.split(",")) if args.only_bench else None
    skip = set(args.skip_bench.split(",")) if args.skip_bench else set()

    def gated(row: dict) -> bool:
        b = row.get("bench")
        if only is not None and b not in only:
            return False
        if b in skip:
            return False
        if args.max_n is not None and isinstance(row.get("n"), int) \
                and row["n"] > args.max_n:
            return False
        return True

    baseline = [r for r in baseline if gated(r)]

    fresh_by_key = {row_key(r): r for r in fresh}
    failures, checked = [], 0
    for brow in baseline:
        key = row_key(brow)
        frow = fresh_by_key.get(key)
        label = ", ".join(f"{k}={v}" for k, v in key)
        if frow is None:
            failures.append(f"[{label}] tracked row MISSING from fresh run")
            continue
        checked += 1
        for p in check_row(brow, frow, args.tol_time, args.tol_ratio):
            failures.append(f"[{label}] {p}")
    base_keys = {row_key(r) for r in baseline}
    new = [row_key(r) for r in fresh
           if gated(r) and row_key(r) not in base_keys]
    for key in new:
        print("  new (unbaselined) row: "
              + ", ".join(f"{k}={v}" for k, v in key))

    print(f"check_regression: {checked}/{len(baseline)} baseline rows "
          f"matched, {len(new)} new rows, {len(failures)} failure(s)")
    for fail in failures:
        print("  FAIL " + fail)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
