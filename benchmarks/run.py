"""Benchmark driver: one entry per paper table/figure + system benches.

  PYTHONPATH=src python -m benchmarks.run            # quick versions of all
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale settings

Individual benchmarks (full CLIs):
  benchmarks.bench_consensus      Figs 1 / 2 / 4 / 6
  benchmarks.bench_scalability    Table I
  benchmarks.bench_training_time  Table II, Figs 7–10
  benchmarks.bench_admm           §V-C solver scalability
  benchmarks.bench_pipeline       outer-pipeline phase breakdown (DESIGN §10)
  benchmarks.bench_kernels        Pallas kernels vs oracles
  benchmarks.bench_roofline       dry-run roofline table (deliverable g)
"""
from __future__ import annotations

import argparse
import os
import time

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow: ~1h)")
    ap.add_argument("--json", default=None, metavar="BENCH_admm.json",
                    help="run ONLY the tracked perf benchmarks (ADMM solver "
                         "grid + outer-pipeline phase breakdown + DSGD "
                         "training-engine compare) and write their "
                         "machine-readable rows (n, solver, psd_backend, "
                         "dtype, ms_per_iter, cg_per_step, r_asym, phase "
                         "timings, train_speedup, …) to this path — the perf "
                         "trajectory file committed across PRs")
    ap.add_argument("--sharded", action="store_true",
                    help="with --json: also run the multi-device sharded-ADMM "
                         "partition compare at n=256/512/1024 (spawns an "
                         "8-simulated-device subprocess; slow — used when "
                         "refreshing the committed baseline, while CI gates "
                         "a dedicated n=512 smoke subset)")
    args = ap.parse_args(argv)
    os.makedirs(ART, exist_ok=True)
    quick = not args.full

    if args.json:
        import json as _json
        import tempfile

        from . import (bench_admm, bench_anytime, bench_chaos,
                       bench_compression, bench_dynamic, bench_elastic,
                       bench_pipeline, bench_service, bench_training_time)
        # Fixed, quick configuration so rows stay comparable across PRs:
        # backend×driver grid at n=16/32 + the fast-compare row at n=64,
        # the end-to-end outer-pipeline rows (device vs host phase
        # breakdown at the ISSUE-3 acceptance point: n=64, 4 restarts),
        # the DSGD training-engine compare at the ISSUE-4 acceptance
        # point (homo, n=16, default epochs; host oracle vs scan engine),
        # and the ISSUE-5 cross-product engines (dynamic round-robin and
        # CHOCO compression at the homo n=16 / 9-topology tracked point,
        # scan vs host-loop compare rows). Only engine-level summary and
        # compare rows are tracked; per-topology rows stay in artifacts.
        with tempfile.TemporaryDirectory() as td:
            bench_admm.main(["--nodes", "16,32", "--iters", "60",
                             "--fast-nodes", "64",
                             "--json-out", f"{td}/admm.json"])
            bench_pipeline.main(["--nodes", "64", "--restarts", "4",
                                 "--json-out", f"{td}/pipeline.json"])
            bench_anytime.main(["--nodes", "64", "--restarts", "4",
                                "--json-out", f"{td}/anytime.json"])
            bench_training_time.main(["--scenario", "homo", "--engine", "both",
                                      "--json-out", f"{td}/training.json"])
            bench_dynamic.main(["--engine", "both",
                                "--json-out", f"{td}/dynamic.json"])
            bench_compression.main(["--engine", "both",
                                    "--json-out", f"{td}/compression.json"])
            bench_chaos.main(["--engine", "both",
                              "--json-out", f"{td}/chaos.json"])
            bench_elastic.main(["--json-out", f"{td}/elastic.json"])
            bench_service.main(["--json-out", f"{td}/service.json"])
            rows = (_json.load(open(f"{td}/admm.json"))
                    + _json.load(open(f"{td}/pipeline.json"))
                    + _json.load(open(f"{td}/anytime.json"))
                    + [r for r in _json.load(open(f"{td}/training.json"))
                       if r.get("bench") == "training"]
                    + [r for r in _json.load(open(f"{td}/dynamic.json"))
                       if r.get("bench") == "dynamic"]
                    + [r for r in _json.load(open(f"{td}/compression.json"))
                       if r.get("bench") == "compression"]
                    + [r for r in _json.load(open(f"{td}/chaos.json"))
                       if r.get("bench") == "chaos"]
                    + [r for r in _json.load(open(f"{td}/elastic.json"))
                       if r.get("bench") == "elastic"]
                    + [r for r in _json.load(open(f"{td}/service.json"))
                       if r.get("bench") == "service"])
            if args.sharded:
                from . import bench_scalability
                bench_scalability.main(
                    ["--nodes", "", "--partition-nodes", "256,512,1024",
                     "--json-out", f"{td}/sharded.json"])
                rows += _json.load(open(f"{td}/sharded.json"))
        with open(args.json, "w") as f:
            _json.dump(rows, f, indent=1)
        print("tracked ADMM + pipeline + anytime + training + dynamic "
              "+ compression + chaos + elastic + service perf rows "
              f"written to {args.json}")
        return

    from . import (bench_admm, bench_compression, bench_consensus,
                   bench_dynamic, bench_kernels, bench_pipeline,
                   bench_roofline, bench_scalability, bench_training_time)

    t0 = time.time()
    sa = "300" if quick else "1500"

    for scenario in (["homo", "node"] if quick else ["homo", "node", "intra", "bcube"]):
        print(f"\n### bench_consensus --scenario {scenario}")
        bench_consensus.main(["--scenario", scenario, "--sa-iters", sa,
                              "--iters", "300" if quick else "600",
                              "--json-out", f"{ART}/consensus_{scenario}.json"])

    print("\n### bench_scalability (Table I)")
    bench_scalability.main(["--nodes", "4,8,16" if quick else "4,8,16,32,64,128",
                            "--sa-iters", sa,
                            "--json-out", f"{ART}/scalability.json"])

    print("\n### bench_training_time (Table II)")
    for scenario in (["homo"] if quick else ["homo", "node", "intra", "bcube"]):
        bench_training_time.main(["--scenario", scenario,
                                  "--epochs", "12" if quick else "40",
                                  "--sa-iters", sa,
                                  "--json-out", f"{ART}/training_{scenario}.json"])

    print("\n### bench_admm (§V-C)")
    bench_admm.main(["--nodes", "8,16" if quick else "8,16,32,64",
                     "--iters", "100" if quick else "400",
                     "--json-out", f"{ART}/admm.json"])

    print("\n### bench_pipeline (outer-pipeline phase breakdown, DESIGN §10)")
    if quick:
        bench_pipeline.main(["--nodes", "24", "--restarts", "2",
                             "--sa-iters", "300", "--polish-iters", "150",
                             "--admm-iters", "200",
                             "--json-out", f"{ART}/pipeline.json"])
    else:
        bench_pipeline.main(["--nodes", "64", "--restarts", "4",
                             "--json-out", f"{ART}/pipeline.json"])

    print("\n### bench_anytime (budgeted best-so-far pipeline, DESIGN §17)")
    from . import bench_anytime
    if quick:
        bench_anytime.main(["--nodes", "24", "--restarts", "2",
                            "--sa-iters", "300", "--polish-iters", "150",
                            "--json-out", f"{ART}/anytime.json"])
    else:
        bench_anytime.main(["--nodes", "64", "--restarts", "4",
                            "--json-out", f"{ART}/anytime.json"])

    print("\n### bench_dynamic (beyond-paper: time-varying gossip)")
    bench_dynamic.main(["--json-out", f"{ART}/dynamic.json"])

    print("\n### bench_compression (beyond-paper: CHOCO gossip)")
    bench_compression.main(["--iters", "800" if quick else "3000",
                            "--json-out", f"{ART}/compression.json"])

    print("\n### bench_chaos (beyond-paper: faults + online re-optimization)")
    from . import bench_chaos
    bench_chaos.main(["--json-out", f"{ART}/chaos.json"])

    print("\n### bench_elastic (elastic real-model training, DESIGN §16)")
    from . import bench_elastic
    bench_elastic.main(["--json-out", f"{ART}/elastic.json"])

    print("\n### bench_service (fault-tolerant topology service, DESIGN §15)")
    from . import bench_service
    bench_service.main((["--n", "16", "--r", "32"] if quick else []) +
                       ["--json-out", f"{ART}/service.json"])

    print("\n### bench_kernels")
    bench_kernels.main(["--json-out", f"{ART}/kernels.json"])

    print("\n### bench_roofline (from dry-run artifacts)")
    bench_roofline.main([])

    print(f"\nall benchmarks done in {time.time() - t0:.0f}s; artifacts in {ART}/")


if __name__ == "__main__":
    main()
