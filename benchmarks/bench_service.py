"""Beyond-paper: the fault-tolerant topology service under a seeded
fault-injection mix (DESIGN.md §15).

Two tracked rows:

  mode=cache      the ISSUE-8 acceptance microbench at the tracked n=32
                  config: one cold miss through the full pipeline, then a
                  burst of identical requests answered from the canonical
                  cache. ``cache_speedup`` (cold / hit latency) is the
                  gated ratio — the acceptance bar is ≥ 10×.
  mode=fault_mix  a seeded request mix over the deadline ladder: fault-free
                  solves, NaN-returning and raising full-tier stubs, tight
                  deadlines, malformed specs and an overload burst against a
                  bounded queue. Tracks p50/p99 request latency, cache
                  hit-rate, degraded-response fraction — and ``all_valid``,
                  the service invariant itself: every response is either a
                  release-valid topology or a structured rejection.

  PYTHONPATH=src python -m benchmarks.bench_service
  PYTHONPATH=src python -m benchmarks.bench_service --json-out rows.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import BATopoConfig
from repro.core.guard import SolveFailure, SolveOutcome, check_invariants
from repro.core.graph import Topology
from repro.serve.topo_service import (
    ServiceHooks, ServicePolicy, TopologyService, TopoRequest, TopoResponse,
)


def _nan_topology(n: int) -> Topology:
    edges = [(i, (i + 1) % n) for i in range(n)]
    return Topology(n, edges, np.full(len(edges), np.nan), name="nan-stub",
                    meta={"connected": True})


def bench_cache(n: int, r: int, cfg: BATopoConfig, hits: int) -> dict:
    """Cold miss vs cache-hit latency at the tracked config."""
    svc = TopologyService(cfg=cfg)
    t0 = time.perf_counter()
    cold = svc.request(n, r)
    cold_ms = (time.perf_counter() - t0) * 1e3
    assert cold.ok and cold.quality_tier == "full", cold.reason
    hit_ms = []
    for _ in range(hits):
        t0 = time.perf_counter()
        resp = svc.request(n, r)
        hit_ms.append((time.perf_counter() - t0) * 1e3)
        assert resp.ok and resp.cache_hit
    hit_p50 = float(np.percentile(hit_ms, 50))
    return {"bench": "service", "mode": "cache", "n": n, "r": r,
            "runs": hits, "cold_ms": round(cold_ms, 2),
            "hit_p50_ms": round(hit_p50, 4),
            "cache_speedup": round(cold_ms / max(hit_p50, 1e-6), 1)}


def bench_fault_mix(cfg: BATopoConfig, requests: int, seed: int) -> dict:
    """Seeded fault mix through the deadline ladder + admission control."""
    rng = np.random.default_rng(seed)

    def faulty_full(req, prof):
        from repro.core.anytime import TopologyRequest, solve_topology

        roll = int(rng.integers(0, 4))
        if roll == 0:
            return _nan_topology(int(req.n))         # garbage matrix
        if roll == 1:
            raise SolveFailure(SolveOutcome.NON_FINITE, "injected NaN solve")
        if roll == 2:
            raise RuntimeError("injected solver crash")
        return solve_topology(TopologyRequest(n=int(req.n), r=int(req.r)),
                              cfg=cfg, profile=prof,
                              engine="barrier").topology  # fault-free

    svc = TopologyService(cfg=cfg, policy=ServicePolicy(max_queue=8),
                          hooks=ServiceHooks(full=faulty_full))
    specs = [(8, 16), (8, 20), (12, 22), (12, 28)]    # small pool → real hits
    responses: list[TopoResponse] = []
    t_start = time.perf_counter()
    k = 0
    while k < requests:
        burst = int(rng.integers(2, 13))              # overload pressure:
        # bursts above the queue bound (8) exercise backpressure rejection
        for _ in range(min(burst, requests - k)):
            malformed = k % 9 == 8
            n, r = specs[int(rng.integers(0, len(specs)))]
            req = TopoRequest(
                n=1 if malformed else n, r=r,
                deadline_ms=4.0 if k % 4 == 3 else None)
            out = svc.submit(req)
            if isinstance(out, TopoResponse):
                responses.append(out)
            k += 1
        responses.extend(svc.drain())
    wall_s = time.perf_counter() - t_start

    ok = [resp for resp in responses if resp.ok]
    all_valid = all(
        (resp.ok and check_invariants(resp.topology) is None)
        or (not resp.ok and bool(resp.reason))
        for resp in responses)
    lat = np.array([resp.latency_ms for resp in ok]) if ok else np.zeros(1)
    st = svc.stats
    answered = st["cache_hits"] + st["misses"]
    return {"bench": "service", "mode": "fault_mix", "runs": requests,
            "answered": len(responses), "ok": len(ok),
            "rejected_overload": st["rejected_overload"],
            "rejected_malformed": st["rejected_malformed"],
            "p50_ms": round(float(np.percentile(lat, 50)), 3),
            "p99_ms": round(float(np.percentile(lat, 99)), 3),
            "cache_hit_rate": round(st["cache_hits"] / max(answered, 1), 3),
            "degraded_frac": round(sum(r.degraded for r in ok)
                                   / max(len(ok), 1), 3),
            "all_valid": bool(all_valid),
            "total_s": round(wall_s, 3)}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=32,
                    help="tracked cache-microbench node count")
    ap.add_argument("--r", type=int, default=64)
    ap.add_argument("--hits", type=int, default=20)
    ap.add_argument("--requests", type=int, default=48,
                    help="fault-mix request count")
    ap.add_argument("--sa-iters", type=int, default=150)
    ap.add_argument("--polish-iters", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    cfg = BATopoConfig(seed=args.seed, sa_iters=args.sa_iters,
                       polish_iters=args.polish_iters)
    print(f"== topology service: cache microbench (n={args.n}, r={args.r}) "
          f"+ fault-injection mix ({args.requests} requests) ==")

    rows = []
    cache_row = bench_cache(args.n, args.r, cfg, args.hits)
    rows.append(cache_row)
    print("  " + json.dumps(cache_row))

    mix_row = bench_fault_mix(cfg, args.requests, args.seed)
    rows.append(mix_row)
    print("  " + json.dumps(mix_row))
    if not mix_row["all_valid"]:
        raise SystemExit("service invariant violated: a response was neither "
                         "a valid topology nor a structured rejection")

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
