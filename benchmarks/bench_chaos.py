"""Beyond-paper: chaos scenarios — static incumbent vs warm-started online
re-optimization under churn, packet loss, stragglers and bandwidth drift.

One tracked scenario (node-hetero n=16): the fleet trains on a BA-Topo
optimized for the §VI-A2 bandwidth profile; mid-run the fast nodes' NICs
degrade (B(t) drops), a node churns out and rejoins, links drop packets and
stragglers stretch steps. Two runs enter ONE vmapped chaos-engine dispatch:

  static:  the incumbent topology rides out the drift unchanged;
  reopt:   a ``DriftDetector`` (core.reopt) fires at the drift step, the
           ADMM re-solves warm-started from the incumbent support under the
           drifted bandwidths, and the new graph activates after a modeled
           decision→activation lag (``--reopt-lag-ms``, deterministic so CI
           rows are machine-comparable; the *measured* wall time of the
           re-solve is reported separately as ``time_to_reopt_s``).

Both runs pay the Eq. 34/35 clock extended with straggler delays and
effective B(t) (``common.chaos_step_times``); the tracked headline is
``reopt_gain`` = static time-to-accuracy / re-optimized time-to-accuracy.
``--engine both`` adds the scan-vs-host parity compare row (chaos train +
consensus oracles) gated by ``check_regression``.

  PYTHONPATH=src python -m benchmarks.bench_chaos
  PYTHONPATH=src python -m benchmarks.bench_chaos --engine both --json-out rows.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax.numpy as jnp

from repro.core import BATopoConfig
from repro.core.reopt import DriftDetector, DriftPolicy, reoptimize_topology
from repro.data import class_balanced_partition, make_classification_data
from repro.dsgd.chaos import drift_profile, make_chaos
from repro.dsgd.dynamic import static_cycle
from repro.dsgd.sim import (
    CommSpec,
    DSGDSimConfig,
    accuracy_curve_host_chaos,
    consensus_curve_host_chaos,
    consensus_curves_chaos,
    train_curves_chaos,
)

from .common import NODE_BW_16, ba_topo, chaos_step_times

DENSE = CommSpec()


def build_chaos(steps: int, n: int, drift_step: int, bw0: np.ndarray,
                args) -> "object":
    churn = []
    if args.churn_node >= 0:
        t1 = min(drift_step + max(steps // 6, 2), steps)
        churn = [(args.churn_node, drift_step, t1)]
    prof = drift_profile(steps, n, drift_step, bw0,
                         args.slow_nodes, args.slow_bw)
    return make_chaos(steps, n, seed=args.seed, churn=churn,
                      p_drop=args.p_drop, straggler_prob=args.straggler_prob,
                      straggler_mult=args.straggler_mult, bandwidth=prof)


def piecewise_cycle(W_before: np.ndarray, W_after: np.ndarray, steps: int,
                    t_switch: int) -> np.ndarray:
    """(T, n, n) cycle tensor switching topologies at ``t_switch`` — with
    R = T the scan's ``t mod R`` gather makes the cycle a per-step script."""
    cyc = np.empty((steps,) + W_before.shape)
    cyc[:t_switch] = W_before
    cyc[t_switch:] = W_after
    return cyc


def run_reopt(incumbent, chaos, cfg):
    """Detector walk + warm-started re-solve. Returns (reopt_result, t_detect)."""
    det = DriftDetector.from_profile(chaos.bandwidth[0], chaos.alive[0],
                                     DriftPolicy(cooldown_steps=chaos.steps))
    t_detect = None
    for t in range(1, chaos.steps):
        if det.check(t, chaos.bandwidth[t], chaos.alive[t]) is not None:
            t_detect = t
            break
    if t_detect is None:                       # no drift → nothing to re-solve
        return None, None
    res = reoptimize_topology(incumbent, scenario="node",
                              node_bandwidths=chaos.bandwidth[t_detect],
                              alive=chaos.alive[t_detect], cfg=cfg)
    return res, t_detect


def _t_target(acc: np.ndarray, step_ms: np.ndarray, iters: int,
              target: float) -> float:
    """Modeled seconds until epoch-boundary accuracy reaches the target."""
    cum = np.cumsum(step_ms)
    hit = np.nonzero(acc >= target)[0]
    if not hit.size:
        return float("inf")
    return float(cum[(int(hit[0]) + 1) * iters - 1] / 1e3)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--r", type=int, default=32)
    ap.add_argument("--train-epochs", type=int, default=6)
    ap.add_argument("--target-acc", type=float, default=0.8)
    ap.add_argument("--consensus-iters", type=int, default=120)
    ap.add_argument("--drift-frac", type=float, default=0.25,
                    help="drift step as a fraction of the total step count")
    ap.add_argument("--slow-nodes", type=int, default=4,
                    help="nodes whose bandwidth collapses at the drift step")
    ap.add_argument("--slow-bw", type=float, default=1.0)
    ap.add_argument("--churn-node", type=int, default=5,
                    help="node that churns out at the drift step (-1: none)")
    ap.add_argument("--p-drop", type=float, default=0.03)
    ap.add_argument("--straggler-prob", type=float, default=0.05)
    ap.add_argument("--straggler-mult", type=float, default=3.0)
    ap.add_argument("--reopt-lag-ms", type=float, default=500.0,
                    help="modeled drift-detection→activation lag (fixed so "
                         "tracked rows are machine-comparable)")
    ap.add_argument("--sa-iters", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="scan",
                    choices=["scan", "host", "both"])
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    n = args.n
    bw0 = NODE_BW_16[:n]
    cfg = BATopoConfig(seed=args.seed, sa_iters=args.sa_iters)
    print(f"== chaos: static incumbent vs online re-optimization, "
          f"node-hetero n={n} r={args.r} (engine={args.engine}) ==")

    t0 = time.time()
    incumbent = ba_topo(n, args.r, "node", node_bw=bw0, seed=args.seed,
                        sa_iters=args.sa_iters)
    topo_s = round(time.time() - t0, 3)

    X, y = make_classification_data(num_classes=10, dim=64,
                                    samples_per_class=400, seed=args.seed)
    Xte, yte = make_classification_data(num_classes=10, dim=64,
                                        samples_per_class=64, seed=args.seed,
                                        noise_seed=args.seed + 10_001)
    parts = class_balanced_partition(y, n, seed=args.seed)
    scfg = DSGDSimConfig(epochs=args.train_epochs, batch=32, lr=0.05,
                         momentum=0.9, seed=args.seed)
    iters = min(len(p) for p in parts) // scfg.batch
    steps = args.train_epochs * iters
    drift_step = max(int(steps * args.drift_frac), 1)
    chaos = build_chaos(steps, n, drift_step, bw0, args)

    # -- drift detection + warm-started re-solve (measured wall time) -------
    reopt, t_detect = run_reopt(incumbent, chaos, cfg)
    if reopt is None:
        raise SystemExit("no drift detected — scenario misconfigured")
    lag_steps = max(int(np.ceil(
        args.reopt_lag_ms / chaos_step_times(incumbent, chaos,
                                             start=t_detect,
                                             stop=t_detect + 1)[0])), 1)
    t_act = min(t_detect + lag_steps, steps)
    new_topo = reopt.topology
    print(f"  drift@{t_detect} (step), reopt: reoptimized={reopt.reoptimized} "
          f"attempts={reopt.attempts} r_asym {reopt.r_asym_before:.4f} -> "
          f"{reopt.r_asym_after:.4f}, measured time_to_reopt="
          f"{reopt.time_to_reopt_s:.2f}s, activates@{t_act}")

    runs = [
        {"mode": "static", "cycle": static_cycle(incumbent.W),
         "step_ms": chaos_step_times(incumbent, chaos)},
        {"mode": "reopt",
         "cycle": piecewise_cycle(incumbent.W, new_topo.W, steps, t_act),
         "step_ms": np.concatenate([
             chaos_step_times(incumbent, chaos, stop=t_act),
             chaos_step_times(new_topo, chaos, start=t_act)])},
    ]
    data = (jnp.asarray(X), jnp.asarray(y), parts,
            jnp.asarray(Xte), jnp.asarray(yte))

    # consensus chaos spec (its own clock: steps = consensus iters)
    c_iters = args.consensus_iters
    c_drift = max(int(c_iters * args.drift_frac), 1)
    c_chaos = build_chaos(c_iters, n, c_drift, bw0, args)
    c_act = min(c_drift + lag_steps, c_iters)
    c_cycles = [static_cycle(incumbent.W),
                piecewise_cycle(incumbent.W, new_topo.W, c_iters, c_act)]
    x0 = np.random.default_rng(args.seed).normal(size=(n, 16))

    engines = ["host", "scan"] if args.engine == "both" else [args.engine]
    all_rows: list[dict] = []
    per_engine: dict[str, dict] = {}
    for engine in engines:
        Xd, yd, _, Xted, yted = data
        t0 = time.time()
        if engine == "scan":
            accs, _ = train_curves_chaos([r["cycle"] for r in runs],
                                         np.ones(len(runs)), DENSE, chaos,
                                         Xd, yd, parts, Xted, yted, scfg)
            accs = np.asarray(accs)
        else:
            accs = np.stack([accuracy_curve_host_chaos(
                r["cycle"], 1.0, DENSE, chaos, Xd, yd, parts, Xted, yted,
                scfg)[0] for r in runs])
        train_s = round(time.time() - t0, 3)

        t0 = time.time()
        if engine == "scan":
            errs = consensus_curves_chaos(c_cycles, np.ones(len(c_cycles)),
                                          DENSE, c_chaos, x0, c_iters,
                                          seed=args.seed)
        else:
            errs = np.stack([consensus_curve_host_chaos(
                c, 1.0, DENSE, c_chaos, x0, c_iters, seed=args.seed)
                for c in c_cycles])
        consensus_s = round(time.time() - t0, 3)

        rows = []
        for r, a in zip(runs, accs):
            tt = _t_target(a, r["step_ms"], iters, args.target_acc)
            rows.append({
                "topology": incumbent.meta.get("label", incumbent.name),
                "mode": r["mode"], "engine": engine,
                "final_acc": round(float(a[-1]), 4),
                "total_modeled_s": round(float(r["step_ms"].sum() / 1e3), 2),
                "t_target_s": round(tt, 2) if np.isfinite(tt)
                else float("inf")})
        t_static = rows[0]["t_target_s"]
        t_reopt = rows[1]["t_target_s"]
        summary = {
            "bench": "chaos", "scenario": "node", "n": n, "engine": engine,
            "train_epochs": args.train_epochs, "steps": steps,
            "drift_step": t_detect, "reopt_step": t_act,
            "reoptimized": reopt.reoptimized, "attempts": reopt.attempts,
            "time_to_reopt_s": round(reopt.time_to_reopt_s, 3),
            "r_asym_before": round(reopt.r_asym_before, 4),
            "r_asym_after": round(reopt.r_asym_after, 4),
            "static_t_target_s": t_static, "reopt_t_target_s": t_reopt,
            "topo_s": topo_s, "train_s": train_s,
            "consensus_s": consensus_s,
            "total_s": round(train_s + consensus_s, 3),
        }
        if np.isfinite(t_static) and np.isfinite(t_reopt) and t_reopt > 0:
            summary["reopt_gain"] = round(t_static / t_reopt, 3)
        per_engine[engine] = {"rows": rows, "accs": accs, "errs": errs,
                              "summary": summary}
        all_rows += rows + [summary]
        hdr = ["mode", "engine", "final_acc", "t_target_s", "total_modeled_s"]
        print(f"  -- engine={engine}: train {train_s}s, "
              f"consensus {consensus_s}s --")
        print(" | ".join(f"{h:>16}" for h in hdr))
        for row in rows:
            print(" | ".join(f"{str(row.get(h)):>16}" for h in hdr))
        keys = ["time_to_reopt_s", "static_t_target_s", "reopt_t_target_s"]
        if "reopt_gain" in summary:
            keys.append("reopt_gain")
        print("  " + json.dumps({k: summary[k] for k in keys}))

    if args.engine == "both":
        h, s = per_engine["host"], per_engine["scan"]
        e0 = h["errs"][:, :1]
        crow = {"bench": "chaos", "scenario": "node", "n": n,
                "engine": "scan-vs-host",
                "speedup": round(h["summary"]["total_s"]
                                 / max(s["summary"]["total_s"], 1e-9), 2),
                "max_final_acc_drift": round(
                    float(np.max(np.abs(h["accs"][:, -1]
                                        - s["accs"][:, -1]))), 6),
                "max_rel_curve_drift": float(
                    f"{float(np.max(np.abs(h['errs'] - s['errs']) / e0)):.3g}")}
        all_rows.append(crow)
        print("  " + json.dumps(crow))

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(all_rows, f, indent=1)


if __name__ == "__main__":
    main()
