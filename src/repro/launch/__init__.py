"""Launch layer: meshes, distribution plans, dry-run, CLI drivers."""
