"""BA-Topo generation CLI — the paper's optimizer as a standalone tool.

  PYTHONPATH=src python -m repro.launch.topo --n 16 --r 32                  # Eq. 9
  PYTHONPATH=src python -m repro.launch.topo --n 16 --r 32 \
      --bandwidths 9.76x8,3.25x8                                            # §IV-B1
  PYTHONPATH=src python -m repro.launch.topo --n 8 --r 12 --scenario intra  # §IV-B2
  PYTHONPATH=src python -m repro.launch.topo --n 16 --r 48 --scenario bcube # §IV-B3
  PYTHONPATH=src python -m repro.launch.topo --n 32 --r 64 --scenario pods --pods 2
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import (
    BATopoConfig,
    TopologyRequest,
    bcube_constraints,
    intra_server_constraints,
    pod_boundary_constraints,
    solve_topology,
)
from repro.core.bandwidth import homo_edge_bandwidth, min_edge_bandwidth, t_iter
from repro.core.graph import weight_matrix_from_weights


def parse_bandwidths(spec: str, n: int) -> np.ndarray:
    """'9.76x8,3.25x8' → [9.76]*8 + [3.25]*8."""
    vals: list[float] = []
    for part in spec.split(","):
        if "x" in part:
            v, k = part.split("x")
            vals.extend([float(v)] * int(k))
        else:
            vals.append(float(part))
    if len(vals) != n:
        raise ValueError(f"--bandwidths expands to {len(vals)} entries "
                         f"but --n is {n}: {spec!r}")
    return np.asarray(vals)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, required=True)
    ap.add_argument("--r", type=int, required=True)
    ap.add_argument("--scenario", default="homo",
                    choices=["homo", "node", "intra", "bcube", "pods"])
    ap.add_argument("--bandwidths", default=None,
                    help="per-node GB/s for --scenario node, e.g. 9.76x8,3.25x8")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--cross-pod-cap", type=int, default=4,
                    help="max edges crossing each pod boundary")
    ap.add_argument("--sa-iters", type=int, default=1500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-ms", type=float, default=None,
                    help="anytime wall-clock budget; omit for the full "
                         "deterministic solve")
    ap.add_argument("--out", default=None, help="write topology json")
    args = ap.parse_args()

    cfg = BATopoConfig(sa_iters=args.sa_iters, seed=args.seed)
    n = args.n
    if args.scenario == "homo":
        req = TopologyRequest(n=n, r=args.r, scenario="homo")
    elif args.scenario == "node":
        if not args.bandwidths:
            raise ValueError("--bandwidths is required for --scenario node "
                             "(e.g. --bandwidths 9.76x8,3.25x8)")
        b = parse_bandwidths(args.bandwidths, n)
        req = TopologyRequest(n=n, r=args.r, scenario="node",
                              node_bandwidths=b)
    elif args.scenario == "intra":
        cs = intra_server_constraints(n)
        req = TopologyRequest(n=n, r=args.r, scenario="constraint", cs=cs)
    elif args.scenario == "bcube":
        cs = bcube_constraints(n)
        req = TopologyRequest(n=n, r=args.r, scenario="constraint", cs=cs)
    else:  # pods
        cs = pod_boundary_constraints(n, args.pods, args.cross_pod_cap)
        req = TopologyRequest(n=n, r=args.r, scenario="constraint", cs=cs)
    res = solve_topology(req, cfg=cfg, budget_ms=args.budget_ms)
    topo = res.topology

    W = weight_matrix_from_weights(n, topo.edges, topo.g)
    bw = homo_edge_bandwidth(topo)
    report = {
        "name": topo.name,
        "n": n, "edges": len(topo.edges),
        "r_asym": topo.r_asym(),
        "quality_tier": res.quality_tier,
        "complete": res.complete,
        "max_degree": int(np.max(np.count_nonzero(W - np.diag(np.diag(W)), axis=1))),
        "b_min_GBs": min_edge_bandwidth(bw),
        "t_iter_ms": t_iter(min_edge_bandwidth(bw)),
        "meta": {k: v for k, v in topo.meta.items()
                 if isinstance(v, (str, int, float, bool))},
        "edge_list": [list(e) for e in topo.edges],
        "weights": np.asarray(topo.g).round(6).tolist(),
    }
    print(json.dumps({k: v for k, v in report.items()
                      if k not in ("edge_list", "weights")}, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
