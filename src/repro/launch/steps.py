"""Step builders: (arch × input-shape × mesh) → (jit-able fn, abstract args).

``input_specs()`` returns weak-type-correct ShapeDtypeStruct stand-ins with
NamedShardings attached — no device allocation — so ``jax.jit(fn).lower(*args)``
compiles the production program exactly as it would run on the target mesh.

Topology selection: the gossip topology for n workers is BA-Topo by default
(the paper's contribution, solved by the ADMM core and cached on disk), with
baseline topologies (ring / exponential / u_equistatic) and the centralized
all-reduce selectable for comparisons — the knobs the §Perf hillclimb turns.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ModelConfig, get_arch, shape_supported
from repro.core import BATopoConfig, TopologyRequest, make_baseline, solve_topology
from repro.core.graph import Topology
from repro.dsgd import (
    DSGDState,
    init_dsgd_state,
    make_sharded_train_step,
    make_tp_train_step,
    schedule_from_topology,
)
from repro.models import transformer
from repro.models.partitioning import rules_ctx
from repro.optim import sgd_momentum
from repro.serve import DecodeState, ServeConfig, make_functional_serve_step

from .sharding import (
    DistPlan,
    axis_sizes,
    batch_specs,
    cache_specs,
    plan_for,
    tree_param_specs,
    with_sharding,
)

__all__ = ["BuiltStep", "build_step", "input_specs", "topology_for", "TOPO_CACHE"]

TOPO_CACHE = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "benchmarks", "artifacts", "topo_cache.json")


@dataclass
class BuiltStep:
    fn: Callable               # jit-able (args…) → outputs
    args: tuple                # abstract ShapeDtypeStructs with shardings
    plan: DistPlan
    mode: str                  # train | prefill | decode
    meta: dict


def _sharding_rules(plan: DistPlan, mesh, mode: str) -> dict:
    """Logical→mesh axis rules for in-model hints (models/partitioning.py).

    MoE dispatch groups follow the token sharding ("data" axis) so the
    scatter/gather stays shard-local (GShard local_groups). Inside the
    partial-manual gossip region "data" is a manual axis and may not be
    referenced → standard train keeps G = 1 (per-worker dispatch is already
    local to the worker's 16-chip slice)."""
    sizes = axis_sizes(mesh)
    if mode == "train" and plan.gossip_axes and plan.gossip_axes != ("pod",):
        return {"moe_ff": "model", "embed": None, "moe_groups": 1,
                "moe_group": None}
    if mode == "train":  # pod-sized worker: per-worker batch shards over data
        if plan.expert_axis:  # expert parallelism: E owns "data", G = 1
            return {"moe_ff": "model", "embed": None, "moe_groups": 1,
                    "moe_group": None, "moe_expert": plan.expert_axis}
        return {"moe_ff": "model", "embed": None,
                "moe_groups": sizes.get("data", 1), "moe_group": "data"}
    axes = plan.batch_axes or ("data",)
    groups = int(np.prod([sizes.get(a, 1) for a in axes]))
    rules = {"moe_ff": "model", "embed": None, "moe_groups": groups,
             "moe_group": axes if len(axes) > 1 else axes[0]}
    if plan.expert_axis:  # shard_map expert-parallel MoE (moe_ep.py)
        rules.update(moe_impl="expert_parallel",
                     moe_expert_axis=plan.expert_axis, moe_groups=1,
                     moe_group=None, moe_token_axes=axes)
    return rules


def _with_rules(fn: Callable, rules: dict) -> Callable:
    def wrapped(*args, **kw):
        with rules_ctx(rules):
            return fn(*args, **kw)
    return wrapped


# ---------------------------------------------------------------------------
# topology cache (the ADMM solve is host-side; reuse across dry-run combos)
# ---------------------------------------------------------------------------

_MEM_CACHE: dict[tuple, Topology] = {}


def topology_for(n: int, kind: str = "ba", r: int | None = None,
                 seed: int = 0,
                 node_bw: "list[float] | None" = None) -> Topology:
    """Gossip topology over n workers. kind ∈ {"ba", "ring", "exponential",
    "u_equistatic", "torus2d", "grid2d"}; r defaults to 2n (the paper's best
    homogeneous budget at n=16). ``node_bw`` (BA only): per-node GB/s —
    the solve runs the §VI-A2 node scenario (Algorithm 1 allocates edge
    capacities to the heterogeneous NICs) instead of homogeneous."""
    r = r if r is not None else 2 * n
    bw_key = tuple(float(b) for b in node_bw) if node_bw is not None else None
    key = (n, kind, r, seed, bw_key)
    if key in _MEM_CACHE:
        return _MEM_CACHE[key]
    if node_bw is not None and kind != "ba":
        raise ValueError("node_bw is a BA-Topo (ADMM) knob — baseline "
                         f"topologies ignore bandwidth (got kind={kind!r})")
    if node_bw is not None and len(node_bw) != n:
        raise ValueError(f"node_bw has {len(node_bw)} entries for n={n}")
    if n == 1:
        topo = Topology(1, [], np.zeros(0), name="singleton")
    elif n == 2:
        topo = Topology(2, [(0, 1)], np.array([0.5]), name="pair")
    elif kind == "ba":
        topo = _cached_ba_topology(n, r, seed, node_bw)
    elif kind == "random":
        topo = make_baseline(kind, n, r=r, seed=seed)
    else:
        topo = make_baseline(kind, n)
    _MEM_CACHE[key] = topo
    return topo


def _cached_ba_topology(n: int, r: int, seed: int,
                        node_bw: "list[float] | None" = None) -> Topology:
    path = os.path.abspath(TOPO_CACHE)
    cache = {}
    if os.path.exists(path):
        with open(path) as f:
            cache = json.load(f)
    ck = f"n{n}_r{r}_s{seed}"
    if node_bw is not None:
        ck += "_bw" + ",".join(f"{b:g}" for b in node_bw)
    if ck in cache:
        d = cache[ck]
        return Topology(n, [tuple(e) for e in d["edges"]], np.asarray(d["g"]),
                        name=f"ba-topo(n={n},r={r})", meta=d.get("meta", {}))
    if node_bw is not None:
        req = TopologyRequest(n=n, r=r, scenario="node",
                              node_bandwidths=np.asarray(node_bw, float))
    else:
        req = TopologyRequest(n=n, r=r, scenario="homo")
    topo = solve_topology(req, cfg=BATopoConfig(seed=seed)).topology
    cache[ck] = {"edges": [list(e) for e in topo.edges],
                 "g": np.asarray(topo.g).tolist(),
                 "meta": {k: v for k, v in topo.meta.items()
                          if isinstance(v, (int, float, str, bool, list))}}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(cache, f)
    return topo


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _abstract(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def _batch_shapes(cfg: ModelConfig, B: int, S: int) -> dict:
    shp = {"tokens": (B, S), "labels": (B, S)}
    if cfg.frontend_tokens:
        shp["embeds"] = (B, cfg.frontend_tokens, cfg.d_model)
    return shp


def _batch_structs(shapes: dict, lead: tuple = ()) -> dict:
    dt = {"tokens": jnp.int32, "labels": jnp.int32, "embeds": jnp.float32}
    return {k: jax.ShapeDtypeStruct(lead + v, dt[k]) for k, v in shapes.items()}


def input_specs(arch: str, shape_name: str, mesh, *, mode: str | None = None,
                **kw) -> tuple:
    """Public helper: the abstract (sharded) inputs ``build_step`` lowers."""
    return build_step(arch, shape_name, mesh, **kw).args


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def build_step(arch: str, shape_name: str, mesh, *, sync: str = "gossip",
               topo_kind: str = "ba", topo_r: int | None = None,
               param_dtype: str | None = None, accum_steps: int = 1,
               tp_only: bool | None = None,
               expert_parallel: bool = False) -> BuiltStep:
    cfg = get_arch(arch)
    if param_dtype:
        from dataclasses import replace
        cfg = replace(cfg, dtype=param_dtype)
    shape = INPUT_SHAPES[shape_name]
    if not shape_supported(arch, shape_name):
        raise ValueError(f"{arch} × {shape_name} not in the supported matrix "
                         "(long_500k needs sub-quadratic attention)")
    if shape.kind == "train":
        return _build_train(cfg, shape, mesh, sync=sync, topo_kind=topo_kind,
                            topo_r=topo_r, accum_steps=accum_steps,
                            expert_parallel=expert_parallel)
    if shape.kind == "prefill":
        return _build_prefill(cfg, shape, mesh, tp_only=tp_only,
                              expert_parallel=expert_parallel)
    return _build_decode(cfg, shape, mesh, tp_only=tp_only,
                         expert_parallel=expert_parallel)


def _build_train(cfg, shape, mesh, *, sync: str, topo_kind: str,
                 topo_r: int | None, accum_steps: int = 1,
                 expert_parallel: bool = False) -> BuiltStep:
    plan = plan_for(cfg, mesh, mode="train", expert_parallel=expert_parallel)
    n = plan.n_workers
    per_b = max(shape.global_batch // max(n, 1), 1)
    if accum_steps == 1 and len(plan.tensor_axes) > 1:
        # pod-sized worker sees the whole (or half the) global batch — auto
        # microbatch to ≤128k tokens/microbatch (§Perf: 68 → 28 GB/dev)
        while per_b % (accum_steps * 2) == 0 and \
                per_b * shape.seq_len // accum_steps > 131072:
            accum_steps *= 2
    opt_init, opt_update = sgd_momentum(0.05)

    bshapes = _batch_shapes(cfg, per_b, shape.seq_len)
    meta: dict = {"n_workers": n, "per_worker_batch": per_b, "sync": sync,
                  "accum_steps": accum_steps}

    if plan.gossip_axes and sync != "none":
        topo = topology_for(n, kind=topo_kind, r=topo_r)
        if plan.gossip_axes == ("pod",):
            # pod-sized workers: gossip = dense W matmul (Eq. 1) under pure
            # pjit — the partial-manual partitioner chokes on 512-device MoE
            # gathers, and at n = #pods the matmul costs the same bytes
            from repro.dsgd import make_matmul_gossip_train_step
            step = make_matmul_gossip_train_step(cfg, topo, opt_update,
                                                 accum_steps=accum_steps)
            meta.update(topology=topo.name, gossip_impl="W-matmul")
        else:
            sched = schedule_from_topology(topo)
            step = make_sharded_train_step(cfg, sched, opt_update, mesh,
                                           gossip_axes=plan.gossip_axes, sync=sync)
            meta.update(topology=topo.name, rounds=sched.rounds,
                        degree_max=int(sched.degrees.max()) if len(topo.edges) else 0,
                        gossip_impl="ppermute-schedule")
        state_sh = jax.eval_shape(
            lambda: init_dsgd_state(jax.random.PRNGKey(0), cfg, n, opt_init))
        stacked = True
        batch = _batch_structs(bshapes, lead=(n,))
    else:
        step = make_tp_train_step(cfg, opt_update, accum_steps=accum_steps)
        params_sh = jax.eval_shape(
            lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
        opt_sh = jax.eval_shape(opt_init, params_sh)
        state_sh = DSGDState(params_sh, opt_sh,
                             jax.ShapeDtypeStruct((), jnp.int32))
        stacked = False
        # single worker sees the whole global batch
        bshapes = _batch_shapes(cfg, shape.global_batch // max(n, 1), shape.seq_len)
        batch = _batch_structs(bshapes, lead=(n,) if n > 1 else ())
        if n > 1:
            stacked = True

    pspecs = tree_param_specs(state_sh.params, plan, mesh, stacked=stacked)
    ospecs = tree_param_specs(state_sh.opt, plan, mesh, stacked=stacked)
    state_specs = DSGDState(pspecs, ospecs, P())
    state = with_sharding(mesh, state_sh, state_specs)

    bsp = batch_specs(cfg, plan, mesh,
                      {k: v.shape for k, v in batch.items()}, stacked=stacked)
    batch_abs = with_sharding(mesh, batch, bsp)

    rules = _sharding_rules(plan, mesh, "train")
    return BuiltStep(fn=_with_rules(step, rules), args=(state, batch_abs),
                     plan=plan, mode="train", meta={**meta, "rules": rules})


def _build_prefill(cfg, shape, mesh, *, tp_only: bool | None = None,
                   expert_parallel: bool = False) -> BuiltStep:
    plan = plan_for(cfg, mesh, mode="prefill", tp_only=tp_only,
                    expert_parallel=expert_parallel)
    B, S = shape.global_batch, shape.seq_len

    def fn(params, batch):
        return transformer.prefill(params, cfg, batch, cache_cap=S)

    params_sh = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = tree_param_specs(params_sh, plan, mesh)
    params = with_sharding(mesh, params_sh, pspecs)

    bshapes = _batch_shapes(cfg, B, S)
    bshapes.pop("labels")
    batch = _batch_structs(bshapes)
    bsp = batch_specs(cfg, plan, mesh, bshapes)
    batch_abs = with_sharding(mesh, batch, bsp)

    rules = _sharding_rules(plan, mesh, "prefill")
    return BuiltStep(fn=_with_rules(fn, rules), args=(params, batch_abs),
                     plan=plan, mode="prefill", meta={"batch": B, "seq": S,
                                                      "rules": rules})


def _build_decode(cfg, shape, mesh, *, tp_only: bool | None = None,
                  expert_parallel: bool = False) -> BuiltStep:
    plan = plan_for(cfg, mesh, mode="decode", tp_only=tp_only,
                    expert_parallel=expert_parallel)
    B, S = shape.global_batch, shape.seq_len
    long_ctx = shape.name == "long_500k"
    if long_ctx and cfg.sliding_window:
        cache_cap = cfg.sliding_window          # ring buffer = the window
    elif long_ctx and cfg.arch_type == "hybrid":
        cache_cap = 4096                        # zamba2 long-context SWA cache
    else:
        cache_cap = S
    scfg = ServeConfig(batch_size=B, cache_len=cache_cap, long_context=long_ctx)
    step = make_functional_serve_step(cfg, scfg, eos_id=-1)

    params_sh = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = tree_param_specs(params_sh, plan, mesh)
    params = with_sharding(mesh, params_sh, pspecs)

    caches_sh = jax.eval_shape(
        lambda: transformer.init_caches(cfg, B, cache_cap))
    cspecs = cache_specs(cfg, plan, mesh, caches_sh, B)
    caches = with_sharding(mesh, caches_sh, cspecs)

    sizes = axis_sizes(mesh)
    btotal = int(np.prod([sizes[a] for a in plan.batch_axes]))
    baxis = (plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]) \
        if (plan.batch_axes and B % btotal == 0 and B >= btotal) else None
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                               sharding=NamedSharding(mesh, P(baxis, None)))
    done = jax.ShapeDtypeStruct((B,), jnp.bool_,
                                sharding=NamedSharding(mesh, P(baxis)))
    rep = lambda shp, dt: jax.ShapeDtypeStruct(
        shp, dt, sharding=NamedSharding(mesh, P(*([None] * len(shp)))))
    state = DecodeState(tokens=tok, caches=caches,
                        pos=rep((), jnp.int32), rng=rep((2,), jnp.uint32),
                        done=done)
    rules = _sharding_rules(plan, mesh, "decode")
    return BuiltStep(fn=_with_rules(step, rules), args=(params, state),
                     plan=plan, mode="decode",
                     meta={"batch": B, "kv_len": S, "cache_cap": cache_cap,
                           "long_context": long_ctx, "rules": rules})
