"""Production meshes.

Factory functions (NOT module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax init.

Target hardware: TPU v5e, 256 chips/pod (16×16 ICI torus), 2 pods via DCI.
  single-pod  (16, 16)        axes ("data", "model")
  multi-pod   (2, 16, 16)     axes ("pod", "data", "model")

The "data" axis hosts the decentralized gossip workers (paper's compute
nodes); "model" is intra-worker tensor parallelism; "pod" crosses the slow
DCI boundary — the BA-Topo heterogeneous machinery treats it exactly like
the paper's inter-server switch tier (core.constraints.pod_boundary_constraints).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "POD_SHAPE", "MULTIPOD_SHAPE"]

POD_SHAPE = (16, 16)
MULTIPOD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    ndev = len(jax.devices())
    assert data * model <= ndev, (data, model, ndev)
    return jax.make_mesh((data, model), ("data", "model"))
