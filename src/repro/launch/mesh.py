"""Production meshes.

Factory functions (NOT module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax init.

Target hardware: TPU v5e, 256 chips/pod (16×16 ICI torus), 2 pods via DCI.
  single-pod  (16, 16)        axes ("data", "model")
  multi-pod   (2, 16, 16)     axes ("pod", "data", "model")

The "data" axis hosts the decentralized gossip workers (paper's compute
nodes); "model" is intra-worker tensor parallelism; "pod" crosses the slow
DCI boundary — the BA-Topo heterogeneous machinery treats it exactly like
the paper's inter-server switch tier (core.constraints.pod_boundary_constraints).
"""
from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "POD_SHAPE", "MULTIPOD_SHAPE"]

POD_SHAPE = (16, 16)
MULTIPOD_SHAPE = (2, 16, 16)


def _check_devices(shape: tuple[int, ...], axes: tuple[str, ...]) -> None:
    """Fail early with an actionable message when the requested mesh does not
    fit the attached devices — XLA's own mesh-construction error on a
    CPU-only box is an opaque reshape failure with no hint about why."""
    need = math.prod(shape)
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {need} devices but only "
            f"{have} are attached ({jax.default_backend()} backend). On a "
            "CPU-only environment, simulate host devices by setting "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            "BEFORE the first jax import (e.g. in a subprocess, as "
            "tests/test_sharded_runtime.py does).")


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    _check_devices(shape, axes)
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    _check_devices((data, model), ("data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
