"""Serving driver: batched greedy generation with a KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_arch, reduced_for_smoke
from repro.models import transformer
from repro.serve import ServeConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--long-context", action="store_true")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--ckpt", default=None, help="npz checkpoint to serve")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        from repro.checkpoint import load_checkpoint
        params, _ = load_checkpoint(args.ckpt, params)

    cache_len = args.cache_len or (args.prompt_len + args.max_new + 8)
    scfg = ServeConfig(batch_size=args.batch, cache_len=cache_len,
                       max_new_tokens=args.max_new, temperature=args.temperature,
                       long_context=args.long_context, use_kernel=args.use_kernel)
    engine = ServingEngine(cfg, params, scfg, eos_id=-1)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len),
                           dtype=np.int64).astype(np.int32)
    extra = None
    if cfg.frontend_tokens:
        extra = {"embeds": rng.normal(
            size=(args.batch, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)}

    t0 = time.time()
    out = engine.generate(prompts, extra_inputs=extra, seed=args.seed)
    dt = time.time() - t0
    toks = out.size
    print(f"arch={cfg.name} batch={args.batch} generated {out.shape[1]} tokens/req "
          f"in {dt:.2f}s ({toks / dt:.1f} tok/s incl. prefill+compile)")
    for i in range(min(args.batch, 2)):
        print(f"  req{i}: {out[i][:16].tolist()}{'...' if out.shape[1] > 16 else ''}")


if __name__ == "__main__":
    main()
