"""Distribution plans + PartitionSpec assignment for every (arch × shape × mesh).

Worker granularity (the decentralized-learning unit the paper calls a "node")
is chosen per architecture from its memory footprint:

  standard     worker = one "data"-axis slice (16 chips of "model" TP);
               16 gossip workers/pod, 32 multi-pod — the paper's n=16/32.
  pod_worker   replica + optimizer state would blow a 16-chip slice's HBM
               (mixtral-8x22b: ~846 GB/replica) → worker = a whole pod with
               2-D ("data","model") tensor sharding; gossip runs over the
               "pod" axis only (n=2) exactly like the paper's inter-server
               tier. Single-pod train then has ONE worker (pure TP, no
               gossip) — recorded in DESIGN.md §7.

Inference shapes never replicate per worker: params shard 2-D over the whole
mesh (FSDP-style), batch/caches over the batch axes.

Spec assignment is rule-based on the pytree key path + dim sizes. Shardings
never change numerics — only layout — so the rules are heuristics with a
replicate fallback; GSPMD pads non-divisible dims.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig

__all__ = ["DistPlan", "plan_for", "param_specs", "tree_param_specs", "batch_specs",
           "cache_specs", "with_sharding", "params_bytes", "REPLICA_BUDGET_BYTES",
           "axis_sizes"]

# one worker slice = 16 chips × 16 GB HBM; keep replica+opt under ~60%
REPLICA_BUDGET_BYTES = int(16 * 16e9 * 0.6)


@dataclass(frozen=True)
class DistPlan:
    gossip_axes: tuple[str, ...]   # mesh axes hosting gossip workers ((), = no DP)
    tensor_axes: tuple[str, ...]   # intra-worker model-sharding axes
    batch_axes: tuple[str, ...]    # inference batch axes
    n_workers: int
    # expert parallelism: mesh axis owning the MoE expert dim (weights stay
    # resident; tokens all-to-all to their experts). GSPMD pads E up to the
    # axis size when uneven (mixtral: 8 experts on a 16-axis).
    expert_axis: str | None = None

    @property
    def gossip_spec_axis(self):
        if not self.gossip_axes:
            return None
        return self.gossip_axes if len(self.gossip_axes) > 1 else self.gossip_axes[0]


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def params_bytes(cfg: ModelConfig) -> int:
    """Replica size in its native dtype, via eval_shape (no allocation)."""
    from repro.models import transformer
    shapes = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(shapes))


def plan_for(cfg: ModelConfig, mesh, *, mode: str,
             tp_only: bool | None = None,
             expert_parallel: bool = False) -> DistPlan:
    """mode ∈ {"train", "prefill", "decode"}. Mesh axes: ("pod",)? + "data"
    + "model"; any mesh without a "pod" axis is treated as single-pod.

    tp_only (inference): shard weights over "model" ONLY, keeping them
    resident (no per-layer FSDP all-gathers); "data" carries just the batch.
    None = auto: TP-only whenever the model fits one "model" slice
    (pb/model_size ≤ ~60% of HBM per chip), 2-D FSDP×TP otherwise (mixtral).
    """
    sizes = axis_sizes(mesh)
    multi_pod = "pod" in sizes and sizes["pod"] > 1
    pb = params_bytes(cfg)
    # per-worker footprint: replica (native dtype) + f32 momentum + f32 grads
    n_params = pb // (2 if cfg.dtype == "bfloat16" else 4)
    train_worker_bytes = pb + 2 * 4 * n_params
    slice_budget = sizes.get("model", 1) * 16e9 * 0.6
    if mode == "train":
        if train_worker_bytes > slice_budget:
            # pod-sized worker: params 2-D sharded; the worker's batch shards
            # over "data" too (activation sharding — the global batch would
            # otherwise replicate 1M-token activations on every chip)
            return DistPlan(
                gossip_axes=("pod",) if multi_pod else (),
                tensor_axes=("data", "model"), batch_axes=("data",),
                n_workers=sizes.get("pod", 1) if multi_pod else 1,
                expert_axis="data" if (expert_parallel and cfg.num_experts) else None)
        gossip = ("pod", "data") if multi_pod else ("data",)
        return DistPlan(
            gossip_axes=gossip, tensor_axes=("model",), batch_axes=(),
            n_workers=int(np.prod([sizes[a] for a in gossip])))
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    if tp_only is None:
        tp_only = pb <= sizes.get("model", 1) * 16e9 * 0.6
    ep_axis = None
    if expert_parallel and cfg.num_experts and \
            cfg.num_experts % sizes.get("model", 1) == 0:
        ep_axis = "model"  # experts resident, tokens all_to_all (moe_ep.py)
    return DistPlan(gossip_axes=(),
                    tensor_axes=("model",) if tp_only else ("data", "model"),
                    batch_axes=batch_axes, n_workers=1, expert_axis=ep_axis)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_STACKED = re.compile(r"\['(layers|enc_layers)'\]")


def _leaf_spec(path: str, shape: tuple[int, ...], plan: DistPlan,
               sizes: dict[str, int], lead: tuple = ()) -> P:
    """Megatron-pattern sharding for the known matmul weights, largest-dim
    heuristic for the rest.

    w_gate/w_up → column-parallel (shard the OUTPUT d_ff dim); w_down →
    row-parallel (shard the INPUT d_ff dim, dim −2). The size heuristic gets
    this wrong whenever d_model > d_ff (granite: 1024 > 512), sharding the
    contraction dim of BOTH layers and forcing resharding between them.
    """
    protect = 1 if _STACKED.search(path) else 0
    entries: list = list(lead) + [None] * len(shape)
    model_ax = plan.tensor_axes[-1]          # the intra-layer TP axis
    used: set[int] = set()

    def try_assign(d: int, ax: str) -> bool:
        if d in used or d < protect or shape[d] < 2 * sizes[ax] or shape[d] % sizes[ax]:
            return False
        entries[len(lead) + d] = ax
        used.add(d)
        return True

    moe_ep = plan.expert_axis and "moe" in path
    if re.search(r"\['(w_gate|w_up)'\]$", path):
        if moe_ep and len(shape) >= 3:
            entries[len(lead) + protect] = plan.expert_axis  # experts resident
            used.add(protect)
        if plan.expert_axis != model_ax or not moe_ep:
            try_assign(len(shape) - 1, model_ax)      # column-parallel: d_ff out
    elif re.search(r"\['w_down'\]$", path):
        if moe_ep and len(shape) >= 3:
            entries[len(lead) + protect] = plan.expert_axis
            used.add(protect)
        if plan.expert_axis != model_ax or not moe_ep:
            try_assign(len(shape) - 2, model_ax)      # row-parallel: d_ff in
    elif re.search(r"\['(wq|wk|wv)'\]$", path):
        try_assign(len(shape) - 1, model_ax)          # heads out
    elif re.search(r"\['wo'\]$", path):
        try_assign(len(shape) - 2, model_ax)          # heads in (row-parallel)

    # fill remaining tensor axes by size rank (2-D plans / untyped leaves)
    order = sorted(range(protect, len(shape)), key=lambda d: -shape[d])
    for ax in plan.tensor_axes:
        if any(entries[len(lead) + d] == ax for d in range(len(shape))):
            continue
        for d in order:
            if try_assign(d, ax):
                break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_specs(cfg: ModelConfig, plan: DistPlan, mesh, *, stacked: bool = False):
    """PartitionSpec pytree matching transformer.init_params(cfg)."""
    from repro.models import transformer
    shapes = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    return tree_param_specs(shapes, plan, mesh,
                            stacked=False) if not stacked else tree_param_specs(
        jax.tree.map(lambda l: jax.ShapeDtypeStruct((plan.n_workers,) + l.shape,
                                                    l.dtype), shapes),
        plan, mesh, stacked=True)


def tree_param_specs(tree, plan: DistPlan, mesh, *, stacked: bool = False):
    """Specs for a params-shaped pytree (params / optimizer momentum / grads).
    ``stacked``: leaves carry a leading (n_workers,) axis → gossip axes."""
    sizes = axis_sizes(mesh)
    lead = (plan.gossip_spec_axis,) if stacked else ()

    def assign(path, leaf):
        shape = tuple(leaf.shape)
        if stacked:
            shape = shape[1:]
        if not shape:  # scalars (step counters)
            return P()
        return _leaf_spec(jax.tree_util.keystr(path), shape, plan, sizes, lead)

    return jax.tree_util.tree_map_with_path(assign, tree)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, plan: DistPlan, mesh, batch_shape: dict, *,
                stacked: bool = False):
    """Specs for {tokens, labels(, embeds)} dicts (stacked adds worker axis 0)."""
    sizes = axis_sizes(mesh)
    lead = (plan.gossip_spec_axis,) if stacked else ()
    baxis = None
    if plan.batch_axes:
        b = batch_shape["tokens"][1 if stacked else 0]
        avail = tuple(a for a in plan.batch_axes if a not in plan.gossip_axes)
        total = int(np.prod([sizes[a] for a in avail])) if avail else 0
        if avail and b % total == 0 and b >= total:
            baxis = avail if len(avail) > 1 else avail[0]
    out = {}
    for k, shp in batch_shape.items():
        rest = [None] * (len(shp) - len(lead) - 1)
        out[k] = P(*lead, baxis, *rest)
    return out


def cache_specs(cfg: ModelConfig, plan: DistPlan, mesh, caches, batch: int):
    """Specs for transformer.Caches: batch over batch_axes (when divisible),
    KV seq over "model", SSM head dims over "model"."""
    sizes = axis_sizes(mesh)
    total = int(np.prod([sizes[a] for a in plan.batch_axes])) if plan.batch_axes else 1
    if plan.batch_axes and batch % total == 0 and batch >= total:
        baxis = plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]
        seq_axes: tuple[str, ...] = ("model",)
    else:
        baxis = None
        # batch unshardable (long_500k B=1) → give the seq dim everything
        seq_axes = tuple(a for a in ("data", "model") if a in sizes)

    seq_total = int(np.prod([sizes[a] for a in seq_axes]))

    def assign(path, leaf):
        key = jax.tree_util.keystr(path)
        shape = leaf.shape
        if ".kv" in key or "shared_kv" in key or "cross_kv" in key:
            # (L_or_G, B, C, Hkv, hd)
            spec: list = [None] * len(shape)
            if len(shape) >= 2:
                spec[1] = baxis
            if len(shape) >= 3:
                C = shape[2]
                if C % seq_total == 0 and C >= seq_total:
                    spec[2] = seq_axes if len(seq_axes) > 1 else seq_axes[0]
            return P(*spec)
        if ".ssm" in key:
            # conv state (L,B,d_inner,k) or ssd state (L,B,H,dh,state)
            spec = [None] * len(shape)
            if len(shape) >= 2:
                spec[1] = baxis
            for d in range(2, len(shape)):
                if shape[d] % sizes.get("model", 1) == 0 and shape[d] >= 2 * sizes.get("model", 1):
                    spec[d] = "model"
                    break
            return P(*spec)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(assign, caches)


def with_sharding(mesh, tree, specs):
    """ShapeDtypeStruct tree with NamedShardings attached (for .lower())."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs)
