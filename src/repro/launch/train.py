"""DSGD training driver.

Runs the full stack on whatever devices exist: reduced configs on CPU for
smoke-scale runs, production configs on a real mesh. The gossip topology is
BA-Topo by default — the paper's technique as a first-class launcher flag.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --workers 8 --steps 50 --topo ba --r 16
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --workers 16 --topo exponential --sync allreduce
"""
from __future__ import annotations

import argparse
import json
import time


import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, reduced_for_smoke
from repro.core.bandwidth import (
    PaperConstants,
    homo_edge_bandwidth,
    min_edge_bandwidth,
    t_iter,
)
from repro.data import DataConfig, synthetic_lm_batch
from repro.dsgd import (
    allreduce_train_step,
    dsgd_train_step,
    init_dsgd_state,
)
from repro.launch.steps import topology_for
from repro.optim import make_optimizer, warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config of the same family (CPU-sized)")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--topo", default="ba",
                    choices=["ba", "ring", "exponential", "equistatic", "torus"])
    ap.add_argument("--r", type=int, default=None, help="edge budget (default 2n)")
    ap.add_argument("--sync", default="gossip",
                    choices=["gossip", "allreduce", "dynamic"])
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"])
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--use-kernel", action="store_true",
                    help="fused Pallas gossip_mix (interpret mode on CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    n = args.workers

    lr = warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps)
    opt_init, opt_update = make_optimizer(args.optimizer, lr)

    topo = topology_for(n, kind=args.topo, r=args.r, seed=args.seed)
    if args.sync == "allreduce":
        step = allreduce_train_step(cfg, n, opt_update)
        sync_desc = "allreduce"
    elif args.sync == "dynamic":
        # beyond-paper: one matching per step (repro/dsgd/dynamic.py)
        from repro.dsgd.dynamic import cycle_weight_matrices, round_robin_schedules
        import jax.numpy as _jnp
        Ws = [_jnp.asarray(W, _jnp.float32)
              for W in cycle_weight_matrices(round_robin_schedules(topo))]
        from repro.dsgd.trainer import DSGDState, _loss_fn
        from repro.dsgd.gossip import gossip_sim_tree
        from repro.optim import apply_updates
        import jax as _jax

        loss_fn = _loss_fn(cfg)

        @_jax.jit
        def _dyn_step(state, batch):
            losses, grads = _jax.vmap(_jax.value_and_grad(loss_fn))(state.params, batch)
            updates, opt = _jax.vmap(opt_update)(grads, state.opt, state.params)
            params = _jax.vmap(apply_updates)(state.params, updates)
            Wt = _jax.lax.switch(state.step % len(Ws), [lambda W=W: W for W in Ws])
            params = gossip_sim_tree(params, Wt)
            from repro.dsgd.trainer import _consensus_error
            return DSGDState(params, opt, state.step + 1), {
                "loss": losses.mean(), "loss_max": losses.max(),
                "consensus_err": _consensus_error(params)}

        step = _dyn_step
        sync_desc = f"dynamic[{topo.name}] rounds={len(Ws)}"
    else:
        step = dsgd_train_step(cfg, topo, opt_update, use_kernel=args.use_kernel)
        sync_desc = f"gossip[{topo.name}] r_asym={topo.r_asym():.3f}"

    # paper's wall-clock model for this topology (Eq. 34/35)
    pc = PaperConstants()
    b_min = (min_edge_bandwidth(homo_edge_bandwidth(topo))
             if len(topo.edges) else pc.b_avail)
    iter_time = t_iter(b_min, pc) / 1e3  # s

    state = init_dsgd_state(jax.random.PRNGKey(args.seed), cfg, n, opt_init)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch, seed=args.seed,
                    frontend_tokens=cfg.frontend_tokens, d_model=cfg.d_model)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    print(f"arch={cfg.name} workers={n} sync={sync_desc} "
          f"modelled t_iter={iter_time * 1e3:.2f}ms (paper Eq. 34)")
    history = []
    t0 = time.time()
    for s in range(args.steps):
        per = [synthetic_lm_batch(dc, s, node=i) for i in range(n)]
        batch = {k: jnp.stack([b[k] for b in per]) for k in per[0]}
        state, metrics = step(state, batch)
        if s % args.log_every == 0 or s == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m.update(step=s, wall_s=round(time.time() - t0, 1),
                     modelled_time_s=round((s + 1) * iter_time, 4))
            history.append(m)
            print("  " + json.dumps(m))
        if mgr and s and s % args.ckpt_every == 0:
            mgr.save(state, s)
    if mgr:
        mgr.save(state, args.steps)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"config": vars(args), "topology": topo.name,
                       "r_asym": topo.r_asym() if len(topo.edges) else None,
                       "history": history}, f, indent=1)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
