"""DSGD training driver.

Runs the full stack on whatever devices exist: reduced configs on CPU for
smoke-scale runs, production configs on a real mesh. The gossip topology is
BA-Topo by default — the paper's technique as a first-class launcher flag.

``--elastic`` wraps the loop in the elastic runtime (DESIGN.md §16):
chaos-spec faults (churn / packet loss / stragglers / bandwidth drift) hit
the REAL model's gossip loop, a heartbeat watchdog drops modeled stragglers
from rounds, a DriftDetector re-optimizes the topology mid-training, and
checkpoints carry the full elastic state so ``--resume`` after a SIGKILL
reproduces the uninterrupted loss curve bit-exactly. With no fault flags the
elastic path is bit-exact versus the plain trainer (tested).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --workers 8 --steps 50 --topo ba --r 16
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --workers 16 --topo exponential --sync allreduce
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --workers 8 --steps 40 --elastic --churn-events 1 --drift-step 20 \
      --slow-nodes 2 --slow-bw 1.0 --ckpt-dir /tmp/ck --resume
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, reduced_for_smoke
from repro.core.bandwidth import (
    PaperConstants,
    homo_edge_bandwidth,
    min_edge_bandwidth,
    t_iter,
)
from repro.data import DataConfig, synthetic_lm_batch
from repro.dsgd import (
    DSGDState,
    ElasticRuntime,
    ElasticSpec,
    allreduce_train_step,
    drift_profile,
    dsgd_train_step,
    gossip_sim_tree,
    init_dsgd_state,
    make_chaos,
    no_chaos,
    random_churn_windows,
)
from repro.dsgd.dynamic import cycle_weight_matrices, round_robin_schedules
from repro.dsgd.trainer import _consensus_error, _loss_fn
from repro.launch.steps import topology_for
from repro.optim import apply_updates, make_optimizer, warmup_cosine


def _build_chaos(args, n: int):
    """The run's ChaosSpec from the fault flags (all-defaults → fault-free)."""
    faulty = (args.churn_events > 0 or args.p_drop > 0
              or args.straggler_prob > 0 or args.drift_step >= 0)
    if not faulty:
        return no_chaos(args.steps, n, bandwidth=args.bw0)
    bw = np.full((args.steps, n), args.bw0, np.float64)
    if args.drift_step >= 0:
        bw = drift_profile(args.steps, n, args.drift_step, args.bw0,
                           args.slow_nodes, args.slow_bw)
    churn = random_churn_windows(n, args.steps, args.churn_events,
                                 seed=args.seed) if args.churn_events else []
    return make_chaos(args.steps, n, seed=args.seed, churn=churn,
                      p_drop=args.p_drop, straggler_prob=args.straggler_prob,
                      straggler_mult=args.straggler_mult, bandwidth=bw)


def _dynamic_step(cfg, topo, opt_update):
    """Beyond-paper ``--sync dynamic``: one matching per step (dsgd/dynamic)."""
    Ws = [jnp.asarray(W, jnp.float32)
          for W in cycle_weight_matrices(round_robin_schedules(topo))]
    loss_fn = _loss_fn(cfg)

    @jax.jit
    def _dyn_step(state, batch):
        losses, grads = jax.vmap(jax.value_and_grad(loss_fn))(state.params, batch)
        updates, opt = jax.vmap(opt_update)(grads, state.opt, state.params)
        params = jax.vmap(apply_updates)(state.params, updates)
        Wt = jax.lax.switch(state.step % len(Ws), [lambda W=W: W for W in Ws])
        params = gossip_sim_tree(params, Wt)
        return DSGDState(params, opt, state.step + 1), {
            "loss": losses.mean(), "loss_max": losses.max(),
            "consensus_err": _consensus_error(params)}

    return _dyn_step, len(Ws)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config of the same family (CPU-sized)")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--topo", default="ba",
                    choices=["ba", "ring", "exponential", "equistatic", "torus"])
    ap.add_argument("--r", type=int, default=None, help="edge budget (default 2n)")
    ap.add_argument("--node-bw", default=None,
                    help="comma-separated per-node GB/s — optimizes the BA "
                         "topology under the §VI-A2 node scenario")
    ap.add_argument("--sync", default="gossip",
                    choices=["gossip", "allreduce", "dynamic"])
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"])
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--use-kernel", action="store_true",
                    help="fused Pallas gossip_mix (interpret mode on CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    # ---- elastic runtime (DESIGN.md §16) --------------------------------
    ap.add_argument("--elastic", action="store_true",
                    help="elastic runtime: fault tensors + watchdog + "
                         "mid-training re-optimization")
    ap.add_argument("--churn-events", type=int, default=0)
    ap.add_argument("--p-drop", type=float, default=0.0)
    ap.add_argument("--straggler-prob", type=float, default=0.0)
    ap.add_argument("--straggler-mult", type=float, default=3.0)
    ap.add_argument("--drift-step", type=int, default=-1,
                    help="step at which the slow nodes' NICs collapse (−1 off)")
    ap.add_argument("--slow-nodes", type=int, default=2)
    ap.add_argument("--slow-bw", type=float, default=1.0)
    ap.add_argument("--bw0", type=float, default=PaperConstants().b_avail)
    ap.add_argument("--deadline-factor", type=float, default=3.0)
    ap.add_argument("--activation-lag", type=int, default=1)
    ap.add_argument("--no-reopt", action="store_true",
                    help="elastic without the DriftDetector→re-solve loop")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest restorable checkpoint in "
                         "--ckpt-dir (crash-safe: bit-exact vs uninterrupted)")
    ap.add_argument("--kill-at-step", type=int, default=-1,
                    help="(testing) SIGKILL this process before running the "
                         "given step — simulates a crash mid-run")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    n = args.workers
    if args.elastic and args.sync != "gossip":
        ap.error("--elastic requires --sync gossip (the elastic runtime IS "
                 "the gossip loop)")
    if args.resume and not args.ckpt_dir:
        ap.error("--resume needs --ckpt-dir")

    lr = warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps)
    opt_init, opt_update = make_optimizer(args.optimizer, lr)

    node_bw = ([float(v) for v in args.node_bw.split(",")]
               if args.node_bw else None)
    topo = topology_for(n, kind=args.topo, r=args.r, seed=args.seed,
                        node_bw=node_bw)

    runtime = es = None
    if args.elastic:
        chaos = _build_chaos(args, n)
        spec = ElasticSpec(chaos=chaos, deadline_factor=args.deadline_factor,
                           reopt=not args.no_reopt,
                           activation_lag_steps=args.activation_lag)
        runtime = ElasticRuntime(cfg, spec, topo, opt_update,
                                 use_kernel=args.use_kernel)
        es = runtime.make_state(topo, seed=args.seed)
        faults = "faultless" if chaos.faultless else "chaotic"
        sync_desc = f"elastic[{topo.name}] {faults} r_asym={topo.r_asym():.3f}"
        step = None
    elif args.sync == "allreduce":
        step = allreduce_train_step(cfg, n, opt_update)
        sync_desc = "allreduce"
    elif args.sync == "dynamic":
        step, rounds = _dynamic_step(cfg, topo, opt_update)
        sync_desc = f"dynamic[{topo.name}] rounds={rounds}"
    else:
        step = dsgd_train_step(cfg, topo, opt_update, use_kernel=args.use_kernel)
        sync_desc = f"gossip[{topo.name}] r_asym={topo.r_asym():.3f}"

    # paper's wall-clock model for this topology (Eq. 34/35)
    pc = PaperConstants()
    b_min = (min_edge_bandwidth(homo_edge_bandwidth(topo))
             if len(topo.edges) else pc.b_avail)
    iter_time = t_iter(b_min, pc) / 1e3  # s

    state = init_dsgd_state(jax.random.PRNGKey(args.seed), cfg, n, opt_init)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch, seed=args.seed,
                    frontend_tokens=cfg.frontend_tokens, d_model=cfg.d_model)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    start = 0
    if args.resume:
        restored, rstep, extras = mgr.restore(state, with_extra=True)
        if restored is not None:
            state, start = restored, int(rstep)
            if args.elastic and extras:
                es = runtime.from_extras(extras, name=topo.name)
            print(f"resumed from step {start} "
                  f"({'elastic state restored' if extras else 'pytree only'})")
        else:
            print("no restorable checkpoint found — starting fresh")

    def save(step_label: int) -> None:
        if mgr:
            mgr.save(state, step_label,
                     extra=runtime.to_extras(es) if args.elastic else None)

    print(f"arch={cfg.name} workers={n} sync={sync_desc} "
          f"modelled t_iter={iter_time * 1e3:.2f}ms (paper Eq. 34)")
    history = []
    elastic_log = []
    t0 = time.time()
    modeled_ms = 0.0
    for s in range(start, args.steps):
        if s == args.kill_at_step:
            os.kill(os.getpid(), signal.SIGKILL)     # crash, not cleanup
        data_step = es.data_step if args.elastic else s
        per = [synthetic_lm_batch(dc, data_step, node=i) for i in range(n)]
        batch = {k: jnp.stack([b[k] for b in per]) for k in per[0]}
        if args.elastic:
            state, metrics, rep = runtime.round(state, es, batch)
            modeled_ms += rep.round_ms
            if rep.dropped.any() or rep.swapped or rep.reopt is not None:
                elastic_log.append(
                    {"step": s, "dropped": int(rep.dropped.sum()),
                     "swapped": rep.swapped, "reopt": rep.reopt_reason,
                     "attempts": rep.attempts})
        else:
            state, metrics = step(state, batch)
            modeled_ms += iter_time * 1e3
        if s % args.log_every == 0 or s == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m.update(step=s, wall_s=round(time.time() - t0, 1),
                     modelled_time_s=round(modeled_ms / 1e3, 4))
            history.append(m)
            print("  " + json.dumps(m))
        if s and s % args.ckpt_every == 0:
            save(int(state.step))
    save(int(state.step) if args.steps > start else args.steps)
    if args.json_out:
        out = {"config": vars(args), "topology": topo.name,
               "r_asym": topo.r_asym() if len(topo.edges) else None,
               "history": history}
        if args.elastic:
            out["elastic"] = {"events": es.events, "log": elastic_log,
                              "reopts": es.reopts, "adopted": es.adopted,
                              "drops": es.drops,
                              "final_topology": es.topology.name}
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
