import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract the roofline terms from the compiled
artifact. MUST be imported before any other jax user (the XLA_FLAGS above
lock in 512 placeholder host devices).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                      # all combos, single-pod
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod          # 2×16×16
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k -v
  PYTHONPATH=src python -m repro.launch.dryrun --sync allreduce     # baseline collective
Outputs one JSON record per combo to benchmarks/artifacts/dryrun_<mesh>.json.
"""

import argparse
import json
import time
import traceback

import numpy as np

import jax

from repro.configs import ARCHS, INPUT_SHAPES, get_arch, shape_supported
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.launch.sharding import params_bytes
from repro.models import transformer
from repro.roofline import (
    analytic_flops_bytes,
    collective_bytes_from_hlo,
    model_flops,
    roofline_report,
)
from repro.roofline.analysis import active_param_count

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "benchmarks", "artifacts")


def _param_counts(cfg) -> dict:
    shapes = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    moe = 0
    if cfg.num_experts:
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        moe = sum(int(np.prod(l.shape)) for p, l in flat
                  if "moe" in jax.tree_util.keystr(p))
    return {"params": total,
            "active": active_param_count(cfg, total, moe),
            "param_bytes": params_bytes(cfg)}


def _cache_bytes(cfg, built) -> int:
    if built.mode != "decode":
        return 0
    caches = built.args[1].caches
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(caches))


def run_combo(arch: str, shape_name: str, mesh, mesh_name: str, *,
              sync: str = "gossip", topo_kind: str = "ba",
              topo_r: int | None = None, verbose: bool = False,
              keep_hlo: bool = False, accum_steps: int = 1,
              tp_only: bool | None = None, expert_parallel: bool = False) -> dict:
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "sync": sync, "topo": topo_kind}
    t0 = time.time()
    built = build_step(arch, shape_name, mesh, sync=sync, topo_kind=topo_kind,
                       topo_r=topo_r, accum_steps=accum_steps, tp_only=tp_only,
                       expert_parallel=expert_parallel)
    # donation mirrors production: train updates (params, opt) in place,
    # decode updates the KV/SSM caches in place — without it the dry-run
    # double-counts a full state copy in temp bytes
    donate = {"train": (0,), "decode": (1,), "prefill": ()}[built.mode]
    with jax.set_mesh(mesh):
        lowered = jax.jit(built.fn, donate_argnums=donate).lower(*built.args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    chips = int(np.prod(mesh.devices.shape))

    counts = _param_counts(cfg)
    counts["cache_bytes"] = _cache_bytes(cfg, built)
    analytic = analytic_flops_bytes(cfg, shape, built.mode, counts)
    mflops = model_flops(cfg, int(analytic["tokens"]), built.mode,
                         counts["params"], counts["active"])
    rep = roofline_report(
        arch=arch, shape=shape, mesh_name=mesh_name, mode=built.mode,
        chips=chips, analytic=analytic, mflops=mflops, collective=coll,
        hlo_flops_raw=float(cost.get("flops", 0.0)),
        cross_pod="pod" in mesh.axis_names and mesh.shape["pod"] > 1,
        extras={"collective_by_op": coll["by_op"], "n_collectives": coll["count"]})

    rec.update(rep.as_dict())
    rec.update(
        lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
        mem_per_device={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        # args + scratch; aliased (donated) outputs live in their argument
        # buffers, and XLA CPU's accounting re-counts them inside temp
        hbm_per_device_gb=round((mem.argument_size_in_bytes +
                                 mem.temp_size_in_bytes -
                                 mem.alias_size_in_bytes) / 1e9, 3),
        plan={"gossip_axes": built.plan.gossip_axes,
              "tensor_axes": built.plan.tensor_axes,
              "n_workers": built.plan.n_workers},
        step_meta=built.meta,
    )
    if keep_hlo:
        os.makedirs(ARTIFACTS, exist_ok=True)
        with open(os.path.join(ARTIFACTS, f"hlo_{arch}_{shape_name}_{mesh_name}.txt"),
                  "w") as f:
            f.write(hlo)
    if verbose:
        print(json.dumps({k: v for k, v in rec.items()
                          if k not in ("collective_by_op",)}, indent=2,
                         default=str))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="override single-pod mesh, e.g. 32x8 (beyond-paper "
                         "worker-geometry experiments; chip count must stay 256)")
    ap.add_argument("--sync", default="gossip",
                    choices=["gossip", "allreduce", "none"])
    ap.add_argument("--topo", default="ba",
                    choices=["ba", "ring", "exponential", "equistatic", "torus"])
    ap.add_argument("--topo-r", type=int, default=None)
    ap.add_argument("--tag", default="", help="suffix for the output json")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--expert-parallel", action="store_true",
                    help="MoE expert dim owns the data axis (pod-worker train)")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train shapes)")
    ap.add_argument("--tp-only", default=None, choices=[None, "on", "off"],
                    help="force TP-only (on) / 2-D FSDP (off) inference sharding")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args()

    if args.mesh_shape:
        import jax as _jax
        d, m = (int(x) for x in args.mesh_shape.split("x"))
        mesh = _jax.make_mesh((d, m), ("data", "model"))
        mesh_name = f"{d}x{m}"
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    records, failures = [], []
    for arch in archs:
        for shape in shapes:
            if not shape_supported(arch, shape):
                records.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                                "skipped": "long_500k needs sub-quadratic attention"})
                print(f"[skip] {arch} × {shape} (full attention)")
                continue
            label = f"{arch} × {shape} on {mesh_name}"
            try:
                t0 = time.time()
                rec = run_combo(arch, shape, mesh, mesh_name, sync=args.sync,
                                topo_kind=args.topo, topo_r=args.topo_r,
                                verbose=args.verbose, keep_hlo=args.keep_hlo,
                                accum_steps=args.accum,
                                tp_only={None: None, "on": True, "off": False}[args.tp_only],
                                expert_parallel=args.expert_parallel)
                records.append(rec)
                print(f"[ok]   {label}: dominant={rec['dominant']} "
                      f"compute={rec['compute_s']:.2e}s memory={rec['memory_s']:.2e}s "
                      f"collective={rec['collective_s']:.2e}s "
                      f"hbm/dev={rec['hbm_per_device_gb']}GB "
                      f"({time.time() - t0:.0f}s)")
            except Exception as e:  # a failure here is a sharding bug
                failures.append(label)
                records.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                                "error": f"{type(e).__name__}: {e}"})
                print(f"[FAIL] {label}: {type(e).__name__}: {e}")
                if args.verbose:
                    traceback.print_exc()

    os.makedirs(ARTIFACTS, exist_ok=True)
    suffix = f"_{args.tag}" if args.tag else ""
    out = os.path.join(ARTIFACTS, f"dryrun_{mesh_name}{suffix}.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=1, default=str)
    print(f"\nwrote {len(records)} records → {out}")
    if failures:
        print(f"{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("all combinations lowered + compiled.")


if __name__ == "__main__":
    main()
