"""Deterministic synthetic data substrate.

Two pipelines:

1. ``token_pipeline`` — language-model batches {tokens, labels} with a
   *learnable* structure (a hidden bigram Markov chain) so training loss
   demonstrably decreases; used by the end-to-end DSGD example and the
   per-arch smoke tests. VLM/audio archs additionally get stub ``embeds``
   (the brief's frontend carve-out).

2. ``make_classification_data`` + ``class_balanced_partition`` — mirrors the
   paper's §VI-B protocol: "each node randomly samples the same number of
   samples from each class" (IID class-balanced CIFAR-like partition), on a
   synthetic Gaussian-mixture task so the decentralized-vs-topology
   comparisons of Table II can run offline.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

__all__ = ["DataConfig", "token_pipeline", "synthetic_lm_batch", "synthetic_batches",
           "make_classification_data", "class_balanced_partition",
           "epoch_permutations"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int           # per-node batch
    frontend_tokens: int = 0  # > 0 → provide stub embeds (vlm/audio)
    d_model: int = 0          # embed dim for stub embeds
    seed: int = 0


def _bigram_table(vocab: int, seed: int) -> np.ndarray:
    """Row-stochastic bigram transition table with low entropy (learnable)."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(vocab, vocab)) * 2.0
    # sparsify: each token strongly predicts ~4 successors
    top = np.argpartition(-logits, 4, axis=1)[:, :4]
    mask = np.full_like(logits, -1e9)
    np.put_along_axis(mask, top, 0.0, axis=1)
    p = np.exp(logits + mask)
    return p / p.sum(axis=1, keepdims=True)


def synthetic_lm_batch(cfg: DataConfig, step: int, node: int = 0) -> dict:
    """One {tokens, labels(, embeds)} batch. Pure function of (cfg, step, node)
    so every DSGD worker regenerates its own shard without host state."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, node, step]))
    table = _bigram_table(cfg.vocab_size, cfg.seed)
    B, S = cfg.batch_size, cfg.seq_len
    toks = np.empty((B, S), dtype=np.int32)
    toks[:, 0] = rng.integers(0, cfg.vocab_size, size=B)
    u = rng.random((B, S))
    cdf = np.cumsum(table, axis=1)
    for t in range(1, S):
        toks[:, t] = np.argmax(cdf[toks[:, t - 1]] > u[:, t, None], axis=1)
    batch = {
        "tokens": jnp.asarray(toks),
        "labels": jnp.asarray(np.concatenate(
            [toks[:, 1:], np.full((B, 1), -100, np.int32)], axis=1)),
    }
    if cfg.frontend_tokens:
        emb = rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        batch["embeds"] = jnp.asarray(emb)
    return batch


def token_pipeline(cfg: DataConfig, node: int = 0):
    """Infinite iterator of LM batches for one worker."""
    step = 0
    while True:
        yield synthetic_lm_batch(cfg, step, node)
        step += 1


def synthetic_batches(cfg: DataConfig, steps: int, node: int = 0) -> list[dict]:
    return [synthetic_lm_batch(cfg, s, node) for s in range(steps)]


# ---------------------------------------------------------------------------
# classification substrate for the DSGD topology experiments (paper §VI-B)
# ---------------------------------------------------------------------------

def make_classification_data(num_classes: int = 10, dim: int = 64,
                             samples_per_class: int = 512, seed: int = 0,
                             class_sep: float = 3.0, noise_seed: int | None = None):
    """Gaussian-mixture classification set (CIFAR-10 stand-in, offline).

    ``seed`` fixes the class means (the task); ``noise_seed`` draws the
    samples — pass a different noise_seed for a held-out test split of the
    SAME task. Returns (X (N, dim) f32, y (N,) i32)."""
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(num_classes, dim)) * class_sep / np.sqrt(dim)
    rng = np.random.default_rng(seed if noise_seed is None else noise_seed)
    # one (C, S, D) draw consumes the PCG64 stream exactly like C sequential
    # (S, D) draws, so this stays bit-identical to the seed per-class loop
    noise = rng.normal(size=(num_classes, samples_per_class, dim))
    X = (means[:, None, :] + noise).reshape(-1, dim).astype(np.float32)
    y = np.repeat(np.arange(num_classes, dtype=np.int32), samples_per_class)
    perm = rng.permutation(len(y))
    return X[perm], y[perm]


def epoch_permutations(parts: list[np.ndarray], epochs: int, batch: int,
                       seed: int = 0) -> np.ndarray:
    """Per-worker minibatch gather indices for a whole training run, as ONE
    int tensor of shape ``(epochs, iters, n, batch)`` (``iters`` = shared
    iterations per epoch = min partition length // batch).

    ``out[e, it, w]`` indexes the global X/y arrays for worker ``w``'s
    ``it``-th minibatch of epoch ``e`` — the device-resident engine gathers
    batches inside its scan (``X[idx]``) instead of host-assembling a
    ``jnp.stack`` per step. Index generation itself stays on the host
    numpy Generator, consuming the SAME stream as the per-epoch loop
    (``rng.permutation(part)`` per worker per epoch), so batch order is
    bit-identical to the host oracle given a seed. int32: device gather
    indices, and every consumer traces one dtype.
    """
    n = len(parts)
    per = min(len(p) for p in parts)
    iters = per // batch
    rng = np.random.default_rng(seed)
    out = np.empty((epochs, iters, n, batch), np.int32)
    for e in range(epochs):
        for w, p in enumerate(parts):
            order = rng.permutation(p)[: iters * batch]
            out[e, :, w, :] = order.reshape(iters, batch)
    return out


def class_balanced_partition(y: np.ndarray, n_nodes: int, seed: int = 0) -> list[np.ndarray]:
    """Paper §VI-B: each node samples the same number of samples per class."""
    rng = np.random.default_rng(seed)
    parts: list[list[int]] = [[] for _ in range(n_nodes)]
    for c in np.unique(y):
        idx = np.nonzero(y == c)[0]
        rng.shuffle(idx)
        take = (len(idx) // n_nodes) * n_nodes
        for k, chunk in enumerate(np.split(idx[:take], n_nodes)):
            parts[k].extend(chunk.tolist())
    return [np.asarray(sorted(p), dtype=np.int64) for p in parts]
