"""Synthetic data pipelines (offline container — no dataset downloads)."""
from .pipeline import (
    DataConfig,
    class_balanced_partition,
    epoch_permutations,
    make_classification_data,
    synthetic_batches,
    synthetic_lm_batch,
    token_pipeline,
)

__all__ = [
    "DataConfig", "class_balanced_partition", "epoch_permutations",
    "make_classification_data", "synthetic_batches", "synthetic_lm_batch",
    "token_pipeline",
]
