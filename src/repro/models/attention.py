"""Grouped-query attention with full / sliding-window masks, optional score
soft-capping (Gemma-2) and QKV bias (Qwen1.5); prefill + single-token decode
paths with an explicit KV cache.

Shapes:
  x              (B, S, D)
  q              (B, S, Hq, hd)
  k, v           (B, S, Hkv, hd)
  cache k/v      (B, C, Hkv, hd)   C = cache capacity (full seq or window)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, softcap

__all__ = ["AttnParams", "init_attn", "attend_full", "attend_chunked", "attn_forward",
           "attn_decode", "KVCache", "init_kv_cache"]


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, C, Hkv, hd)
    v: jnp.ndarray
    # ring-buffer write index is derived from absolute position for SWA caches


def init_kv_cache(batch: int, capacity: int, kv_heads: int, head_dim: int, dtype) -> KVCache:
    shape = (batch, capacity, kv_heads, head_dim)
    return KVCache(jnp.zeros(shape, dtype=dtype), jnp.zeros(shape, dtype=dtype))


def init_attn(key, cfg, dtype) -> dict:
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(kv, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.num_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype=dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype=dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype=dtype)
    return p


def _qkv(params, x, cfg):
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    return q, k, v


def attend_full(q, k, v, mask, attn_softcap: float = 0.0):
    """q: (B,Sq,Hq,hd); k,v: (B,Sk,Hkv,hd); mask: (B,1,Sq,Sk) or broadcastable.
    GQA: query heads grouped onto kv heads."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    q = q.reshape(B, Sq, Hkv, group, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) / jnp.sqrt(hd).astype(jnp.float32)
    if attn_softcap:
        scores = softcap(scores, attn_softcap)
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, Hq, hd)


def _causal_mask(S: int, window, dtype=jnp.bool_):
    """Tracer-safe causal(+sliding-window) mask. ``window`` may be a traced
    int32 scalar (0 → full causal) so it can be a per-layer scan input."""
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    w = jnp.asarray(window, jnp.int32)
    m = m & jnp.where(w > 0, j > i - w, True)
    return m[None, None]  # (1,1,S,S)


def attend_chunked(q, k, v, window, attn_softcap: float = 0.0, *, chunk: int = 1024,
                   causal: bool = True):
    """Flash-style online-softmax attention, lax.scan over KV chunks.

    Memory O(S·chunk) instead of O(S²) — the pure-JAX analogue of the Pallas
    flash kernel's tiling, and the oracle the kernel validates against.
    q: (B,S,Hq,hd); k,v: (B,S,Hkv,hd); window traced int32 (0 = full causal).
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    C = min(chunk, S)
    while S % C:  # largest divisor of S ≤ chunk (VLM/audio odd lengths)
        C -= 1
    nc = S // C
    qf = q.reshape(B, S, Hkv, group, hd).astype(jnp.float32)
    kc = k.reshape(B, nc, C, Hkv, hd).astype(jnp.float32)
    vc = v.reshape(B, nc, C, Hkv, hd).astype(jnp.float32)
    w = jnp.asarray(window, jnp.int32)
    qpos = jnp.arange(S)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    def body(carry, inp):
        m, l, acc = carry                      # (B,S,Hkv,g), (B,S,Hkv,g), (B,S,Hkv,g,hd)
        kb, vb, c_idx = inp                    # (B,C,Hkv,hd), (B,C,Hkv,hd), scalar
        kpos = c_idx * C + jnp.arange(C)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb) * scale
        if attn_softcap:
            s = attn_softcap * jnp.tanh(s / attn_softcap)
        msk = kpos[None, :] <= qpos[:, None] if causal else jnp.ones((S, C), bool)
        msk = msk & jnp.where(w > 0, kpos[None, :] > qpos[:, None] - w, True)
        s = jnp.where(msk[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqhgk,bkhd->bqhgd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, Hkv, group), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, group), jnp.float32)
    acc0 = jnp.zeros((B, S, Hkv, group, hd), jnp.float32)
    # checkpoint: recompute the (B,S,Hkv,g,C) score block in bwd instead of
    # saving one per chunk — otherwise bwd memory is O(S²) again
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), (m0, l0, acc0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, Hq, hd).astype(q.dtype)


def attn_forward(params, x, cfg, *, window=0, positions=None, cache: KVCache | None = None,
                 chunked: bool = True):
    """Full-sequence forward (train / prefill). Returns (out, new_cache)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if chunked and S > 128:
        out = attend_chunked(q, k, v, window, cfg.attn_logit_softcap)
    else:
        mask = _causal_mask(S, window)
        out = attend_full(q, k, v, mask, cfg.attn_logit_softcap)
    new_cache = None
    if cache is not None:
        C = cache.k.shape[1]
        if C >= S:
            newk = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0))
            newv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0))
        else:  # ring cache keeps the last C positions at slot = pos % C
            newk = jnp.roll(k[:, S - C:], S % C, axis=1).astype(cache.k.dtype)
            newv = jnp.roll(v[:, S - C:], S % C, axis=1).astype(cache.v.dtype)
        new_cache = KVCache(newk, newv)
    hd = cfg.resolved_head_dim
    return out.reshape(B, S, cfg.num_heads * hd) @ params["wo"], new_cache


def attn_decode(params, x, cfg, cache: KVCache, pos: jnp.ndarray, *, window=0,
                ring: bool = False, use_kernel: bool = False):
    """Single-token decode: x (B, 1, D); pos scalar absolute position.

    Two static cache regimes (chosen by the serving layer):
      linear (C ≥ max position): slot = pos, window enforced by explicit mask
        — ``window`` may be a traced per-layer scan input (gemma2 local/global);
      ring  (C == window): slot = pos % C, the buffer itself IS the window.
    Returns (out (B,1,D), updated cache)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    w = jnp.asarray(window, jnp.int32)
    q, k, v = _qkv(params, x, cfg)
    q = apply_rope(q, pos[None, None].astype(jnp.int32) * jnp.ones((B, 1), jnp.int32), cfg.rope_theta)
    k = apply_rope(k, pos[None, None].astype(jnp.int32) * jnp.ones((B, 1), jnp.int32), cfg.rope_theta)
    C = cache.k.shape[1]
    slot = ((pos % C) if ring else jnp.minimum(pos, C - 1)).astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    newk = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (zero, slot, zero, zero))
    newv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (zero, slot, zero, zero))
    idx = jnp.arange(C)
    if ring:
        valid = (idx <= slot) | (pos >= C)   # fully valid once wrapped
    else:
        valid = (idx <= slot) & jnp.where(w > 0, idx > pos - w, True)
    if use_kernel:
        from repro.kernels.decode_attention import ops as dec_ops

        out = dec_ops.decode_attention(q[:, 0], newk, newv, valid,
                                       attn_softcap=cfg.attn_logit_softcap)
        out = out[:, None]
    else:
        mask = valid[None, None, None, :]  # (1,1,1,C)
        out = attend_full(q, newk, newv, mask, cfg.attn_logit_softcap)
    return out.reshape(B, 1, cfg.num_heads * hd) @ params["wo"], KVCache(newk, newv)
