"""Model assembly for all assigned architecture families.

One functional model per family, layers stacked with ``jax.lax.scan`` over
vmapped-init parameter stacks (small HLO, fast multi-arch dry-run compiles):

  dense   — GQA attention + SwiGLU (smollm, minitron, qwen1.5, gemma2 with
            local/global alternating windows + logit softcaps)
  moe     — GQA attention + top-k MoE FFN (mixtral 8e/top2 SWA,
            granite 32e/top8)
  ssm     — Mamba-2 / SSD blocks (mamba2-780m)
  hybrid  — Mamba-2 blocks with one SHARED attention block every
            ``shared_attn_every`` layers (zamba2)
  vlm     — dense decoder consuming [patch-embeds ; text-embeds]
            (internvl2 backbone; ViT frontend is a stub per the brief)
  audio   — encoder-decoder with cross attention (whisper backbone;
            mel+conv frontend is a stub per the brief)

Public entry points (all pure functions of (params, cfg, ...)):
  init_params, train_loss, prefill, decode_step, init_caches
"""
from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .attention import attn_decode, attn_forward, init_attn, init_kv_cache
from .common import dense_init, embed_init, rms_norm, softcap
from .mlp import gelu_mlp, init_gelu_mlp, init_swiglu, swiglu
from .moe import init_moe, moe_forward
from .partitioning import get_rules
from .ssm import init_mamba2, init_ssm_cache, mamba2_decode, mamba2_forward

__all__ = [
    "init_params", "train_loss", "prefill", "decode_step", "init_caches",
    "layer_windows", "param_count", "Caches",
]


def _moe(mp, h2, cfg, *, min_capacity: int = 1):
    """Route to the pjit dispatch (default) or the shard_map expert-parallel
    block when the launch layer installed ``moe_impl: expert_parallel``."""
    if get_rules().get("moe_impl") == "expert_parallel":
        from .moe_ep import moe_forward_expert_parallel
        return moe_forward_expert_parallel(
            mp, h2, top_k=cfg.experts_per_token,
            axis=get_rules().get("moe_expert_axis", "model"),
            token_axes=get_rules().get("moe_token_axes", ("data",)),
            capacity_factor=cfg.moe_capacity_factor, min_capacity=min_capacity)
    return moe_forward(mp, h2, top_k=cfg.experts_per_token,
                       capacity_factor=cfg.moe_capacity_factor,
                       min_capacity=min_capacity)


class Caches(NamedTuple):
    """Stacked per-layer decode state. Unused fields are () placeholders."""
    kv: Any = ()         # (L, B, C, Hkv, hd) ×2 — self-attention KV
    ssm: Any = ()        # SSMCache with (L, B, ...) leaves
    shared_kv: Any = ()  # hybrid: (G, B, C, Hkv, hd) ×2 for the shared block
    cross_kv: Any = ()   # audio: precomputed (L, B, Tenc, Hkv, hd) ×2


# ---------------------------------------------------------------------------
# per-layer heterogeneity
# ---------------------------------------------------------------------------

def layer_windows(cfg, *, long_context: bool = False) -> jnp.ndarray:
    """Per-layer sliding windows (int32, 0 = full attention).

    gemma2 ``local_global``: even layers SWA, odd layers global — in the
    documented long-context serving variant every layer is SWA.
    mixtral ``swa``: every layer windowed.
    """
    L = cfg.num_layers
    if cfg.attn_pattern == "local_global" and cfg.sliding_window:
        w = [cfg.sliding_window if (i % 2 == 0 or long_context) else 0 for i in range(L)]
    elif cfg.sliding_window:
        w = [cfg.sliding_window] * L
    elif long_context and cfg.arch_type == "hybrid":
        # zamba2 long-context serving: shared attention gets a sliding-window
        # ring cache (documented liberty — the Mamba2 state is the long path)
        w = [4096] * L
    else:
        w = [0] * L
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _init_attn_layer(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype), "attn": init_attn(k1, cfg, dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.num_experts:
        p["moe"] = init_moe(k2, cfg.d_model, cfg.d_ff, cfg.num_experts, dtype)
    elif cfg.arch_type == "audio":
        p["mlp"] = init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    else:
        p["mlp"] = init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)
    if cfg.cross_attention and cfg.arch_type == "audio":
        p["ln_x"] = jnp.zeros((cfg.d_model,), dtype)
        p["xattn"] = init_attn(k3, cfg, dtype)
    return p


def _init_ssm_layer(key, cfg, dtype):
    return {"ln": jnp.zeros((cfg.d_model,), dtype), "mamba": init_mamba2(key, cfg, dtype)}


def init_params(key, cfg) -> dict:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    p: dict = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
               "final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)

    L = cfg.num_layers
    if cfg.arch_type in ("dense", "moe", "vlm"):
        lkeys = jax.random.split(keys[2], L)
        p["layers"] = jax.vmap(lambda k: _init_attn_layer(k, cfg, dtype))(lkeys)
    elif cfg.arch_type == "ssm":
        lkeys = jax.random.split(keys[2], L)
        p["layers"] = jax.vmap(lambda k: _init_ssm_layer(k, cfg, dtype))(lkeys)
    elif cfg.arch_type == "hybrid":
        lkeys = jax.random.split(keys[2], L)
        p["layers"] = jax.vmap(lambda k: _init_ssm_layer(k, cfg, dtype))(lkeys)
        p["shared_attn"] = _init_attn_layer(keys[3], cfg, dtype)  # ONE block, reused
    elif cfg.arch_type == "audio":
        ekeys = jax.random.split(keys[2], cfg.encoder_layers)
        enc_cfg = cfg  # same dims for whisper-tiny enc/dec
        p["enc_layers"] = jax.vmap(lambda k: _init_attn_layer(k, _no_cross(enc_cfg), dtype))(ekeys)
        p["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
        dkeys = jax.random.split(keys[3], L)
        p["layers"] = jax.vmap(lambda k: _init_attn_layer(k, cfg, dtype))(dkeys)
    else:
        raise ValueError(cfg.arch_type)
    if cfg.frontend:
        # projector from frontend embedding space to d_model (stubbed frontend
        # provides d_model-sized embeddings already; keep a learned projector
        # so the parameter inventory matches a real VLM/audio deployment)
        p["frontend_proj"] = dense_init(keys[4], cfg.d_model, cfg.d_model, dtype)
    return p


def _no_cross(cfg):
    from dataclasses import replace
    return replace(cfg, cross_attention=False)


def param_count(params) -> int:
    return int(sum(x.size for x in jax.tree.leaves(params)))


# ---------------------------------------------------------------------------
# block bodies (full-sequence)
# ---------------------------------------------------------------------------

def _attn_block(lp, x, cfg, window, positions, *, causal=True, cache=None):
    h, new_cache = attn_forward(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
                                window=window, positions=positions, cache=cache)
    x = x + h
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        out, aux = _moe(lp["moe"], h2, cfg)
    elif cfg.arch_type == "audio":
        out, aux = gelu_mlp(lp["mlp"], h2), 0.0
    else:
        out, aux = swiglu(lp["mlp"], h2), 0.0
    return x + out, aux, new_cache


def _ssm_block(lp, x, cfg, cache=None, use_kernel=False):
    h, new_cache = mamba2_forward(lp["mamba"], rms_norm(x, lp["ln"], cfg.norm_eps), cfg,
                                  cache=cache, use_kernel=use_kernel)
    return x + h, new_cache


# ---------------------------------------------------------------------------
# full-sequence stacks (train / prefill) — lax.scan over stacked layer params
# ---------------------------------------------------------------------------

def _stack_dense(params, x, cfg, windows, positions, *, with_cache: bool, cache_cap: int = 0):
    dtype = x.dtype
    B, S, _ = x.shape

    def body(carry, inp):
        h, aux = carry
        lp, w = inp
        cache = (init_kv_cache(B, cache_cap, cfg.num_kv_heads, cfg.resolved_head_dim, dtype)
                 if with_cache else None)
        h, a, new_cache = _attn_block(lp, h, cfg, w, positions, cache=cache)
        ys = new_cache if with_cache else 0
        return (h, aux + a), ys

    (x, aux), caches = jax.lax.scan(_maybe_remat(body, cfg), (x, 0.0), (params["layers"], windows))
    return x, aux, caches if with_cache else ()


def _stack_ssm(params, x, cfg, *, with_cache: bool, use_kernel: bool = False):
    B = x.shape[0]

    def body(h, lp):
        cache = init_ssm_cache(B, cfg, h.dtype) if with_cache else None
        h, new_cache = _ssm_block(lp, h, cfg, cache=cache, use_kernel=use_kernel)
        return h, (new_cache if with_cache else 0)

    x, caches = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
    return x, caches if with_cache else ()


def _stack_hybrid(params, x, cfg, windows, positions, *, with_cache: bool, cache_cap: int = 0):
    """zamba2: groups of ``shared_attn_every`` mamba layers, each followed by
    the single shared attention block. Scan over groups; inner scan over the
    group's mamba layers (params reshaped to (G, k, ...))."""
    k = cfg.shared_attn_every
    G = cfg.num_layers // k
    B = x.shape[0]
    grouped = jax.tree.map(lambda a: a.reshape((G, k) + a.shape[1:]), params["layers"])
    shared = params["shared_attn"]
    w = windows[0] if windows.shape[0] else jnp.int32(0)

    def group_body(carry, inp):
        h, _ = carry
        glp = inp

        def inner(hh, lp):
            cache = init_ssm_cache(B, cfg, hh.dtype) if with_cache else None
            hh, c = _ssm_block(lp, hh, cfg, cache=cache)
            return hh, (c if with_cache else 0)

        h, ssm_caches = jax.lax.scan(inner, h, glp)
        cache = (init_kv_cache(B, cache_cap, cfg.num_kv_heads, cfg.resolved_head_dim, h.dtype)
                 if with_cache else None)
        h, _, akv = _attn_block(shared, h, cfg, w, positions, cache=cache)
        return (h, 0.0), (ssm_caches if with_cache else 0, akv if with_cache else 0)

    (x, _), (ssm_caches, attn_caches) = jax.lax.scan(
        _maybe_remat(group_body, cfg), (x, 0.0), grouped)
    if with_cache:
        # ssm_caches leaves: (G, k, B, ...) → (L, B, ...)
        ssm_caches = jax.tree.map(lambda a: a.reshape((G * k,) + a.shape[2:]), ssm_caches)
        return x, ssm_caches, attn_caches
    return x, (), ()


def _encode_audio(params, frames, cfg):
    """Whisper encoder over (projected) stub frame embeddings: non-causal."""
    x = frames @ params["frontend_proj"]
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, lp):
        a, _ = attn_forward(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                            _no_cross(cfg), window=0, positions=positions)
        h = h + a
        h = h + gelu_mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, 0

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _stack_audio_decoder(params, x, enc_out, cfg, positions, *, with_cache: bool,
                         cache_cap: int = 0):
    """Whisper decoder: causal self-attn + cross-attn to enc_out + GELU MLP."""
    B, S, _ = x.shape
    from .attention import _qkv, attend_full  # cross-attn building blocks

    def body(carry, lp):
        h, _ = carry
        cache = (init_kv_cache(B, cache_cap, cfg.num_kv_heads, cfg.resolved_head_dim, h.dtype)
                 if with_cache else None)
        a, kv = attn_forward(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                             window=0, positions=positions, cache=cache)
        h = h + a
        # cross attention (non-causal over encoder tokens)
        hq = rms_norm(h, lp["ln_x"], cfg.norm_eps)
        q, _, _ = _qkv(lp["xattn"], hq, cfg)
        _, ck, cv = _qkv(lp["xattn"], enc_out, cfg)
        mask = jnp.ones((1, 1, S, enc_out.shape[1]), bool)
        xa = attend_full(q, ck, cv, mask)
        hd = cfg.resolved_head_dim
        h = h + xa.reshape(B, S, cfg.num_heads * hd) @ lp["xattn"]["wo"]
        h = h + gelu_mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))
        return (h, 0.0), ((kv, (ck, cv)) if with_cache else 0)

    (x, _), caches = jax.lax.scan(body, (x, 0.0), params["layers"])
    if with_cache:
        return x, caches[0], caches[1]
    return x, (), ()


def _maybe_remat(body, cfg):
    """Per-layer activation checkpointing for big configs (train memory)."""
    if getattr(cfg, "_remat", True):
        return jax.checkpoint(body, prevent_cse=False)
    return body


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg):
    x = params["embed"][tokens]
    if cfg.arch_type in ("dense", "vlm") or cfg.arch_type == "moe":
        x = x * jnp.sqrt(cfg.d_model).astype(x.dtype) if cfg.logit_softcap else x
    return x


def _logits(params, x, cfg):
    head = params.get("lm_head", None)
    logits = x @ head if head is not None else x @ params["embed"].T
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _forward_seq(params, cfg, batch, *, with_cache: bool = False, cache_cap: int = 0,
                 long_context: bool = False):
    """Shared full-sequence path. batch: {"tokens", optional "embeds"}.
    Returns (hidden (B,S_total,D), aux, caches, n_prefix)."""
    tokens = batch["tokens"]
    x = _embed(params, tokens, cfg)
    n_prefix = 0
    windows = layer_windows(cfg, long_context=long_context)
    positions = None
    if cfg.arch_type == "vlm":
        patches = batch["embeds"] @ params["frontend_proj"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        n_prefix = patches.shape[1]
    if cfg.arch_type == "audio":
        enc_out = _encode_audio(params, batch["embeds"], cfg)
        positions = jnp.arange(x.shape[1])[None, :]
        x, kv, cross = _stack_audio_decoder(params, x, enc_out, cfg, positions,
                                            with_cache=with_cache, cache_cap=cache_cap)
        return rms_norm(x, params["final_norm"], cfg.norm_eps), 0.0, Caches(kv=kv, cross_kv=cross), 0
    positions = jnp.arange(x.shape[1])[None, :]
    if cfg.arch_type == "ssm":
        x, caches = _stack_ssm(params, x, cfg, with_cache=with_cache)
        caches = Caches(ssm=caches)
        aux = 0.0
    elif cfg.arch_type == "hybrid":
        x, ssm_c, attn_c = _stack_hybrid(params, x, cfg, windows, positions,
                                         with_cache=with_cache, cache_cap=cache_cap)
        caches = Caches(ssm=ssm_c, shared_kv=attn_c)
        aux = 0.0
    else:
        x, aux, kv = _stack_dense(params, x, cfg, windows, positions,
                                  with_cache=with_cache, cache_cap=cache_cap)
        caches = Caches(kv=kv)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux, caches, n_prefix


def _nll_sum(params, x, labels, cfg):
    """Σ nll over valid positions + valid count, for one (B, c, D) chunk.

    nll = logsumexp(logits) − logits[label], written entirely as REDUCTIONS
    over the vocab axis (max / sum / masked-sum) — a ``take_along_axis``
    gather on a vocab-sharded logits tensor forces GSPMD to all-gather the
    full (B, c, V) block per chunk (≈8 GB f32 at V=256k), whereas reductions
    stay sharded and only their scalar partials cross chips.
    """
    logits = _logits(params, x, cfg)              # (B,c,V) f32
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    onehot = (jnp.arange(logits.shape[-1])[None, None, :] == safe[..., None])
    target = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - target
    return jnp.sum(nll * valid).astype(jnp.float32), jnp.sum(valid).astype(jnp.int32)


def loss_chunk_for(cfg, batch_size: int, budget_bytes: float = 2e9) -> int:
    """Sequence-chunk length keeping the (B, c, V) f32 logits under budget —
    big-vocab models (gemma2: 256k) cannot materialize (B, S, V) at once."""
    c = budget_bytes / (4.0 * batch_size * cfg.vocab_size)
    return max(64, int(2 ** np.floor(np.log2(max(c, 64)))))


def train_loss(params, cfg, batch, *, aux_weight: float = 0.01,
               loss_chunk: int | None = None):
    """Causal-LM next-token loss. batch: tokens (B,S), labels (B,S) with
    -100 = ignore; vlm/audio additionally embeds (B,T,D).

    The unembedding + cross-entropy is scanned over sequence chunks so the
    f32 logits never materialize at (B, S, V) — with 256k vocabs that single
    tensor would dwarf the model. ``loss_chunk=None`` picks a chunk from a
    2 GB logits budget; pass 0 to disable chunking.
    """
    x, aux, _, n_prefix = _forward_seq(params, cfg, batch, with_cache=False)
    if n_prefix:
        x = x[:, n_prefix:]
    labels = batch["labels"]
    B, S, _ = x.shape
    if loss_chunk is None:
        loss_chunk = loss_chunk_for(cfg, B)
    if loss_chunk and S % loss_chunk == 0 and S > loss_chunk:
        nc = S // loss_chunk
        xc = x.reshape(B, nc, loss_chunk, -1).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, nc, loss_chunk).transpose(1, 0, 2)

        def body(carry, inp):
            s, n = carry
            xi, li = inp
            si, ni = jax.checkpoint(
                lambda a, b: _nll_sum(params, a, b, cfg))(xi, li)
            return (s + si, n + ni), None

        (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.int32(0)), (xc, lc))
    else:
        tot, cnt = _nll_sum(params, x, labels, cfg)
    loss = tot / jnp.maximum(cnt, 1)
    return loss + aux_weight * aux


def prefill(params, cfg, batch, *, cache_cap: int | None = None, long_context: bool = False):
    """Prefill: full forward writing KV/SSM caches. Returns (last_logits, caches)."""
    S = batch["tokens"].shape[1]
    if cfg.arch_type == "vlm":
        S = S + cfg.frontend_tokens  # patch prefix occupies cache slots too
    if cache_cap is None:
        w = int(cfg.sliding_window) if cfg.sliding_window else 0
        cache_cap = min(S, w) if (w and long_context) else S
    x, _, caches, _ = _forward_seq(params, cfg, batch, with_cache=True,
                                   cache_cap=cache_cap, long_context=long_context)
    return _logits(params, x[:, -1:], cfg), caches


def init_caches(cfg, batch_size: int, cache_cap: int, dtype=None) -> Caches:
    """Empty decode caches sized for ``cache_cap`` past positions."""
    dtype = dtype or _dtype(cfg)
    L, B = cfg.num_layers, batch_size
    if cfg.arch_type == "ssm":
        c = init_ssm_cache(B, cfg, dtype)
        return Caches(ssm=jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), c))
    if cfg.arch_type == "hybrid":
        c = init_ssm_cache(B, cfg, dtype)
        ssm = jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), c)
        G = cfg.num_layers // cfg.shared_attn_every
        kv = init_kv_cache(B, cache_cap, cfg.num_kv_heads, cfg.resolved_head_dim, dtype)
        shared = jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), kv)
        return Caches(ssm=ssm, shared_kv=shared)
    kv = init_kv_cache(B, cache_cap, cfg.num_kv_heads, cfg.resolved_head_dim, dtype)
    kv = jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), kv)
    if cfg.arch_type == "audio":
        xkv = init_kv_cache(B, max(cfg.frontend_tokens, 1), cfg.num_kv_heads,
                            cfg.resolved_head_dim, dtype)
        cross = jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), xkv)
        return Caches(kv=kv, cross_kv=cross)
    return Caches(kv=kv)


def decode_step(params, cfg, token, caches: Caches, pos, *, long_context: bool = False,
                use_kernel: bool = False):
    """One-token decode. token: (B,1) int32; pos: scalar int32 absolute
    position. Returns (logits (B,1,V), new caches)."""
    x = _embed(params, token, cfg)
    windows = layer_windows(cfg, long_context=long_context)

    if cfg.arch_type == "ssm":
        def body(h, inp):
            lp, c = inp
            h2, nc = mamba2_decode(lp["mamba"], rms_norm(h, lp["ln"], cfg.norm_eps), cfg, c)
            return h + h2, nc
        x, ssm = jax.lax.scan(body, x, (params["layers"], caches.ssm))
        new = Caches(ssm=ssm)
    elif cfg.arch_type == "hybrid":
        k = cfg.shared_attn_every
        G = cfg.num_layers // k
        grouped = jax.tree.map(lambda a: a.reshape((G, k) + a.shape[1:]), params["layers"])
        gcaches = jax.tree.map(lambda a: a.reshape((G, k) + a.shape[1:]), caches.ssm)
        shared = params["shared_attn"]
        w = windows[0]

        def gbody(h, inp):
            glp, gc, akv = inp

            def inner(hh, i2):
                lp, c = i2
                h2, nc = mamba2_decode(lp["mamba"], rms_norm(hh, lp["ln"], cfg.norm_eps), cfg, c)
                return hh + h2, nc
            h, ssm_new = jax.lax.scan(inner, h, (glp, gc))
            a, nkv = attn_decode(shared["attn"], rms_norm(h, shared["ln1"], cfg.norm_eps),
                                 cfg, akv, pos, window=w, ring=long_context,
                                 use_kernel=use_kernel)
            h = h + a
            h = h + swiglu(shared["mlp"], rms_norm(h, shared["ln2"], cfg.norm_eps))
            return h, (ssm_new, nkv)
        x, (ssm_new, akv_new) = jax.lax.scan(gbody, x, (grouped, gcaches, caches.shared_kv))
        ssm_new = jax.tree.map(lambda a: a.reshape((G * k,) + a.shape[2:]), ssm_new)
        new = Caches(ssm=ssm_new, shared_kv=akv_new)
    elif cfg.arch_type == "audio":
        B = x.shape[0]
        hd = cfg.resolved_head_dim
        from .attention import _qkv, attend_full

        def body(h, inp):
            lp, kv, (ck, cv) = inp
            a, nkv = attn_decode(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                                 kv, pos, window=jnp.int32(0), use_kernel=use_kernel)
            h = h + a
            hq = rms_norm(h, lp["ln_x"], cfg.norm_eps)
            q, _, _ = _qkv(lp["xattn"], hq, cfg)
            mask = jnp.ones((1, 1, 1, ck.shape[1]), bool)
            xa = attend_full(q, ck, cv, mask)
            h = h + xa.reshape(B, 1, cfg.num_heads * hd) @ lp["xattn"]["wo"]
            h = h + gelu_mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))
            return h, nkv
        x, kv_new = jax.lax.scan(body, x, (params["layers"], caches.kv, caches.cross_kv))
        new = Caches(kv=kv_new, cross_kv=caches.cross_kv)
    else:
        def body(h, inp):
            lp, kv, w = inp
            a, nkv = attn_decode(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                                 kv, pos, window=w, ring=long_context,
                                 use_kernel=use_kernel)
            h = h + a
            h2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if "moe" in lp:
                out, _ = _moe(lp["moe"], h2, cfg,
                              min_capacity=h2.shape[0] * cfg.experts_per_token)
            else:
                out = swiglu(lp["mlp"], h2)
            return h + out, nkv
        x, kv_new = jax.lax.scan(body, x, (params["layers"], caches.kv, windows))
        new = Caches(kv=kv_new)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(params, x, cfg), new
