"""Shared model building blocks (pure-functional JAX, explicit param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "rope_freqs", "apply_rope", "softcap", "dense_init", "embed_init", "Param"]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap · tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., seq, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d_model), dtype=jnp.float32) * 0.02).astype(dtype)


Param = jnp.ndarray
