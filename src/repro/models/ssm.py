"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD forward for train/prefill (quadratic within chunks, linear state
passing across chunks) and an O(1)-per-token recurrent decode step. Heads of
size P = ssm_head_dim over d_inner = expand·d_model channels; one B/C group
(G = 1); scalar decay A per head.

Recurrence (per head):
  h_t = exp(A·dt_t) · h_{t−1} + dt_t · B_t ⊗ x_t        h ∈ R^{P×N}
  y_t = (C_t · h_tᵀ) + D ⊙ x_t
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm

__all__ = ["SSMCache", "init_mamba2", "mamba2_forward", "mamba2_decode", "init_ssm_cache", "ssd_chunk_scan"]


class SSMCache(NamedTuple):
    conv: jnp.ndarray   # (B, K−1, conv_channels) rolling conv input buffer
    state: jnp.ndarray  # (B, H, P, N) SSD state


def _conv_channels(cfg) -> int:
    # x, B, C are convolved (Mamba-2): d_inner + 2·N
    return cfg.d_inner + 2 * cfg.ssm_state


def init_ssm_cache(batch: int, cfg, dtype) -> SSMCache:
    K = cfg.ssm_conv
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return SSMCache(
        conv=jnp.zeros((batch, K - 1, _conv_channels(cfg)), dtype=dtype),
        state=jnp.zeros((batch, H, P, N), dtype=jnp.float32),
    )


def init_mamba2(key, cfg, dtype) -> dict:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k1, k2, k3 = jax.random.split(key, 3)
    proj_out = 2 * di + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": dense_init(k1, d, proj_out, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, _conv_channels(cfg)), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((_conv_channels(cfg),), dtype=dtype),
        "A_log": jnp.zeros((H,), dtype=jnp.float32),       # A = −exp(A_log) ∈ (−∞, 0)
        "D": jnp.ones((H,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "norm": jnp.zeros((di,), dtype=dtype),             # gated RMSNorm scale
        "out_proj": dense_init(k3, di, d, dtype),
    }


def _split_proj(proj, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di: 2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N:]
    return z, xBC, dt


def _causal_depthwise_conv(xBC, w, b):
    """xBC: (B, S, C); w: (K, C) depthwise causal conv + SiLU."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xBC.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssd_chunk_scan(x, dt, A, B_mat, C_mat, chunk: int, h0=None, use_kernel: bool = False):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) negative;
    B_mat/C_mat: (B, S, N). Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = B_mat.shape[-1]
    S0 = S
    if S % chunk:
        # pad tail with dt=0 steps: decay exp(A·0)=1 and zero input leave the
        # final state untouched; padded outputs are sliced off below
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // chunk
    Q = chunk
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = B_mat.reshape(Bsz, nc, Q, N)
    Cc = C_mat.reshape(Bsz, nc, Q, N)

    la = jnp.cumsum(A[None, None, None, :] * dtc, axis=2)          # (B,nc,Q,H) log-decay cumsum
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), dtype=jnp.float32)
    causal = jnp.tril(jnp.ones((Q, Q), dtype=bool))

    # ONE streaming scan over chunks: the (B,Q,Q,H) decay block and all other
    # intra-chunk intermediates live for one chunk only (materializing them
    # for all nc chunks at once is O(S·Q·H) — hundreds of GB at 32k/500k).
    # This is the VMEM-resident structure the Pallas kernel mirrors on TPU.
    def scan_fn(h, inp):
        xq, dtq, laq, Bq, Cq = inp  # (B,Q,H,P),(B,Q,H),(B,Q,H),(B,Q,N),(B,Q,N)
        if use_kernel:
            from repro.kernels.ssd_scan import ops as ssd_ops

            y_intra, st = ssd_ops.ssd_intra_chunk(
                xq[:, None], dtq[:, None], laq[:, None], Bq[:, None], Cq[:, None])
            y_intra = y_intra[:, 0]
            st = st[:, 0]
        else:
            Ldec = jnp.exp(laq[:, :, None, :] - laq[:, None, :, :])   # (B,Q_t,Q_s,H)
            # f32 literal: a weak 0.0 promotes to f64 under x64 (the ADMM
            # core enables x64 globally) and breaks the scan carry dtype
            Ldec = jnp.where(causal[None, :, :, None], Ldec,
                             jnp.zeros((), Ldec.dtype))
            CB = jnp.einsum("btn,bsn->bts", Cq, Bq)                   # (B,Q,Q)
            y_intra = jnp.einsum("bts,btsh,bsh,bshp->bthp", CB, Ldec, dtq, xq)
            decay_out = jnp.exp(laq[:, -1:, :] - laq)                 # (B,Q,H)
            st = jnp.einsum("bsh,bsh,bsn,bshp->bhpn", decay_out, dtq, Bq, xq)
        # incoming-state contribution + state update
        y_inter = jnp.einsum("btn,bth,bhpn->bthp", Cq, jnp.exp(laq),
                             h.astype(xq.dtype))
        dec = jnp.exp(laq[:, -1, :])                                  # (B,H)
        h_new = (dec[:, :, None, None] * h).astype(jnp.float32) + st.astype(jnp.float32)
        return h_new, (y_intra + y_inter).astype(x.dtype)

    swap = lambda a: jnp.moveaxis(a, 1, 0)                            # nc leading
    hT, yc = jax.lax.scan(
        jax.checkpoint(scan_fn, prevent_cse=False), h0.astype(jnp.float32),
        (swap(xc), swap(dtc), swap(la), swap(Bc), swap(Cc)))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, S, H, P)
    return y[:, :S0], hT


def mamba2_forward(params, x, cfg, cache: SSMCache | None = None, use_kernel: bool = False):
    """Full-sequence forward. x: (B, S, D) → (out, new_cache)."""
    B, S, D = x.shape
    proj = x @ params["in_proj"]
    z, xBC, dt = _split_proj(proj, cfg)
    xBC = _causal_depthwise_conv(xBC, params["conv_w"], params["conv_b"])
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xs = xBC[..., :di].reshape(B, S, H, P)
    B_mat = xBC[..., di: di + N]
    C_mat = xBC[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    A = -jnp.exp(params["A_log"])
    y, hT = ssd_chunk_scan(xs, dt, A, B_mat, C_mat, cfg.ssm_chunk, use_kernel=use_kernel)
    y = y + params["D"][None, None, :, None] * xs
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)     # gated norm
    out = (y @ params["out_proj"]).astype(x.dtype)  # f32 D/dt math → back to model dtype
    new_cache = None
    if cache is not None:
        K = cfg.ssm_conv
        # store last K−1 *pre-conv* xBC inputs for decode continuity
        pre = _split_proj(proj, cfg)[1]
        tail = jnp.pad(pre, ((0, 0), (max(K - 1 - S, 0), 0), (0, 0)))[:, -(K - 1):]
        new_cache = SSMCache(conv=tail.astype(cache.conv.dtype), state=hT)
    return out, new_cache


def mamba2_decode(params, x, cfg, cache: SSMCache):
    """Single-token recurrent step. x: (B, 1, D) → (out, new_cache)."""
    B = x.shape[0]
    di, N, H, P, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    proj = x[:, 0] @ params["in_proj"]                                  # (B, proj)
    z, xBC_new, dt = _split_proj(proj, cfg)
    # causal conv over the rolling buffer
    window = jnp.concatenate([cache.conv, xBC_new[:, None]], axis=1)    # (B, K, C)
    xBC = jax.nn.silu(jnp.sum(window * params["conv_w"][None], axis=1) + params["conv_b"])
    xs = xBC[..., :di].reshape(B, H, P)
    B_mat = xBC[..., di: di + N]
    C_mat = xBC[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])    # (B,H)
    A = -jnp.exp(params["A_log"])
    dec = jnp.exp(A[None] * dt)                                         # (B,H)
    h = dec[:, :, None, None] * cache.state + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, B_mat, xs.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", C_mat, h.astype(x.dtype)) + params["D"][None, :, None] * xs
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None].astype(x.dtype)
    new_conv = window[:, 1:]
    return out, SSMCache(conv=new_conv.astype(cache.conv.dtype), state=h)
