"""Logical-axis sharding hints (MaxText-style logical rules).

Model code annotates internal buffers with LOGICAL axis names
(``hint(x, "moe_expert", "moe_capacity", "embed")``). The launch layer
installs a {logical → mesh-axis|None} rules table per distribution plan;
with no rules installed (unit tests, single-device sim) hints are no-ops,
keeping the model code mesh-agnostic.

Needed because XLA's sharding propagation gives up on scatter/gather-fed
buffers (the MoE dispatch) and replicates them — hundreds of GB/device at
mixtral scale (see DESIGN.md §7).
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["set_rules", "get_rules", "rules_ctx", "hint"]

_RULES: dict[str, str | None] = {}


def set_rules(rules: dict[str, str | None] | None) -> None:
    global _RULES
    _RULES = dict(rules) if rules else {}


def get_rules() -> dict[str, str | None]:
    return dict(_RULES)


@contextmanager
def rules_ctx(rules: dict[str, str | None] | None):
    prev = get_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


def hint(x, *logical_axes: str | None):
    """Apply a sharding constraint by logical axis names (None = replicated).
    No-op when no rules are installed or the spec is fully unresolved."""
    if not _RULES:
        return x
    entries = [(_RULES.get(a) if a else None) for a in logical_axes]
    if all(e is None for e in entries):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except RuntimeError:
        # with_sharding_constraint raises RuntimeError when a PartitionSpec
        # is used with no ambient mesh (e.g. sim path) — hints are
        # best-effort there; anything else is a real bug and propagates
        return x
