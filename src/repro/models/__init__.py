"""Model zoo: composable JAX model definitions for the assigned architectures."""
from .transformer import (Caches, decode_step, init_caches, init_params, layer_windows,
                          param_count, prefill, train_loss)

__all__ = ["Caches", "decode_step", "init_caches", "init_params", "layer_windows",
           "param_count", "prefill", "train_loss"]
