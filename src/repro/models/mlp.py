"""Feed-forward blocks: SwiGLU (llama-family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init

__all__ = ["init_swiglu", "swiglu", "init_gelu_mlp", "gelu_mlp"]


def init_swiglu(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params, x):
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, d_model, d_ff, dtype),
        "b_in": jnp.zeros((d_ff,), dtype=dtype),
        "w_out": dense_init(k2, d_ff, d_model, dtype),
        "b_out": jnp.zeros((d_model,), dtype=dtype),
    }


def gelu_mlp(params, x):
    return jax.nn.gelu(x @ params["w_in"] + params["b_in"]) @ params["w_out"] + params["b_out"]
