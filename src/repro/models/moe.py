"""Token-choice top-k Mixture-of-Experts with capacity-based dispatch.

Dispatch is scatter/gather-based (O(E·C·D) memory — the einsum dispatch
tensor of Switch/GShard is O(T·E·C), tens of TB at 1M tokens) and GROUPED:
tokens are split into G independent dispatch groups, each with its own
capacity slice (GShard's ``local_groups``). When the launch layer installs
``moe_groups = <data-axis size>`` via models.partitioning rules, groups
align with the token sharding and the scatter/gather never crosses shards —
expert compute becomes a fully local batched matmul. G = 1 (tests, sim)
reproduces global capacity semantics exactly. Aux load-balance loss per [6].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init
from .partitioning import get_rules, hint

__all__ = ["init_moe", "moe_forward"]


def init_moe(key, d_model: int, d_ff: int, num_experts: int, dtype) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, d_model, num_experts, dtype),
        "w_gate": dense_init(k1, d_model, num_experts * d_ff, dtype).reshape(d_model, num_experts, d_ff).transpose(1, 0, 2),
        "w_up": dense_init(k2, d_model, num_experts * d_ff, dtype).reshape(d_model, num_experts, d_ff).transpose(1, 0, 2),
        "w_down": dense_init(k3, num_experts * d_ff, d_model, dtype).reshape(num_experts, d_ff, d_model),
    }


def moe_forward(params, x, *, top_k: int, capacity_factor: float = 1.25,
                min_capacity: int = 1):
    """x: (B, S, D) → (out, aux_loss). Tokens over their group's capacity are
    dropped (contribution zero) — standard capacity-based routing. Decode
    passes ``min_capacity=T·k`` so single-token steps never drop."""
    B, S, D = x.shape
    E = params["router"].shape[1]
    T = B * S
    G = int(get_rules().get("moe_groups", 1) or 1)
    if T % G or G < 1:
        G = 1
    Tg = T // G
    xg = x.reshape(G, Tg, D)
    logits = (xg @ params["router"]).astype(jnp.float32)       # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)                   # (G, Tg, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)        # renormalize (mixtral)

    C = max(int(capacity_factor * Tg * top_k / E), 1,
            -(-min_capacity // G))                             # per-group capacity
    # position of each (token, slot) within its (group, expert) queue
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)          # (G, Tg, k, E)
    flat = onehot.reshape(G, Tg * top_k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(G, Tg, top_k, E)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)             # (G, Tg, k)
    keep = pos < C

    slot = jnp.where(keep, pos, C)                             # C = OOB → dropped

    # vmap over groups: the group dim becomes a scatter/gather BATCH dim,
    # which GSPMD partitions shard-locally (an explicit arange(G) index
    # array would force it to assume cross-shard traffic and replicate)
    def dispatch_one(xg1, topi1, slot1):                       # (Tg,D),(Tg,k),(Tg,k)
        buf = jnp.zeros((E, C, D), x.dtype)
        for j in range(top_k):                                 # static k ≤ 8
            buf = buf.at[topi1[:, j], slot1[:, j]].add(xg1, mode="drop")
        return buf

    expert_in = jax.vmap(dispatch_one)(xg, topi, slot)         # (G, E, C, D)
    expert_in = hint(expert_in, "moe_group", "moe_expert", None, "embed")
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    h = hint(h, "moe_group", "moe_expert", None, "moe_ff")
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    expert_out = hint(expert_out, "moe_group", "moe_expert", None, "embed")

    def combine_one(eo1, topi1, slot1, w1):                    # (E,C,D),(Tg,k),(Tg,k),(Tg,k)
        o = jnp.zeros((Tg, D), x.dtype)
        for j in range(top_k):
            o = o + eo1[topi1[:, j], jnp.minimum(slot1[:, j], C - 1)] * w1[:, j, None]
        return o

    w_all = (topv * keep).astype(x.dtype)                      # (G, Tg, k)
    out = jax.vmap(combine_one)(expert_out, topi, slot, w_all)
    out = out.reshape(B, S, D)

    # load-balance aux loss: E · Σ_e f_e · P_e (over ALL tokens)
    f = jnp.mean(jnp.sum(onehot, axis=2).astype(jnp.float32), axis=(0, 1))
    P = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * P) / top_k
    return out, aux
