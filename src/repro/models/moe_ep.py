"""Expert-parallel MoE block (shard_map + all_to_all) — the §Perf "designed
next step" for collective-bound MoE shapes.

The pjit path (moe.py) shards expert FFN weights FSDP-style, paying a
re-gather of every expert's weights each layer. Here the expert dim is
MANUALLY sharded over the "model" axis: weights stay resident, and the
TOKENS move — two `all_to_all`s of capacity buffers per layer, the classic
GShard/Switch expert-parallel schedule, which on TPU lowers to a single
fused ICI all-to-all instead of per-layer weight gathers.

Layout inside shard_map(axis_names={"model"}, D = devices on the axis):
  x        (B, S, d)          — replicated over "model" (the caller's
                                 activations; batch stays sharded over the
                                 auto "data" axis)
  w_gate   (E/D, d, F)        — this device's experts (manual shard)
  dispatch (D, C, d)          — slot buffer per TARGET device
  all_to_all → (D, C, d)      — slots for MY experts from every source
  FFN on (D·C, d) with my E/D experts → all_to_all back → combine.

Requires E % D == 0 (granite: 32 % 16 ✓). mixtral's E = 8 on a 16-axis
needs virtual-expert splitting (each expert column-split in two) — not
implemented; build_step falls back to the pjit path and says so.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["moe_forward_expert_parallel", "supports_expert_parallel"]


def supports_expert_parallel(num_experts: int, axis_size: int) -> bool:
    return num_experts % axis_size == 0


def _local_moe(xt, topi, topv, keep, w_gate, w_up, w_down, *, axis: str,
               E: int, top_k: int, C: int):
    """Body inside shard_map. xt (T_loc, d) this token-shard's rows; w_*
    carry this device's E_loc experts. C = per-(shard, expert) capacity.

    Slot streams are PER EXPERT (not per device): the receiver then runs a
    dense (E_loc, D·C, d) batched FFN with zero weight gathers — a per-slot
    weight gather would materialize a (C, d, F) tensor per layer.
    """
    D = jax.lax.axis_size(axis)
    E_loc = E // D
    T, d = xt.shape

    # per-expert slot positions (same accounting as the pjit path)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)        # (T, k, E)
    flat = onehot.reshape(T * top_k, E)
    pos = (jnp.cumsum(flat, axis=0) - flat).reshape(T, top_k, E)
    pos = jnp.sum(pos * onehot, axis=-1)                     # (T, k)
    ok = keep & (pos < C)
    slot = jnp.where(ok, pos, C)

    buf = jnp.zeros((E, C, d), xt.dtype)
    for j in range(top_k):                                   # static k
        buf = buf.at[topi[:, j], slot[:, j]].add(xt, mode="drop")

    # (E, C, d) → (D, E_loc, C, d); all_to_all swaps the device dim: each
    # device receives every token-shard's slots for ITS experts
    buf = buf.reshape(D, E_loc, C, d)
    recv = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                              tiled=False)                   # (D, E_loc, C, d)

    # dense batched FFN over my experts — no gathers
    h = jax.nn.silu(jnp.einsum("secd,edf->secf", recv, w_gate))
    h = h * jnp.einsum("secd,edf->secf", recv, w_up)
    out_slots = jnp.einsum("secf,efd->secd", h, w_down)      # (D, E_loc, C, d)

    back = jax.lax.all_to_all(out_slots, axis, split_axis=0, concat_axis=0,
                              tiled=False).reshape(E, C, d)

    out = jnp.zeros((T, d), xt.dtype)
    for j in range(top_k):
        g = back[topi[:, j], jnp.minimum(slot[:, j], C - 1)]
        w = (topv[:, j] * ok[:, j]).astype(xt.dtype)
        out = out + g * w[:, None]
    return out


def moe_forward_expert_parallel(params, x, *, top_k: int, axis: str = "model",
                                token_axes=("data",),
                                capacity_factor: float = 1.25,
                                min_capacity: int = 1, mesh=None):
    """Drop-in for moe.moe_forward on an E-divisible mesh axis.

    Router + top-k run replicated (cheap); dispatch/FFN/combine run inside a
    partial-manual shard_map, manual over BOTH the expert axis and the token
    (batch) axes — each token shard dispatches only its own rows, so the
    capacity buffers scale with LOCAL tokens (a global-C buffer is D× too
    large). Weights enter with their expert dim manually sharded — they
    never move; only capacity slots cross the ``axis`` all_to_all.
    """
    B, S, d = x.shape
    E = params["router"].shape[1]
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    keep = jnp.ones(topi.shape, bool)

    if mesh is None:
        mesh = jax.sharding.get_abstract_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    Dsz = sizes[axis]
    token_axes = tuple(a for a in token_axes if a in sizes and a != axis)
    t_shards = int(np.prod([sizes[a] for a in token_axes])) if token_axes else 1
    if T % t_shards:
        token_axes, t_shards = (), 1
    T_loc = T // t_shards
    # per (token-shard, expert) capacity
    C = max(int(capacity_factor * T_loc * top_k / E), 1,
            -(-min_capacity // t_shards))

    tok = (token_axes if len(token_axes) > 1 else token_axes[0]) \
        if token_axes else None
    body = functools.partial(_local_moe, axis=axis, E=E, top_k=top_k, C=C)
    smapped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(tok), P(tok), P(tok), P(tok), P(axis), P(axis), P(axis)),
        out_specs=P(tok),
        axis_names={axis} | set(token_axes), check_vma=False)
    out = smapped(xt, topi, topv.astype(x.dtype), keep,
                  params["w_gate"], params["w_up"], params["w_down"])

    f = jnp.mean(jax.nn.one_hot(topi, E).sum(1), axis=0)
    aux = E * jnp.sum(f * jnp.mean(probs, axis=0)) / top_k
    return out.reshape(B, S, d), aux
