"""Minitron-8B [arXiv:2407.14679] — pruned Nemotron-4, GQA 32H/8KV."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", arch_type="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16384, vocab_size=256000, head_dim=128,
    tie_embeddings=False, dtype="bfloat16", source="arXiv:2407.14679",
)
