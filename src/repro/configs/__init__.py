"""Architecture registry: ``--arch <id>`` resolves through ARCHS."""
from .base import INPUT_SHAPES, InputShape, ModelConfig, reduced_for_smoke
from .gemma2_9b import CONFIG as GEMMA2_9B
from .mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from .granite_moe_1b import CONFIG as GRANITE_MOE_1B
from .mamba2_780m import CONFIG as MAMBA2_780M
from .internvl2_1b import CONFIG as INTERNVL2_1B
from .whisper_tiny import CONFIG as WHISPER_TINY
from .smollm_135m import CONFIG as SMOLLM_135M
from .minitron_8b import CONFIG as MINITRON_8B
from .qwen15_05b import CONFIG as QWEN15_05B
from .zamba2_27b import CONFIG as ZAMBA2_27B

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        GEMMA2_9B, MIXTRAL_8X22B, GRANITE_MOE_1B, MAMBA2_780M, INTERNVL2_1B,
        WHISPER_TINY, SMOLLM_135M, MINITRON_8B, QWEN15_05B, ZAMBA2_27B,
    ]
}

# long_500k requires sub-quadratic attention (see DESIGN.md §8): run it for
# SSM/hybrid and for SWA-capable archs; skip pure full-attention archs.
LONG_CONTEXT_ARCHS = {"mamba2-780m", "zamba2-2.7b", "gemma2-9b", "mixtral-8x22b"}

def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise ValueError(
            f"unknown arch {name!r}; the config zoo has: "
            + ", ".join(sorted(ARCHS)))
    return ARCHS[name]

def shape_supported(arch: str, shape: str) -> bool:
    """Whether (arch × input-shape) is in the supported matrix (DESIGN.md §8)."""
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True

__all__ = ["ARCHS", "LONG_CONTEXT_ARCHS", "INPUT_SHAPES", "InputShape", "ModelConfig",
           "get_arch", "reduced_for_smoke", "shape_supported"]
