"""Whisper tiny [arXiv:2212.04356] — encoder-decoder audio backbone; the
mel-spectrogram + conv frontend is a STUB per the brief: input_specs provides
1500 precomputed frame embeddings. Decoder positions use RoPE (repro liberty,
see DESIGN.md §8)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", arch_type="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    encoder_layers=4, cross_attention=True,
    frontend="audio", frontend_tokens=1500,
    dtype="float32", source="arXiv:2212.04356",
)
