"""InternVL2-1B [arXiv:2404.16821] — language backbone (Qwen2-0.5B-style,
GQA 14H/2KV); InternViT vision frontend is a STUB per the brief:
input_specs provides 256 precomputed patch embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", arch_type="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, qkv_bias=True,
    frontend="vision", frontend_tokens=256,
    dtype="bfloat16", source="arXiv:2404.16821",
)
