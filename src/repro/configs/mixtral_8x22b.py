"""Mixtral 8x22B [arXiv:2401.04088] — MoE 8 experts top-2, SWA, GQA 48H/8KV."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", arch_type="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    num_experts=8, experts_per_token=2,
    sliding_window=4096, attn_pattern="swa",
    tie_embeddings=False, dtype="bfloat16", source="arXiv:2401.04088",
)
