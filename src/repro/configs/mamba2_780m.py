"""Mamba-2 780M [arXiv:2405.21060] — attention-free SSD (state-space duality),
d_state 128, expand 2, head dim 64 (48 SSD heads over d_inner 3072)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", arch_type="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    dtype="bfloat16", source="arXiv:2405.21060",
)
