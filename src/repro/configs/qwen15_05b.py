"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — MHA (16H/16KV) with QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", arch_type="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=2816, vocab_size=151936, qkv_bias=True,
    dtype="bfloat16", source="hf:Qwen/Qwen1.5-0.5B",
)
