"""Zamba2-2.7B [arXiv:2411.15242] — hybrid: 54 Mamba2 blocks + ONE shared
attention block applied every 6 layers (32H MHA), ssm_state 64."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", arch_type="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    shared_attn_every=6,
    dtype="bfloat16", source="arXiv:2411.15242",
)
