"""Architecture config schema. One frozen dataclass per assigned architecture
lives in ``repro/configs/<id>.py`` with the exact figures from the assignment
(source paper / model card cited in each file).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

__all__ = ["ModelConfig", "reduced_for_smoke", "INPUT_SHAPES", "InputShape"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int            # 0 for attention-free (ssm)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 → d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- attention flavor ---
    sliding_window: int = 0           # 0 → full attention
    attn_pattern: str = "global"      # global | local_global (gemma2) | swa (mixtral)
    logit_softcap: float = 0.0        # final-logit softcap (gemma2: 30)
    attn_logit_softcap: float = 0.0   # attention-score softcap (gemma2: 50)
    qkv_bias: bool = False            # qwen1.5
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0        # one SHARED attention block every N mamba blocks
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    cross_attention: bool = False
    # --- modality frontend stubs (brief's carve-out) ---
    frontend: str = ""                # "" | "vision" | "audio"
    frontend_tokens: int = 0          # patch/frame embeddings provided by input_specs
    # --- misc ---
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    dtype: str = "float32"            # activation/param dtype for smoke tests
    source: str = ""                  # citation from the assignment

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0


def reduced_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests:
    2 layers, d_model ≤ 512 (usually 128), ≤ 4 experts, small vocab."""
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    if heads:
        ratio = max(cfg.num_heads // max(cfg.num_kv_heads, 1), 1)
        kv = max(heads // ratio, 1)
        while heads % kv:  # keep GQA grouping exact
            kv -= 1
    else:
        kv = 0
    d_model = 128
    kw = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=max(kv, 1) if heads else 0,
        head_dim=(d_model // heads if heads else 0),
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        dtype="float32",
    )
    if cfg.num_experts:
        kw["num_experts"] = min(cfg.num_experts, 4)
        kw["experts_per_token"] = min(cfg.experts_per_token, 2)
    if cfg.ssm_state:
        kw["ssm_state"] = min(cfg.ssm_state, 16)
        kw["ssm_head_dim"] = 32
        kw["ssm_chunk"] = 32
    if cfg.sliding_window:
        kw["sliding_window"] = 16
    if cfg.shared_attn_every:
        kw["shared_attn_every"] = 2
        kw["num_layers"] = 4
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
    if cfg.frontend_tokens:
        kw["frontend_tokens"] = 8
    return replace(cfg, name=cfg.name + "-smoke", **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
