"""Granite 3.0 1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] — MoE
32 experts top-8, GQA 16H/8KV."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", arch_type="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    num_experts=32, experts_per_token=8,
    dtype="bfloat16", source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
