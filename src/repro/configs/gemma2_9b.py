"""Gemma-2 9B [arXiv:2408.00118] — dense, local+global alternating attention,
attention-score softcap 50, final-logit softcap 30, GQA 16H/8KV, head_dim 256."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", arch_type="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
    d_ff=14336, vocab_size=256000, head_dim=256,
    sliding_window=4096, attn_pattern="local_global",
    logit_softcap=30.0, attn_logit_softcap=50.0,
    dtype="bfloat16", source="arXiv:2408.00118",
)
