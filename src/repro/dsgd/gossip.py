"""Gossip application — three interchangeable backends, one semantics (x ← W x).

  gossip_shard     inside shard_map: ppermute matching-rounds (production TPU)
  gossip_sim       single-device: dense W einsum over the leading node axis
                   (the paper's Eq. 1 verbatim — the oracle)
  gossip_sim_tree  gossip_sim over a parameter pytree, optionally through the
                   fused Pallas gossip_mix kernel
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .schedule import GossipSchedule

__all__ = ["gossip_shard", "gossip_sim", "gossip_sim_tree"]


def gossip_shard(tree, sched: GossipSchedule, axis):
    """Apply one gossip sync to a per-worker pytree INSIDE shard_map.

    ``tree`` leaves: this worker's shard, any shape (leading worker axis of
    size 1 is fine — it is just data). ``axis``: manual mesh axis name (or
    tuple of names) hosting the n workers.
    """
    i = jax.lax.axis_index(axis)
    w_self = jnp.asarray(sched.self_weights, jnp.float32)[i]
    accs = jax.tree.map(lambda x: x.astype(jnp.float32) * w_self, tree)
    for perm, wr in zip(sched.perms, sched.recv_weights):
        w_recv = jnp.asarray(wr, jnp.float32)[i]
        recv = jax.tree.map(lambda x: jax.lax.ppermute(x, axis, list(perm)), tree)
        accs = jax.tree.map(
            lambda a, r: a + r.astype(jnp.float32) * w_recv, accs, recv)
    return jax.tree.map(lambda a, x: a.astype(x.dtype), accs, tree)


def gossip_sim(x: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    """x: (n, ...) stacked worker copies; returns W x (Eq. 1).

    Contracts the worker dim IN PLACE (tensordot on the native shape) — a
    reshape-to-(n, -1) merges sharded dims, which GSPMD cannot represent and
    answers by replicating the flattened replica (≈180 GB/leaf at mixtral
    scale). f32 accumulation via preferred_element_type, no upcast copy.
    """
    if x.ndim == 1:
        return (W.astype(jnp.float32) @ x.astype(jnp.float32)).astype(x.dtype)
    out = jax.lax.dot_general(
        W.astype(x.dtype), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def gossip_sim_tree(tree, W: jnp.ndarray, *, use_kernel: bool = False):
    """Leaf-wise gossip over stacked (n, ...) parameter pytrees.

    use_kernel routes through the Pallas ``gossip_mix`` kernel per worker row
    (interpret mode on CPU; fused VMEM kernel on TPU).
    """
    if not use_kernel:
        return jax.tree.map(lambda x: gossip_sim(x, W), tree)

    from repro.kernels.gossip_mix.ops import gossip_mix

    n = W.shape[0]
    Wnp = np.asarray(W)

    def mix_leaf(x):
        rows = []
        for i in range(n):
            nbrs = [j for j in range(n) if j != i and Wnp[i, j] != 0.0]
            weights = jnp.asarray([Wnp[i, i]] + [Wnp[i, j] for j in nbrs], jnp.float32)
            rows.append(gossip_mix(x[i], x[jnp.asarray(nbrs)], weights))
        return jnp.stack(rows)

    return jax.tree.map(mix_leaf, tree)
