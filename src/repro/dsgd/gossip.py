"""Gossip application — three interchangeable backends, one semantics (x ← W x).

  gossip_shard     inside shard_map: ppermute matching-rounds (production TPU)
  gossip_sim       single-device: dense W einsum over the leading node axis
                   (the paper's Eq. 1 verbatim — the oracle)
  gossip_sim_tree  gossip_sim over a parameter pytree, optionally through the
                   fused Pallas gossip_mix kernel
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .schedule import GossipSchedule

__all__ = ["gossip_shard", "gossip_shard_elastic", "gossip_sim",
           "gossip_sim_tree", "gossip_sim_tree_rowloop", "padded_neighbors",
           "elastic_neighbor_tables", "gather_neighbor_weights",
           "schedule_weight_arrays", "select_cycle_matrix"]


def select_cycle_matrix(Wc: jnp.ndarray, R, t) -> jnp.ndarray:
    """``W_{t mod R}`` from a stacked ``(R_max, n, n)`` cycle tensor.

    ``t`` (the global step counter carried through the scan) and ``R`` (the
    true cycle length, ≤ R_max after padding) may both be traced scalars: the
    selection is a dynamic step-index gather, NOT a ``lax.switch`` over host
    branches, so it vmaps across topologies whose cycles have different
    lengths (DESIGN.md §12). Static topologies pass R = 1 and always get W.
    """
    return jax.lax.dynamic_index_in_dim(Wc, jnp.mod(t, R), 0, keepdims=False)


def gossip_shard(tree, sched: GossipSchedule, axis):
    """Apply one gossip sync to a per-worker pytree INSIDE shard_map.

    ``tree`` leaves: this worker's shard, any shape (leading worker axis of
    size 1 is fine — it is just data). ``axis``: manual mesh axis name (or
    tuple of names) hosting the n workers.
    """
    i = jax.lax.axis_index(axis)
    w_self = jnp.asarray(sched.self_weights, jnp.float32)[i]
    accs = jax.tree.map(lambda x: x.astype(jnp.float32) * w_self, tree)
    for perm, wr in zip(sched.perms, sched.recv_weights):
        w_recv = jnp.asarray(wr, jnp.float32)[i]
        recv = jax.tree.map(lambda x: jax.lax.ppermute(x, axis, list(perm)), tree)
        accs = jax.tree.map(
            lambda a, r: a + r.astype(jnp.float32) * w_recv, accs, recv)
    return jax.tree.map(lambda a, x: a.astype(x.dtype), accs, tree)


def gossip_shard_elastic(tree, sched: GossipSchedule, axis,
                         mix_mask: jnp.ndarray, self_weights: jnp.ndarray,
                         recv_weights: jnp.ndarray):
    """Elastic variant of :func:`gossip_shard` — weights and membership are
    DATA, so a re-optimized weight polish or a membership flip never
    retraces the step (DESIGN.md §16).

    ``mix_mask (n,)``: 1 for nodes participating in this round's exchange
    (alive and not watchdog-dropped). A non-participant's sends are weighted
    0 by every receiver and the lost mass is folded into the receiver's self
    weight — the on-device row-stochastic renorm of ``chaos.degrade_matrix``
    expressed over ppermute rounds: w_self + Σ_r w_r·a_r + Σ_r w_r·(1−a_r)
    = w_self + Σ_r w_r = 1. The non-participant's OWN row is overwritten by
    the caller (freeze / keep-local), matching the dense engine.
    ``self_weights (n,)`` / ``recv_weights (rounds, n)``: the schedule's
    weights as arrays (see :func:`schedule_weight_arrays`); the perm
    structure itself stays static — a support change still retraces.
    """
    i = jax.lax.axis_index(axis)
    a_i = mix_mask[i].astype(jnp.float32)
    w_self = self_weights[i].astype(jnp.float32)
    accs = jax.tree.map(lambda x: x.astype(jnp.float32) * w_self, tree)
    lost = jnp.float32(0.0)
    for r, perm in enumerate(sched.perms):
        w_recv = recv_weights[r][i].astype(jnp.float32)
        a_src = jax.lax.ppermute(a_i, axis, list(perm))
        recv = jax.tree.map(lambda x: jax.lax.ppermute(x, axis, list(perm)), tree)
        accs = jax.tree.map(
            lambda a, rx: a + rx.astype(jnp.float32) * (w_recv * a_src),
            accs, recv)
        lost = lost + w_recv * (1.0 - a_src)
    accs = jax.tree.map(lambda a, x: a + x.astype(jnp.float32) * lost,
                        accs, tree)
    return jax.tree.map(lambda a, x: a.astype(x.dtype), accs, tree)


def schedule_weight_arrays(sched: GossipSchedule) -> tuple[np.ndarray, np.ndarray]:
    """A schedule's weights as ``(self (n,), recv (rounds, n))`` float32
    arrays — the data leaves :func:`gossip_shard_elastic` consumes (the
    tuples baked into ``GossipSchedule`` are jit-static and would retrace)."""
    return (np.asarray(sched.self_weights, np.float32),
            np.asarray(sched.recv_weights, np.float32).reshape(
                sched.rounds, sched.n))


def gossip_sim(x: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    """x: (n, ...) stacked worker copies; returns W x (Eq. 1).

    Contracts the worker dim IN PLACE (tensordot on the native shape) — a
    reshape-to-(n, -1) merges sharded dims, which GSPMD cannot represent and
    answers by replicating the flattened replica (≈180 GB/leaf at mixtral
    scale). f32 accumulation via preferred_element_type, no upcast copy.
    """
    if x.ndim == 1:
        return (W.astype(jnp.float32) @ x.astype(jnp.float32)).astype(x.dtype)
    out = jax.lax.dot_general(
        W.astype(x.dtype), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def padded_neighbors(W) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed max-degree padded neighbor indexing for a CONCRETE gossip matrix.

    Returns ``(nbr_idx (n, deg) int32, weights (n, deg+1) float32)`` where
    ``deg`` is the graph's maximum degree, ``weights[:, 0]`` is the self
    weight and padded slots gather the row itself with weight 0 (so the mix
    is exact for every degree). Build this ONCE from a concrete W at step-
    construction time; the batched mixing itself is then trace-safe.
    """
    Wnp = np.asarray(W)
    n = Wnp.shape[0]
    off = Wnp.copy()
    np.fill_diagonal(off, 0.0)
    rows = [np.nonzero(off[i])[0] for i in range(n)]
    deg = max((len(r) for r in rows), default=0) or 1
    nbr_idx = np.empty((n, deg), np.int32)
    weights = np.zeros((n, deg + 1), np.float32)
    for i, r in enumerate(rows):
        nbr_idx[i, :len(r)] = r
        nbr_idx[i, len(r):] = i
        weights[i, 0] = Wnp[i, i]
        weights[i, 1:1 + len(r)] = off[i, r]
    return jnp.asarray(nbr_idx), jnp.asarray(weights)


def elastic_neighbor_tables(W, deg_cap: int | None = None
                            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Hot-swappable neighbor indexing for the elastic kernel path.

    Returns ``(nbr_idx (n, deg_cap) int32, nbr_mask (n, deg_cap) bool)`` for
    a CONCRETE W: real neighbor slots carry the neighbor index, padded slots
    point at the row itself with mask False. Padding every topology to the
    same ``deg_cap`` (default n−1, every possible degree) keeps the table
    shapes identical across re-optimized topologies, so a mid-training
    hot-swap replaces data instead of retracing the step. Per-step weights
    are gathered on device from the degraded matrix by
    :func:`gather_neighbor_weights`.
    """
    Wnp = np.asarray(W)
    n = Wnp.shape[0]
    off = Wnp.copy()
    np.fill_diagonal(off, 0.0)
    rows = [np.nonzero(off[i])[0] for i in range(n)]
    deg = deg_cap if deg_cap is not None else max(n - 1, 1)
    widest = max((len(r) for r in rows), default=0)
    if widest > deg:
        raise ValueError(f"deg_cap={deg} < max degree {widest} of W")
    nbr_idx = np.empty((n, deg), np.int32)
    nbr_mask = np.zeros((n, deg), bool)
    for i, r in enumerate(rows):
        nbr_idx[i, :len(r)] = r
        nbr_idx[i, len(r):] = i
        nbr_mask[i, :len(r)] = True
    return jnp.asarray(nbr_idx), jnp.asarray(nbr_mask)


def gather_neighbor_weights(W_eff: jnp.ndarray, nbr_idx: jnp.ndarray,
                            nbr_mask: jnp.ndarray) -> jnp.ndarray:
    """(n, deg+1) float32 kernel weights gathered from a (possibly degraded)
    mixing matrix on device — column 0 the self weight, padded slots 0, the
    layout ``gossip_mix_batched`` consumes. Trace-safe: the fault masks and
    the hot-swapped tables are all data."""
    n = W_eff.shape[0]
    rows = jnp.arange(n)[:, None]
    w = jnp.where(nbr_mask, W_eff[rows, nbr_idx], 0.0)
    diag = jnp.diagonal(W_eff)[:, None]
    return jnp.concatenate([diag, w], axis=1).astype(jnp.float32)


def gossip_sim_tree(tree, W: jnp.ndarray, *, use_kernel: bool = False,
                    nbr: tuple[jnp.ndarray, jnp.ndarray] | None = None):
    """Leaf-wise gossip over stacked (n, ...) parameter pytrees.

    use_kernel routes through the Pallas ``gossip_mix_batched`` kernel — ONE
    dispatch per leaf covering all n workers over the padded neighbor-index
    matrix (interpret mode on CPU; fused VMEM kernel on TPU). Pass
    ``nbr=padded_neighbors(W)`` precomputed when calling from inside a trace
    (W must be concrete to derive the sparsity pattern).
    """
    if not use_kernel:
        return jax.tree.map(lambda x: gossip_sim(x, W), tree)

    from repro.kernels.gossip_mix.ops import gossip_mix_batched

    nbr_idx, weights = padded_neighbors(W) if nbr is None else nbr
    return jax.tree.map(lambda x: gossip_mix_batched(x, nbr_idx, weights), tree)


def gossip_sim_tree_rowloop(tree, W: jnp.ndarray):
    """Per-worker-row ``gossip_mix`` dispatch loop — the parity oracle for
    ``gossip_sim_tree(use_kernel=True)``.

    O(n) kernel dispatches per leaf, one jit variant per distinct neighbor
    count, host read of W — kept only to pin down the batched path's
    numerics (tests) and as the dispatch-cost baseline (bench_kernels)."""
    from repro.kernels.gossip_mix.ops import gossip_mix

    n = W.shape[0]
    Wnp = np.asarray(W)

    def mix_leaf(x):
        rows = []
        for i in range(n):
            nbrs = [j for j in range(n) if j != i and Wnp[i, j] != 0.0]
            weights = jnp.asarray([Wnp[i, i]] + [Wnp[i, j] for j in nbrs], jnp.float32)
            rows.append(gossip_mix(x[i], x[jnp.asarray(nbrs)], weights))
        return jnp.stack(rows)

    return jax.tree.map(mix_leaf, tree)
