"""Gossip application — three interchangeable backends, one semantics (x ← W x).

  gossip_shard     inside shard_map: ppermute matching-rounds (production TPU)
  gossip_sim       single-device: dense W einsum over the leading node axis
                   (the paper's Eq. 1 verbatim — the oracle)
  gossip_sim_tree  gossip_sim over a parameter pytree, optionally through the
                   fused Pallas gossip_mix kernel
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .schedule import GossipSchedule

__all__ = ["gossip_shard", "gossip_sim", "gossip_sim_tree",
           "gossip_sim_tree_rowloop", "padded_neighbors",
           "select_cycle_matrix"]


def select_cycle_matrix(Wc: jnp.ndarray, R, t) -> jnp.ndarray:
    """``W_{t mod R}`` from a stacked ``(R_max, n, n)`` cycle tensor.

    ``t`` (the global step counter carried through the scan) and ``R`` (the
    true cycle length, ≤ R_max after padding) may both be traced scalars: the
    selection is a dynamic step-index gather, NOT a ``lax.switch`` over host
    branches, so it vmaps across topologies whose cycles have different
    lengths (DESIGN.md §12). Static topologies pass R = 1 and always get W.
    """
    return jax.lax.dynamic_index_in_dim(Wc, jnp.mod(t, R), 0, keepdims=False)


def gossip_shard(tree, sched: GossipSchedule, axis):
    """Apply one gossip sync to a per-worker pytree INSIDE shard_map.

    ``tree`` leaves: this worker's shard, any shape (leading worker axis of
    size 1 is fine — it is just data). ``axis``: manual mesh axis name (or
    tuple of names) hosting the n workers.
    """
    i = jax.lax.axis_index(axis)
    w_self = jnp.asarray(sched.self_weights, jnp.float32)[i]
    accs = jax.tree.map(lambda x: x.astype(jnp.float32) * w_self, tree)
    for perm, wr in zip(sched.perms, sched.recv_weights):
        w_recv = jnp.asarray(wr, jnp.float32)[i]
        recv = jax.tree.map(lambda x: jax.lax.ppermute(x, axis, list(perm)), tree)
        accs = jax.tree.map(
            lambda a, r: a + r.astype(jnp.float32) * w_recv, accs, recv)
    return jax.tree.map(lambda a, x: a.astype(x.dtype), accs, tree)


def gossip_sim(x: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    """x: (n, ...) stacked worker copies; returns W x (Eq. 1).

    Contracts the worker dim IN PLACE (tensordot on the native shape) — a
    reshape-to-(n, -1) merges sharded dims, which GSPMD cannot represent and
    answers by replicating the flattened replica (≈180 GB/leaf at mixtral
    scale). f32 accumulation via preferred_element_type, no upcast copy.
    """
    if x.ndim == 1:
        return (W.astype(jnp.float32) @ x.astype(jnp.float32)).astype(x.dtype)
    out = jax.lax.dot_general(
        W.astype(x.dtype), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def padded_neighbors(W) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed max-degree padded neighbor indexing for a CONCRETE gossip matrix.

    Returns ``(nbr_idx (n, deg) int32, weights (n, deg+1) float32)`` where
    ``deg`` is the graph's maximum degree, ``weights[:, 0]`` is the self
    weight and padded slots gather the row itself with weight 0 (so the mix
    is exact for every degree). Build this ONCE from a concrete W at step-
    construction time; the batched mixing itself is then trace-safe.
    """
    Wnp = np.asarray(W)
    n = Wnp.shape[0]
    off = Wnp.copy()
    np.fill_diagonal(off, 0.0)
    rows = [np.nonzero(off[i])[0] for i in range(n)]
    deg = max((len(r) for r in rows), default=0) or 1
    nbr_idx = np.empty((n, deg), np.int32)
    weights = np.zeros((n, deg + 1), np.float32)
    for i, r in enumerate(rows):
        nbr_idx[i, :len(r)] = r
        nbr_idx[i, len(r):] = i
        weights[i, 0] = Wnp[i, i]
        weights[i, 1:1 + len(r)] = off[i, r]
    return jnp.asarray(nbr_idx), jnp.asarray(weights)


def gossip_sim_tree(tree, W: jnp.ndarray, *, use_kernel: bool = False,
                    nbr: tuple[jnp.ndarray, jnp.ndarray] | None = None):
    """Leaf-wise gossip over stacked (n, ...) parameter pytrees.

    use_kernel routes through the Pallas ``gossip_mix_batched`` kernel — ONE
    dispatch per leaf covering all n workers over the padded neighbor-index
    matrix (interpret mode on CPU; fused VMEM kernel on TPU). Pass
    ``nbr=padded_neighbors(W)`` precomputed when calling from inside a trace
    (W must be concrete to derive the sparsity pattern).
    """
    if not use_kernel:
        return jax.tree.map(lambda x: gossip_sim(x, W), tree)

    from repro.kernels.gossip_mix.ops import gossip_mix_batched

    nbr_idx, weights = padded_neighbors(W) if nbr is None else nbr
    return jax.tree.map(lambda x: gossip_mix_batched(x, nbr_idx, weights), tree)


def gossip_sim_tree_rowloop(tree, W: jnp.ndarray):
    """Per-worker-row ``gossip_mix`` dispatch loop — the parity oracle for
    ``gossip_sim_tree(use_kernel=True)``.

    O(n) kernel dispatches per leaf, one jit variant per distinct neighbor
    count, host read of W — kept only to pin down the batched path's
    numerics (tests) and as the dispatch-cost baseline (bench_kernels)."""
    from repro.kernels.gossip_mix.ops import gossip_mix

    n = W.shape[0]
    Wnp = np.asarray(W)

    def mix_leaf(x):
        rows = []
        for i in range(n):
            nbrs = [j for j in range(n) if j != i and Wnp[i, j] != 0.0]
            weights = jnp.asarray([Wnp[i, i]] + [Wnp[i, j] for j in nbrs], jnp.float32)
            rows.append(gossip_mix(x[i], x[jnp.asarray(nbrs)], weights))
        return jnp.stack(rows)

    return jax.tree.map(mix_leaf, tree)
