"""Time-varying gossip (beyond paper — its §VII names dynamic topologies as
future work).

Instead of applying the full weight matrix every step (deg(i) sends per
node), the static BA-Topo is decomposed into its matching rounds and ONE
round is applied per optimizer step, cycling round-robin:

    x_{t+1} = W_{t mod R} x_t,   W_c = I − Σ_{(i,j)∈M_c} g_ij (e_i−e_j)(e_i−e_j)ᵀ

Each W_c is symmetric doubly stochastic (a matching step), so the cycle
product Π W_c is doubly stochastic with spectral contraction measured by
``cycle_contraction``. Per-step communication drops to ≤1 send/node (the
per-edge bandwidth under the paper's sharing model rises to the FULL node
bandwidth — b_unit = b_i instead of b_i/deg), trading per-step consensus
for much cheaper steps: the net effect on the paper's t_iter model is
evaluated in benchmarks/bench_dynamic.py.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core.graph import Topology, weight_matrix_from_weights

from .gossip import gossip_shard
from .schedule import GossipSchedule, _edge_color

__all__ = ["round_robin_schedules", "cycle_weight_matrices", "cycle_contraction",
           "gossip_shard_dynamic"]


def round_robin_schedules(topo: Topology) -> list[GossipSchedule]:
    """One single-round GossipSchedule per matching of the topology.

    Edge weights are re-balanced for single-matching application: within a
    matching, the pairwise-averaging-with-weight step uses
    w_ij' = min(2·g_ij, 0.5) (a lazy pairwise average), which keeps each W_c
    doubly stochastic and PSD-contractive regardless of the static weights.
    """
    n = topo.n
    eidx = {tuple(sorted(e)): k for k, e in enumerate(topo.edges)}
    matchings = _edge_color(n, list(topo.edges))
    schedules = []
    for c, matching in enumerate(matchings):
        pairs: list[tuple[int, int]] = []
        recv = np.zeros(n)
        selfw = np.ones(n)
        for i, j in matching:
            w = min(2.0 * float(topo.g[eidx[tuple(sorted((i, j)))]]), 0.5)
            pairs.extend([(i, j), (j, i)])
            recv[i] = w
            recv[j] = w
            selfw[i] = 1.0 - w
            selfw[j] = 1.0 - w
        schedules.append(GossipSchedule(
            n=n, perms=(tuple(sorted(pairs)),),
            recv_weights=(tuple(recv),),
            self_weights=tuple(selfw),
            name=f"{topo.name}/round{c}"))
    return schedules


def cycle_weight_matrices(schedules: list[GossipSchedule]) -> list[np.ndarray]:
    from .schedule import reconstruct_weight_matrix
    return [reconstruct_weight_matrix(s) for s in schedules]


def cycle_contraction(schedules: list[GossipSchedule]) -> float:
    """ρ(Π W_c − 11ᵀ/n): per-cycle consensus contraction of the round-robin
    scheme (compare against r_asym(W_static)^1 per full static sync)."""
    Ws = cycle_weight_matrices(schedules)
    n = Ws[0].shape[0]
    prod = np.eye(n)
    for W in Ws:
        prod = W @ prod
    dev = prod - np.ones((n, n)) / n
    return float(np.max(np.abs(np.linalg.eigvals(dev))))


def gossip_shard_dynamic(tree, schedules: list[GossipSchedule], step, axis):
    """Apply round ``step % R`` inside shard_map. ``step`` is a traced scalar;
    rounds are selected with lax.switch over the (static) schedule list."""
    branches = [
        (lambda s: (lambda t: gossip_shard(t, s, axis)))(s) for s in schedules
    ]
    idx = step % len(schedules)
    return jax.lax.switch(idx, branches, tree)
