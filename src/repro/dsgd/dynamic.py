"""Time-varying gossip (beyond paper — its §VII names dynamic topologies as
future work).

Instead of applying the full weight matrix every step (deg(i) sends per
node), the static BA-Topo is decomposed into its matching rounds and ONE
round is applied per optimizer step, cycling round-robin:

    x_{t+1} = W_{t mod R} x_t,   W_c = I − Σ_{(i,j)∈M_c} g_ij (e_i−e_j)(e_i−e_j)ᵀ

Each W_c is symmetric doubly stochastic (a matching step), so the cycle
product Π W_c is doubly stochastic with spectral contraction measured by
``cycle_contraction``. Per-step communication drops to ≤1 send/node (the
per-edge bandwidth under the paper's sharing model rises to the FULL node
bandwidth — b_unit = b_i instead of b_i/deg), trading per-step consensus
for much cheaper steps: the net effect on the paper's t_iter model is
evaluated in benchmarks/bench_dynamic.py.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core.graph import Topology

from .gossip import gossip_shard
from .schedule import GossipSchedule, edge_color

__all__ = ["round_robin_schedules", "cycle_weight_matrices", "cycle_contraction",
           "cycle_tensor", "static_cycle", "stack_cycles",
           "gossip_shard_dynamic"]


def round_robin_schedules(topo: Topology) -> list[GossipSchedule]:
    """One single-round GossipSchedule per matching of the topology.

    Edge weights are re-balanced for single-matching application: within a
    matching, the pairwise-averaging-with-weight step uses
    w_ij' = min(2·W_ij, 0.5) (a lazy pairwise average), which keeps each W_c
    doubly stochastic and PSD-contractive regardless of the static weights.
    Weights are read off the topology's realized gossip matrix ``topo.W``
    (NOT ``topo.g``), so symmetric W-override baselines — U-EquiStatic —
    decompose into their actual mixing weights instead of degenerating to
    identity rounds. A directed override (the exponential graph) has no
    symmetric matching decomposition and is rejected — its ``g`` vector is
    all-zero, so a silent fallback would produce identity rounds, the exact
    bug class this check exists to prevent. Callers (the benches) skip
    directed topologies via ``topo.meta['directed']``.
    """
    n = topo.n
    W = np.asarray(topo.W)
    if not np.allclose(W, W.T):
        raise ValueError(
            f"{topo.name}: asymmetric W has no symmetric matching "
            "decomposition (round-robin gossip needs pairwise exchanges)")
    matchings = edge_color(n, list(topo.edges))
    schedules = []
    for c, matching in enumerate(matchings):
        pairs: list[tuple[int, int]] = []
        recv = np.zeros(n)
        selfw = np.ones(n)
        for i, j in matching:
            w = min(2.0 * float(W[i, j]), 0.5)
            pairs.extend([(i, j), (j, i)])
            recv[i] = w
            recv[j] = w
            selfw[i] = 1.0 - w
            selfw[j] = 1.0 - w
        schedules.append(GossipSchedule(
            n=n, perms=(tuple(sorted(pairs)),),
            recv_weights=(tuple(recv),),
            self_weights=tuple(selfw),
            name=f"{topo.name}/round{c}"))
    return schedules


def cycle_weight_matrices(schedules: list[GossipSchedule]) -> list[np.ndarray]:
    from .schedule import reconstruct_weight_matrix
    return [reconstruct_weight_matrix(s) for s in schedules]


def cycle_contraction(schedules: list[GossipSchedule]) -> float:
    """ρ(Π W_c − 11ᵀ/n): per-cycle consensus contraction of the round-robin
    scheme (compare against r_asym(W_static)^1 per full static sync)."""
    Ws = cycle_weight_matrices(schedules)
    n = Ws[0].shape[0]
    prod = np.eye(n)
    for W in Ws:
        prod = W @ prod
    dev = prod - np.ones((n, n)) / n
    return float(np.max(np.abs(np.linalg.eigvals(dev))))


def cycle_tensor(topo: Topology) -> np.ndarray:
    """The round-robin matching cycle as ONE stacked ``(R, n, n)`` tensor.

    Step ``t`` of the dynamic scheme applies ``Wc[t % R]`` — the same
    matrix sequence ``gossip_shard_dynamic`` realizes with its
    ``lax.switch`` over schedules (each W_c is the reconstruction of
    schedule c). The stacked form is what the device-resident engine
    gathers from inside its scan (``repro.dsgd.sim``, DESIGN.md §12):
    a step-index gather instead of host branches.
    """
    return np.stack(cycle_weight_matrices(round_robin_schedules(topo)))


def static_cycle(W: np.ndarray) -> np.ndarray:
    """A static topology as a length-1 cycle: every step applies the full W.

    Lets the cross-product engine treat {static, dynamic} uniformly — the
    step-index gather ``Wc[t % 1]`` always selects W.
    """
    return np.asarray(W)[None]


def stack_cycles(cycles) -> tuple[np.ndarray, np.ndarray]:
    """Pad variable-length cycles to ``(B, R_max, n, n)`` + lengths ``(B,)``.

    Padding slots are identity matrices and UNREACHABLE: the engine's step
    index is ``t % R_b`` which never exceeds the true cycle length, so the
    pad value is irrelevant to the computation (identity keeps accidental
    selection harmless and debuggable). This is what lets topologies with
    different matching counts share one vmapped dispatch.
    """
    cycles = [np.asarray(c, dtype=np.float64) for c in cycles]
    if not cycles:
        return np.zeros((0, 1, 0, 0)), np.zeros((0,), np.int32)
    n = cycles[0].shape[-1]
    r_max = max(c.shape[0] for c in cycles)
    out = np.broadcast_to(np.eye(n), (len(cycles), r_max, n, n)).copy()
    lens = np.empty(len(cycles), np.int32)
    for b, c in enumerate(cycles):
        out[b, :c.shape[0]] = c
        lens[b] = c.shape[0]
    return out, lens


def gossip_shard_dynamic(tree, schedules: list[GossipSchedule], step, axis):
    """Apply round ``step % R`` inside shard_map. ``step`` is a traced scalar;
    rounds are selected with lax.switch over the (static) schedule list."""
    branches = [
        (lambda s: (lambda t: gossip_shard(t, s, axis)))(s) for s in schedules
    ]
    idx = step % len(schedules)
    return jax.lax.switch(idx, branches, tree)
