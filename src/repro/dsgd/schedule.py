"""Gossip collective schedule: W → matching rounds of collective-permute.

The paper's synchronization x ← W x (Eq. 1) runs over gloo point-to-point
sends. TPU collectives are compiled and static, so we adapt (DESIGN.md §7):
the undirected edge set is greedily edge-colored into *matching rounds* —
in each round every worker exchanges with at most one neighbor — and each
round becomes ONE ``jax.lax.ppermute`` (a bidirectional pair (i,j),(j,i) per
matched edge). A node's mixing weight for the copy it receives in round c is
looked up from a per-round (n,) weight table, so the weighted accumulation

    acc = W_ii · x_i + Σ_rounds  w_round[i] · ppermute(x)_i

reproduces x ← W x exactly (ppermute delivers zeros to unmatched nodes and
w_round[i] = 0 there). Greedy coloring uses ≤ 2Δ−1 rounds, Δ+O(1) in
practice; collective bytes per sync per worker = deg(i) · |params| — the
sparse-topology saving the paper is after, visible in compiled HLO.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.graph import Topology, weight_matrix_from_weights

__all__ = ["GossipSchedule", "edge_color", "schedule_from_topology",
           "reconstruct_weight_matrix", "bytes_per_sync"]


@dataclass(frozen=True)
class GossipSchedule:
    """Static gossip plan (hashable → usable as a jit static argument)."""
    n: int
    # one entry per round: tuple of (src, dst) pairs — a symmetric matching
    perms: tuple[tuple[tuple[int, int], ...], ...]
    # per round, per node: weight applied to the received copy (0 if idle)
    recv_weights: tuple[tuple[float, ...], ...]
    self_weights: tuple[float, ...]          # diag(W)
    name: str = "gossip"

    @property
    def rounds(self) -> int:
        return len(self.perms)

    @property
    def degrees(self) -> np.ndarray:
        d = np.zeros(self.n, dtype=np.int64)
        for perm in self.perms:
            for s, _ in perm:
                d[s] += 1
        return d


def _greedy_color(n: int, edges: list[tuple[int, int]],
                  order: list[int]) -> dict[int, int]:
    node_colors: list[set[int]] = [set() for _ in range(n)]
    color_of: dict[int, int] = {}
    for ei in order:
        i, j = edges[ei]
        c = 0
        while c in node_colors[i] or c in node_colors[j]:
            c += 1
        color_of[ei] = c
        node_colors[i].add(c)
        node_colors[j].add(c)
    return color_of


def edge_color(n: int, edges: list[tuple[int, int]],
               trials: int = 16) -> list[list[tuple[int, int]]]:
    """Proper edge coloring → list of matchings (= ppermute rounds).

    Each round costs one full collective-permute of the params shard, so the
    color count is the gossip critical path: Δ ≤ χ′ ≤ Δ+1 (Vizing). Greedy
    can use up to 2Δ−1; we take the best of several greedy orders (degree-sum
    first + random restarts), which empirically reaches Δ or Δ+1 on the
    BA-Topo/exponential graphs used here. Deterministic for a given edge
    list — the round-robin cycle tensor (dynamic.py) and the per-matching
    bandwidth model (benchmarks) rely on getting the SAME matching order.
    """
    m = len(edges)
    deg = np.zeros(n, dtype=np.int64)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    orders = [sorted(range(m),
                     key=lambda ei: -(deg[edges[ei][0]] + deg[edges[ei][1]]))]
    rng = np.random.default_rng(0)
    for _ in range(max(trials - 1, 0)):
        orders.append(list(rng.permutation(m)))
    best: dict[int, int] | None = None
    for order in orders:
        cand = _greedy_color(n, edges, order)
        if best is None or max(cand.values(), default=-1) < max(best.values(), default=-1):
            best = cand
        if best and len(edges) and max(best.values()) + 1 == deg.max():
            break  # Δ rounds — optimal
    ncolors = 1 + max(best.values()) if best else 0
    matchings: list[list[tuple[int, int]]] = [[] for _ in range(ncolors)]
    for ei, c in best.items():
        matchings[c].append(edges[ei])
    return matchings


#: Backwards-compatible alias (pre-ISSUE-5 private name).
_edge_color = edge_color


def schedule_from_topology(topo: Topology) -> GossipSchedule:
    """Compile a Topology (graph + weights g) into a ppermute schedule."""
    n = topo.n
    W = weight_matrix_from_weights(n, topo.edges, topo.g)
    matchings = edge_color(n, list(topo.edges))
    perms, recv = [], []
    for matching in matchings:
        pairs: list[tuple[int, int]] = []
        w_round = np.zeros(n)
        for i, j in matching:
            pairs.extend([(i, j), (j, i)])
            w_round[j] = W[j, i]   # j receives x_i
            w_round[i] = W[i, j]
        perms.append(tuple(sorted(pairs)))
        recv.append(tuple(float(v) for v in w_round))
    return GossipSchedule(
        n=n,
        perms=tuple(perms),
        recv_weights=tuple(recv),
        self_weights=tuple(float(W[i, i]) for i in range(n)),
        name=f"gossip[{topo.name}]",
    )


def reconstruct_weight_matrix(sched: GossipSchedule) -> np.ndarray:
    """Invert the schedule back to W — the validation oracle for the
    decomposition (tests assert allclose against the source Topology's W)."""
    n = sched.n
    W = np.diag(np.asarray(sched.self_weights))
    for perm, wr in zip(sched.perms, sched.recv_weights):
        for s, d in perm:
            W[d, s] += wr[d]
    return W


def bytes_per_sync(sched: GossipSchedule, param_bytes: int) -> dict:
    """Collective traffic of one gossip sync (per the roofline's collective
    term). All-reduce reference: ring all-reduce moves 2·(n−1)/n·|params|."""
    deg = sched.degrees
    return {
        "per_worker_max": int(deg.max()) * param_bytes,
        "per_worker_mean": float(deg.mean()) * param_bytes,
        "total": int(deg.sum()) * param_bytes,
        "allreduce_per_worker": 2 * (sched.n - 1) / sched.n * param_bytes,
        "rounds": sched.rounds,
    }
