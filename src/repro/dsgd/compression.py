"""CHOCO-Gossip: compressed consensus over BA-Topo (beyond paper).

Composes communication compression (Koloskova et al., 2019) with the
paper's bandwidth-aware topology: each round transmits compress(x − x̂)
instead of x, and under the paper's time model (Eq. 34, t ∝ bytes/b_min)
the per-iteration cost scales by the compression ratio ω while CHOCO's
error-feedback keeps convergence (at a γ-slowed consensus rate).

    q_i   = C(x_i − x̂_i)                 (compressed innovation)
    x̂_j  += q_j  for every neighbor j    (all nodes track the same x̂'s)
    x_i  += γ Σ_j W_ij (x̂_j − x̂_i)      (gossip on the estimates)

The net effect benchmarked in benchmarks/bench_compression.py: with top-10%
compression, bytes-to-consensus drop whenever the topology is
bandwidth-bound — exactly the regime the paper targets.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph import Topology, weight_matrix_from_weights

__all__ = ["Compressor", "top_k_compressor", "random_k_compressor",
           "identity_compressor", "ChocoState", "choco_gossip_init",
           "choco_gossip_step", "choco_gamma"]


class Compressor(NamedTuple):
    fn: Callable            # (x, key) -> sparse/quantized y with same shape
    ratio: float            # transmitted fraction of the dense bytes
    name: str


def top_k_compressor(frac: float) -> Compressor:
    """Keep the top-⌈frac·d⌉ magnitudes (per worker), zero the rest."""
    def fn(x, key):
        flat = x.reshape(x.shape[0], -1)
        k = max(int(np.ceil(frac * flat.shape[1])), 1)
        thresh = -jnp.sort(-jnp.abs(flat), axis=1)[:, k - 1:k]
        mask = jnp.abs(flat) >= thresh
        return (flat * mask).reshape(x.shape)
    # indices cost ~half a float each in practice; charge 1.5× values
    return Compressor(fn, min(1.5 * frac, 1.0), f"top{int(frac * 100)}%")


def random_k_compressor(frac: float) -> Compressor:
    """Unbiased random-k sparsification (scaled by 1/frac)."""
    def fn(x, key):
        flat = x.reshape(x.shape[0], -1)
        mask = jax.random.bernoulli(key, frac, flat.shape)
        return (flat * mask / frac).reshape(x.shape)
    return Compressor(fn, min(1.5 * frac, 1.0), f"rand{int(frac * 100)}%")


def identity_compressor() -> Compressor:
    return Compressor(lambda x, key: x, 1.0, "dense")


class ChocoState(NamedTuple):
    x: jnp.ndarray        # (n, d) worker values
    x_hat: jnp.ndarray    # (n, d) public estimates (identical on all nodes)


def choco_gamma(topo: Topology, delta: float) -> float:
    """Stable consensus step size: γ ≲ δ·(1−|λ₂|)/… ; the simple rule
    γ = δ/(8 + δ) from the CHOCO paper's practical guidance."""
    return delta / (8.0 + delta)


def choco_gossip_init(x0: jnp.ndarray) -> ChocoState:
    return ChocoState(x=x0, x_hat=jnp.zeros_like(x0))


def choco_gossip_step(state: ChocoState, W: jnp.ndarray, comp: Compressor,
                      gamma: float, key) -> ChocoState:
    q = comp.fn(state.x - state.x_hat, key)          # innovation, compressed
    x_hat = state.x_hat + q                          # everyone updates copies
    mix = (W - jnp.eye(W.shape[0], dtype=W.dtype)) @ x_hat
    return ChocoState(x=state.x + gamma * mix, x_hat=x_hat)
