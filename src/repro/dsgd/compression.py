"""CHOCO-Gossip: compressed consensus over BA-Topo (beyond paper).

Composes communication compression (Koloskova et al., 2019) with the
paper's bandwidth-aware topology: each round transmits compress(x − x̂)
instead of x, and under the paper's time model (Eq. 34, t ∝ bytes/b_min)
the per-iteration cost scales by the compression ratio ω while CHOCO's
error-feedback keeps convergence (at a γ-slowed consensus rate).

    q_i   = C(x_i − x̂_i)                 (compressed innovation)
    x̂_j  += q_j  for every neighbor j    (all nodes track the same x̂'s)
    x_i  += γ Σ_j W_ij (x̂_j − x̂_i)      (gossip on the estimates)

The compression primitives (``compress_top_k`` / ``compress_random_k``) and
the estimate-gossip update (``choco_mix``) are standalone functions so the
device-resident cross-product engine (``repro.dsgd.sim``, DESIGN.md §12) and
the host-loop oracles here share ONE definition — parity between the scan
engine and ``choco_gossip_step`` is then a matter of key streams, not of
reimplemented math. The net effect is benchmarked in
benchmarks/bench_compression.py: with top-10% compression, bytes-to-consensus
drop whenever the topology is bandwidth-bound — exactly the regime the paper
targets.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.graph import Topology

__all__ = ["Compressor", "compress_top_k", "compress_random_k",
           "compression_ratio", "top_k_compressor", "random_k_compressor",
           "identity_compressor", "ChocoState", "choco_gossip_init",
           "choco_gossip_step", "choco_mix", "choco_gamma"]


class Compressor(NamedTuple):
    fn: Callable            # (x, key) -> sparse/quantized y with same shape
    ratio: float            # transmitted fraction of the dense bytes
    name: str


def compression_ratio(frac: float) -> float:
    """Transmitted fraction ω of the dense bytes for a sparsifying compressor:
    indices cost ~half a float each in practice, so charge 1.5× values."""
    return min(1.5 * frac, 1.0)


def _kth_largest_bitselect(absx: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exact k-th largest per row of a NON-NEGATIVE array, by radix select.

    For non-negative IEEE floats, value order equals unsigned integer order
    of the bit patterns, so the k-th largest is found by building its bit
    pattern top-down: keep bit b iff at least k elements match the prefix.
    Cost is ``bits`` vectorized compare+count passes — measured ~5× cheaper
    than ``lax.top_k`` on XLA:CPU at (1360 rows × 512, k=128), whose
    sort-bound TopK dominated the whole CHOCO engine (DESIGN.md §12).
    Returns the k-th largest VALUE per row (shape ``absx.shape[:-1] + (1,)``),
    bit-identical to ``lax.top_k(absx, k)[0][..., k-1]``.
    """
    bits = 64 if absx.dtype == jnp.float64 else 32
    uint = jnp.uint64 if bits == 64 else jnp.uint32
    v = lax.bitcast_convert_type(absx, uint)

    def body(b, prefix):
        cand = prefix | uint(1) << uint(bits - 1 - b)
        cnt = jnp.sum(v >= cand[..., None], axis=-1)
        return jnp.where(cnt >= k, cand, prefix)

    prefix = lax.fori_loop(0, bits, body,
                           jnp.zeros(absx.shape[:-1], uint))
    return lax.bitcast_convert_type(prefix, absx.dtype)[..., None]


def compress_top_k(x: jnp.ndarray, frac: float,
                   method: str = "auto") -> jnp.ndarray:
    """Keep the top-⌈frac·d⌉ magnitudes per worker row, zero the rest.

    The threshold is the exact k-th largest |x| (k static) and the kept set
    is ``|x| >= thresh`` — the same threshold value and tie rule as the seed
    sort-and-slice implementation. ``method`` picks how the threshold is
    computed: ``"top_k"`` = ``jax.lax.top_k``; ``"bitselect"`` = the radix
    select above; ``"auto"`` = bitselect on CPU (where XLA's TopK is
    sort-bound and ~40× slower), top_k elsewhere. All three are bit-identical
    (tested), so engine/oracle parity never depends on the choice.
    """
    flat = x.reshape(x.shape[0], -1)
    k = max(int(np.ceil(frac * flat.shape[1])), 1)
    absx = jnp.abs(flat)
    if method == "auto":
        method = "bitselect" if jax.default_backend() == "cpu" else "top_k"
    if method == "bitselect":
        thresh = _kth_largest_bitselect(absx, k)
    else:
        thresh = lax.top_k(absx, k)[0][:, k - 1:k]
    mask = absx >= thresh
    return (flat * mask).reshape(x.shape)


def compress_random_k(x: jnp.ndarray, frac: float, key) -> jnp.ndarray:
    """Unbiased random-k sparsification (scaled by 1/frac), keyed per call."""
    flat = x.reshape(x.shape[0], -1)
    mask = jax.random.bernoulli(key, frac, flat.shape)
    return (flat * mask / frac).reshape(x.shape)


def top_k_compressor(frac: float) -> Compressor:
    """Keep the top-⌈frac·d⌉ magnitudes (per worker), zero the rest."""
    return Compressor(lambda x, key: compress_top_k(x, frac),
                      compression_ratio(frac), f"top{int(frac * 100)}%")


def random_k_compressor(frac: float) -> Compressor:
    """Unbiased random-k sparsification (scaled by 1/frac)."""
    return Compressor(lambda x, key: compress_random_k(x, frac, key),
                      compression_ratio(frac), f"rand{int(frac * 100)}%")


def identity_compressor() -> Compressor:
    return Compressor(lambda x, key: x, 1.0, "dense")


class ChocoState(NamedTuple):
    x: jnp.ndarray        # (n, d) worker values
    x_hat: jnp.ndarray    # (n, d) public estimates (identical on all nodes)


def choco_gamma(topo: Topology, delta: float) -> float:
    """Stable consensus step size: γ ≲ δ·(1−|λ₂|)/… ; the simple rule
    γ = δ/(8 + δ) from the CHOCO paper's practical guidance."""
    return delta / (8.0 + delta)


def choco_gossip_init(x0: jnp.ndarray) -> ChocoState:
    return ChocoState(x=x0, x_hat=jnp.zeros_like(x0))


def choco_mix(x: jnp.ndarray, x_hat: jnp.ndarray, W: jnp.ndarray,
              gamma) -> jnp.ndarray:
    """x + γ (W − I) x̂ on a stacked ``(n, ...)`` array.

    The worker dimension is contracted in place (dot_general on the native
    shape, same convention as ``gossip_sim``), so parameter-pytree leaves of
    any rank flow through without a merging reshape. ``gamma`` may be traced
    data — the cross-product engine vmaps over a γ grid.
    """
    delta = lax.dot_general(
        W - jnp.eye(W.shape[0], dtype=W.dtype), x_hat,
        (((1,), (0,)), ((), ())))
    return x + gamma * delta


def choco_gossip_step(state: ChocoState, W: jnp.ndarray, comp: Compressor,
                      gamma: float, key) -> ChocoState:
    q = comp.fn(state.x - state.x_hat, key)          # innovation, compressed
    x_hat = state.x_hat + q                          # everyone updates copies
    return ChocoState(x=choco_mix(state.x, x_hat, W, gamma), x_hat=x_hat)
