"""Fault injection for the DSGD engines (DESIGN.md §14).

Every scenario the repo could run before this module was a fixed graph with
fixed bandwidths. ``ChaosSpec`` packages the four fault modes of a real
decentralized deployment as PRECOMPUTED per-step tensors, so the scan engine
consumes them as data leaves (a step-index gather inside the scan, the same
trick that made dynamic cycles and CHOCO state vmap-able in DESIGN.md §12):

  - ``alive      (T, n)``    node-alive masks — join/leave churn. A dead node
    freezes (no gradient step, no mixing) and rejoins at its last params.
  - ``link_up    (T, n, n)`` symmetric per-edge Bernoulli draws — packet
    loss. A down link carries nothing that step; both endpoints fold the
    lost weight into their self-weight (see ``degrade_matrix``).
  - ``straggler  (T, n)``    per-node delay multipliers (≥ 1) — feed the
    Eq. 34 step-time model (``benchmarks.common.chaos_step_times``), not the
    training math: a straggler is late, not wrong.
  - ``bandwidth  (T, n)``    time-varying per-node bandwidth profile B(t),
    GB/s — feeds the time model and the drift detector
    (``repro.core.reopt``), not the training math.

``degrade_matrix`` is the graceful-degradation rule: lost off-diagonal mass
(dead neighbors, down links) is folded into the surviving nodes' self
weights, so the effective gossip matrix stays row-stochastic on the alive
subgraph — mixing slows down instead of diverging. Dead rows AND columns are
fully zeroed: a dead node neither sends nor receives, and the engine restores
its frozen parameters with a ``where(alive, ...)`` after the mix. When W and
``link_up`` are symmetric the degraded matrix stays symmetric (the mass a row
loses equals the mass the mirror column loses), so double stochasticity — and
therefore mean preservation across the alive set — survives every fault
pattern.

All constructors are host-side numpy (seeded, reproducible); only ``alive``
and ``link_up`` ever ship to the device.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

__all__ = ["ChaosSpec", "no_chaos", "make_chaos", "random_churn_windows",
           "drift_profile", "degrade_matrix"]


@dataclass(frozen=True)
class ChaosSpec:
    """Precomputed fault tensors for a ``steps``-iteration run on n nodes."""

    alive: np.ndarray       # (T, n) float32 ∈ {0, 1}
    link_up: np.ndarray     # (T, n, n) float32 ∈ {0, 1}, symmetric, diag 1
    straggler: np.ndarray   # (T, n) float64 ≥ 1 — step-time multipliers
    bandwidth: np.ndarray   # (T, n) float64 GB/s — B(t) per node
    meta: dict = field(default_factory=dict)

    @property
    def steps(self) -> int:
        return self.alive.shape[0]

    @property
    def n(self) -> int:
        return self.alive.shape[1]

    @property
    def faultless(self) -> bool:
        """True when the *training-math* fault tensors are all-clear (alive
        everywhere, every link up). Stragglers and bandwidth drift do not
        touch the math — they only stretch the modeled clock."""
        return bool(np.all(self.alive == 1.0) and np.all(self.link_up == 1.0))

    def device_leaves(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """The two tensors the scan engine actually needs, as device arrays."""
        return jnp.asarray(self.alive, jnp.float32), \
            jnp.asarray(self.link_up, jnp.float32)

    def validate(self) -> None:
        T, n = self.alive.shape
        if self.link_up.shape != (T, n, n):
            raise ValueError(f"link_up shape {self.link_up.shape} != {(T, n, n)}")
        if self.straggler.shape != (T, n) or self.bandwidth.shape != (T, n):
            raise ValueError("straggler/bandwidth must be (steps, n)")
        if not np.allclose(self.link_up, np.swapaxes(self.link_up, 1, 2)):
            raise ValueError("link_up must be symmetric per step "
                             "(an undirected edge drops for both endpoints)")
        if np.any(self.straggler < 1.0):
            raise ValueError("straggler multipliers must be ≥ 1")
        if np.any(self.bandwidth <= 0.0):
            raise ValueError("bandwidth profile must be positive")


def no_chaos(steps: int, n: int, bandwidth: float = 9.76) -> ChaosSpec:
    """The fault-free spec: running the chaos engine with it is a bit-exact
    no-op versus the fault-less engine (tested)."""
    return ChaosSpec(
        alive=np.ones((steps, n), np.float32),
        link_up=np.ones((steps, n, n), np.float32),
        straggler=np.ones((steps, n), np.float64),
        bandwidth=np.full((steps, n), float(bandwidth), np.float64),
        meta={"faultless": True},
    )


def drift_profile(steps: int, n: int, drift_step: int, bw0: np.ndarray,
                  slow_nodes: int, slow_bw: float) -> np.ndarray:
    """(T, n) bandwidth profile: ``bw0`` until ``drift_step``, then the
    first ``slow_nodes`` nodes collapse to ``slow_bw`` GB/s for good — the
    canonical NIC-collapse scenario shared by bench_chaos, bench_elastic
    and the elastic tests."""
    prof = np.broadcast_to(np.asarray(bw0, np.float64), (steps, n)).copy()
    prof[drift_step:, :slow_nodes] = slow_bw
    return prof


def random_churn_windows(n: int, steps: int, events: int, seed: int = 0,
                         min_alive: int = 2,
                         min_down: int | None = None) -> list[tuple[int, int, int]]:
    """Draw ``events`` reproducible (node, t_leave, t_rejoin) churn windows.

    Windows never overlap on the same node and never take the alive count
    below ``min_alive`` at any step. ``t_rejoin == steps`` means the node
    leaves for good."""
    rng = np.random.default_rng(seed)
    down = np.zeros((steps, n), np.int64)
    out: list[tuple[int, int, int]] = []
    lo = max(min_down or steps // 8, 1)
    for _ in range(events):
        for _attempt in range(64):
            node = int(rng.integers(n))
            t0 = int(rng.integers(0, max(steps - lo, 1)))
            t1 = min(int(t0 + rng.integers(lo, max(steps // 2, lo + 1))), steps)
            window = down[t0:t1]
            if window[:, node].any():
                continue                          # node already down here
            if (n - (window.sum(axis=1) + 1)).min() < min_alive:
                continue                          # would depopulate the net
            window[:, node] = 1
            out.append((node, t0, t1))
            break
    return out


def make_chaos(steps: int, n: int, seed: int = 0, *,
               churn: list[tuple[int, int, int]] | None = None,
               p_drop: float = 0.0,
               straggler_prob: float = 0.0,
               straggler_mult: float = 3.0,
               bandwidth: np.ndarray | float = 9.76) -> ChaosSpec:
    """Build a ChaosSpec from scenario knobs.

    ``churn``: explicit (node, t_leave, t_rejoin) windows (deterministic —
    what the benches and the drift detector key on; use
    ``random_churn_windows`` to draw them). ``p_drop``: per-step per-edge
    Bernoulli link-drop probability (drawn once on the upper triangle and
    mirrored, so the draw is symmetric). ``straggler_prob``/``straggler_mult``:
    each step each node independently runs ``straggler_mult×`` slow with the
    given probability. ``bandwidth``: scalar, (n,) static profile, or a full
    (T, n) drifting profile B(t).
    """
    rng = np.random.default_rng(seed)
    alive = np.ones((steps, n), np.float32)
    for node, t0, t1 in churn or ():
        if not (0 <= node < n and 0 <= t0 <= t1 <= steps):
            raise ValueError(f"churn window {(node, t0, t1)} out of range "
                             f"for steps={steps}, n={n}")
        alive[t0:t1, node] = 0.0

    link_up = np.ones((steps, n, n), np.float32)
    if p_drop > 0.0:
        iu, ju = np.triu_indices(n, k=1)
        drops = rng.random((steps, len(iu))) < p_drop
        link_up[:, iu, ju] = np.where(drops, 0.0, 1.0)
        link_up[:, ju, iu] = link_up[:, iu, ju]

    straggler = np.ones((steps, n), np.float64)
    if straggler_prob > 0.0:
        slow = rng.random((steps, n)) < straggler_prob
        straggler = np.where(slow, float(straggler_mult), 1.0)

    bw = np.asarray(bandwidth, np.float64)
    if bw.ndim == 0:
        bw = np.full((steps, n), float(bw))
    elif bw.ndim == 1:
        bw = np.broadcast_to(bw, (steps, n)).copy()
    elif bw.shape != (steps, n):
        raise ValueError(f"bandwidth profile shape {bw.shape} != {(steps, n)}")

    spec = ChaosSpec(alive=alive, link_up=link_up, straggler=straggler,
                     bandwidth=bw,
                     meta={"seed": seed, "p_drop": p_drop,
                           "churn": list(churn or ()),
                           "straggler_prob": straggler_prob})
    spec.validate()
    return spec


def degrade_matrix(W: jnp.ndarray, alive: jnp.ndarray,
                   link_up: jnp.ndarray) -> jnp.ndarray:
    """Renormalize a gossip matrix under node/link faults — on device.

    An off-diagonal entry survives iff both endpoints are alive AND the link
    is up; every entry a row loses is folded into that row's self-weight, so
    alive rows stay row-stochastic (mixing degrades gracefully instead of
    leaking mass). Dead rows and columns are fully zeroed — the engine
    restores dead nodes' frozen state after the mix.

    With no faults this is an IEEE-exact identity (mask multiplies by 1.0,
    the folded loss is an exact 0.0 sum), which is what makes the fault-free
    chaos engine bit-equal to the fault-less engine. Broadcasts over leading
    batch axes; symmetric (W, link_up) stays symmetric.
    """
    dt = W.dtype
    n = W.shape[-1]
    alive = alive.astype(dt)
    pair = alive[..., :, None] * alive[..., None, :] * link_up.astype(dt)
    eye = jnp.eye(n, dtype=dt)
    off = W * (1.0 - eye)
    kept = off * pair
    lost = (off - kept).sum(axis=-1)
    diag = (jnp.diagonal(W, axis1=-2, axis2=-1) + lost) * alive
    return kept + eye * diag[..., :, None]
