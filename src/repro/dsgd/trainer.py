"""DSGD training steps (Lian et al. 2017, adapt-then-combine):

    x_i ← Σ_j W_ij · ( x_j − lr · ∇f_j(x_j) )

Three step builders share the same math:

  dsgd_train_step          single-device oracle: workers stacked on a leading
                           (n,) axis, vmapped grads, gossip = dense W matmul
                           (paper Eq. 1 verbatim).
  allreduce_train_step     centralized baseline (W = 11ᵀ/n ⇒ exact averaging);
                           same stacked layout so time-to-accuracy comparisons
                           are apples-to-apples.
  make_sharded_train_step  production path: jit(shard_map) manual over the
                           gossip axis ("data", or ("pod","data") multi-pod),
                           auto over "model"; gossip = ppermute matching
                           rounds from schedule.py. This is what the multi-pod
                           dry-run lowers.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.graph import Topology, weight_matrix_from_weights
from repro.models import transformer
from repro.optim import apply_updates

from .gossip import gossip_shard, gossip_sim_tree, padded_neighbors
from .schedule import GossipSchedule

__all__ = ["DSGDState", "init_dsgd_state", "dsgd_train_step", "allreduce_train_step",
           "make_sharded_train_step"]


class DSGDState(NamedTuple):
    """Per-worker replicas stacked on a leading (n,) axis (sharded over the
    gossip mesh axis in the production path, a plain array axis in the sim)."""
    params: Any
    opt: Any
    step: jnp.ndarray


def init_dsgd_state(key, cfg, n_workers: int, opt_init: Callable) -> DSGDState:
    """All workers start from identical params (standard DSGD init: the
    consensus error starts at 0 and is re-introduced only by gradient noise)."""
    params = transformer.init_params(key, cfg)
    opt = opt_init(params)
    rep = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_workers,) + x.shape), t)
    return DSGDState(rep(params), rep(opt), jnp.zeros((), jnp.int32))


def _loss_fn(cfg, aux_weight: float = 0.01):
    def fn(params, batch):
        return transformer.train_loss(params, cfg, batch, aux_weight=aux_weight)
    return fn


# ---------------------------------------------------------------------------
# single-device oracle paths
# ---------------------------------------------------------------------------

def dsgd_train_step(cfg, topo: Topology, opt_update: Callable, *,
                    use_kernel: bool = False):
    """Returns jit'd (state, batch) → (state, metrics); batch leaves (n, b, ...)."""
    W = jnp.asarray(weight_matrix_from_weights(topo.n, topo.edges, topo.g),
                    jnp.float32)
    loss_fn = _loss_fn(cfg)
    nbr = padded_neighbors(W) if use_kernel else None

    @jax.jit
    def step(state: DSGDState, batch):
        losses, grads = jax.vmap(jax.value_and_grad(loss_fn))(state.params, batch)
        updates, opt = jax.vmap(opt_update)(grads, state.opt, state.params)
        params = jax.vmap(apply_updates)(state.params, updates)
        params = gossip_sim_tree(params, W, use_kernel=use_kernel, nbr=nbr)
        metrics = {"loss": losses.mean(), "loss_max": losses.max(),
                   "consensus_err": _consensus_error(params)}
        return DSGDState(params, opt, state.step + 1), metrics

    return step


def allreduce_train_step(cfg, n_workers: int, opt_update: Callable):
    """Centralized all-reduce baseline: exact parameter averaging each step."""
    W = jnp.full((n_workers, n_workers), 1.0 / n_workers, jnp.float32)
    loss_fn = _loss_fn(cfg)

    @jax.jit
    def step(state: DSGDState, batch):
        losses, grads = jax.vmap(jax.value_and_grad(loss_fn))(state.params, batch)
        updates, opt = jax.vmap(opt_update)(grads, state.opt, state.params)
        params = jax.vmap(apply_updates)(state.params, updates)
        params = gossip_sim_tree(params, W)
        metrics = {"loss": losses.mean(), "loss_max": losses.max(),
                   "consensus_err": _consensus_error(params)}
        return DSGDState(params, opt, state.step + 1), metrics

    return step


def _consensus_error(params) -> jnp.ndarray:
    """‖x − x̄‖_F over all stacked leaves (the paper's consensus metric)."""
    def leaf_err(x):
        mean = x.mean(axis=0, keepdims=True)
        return jnp.sum(jnp.square((x - mean).astype(jnp.float32)))
    return jnp.sqrt(sum(jax.tree.leaves(jax.tree.map(leaf_err, params))))


def _accum_value_and_grad(loss_fn, params, batch, accum_steps: int):
    """Gradient accumulation: scan over ``accum_steps`` microbatches (split on
    the batch dim) — peak activation memory shrinks ×accum_steps while the
    gradient is mathematically identical (mean of microbatch grads)."""
    if accum_steps <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def split(x):
        b = x.shape[0]
        return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])

    micro = jax.tree.map(split, batch)
    gfn = jax.value_and_grad(loss_fn)

    def body(carry, mb):
        loss_acc, grad_acc = carry
        loss, grads = gfn(params, mb)
        return (loss_acc + loss,
                jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                             grad_acc, grads)), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grad_sum), _ = jax.lax.scan(body, (jnp.float32(0), zeros), micro)
    scale = 1.0 / accum_steps
    return loss_sum * scale, jax.tree.map(lambda g: g * scale, grad_sum)


def make_matmul_gossip_train_step(cfg, topo: Topology, opt_update: Callable, *,
                                  accum_steps: int = 1):
    """Stacked-worker DSGD step with gossip as the dense W matmul (Eq. 1)
    under pure pjit — no manual mesh axes. Used for pod-sized workers
    (n = #pods is tiny, so the (n×n)·params einsum is cheap), where XLA's
    partial-manual partitioner chokes on the MoE gathers at 512 devices.
    XLA lowers the worker-axis contraction to pod-boundary collectives."""
    W = jnp.asarray(weight_matrix_from_weights(topo.n, topo.edges, topo.g),
                    jnp.float32)
    loss_fn = _loss_fn(cfg)

    def train_step(state: DSGDState, batch):
        losses, grads = jax.vmap(
            lambda p, b: _accum_value_and_grad(loss_fn, p, b, accum_steps)
        )(state.params, batch)
        updates, opt = jax.vmap(opt_update)(grads, state.opt, state.params)
        params = jax.vmap(apply_updates)(state.params, updates)
        params = gossip_sim_tree(params, W)
        return DSGDState(params, opt, state.step + 1), {"loss": losses.mean()}

    return train_step


def make_tp_train_step(cfg, opt_update: Callable, *, accum_steps: int = 1):
    """Single-worker step (no gossip): pure tensor/2-D-parallel training via
    pjit sharding constraints — the big-arch (mixtral) single-pod fallback."""
    loss_fn = _loss_fn(cfg)

    def train_step(state: DSGDState, batch):
        loss, grads = _accum_value_and_grad(loss_fn, state.params, batch,
                                            accum_steps)
        updates, opt = opt_update(grads, state.opt, state.params)
        params = apply_updates(state.params, updates)
        return DSGDState(params, opt, state.step + 1), {"loss": loss}

    return train_step


# ---------------------------------------------------------------------------
# production sharded path (dry-run target)
# ---------------------------------------------------------------------------

def make_sharded_train_step(cfg, sched: GossipSchedule, opt_update: Callable,
                            mesh, *, gossip_axes=("data",), sync: str = "gossip"):
    """Build the pjit-able DSGD step for a mesh.

    gossip_axes: mesh axis name(s) hosting the n workers — ("data",) single
    pod, ("pod", "data") multi-pod (ppermute treats the tuple as one
    flattened logical axis; BA-Topo's pod_boundary_constraints penalize
    edges crossing the slow boundary).
    sync ∈ {"gossip", "allreduce", "none"}: allreduce is the centralized
    baseline lowered on the same mesh; none isolates compute for roofline.
    """
    axis = gossip_axes if len(gossip_axes) > 1 else gossip_axes[0]
    loss_fn = _loss_fn(cfg)

    def worker(params, opt, batch, step):
        # leaves arrive with leading worker axis of size 1 (manual shard)
        sq = lambda t: jax.tree.map(lambda x: x[0], t)
        un = lambda t: jax.tree.map(lambda x: x[None], t)
        p1, o1 = sq(params), sq(opt)
        b1 = sq(batch)
        loss, grads = jax.value_and_grad(loss_fn)(p1, b1)
        updates, o1 = opt_update(grads, o1, p1)
        p1 = apply_updates(p1, updates)
        if sync == "gossip":
            p1 = gossip_shard(p1, sched, axis)
        elif sync == "allreduce":
            # pmean in f32: XLA CPU's float-normalization pass crashes
            # cloning a bf16 all-reduce (ChangeOpDataType/CloneAllReduce)
            p1 = jax.tree.map(
                lambda x: jax.lax.pmean(x.astype(jnp.float32), axis).astype(x.dtype),
                p1)
        loss = jax.lax.pmean(loss, axis)
        return un(p1), un(o1), loss

    nspec = P(gossip_axes if len(gossip_axes) > 1 else gossip_axes[0])
    smapped = jax.shard_map(
        worker, mesh=mesh,
        in_specs=(nspec, nspec, nspec, P()),
        out_specs=(nspec, nspec, P()),
        axis_names=set(gossip_axes),
        # model code is mesh-agnostic: its scan carries start axis-invariant
        # and become varying, which the static VMA checker rejects
        check_vma=False,
    )

    def train_step(state: DSGDState, batch):
        params, opt, loss = smapped(state.params, state.opt, batch, state.step)
        return DSGDState(params, opt, state.step + 1), {"loss": loss}

    return train_step
