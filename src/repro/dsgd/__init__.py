"""Decentralized-SGD runtime: BA-Topo gossip as a TPU collective schedule."""
from .schedule import GossipSchedule, bytes_per_sync, reconstruct_weight_matrix, schedule_from_topology
from .compression import (
    ChocoState,
    choco_gamma,
    choco_gossip_init,
    choco_gossip_step,
    identity_compressor,
    random_k_compressor,
    top_k_compressor,
)
from .dynamic import cycle_contraction, round_robin_schedules
from .gossip import (
    gossip_shard,
    gossip_sim,
    gossip_sim_tree,
    gossip_sim_tree_rowloop,
    padded_neighbors,
)
from .sim import (
    DSGDSimConfig,
    accuracy_curve_host,
    accuracy_curves,
    accuracy_curves_seeds,
)
from .trainer import (
    DSGDState,
    allreduce_train_step,
    dsgd_train_step,
    init_dsgd_state,
    make_matmul_gossip_train_step,
    make_sharded_train_step,
    make_tp_train_step,
)

__all__ = [
    "GossipSchedule", "bytes_per_sync", "reconstruct_weight_matrix",
    "schedule_from_topology", "gossip_shard", "gossip_sim", "gossip_sim_tree",
    "gossip_sim_tree_rowloop", "padded_neighbors",
    "DSGDSimConfig", "accuracy_curve_host", "accuracy_curves",
    "accuracy_curves_seeds",
    "ChocoState", "choco_gamma", "choco_gossip_init", "choco_gossip_step",
    "identity_compressor", "random_k_compressor", "top_k_compressor",
    "cycle_contraction", "round_robin_schedules",
    "DSGDState", "allreduce_train_step", "dsgd_train_step", "init_dsgd_state",
    "make_matmul_gossip_train_step", "make_sharded_train_step", "make_tp_train_step",
]
