"""Decentralized-SGD runtime: BA-Topo gossip as a TPU collective schedule."""
from .schedule import (
    GossipSchedule,
    bytes_per_sync,
    edge_color,
    reconstruct_weight_matrix,
    schedule_from_topology,
)
from .compression import (
    ChocoState,
    choco_gamma,
    choco_gossip_init,
    choco_gossip_step,
    choco_mix,
    compress_random_k,
    compress_top_k,
    identity_compressor,
    random_k_compressor,
    top_k_compressor,
)
from .chaos import (
    ChaosSpec,
    degrade_matrix,
    make_chaos,
    no_chaos,
    random_churn_windows,
)
from .dynamic import (
    cycle_contraction,
    cycle_tensor,
    round_robin_schedules,
    stack_cycles,
    static_cycle,
)
from .gossip import (
    gossip_shard,
    gossip_sim,
    gossip_sim_tree,
    gossip_sim_tree_rowloop,
    padded_neighbors,
    select_cycle_matrix,
)
from .sim import (
    CommSpec,
    DSGDSimConfig,
    accuracy_curve_host,
    accuracy_curve_host_chaos,
    accuracy_curve_host_cross,
    accuracy_curves,
    accuracy_curves_seeds,
    consensus_curve_host_chaos,
    consensus_curve_host_cross,
    consensus_curves_chaos,
    consensus_curves_cross,
    train_curves_chaos,
    train_curves_cross,
)
from .trainer import (
    DSGDState,
    allreduce_train_step,
    dsgd_train_step,
    init_dsgd_state,
    make_matmul_gossip_train_step,
    make_sharded_train_step,
    make_tp_train_step,
)

__all__ = [
    "GossipSchedule", "bytes_per_sync", "edge_color",
    "reconstruct_weight_matrix", "schedule_from_topology",
    "gossip_shard", "gossip_sim", "gossip_sim_tree",
    "gossip_sim_tree_rowloop", "padded_neighbors", "select_cycle_matrix",
    "DSGDSimConfig", "accuracy_curve_host", "accuracy_curves",
    "accuracy_curves_seeds",
    "CommSpec", "train_curves_cross", "accuracy_curve_host_cross",
    "consensus_curves_cross", "consensus_curve_host_cross",
    "ChaosSpec", "no_chaos", "make_chaos", "random_churn_windows",
    "degrade_matrix",
    "train_curves_chaos", "accuracy_curve_host_chaos",
    "consensus_curves_chaos", "consensus_curve_host_chaos",
    "ChocoState", "choco_gamma", "choco_gossip_init", "choco_gossip_step",
    "choco_mix", "compress_top_k", "compress_random_k",
    "identity_compressor", "random_k_compressor", "top_k_compressor",
    "cycle_contraction", "cycle_tensor", "round_robin_schedules",
    "stack_cycles", "static_cycle",
    "DSGDState", "allreduce_train_step", "dsgd_train_step", "init_dsgd_state",
    "make_matmul_gossip_train_step", "make_sharded_train_step", "make_tp_train_step",
]
