"""Elastic gossip training runtime for the real model zoo (DESIGN.md §16).

The chaos tier (§14) made the *simulated* DSGD engines fault-tolerant; this
module does the same for the real-model gossip loop that ``launch/train.py``
drives over ``repro/models``. One ``ElasticRuntime`` wraps a single jitted
train step with every time-varying input passed as DATA, so nothing a fault
or a re-optimization changes ever retraces:

  membership   ``ChaosSpec.alive``/``link_up`` rows feed ``degrade_matrix``
               inside the step: the effective mixing matrix is renormalized
               row-stochastic on the alive subgraph, dead workers freeze
               params AND optimizer state (``where(alive, …)``) and rejoin
               at their frozen state. With the all-clear masks the step is
               an IEEE-exact identity over ``dsgd_train_step`` — the
               fault-free elastic path is bit-exact vs the plain trainer
               (tested).
  watchdog     a per-round deadline derived from the Eq. 34 modeled latency
               (``node_step_latency_ms``, the per-node refinement of
               ``benchmarks.common.chaos_step_times``): nodes whose modeled
               round latency exceeds ``deadline_factor ×`` the fault-free
               round are dropped from the round's exchange only — they keep
               their local update, survivors renormalize, the round clock is
               capped at the deadline instead of waiting out the straggler.
               Round execution itself runs a bounded retry/backoff ladder
               with ``core.guard.run_ladder`` semantics (classified
               ``RungReport`` trail, never raises): a non-finite loss is
               retried ``max_round_retries`` times, then the round is
               skipped with the state frozen.
  re-optimize  a ``core.reopt.DriftDetector`` watches (B(t), alive) each
               round; on a trigger the incumbent is re-solved warm-started
               (``reoptimize_topology``'s warm → cold → keep-incumbent
               ladder) and the winner is adopted a deterministic
               ``activation_lag_steps`` later by hot-swapping the W matrix
               (and the deg-capped padded-neighbor tables of the kernel
               path) — data swaps, no retrace.
  resume       ``ElasticState`` round-trips through the checkpoint extras
               payload (``to_extras``/``from_extras``): incumbent + pending
               topology, detector baselines, PRNG key, data-stream position
               and the membership counters — everything a SIGKILLed run
               needs to reproduce the uninterrupted loss curve bit-exactly.

``make_elastic_sharded_train_step`` applies the same contract to the
production ppermute path: schedule weights and membership masks are data
(``gossip_shard_elastic``), so weight re-polish and churn never retrace;
only a support change rebuilds the schedule.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.bandwidth import PaperConstants, t_iter
from repro.core.graph import Topology, degrees, weight_matrix_from_weights
from repro.core.guard import RungReport
from repro.core.reopt import (
    DriftDetector,
    DriftPolicy,
    ReoptResult,
    reoptimize_topology,
)
from repro.optim import apply_updates

from .chaos import ChaosSpec, degrade_matrix
from .gossip import (
    elastic_neighbor_tables,
    gather_neighbor_weights,
    gossip_shard_elastic,
    gossip_sim_tree,
    schedule_weight_arrays,
)
from .schedule import GossipSchedule
from .trainer import DSGDState, _loss_fn

__all__ = ["ElasticSpec", "ElasticState", "ElasticHooks", "RoundReport",
           "ElasticRuntime", "make_elastic_train_step",
           "make_elastic_sharded_train_step", "node_step_latency_ms",
           "fault_free_round_ms"]


# ---------------------------------------------------------------------------
# modeled per-node latency (the watchdog's clock)
# ---------------------------------------------------------------------------

def node_step_latency_ms(topo: Topology, chaos: ChaosSpec, t: int,
                         const: PaperConstants = PaperConstants()
                         ) -> np.ndarray:
    """Per-node modeled latency (ms) of round ``t`` — the per-node view of
    ``benchmarks.common.chaos_step_times``.

    Node i's comm time is Eq. 34 at the slowest of its *active* incident
    edges (both endpoints alive; degree-shared ``min(B_i/d_i, B_j/d_j)``
    with static degrees — ports are provisioned for the full graph); its
    round latency is ``(t_comm + t_comp) × straggler_i(t)``. Dead nodes
    report 0 — they are not waited on. Link drops cost accuracy, not time
    (the exchange window elapses either way), matching the chaos clock.
    """
    n = topo.n
    alive = np.asarray(chaos.alive[t]) > 0
    bw = np.asarray(chaos.bandwidth[t], np.float64)
    strag = np.asarray(chaos.straggler[t], np.float64)
    d = np.maximum(degrees(n, topo.edges).astype(np.float64), 1.0)
    comm = np.zeros(n)
    for i, j in topo.edges:
        if alive[i] and alive[j]:
            b_e = min(bw[i] / d[i], bw[j] / d[j])
            t_e = t_iter(b_e, const)
            comm[i] = max(comm[i], t_e)
            comm[j] = max(comm[j], t_e)
    lat = (comm + const.t_comp_ms) * strag
    lat[~alive] = 0.0
    return lat


def fault_free_round_ms(topo: Topology, bandwidth: np.ndarray,
                        const: PaperConstants = PaperConstants()) -> float:
    """The fault-free modeled round time (ms) of ``topo`` under a static
    per-node ``bandwidth`` profile — the watchdog deadline's baseline."""
    n = topo.n
    bw = np.broadcast_to(np.asarray(bandwidth, np.float64), (n,))
    d = np.maximum(degrees(n, topo.edges).astype(np.float64), 1.0)
    comm = 0.0
    for i, j in topo.edges:
        comm = max(comm, t_iter(min(bw[i] / d[i], bw[j] / d[j]), const))
    return comm + const.t_comp_ms


# ---------------------------------------------------------------------------
# spec / state / reports
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ElasticSpec:
    """Static policy of an elastic run (the ChaosSpec carries the faults).

    ``deadline_factor``: round deadline = factor × the incumbent's
    fault-free modeled round time at the initial bandwidth profile.
    ``drop_stragglers``: watchdog authority to drop over-deadline nodes from
    a round's exchange (False = classic BSP: every round waits out the
    slowest straggler). ``max_round_retries``/``retry_backoff``: bounded
    retry ladder for non-finite rounds; retry k is modeled to cost
    ``backoff^k`` extra round times. ``reopt``: close the DriftDetector →
    ``reoptimize_topology`` loop; adopted topologies activate
    ``activation_lag_steps`` rounds after the trigger (deterministic in
    steps, so a resumed run replays the same adoption schedule bit-exactly;
    the measured solve wall time is reported, not modeled).
    ``reopt_budget``: bound the re-solve with the anytime pipeline —
    ``"window"`` budgets it to exactly the adoption window the fleet waits
    out anyway (``activation_lag_steps`` × the incumbent's modeled
    fault-free round time at the drifted profile), a float is an explicit
    ms budget, and None (default) keeps the unbudgeted deterministic
    re-solve: a wall-clock budget makes the adopted support
    timing-dependent, which would break the bit-exact crash/resume replay
    guarantee (DESIGN.md §16) — so budgeting is opt-in.
    """

    chaos: ChaosSpec
    deadline_factor: float = 3.0
    drop_stragglers: bool = True
    max_round_retries: int = 1
    retry_backoff: float = 2.0
    reopt: bool = True
    reopt_scenario: str = "node"
    reopt_r: int | None = None
    reopt_budget: float | str | None = None
    activation_lag_steps: int = 1
    drift: DriftPolicy = field(default_factory=DriftPolicy)
    topo_cfg: Any = None              # BATopoConfig | None (core.api import cycle)
    const: PaperConstants = field(default_factory=PaperConstants)


@dataclass
class ElasticState:
    """Host-side elastic runtime state — everything `--resume` must restore
    beyond the DSGDState pytree (see ``to_extras``/``from_extras``)."""

    topology: Topology
    W: jnp.ndarray                                  # (n, n) f32, data leaf
    nbr: tuple[jnp.ndarray, jnp.ndarray] | None     # deg-capped kernel tables
    detector: DriftDetector
    key: jnp.ndarray                                # PRNG key (folded per round)
    data_step: int = 0                              # batches consumed
    pending: tuple[int, Topology] | None = None     # (activate_step, topology)
    reopts: int = 0                                 # solver runs triggered
    adopted: int = 0                                # topologies hot-swapped
    dropped_rounds: int = 0                         # rounds with ≥1 drop
    drops: int = 0                                  # node-rounds dropped
    events: list[dict] = field(default_factory=list)


@dataclass
class RoundReport:
    """What one elastic round did (the watchdog/membership trail)."""

    step: int
    alive: np.ndarray                 # (n,) bool — chaos membership this round
    dropped: np.ndarray               # (n,) bool — watchdog drops this round
    round_ms: float                   # modeled round time (deadline-capped)
    deadline_ms: float
    attempts: int                     # step executions (1 + retries)
    rungs: list[RungReport]
    reopt: ReoptResult | None = None  # set when the detector fired this round
    reopt_reason: str | None = None
    swapped: bool = False             # a pending topology activated this round


class ElasticHooks:
    """Fault-injection seams (tests/bench only — production uses defaults).

    ``on_attempt(step, attempt, batch) -> batch`` runs before every step
    execution; returning a poisoned batch exercises the retry ladder,
    returning a repaired one exercises recovery."""

    def on_attempt(self, step: int, attempt: int, batch):
        return batch


# ---------------------------------------------------------------------------
# the jitted steps (everything time-varying is data)
# ---------------------------------------------------------------------------

def _bmask(mask: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(n,) mask broadcast against a stacked (n, ...) leaf, as bool."""
    return (mask > 0).reshape((x.shape[0],) + (1,) * (x.ndim - 1))


def _masked_consensus_error(params, alive: jnp.ndarray,
                            n_alive: jnp.ndarray) -> jnp.ndarray:
    """‖x − x̄‖_F over the ALIVE replicas. With the all-ones mask this is
    bit-equal to ``trainer._consensus_error`` (multiplies by 1.0 are exact,
    the reductions are the same); dead nodes' frozen params are excluded so
    churn does not masquerade as divergence."""
    def leaf_err(x):
        m = _bmask(alive, x).astype(x.dtype)
        mean = (x * m).sum(axis=0, keepdims=True) / n_alive.astype(x.dtype)
        return jnp.sum(jnp.square(((x - mean) * m).astype(jnp.float32)))
    return jnp.sqrt(sum(jax.tree.leaves(jax.tree.map(leaf_err, params))))


def make_elastic_train_step(cfg, opt_update: Callable, *,
                            use_kernel: bool = False):
    """The elastic stacked-worker step — ``dsgd_train_step``'s math with the
    fault tensors as arguments:

      step(state, batch, W, alive, link_up, mix_mask[, nbr_idx, nbr_mask])
        → (state, metrics)

    ``W (n,n)`` the incumbent mixing matrix (hot-swap = new array),
    ``alive (n,)`` chaos membership (dead ⇒ params+optimizer freeze),
    ``mix_mask (n,)`` round participation = alive ∧ ¬watchdog-dropped
    (dropped nodes keep their LOCAL update — they are late, not dead),
    ``link_up (n,n)`` packet-loss mask. Mixing runs over
    ``degrade_matrix(W, mix_mask, link_up)`` — row-stochastic on the
    participating subgraph. All-clear masks make every mask op an IEEE-exact
    identity, so the fault-free elastic step is bit-exact vs
    ``dsgd_train_step`` (tested). The kernel path gathers its per-round
    weights from the degraded matrix on device over deg-capped tables, so
    topology swaps stay retrace-free there too.
    """
    loss_fn = _loss_fn(cfg)

    def _step(state: DSGDState, batch, W, alive, link_up, mix_mask,
              nbr_idx=None, nbr_mask=None):
        losses, grads = jax.vmap(jax.value_and_grad(loss_fn))(state.params, batch)
        updates, opt = jax.vmap(opt_update)(grads, state.opt, state.params)
        local = jax.vmap(apply_updates)(state.params, updates)
        W_eff = degrade_matrix(W, mix_mask, link_up)
        if use_kernel:
            from repro.kernels.gossip_mix.ops import gossip_mix_batched

            weights = gather_neighbor_weights(W_eff, nbr_idx, nbr_mask)
            mixed = jax.tree.map(
                lambda x: gossip_mix_batched(x, nbr_idx, weights), local)
        else:
            mixed = gossip_sim_tree(local, W_eff)
        params = jax.tree.map(
            lambda mx, lc, od: jnp.where(
                _bmask(mix_mask, mx), mx, jnp.where(_bmask(alive, lc), lc, od)),
            mixed, local, state.params)
        opt = jax.tree.map(
            lambda nw, od: jnp.where(_bmask(alive, nw), nw, od),
            opt, state.opt)
        n_alive = alive.sum()
        loss = (losses * alive).sum() / n_alive
        loss_max = jnp.where(alive > 0, losses, -jnp.inf).max()
        metrics = {"loss": loss, "loss_max": loss_max,
                   "consensus_err": _masked_consensus_error(params, alive,
                                                            n_alive),
                   "n_alive": n_alive}
        return DSGDState(params, opt, state.step + 1), metrics

    return jax.jit(_step)


def make_elastic_sharded_train_step(cfg, sched: GossipSchedule,
                                    opt_update: Callable, mesh, *,
                                    gossip_axes=("data",)):
    """Elastic variant of ``make_sharded_train_step`` (the production
    ppermute path): schedule weights and membership are DATA —

      step(state, batch, alive, mix_mask, w_self, w_recv) → (state, metrics)

    ``w_self (n,)`` / ``w_recv (rounds, n)`` from
    ``gossip.schedule_weight_arrays`` (a re-polished weight set hot-swaps
    without retrace; a support change rebuilds the schedule and retraces),
    ``alive``/``mix_mask`` as in the stacked step. Dead workers freeze
    params+optimizer on device; dropped stragglers skip the exchange with
    the row-stochastic renorm done inside ``gossip_shard_elastic``.
    """
    axis = gossip_axes if len(gossip_axes) > 1 else gossip_axes[0]
    loss_fn = _loss_fn(cfg)

    def worker(params, opt, batch, step, alive, mix_mask, w_self, w_recv):
        sq = lambda t: jax.tree.map(lambda x: x[0], t)
        un = lambda t: jax.tree.map(lambda x: x[None], t)
        p1, o1 = sq(params), sq(opt)
        b1 = sq(batch)
        loss, grads = jax.value_and_grad(loss_fn)(p1, b1)
        updates, o2 = opt_update(grads, o1, p1)
        p2 = apply_updates(p1, updates)
        pm = gossip_shard_elastic(p2, sched, axis, mix_mask, w_self, w_recv)
        i = jax.lax.axis_index(axis)
        a_i, m_i = alive[i] > 0, mix_mask[i] > 0
        p_out = jax.tree.map(
            lambda mx, lc, od: jnp.where(m_i, mx, jnp.where(a_i, lc, od)),
            pm, p2, p1)
        o_out = jax.tree.map(lambda nw, od: jnp.where(a_i, nw, od), o2, o1)
        a_f = alive[i].astype(jnp.float32)
        loss = jax.lax.psum(loss * a_f, axis) / jax.lax.psum(a_f, axis)
        return un(p_out), un(o_out), loss

    nspec = P(gossip_axes if len(gossip_axes) > 1 else gossip_axes[0])
    smapped = jax.shard_map(
        worker, mesh=mesh,
        in_specs=(nspec, nspec, nspec, P(), P(), P(), P(), P()),
        out_specs=(nspec, nspec, P()),
        axis_names=set(gossip_axes),
        check_vma=False,  # model scan carries flip axis-invariant → varying
    )

    def train_step(state: DSGDState, batch, alive, mix_mask, w_self, w_recv):
        params, opt, loss = smapped(state.params, state.opt, batch, state.step,
                                    alive, mix_mask, w_self, w_recv)
        return DSGDState(params, opt, state.step + 1), {"loss": loss}

    return train_step


# ---------------------------------------------------------------------------
# the runtime (host-side orchestration around the one jitted step)
# ---------------------------------------------------------------------------

class ElasticRuntime:
    """Watchdog + membership + re-optimization around one jitted step.

    ``round()`` never raises on a classified failure: a poisoned round walks
    the retry ladder and, exhausted, freezes the state for that round — the
    ``RoundReport`` carries the full rung trail (``run_ladder`` semantics).
    """

    def __init__(self, cfg, spec: ElasticSpec, topology: Topology,
                 opt_update: Callable, *, use_kernel: bool = False,
                 deg_cap: int | None = None, step_fn=None,
                 hooks: ElasticHooks | None = None):
        if spec.chaos.n != topology.n:
            raise ValueError(f"ChaosSpec is for n={spec.chaos.n} nodes but "
                             f"the topology has n={topology.n}")
        self.cfg = cfg
        self.spec = spec
        self.n = topology.n
        self.use_kernel = use_kernel
        self.deg_cap = deg_cap if deg_cap is not None else max(self.n - 1, 1)
        self.step_fn = step_fn if step_fn is not None else \
            make_elastic_train_step(cfg, opt_update, use_kernel=use_kernel)
        self.hooks = hooks or ElasticHooks()
        self.deadline_ms = spec.deadline_factor * fault_free_round_ms(
            topology, spec.chaos.bandwidth[0], spec.const)

    # -- state ------------------------------------------------------------

    def make_state(self, topology: Topology, seed: int = 0) -> ElasticState:
        ch = self.spec.chaos
        return ElasticState(
            topology=topology,
            W=self._matrix(topology),
            nbr=self._tables(topology),
            detector=DriftDetector.from_profile(ch.bandwidth[0], ch.alive[0],
                                                self.spec.drift),
            key=jax.random.PRNGKey(seed),
        )

    def _matrix(self, topo: Topology) -> jnp.ndarray:
        return jnp.asarray(
            weight_matrix_from_weights(topo.n, topo.edges, topo.g), jnp.float32)

    def _tables(self, topo: Topology):
        if not self.use_kernel:
            return None
        return elastic_neighbor_tables(np.asarray(self._matrix(topo)),
                                       deg_cap=self.deg_cap)

    def _adopt(self, es: ElasticState, topo: Topology, t: int,
               bw: np.ndarray, alive: np.ndarray) -> None:
        es.topology = topo
        es.W = self._matrix(topo)
        es.nbr = self._tables(topo)
        es.detector.rebase(bw, alive)
        es.pending = None
        es.adopted += 1
        es.events.append({"step": t, "event": "adopt", "name": topo.name})

    # -- one round --------------------------------------------------------

    def round(self, state: DSGDState, es: ElasticState, batch
              ) -> tuple[DSGDState, dict, RoundReport]:
        spec, ch = self.spec, self.spec.chaos
        t = int(state.step)
        ti = min(t, ch.steps - 1)
        alive_np = np.asarray(ch.alive[ti]) > 0
        bw_np = np.asarray(ch.bandwidth[ti], np.float64)

        swapped = False
        if es.pending is not None and t >= es.pending[0]:
            self._adopt(es, es.pending[1], t, bw_np, ch.alive[ti])
            swapped = True

        # watchdog: modeled latencies vs the round deadline
        lat = node_step_latency_ms(es.topology, ch, ti, spec.const)
        dropped = np.zeros(self.n, bool)
        if spec.drop_stragglers:
            dropped = alive_np & (lat > self.deadline_ms)
            if dropped.all() or not (alive_np & ~dropped).any():
                dropped[:] = False          # the watchdog cannot drop everyone
        mix_np = (alive_np & ~dropped).astype(np.float32)
        participants = lat[alive_np & ~dropped]
        round_ms = float(participants.max()) if participants.size else 0.0
        if dropped.any():
            # the watchdog waits until the deadline to declare the drop
            round_ms = max(round_ms, self.deadline_ms)
            es.dropped_rounds += 1
            es.drops += int(dropped.sum())

        # bounded retry/backoff ladder (run_ladder semantics: classified
        # rung reports, never raises; terminal rung freezes the round)
        alive_d = jnp.asarray(ch.alive[ti], jnp.float32)
        link_d = jnp.asarray(ch.link_up[ti], jnp.float32)
        mix_d = jnp.asarray(mix_np)
        rungs: list[RungReport] = []
        new_state = metrics = None
        attempts = 0
        for k in range(spec.max_round_retries + 1):
            attempts = k + 1
            ab = self.hooks.on_attempt(t, k, batch)
            cand_state, cand_metrics = self._run(state, ab, es, alive_d,
                                                 link_d, mix_d)
            loss = float(cand_metrics["loss"])
            name = "round" if k == 0 else f"retry{k}"
            if np.isfinite(loss):
                rungs.append(RungReport(name, "ok"))
                new_state, metrics = cand_state, cand_metrics
                break
            rungs.append(RungReport(name, "non_finite", f"loss={loss}"))
            round_ms += round_ms and self.deadline_ms * spec.retry_backoff ** k
        if new_state is None:
            rungs.append(RungReport("freeze", "ok",
                                    "retries exhausted — round skipped, "
                                    "state frozen"))
            new_state = DSGDState(state.params, state.opt, state.step + 1)
            metrics = {"loss": jnp.float32(np.nan),
                       "loss_max": jnp.float32(np.nan),
                       "consensus_err": jnp.float32(np.nan),
                       "n_alive": jnp.float32(alive_np.sum())}

        # drift detection → warm re-optimization → deferred adoption
        reopt_res, reason = None, None
        if spec.reopt and es.pending is None:
            reason = es.detector.check(t, bw_np, ch.alive[ti])
            if reason is not None:
                reopt_res = self._reoptimize(es, t, bw_np, ch.alive[ti], reason)

        es.data_step += 1
        es.key = jax.random.fold_in(es.key, t)
        report = RoundReport(step=t, alive=alive_np, dropped=dropped,
                             round_ms=round_ms, deadline_ms=self.deadline_ms,
                             attempts=attempts, rungs=rungs, reopt=reopt_res,
                             reopt_reason=reason, swapped=swapped)
        return new_state, metrics, report

    def _run(self, state, batch, es: ElasticState, alive, link_up, mix):
        if self.use_kernel:
            return self.step_fn(state, batch, es.W, alive, link_up, mix,
                                es.nbr[0], es.nbr[1])
        return self.step_fn(state, batch, es.W, alive, link_up, mix)

    def _reoptimize(self, es: ElasticState, t: int, bw: np.ndarray,
                    alive, reason: str) -> ReoptResult:
        spec = self.spec
        budget_ms = None
        if spec.reopt_budget is not None:
            if spec.reopt_budget == "window":
                budget_ms = (max(spec.activation_lag_steps, 1)
                             * fault_free_round_ms(es.topology, bw, spec.const))
            else:
                budget_ms = float(spec.reopt_budget)
        res = reoptimize_topology(
            es.topology, scenario=spec.reopt_scenario,
            node_bandwidths=bw if spec.reopt_scenario == "node" else None,
            r=spec.reopt_r, alive=np.asarray(alive), cfg=spec.topo_cfg,
            policy=spec.drift, budget_ms=budget_ms)
        es.reopts += 1
        if res.reoptimized:
            es.pending = (t + max(spec.activation_lag_steps, 1), res.topology)
            es.events.append({"step": t, "event": "reopt", "reason": reason,
                              "time_to_reopt_s": res.time_to_reopt_s,
                              "r_asym_after": res.r_asym_after})
        else:
            es.events.append({"step": t, "event": "keep_incumbent",
                              "reason": res.fallback_reason})
        return res

    # -- crash-safe resume (checkpoint extras payload) --------------------

    def to_extras(self, es: ElasticState) -> dict[str, np.ndarray]:
        """ElasticState → named arrays for ``CheckpointManager.save(extra=)``.
        Everything here is exactly what ``from_extras`` needs to continue
        the run bit-exactly: topology support+weights (edge counts change
        across reopts, hence the shape-free extras channel), detector
        baselines, pending adoption, PRNG key, stream position, counters."""
        topo = es.topology
        out = {
            "edges": np.asarray(topo.edges, np.int64).reshape(-1, 2),
            "g": np.asarray(topo.g, np.float64),
            **es.detector.to_state(),
            "key": np.asarray(es.key),
            "data_step": np.asarray(es.data_step, np.int64),
            "counters": np.asarray([es.reopts, es.adopted, es.dropped_rounds,
                                    es.drops], np.int64),
            "pending_step": np.asarray(
                -1 if es.pending is None else es.pending[0], np.int64),
        }
        if es.pending is not None:
            ptopo = es.pending[1]
            out["pending_edges"] = np.asarray(ptopo.edges,
                                              np.int64).reshape(-1, 2)
            out["pending_g"] = np.asarray(ptopo.g, np.float64)
        return out

    def from_extras(self, extras: dict[str, np.ndarray],
                    name: str = "resumed") -> ElasticState:
        """Rebuild the ElasticState a checkpoint carried (inverse of
        ``to_extras``)."""
        edges = [tuple(int(v) for v in e) for e in extras["edges"]]
        topo = Topology(self.n, edges, np.asarray(extras["g"]), name=name)
        det = DriftDetector.from_state(extras, self.spec.drift)
        reopts, adopted, dropped_rounds, drops = (
            int(v) for v in extras["counters"])
        pending = None
        p_step = int(extras["pending_step"])
        if p_step >= 0:
            p_edges = [tuple(int(v) for v in e)
                       for e in extras["pending_edges"]]
            pending = (p_step, Topology(self.n, p_edges,
                                        np.asarray(extras["pending_g"]),
                                        name=name + "-pending"))
        return ElasticState(
            topology=topo, W=self._matrix(topo), nbr=self._tables(topo),
            detector=det, key=jnp.asarray(extras["key"]),
            data_step=int(extras["data_step"]), pending=pending,
            reopts=reopts, adopted=adopted, dropped_rounds=dropped_rounds,
            drops=drops)
