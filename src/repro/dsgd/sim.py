"""Device-resident DSGD evaluation engine (paper §VI — Table II, Figs 7–10).

Mirrors the ``core/engine.py`` architecture for the *training-side*
evaluation loop: where the seed benchmark ran a host Python loop per
training iteration (one jitted step dispatch + a host-side ``jnp.stack``
batch assembly per step, one ``float()`` sync per epoch, serial per
topology), this module compiles the entire run into one device program:

  - ``train_curve``          — jitted ``lax.scan`` over epochs with an inner
    scan over iterations; minibatches are GATHERED inside the scan
    (``X[idx]``) from the device-resident dataset via the precomputed
    ``(epochs, iters, n, batch)`` permutation tensor
    (``repro.data.epoch_permutations``), and the mean-model test accuracy is
    evaluated at epoch boundaries inside the scan — zero host round-trips
    between epochs.
  - ``accuracy_curves``      — every topology trains the same model on the
    same data with the same hyperparameters, so the ``(n, n)`` gossip
    matrices are stacked ``(T, n, n)`` and the WHOLE training run is
    ``jax.vmap``-ed across topologies: the serial per-topology loop of the
    benchmark becomes one batched device call.
  - ``accuracy_curves_seeds``— same trick one axis up: vmap over seeds
    (per-seed init + batch order) × topologies in one dispatch.
  - ``accuracy_curve_host``  — the seed per-iteration host loop, kept
    verbatim as the ``engine="host"`` fallback and the parity oracle
    (identical batch order by construction: both consume
    ``epoch_permutations``'s numpy stream).

The model is the benchmark's 2-layer-MLP CIFAR stand-in (``init_mlp`` /
``mlp_logits`` / ``mlp_loss``), exposed here so benchmarks and tests share
one definition. See DESIGN.md §11.

Cross-product engine (DESIGN.md §12): the same scan architecture extended to
the full scenario cross-product {static, dynamic round-robin} × {dense,
top-k CHOCO, random-k CHOCO}. Topology cycles are stacked ``(R, n, n)``
tensors (``repro.dsgd.dynamic.stack_cycles``) and the per-step matrix is a
step-index GATHER inside the scan (``select_cycle_matrix`` — no
``lax.switch`` host branches, so topologies with different cycle lengths
vmap together); CHOCO's error-feedback state (x̂ per parameter leaf, PRNG
key for random-k) rides the scan carry while γ is a vmapped data leaf.

  - ``train_curves_cross``     — time-to-accuracy for B = (topology-cycle, γ)
    runs in one vmapped dispatch; batch order bit-identical to the host
    loops (same ``epoch_permutations`` stream).
  - ``consensus_curves_cross`` — consensus-error curves x ← mix(x) for the
    same cross product (the §VI-A-style workload of bench_dynamic /
    bench_compression), one dispatch.
  - ``accuracy_curve_host_cross`` / ``consensus_curve_host_cross`` — the
    per-iteration host loops (one dispatch + host sync per step), kept as
    the ``engine="host"`` fallbacks and parity oracles. They share the mix
    helper and key-split stream with the scan engine, so parity is exact up
    to scan-vs-loop float reassociation.

Chaos engine (DESIGN.md §14): the cross-product engine under injected
faults. A :class:`repro.dsgd.chaos.ChaosSpec` provides per-step node-alive
masks and link-drop draws; each step the selected cycle matrix is
renormalized on device (``degrade_matrix`` — lost mass folds into self
weights, row-stochastic on the alive subgraph) and dead nodes are frozen at
their last state with a ``where(alive, ...)`` after the mix, so they rejoin
at exactly the params they left with. The fault tensors ride the scan as
per-step data leaves next to the batch-index stream, vmapped across runs —
one dispatch for the whole fault × {static, dynamic} × {dense, CHOCO} cross
product. A fault-free spec is a bit-exact no-op versus the fault-less
engine (the degradation arithmetic is IEEE-exact under all-clear masks).

  - ``train_curves_chaos`` / ``consensus_curves_chaos`` — the vmapped scan
    engines; ``chaos`` is one shared ChaosSpec or one per run.
  - ``accuracy_curve_host_chaos`` / ``consensus_curve_host_chaos`` — the
    per-iteration host loops, fallback + parity oracles (≤ 1e-6, tested).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.data import epoch_permutations

from .compression import (
    Compressor,
    choco_mix,
    compress_random_k,
    compress_top_k,
    compression_ratio,
    identity_compressor,
    random_k_compressor,
    top_k_compressor,
)
from .chaos import ChaosSpec, degrade_matrix
from .dynamic import stack_cycles
from .gossip import gossip_sim_tree, select_cycle_matrix

__all__ = [
    "DSGDSimConfig", "init_mlp", "mlp_logits", "mlp_loss",
    "train_curve", "accuracy_curves", "accuracy_curves_seeds",
    "accuracy_curve_host",
    "CommSpec", "train_curves_cross", "accuracy_curve_host_cross",
    "consensus_curves_cross", "consensus_curve_host_cross",
    "train_curves_chaos", "accuracy_curve_host_chaos",
    "consensus_curves_chaos", "consensus_curve_host_chaos",
]


@dataclass(frozen=True)
class DSGDSimConfig:
    """Hyperparameters of the §VI-B time-to-accuracy protocol."""
    epochs: int = 30
    batch: int = 32
    lr: float = 0.05
    momentum: float = 0.9
    hidden: int = 128
    seed: int = 0


# ---------------------------------------------------------------------------
# model: 2-layer MLP on the Gaussian-mixture task (CIFAR-10 stand-in)
# ---------------------------------------------------------------------------

def init_mlp(key, dim: int, hidden: int, classes: int) -> dict:
    """Explicitly float32: with the solver's ``jax_enable_x64`` active, the
    dtype-less seed init silently promoted the whole training loop to f64
    (~2× slower per step on CPU for identical curves)."""
    k1, k2 = jax.random.split(key)
    s1 = 1.0 / np.sqrt(dim)
    s2 = 1.0 / np.sqrt(hidden)
    return {"w1": jax.random.uniform(k1, (dim, hidden), jnp.float32,
                                     minval=-s1, maxval=s1),
            "b1": jnp.zeros((hidden,), jnp.float32),
            "w2": jax.random.uniform(k2, (hidden, classes), jnp.float32,
                                     minval=-s2, maxval=s2),
            "b2": jnp.zeros((classes,), jnp.float32)}


def mlp_logits(p, x):
    return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def mlp_loss(p, x, y):
    lp = jax.nn.log_softmax(mlp_logits(p, x))
    return -jnp.mean(jnp.take_along_axis(lp, y[:, None], axis=1))


def _init_worker_state(n: int, dim: int, classes: int, cfg: DSGDSimConfig):
    """All workers start from identical params (standard DSGD init)."""
    p0 = init_mlp(jax.random.PRNGKey(cfg.seed), dim, cfg.hidden, classes)
    params = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), p0)
    mom = jax.tree.map(jnp.zeros_like, params)
    return params, mom


# ---------------------------------------------------------------------------
# scan-compiled core
# ---------------------------------------------------------------------------

def _train_curve_impl(W, X, y, Xte, yte, perm, params, mom, lr, momentum):
    """One full DSGD run → per-epoch mean-model accuracy (epochs,).

    W (n, n); X (N, d)/y (N,) device-resident train set; Xte/yte test split;
    perm (epochs, iters, n, batch) gather indices; params/mom stacked
    (n, ...) worker state. Pure — jit/vmap applied by the public wrappers.
    """
    grad_fn = jax.vmap(jax.grad(mlp_loss))

    def it_body(carry, idx):                      # idx: (n, batch)
        params, mom = carry
        xb, yb = X[idx], y[idx]                   # on-device batch gather
        g = grad_fn(params, xb, yb)
        mom = jax.tree.map(lambda m, gg: momentum * m + gg, mom, g)
        params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        params = gossip_sim_tree(params, W)
        return (params, mom), None

    def epoch_body(carry, perm_e):                # perm_e: (iters, n, batch)
        carry, _ = lax.scan(it_body, carry, perm_e)
        mean = jax.tree.map(lambda a: a.mean(axis=0), carry[0])
        pred = jnp.argmax(mlp_logits(mean, Xte), axis=1)
        return carry, jnp.mean(pred == yte)

    _, accs = lax.scan(epoch_body, (params, mom), perm)
    return accs


_train_curve_jit = jax.jit(_train_curve_impl)
# topologies share data/init/batch order → only W is batched
_train_curves_vmapped = jax.jit(jax.vmap(
    _train_curve_impl,
    in_axes=(0, None, None, None, None, None, None, None, None, None)))
# seeds batch the init AND the batch order on top of the topology axis
_train_curves_seeds_vmapped = jax.jit(jax.vmap(
    jax.vmap(_train_curve_impl,
             in_axes=(0, None, None, None, None, None, None, None, None, None)),
    in_axes=(None, None, None, None, None, 0, 0, 0, None, None)))


def train_curve(W, X, y, Xte, yte, perm, cfg: DSGDSimConfig = DSGDSimConfig()):
    """Scan-compiled run for ONE topology; returns accs (epochs,)."""
    n = W.shape[-1]
    classes = int(np.asarray(y).max()) + 1
    params, mom = _init_worker_state(n, X.shape[-1], classes, cfg)
    return _train_curve_jit(W, X, y, Xte, yte, jnp.asarray(perm), params, mom,
                            cfg.lr, cfg.momentum)


def accuracy_curves(Ws, X, y, parts, Xte, yte,
                    cfg: DSGDSimConfig = DSGDSimConfig()):
    """Train ALL topologies in one batched device call.

    Ws: (T, n, n) stacked gossip matrices (or (n, n) for a single run).
    Returns (accs (T, epochs) [or (epochs,)], iters_per_epoch).
    """
    Ws = jnp.asarray(Ws, jnp.float32)
    n = Ws.shape[-1]
    perm = jnp.asarray(epoch_permutations(parts, cfg.epochs, cfg.batch,
                                          seed=cfg.seed))
    iters = perm.shape[1]
    classes = int(np.asarray(y).max()) + 1
    params, mom = _init_worker_state(n, X.shape[-1], classes, cfg)
    fn = _train_curve_jit if Ws.ndim == 2 else _train_curves_vmapped
    accs = fn(Ws, X, y, Xte, yte, perm, params, mom, cfg.lr, cfg.momentum)
    return accs, iters


def accuracy_curves_seeds(Ws, X, y, parts, Xte, yte, seeds,
                          cfg: DSGDSimConfig = DSGDSimConfig()):
    """Seeds × topologies in one dispatch; returns (accs (S, T, epochs), iters).

    Each seed draws its own init and batch order (the §VI-B repeat-runs
    protocol); topologies within a seed share both.
    """
    Ws = jnp.asarray(Ws, jnp.float32)
    n = Ws.shape[-1]
    classes = int(np.asarray(y).max()) + 1
    perms, params, moms = [], [], []
    for s in seeds:
        c = dataclasses.replace(cfg, seed=int(s))
        perms.append(epoch_permutations(parts, c.epochs, c.batch, seed=c.seed))
        p, m = _init_worker_state(n, X.shape[-1], classes, c)
        params.append(p)
        moms.append(m)
    perm = jnp.asarray(np.stack(perms))
    stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
    accs = _train_curves_seeds_vmapped(Ws, X, y, Xte, yte, perm,
                                       stack(params), stack(moms),
                                       cfg.lr, cfg.momentum)
    return accs, perm.shape[2]


# ---------------------------------------------------------------------------
# host-loop oracle (the seed benchmark path, verbatim)
# ---------------------------------------------------------------------------

def accuracy_curve_host(W, X, y, parts, Xte, yte,
                        cfg: DSGDSimConfig = DSGDSimConfig()):
    """Per-iteration host loop: one jitted step dispatch + host ``jnp.stack``
    batch assembly per step, one accuracy sync per epoch — the ``engine="host"``
    fallback and the parity oracle for :func:`accuracy_curves`.

    Consumes the SAME ``epoch_permutations`` index stream as the scan engine,
    so batch order is identical given a seed. Returns (accs (epochs,), iters).
    """
    W = jnp.asarray(W, jnp.float32)
    n = W.shape[-1]
    classes = int(np.asarray(y).max()) + 1
    params, mom = _init_worker_state(n, X.shape[-1], classes, cfg)
    lr, momentum = cfg.lr, cfg.momentum

    grad_fn = jax.vmap(jax.grad(mlp_loss))

    @jax.jit
    def step(params, mom, xb, yb):
        g = grad_fn(params, xb, yb)
        mom = jax.tree.map(lambda m, gg: momentum * m + gg, mom, g)
        params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        params = gossip_sim_tree(params, W)
        return params, mom

    @jax.jit
    def accuracy(params):
        mean = jax.tree.map(lambda a: a.mean(axis=0), params)
        pred = jnp.argmax(mlp_logits(mean, Xte), axis=1)
        return jnp.mean(pred == yte)

    perm = epoch_permutations(parts, cfg.epochs, cfg.batch, seed=cfg.seed)
    iters = perm.shape[1]
    accs = []
    for e in range(cfg.epochs):
        for it in range(iters):
            idx = perm[e, it]                     # (n, batch)
            # per-worker device gathers + host jnp.stack, as the seed bench
            xb = jnp.stack([X[idx[w]] for w in range(n)])
            yb = jnp.stack([y[idx[w]] for w in range(n)])
            params, mom = step(params, mom, xb, yb)
        accs.append(float(accuracy(params)))
    return np.asarray(accs), iters


# ---------------------------------------------------------------------------
# cross-product engine: {static, dynamic cycle} × {dense, CHOCO compressors}
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CommSpec:
    """Static (hashable → jit-cache key) half of the communication config.

    ``compressor`` ∈ {"dense", "top_k", "random_k"}: dense applies x ← W_t x
    directly; the CHOCO modes gossip on compressed-innovation estimates with
    the error-feedback state threaded through the scan carry. ``frac`` is the
    kept fraction (fixes the static k of ``lax.top_k``). The data half — the
    cycle tensor, cycle length R, and γ — is vmapped, so one compiled variant
    per CommSpec serves every topology × γ grid point.
    """
    compressor: str = "dense"
    frac: float = 1.0

    def __post_init__(self):
        if self.compressor not in ("dense", "top_k", "random_k"):
            raise ValueError(f"unknown compressor {self.compressor!r}")

    @property
    def choco(self) -> bool:
        return self.compressor != "dense"

    @property
    def ratio(self) -> float:
        """Transmitted fraction ω of the dense bytes (Eq. 34 time scaling)."""
        return 1.0 if not self.choco else compression_ratio(self.frac)

    @property
    def name(self) -> str:
        if not self.choco:
            return "dense"
        tag = "top" if self.compressor == "top_k" else "rand"
        return f"{tag}{int(self.frac * 100)}%"

    def to_compressor(self) -> Compressor:
        """The equivalent host-loop :class:`Compressor` (oracle paths)."""
        if not self.choco:
            return identity_compressor()
        if self.compressor == "top_k":
            return top_k_compressor(self.frac)
        return random_k_compressor(self.frac)


def _mix_pytree(spec: CommSpec, x, hat, W, gamma, key):
    """One CHOCO exchange on stacked ``(n, ...)`` pytrees → (x', x̂').

    Leaves are processed in ``jax.tree.flatten`` order with per-leaf keys
    ``fold_in(key, leaf_index)`` — the host oracles reuse this function, so
    engine/oracle parity is by construction, not by re-derivation.
    """
    leaves, tdef = jax.tree.flatten(x)
    hat_leaves = jax.tree.leaves(hat)
    out_x, out_h = [], []
    for i, (xl, hl) in enumerate(zip(leaves, hat_leaves)):
        if spec.compressor == "top_k":
            q = compress_top_k(xl - hl, spec.frac)
        else:
            q = compress_random_k(xl - hl, spec.frac,
                                  jax.random.fold_in(key, i))
        hl = hl + q
        out_x.append(choco_mix(xl, hl, W, gamma))
        out_h.append(hl)
    return jax.tree.unflatten(tdef, out_x), jax.tree.unflatten(tdef, out_h)


def _train_cross_impl(Wc, R, gamma, X, y, Xte, yte, perm, params, mom, key0,
                      lr, momentum, *, spec: CommSpec):
    """One cross-product DSGD run → per-epoch mean-model accuracy (epochs,).

    Wc (R_max, n, n) padded cycle tensor; R () int32 true cycle length;
    gamma () CHOCO step size (ignored for dense); key0 the compressor PRNG
    stream head. The global step counter t rides the carry so the gossip
    matrix of iteration t is the gather Wc[t % R] — bit-identical to the
    host rule (``gossip_shard_dynamic``'s ``step % R``). Pure — jit/vmap
    applied by the cached wrappers.
    """
    grad_fn = jax.vmap(jax.grad(mlp_loss))

    def it_body(carry, idx):                      # idx: (n, batch)
        if spec.choco:
            params, mom, hat, t, key = carry
        else:
            params, mom, t = carry
        xb, yb = X[idx], y[idx]                   # on-device batch gather
        g = grad_fn(params, xb, yb)
        mom = jax.tree.map(lambda m, gg: momentum * m + gg, mom, g)
        params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        W = select_cycle_matrix(Wc, R, t)
        if spec.choco:
            key, sub = jax.random.split(key)
            params, hat = _mix_pytree(spec, params, hat, W, gamma, sub)
            return (params, mom, hat, t + 1, key), None
        params = gossip_sim_tree(params, W.astype(jnp.float32))
        return (params, mom, t + 1), None

    def epoch_body(carry, perm_e):                # perm_e: (iters, n, batch)
        carry, _ = lax.scan(it_body, carry, perm_e)
        mean = jax.tree.map(lambda a: a.mean(axis=0), carry[0])
        pred = jnp.argmax(mlp_logits(mean, Xte), axis=1)
        return carry, jnp.mean(pred == yte)

    t0 = jnp.int32(0)
    if spec.choco:
        hat = jax.tree.map(jnp.zeros_like, params)
        init = (params, mom, hat, t0, key0)
    else:
        init = (params, mom, t0)
    _, accs = lax.scan(epoch_body, init, perm)
    return accs


@functools.lru_cache(maxsize=None)
def _cross_train_fns(spec: CommSpec):
    # batched over (cycle tensor, cycle length, γ); data/init/batch order and
    # the compressor key stream are shared across the whole cross product
    impl = functools.partial(_train_cross_impl, spec=spec)
    return jax.jit(jax.vmap(impl, in_axes=(0, 0, 0) + (None,) * 10))


def train_curves_cross(cycles, gammas, spec: CommSpec, X, y, parts, Xte, yte,
                       cfg: DSGDSimConfig = DSGDSimConfig()):
    """Train B = len(cycles) cross-product runs in ONE batched device call.

    ``cycles``: list of (R_b, n, n) arrays — ``static_cycle(W)`` for static
    topologies, ``cycle_tensor(topo)`` for round-robin dynamic ones; lengths
    may differ (padded + gathered, never branched). ``gammas``: (B,) CHOCO
    step sizes, ignored for dense. Batch order is bit-identical to the host
    loops (same ``epoch_permutations`` stream); the compressor key stream is
    ``PRNGKey(cfg.seed + 1)``, split once per iteration.
    Returns (accs (B, epochs), iters_per_epoch).
    """
    Wc, R = stack_cycles(cycles)
    Wc = jnp.asarray(Wc, jnp.float32)
    n = Wc.shape[-1]
    perm = jnp.asarray(epoch_permutations(parts, cfg.epochs, cfg.batch,
                                          seed=cfg.seed))
    classes = int(np.asarray(y).max()) + 1
    params, mom = _init_worker_state(n, X.shape[-1], classes, cfg)
    key0 = jax.random.PRNGKey(cfg.seed + 1)
    gammas = jnp.asarray(gammas, jnp.float32)
    accs = _cross_train_fns(spec)(Wc, jnp.asarray(R), gammas, X, y, Xte, yte, perm,
                   params, mom, key0, cfg.lr, cfg.momentum)
    return accs, perm.shape[1]


def accuracy_curve_host_cross(cycle, gamma, spec: CommSpec, X, y, parts,
                              Xte, yte, cfg: DSGDSimConfig = DSGDSimConfig()):
    """Per-iteration host loop for ONE cross-product run — the
    ``engine="host"`` fallback and the parity oracle of
    :func:`train_curves_cross`.

    Same batch order (``epoch_permutations``), same host-side cycle rule
    ``cycle[t % R]``, same mix helper and per-iteration key split as the
    scan engine. Returns (accs (epochs,), iters).
    """
    cycle = [jnp.asarray(W, jnp.float32) for W in np.asarray(cycle)]
    n = cycle[0].shape[-1]
    classes = int(np.asarray(y).max()) + 1
    params, mom = _init_worker_state(n, X.shape[-1], classes, cfg)
    hat = jax.tree.map(jnp.zeros_like, params)
    key = jax.random.PRNGKey(cfg.seed + 1)
    lr, momentum = cfg.lr, cfg.momentum
    gamma = jnp.float32(gamma)

    grad_fn = jax.vmap(jax.grad(mlp_loss))

    @jax.jit
    def step(params, mom, hat, xb, yb, W, sub):
        g = grad_fn(params, xb, yb)
        mom = jax.tree.map(lambda m, gg: momentum * m + gg, mom, g)
        params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        if spec.choco:
            params, hat = _mix_pytree(spec, params, hat, W, gamma, sub)
        else:
            params = gossip_sim_tree(params, W)
        return params, mom, hat

    @jax.jit
    def accuracy(params):
        mean = jax.tree.map(lambda a: a.mean(axis=0), params)
        pred = jnp.argmax(mlp_logits(mean, Xte), axis=1)
        return jnp.mean(pred == yte)

    perm = epoch_permutations(parts, cfg.epochs, cfg.batch, seed=cfg.seed)
    iters = perm.shape[1]
    accs = []
    t = 0
    for e in range(cfg.epochs):
        for it in range(iters):
            idx = perm[e, it]                     # (n, batch)
            xb = jnp.stack([X[idx[w]] for w in range(n)])
            yb = jnp.stack([y[idx[w]] for w in range(n)])
            key, sub = jax.random.split(key)
            params, mom, hat = step(params, mom, hat, xb, yb,
                                    cycle[t % len(cycle)], sub)
            t += 1
        accs.append(float(accuracy(params)))
    return np.asarray(accs), iters


def _consensus_cross_impl(Wc, R, gamma, x0, key0, ts, *, spec: CommSpec):
    """Consensus-error curve of one cross-product run → errors (iters+1,).

    x ← W_t x (dense) or one CHOCO step (compressed) per iteration, with the
    consensus error ‖x − x̄‖ recorded on device — zero host round-trips.
    """
    def step(carry, t):
        W = select_cycle_matrix(Wc, R, t)
        if spec.choco:
            x, hat, key = carry
            key, sub = jax.random.split(key)
            x, hat = _mix_pytree(spec, x, hat, W, gamma, sub)
            carry = (x, hat, key)
        else:
            x = W @ carry
            carry = x
        return carry, jnp.linalg.norm(x - x.mean(axis=0, keepdims=True))

    e0 = jnp.linalg.norm(x0 - x0.mean(axis=0, keepdims=True))
    init = (x0, jnp.zeros_like(x0), key0) if spec.choco else x0
    _, errs = lax.scan(step, init, ts)
    return jnp.concatenate([e0[None], errs])


@functools.lru_cache(maxsize=None)
def _cross_consensus_fns(spec: CommSpec):
    impl = functools.partial(_consensus_cross_impl, spec=spec)
    return jax.jit(jax.vmap(impl, in_axes=(0, 0, 0, None, None, None)))


def consensus_curves_cross(cycles, gammas, spec: CommSpec, x0, iters: int,
                           seed: int = 0):
    """Consensus curves for B = len(cycles) runs in ONE batched device call.

    Shared x0 (n, dim) across runs (the host benches draw one initial value
    per comparison); compressor key stream ``PRNGKey(seed + 1)``. Returns
    errors (B, iters+1) as numpy.
    """
    Wc, R = stack_cycles(cycles)
    x0 = jnp.asarray(x0)
    Wc = jnp.asarray(Wc, x0.dtype)
    gammas = jnp.asarray(gammas, x0.dtype)
    key0 = jax.random.PRNGKey(seed + 1)
    errs = _cross_consensus_fns(spec)(Wc, jnp.asarray(R), gammas, x0, key0, jnp.arange(iters))
    return np.asarray(errs)


# ---------------------------------------------------------------------------
# chaos engine: the cross product under injected faults (DESIGN.md §14)
# ---------------------------------------------------------------------------

def _freeze_tree(alive_t, new, old):
    """``where(alive, new, old)`` over stacked ``(n, ...)`` pytrees — dead
    nodes keep their previous state bit-for-bit (freeze/rejoin semantics)."""
    keep = alive_t > 0

    def sel(a, b):
        return jnp.where(keep.reshape((keep.shape[0],) + (1,) * (a.ndim - 1)),
                         a, b)

    return jax.tree.map(sel, new, old)


def _stack_chaos(chaos, runs: int, steps: int, n: int):
    """Per-run (alive, link_up) device tensors, truncated to ``steps``.

    ``chaos``: one ChaosSpec shared by every run, or a sequence of one per
    run. Each spec must cover ≥ ``steps`` iterations on exactly n nodes.
    """
    specs = [chaos] * runs if isinstance(chaos, ChaosSpec) else list(chaos)
    if len(specs) != runs:
        raise ValueError(f"got {len(specs)} ChaosSpecs for {runs} runs")
    for s in specs:
        if s.n != n:
            raise ValueError(f"ChaosSpec is for n={s.n}, engine runs n={n}")
        if s.steps < steps:
            raise ValueError(f"ChaosSpec covers {s.steps} steps, run needs "
                             f"{steps}")
    alive = jnp.asarray(np.stack([s.alive[:steps] for s in specs]),
                        jnp.float32)
    link = jnp.asarray(np.stack([s.link_up[:steps] for s in specs]),
                       jnp.float32)
    return alive, link


def _train_chaos_impl(Wc, R, gamma, alive, link_up, X, y, Xte, yte, perm,
                      params, mom, key0, lr, momentum, *, spec: CommSpec):
    """One cross-product DSGD run under faults → per-epoch accuracy (epochs,).

    ``alive (epochs, iters, n)`` / ``link_up (epochs, iters, n, n)`` ride the
    scan next to the batch-index stream. Each step the cycle matrix is
    degraded on device and dead nodes are frozen (no gradient step, no mix)
    at their pre-step state, rejoining at exactly their last params. With
    all-clear masks every extra op is IEEE-exact, so the fault-free run is
    bit-equal to ``_train_cross_impl``.
    """
    grad_fn = jax.vmap(jax.grad(mlp_loss))

    def it_body(carry, xs):
        idx, alive_t, link_t = xs                 # (n, batch), (n,), (n, n)
        if spec.choco:
            params, mom, hat, t, key = carry
        else:
            params, mom, t = carry
        xb, yb = X[idx], y[idx]                   # on-device batch gather
        g = grad_fn(params, xb, yb)
        mom_new = jax.tree.map(lambda m, gg: momentum * m + gg, mom, g)
        p_new = jax.tree.map(lambda p, m: p - lr * m, params, mom_new)
        W = degrade_matrix(select_cycle_matrix(Wc, R, t), alive_t, link_t)
        if spec.choco:
            key, sub = jax.random.split(key)
            p_mix, hat_new = _mix_pytree(spec, p_new, hat, W, gamma, sub)
            params = _freeze_tree(alive_t, p_mix, params)
            mom = _freeze_tree(alive_t, mom_new, mom)
            hat = _freeze_tree(alive_t, hat_new, hat)
            return (params, mom, hat, t + 1, key), None
        p_mix = gossip_sim_tree(p_new, W.astype(jnp.float32))
        params = _freeze_tree(alive_t, p_mix, params)
        mom = _freeze_tree(alive_t, mom_new, mom)
        return (params, mom, t + 1), None

    def epoch_body(carry, xs):
        carry, _ = lax.scan(it_body, carry, xs)
        mean = jax.tree.map(lambda a: a.mean(axis=0), carry[0])
        pred = jnp.argmax(mlp_logits(mean, Xte), axis=1)
        return carry, jnp.mean(pred == yte)

    t0 = jnp.int32(0)
    if spec.choco:
        hat = jax.tree.map(jnp.zeros_like, params)
        init = (params, mom, hat, t0, key0)
    else:
        init = (params, mom, t0)
    _, accs = lax.scan(epoch_body, init, (perm, alive, link_up))
    return accs


@functools.lru_cache(maxsize=None)
def _chaos_train_fns(spec: CommSpec):
    # batched over (cycle, length, γ, alive, link_up) — each run carries its
    # own fault realization; data/init/batch order/key stream are shared
    impl = functools.partial(_train_chaos_impl, spec=spec)
    return jax.jit(jax.vmap(impl, in_axes=(0, 0, 0, 0, 0) + (None,) * 10))


def train_curves_chaos(cycles, gammas, spec: CommSpec, chaos, X, y, parts,
                       Xte, yte, cfg: DSGDSimConfig = DSGDSimConfig()):
    """``train_curves_cross`` under injected faults — ONE vmapped dispatch.

    ``chaos``: a :class:`~repro.dsgd.chaos.ChaosSpec` shared by all runs or
    a sequence with one spec per run (each covering ≥ epochs × iters steps).
    Dead nodes freeze and rejoin at their last params; a fault-free spec
    reproduces :func:`train_curves_cross` bit-exactly (tested). Returns
    (accs (B, epochs), iters_per_epoch).
    """
    Wc, R = stack_cycles(cycles)
    Wc = jnp.asarray(Wc, jnp.float32)
    n = Wc.shape[-1]
    perm = jnp.asarray(epoch_permutations(parts, cfg.epochs, cfg.batch,
                                          seed=cfg.seed))
    iters = perm.shape[1]
    alive, link = _stack_chaos(chaos, len(cycles), cfg.epochs * iters, n)
    alive = alive.reshape(len(cycles), cfg.epochs, iters, n)
    link = link.reshape(len(cycles), cfg.epochs, iters, n, n)
    classes = int(np.asarray(y).max()) + 1
    params, mom = _init_worker_state(n, X.shape[-1], classes, cfg)
    key0 = jax.random.PRNGKey(cfg.seed + 1)
    gammas = jnp.asarray(gammas, jnp.float32)
    accs = _chaos_train_fns(spec)(Wc, jnp.asarray(R), gammas, alive, link,
                                  X, y, Xte, yte, perm, params, mom, key0,
                                  cfg.lr, cfg.momentum)
    return accs, iters


def accuracy_curve_host_chaos(cycle, gamma, spec: CommSpec, chaos: ChaosSpec,
                              X, y, parts, Xte, yte,
                              cfg: DSGDSimConfig = DSGDSimConfig()):
    """Per-iteration host loop for ONE chaos run — the ``engine="host"``
    fallback and parity oracle of :func:`train_curves_chaos`.

    Fault tensors are indexed on host (``chaos.alive[t]``); the jitted step
    shares ``degrade_matrix``, the mix helpers, and the freeze rule with the
    scan engine. Returns (accs (epochs,), iters).
    """
    cycle = [jnp.asarray(W, jnp.float32) for W in np.asarray(cycle)]
    n = cycle[0].shape[-1]
    classes = int(np.asarray(y).max()) + 1
    params, mom = _init_worker_state(n, X.shape[-1], classes, cfg)
    hat = jax.tree.map(jnp.zeros_like, params)
    key = jax.random.PRNGKey(cfg.seed + 1)
    lr, momentum = cfg.lr, cfg.momentum
    gamma = jnp.float32(gamma)

    grad_fn = jax.vmap(jax.grad(mlp_loss))

    @jax.jit
    def step(params, mom, hat, xb, yb, W, alive_t, link_t, sub):
        g = grad_fn(params, xb, yb)
        mom_new = jax.tree.map(lambda m, gg: momentum * m + gg, mom, g)
        p_new = jax.tree.map(lambda p, m: p - lr * m, params, mom_new)
        Wd = degrade_matrix(W, alive_t, link_t)
        if spec.choco:
            p_mix, hat_new = _mix_pytree(spec, p_new, hat, Wd, gamma, sub)
        else:
            p_mix, hat_new = gossip_sim_tree(p_new, Wd), hat
        return (_freeze_tree(alive_t, p_mix, params),
                _freeze_tree(alive_t, mom_new, mom),
                _freeze_tree(alive_t, hat_new, hat))

    @jax.jit
    def accuracy(params):
        mean = jax.tree.map(lambda a: a.mean(axis=0), params)
        pred = jnp.argmax(mlp_logits(mean, Xte), axis=1)
        return jnp.mean(pred == yte)

    perm = epoch_permutations(parts, cfg.epochs, cfg.batch, seed=cfg.seed)
    iters = perm.shape[1]
    alive, link = _stack_chaos(chaos, 1, cfg.epochs * iters, n)
    alive, link = alive[0], link[0]
    accs = []
    t = 0
    for e in range(cfg.epochs):
        for it in range(iters):
            idx = perm[e, it]                     # (n, batch)
            xb = jnp.stack([X[idx[w]] for w in range(n)])
            yb = jnp.stack([y[idx[w]] for w in range(n)])
            key, sub = jax.random.split(key)
            params, mom, hat = step(params, mom, hat, xb, yb,
                                    cycle[t % len(cycle)],
                                    alive[t], link[t], sub)
            t += 1
        accs.append(float(accuracy(params)))
    return np.asarray(accs), iters


def _consensus_chaos_impl(Wc, R, gamma, alive, link_up, x0, key0, ts,
                          *, spec: CommSpec):
    """Consensus curve of one run under faults → errors (iters+1,).

    The error is measured against the FULL network mean (frozen dead nodes
    included), so a long leave window shows up as an error plateau — the
    honest view of what the network has actually agreed on.
    """
    def step(carry, xs):
        t, alive_t, link_t = xs
        W = degrade_matrix(select_cycle_matrix(Wc, R, t), alive_t, link_t)
        keep = (alive_t > 0)[:, None]
        if spec.choco:
            x, hat, key = carry
            key, sub = jax.random.split(key)
            x_new, hat_new = _mix_pytree(spec, x, hat, W, gamma, sub)
            x = jnp.where(keep, x_new, x)
            hat = jnp.where(keep, hat_new, hat)
            carry = (x, hat, key)
        else:
            x = jnp.where(keep, W @ carry, carry)
            carry = x
        return carry, jnp.linalg.norm(x - x.mean(axis=0, keepdims=True))

    e0 = jnp.linalg.norm(x0 - x0.mean(axis=0, keepdims=True))
    init = (x0, jnp.zeros_like(x0), key0) if spec.choco else x0
    _, errs = lax.scan(step, init, (ts, alive, link_up))
    return jnp.concatenate([e0[None], errs])


@functools.lru_cache(maxsize=None)
def _chaos_consensus_fns(spec: CommSpec):
    impl = functools.partial(_consensus_chaos_impl, spec=spec)
    return jax.jit(jax.vmap(impl, in_axes=(0, 0, 0, 0, 0, None, None, None)))


def consensus_curves_chaos(cycles, gammas, spec: CommSpec, chaos, x0,
                           iters: int, seed: int = 0):
    """``consensus_curves_cross`` under injected faults — one dispatch.

    Same contract (shared x0, ``PRNGKey(seed + 1)`` compressor stream);
    ``chaos`` as in :func:`train_curves_chaos`. Returns (B, iters+1) numpy.
    """
    Wc, R = stack_cycles(cycles)
    x0 = jnp.asarray(x0)
    n = Wc.shape[-1]
    Wc = jnp.asarray(Wc, x0.dtype)
    alive, link = _stack_chaos(chaos, len(cycles), iters, n)
    gammas = jnp.asarray(gammas, x0.dtype)
    key0 = jax.random.PRNGKey(seed + 1)
    errs = _chaos_consensus_fns(spec)(Wc, jnp.asarray(R), gammas, alive, link,
                                      x0, key0, jnp.arange(iters))
    return np.asarray(errs)


def consensus_curve_host_chaos(cycle, gamma, spec: CommSpec,
                               chaos: ChaosSpec, x0, iters: int,
                               seed: int = 0):
    """Per-iteration host loop for ONE chaos consensus run — fallback and
    parity oracle of :func:`consensus_curves_chaos`. Shares the degradation,
    mix, and freeze rules (jitted step) and the key stream with the engine.
    """
    x0 = jnp.asarray(x0)
    cycle = [jnp.asarray(W, x0.dtype) for W in np.asarray(cycle)]
    n = cycle[0].shape[-1]
    gamma = jnp.asarray(gamma, x0.dtype)

    @jax.jit
    def step(x, hat, W, alive_t, link_t, sub):
        Wd = degrade_matrix(W, alive_t, link_t)
        keep = (alive_t > 0)[:, None]
        if spec.choco:
            x_new, hat_new = _mix_pytree(spec, x, hat, Wd, gamma, sub)
            return jnp.where(keep, x_new, x), jnp.where(keep, hat_new, hat)
        return jnp.where(keep, Wd @ x, x), hat

    alive, link = _stack_chaos(chaos, 1, iters, n)
    alive, link = alive[0], link[0]
    x, hat = x0, jnp.zeros_like(x0)
    key = jax.random.PRNGKey(seed + 1)
    errs = [float(jnp.linalg.norm(x0 - x0.mean(axis=0, keepdims=True)))]
    for t in range(iters):
        key, sub = jax.random.split(key)
        x, hat = step(x, hat, cycle[t % len(cycle)], alive[t], link[t], sub)
        errs.append(float(jnp.linalg.norm(
            x - x.mean(axis=0, keepdims=True))))
    return np.asarray(errs)


@functools.lru_cache(maxsize=None)
def _host_consensus_step(spec: CommSpec):
    """One jitted consensus step per CommSpec — cached so a host sweep over
    many (topology, γ) runs compiles ONCE instead of once per run (184
    recompiles of an identical tiny program would otherwise land in the
    host wall-clock that the tracked scan-vs-host speedup is gated on)."""
    from .compression import choco_gossip_step

    comp = spec.to_compressor()

    @jax.jit
    def step(state, W, gamma, key):
        if spec.choco:
            return choco_gossip_step(state, W, comp, gamma,
                                     jax.random.fold_in(key, 0))
        return state._replace(x=W @ state.x)

    return step


def consensus_curve_host_cross(cycle, gamma, spec: CommSpec, x0, iters: int,
                               seed: int = 0, stop_rel: float | None = None):
    """Per-iteration host loop for ONE consensus run — the seed bench
    behaviour (one step dispatch + a ``float()`` sync per step) kept as the
    ``engine="host"`` fallback and parity oracle. Same cycle rule
    (``cycle[t % R]`` selected on host) and key stream as the scan engine;
    the step itself is jitted so host/engine arithmetic is bit-identical
    (the 1/frac error-feedback scaling amplifies any op-fusion roundoff
    difference chaotically). ``stop_rel`` replays the seed bench's early
    exit: the loop stops once the relative error reaches it. Returns
    errors (≤ iters+1,) numpy.
    """
    from .compression import choco_gossip_init

    x0 = jnp.asarray(x0)
    cycle = [jnp.asarray(W, x0.dtype) for W in np.asarray(cycle)]
    gamma = jnp.asarray(gamma, x0.dtype)
    step = _host_consensus_step(spec)

    state = choco_gossip_init(x0)
    key = jax.random.PRNGKey(seed + 1)
    errs = [float(jnp.linalg.norm(x0 - x0.mean(axis=0, keepdims=True)))]
    for t in range(iters):
        key, sub = jax.random.split(key)
        state = step(state, cycle[t % len(cycle)], gamma, sub)
        errs.append(float(jnp.linalg.norm(
            state.x - state.x.mean(axis=0, keepdims=True))))
        if stop_rel is not None and errs[-1] <= stop_rel * errs[0]:
            break
    return np.asarray(errs)
