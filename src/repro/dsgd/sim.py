"""Device-resident DSGD evaluation engine (paper §VI — Table II, Figs 7–10).

Mirrors the ``core/engine.py`` architecture for the *training-side*
evaluation loop: where the seed benchmark ran a host Python loop per
training iteration (one jitted step dispatch + a host-side ``jnp.stack``
batch assembly per step, one ``float()`` sync per epoch, serial per
topology), this module compiles the entire run into one device program:

  - ``train_curve``          — jitted ``lax.scan`` over epochs with an inner
    scan over iterations; minibatches are GATHERED inside the scan
    (``X[idx]``) from the device-resident dataset via the precomputed
    ``(epochs, iters, n, batch)`` permutation tensor
    (``repro.data.epoch_permutations``), and the mean-model test accuracy is
    evaluated at epoch boundaries inside the scan — zero host round-trips
    between epochs.
  - ``accuracy_curves``      — every topology trains the same model on the
    same data with the same hyperparameters, so the ``(n, n)`` gossip
    matrices are stacked ``(T, n, n)`` and the WHOLE training run is
    ``jax.vmap``-ed across topologies: the serial per-topology loop of the
    benchmark becomes one batched device call.
  - ``accuracy_curves_seeds``— same trick one axis up: vmap over seeds
    (per-seed init + batch order) × topologies in one dispatch.
  - ``accuracy_curve_host``  — the seed per-iteration host loop, kept
    verbatim as the ``engine="host"`` fallback and the parity oracle
    (identical batch order by construction: both consume
    ``epoch_permutations``'s numpy stream).

The model is the benchmark's 2-layer-MLP CIFAR stand-in (``init_mlp`` /
``mlp_logits`` / ``mlp_loss``), exposed here so benchmarks and tests share
one definition. See DESIGN.md §11.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.data import epoch_permutations

from .gossip import gossip_sim_tree

__all__ = [
    "DSGDSimConfig", "init_mlp", "mlp_logits", "mlp_loss",
    "train_curve", "accuracy_curves", "accuracy_curves_seeds",
    "accuracy_curve_host",
]


@dataclass(frozen=True)
class DSGDSimConfig:
    """Hyperparameters of the §VI-B time-to-accuracy protocol."""
    epochs: int = 30
    batch: int = 32
    lr: float = 0.05
    momentum: float = 0.9
    hidden: int = 128
    seed: int = 0


# ---------------------------------------------------------------------------
# model: 2-layer MLP on the Gaussian-mixture task (CIFAR-10 stand-in)
# ---------------------------------------------------------------------------

def init_mlp(key, dim: int, hidden: int, classes: int) -> dict:
    """Explicitly float32: with the solver's ``jax_enable_x64`` active, the
    dtype-less seed init silently promoted the whole training loop to f64
    (~2× slower per step on CPU for identical curves)."""
    k1, k2 = jax.random.split(key)
    s1 = 1.0 / np.sqrt(dim)
    s2 = 1.0 / np.sqrt(hidden)
    return {"w1": jax.random.uniform(k1, (dim, hidden), jnp.float32,
                                     minval=-s1, maxval=s1),
            "b1": jnp.zeros((hidden,), jnp.float32),
            "w2": jax.random.uniform(k2, (hidden, classes), jnp.float32,
                                     minval=-s2, maxval=s2),
            "b2": jnp.zeros((classes,), jnp.float32)}


def mlp_logits(p, x):
    return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def mlp_loss(p, x, y):
    lp = jax.nn.log_softmax(mlp_logits(p, x))
    return -jnp.mean(jnp.take_along_axis(lp, y[:, None], axis=1))


def _init_worker_state(n: int, dim: int, classes: int, cfg: DSGDSimConfig):
    """All workers start from identical params (standard DSGD init)."""
    p0 = init_mlp(jax.random.PRNGKey(cfg.seed), dim, cfg.hidden, classes)
    params = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), p0)
    mom = jax.tree.map(jnp.zeros_like, params)
    return params, mom


# ---------------------------------------------------------------------------
# scan-compiled core
# ---------------------------------------------------------------------------

def _train_curve_impl(W, X, y, Xte, yte, perm, params, mom, lr, momentum):
    """One full DSGD run → per-epoch mean-model accuracy (epochs,).

    W (n, n); X (N, d)/y (N,) device-resident train set; Xte/yte test split;
    perm (epochs, iters, n, batch) gather indices; params/mom stacked
    (n, ...) worker state. Pure — jit/vmap applied by the public wrappers.
    """
    grad_fn = jax.vmap(jax.grad(mlp_loss))

    def it_body(carry, idx):                      # idx: (n, batch)
        params, mom = carry
        xb, yb = X[idx], y[idx]                   # on-device batch gather
        g = grad_fn(params, xb, yb)
        mom = jax.tree.map(lambda m, gg: momentum * m + gg, mom, g)
        params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        params = gossip_sim_tree(params, W)
        return (params, mom), None

    def epoch_body(carry, perm_e):                # perm_e: (iters, n, batch)
        carry, _ = lax.scan(it_body, carry, perm_e)
        mean = jax.tree.map(lambda a: a.mean(axis=0), carry[0])
        pred = jnp.argmax(mlp_logits(mean, Xte), axis=1)
        return carry, jnp.mean(pred == yte)

    _, accs = lax.scan(epoch_body, (params, mom), perm)
    return accs


_train_curve_jit = jax.jit(_train_curve_impl)
# topologies share data/init/batch order → only W is batched
_train_curves_vmapped = jax.jit(jax.vmap(
    _train_curve_impl,
    in_axes=(0, None, None, None, None, None, None, None, None, None)))
# seeds batch the init AND the batch order on top of the topology axis
_train_curves_seeds_vmapped = jax.jit(jax.vmap(
    jax.vmap(_train_curve_impl,
             in_axes=(0, None, None, None, None, None, None, None, None, None)),
    in_axes=(None, None, None, None, None, 0, 0, 0, None, None)))


def train_curve(W, X, y, Xte, yte, perm, cfg: DSGDSimConfig = DSGDSimConfig()):
    """Scan-compiled run for ONE topology; returns accs (epochs,)."""
    n = W.shape[-1]
    classes = int(np.asarray(y).max()) + 1
    params, mom = _init_worker_state(n, X.shape[-1], classes, cfg)
    return _train_curve_jit(W, X, y, Xte, yte, jnp.asarray(perm), params, mom,
                            cfg.lr, cfg.momentum)


def accuracy_curves(Ws, X, y, parts, Xte, yte,
                    cfg: DSGDSimConfig = DSGDSimConfig()):
    """Train ALL topologies in one batched device call.

    Ws: (T, n, n) stacked gossip matrices (or (n, n) for a single run).
    Returns (accs (T, epochs) [or (epochs,)], iters_per_epoch).
    """
    Ws = jnp.asarray(Ws, jnp.float32)
    n = Ws.shape[-1]
    perm = jnp.asarray(epoch_permutations(parts, cfg.epochs, cfg.batch,
                                          seed=cfg.seed))
    iters = perm.shape[1]
    classes = int(np.asarray(y).max()) + 1
    params, mom = _init_worker_state(n, X.shape[-1], classes, cfg)
    fn = _train_curve_jit if Ws.ndim == 2 else _train_curves_vmapped
    accs = fn(Ws, X, y, Xte, yte, perm, params, mom, cfg.lr, cfg.momentum)
    return accs, iters


def accuracy_curves_seeds(Ws, X, y, parts, Xte, yte, seeds,
                          cfg: DSGDSimConfig = DSGDSimConfig()):
    """Seeds × topologies in one dispatch; returns (accs (S, T, epochs), iters).

    Each seed draws its own init and batch order (the §VI-B repeat-runs
    protocol); topologies within a seed share both.
    """
    Ws = jnp.asarray(Ws, jnp.float32)
    n = Ws.shape[-1]
    classes = int(np.asarray(y).max()) + 1
    perms, params, moms = [], [], []
    for s in seeds:
        c = dataclasses.replace(cfg, seed=int(s))
        perms.append(epoch_permutations(parts, c.epochs, c.batch, seed=c.seed))
        p, m = _init_worker_state(n, X.shape[-1], classes, c)
        params.append(p)
        moms.append(m)
    perm = jnp.asarray(np.stack(perms))
    stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
    accs = _train_curves_seeds_vmapped(Ws, X, y, Xte, yte, perm,
                                       stack(params), stack(moms),
                                       cfg.lr, cfg.momentum)
    return accs, perm.shape[2]


# ---------------------------------------------------------------------------
# host-loop oracle (the seed benchmark path, verbatim)
# ---------------------------------------------------------------------------

def accuracy_curve_host(W, X, y, parts, Xte, yte,
                        cfg: DSGDSimConfig = DSGDSimConfig()):
    """Per-iteration host loop: one jitted step dispatch + host ``jnp.stack``
    batch assembly per step, one accuracy sync per epoch — the ``engine="host"``
    fallback and the parity oracle for :func:`accuracy_curves`.

    Consumes the SAME ``epoch_permutations`` index stream as the scan engine,
    so batch order is identical given a seed. Returns (accs (epochs,), iters).
    """
    W = jnp.asarray(W, jnp.float32)
    n = W.shape[-1]
    classes = int(np.asarray(y).max()) + 1
    params, mom = _init_worker_state(n, X.shape[-1], classes, cfg)
    lr, momentum = cfg.lr, cfg.momentum

    grad_fn = jax.vmap(jax.grad(mlp_loss))

    @jax.jit
    def step(params, mom, xb, yb):
        g = grad_fn(params, xb, yb)
        mom = jax.tree.map(lambda m, gg: momentum * m + gg, mom, g)
        params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        params = gossip_sim_tree(params, W)
        return params, mom

    @jax.jit
    def accuracy(params):
        mean = jax.tree.map(lambda a: a.mean(axis=0), params)
        pred = jnp.argmax(mlp_logits(mean, Xte), axis=1)
        return jnp.mean(pred == yte)

    perm = epoch_permutations(parts, cfg.epochs, cfg.batch, seed=cfg.seed)
    iters = perm.shape[1]
    accs = []
    for e in range(cfg.epochs):
        for it in range(iters):
            idx = perm[e, it]                     # (n, batch)
            # per-worker device gathers + host jnp.stack, as the seed bench
            xb = jnp.stack([X[idx[w]] for w in range(n)])
            yb = jnp.stack([y[idx[w]] for w in range(n)])
            params, mom = step(params, mom, xb, yb)
        accs.append(float(accuracy(params)))
    return np.asarray(accs), iters
