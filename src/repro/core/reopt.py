"""Online topology re-optimization under drift (DESIGN.md §14).

The chaos layer (``repro.dsgd.chaos``) makes bandwidth and membership
time-varying; this module closes the loop. A ``DriftDetector`` watches the
per-step bandwidth profile B(t) and the alive mask against a baseline and
fires when either moves past the ``DriftPolicy`` thresholds. On a trigger,
``reoptimize_topology`` re-runs the ADMM pipeline **warm-started from the
incumbent support** — ``g0``/``z0``/``lam0`` packed from the live topology
exactly the way the cold pipeline packs its annealed warm starts — under
the drifted ``ConstraintSet``, with a retry/fallback ladder (run through
the shared ``core.guard`` ladder runner — reopt and the topology service
classify and recover from solver failures via one code path, DESIGN.md §15):

  rung "warm"  warm ADMM from the incumbent support (cheap: the solve starts
               at a feasible, near-optimal point and usually just re-rounds),
  rung "cold"  the full cold pipeline (``optimize_topology``: SA warm start,
               restarts, classic baselines) if the warm solve fails to
               converge or rounds to a disconnected support,
  fallback     keep the incumbent and report why — a degraded-but-connected
               topology beats a "better" one that never materialized.

``time_to_reoptimized_topology`` (seconds of wall time from trigger to an
adopted topology) is a first-class output: under churn the metric that
matters is not just the new r_asym but how long the fleet ran on the stale
graph, and ``benchmarks/bench_chaos.py`` folds it into the Eq. 34 clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .api import BATopoConfig, _pack_warm
from .constraints import ConstraintSet
from .graph import Topology
from .guard import GuardPolicy, attempt_admm, run_ladder

__all__ = ["DriftPolicy", "DriftDetector", "ReoptResult",
           "reoptimize_topology", "first_drift"]


@dataclass(frozen=True)
class DriftPolicy:
    """When is the world different enough to re-solve?

    ``bw_rel_threshold``: trigger when any node's bandwidth moved by more
    than this fraction of its baseline value (|B_i(t) − B_i(0)| / B_i(0)).
    ``churn_events``: trigger when at least this many nodes flipped
    alive/dead versus the baseline membership.
    ``cooldown_steps``: suppress re-triggers for this many steps after one
    fires — a re-solve in flight should not be pre-empted by the same drift.
    ``max_residual``: an ADMM re-solve whose final summed-squared primal
    residual exceeds this is declared non-convergent (fallback ladder).
    """

    bw_rel_threshold: float = 0.25
    churn_events: int = 1
    cooldown_steps: int = 0
    max_residual: float = 1.0


@dataclass
class DriftDetector:
    """Streaming comparison of (B(t), alive(t)) against a rebased baseline."""

    policy: DriftPolicy
    base_bandwidth: np.ndarray           # (n,)
    base_alive: np.ndarray               # (n,)
    last_trigger: int | None = None

    @classmethod
    def from_profile(cls, bandwidth0: np.ndarray, alive0: np.ndarray,
                     policy: DriftPolicy | None = None) -> "DriftDetector":
        return cls(policy or DriftPolicy(),
                   np.asarray(bandwidth0, np.float64).copy(),
                   np.asarray(alive0, np.float64).copy())

    def check(self, t: int, bandwidth_t: np.ndarray,
              alive_t: np.ndarray) -> str | None:
        """Reason string ("bandwidth" / "churn") if step ``t`` drifted past
        the thresholds, else None. Does not rebase — call :meth:`rebase`
        after a re-optimized topology is actually adopted."""
        if (self.last_trigger is not None
                and t - self.last_trigger < self.policy.cooldown_steps):
            return None
        flips = int(np.sum(np.asarray(alive_t) != self.base_alive))
        if flips >= self.policy.churn_events:
            self.last_trigger = t
            return "churn"
        rel = np.abs(np.asarray(bandwidth_t, np.float64) - self.base_bandwidth)
        rel = rel / np.maximum(self.base_bandwidth, 1e-12)
        if float(rel.max(initial=0.0)) > self.policy.bw_rel_threshold:
            self.last_trigger = t
            return "bandwidth"
        return None

    def rebase(self, bandwidth_t: np.ndarray, alive_t: np.ndarray) -> None:
        """Adopt the current world as the new baseline (after a reopt)."""
        self.base_bandwidth = np.asarray(bandwidth_t, np.float64).copy()
        self.base_alive = np.asarray(alive_t, np.float64).copy()

    def to_state(self) -> dict[str, np.ndarray]:
        """Named arrays capturing the detector's mutable state (baselines +
        cooldown clock) — the checkpoint extras payload of a crash-safe
        resume (DESIGN.md §16). ``last_trigger`` uses −1 for "never"."""
        return {
            "base_bandwidth": self.base_bandwidth.copy(),
            "base_alive": self.base_alive.copy(),
            "last_trigger": np.asarray(
                -1 if self.last_trigger is None else self.last_trigger,
                np.int64),
        }

    @classmethod
    def from_state(cls, state: dict[str, np.ndarray],
                   policy: DriftPolicy | None = None) -> "DriftDetector":
        """Inverse of :meth:`to_state` (the policy itself is static config,
        not state — pass the run's)."""
        det = cls(policy or DriftPolicy(),
                  np.asarray(state["base_bandwidth"], np.float64).copy(),
                  np.asarray(state["base_alive"], np.float64).copy())
        lt = int(state["last_trigger"])
        det.last_trigger = None if lt < 0 else lt
        return det


def first_drift(chaos, policy: DriftPolicy | None = None,
                start: int = 0) -> tuple[int, str] | None:
    """Walk a ChaosSpec's (bandwidth, alive) tensors from ``start`` and
    return the first (step, reason) the detector fires at, or None."""
    det = DriftDetector.from_profile(chaos.bandwidth[start],
                                     chaos.alive[start], policy)
    for t in range(start + 1, chaos.steps):
        reason = det.check(t, chaos.bandwidth[t], chaos.alive[t])
        if reason is not None:
            return t, reason
    return None


@dataclass
class ReoptResult:
    """Outcome of one re-optimization attempt ladder."""

    topology: Topology
    reoptimized: bool                 # False ⇒ incumbent kept (see reason)
    attempts: int                     # solver attempts actually made
    fallback_reason: str | None       # set iff reoptimized is False
    time_to_reopt_s: float            # wall: trigger → adopted topology
    r_asym_before: float
    r_asym_after: float
    meta: dict = field(default_factory=dict)


def reoptimize_topology(
    incumbent: Topology,
    scenario: str = "homo",
    cs: ConstraintSet | None = None,
    node_bandwidths: np.ndarray | None = None,
    r: int | None = None,
    alive: np.ndarray | None = None,
    cfg: BATopoConfig | None = None,
    policy: DriftPolicy | None = None,
    budget_ms: float | None = None,
) -> ReoptResult:
    """Re-solve the topology under drifted constraints, warm-started from
    the incumbent; keep the incumbent on any failure.

    ``node_bandwidths`` is the *drifted* profile (node scenario — Algorithm 1
    re-allocates per-node capacities under it); ``cs`` the drifted
    ConstraintSet (constraint scenario). ``alive`` (optional, (n,) mask)
    prunes dead nodes' edges from the warm-start support only — the re-solve
    still covers all n nodes, because churned nodes rejoin at their frozen
    params and need edges waiting for them.

    ``budget_ms`` (opt-in) bounds the COLD rung with a budgeted anytime
    solve of whatever budget remains after the warm attempt — the elastic
    runtime passes its ``activation_lag_steps`` adoption window here so the
    re-solve fills exactly the time the fleet must wait anyway. The default
    (None) keeps the unbudgeted deterministic ladder: wall-clock budgets
    make the adopted support timing-dependent, which would break bit-exact
    crash/resume replay (DESIGN.md §16) — hence opt-in.

    The attempt ladder and the non-convergence test (``policy.max_residual``)
    are documented in the module docstring; ``time_to_reopt_s`` measures
    this call's wall time, i.e. how long training would run on the stale
    incumbent before the new graph exists.
    """
    t_start = time.perf_counter()
    cfg = cfg or BATopoConfig()
    policy = policy or DriftPolicy()
    n = incumbent.n
    r = int(r if r is not None else len(incumbent.edges))

    from .anytime import resolve_scenario

    cs, _, meta = resolve_scenario(n, r, scenario, cs, node_bandwidths,
                                   context="reopt")
    meta.pop("alloc_e", None)  # reopt meta stays (scenario, r[, b_unit])

    live_edges = incumbent.edges
    if alive is not None:
        a = np.asarray(alive)
        live_edges = [e for e in incumbent.edges if a[e[0]] > 0 and a[e[1]] > 0]
    if not live_edges:                      # a fully-dead incumbent support
        live_edges = incumbent.edges        # fall back to the full support

    r_before = incumbent.r_asym()

    # ---- shared guard ladder: warm → cold (keep-incumbent is OUR fallback)
    guard_policy = GuardPolicy(max_residual=policy.max_residual,
                               warm_retries=0)
    warm = _pack_warm(n, live_edges)

    def _cold():
        from .anytime import TopologyRequest, solve_topology

        req = TopologyRequest(n=n, r=r, scenario=scenario, cs=cs,
                              node_bandwidths=node_bandwidths)
        if budget_ms is None:
            cand = solve_topology(req, cfg=cfg, engine="barrier").topology
        else:
            remaining = budget_ms - (time.perf_counter() - t_start) * 1e3
            if remaining <= 0:
                return None                 # window spent — keep incumbent
            res = solve_topology(req, cfg=cfg, budget_ms=remaining)
            # an internal classic fallback on an expired budget is NOT an
            # upgrade over a live incumbent — treat it as "no candidate"
            if not res.complete and res.quality_tier == "classic":
                return None
            cand = res.topology
        return (cand if cand is not None
                and cand.meta.get("connected", True) else None)

    ladder = run_ladder([
        ("warm", lambda: attempt_admm(
            n, r, scenario, cs, cfg, warm,
            f"ba-topo(n={n},r={r},reopt-warm)", guard_policy)),
        ("cold", _cold),
    ])
    candidate = ladder.topology

    elapsed = time.perf_counter() - t_start
    if candidate is None:
        return ReoptResult(topology=incumbent, reoptimized=False,
                           attempts=ladder.attempts,
                           fallback_reason=ladder.reason or "no connected candidate",
                           time_to_reopt_s=elapsed,
                           r_asym_before=r_before, r_asym_after=r_before,
                           meta=meta)

    r_after = candidate.r_asym()
    candidate.meta.update(meta)
    candidate.meta["r_asym"] = r_after
    candidate.meta["time_to_reopt_s"] = elapsed
    return ReoptResult(topology=candidate, reoptimized=True,
                       attempts=ladder.attempts, fallback_reason=None,
                       time_to_reopt_s=elapsed,
                       r_asym_before=r_before, r_asym_after=r_after,
                       meta=meta)
