"""Builders for the unified heterogeneous-bandwidth constraint (M, e) of Eq. (10).

Each scenario yields a ``ConstraintSet``:
  - ``M ∈ {0,1}^{q×|E|}`` maps logical edges to physical resources,
  - ``e_cap ∈ N^q`` per-resource edge capacities,
  - ``equality``: True → ``M z = e`` (node-level, where Algorithm 1 produced an
    exact degree allocation); False → ``M z ≤ e`` (link/port capacities),
  - ``edge_ok``: mask of logical edges that exist at all (e.g. BCube only
    allows one-hop pairs),
  - ``edge_bandwidth(sel)``: the per-edge available bandwidth given a selected
    edge set, used by the time model (§VI Eqs. 34–35).

Scenarios (§IV-B / §VI-A):
  1. node-level        — M = abs(A) (Eq. 16), e from Algorithm 1.
  2. intra-server tree — PIX/NODE/SYS tiers of a standard 8-GPU server
                         (Fig. 3), e = (1,1,1,1,4,4,16).
  3. BCube(p, k)       — per-port rows (Eq. 18–19), cap p−1 per port.
  4. pod-boundary      — our TPU adaptation: intra-pod ICI vs inter-pod DCI.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .graph import all_edges

__all__ = [
    "ConstraintSet",
    "node_level_constraints",
    "intra_server_constraints",
    "bcube_constraints",
    "pod_boundary_constraints",
    "INTRA_SERVER_CAPS",
]


@dataclass
class ConstraintSet:
    n: int
    M: np.ndarray  # (q, |E|) over the FULL candidate edge list all_edges(n)
    e_cap: np.ndarray  # (q,)
    equality: bool
    name: str
    edge_ok: np.ndarray  # (|E|,) bool — which logical edges are admissible
    resource_bw: np.ndarray  # (q,) bandwidth of each physical resource
    # maps a selected-edge boolean mask to per-edge available bandwidth:
    edge_bandwidth: Callable[[np.ndarray], np.ndarray] = field(repr=False, default=None)  # type: ignore

    @property
    def q(self) -> int:
        return self.M.shape[0]

    def feasible(self, z: np.ndarray) -> bool:
        """Check M z (= or ≤) e for a 0/1 selection vector z."""
        lhs = self.M @ z.astype(np.int64)
        if self.equality:
            return bool(np.all(lhs == self.e_cap))
        return bool(np.all(lhs <= self.e_cap))

    def usage(self, z: np.ndarray) -> np.ndarray:
        return self.M @ z.astype(np.int64)


def _endpoint_arrays(edges) -> tuple[np.ndarray, np.ndarray]:
    ei = np.fromiter((i for i, _ in edges), dtype=np.int64, count=len(edges))
    ej = np.fromiter((j for _, j in edges), dtype=np.int64, count=len(edges))
    return ei, ej


def node_level_constraints(n: int, e_per_node: np.ndarray, b: np.ndarray) -> ConstraintSet:
    """§IV-B1: q = n rows, M = abs(A) (Eq. 16), e from Algorithm 1."""
    edges = all_edges(n)
    m = len(edges)
    ei, ej = _endpoint_arrays(edges)
    M = np.zeros((n, m), dtype=np.int64)
    M[ei, np.arange(m)] = 1
    M[ej, np.arange(m)] = 1
    e_cap = np.asarray(e_per_node, dtype=np.int64)
    b = np.asarray(b, dtype=np.float64)

    def edge_bw(sel: np.ndarray) -> np.ndarray:
        deg = np.maximum(M @ sel.astype(np.int64), 1)
        out = np.minimum(b[ei] / deg[ei], b[ej] / deg[ej])
        return np.where(sel, out, np.inf)

    cs = ConstraintSet(
        n=n, M=M, e_cap=e_cap, equality=True, name="node-level",
        edge_ok=np.ones(m, dtype=bool), resource_bw=b,
    )
    cs.edge_bandwidth = edge_bw
    return cs


# (PIX1..4, NODE1, NODE2, SYS) caps from §VI-A3.
INTRA_SERVER_CAPS = np.array([1, 1, 1, 1, 4, 4, 16], dtype=np.int64)


def intra_server_constraints(
    n: int = 8,
    caps: np.ndarray = INTRA_SERVER_CAPS,
    b_pix: float = 4.88,
    b_node: float = 4.88,
    b_sys: float = 9.76,
) -> ConstraintSet:
    """§IV-B2 / §VI-A3: standard 8-GPU server tree (Fig. 3).

    GPU pairs {0,1},{2,3},{4,5},{6,7} sit under PIX switches 1..4; PIX1/2
    under NODE1 (socket 0), PIX3/4 under NODE2; sockets joined by SYS. A
    logical edge is *classified by the highest tier its path traverses*:
    intra-pair → PIXk, intra-socket cross-pair → NODEm, cross-socket → SYS.
    With e = (1,1,1,1,4,4,16) every class capacity equals the number of
    possible edges of that class, matching the paper's accounting (the
    exponential graph on n=8 maps exactly 10 edges onto SYS → min edge
    bandwidth 9.76/10 = 0.976 GB/s, reproducing §VI-A3).
    """
    if n != 8:
        raise ValueError("the paper's standard server architecture has 8 GPUs")
    edges = all_edges(n)
    m = len(edges)
    q = 7
    M = np.zeros((q, m), dtype=np.int64)

    def tier(i: int, j: int) -> int:
        if i // 2 == j // 2:
            return i // 2  # PIX row 0..3
        if i // 4 == j // 4:
            return 4 + i // 4  # NODE row 4..5
        return 6  # SYS

    edge_tier = np.array([tier(i, j) for i, j in edges], dtype=np.int64)
    M[edge_tier, np.arange(m)] = 1
    bw = np.array([b_pix] * 4 + [b_node] * 2 + [b_sys])

    def edge_bw(sel: np.ndarray) -> np.ndarray:
        load = np.maximum(M @ sel.astype(np.int64), 1)
        return np.where(sel, bw[edge_tier] / load[edge_tier], np.inf)

    cs = ConstraintSet(
        n=n, M=M, e_cap=np.asarray(caps, dtype=np.int64), equality=False,
        name="intra-server", edge_ok=np.ones(m, dtype=bool), resource_bw=bw,
    )
    cs.edge_bandwidth = edge_bw
    return cs


def bcube_constraints(p: int = 4, k: int = 2, layer_bw: tuple[float, ...] = (4.88, 9.76)) -> ConstraintSet:
    """§IV-B3 / §VI-A4: BCube(p, k) switch-port capacities.

    n = p^k servers, addressed by k base-p digits. Servers share a layer-l
    switch iff their addresses differ only in digit l; only such one-hop
    pairs are admissible logical edges. Each server has one port per layer;
    a layer-l edge consumes the layer-l port of both endpoints. Per-port
    capacity e_{s_l} = p − 1 (Fig. 5 discussion).
    """
    n = p**k
    edges = all_edges(n)
    m = len(edges)

    def digits(x: int) -> list[int]:
        return [(x // p**t) % p for t in range(k)]

    def shared_layer(i: int, j: int) -> int | None:
        di, dj = digits(i), digits(j)
        diff = [t for t in range(k) if di[t] != dj[t]]
        return diff[0] if len(diff) == 1 else None

    q = k * n  # port (layer l, server i) → row l*n + i
    M = np.zeros((q, m), dtype=np.int64)
    edge_ok = np.zeros(m, dtype=bool)
    edge_layer = np.full(m, -1, dtype=np.int64)
    for l, (i, j) in enumerate(edges):
        lay = shared_layer(i, j)
        if lay is None:
            continue
        edge_ok[l] = True
        edge_layer[l] = lay
        M[lay * n + i, l] = 1
        M[lay * n + j, l] = 1
    e_cap = np.full(q, p - 1, dtype=np.int64)
    bw = np.concatenate([np.full(n, layer_bw[lay]) for lay in range(k)])
    # an admissible layer-l edge {i, j} consumes ports l·n+i and l·n+j
    ei, ej = _endpoint_arrays(edges)
    lay0 = np.maximum(edge_layer, 0)  # sentinel −1 → row 0 (masked below)
    port_i = lay0 * n + ei
    port_j = lay0 * n + ej

    def edge_bw(sel: np.ndarray) -> np.ndarray:
        load = np.maximum(M @ sel.astype(np.int64), 1)
        out = np.minimum(bw[port_i] / load[port_i], bw[port_j] / load[port_j])
        return np.where(sel & edge_ok, out, np.inf)

    cs = ConstraintSet(
        n=n, M=M, e_cap=e_cap, equality=False, name=f"bcube(p={p},k={k})",
        edge_ok=edge_ok, resource_bw=bw,
    )
    cs.edge_bandwidth = edge_bw
    cs.edge_layer = edge_layer  # type: ignore[attr-defined]  # kept for tests
    return cs


def pod_boundary_constraints(
    n: int,
    pods: int = 2,
    ici_bw: float = 50.0,
    dci_bw: float = 25.0,
    ici_cap_per_node: int = 4,
    dci_cap_total: int = 8,
) -> ConstraintSet:
    """TPU adaptation (DESIGN.md §7): intra-pod ICI vs inter-pod DCI.

    Rows: one per node for intra-pod edge capacity (ICI ports), plus one
    aggregate row for edges crossing the pod boundary (DCI).
    """
    edges = all_edges(n)
    m = len(edges)
    per_pod = n // pods
    q = n + 1
    ei, ej = _endpoint_arrays(edges)
    intra = (ei // per_pod) == (ej // per_pod)
    M = np.zeros((q, m), dtype=np.int64)
    cols = np.arange(m)
    M[ei[intra], cols[intra]] = 1
    M[ej[intra], cols[intra]] = 1
    M[n, cols[~intra]] = 1
    e_cap = np.concatenate([np.full(n, ici_cap_per_node), [dci_cap_total]]).astype(np.int64)
    bw = np.concatenate([np.full(n, ici_bw), [dci_bw]])

    def edge_bw(sel: np.ndarray) -> np.ndarray:
        load = np.maximum(M @ sel.astype(np.int64), 1)
        out = np.where(
            intra,
            np.minimum(ici_bw / load[ei], ici_bw / load[ej]),
            dci_bw / load[n],
        )
        return np.where(sel, out, np.inf)

    cs = ConstraintSet(
        n=n, M=M, e_cap=e_cap, equality=False, name=f"pod-boundary(pods={pods})",
        edge_ok=np.ones(m, dtype=bool), resource_bw=bw,
    )
    cs.edge_bandwidth = edge_bw
    return cs
