"""Multi-device sharded execution layer for the ADMM engine (DESIGN.md §13).

``core.engine`` solves one topology MI-SDP instance per device; this module
scales the same pure ``step(spec, state)`` math out over devices along two
orthogonal axes, selected by ``ADMMConfig.partition``:

  - ``"edges"``     — ONE instance, its edge-space leaves block-partitioned
    over a 1-D mesh axis. Each device owns a contiguous window of the packed
    edge vector (g, μ_g, and heterogeneous z/ν blocks plus the coupling
    multiplier v); the node-space (n, n) blocks (S, T, Laplacian, PSD
    projections) stay replicated. Per CG matvec the only cross-device
    collectives are one ``psum`` of the per-window additive Laplacian
    contribution (``kernels.edge_laplacian.edge_laplacian_window``), a
    ``psum`` of the capacity-row partials M z, and — heterogeneous only — a
    ``psum`` of the fp64 partial dot over the partitioned v-leaf. The
    quadform/degree pullbacks in Aᵀ are purely local gathers. Cardinality /
    binary projections run a distributed top-k (local ``top_k`` +
    ``all_gather`` of candidates); the Newton–Schulz PSD projection is
    row-partitioned, ``all_gather``-ing the iterate once per sign iteration.
  - ``"instances"`` — a batch of restarts / sweep elements laid out over the
    mesh (data parallelism): the engine's vmapped drivers are reused
    unchanged, with the state leaves ``device_put`` under a
    ``NamedSharding`` so the computation follows the data.
  - ``"auto"``      — resolved by :func:`resolve_partition` from
    (n, batch, device count); single-device environments resolve to
    ``"none"``, so the default pipeline is unchanged on one device.

Padding invariant (edge partitioning): the packed edge dimension m is padded
to a multiple of the device count. Padded slots carry ``edge_ok=False`` and
endpoint (0, 0); every projection zeroes them, Aᵀ masks its edge-space
output there (the degree pullback w_i + w_j is nonzero even at the (0, 0)
sentinel endpoints), and all other padded-slot values are zero-preserved by
induction — so padded slots contribute exactly 0 to every psum and the
sharded iterates match the single-device ones up to float reassociation of
the cross-device reductions (the parity tests bound the drift).
"""
from __future__ import annotations

from functools import lru_cache
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..kernels.edge_laplacian import ops as _el_ops
from . import engine
from .engine import (
    FP32_TOL_FLOOR, INEXACT_CAP, INEXACT_ETA,
    ADMMConfig, ADMMResult, ADMMState, ProblemSpec, proj_psd,
)

__all__ = [
    "EDGE_PARTITION_MIN_N", "resolve_partition",
    "solve_spec_sharded", "solve_batched_spec_sharded",
    "solve_sweep_spec_sharded",
]

_AXIS = "edges"
_INST_AXIS = "inst"

# ---------------------------------------------------------------------------
# Partition resolution ("auto" policy) — thresholds measured in
# benchmarks/bench_scalability.py, tables in DESIGN.md §13.
# ---------------------------------------------------------------------------

# Below this node count the per-matvec psum of the (n, n) Laplacian costs
# more than the O(m) edge work it parallelizes; instance parallelism (when a
# batch exists) or the single-device path wins.
EDGE_PARTITION_MIN_N = 512

_PARTITIONS = ("none", "edges", "instances", "auto")


def resolve_partition(partition: str, n: int, batch: int | None = None,
                      ndev: int | None = None) -> str:
    """Resolve ``ADMMConfig.partition`` to a concrete layout.

    ``auto`` prefers instance parallelism whenever the batch can fill the
    devices (restarts/sweep elements are embarrassingly parallel — no
    per-iteration collectives), falls back to edge partitioning for single
    large instances, and degenerates to the single-device path otherwise.
    """
    if partition not in _PARTITIONS:
        raise ValueError(f"unknown partition {partition!r}; expected one of "
                         f"{_PARTITIONS}")
    if partition != "auto":
        return partition
    ndev = jax.device_count() if ndev is None else ndev
    if ndev <= 1:
        return "none"
    if batch is not None and batch >= ndev:
        return "instances"
    if n >= EDGE_PARTITION_MIN_N:
        return "edges"
    return "none"


# ---------------------------------------------------------------------------
# Edge-partitioned solver
# ---------------------------------------------------------------------------

class SState(NamedTuple):
    """Sharded ADMM iterate. Same blocks as ``engine.ADMMState`` but with the
    x-vector split into its partitioned g-part and replicated λ̃ scalar:
    ``X = (g, λ̃, S, y, T[, z, ν, s])``; constraint multipliers
    ``lam = (P, Q, w[, u, v])`` with only the v-leaf partitioned."""

    X: tuple
    Y: tuple
    D: tuple
    lam: tuple
    res: jnp.ndarray
    cg: jnp.ndarray


def _pad1(a, size, fill=0):
    """Pad axis 0 of ``a`` to ``size`` with a constant."""
    pad = size - a.shape[0]
    if pad == 0:
        return a
    return jnp.concatenate(
        [a, jnp.full((pad,) + a.shape[1:], fill, dtype=a.dtype)])


def _state_specs(hetero: bool) -> SState:
    Pp, Pr = P(_AXIS), P()
    X = (Pp, Pr, Pr, Pr, Pr) + ((Pp, Pp, Pr) if hetero else ())
    lam = (Pr, Pr, Pr) + ((Pr, Pp) if hetero else ())
    return SState(X=X, Y=X, D=X, lam=lam, res=Pr, cg=Pr)


def _data_keys(hetero: bool, precond: str):
    ed = ["ei", "ej", "ok", "pmask"]
    rd = ["lidx", "B0", "I", "r", "rho"]
    if hetero:
        ed.append("mt")
        rd.append("e_cap")
    if precond == "jacobi":
        rd += ["jP", "jw"]
        if hetero:
            rd.append("ju")
            ed.append("dv")
    return tuple(ed), tuple(rd)


@lru_cache(maxsize=None)
def _edge_mesh(ndev: int):
    return jax.make_mesh((ndev,), (_AXIS,))


@lru_cache(maxsize=None)
def _instance_mesh(ndev: int):
    return jax.make_mesh((ndev,), (_INST_AXIS,))


@lru_cache(maxsize=None)
def _get_runner(meta: tuple):
    """Build (and cache) the jitted ``shard_map`` driver for one static
    problem shape. Every function below mirrors its ``engine`` counterpart;
    the parity tests in tests/test_admm_sharding.py hold the pair together."""
    (n, m, m_loc, q, hetero, equality, dtype, psd_backend, psd_iters,
     precond, cg_inexact, cg_tol, cg_maxiter, r_cap, max_iters, check_every,
     eps, abort_nonfinite, ndev) = meta
    dt = jnp.dtype(dtype)
    m_pad = ndev * m_loc
    rows_loc = -(-n // ndev)
    n_pad = ndev * rows_loc
    k_cap = max(1, min(m_loc, r_cap + 1))

    def run(ed, rd, st0):
        ei, ej, ok, pmask = ed["ei"], ed["ej"], ed["ok"], ed["pmask"]
        lidx, B0, I = rd["lidx"], rd["B0"], rd["I"]
        r, rho = rd["r"], rd["rho"]
        offset = lax.axis_index(_AXIS).astype(jnp.int32) * m_loc

        # ---- constraint operator (engine A_op/AT_op, window form) ---------
        def A_sh(X):
            g, lamt, S, y, T = X[:5]
            L = lax.psum(_el_ops.edge_laplacian_window(g, lidx, offset), _AXIS)
            base = (L - lamt * I + S, L + lamt * I + T, jnp.diag(L) + y)
            if not hetero:
                return base
            z, nu, s = X[5], X[6], X[7]
            r4 = lax.psum(z @ ed["mt"], _AXIS) + (0.0 if equality else s)
            r5 = g - z + nu
            return base + (r4, r5)

        def AT_sh(lamv):
            Pm, Q, w = lamv[:3]
            PQ = Pm + Q
            xg = (PQ[ei, ei] + PQ[ej, ej] - PQ[ei, ej] - PQ[ej, ei]
                  + w[ei] + w[ej])
            xl = -jnp.trace(Pm) + jnp.trace(Q)
            if not hetero:
                return (jnp.where(pmask, xg, 0.0), xl, Pm, w, Q)
            u, v = lamv[3], lamv[4]
            xg = jnp.where(pmask, xg + v, 0.0)
            z_adj = ed["mt"] @ u - v
            s_adj = u if not equality else jnp.zeros_like(u)
            return (xg, xl, Pm, w, Q, z_adj, v, s_adj)

        def b_sh():
            base = (-B0, 2.0 * I, jnp.ones(n, dtype=dt))
            if not hetero:
                return base
            return base + (rd["e_cap"], jnp.zeros(m_loc, dtype=dt))

        # ---- fp64 constraint-space dot: only the v-leaf is partitioned ----
        def cdot(a, b):
            parts = [jnp.sum(x.astype(jnp.float64) * y.astype(jnp.float64))
                     for x, y in zip(a, b)]
            tot = parts[0]
            for p_ in parts[1 : (4 if hetero else 3)]:
                tot = tot + p_
            if hetero:
                tot = tot + lax.psum(parts[4], _AXIS)
            return tot

        if precond == "jacobi":
            jd = (rd["jP"], rd["jP"], rd["jw"])
            if hetero:
                jd = jd + (rd["ju"], ed["dv"])
            Minv = lambda rr: jax.tree.map(lambda rl, dl: rl / dl, rr, jd)  # noqa: E731
        else:
            Minv = lambda rr: rr  # noqa: E731

        def axpy(alpha, x, y):
            return jax.tree.map(
                lambda xl_, yl: xl_ + alpha.astype(xl_.dtype) * yl, x, y)

        def pcg_sh(V, lam0, tol):
            """linalg.pcg_solve with sharded operator and psum'd dots."""
            def matvec(lamv):
                return A_sh(AT_sh(lamv))

            b = b_sh()
            rhs = jax.tree.map(lambda av, bb_: av - bb_, A_sh(V), b)
            bb = cdot(rhs, rhs)
            r0 = jax.tree.map(lambda rh, ax: rh - ax, rhs, matvec(lam0))
            z0 = Minv(r0)
            rz0 = cdot(r0, z0)
            rr0 = cdot(r0, r0)
            tol2bb = jnp.asarray(tol, jnp.float64) ** 2 * bb

            def cond(carry):
                _, _, _, _, rr, _, k = carry
                return (rr > tol2bb) & (k < cg_maxiter)

            def body(carry):
                x, rr_, z, p, _, rz, k = carry
                Ap = matvec(p)
                alpha = rz / cdot(p, Ap)
                x = axpy(alpha, x, p)
                rr_ = axpy(-alpha, rr_, Ap)
                z = Minv(rr_)
                rz_new = cdot(rr_, z)
                beta = rz_new / rz
                p = axpy(beta, z, p)
                return (x, rr_, z, p, cdot(rr_, rr_), rz_new, k + 1)

            init = (lam0, r0, z0, z0, rr0, rz0, jnp.asarray(0, jnp.int32))
            lamv, _, _, _, _, _, iters = lax.while_loop(cond, body, init)
            AtL = AT_sh(lamv)
            X = jax.tree.map(lambda v_, a_: v_ - a_, V, AtL)
            return tuple(X), tuple(lamv), iters

        # ---- projections (engine Eq. 24/25/30, distributed) ---------------
        def proj_card_sh(v_loc):
            v_loc = jnp.where(ok, jnp.maximum(v_loc, 0.0), 0.0)
            top = lax.top_k(v_loc, k_cap)[0]
            desc = -jnp.sort(-lax.all_gather(top, _AXIS).reshape(-1))
            idx = jnp.clip(jnp.minimum(r, m - 1), 0, desc.shape[0] - 1)
            thresh = jnp.where(r >= m, -1.0, desc[idx])
            keep = v_loc > jnp.maximum(thresh, 0.0)
            return jnp.where(keep, v_loc, 0.0)

        def proj_binary_sh(v_loc):
            vm = jnp.where(ok, v_loc + 0.0, -jnp.inf)
            allv = lax.all_gather(vm, _AXIS).reshape(-1)
            order = jnp.argsort(-allv)  # stable: global packed order is
            rank = (jnp.zeros(m_pad, dtype=jnp.int64)  # device-major
                    .at[order].set(jnp.arange(m_pad)))
            rank_loc = lax.dynamic_slice(rank, (offset,), (m_loc,))
            return (rank_loc < jnp.asarray(r)).astype(dt)

        def proj_psd_ns_sh(Mx, sign):
            """Row-partitioned Newton–Schulz sign iteration: device d owns
            rows [d·rows_loc, (d+1)·rows_loc) of the iterate; one
            all_gather per iteration rebuilds the full matrix the local
            row-block multiplies against. Same left-association
            (X_loc @ X) @ X as the engine's X @ X @ X."""
            Msym = (Mx + Mx.T) / 2.0
            nrm = jnp.sqrt(jnp.sum(Msym * Msym)) + jnp.asarray(1e-30, dt)
            Y0 = Msym / nrm
            Yp = jnp.pad(Y0, ((0, n_pad - n), (0, 0)))
            roff = lax.axis_index(_AXIS).astype(jnp.int32) * rows_loc
            Xl = lax.dynamic_slice(Yp, (roff, jnp.asarray(0, jnp.int32)),
                                   (rows_loc, n))

            def body(_, Xl_):
                Xf = lax.all_gather(Xl_, _AXIS).reshape(n_pad, n)[:n]
                return 1.5 * Xl_ - 0.5 * ((Xl_ @ Xf) @ Xf)

            Xl = lax.fori_loop(0, psd_iters, body, Xl)
            Xf = lax.all_gather(Xl, _AXIS).reshape(n_pad, n)[:n]
            absM = nrm * (Xf @ Y0)
            absM = (absM + absM.T) / 2.0
            return (Msym + absM) / 2.0 if sign > 0 else (Msym - absM) / 2.0

        psd = (proj_psd_ns_sh if psd_backend == "newton_schulz"
               else proj_psd)

        def project(U):
            g1 = proj_card_sh(U[0])
            lam1 = jnp.maximum(U[1], 0.0)
            S1 = psd(U[2], -1.0)
            y1 = jnp.maximum(U[3], 0.0)
            T1 = psd(U[4], +1.0)
            if not hetero:
                return (g1, lam1, S1, y1, T1)
            z1 = proj_binary_sh(U[5])
            nu1 = jnp.maximum(U[6], 0.0)
            s1 = (jnp.zeros_like(U[7]) if equality
                  else jnp.maximum(U[7], 0.0))
            return (g1, lam1, S1, y1, T1, z1, nu1, s1)

        def xstep_target(Y, D):
            V = tuple(jax.tree.map(lambda y1, d_: y1 - d_ / rho, Y, D))
            # c has a single −1 at the λ̃ slot (minimize −λ̃)
            V = (V[0], V[1] + 1.0 / rho) + V[2:]
            if hetero and equality:
                V = V[:7] + (jnp.zeros_like(V[7]),)
            return V

        def cg_tolerance(prev_res):
            floor = FP32_TOL_FLOOR if dt == jnp.float32 else 0.0
            tol0 = max(cg_tol, floor)
            if not cg_inexact:
                return tol0
            cap = max(INEXACT_CAP, tol0)
            return jnp.clip(INEXACT_ETA * jnp.sqrt(prev_res), tol0, cap)

        part_idx = {0, 5, 6} if hetero else {0}

        def step_sh(st: SState):
            U = tuple(jax.tree.map(lambda x, d_: x + d_ / rho, st.X, st.D))
            Y = project(U)
            V = xstep_target(Y, st.D)
            tol = cg_tolerance(st.res)
            Xn, lamc, cg_it = pcg_sh(V, st.lam, tol)
            if hetero and equality:
                Xn = Xn[:7] + (jnp.zeros_like(Xn[7]),)
            D = tuple(jax.tree.map(
                lambda d_, xn, y1: d_ + rho * (xn - y1), st.D, Xn, Y))
            res = jnp.asarray(0.0, jnp.float64)
            for i, (xn, y1) in enumerate(zip(Xn, Y)):
                ssq = jnp.sum((xn - y1).astype(jnp.float64) ** 2)
                if i in part_idx:
                    ssq = lax.psum(ssq, _AXIS)
                res = res + ssq
            return SState(X=Xn, Y=Y, D=D, lam=lamc, res=res,
                          cg=st.cg + cg_it), res

        # ---- chunked scan driver (engine._run_chunks) ----------------------
        n_chunks = -(-max_iters // check_every)
        last = max_iters - check_every * (n_chunks - 1)
        lengths = jnp.full(n_chunks, check_every, dtype=jnp.int64).at[-1].set(last)

        def chunk_fn(carry, clen):
            st, it, res, done = carry

            def one_chunk(operand):
                st_, _ = operand

                def body(_, val):
                    st2, _ = val
                    return step_sh(st2)

                return lax.fori_loop(0, clen, body, (st_, jnp.asarray(jnp.inf)))

            st2, res2 = lax.cond(done, lambda op: op, one_chunk, (st, res))
            it2 = jnp.where(done, it, it + clen)
            done2 = done | (res2 < eps)
            if abort_nonfinite:  # solver guard (engine._run_chunks parity)
                done2 = done2 | ~jnp.isfinite(res2)
            return (st2, it2, res2, done2), (it2, res2, st2.X[1])

        init = (st0, jnp.asarray(0, dtype=jnp.int64), jnp.asarray(jnp.inf),
                jnp.asarray(False))
        (st, it, res, _), hist = lax.scan(chunk_fn, init, lengths)
        return st, it, res, hist

    ed_keys, rd_keys = _data_keys(hetero, precond)
    sspec = _state_specs(hetero)
    mesh = _edge_mesh(ndev)
    f = shard_map(
        run, mesh=mesh,
        in_specs=({k: P(_AXIS) for k in ed_keys}, {k: P() for k in rd_keys},
                  sspec),
        out_specs=(sspec, P(), P(), (P(), P(), P())),
        check_rep=False)
    return jax.jit(f)


def _split_state(spec: ProblemSpec, st: ADMMState, m_pad: int) -> SState:
    """engine.ADMMState → SState: split x into (g, λ̃), pad edge leaves."""
    m = spec.m

    def xsplit(t):
        x = t[0]
        base = (_pad1(x[:m], m_pad), x[m], t[1], t[2], t[3])
        if spec.hetero:
            base += (_pad1(t[4], m_pad), _pad1(t[5], m_pad), t[6])
        return base

    lam = tuple(st.lam[:3])
    if spec.hetero:
        lam += (st.lam[3], _pad1(st.lam[4], m_pad))
    return SState(X=xsplit(st.X), Y=xsplit(st.Y), D=xsplit(st.D),
                  lam=lam, res=st.res, cg=st.cg)


def _merge_state(spec: ProblemSpec, sst: SState) -> ADMMState:
    """SState → engine.ADMMState: rejoin x = [g; λ̃], drop padding."""
    m = spec.m

    def xjoin(t):
        x = jnp.concatenate([t[0][:m], jnp.reshape(t[1], (1,))])
        base = (x, t[2], t[3], t[4])
        if spec.hetero:
            base += (t[5][:m], t[6][:m], t[7])
        return base

    lam = tuple(sst.lam[:3])
    if spec.hetero:
        lam += (sst.lam[3], sst.lam[4][:m])
    return ADMMState(X=xjoin(sst.X), Y=xjoin(sst.Y), D=xjoin(sst.D),
                     lam=lam, res=sst.res, cg=sst.cg)


def _edge_repl_data(spec: ProblemSpec, m_pad: int):
    lidx = (spec.lidx if spec.lidx is not None
            else engine._packed_edge_index(spec.n))
    ed = {
        "ei": _pad1(spec.ei.astype(jnp.int32), m_pad),
        "ej": _pad1(spec.ej.astype(jnp.int32), m_pad),
        "ok": _pad1(spec.edge_ok, m_pad, False),
        "pmask": jnp.arange(m_pad) < spec.m,
    }
    rd = {"lidx": lidx, "B0": spec.B0, "I": spec.I,
          "r": spec.r, "rho": spec.rho}
    if spec.hetero:
        ed["mt"] = jnp.pad(spec.M.T, ((0, m_pad - spec.m), (0, 0)))
        rd["e_cap"] = spec.e_cap
    if spec.jd is not None:
        rd["jP"], rd["jw"] = spec.jd[0], spec.jd[2]
        if spec.hetero:
            rd["ju"] = spec.jd[3]
            # padded slots divide a zero residual — any nonzero diag works
            ed["dv"] = _pad1(spec.jd[4], m_pad, 1.0)
    return ed, rd


def solve_spec_sharded(spec: ProblemSpec, state0: ADMMState, cfg: ADMMConfig,
                       ndev: int | None = None,
                       r_cap: int | None = None) -> ADMMResult:
    """Edge-partitioned scan-compiled solve of ONE instance across devices.

    Drop-in for ``engine.solve_spec``; ``r_cap`` bounds the traced budget
    ``spec.r`` for the distributed top-k (defaults to the spec's own r —
    pass the sweep maximum when reusing the runner across budgets).
    """
    if cfg.solver != "schur_cg":
        raise ValueError("partition='edges' supports solver='schur_cg' only "
                         f"(got {cfg.solver!r})")
    if spec.edge_kernel:
        raise ValueError(
            "partition='edges' is incompatible with edge_kernel=True: the "
            "Pallas pair needs the complete edge list; the sharded path uses "
            "the windowed-gather form instead")
    ndev = jax.device_count() if ndev is None else ndev
    m = spec.m
    m_loc = -(-m // ndev)
    m_pad = ndev * m_loc
    r_cap = int(np.asarray(spec.r)) if r_cap is None else int(r_cap)
    max_iters, chunk = engine._chunk_plan(cfg)
    meta = (spec.n, m, m_loc, spec.q, spec.hetero, spec.equality, spec.dtype,
            spec.psd_backend, spec.psd_iters,
            "jacobi" if spec.jd is not None else "none",
            spec.cg_inexact, spec.cg_tol, spec.cg_maxiter, r_cap,
            max_iters, chunk, cfg.eps, cfg.abort_nonfinite, ndev)
    runner = _get_runner(meta)
    ed, rd = _edge_repl_data(spec, m_pad)
    sst, it, res, hist = runner(ed, rd, _split_state(spec, state0, m_pad))
    history = engine._history_list(*hist)
    if cfg.verbose:
        tag = "admm-het-sh" if spec.hetero else "admm-homo-sh"
        for it_, res_, lam_ in history:
            print(f"[{tag}] it={it_} res={res_:.3e} lam~={lam_:.4f}")
    return engine._result_from(spec, _merge_state(spec, sst), it, res, history)


# ---------------------------------------------------------------------------
# Instance-partitioned drivers (restarts / sweeps as data parallelism)
# ---------------------------------------------------------------------------

def _pad_batch(tree, B_pad: int):
    """Pad the leading batch axis by repeating element 0 (dropped on the way
    out) so the batch divides the device count."""

    def pad(leaf):
        reps = B_pad - leaf.shape[0]
        if reps == 0:
            return leaf
        fill = jnp.broadcast_to(leaf[:1], (reps,) + leaf.shape[1:])
        return jnp.concatenate([leaf, fill], axis=0)

    return jax.tree.map(pad, tree)


def _place_instances(tree, mesh):
    def put(leaf):
        spec = P(_INST_AXIS, *([None] * (leaf.ndim - 1)))
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree)


def solve_batched_spec_sharded(spec: ProblemSpec, states: ADMMState,
                               cfg: ADMMConfig,
                               ndev: int | None = None) -> list[ADMMResult]:
    """``engine.solve_batched_spec`` with the restart batch laid out over the
    devices: leaves are placed under NamedSharding(P("inst", ...)) and the
    engine's vmapped driver follows the data — no per-iteration collectives,
    each device advances its slice of restarts independently."""
    ndev = jax.device_count() if ndev is None else ndev
    B = int(jax.tree.leaves(states)[0].shape[0])
    B_pad = -(-B // ndev) * ndev
    mesh = _instance_mesh(ndev)
    states_p = _place_instances(_pad_batch(states, B_pad), mesh)
    return engine.solve_batched_spec(spec, states_p, cfg)[:B]


def solve_sweep_spec_sharded(spec: ProblemSpec, rs, states: ADMMState,
                             cfg: ADMMConfig, rhos=None,
                             ndev: int | None = None) -> list[ADMMResult]:
    """``engine.solve_sweep_spec`` with sweep elements laid out over the
    devices (r and ρ are data leaves, so the padded elements re-solve
    element 0 and are dropped from the result list)."""
    ndev = jax.device_count() if ndev is None else ndev
    rs = jnp.asarray(rs, dtype=jnp.int64)
    B = int(rs.shape[0])
    B_pad = -(-B // ndev) * ndev
    mesh = _instance_mesh(ndev)
    rhos = (jnp.broadcast_to(spec.rho, rs.shape) if rhos is None
            else jnp.asarray(rhos, dtype=jnp.dtype(spec.dtype)))
    rs_p = _place_instances(_pad_batch(rs, B_pad), mesh)
    rhos_p = _place_instances(_pad_batch(rhos, B_pad), mesh)
    states_p = _place_instances(_pad_batch(states, B_pad), mesh)
    return engine.solve_sweep_spec(spec, rs_p, states_p, cfg, rhos=rhos_p)[:B]
