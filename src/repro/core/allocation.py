"""Algorithm 1 — Bandwidth-Aware Edge-Capacity Allocation.

Given per-node available bandwidths b, a total edge budget r, and per-node
degree caps ē, determine per-node edge counts e that maximize the minimum
per-edge ("unit") bandwidth b_unit. Faithful to the paper's pseudocode
(Eqs. 12–14), including the final trim step (lines 6–8).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["AllocationResult", "allocate_edge_capacity", "is_graphical", "graphical_repair"]


def is_graphical(d: np.ndarray) -> bool:
    """Erdős–Gallai test: is d realizable as a simple undirected graph?"""
    d = np.sort(np.asarray(d, dtype=np.int64))[::-1]
    n = d.shape[0]
    if d.sum() % 2 == 1 or (n and d[0] > n - 1) or np.any(d < 0):
        return False
    pre = np.cumsum(d)
    for k in range(1, n + 1):
        rhs = k * (k - 1) + sum(min(int(di), k) for di in d[k:])
        if pre[k - 1] > rhs:
            return False
    return True


def graphical_repair(e: np.ndarray, e_bar: np.ndarray | None = None) -> np.ndarray:
    """Minimal repair of a degree sequence to a graphical one (Σ preserved when
    possible). Algorithm 1 maximizes bandwidth but does not guarantee
    realizability (e.g. [5,5,5,5,1,1,1,1] fails Erdős–Gallai); this moves one
    unit of degree at a time from the largest-degree node to the node with the
    most headroom until the sequence is graphical (beyond-paper robustness,
    DESIGN.md §6)."""
    e = np.asarray(e, dtype=np.int64).copy()
    n = e.shape[0]
    if e_bar is None:
        e_bar = np.full(n, n - 1, dtype=np.int64)
    for _ in range(int(e.sum()) + n):
        if is_graphical(e):
            return e
        hi = int(np.argmax(e))
        headroom = np.minimum(e_bar, n - 1) - e
        headroom[hi] = -1
        lo = int(np.argmax(headroom))
        if headroom[lo] > 0:
            e[hi] -= 1
            e[lo] += 1
        else:
            e[hi] -= 2  # keep parity, shrink the infeasible peak
            e[hi] = max(e[hi], 0)
    return e


@dataclass
class AllocationResult:
    b_unit: float
    e: np.ndarray  # per-node edge counts
    feasible: bool


def allocate_edge_capacity(
    b: np.ndarray,
    r: int,
    e_bar: np.ndarray | None = None,
    max_rounds: int = 10_000,
) -> AllocationResult:
    """Run Algorithm 1.

    Args:
        b: node bandwidths (b_1, …, b_n).
        r: total number of edges to allocate.
        e_bar: per-node caps ē (defaults to n−1 each).

    Returns:
        AllocationResult with unit bandwidth and per-node counts e summing to
        ≥ 2r before the trim, == 2r after (when feasible).
    """
    b = np.asarray(b, dtype=np.float64)
    n = b.shape[0]
    if e_bar is None:
        e_bar = np.full(n, n - 1, dtype=np.int64)
    e_bar = np.asarray(e_bar, dtype=np.int64)

    # Eq. (12): start from the weakest node's bandwidth as the unit.
    b_unit = float(b.min())
    e = np.minimum(np.floor(b / b_unit).astype(np.int64), e_bar)
    edge_count = int(e.sum()) // 2

    rounds = 0
    while edge_count < r and rounds < max_rounds:
        rounds += 1
        # Eq. (13): shrink the unit bandwidth just enough to admit one more
        # edge at the node where that is cheapest.
        b_unit_new = float(np.max(b / (e + 1)))
        if b_unit_new >= b_unit:
            # All nodes capped — cannot add more edges by shrinking b_unit.
            if np.all(e >= e_bar):
                break
            b_unit_new = np.nextafter(b_unit, 0.0)
        b_unit = b_unit_new
        e = np.minimum(np.floor(b / b_unit + 1e-12).astype(np.int64), e_bar)
        edge_count = int(e.sum()) // 2
        if np.all(e >= e_bar):
            edge_count = int(e.sum()) // 2
            break

    # Lines 6–8: trim the largest-degree nodes until Σe/2 == r.
    while int(e.sum()) // 2 > r:
        k = int(np.argmax(e))
        e[k] -= 1

    # Degree-sum parity / handshake feasibility guard: Σe must be even and
    # each node's count realizable (e_i ≤ Σ_{j≠i} min(e_j, 1)·… — we only
    # enforce the Erdős–Gallai-lite necessary checks used downstream).
    if int(e.sum()) % 2 == 1:
        k = int(np.argmax(e))
        e[k] -= 1

    feasible = int(e.sum()) // 2 >= min(r, int(e_bar.sum()) // 2) or int(e.sum()) // 2 == r
    return AllocationResult(b_unit=b_unit, e=e, feasible=bool(feasible))
