"""Graph primitives for parameter-synchronization topologies.

Implements the notation of §III of the paper: undirected graphs G(N, E) with
edge-weight vector ``g``, incidence matrix ``A`` (Eq. 6), Laplacian
``L = A Diag(g) Aᵀ`` (Eq. 5), weight matrix ``W = I − L`` and the asymptotic
convergence factor ``r_asym(W) = max{|λ₂(W)|, |λₙ(W)|}`` (Eq. 3).

All constructors here are host-side (numpy); the ADMM solver consumes the
edge index arrays and runs in JAX.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "all_edges",
    "edge_index",
    "incidence_matrix",
    "laplacian_from_weights",
    "weight_matrix_from_weights",
    "r_asym",
    "r_asym_fast",
    "FAST_SPECTRAL_MIN_N",
    "spectral_gap",
    "degrees",
    "adjacency",
    "aspl",
    "is_connected",
    "Topology",
]

# Above this node count, ``Topology.r_asym`` (and the polish objective check)
# use the Lanczos largest-magnitude path; below it, full ``eigvalsh`` is
# faster (LAPACK's constant is tiny at small n — measured crossover is
# between n=128 and n=256 on CPU). The Lanczos path falls back to the
# exact one whenever ARPACK does not certify convergence.
FAST_SPECTRAL_MIN_N = 192


def all_edges(n: int) -> list[tuple[int, int]]:
    """Every candidate undirected edge {i, j}, i < j. |E| = n(n−1)/2."""
    return list(itertools.combinations(range(n), 2))


def edge_index(n: int) -> dict[tuple[int, int], int]:
    """Map (i, j) with i < j to its column index in the incidence matrix."""
    return {e: l for l, e in enumerate(all_edges(n))}


def incidence_matrix(n: int, edges: list[tuple[int, int]] | None = None) -> np.ndarray:
    """Signed incidence matrix A ∈ R^{n×m} (Eq. 6).

    For undirected graphs the arbitrary orientation (i→j for i<j) yields the
    same Laplacian.
    """
    if edges is None:
        edges = all_edges(n)
    A = np.zeros((n, len(edges)))
    for l, (i, j) in enumerate(edges):
        A[i, l] = 1.0
        A[j, l] = -1.0
    return A


def laplacian_from_weights(n: int, edges: list[tuple[int, int]], g: np.ndarray) -> np.ndarray:
    """L = A Diag(g) Aᵀ (Eq. 5) without materializing A."""
    L = np.zeros((n, n))
    for l, (i, j) in enumerate(edges):
        w = g[l]
        L[i, i] += w
        L[j, j] += w
        L[i, j] -= w
        L[j, i] -= w
    return L


def weight_matrix_from_weights(n: int, edges: list[tuple[int, int]], g: np.ndarray) -> np.ndarray:
    """W = I − L. Symmetric & doubly stochastic by construction (§IV-A)."""
    return np.eye(n) - laplacian_from_weights(n, edges, g)


def _is_doubly_stochastic(W: np.ndarray, atol: float = 1e-9) -> bool:
    """Row sums == 1 (for symmetric W that implies column sums too)."""
    return bool(np.allclose(W.sum(axis=1), 1.0, atol=atol))


def r_asym(W: np.ndarray, symmetric: bool | None = None) -> float:
    """Asymptotic convergence factor (Eq. 3): spectral radius of W − 11ᵀ/n.

    Works for non-symmetric (e.g. directed exponential) matrices too.

    ``symmetric`` is a caller hint that skips the O(n²) ``W == Wᵀ`` scan
    (callers that build W from ``laplacian_from_weights`` know it is
    symmetric). For symmetric doubly stochastic W the all-ones eigenpair
    (eigenvalue 1) is deflated *implicitly*: the spectrum of W − 11ᵀ/n is
    spec(W) with one copy of that eigenvalue replaced by 0, so we drop it
    from ``eigvalsh(W)`` instead of materializing the dense rank-1 shift.
    """
    n = W.shape[0]
    if n <= 1:
        return 0.0
    if symmetric is None:
        symmetric = bool(np.allclose(W, W.T, atol=1e-12))
    if symmetric:
        if _is_doubly_stochastic(W):
            ev = np.linalg.eigvalsh(W)
            k = int(np.argmin(np.abs(ev - 1.0)))
            ev = np.delete(ev, k)
            # the deflated eigenvalue becomes 0, which never wins the max
            return float(np.max(np.abs(ev), initial=0.0))
        ev = np.linalg.eigvalsh(W - 1.0 / n)  # scalar broadcast, no ones((n,n))
        return float(np.max(np.abs(ev)))
    ev = np.linalg.eigvals(W - 1.0 / n)
    return float(np.max(np.abs(ev)))


def r_asym_fast(W: np.ndarray, symmetric: bool | None = None,
                tol: float = 1e-10) -> float:
    """``r_asym`` via a Lanczos largest-magnitude eigenpair of M = W − 11ᵀ/n.

    Matvec-only: M v = W v − (Σv)/n · 1 — the rank-1 deflation is never
    materialized (and W is applied as a sparse CSR operator: mixing
    matrices have O(r) nonzeros, so each matvec is O(n + r) instead of
    n²). r_asym(W) is *exactly* the largest-magnitude eigenvalue of M:
    for symmetric doubly stochastic W, spec(M) is spec(W) with the
    all-ones eigenvalue replaced by 0, and 0 never wins the magnitude
    max. One ``which='LM'`` Lanczos pair (ARPACK) therefore suffices —
    much cheaper than resolving both spectrum ends separately.

    Falls back to the exact ``eigvalsh`` path whenever W is not symmetric
    doubly stochastic or ARPACK fails to converge to ``tol`` — callers
    get r_asym-parity to ~``tol`` unconditionally.
    """
    n = W.shape[0]
    if n <= 3:
        return r_asym(W, symmetric)
    if symmetric is None:
        symmetric = bool(np.allclose(W, W.T, atol=1e-12))
    if not symmetric or not _is_doubly_stochastic(W):
        return r_asym(W, symmetric)
    try:
        import scipy.sparse as sp
        from scipy.sparse.linalg import ArpackError, LinearOperator, eigsh
    except ImportError:
        return r_asym(W, True)
    Ws = sp.csr_matrix(W)
    op = LinearOperator((n, n), matvec=lambda v: Ws @ v - v.sum() / n,
                        dtype=np.float64)
    try:
        ev = eigsh(op, k=1, which="LM", tol=tol, return_eigenvectors=False)
    except ArpackError:
        # non-convergence (incl. ArpackNoConvergence): exact parity oracle.
        # Deliberately narrow — any other exception is a real bug and raises.
        return r_asym(W, True)
    return float(abs(ev[0]))


def spectral_gap(W: np.ndarray) -> float:
    return 1.0 - r_asym(W)


def degrees(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    d = np.zeros(n, dtype=np.int64)
    for i, j in edges:
        d[i] += 1
        d[j] += 1
    return d


def adjacency(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    Adj = np.zeros((n, n), dtype=bool)
    for i, j in edges:
        Adj[i, j] = Adj[j, i] = True
    return Adj


def _bfs_dists(adj_lists: list[list[int]], src: int) -> np.ndarray:
    n = len(adj_lists)
    dist = np.full(n, -1, dtype=np.int64)
    dist[src] = 0
    frontier = [src]
    d = 0
    while frontier:
        d += 1
        nxt = []
        for u in frontier:
            for v in adj_lists[u]:
                if dist[v] < 0:
                    dist[v] = d
                    nxt.append(v)
        frontier = nxt
    return dist


def _adj_lists(n: int, edges: list[tuple[int, int]]) -> list[list[int]]:
    al: list[list[int]] = [[] for _ in range(n)]
    for i, j in edges:
        al[i].append(j)
        al[j].append(i)
    return al


def aspl(n: int, edges: list[tuple[int, int]]) -> float:
    """Average shortest path length; +inf if disconnected.

    Used by the simulated-annealing warm start (§VI: small ASPL correlates
    with low communication delay [41]).
    """
    al = _adj_lists(n, edges)
    total = 0
    for s in range(n):
        dist = _bfs_dists(al, s)
        if np.any(dist < 0):
            return float("inf")
        total += int(dist.sum())
    return total / (n * (n - 1))


def is_connected(n: int, edges: list[tuple[int, int]]) -> bool:
    if n == 1:
        return True
    al = _adj_lists(n, edges)
    return bool(np.all(_bfs_dists(al, 0) >= 0))


@dataclass
class Topology:
    """A concrete parameter-synchronization topology: graph + weight matrix.

    ``edges`` lists the selected undirected edges; ``g`` their weights
    (aligned with ``edges``); ``W`` the full mixing matrix; ``name`` for
    reporting; ``directed_W`` may override W for directed baselines
    (exponential graph) — consensus simulation and r_asym use ``W``.
    """

    n: int
    edges: list[tuple[int, int]]
    g: np.ndarray
    name: str = "topology"
    meta: dict = field(default_factory=dict)

    @property
    def W(self) -> np.ndarray:
        if "W_override" in self.meta:
            return self.meta["W_override"]
        return weight_matrix_from_weights(self.n, self.edges, self.g)

    @property
    def r(self) -> int:
        return len(self.edges)

    @property
    def deg(self) -> np.ndarray:
        return degrees(self.n, self.edges)

    @property
    def max_degree(self) -> int:
        return int(self.deg.max()) if self.edges else 0

    def r_asym(self) -> float:
        W = self.W
        # W built from laplacian_from_weights is symmetric by construction;
        # a directed override (exponential graph) must take the general path.
        sym = None if "W_override" in self.meta else True
        if self.n >= FAST_SPECTRAL_MIN_N:
            return r_asym_fast(W, symmetric=sym)
        return r_asym(W, symmetric=sym)

    def validate(self, atol: float = 1e-8) -> None:
        W = self.W
        n = self.n
        assert W.shape == (n, n)
        ones = np.ones(n)
        np.testing.assert_allclose(W @ ones, ones, atol=atol)
        np.testing.assert_allclose(ones @ W, ones, atol=atol)
        assert is_connected(n, self.edges) or "W_override" in self.meta, "topology must be connected"
        assert r_asym(W) < 1.0 - 1e-9, "W must contract toward consensus"
