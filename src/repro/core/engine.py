"""Device-resident, batched ADMM solver engine (Algorithm 2, §V).

This module is the single implementation of the ADMM iteration for both the
homogeneous problem (Eq. 20) and the heterogeneous Mixed-Integer SDP
(Eq. 28). The problem data lives in a :class:`ProblemSpec` pytree and the
iterate in an :class:`ADMMState` pytree, so one pure ``step(spec, state)``
serves every scenario/backend combination and composes with ``jax.jit``,
``jax.lax.scan`` and ``jax.vmap``:

  - ``solve_spec``          — chunked, scan-compiled driver: ``check_every``
    iterations per device call, convergence checked on-device, residual/λ̃
    history recorded at chunk granularity. Eliminates the per-iteration
    host round-trip of a Python ``for`` loop (~``max_iters`` syncs/solve).
  - ``solve_python``        — the seed per-iteration host driver, kept both
    as the baseline for benchmarks and as the carrier for host-side
    backends (scipy ILU).
  - ``solve_batched_spec``  — ``jax.vmap`` of the scan driver over a batch
    of warm starts (restarts run in one compiled call).
  - ``solve_sweep_spec``    — ``jax.vmap`` over *problem* axes (cardinality
    budget r, penalty ρ) with per-element warm starts: many (n, r)
    scenarios amortize one compilation.

Variable layout (homogeneous, Eq. 20):
  X = (x, S, y, T)     with x = [g; λ̃] ∈ R^{m+1}
  Y = (x₁, S₁, y₁, T₁)
  duals D = (μ, Λ, σ, Γ)
Constraints C_X (Eq. 23):
  L(g) − λ̃I + S = −B₀,   L(g) + λ̃I + T = 2I,   diag(L(g)) + y = 1
Heterogeneous appends (z, ν, s) with M z (+ s) = e and g − z + ν = 0.

See DESIGN.md §2–§4 for the architecture rationale.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels.edge_laplacian import ops as _el_ops
from .graph import all_edges
from .linalg import ILUKKTSolver, kkt_bicgstab_solve, pcg_solve

# Enables the 64-bit dtype set; the solver precision actually used is a
# per-ProblemSpec choice (``ADMMConfig.dtype`` → ``ProblemSpec.dtype``),
# NOT a global default — fp32 specs stay fp32 end-to-end (DESIGN.md §9).
jax.config.update("jax_enable_x64", True)

__all__ = [
    "ADMMConfig", "ADMMResult", "ADMMState", "ProblemSpec",
    "make_homo_spec", "make_hetero_spec", "init_state", "step",
    "solve_spec", "solve_python", "solve_batched_spec", "solve_sweep_spec",
    "proj_psd", "proj_psd_ns", "proj_card_nonneg", "proj_binary_topr",
    "jacobi_diag", "build_sparse_A", "resolve_psd_backend",
]

# Inexact-ADMM CG tolerance schedule (DESIGN.md §9): relative tolerance
# η·√(previous squared primal residual), clipped to [cg_tol, cap] — loose
# while the splitting is far from consensus, tight near convergence.
INEXACT_ETA = 1e-2
INEXACT_CAP = 1e-3
# Relative CG tolerances below ~machine-ε are unreachable in fp32 and only
# burn ``cg_maxiter`` iterations per step; floor the request there.
FP32_TOL_FLOOR = 1e-6

# Measured eigh ↔ Newton–Schulz crossover for ``psd_backend="auto"``
# (benchmarks/bench_scalability.py --psd-crossover; table in DESIGN.md §13).
# On XLA:CPU the LAPACK eigh stays *faster* than the matmul-only NS-16
# iteration at every n ≤ 1024 (203 ms vs 848 ms at n=1024) — NS pays off
# only where matmul throughput towers over eigh, i.e. accelerators with
# matrix units (None = never switch on this platform).
NS_MIN_N = {"cpu": None, "default": 256}


def resolve_psd_backend(psd_backend: str, n: int,
                        platform: str | None = None) -> str:
    """Resolve ``psd_backend="auto"`` to a concrete backend for size n."""
    if psd_backend != "auto":
        return psd_backend
    platform = platform or jax.default_backend()
    thr = NS_MIN_N.get(platform, NS_MIN_N["default"])
    return "newton_schulz" if (thr is not None and n >= thr) else "eigh"


@dataclass
class ADMMConfig:
    rho: float = 5.0  # tuned on n=16, r=32: see DESIGN.md §5 (ρ=5 → 0.517 vs paper 0.52)
    alpha: float = 2.0  # Lemma 1 shift; any α ≥ λ_{n−1}(L) works, and λ < 2 always (Eq. 7)
    max_iters: int = 1500
    eps: float = 1e-7  # threshold on the summed squared primal residual (Alg. 2 line 4)
    solver: str = "schur_cg"  # schur_cg | kkt_bicgstab | kkt_bicgstab_ilu
    driver: str = "scan"  # scan (device-resident) | python (seed per-iteration loop)
    cg_tol: float = 1e-11
    cg_maxiter: int = 3000
    check_every: int = 10
    verbose: bool = False
    # -- solver performance stack (DESIGN.md §9) ----------------------------
    # NOTE: "none" is the measured-best default — the Schur complement is
    # identity-plus-structured-low-rank with a block-constant diagonal, so
    # Jacobi scaling splits its unit eigenvalue cluster and *costs* CG
    # iterations (~1.5–2.5×) on every paper scenario; see DESIGN.md §9.
    precond: str = "none"         # jacobi | none — Schur-complement CG preconditioner
    cg_inexact: bool = False      # adaptive CG tolerance tied to the primal residual
    psd_backend: str = "eigh"     # eigh (exact) | newton_schulz (matmul-only)
    #                             # | auto (platform/size crossover, NS_MIN_N)
    psd_iters: int = 30           # Newton–Schulz sign iterations
    dtype: str = "float64"        # float64 | float32 (fp32 loop, fp64 residuals)
    edge_kernel: bool = False     # route L(g)/quadform through the Pallas pair
    # -- multi-device layout (core.shard, DESIGN.md §13) --------------------
    partition: str = "none"       # none | edges | instances | auto
    # -- solver guard (core.guard, DESIGN.md §15) ---------------------------
    # A NaN/Inf squared primal residual can never recover (every later step
    # propagates it), so the chunked-scan driver treats non-finite exactly
    # like convergence and skips the remaining chunks instead of burning the
    # iteration budget on poisoned state. On fault-free runs the extra
    # predicate never fires and the trajectory is bit-exact (tested).
    abort_nonfinite: bool = True


@dataclass
class ADMMResult:
    g: np.ndarray          # edge weights (candidate-edge order), from x₁
    g_raw: np.ndarray      # from x (pre-projection side)
    lam_tilde: float
    z: np.ndarray | None   # binary edge selection (hetero only)
    iters: int
    residual: float
    history: list = field(default_factory=list)
    cg_iters: int = 0      # cumulative X-step CG iterations (schur_cg only)


# =========================================================================
# ProblemSpec — all problem data as one pytree
# =========================================================================

@partial(
    jax.tree_util.register_dataclass,
    data_fields=("r", "rho", "edge_ok", "c", "ei", "ej", "B0", "I", "M", "e_cap",
                 "jd", "lidx"),
    meta_fields=("n", "m", "q", "hetero", "equality", "cg_tol", "cg_maxiter",
                 "dtype", "psd_backend", "psd_iters", "cg_inexact",
                 "edge_kernel"),
)
@dataclass(frozen=True)
class ProblemSpec:
    """Pure-data description of one topology MI-SDP instance.

    ``meta`` fields are static (part of the jit cache key / tree structure);
    ``data`` fields are array leaves — notably ``r`` and ``rho`` are traced
    scalars so ``jax.vmap`` can batch over cardinality budgets and penalty
    weights without recompiling.
    """

    # -- static structure ---------------------------------------------------
    n: int
    m: int
    q: int                    # capacity rows (0 for the homogeneous problem)
    hetero: bool
    equality: bool
    cg_tol: float
    cg_maxiter: int
    # -- array leaves -------------------------------------------------------
    r: jnp.ndarray            # scalar int64 — cardinality budget
    rho: jnp.ndarray          # scalar — ADMM penalty (spec dtype)
    edge_ok: jnp.ndarray      # (m,) bool admissibility mask
    c: jnp.ndarray            # (m+1,) objective: minimize −λ̃
    ei: jnp.ndarray           # (m,) edge endpoints i < j
    ej: jnp.ndarray
    B0: jnp.ndarray           # (n, n) Lemma-1 shift α·11ᵀ/n
    I: jnp.ndarray            # (n, n)
    M: jnp.ndarray | None     # (q, m) capacity rows (hetero only)
    e_cap: jnp.ndarray | None # (q,) capacities (hetero only)
    # -- solver performance stack (DESIGN.md §9) ----------------------------
    jd: tuple | None = None   # diag(A Aᵀ) constraint-tree (Jacobi precond)
    lidx: jnp.ndarray | None = None  # (n, n) packed edge index; diag → m
    dtype: str = "float64"    # scan-loop/CG dtype; residuals always fp64
    psd_backend: str = "eigh"
    psd_iters: int = 30
    cg_inexact: bool = False
    edge_kernel: bool = False

    def replace(self, **kw) -> "ProblemSpec":
        return dataclasses.replace(self, **kw)


class ADMMState(NamedTuple):
    """One ADMM iterate. Block tuples have 4 entries (homo: x, S, y, T) or
    7 (hetero: + z, ν, s); structure is fixed by the spec's ``hetero`` flag.
    ``res``/``cg`` carry the previous squared primal residual (feeds the
    inexact-CG tolerance schedule) and the cumulative CG iteration count."""

    X: tuple   # primal blocks
    Y: tuple   # projected blocks (Y / Y′)
    D: tuple   # scaled duals
    lam: tuple # constraint-space multipliers (X-step warm start)
    res: jnp.ndarray  # previous iteration's squared primal residual (f64)
    cg: jnp.ndarray   # cumulative X-step CG iterations (int32)


def _edge_arrays(n: int):
    edges = all_edges(n)
    ei = jnp.array([i for i, _ in edges])
    ej = jnp.array([j for _, j in edges])
    return edges, ei, ej


def _packed_edge_index(n: int) -> jnp.ndarray:
    """(n, n) int32 map from (a, b) to the packed index of edge {a, b} in
    ``all_edges(n)`` order; the diagonal maps to the sentinel m (a zero slot
    appended to the weight vector). ``np.triu_indices`` enumerates the upper
    triangle row-major — the same lexicographic order as ``all_edges``."""
    m = n * (n - 1) // 2
    lidx = np.full((n, n), m, dtype=np.int32)
    iu = np.triu_indices(n, 1)
    lidx[iu] = np.arange(m, dtype=np.int32)
    lidx.T[iu] = np.arange(m, dtype=np.int32)
    return jnp.asarray(lidx)


def jacobi_diag(n: int, ei, ej, dtype, M=None, equality: bool = True):
    """Analytic diag(A Aᵀ) of the constraint operator, as a constraint-tree.

    Derived row-wise from the edge incidence structure (no materialization):
      - B̃∓ rows (P/Q blocks): entry (a,b) sums the squared coefficients of
        the primal unknowns appearing in ``L(g)[a,b] ∓ λ̃δ_ab + S/T[a,b]`` —
        1 per candidate edge {a,b} off-diagonal, deg(a) + 1 (λ̃) on the
        diagonal, + 1 for the slack block S/T.
      - D rows (w block): deg(a) ones from diag(L) + 1 for y.
      - capacity rows (u, hetero): ‖M_t‖² (+1 for the slack s when the
        constraint is an inequality).
      - coupling rows (v, hetero): g − z + ν → 1 + 1 + 1 = 3.
    """
    ei = jnp.asarray(ei)
    ej = jnp.asarray(ej)
    m = int(ei.shape[0])
    deg = jnp.zeros(n, dtype=dtype).at[ei].add(1.0).at[ej].add(1.0)
    C = jnp.zeros((n, n), dtype=dtype).at[ei, ej].add(1.0).at[ej, ei].add(1.0)
    diag = jnp.arange(n)
    dP = (C + 1.0).at[diag, diag].add(deg + 1.0)
    dw = deg + 1.0
    if M is None:
        return (dP, dP, dw)
    Mj = jnp.asarray(M, dtype=dtype)
    du = jnp.sum(Mj * Mj, axis=1) + (0.0 if equality else 1.0)
    du = jnp.maximum(du, jnp.asarray(1e-12, dtype))  # guard all-zero rows
    dv = jnp.full(m, 3.0, dtype=dtype)
    return (dP, dP, dw, du, dv)


def _validate_cfg(cfg: ADMMConfig) -> None:
    """Reject typo'd solver-stack selectors (a silently-ignored
    ``precond="Jacobi"`` would benchmark the wrong configuration)."""
    if cfg.precond not in ("jacobi", "none"):
        raise ValueError(f"unknown precond {cfg.precond!r}; expected 'jacobi' or 'none'")
    if cfg.psd_backend not in ("eigh", "newton_schulz", "auto"):
        raise ValueError(f"unknown psd_backend {cfg.psd_backend!r}; "
                         "expected 'eigh', 'newton_schulz' or 'auto'")
    if cfg.dtype not in ("float64", "float32"):
        raise ValueError(f"unknown dtype {cfg.dtype!r}; expected 'float64' or 'float32'")
    if cfg.partition not in ("none", "edges", "instances", "auto"):
        raise ValueError(f"unknown partition {cfg.partition!r}; expected "
                         "'none', 'edges', 'instances' or 'auto'")


def make_homo_spec(n: int, r: int, cfg: ADMMConfig,
                   edge_ok: np.ndarray | None = None) -> ProblemSpec:
    _validate_cfg(cfg)
    _, ei, ej = _edge_arrays(n)
    m = ei.shape[0]
    dt = jnp.dtype(cfg.dtype)
    ok = jnp.ones(m, dtype=bool) if edge_ok is None else jnp.asarray(edge_ok, dtype=bool)
    r_eff = min(int(r), int(np.asarray(ok).sum()))
    return ProblemSpec(
        n=n, m=m, q=0, hetero=False, equality=True,
        cg_tol=cfg.cg_tol, cg_maxiter=cfg.cg_maxiter,
        r=jnp.asarray(r_eff, dtype=jnp.int64),
        rho=jnp.asarray(cfg.rho, dtype=dt),
        edge_ok=ok,
        c=jnp.zeros(m + 1, dtype=dt).at[m].set(-1.0),
        ei=ei, ej=ej,
        B0=cfg.alpha * jnp.ones((n, n), dtype=dt) / n,
        I=jnp.eye(n, dtype=dt),
        M=None, e_cap=None,
        jd=jacobi_diag(n, ei, ej, dt) if cfg.precond == "jacobi" else None,
        lidx=_packed_edge_index(n),
        dtype=cfg.dtype, psd_backend=resolve_psd_backend(cfg.psd_backend, n),
        psd_iters=cfg.psd_iters,
        cg_inexact=cfg.cg_inexact, edge_kernel=cfg.edge_kernel,
    )


def make_hetero_spec(n: int, r: int, M: np.ndarray, e_cap: np.ndarray,
                     cfg: ADMMConfig, equality: bool = True,
                     edge_ok: np.ndarray | None = None) -> ProblemSpec:
    _validate_cfg(cfg)
    _, ei, ej = _edge_arrays(n)
    m = int(ei.shape[0])
    assert M.shape[1] == m, f"M must cover all {m} candidate edges"
    dt = jnp.dtype(cfg.dtype)
    ok = jnp.ones(m, dtype=bool) if edge_ok is None else jnp.asarray(edge_ok, dtype=bool)
    r_eff = min(int(r), int(np.asarray(ok).sum()))
    return ProblemSpec(
        n=n, m=m, q=int(M.shape[0]), hetero=True, equality=equality,
        cg_tol=cfg.cg_tol, cg_maxiter=cfg.cg_maxiter,
        r=jnp.asarray(r_eff, dtype=jnp.int64),
        rho=jnp.asarray(cfg.rho, dtype=dt),
        edge_ok=ok,
        c=jnp.zeros(m + 1, dtype=dt).at[m].set(-1.0),
        ei=ei, ej=ej,
        B0=cfg.alpha * jnp.ones((n, n), dtype=dt) / n,
        I=jnp.eye(n, dtype=dt),
        M=jnp.asarray(M, dtype=dt),
        e_cap=jnp.asarray(e_cap, dtype=dt),
        jd=(jacobi_diag(n, ei, ej, dt, M=M, equality=equality)
            if cfg.precond == "jacobi" else None),
        lidx=_packed_edge_index(n),
        dtype=cfg.dtype, psd_backend=resolve_psd_backend(cfg.psd_backend, n),
        psd_iters=cfg.psd_iters,
        cg_inexact=cfg.cg_inexact, edge_kernel=cfg.edge_kernel,
    )


# =========================================================================
# Projections (Eq. 24/25/30) — r may be a traced scalar
# =========================================================================

def proj_psd(M: jnp.ndarray, sign: float) -> jnp.ndarray:
    """Eq. 25: eigenvalue clipping. sign=+1 → PSD (T₁ ≽ 0), −1 → NSD (S₁ ≼ 0)."""
    Msym = (M + M.T) / 2.0
    ev, U = jnp.linalg.eigh(Msym)
    ev = jnp.maximum(ev, 0.0) if sign > 0 else jnp.minimum(ev, 0.0)
    return (U * ev) @ U.T


def proj_psd_ns(M: jnp.ndarray, sign: float, iters: int = 30) -> jnp.ndarray:
    """Matmul-only PSD/NSD projection via Newton–Schulz polar iteration.

    P_±(M) = (M ± |M|)/2 with |M| = sign(M)·M; the matrix sign is iterated
    as X ← (3X − X³)/2 from X₀ = M/‖M‖_F (Frobenius normalization bounds
    the spectral radius by 1, the iteration's convergence region). Two n³
    matmuls per iteration, no eigendecomposition — MXU-friendly where
    ``eigh`` serializes. Deviation from the exact projection is O(|λ|) for
    eigenvalues |λ|/‖M‖_F ≲ 1.5^{−iters} (the sign iterate has not
    saturated there); the parity test bounds it empirically.
    """
    Msym = (M + M.T) / 2.0
    nrm = jnp.sqrt(jnp.sum(Msym * Msym)) + jnp.asarray(1e-30, Msym.dtype)
    Y = Msym / nrm

    def body(_, X):
        return 1.5 * X - 0.5 * (X @ X @ X)

    X = lax.fori_loop(0, iters, body, Y)
    absM = nrm * (X @ Y)
    absM = (absM + absM.T) / 2.0
    return (Msym + absM) / 2.0 if sign > 0 else (Msym - absM) / 2.0


def proj_card_nonneg(v: jnp.ndarray, r, ok: jnp.ndarray) -> jnp.ndarray:
    """Project onto {g ≥ 0, Card(g) ≤ r} ∩ {g_l = 0 for inadmissible l}.

    Keep the largest r nonnegative entries (Eq. 24 discussion), zero the
    rest. ``r`` may be a Python int or a traced int scalar (the threshold is
    read from the sorted vector at a dynamic index, so cardinality sweeps
    can be vmapped).
    """
    v = jnp.where(ok, jnp.maximum(v, 0.0), 0.0)
    m = v.shape[0]
    r = jnp.asarray(r)
    desc = -jnp.sort(-v)
    # (r+1)-th largest; r ≥ m keeps every nonnegative entry (threshold < 0)
    thresh = jnp.where(r >= m, -1.0, desc[jnp.minimum(r, m - 1)])
    keep = v > jnp.maximum(thresh, 0.0)
    return jnp.where(keep, v, 0.0)


def proj_binary_topr(v: jnp.ndarray, r, ok: jnp.ndarray) -> jnp.ndarray:
    """Heterogeneous z₁ projection: largest r entries → 1, others → 0 (§V-B).

    Ties break to the lowest index (stable sort); ``+ 0.0`` folds −0.0
    into +0.0 so signed-zero ties are index-ordered too (``lax.top_k``'s
    total order instead ranks +0.0 above −0.0 — the one input class where
    this deviates from the seed's top_k formulation).
    """
    v = jnp.where(ok, v + 0.0, -jnp.inf)
    m = v.shape[0]
    order = jnp.argsort(-v)  # stable: ties keep lowest index first
    rank = jnp.zeros(m, dtype=jnp.int64).at[order].set(jnp.arange(m))
    return (rank < jnp.asarray(r)).astype(v.dtype)


# =========================================================================
# Matrix-free constraint operator A, its adjoint, and the RHS b
# =========================================================================

def _L_of_g(spec: ProblemSpec, g: jnp.ndarray) -> jnp.ndarray:
    """Laplacian of the packed edge-weight vector.

    Default: the fused gather form — unpack g through the precomputed
    packed-index map ``spec.lidx`` (diagonal hits the appended zero slot)
    and assemble L = Diag(G·1) − G in one pass. This is the same math the
    ``edge_laplacian`` Pallas kernel runs tile-wise; as pure JAX it replaces
    the seed's 4 scatter-adds, which XLA:CPU serializes (~40× slower at
    n=128). ``spec.edge_kernel`` routes to the Pallas pair instead; specs
    without ``lidx`` keep the scatter fallback.
    """
    if spec.edge_kernel:
        return _el_ops.edge_laplacian(g, spec.ei, spec.ej, spec.n)
    if spec.lidx is not None:
        g_ext = jnp.concatenate([g, jnp.zeros(1, dtype=g.dtype)])
        G = g_ext[spec.lidx]
        return jnp.diag(jnp.sum(G, axis=1)) - G
    ei, ej = spec.ei, spec.ej
    L = jnp.zeros((spec.n, spec.n), dtype=g.dtype)
    L = L.at[ei, ej].add(-g).at[ej, ei].add(-g)
    L = L.at[ei, ei].add(g).at[ej, ej].add(g)
    return L


def _edge_quadform(spec: ProblemSpec, P: jnp.ndarray) -> jnp.ndarray:
    """⟨∂L/∂g_l, P⟩ = P_ii + P_jj − P_ij − P_ji per edge l = {i, j}."""
    if spec.edge_kernel:
        return _el_ops.edge_quadform(P, spec.ei, spec.ej)
    ei, ej = spec.ei, spec.ej
    return P[ei, ei] + P[ej, ej] - P[ei, ej] - P[ej, ei]


def _deg_sum(spec: ProblemSpec, w: jnp.ndarray) -> jnp.ndarray:
    """(Dᵀ w)_l = w_i + w_j."""
    return w[spec.ei] + w[spec.ej]


def A_op(spec: ProblemSpec, X):
    """Constraint operator: 3 blocks (Eq. 23) plus capacity/coupling rows
    (Eq. 29) when heterogeneous."""
    x, S, y, T = X[:4]
    g, lam = x[:-1], x[-1]
    L = _L_of_g(spec, g)
    I = spec.I
    base = (L - lam * I + S, L + lam * I + T, jnp.diag(L) + y)
    if not spec.hetero:
        return base
    z, nu, s = X[4], X[5], X[6]
    r4 = spec.M @ z + (0.0 if spec.equality else s)
    r5 = g - z + nu
    return base + (r4, r5)


def AT_op(spec: ProblemSpec, lamv):
    if not spec.hetero:
        P, Q, w = lamv
        xg = _edge_quadform(spec, P + Q) + _deg_sum(spec, w)
        xl = -jnp.trace(P) + jnp.trace(Q)
        return (jnp.concatenate([xg, xl[None]]), P, w, Q)
    P, Q, w, u, v = lamv
    xg = _edge_quadform(spec, P + Q) + _deg_sum(spec, w) + v
    xl = -jnp.trace(P) + jnp.trace(Q)
    x_adj = jnp.concatenate([xg, xl[None]])
    z_adj = spec.M.T @ u - v
    s_adj = u if not spec.equality else jnp.zeros_like(u)
    return (x_adj, P, w, Q, z_adj, v, s_adj)


def b_rhs(spec: ProblemSpec):
    dt = spec.B0.dtype
    base = (-spec.B0, 2.0 * spec.I, jnp.ones(spec.n, dtype=dt))
    if not spec.hetero:
        return base
    return base + (spec.e_cap, jnp.zeros(spec.m, dtype=dt))


# =========================================================================
# The unified ADMM step (Alg. 2 lines 5–8 / 12–15)
# =========================================================================

def _project_blocks(spec: ProblemSpec, U):
    """Y-update (Eq. 24 / Eq. 30): per-block Euclidean projections."""
    m = spec.m
    if spec.psd_backend == "newton_schulz":
        psd = partial(proj_psd_ns, iters=spec.psd_iters)
    else:
        psd = proj_psd
    x1 = jnp.concatenate([
        proj_card_nonneg(U[0][:m], spec.r, spec.edge_ok),
        jnp.maximum(U[0][m], 0.0)[None],
    ])
    S1 = psd(U[1], sign=-1.0)
    y1 = jnp.maximum(U[2], 0.0)
    T1 = psd(U[3], sign=+1.0)
    if not spec.hetero:
        return (x1, S1, y1, T1)
    z1 = proj_binary_topr(U[4], spec.r, spec.edge_ok)
    nu1 = jnp.maximum(U[5], 0.0)
    # without a slack variable the s-block stays pinned at 0
    s1 = jnp.zeros_like(U[6]) if spec.equality else jnp.maximum(U[6], 0.0)
    return (x1, S1, y1, T1, z1, nu1, s1)


def _xstep_target(spec: ProblemSpec, Y, D):
    """V = Y − (D + c·e₀)/ρ for the X-update (Eq. 27 / 31)."""
    V = tuple(jax.tree.map(lambda y1, d: y1 - d / spec.rho, Y, D))
    V = (V[0] - spec.c / spec.rho,) + V[1:]
    if spec.hetero and spec.equality:
        V = V[:6] + (jnp.zeros_like(V[6]),)
    return V


def _cg_tolerance(spec: ProblemSpec, prev_res):
    """Per-iteration relative CG tolerance (DESIGN.md §9).

    Exact mode: ``cg_tol``, floored at what the spec dtype can resolve.
    Inexact mode: η·√(previous squared primal residual), clipped to
    [floored cg_tol, cap] — the first iteration (res = ∞) starts at the cap.
    """
    floor = FP32_TOL_FLOOR if jnp.dtype(spec.dtype) == jnp.float32 else 0.0
    tol0 = max(spec.cg_tol, floor)
    if not spec.cg_inexact:
        return tol0
    cap = max(INEXACT_CAP, tol0)
    return jnp.clip(INEXACT_ETA * jnp.sqrt(prev_res), tol0, cap)


def step(spec: ProblemSpec, state: ADMMState, backend: str = "schur_cg"):
    """One ADMM iteration: Y-projection, X-step KKT solve, dual update.

    Pure and jittable for the JAX backends; ``vmap``/``scan`` compose over
    it. Returns ``(new_state, squared primal residual)``; the residual is
    always accumulated in float64, whatever the spec dtype.
    """
    rho = spec.rho
    U = tuple(jax.tree.map(lambda x, d: x + d / rho, state.X, state.D))
    Y = _project_blocks(spec, U)
    V = _xstep_target(spec, Y, state.D)
    A = partial(A_op, spec)
    AT = partial(AT_op, spec)
    tol = _cg_tolerance(spec, state.res)
    cg_it = jnp.asarray(0, jnp.int32)
    if backend == "schur_cg":
        Xn, lam, cg_it = pcg_solve(A, AT, V, b_rhs(spec), state.lam,
                                   jd=spec.jd, tol=tol,
                                   maxiter=spec.cg_maxiter)
    elif backend == "kkt_bicgstab":
        Xn, lam = kkt_bicgstab_solve(A, AT, V, b_rhs(spec), state.X, state.lam,
                                     tol=tol, maxiter=spec.cg_maxiter)
    else:
        raise ValueError(f"unknown device backend {backend!r}")
    Xn = tuple(Xn)
    if spec.hetero and spec.equality:
        Xn = Xn[:6] + (jnp.zeros_like(Xn[6]),)
    D = tuple(jax.tree.map(lambda d, xn, y1: d + rho * (xn - y1), state.D, Xn, Y))
    res = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda xn, y1: jnp.sum((xn - y1).astype(jnp.float64) ** 2),
                     Xn, Y),
    )
    return ADMMState(X=Xn, Y=Y, D=D, lam=tuple(lam), res=res,
                     cg=state.cg + cg_it), res


def init_state(spec: ProblemSpec, g: jnp.ndarray, lam0,
               z: jnp.ndarray | None = None) -> ADMMState:
    """Initial iterate from a warm start. Pure JAX — composes with vmap."""
    n, m = spec.n, spec.m
    dt = jnp.dtype(spec.dtype)
    g = jnp.asarray(g, dtype=dt)
    lam0 = jnp.asarray(lam0, dtype=dt)
    x = jnp.concatenate([g, lam0[None]])
    L = _L_of_g(spec, g)
    S = -(L - lam0 * spec.I + spec.B0)
    T = 2 * spec.I - (L + lam0 * spec.I)
    y = 1.0 - jnp.diag(L)
    zn2 = jnp.zeros((n, n), dtype=dt)
    res0 = jnp.asarray(jnp.inf, jnp.float64)
    cg0 = jnp.asarray(0, jnp.int32)
    if not spec.hetero:
        X = (x, S, y, T)
        D = (jnp.zeros(m + 1, dtype=dt), zn2, jnp.zeros(n, dtype=dt), zn2)
        lam = (zn2, zn2, jnp.zeros(n, dtype=dt))
        return ADMMState(X=X, Y=X, D=D, lam=lam, res=res0, cg=cg0)
    q = spec.q
    z = (g > 0).astype(dt) if z is None else jnp.asarray(z, dtype=dt)
    nu = z - g
    s = (jnp.zeros(q, dtype=dt) if spec.equality
         else jnp.maximum(spec.e_cap - spec.M @ z, 0.0))
    X = (x, S, y, T, z, nu, s)
    D = (jnp.zeros(m + 1, dtype=dt), zn2, jnp.zeros(n, dtype=dt), zn2,
         jnp.zeros(m, dtype=dt), jnp.zeros(m, dtype=dt), jnp.zeros(q, dtype=dt))
    lam = (zn2, zn2, jnp.zeros(n, dtype=dt), jnp.zeros(q, dtype=dt),
           jnp.zeros(m, dtype=dt))
    return ADMMState(X=X, Y=X, D=D, lam=lam, res=res0, cg=cg0)


# =========================================================================
# Drivers
# =========================================================================

def _run_chunks(spec: ProblemSpec, state0: ADMMState, max_iters: int,
                check_every: int, eps: float, backend: str,
                abort_nonfinite: bool = True):
    """Device-resident driver: scan over chunks of ``check_every`` steps
    (the last chunk is shortened so exactly ``max_iters`` iterations run).

    Convergence is checked on-device once per chunk; a converged carry
    skips the remaining chunks via ``lax.cond`` (under ``vmap`` the cond
    lowers to a select, so batched solves run all chunks — still one
    device call for the whole batch). History ys: (it, res, λ̃) per chunk.

    ``abort_nonfinite`` (the solver-guard flag, DESIGN.md §15) adds a
    non-finite test to the same on-device check: a NaN/Inf residual marks
    the carry done so the remaining chunks are skipped — the poisoned
    residual survives into the result, where ``core.guard`` classifies it
    as ``non_finite``. The predicate never fires on finite trajectories,
    so the fault-free path is bit-exact with the flag off (tested).
    """
    n_chunks = -(-max_iters // check_every)
    last = max_iters - check_every * (n_chunks - 1)
    lengths = jnp.full(n_chunks, check_every, dtype=jnp.int64).at[-1].set(last)

    def chunk_fn(carry, clen):
        st, it, res, done = carry

        def one_chunk(operand):
            st_, _ = operand

            def body(_, val):
                st2, _ = val
                return step(spec, st2, backend)

            return lax.fori_loop(0, clen, body, (st_, jnp.asarray(jnp.inf)))

        st2, res2 = lax.cond(done, lambda op: op, one_chunk, (st, res))
        it2 = jnp.where(done, it, it + clen)
        done2 = done | (res2 < eps)
        if abort_nonfinite:
            done2 = done2 | ~jnp.isfinite(res2)
        return (st2, it2, res2, done2), (it2, res2, st2.X[0][-1])

    init = (state0, jnp.asarray(0, dtype=jnp.int64), jnp.asarray(jnp.inf),
            jnp.asarray(False))
    (st, it, res, _), hist = lax.scan(chunk_fn, init, lengths)
    return st, it, res, hist


@partial(jax.jit, static_argnames=("max_iters", "check_every", "eps", "backend",
                                   "abort_nonfinite"))
def _solve_device(spec, state0, max_iters, check_every, eps, backend,
                  abort_nonfinite=True):
    return _run_chunks(spec, state0, max_iters, check_every, eps, backend,
                       abort_nonfinite)


@partial(jax.jit, static_argnames=("max_iters", "check_every", "eps", "backend",
                                   "abort_nonfinite"))
def _solve_device_batched(spec, states, max_iters, check_every, eps, backend,
                          abort_nonfinite=True):
    return jax.vmap(
        lambda st: _run_chunks(spec, st, max_iters, check_every, eps, backend,
                               abort_nonfinite)
    )(states)


@partial(jax.jit, static_argnames=("max_iters", "check_every", "eps", "backend",
                                   "abort_nonfinite"))
def _solve_device_sweep(spec, rs, rhos, states, max_iters, check_every, eps,
                        backend, abort_nonfinite=True):
    def one(r, rho, st):
        return _run_chunks(spec.replace(r=r, rho=rho), st, max_iters,
                           check_every, eps, backend, abort_nonfinite)

    return jax.vmap(one)(rs, rhos, states)


def _history_list(its, ress, lams) -> list:
    hist, prev = [], 0
    for it_, res_, lam_ in zip(np.asarray(its), np.asarray(ress), np.asarray(lams)):
        it_ = int(it_)
        if it_ <= prev:  # converged carry repeats the last chunk's entry
            continue
        hist.append((it_, float(res_), float(lam_)))
        prev = it_
    return hist


def _result_from(spec: ProblemSpec, st: ADMMState, iters, res, history) -> ADMMResult:
    m = spec.m
    x, x1 = st.X[0], st.Y[0]
    return ADMMResult(
        g=np.asarray(x1[:m]), g_raw=np.asarray(x[:m]), lam_tilde=float(x1[m]),
        z=np.asarray(st.Y[4]) if spec.hetero else None,
        iters=int(iters), residual=float(res), history=history,
        cg_iters=int(st.cg),
    )


def _chunk_plan(cfg: ADMMConfig) -> tuple[int, int]:
    """(max_iters, chunk_len): convergence is checked every ``chunk_len``
    iterations; the driver runs exactly ``max_iters`` iterations at most."""
    return cfg.max_iters, min(cfg.check_every, cfg.max_iters)


def solve_spec(spec: ProblemSpec, state0: ADMMState, cfg: ADMMConfig) -> ADMMResult:
    """Scan-compiled solve: one (or a few) device calls for the whole run."""
    max_iters, chunk = _chunk_plan(cfg)
    st, it, res, hist = _solve_device(
        spec, state0, max_iters=max_iters, check_every=chunk,
        eps=cfg.eps, backend=cfg.solver,
        abort_nonfinite=cfg.abort_nonfinite)
    history = _history_list(*hist)
    if cfg.verbose:
        tag = "admm-het" if spec.hetero else "admm-homo"
        for it_, res_, lam_ in history:
            print(f"[{tag}] it={it_} res={res_:.3e} lam~={lam_:.4f}")
    return _result_from(spec, st, it, res, history)


def solve_batched_spec(spec: ProblemSpec, states: ADMMState,
                       cfg: ADMMConfig) -> list[ADMMResult]:
    """Batched restarts: ``states`` has a leading batch axis on every leaf;
    all restarts advance in one vmapped, scan-compiled device call."""
    max_iters, chunk = _chunk_plan(cfg)
    sts, its, ress, hists = _solve_device_batched(
        spec, states, max_iters=max_iters, check_every=chunk,
        eps=cfg.eps, backend=cfg.solver,
        abort_nonfinite=cfg.abort_nonfinite)
    batch = int(np.asarray(its).shape[0])
    out = []
    for b in range(batch):
        st_b = jax.tree.map(lambda a: a[b], sts)
        # vmap puts the batch axis first: hists[k] is (batch, n_chunks)
        hist = _history_list(hists[0][b], hists[1][b], hists[2][b])
        out.append(_result_from(spec, st_b, its[b], ress[b], hist))
    return out


def solve_sweep_spec(spec: ProblemSpec, rs, states: ADMMState, cfg: ADMMConfig,
                     rhos=None) -> list[ADMMResult]:
    """Sweep over problem axes: element k solves the instance with budget
    ``rs[k]`` (and optionally penalty ``rhos[k]``) from warm start k. All
    instances share ``spec``'s shape (same n), so one compilation serves
    the whole sweep."""
    rs = jnp.asarray(rs, dtype=jnp.int64)
    rhos = (jnp.broadcast_to(spec.rho, rs.shape) if rhos is None
            else jnp.asarray(rhos, dtype=jnp.dtype(spec.dtype)))
    max_iters, chunk = _chunk_plan(cfg)
    sts, its, ress, hists = _solve_device_sweep(
        spec, rs, rhos, states, max_iters=max_iters, check_every=chunk,
        eps=cfg.eps, backend=cfg.solver,
        abort_nonfinite=cfg.abort_nonfinite)
    out = []
    for b in range(int(rs.shape[0])):
        st_b = jax.tree.map(lambda a: a[b], sts)
        hist = _history_list(hists[0][b], hists[1][b], hists[2][b])
        out.append(_result_from(spec.replace(r=rs[b]), st_b, its[b], ress[b], hist))
    return out


_jit_step = jax.jit(step, static_argnames=("backend",))


def solve_python(spec: ProblemSpec, state0: ADMMState, cfg: ADMMConfig,
                 step_fn=None, reuse_jit: bool = True) -> ADMMResult:
    """Per-iteration host driver: one device call and one blocking
    ``float(res)`` sync per iteration. Kept as (a) the benchmark baseline
    the scan driver is measured against and (b) the carrier for host-side
    backends (``step_fn`` = ILU closure).

    By default the step shares the module-level jit cache, so repeated
    solves compile once (like the scan driver). ``reuse_jit=False`` jits
    per solve instead — the *seed's* cost structure, which jitted per
    solver instance so every benchmark solve and every restart recompiled;
    the benchmark uses it as the seed-faithful baseline (DESIGN.md §4)."""
    if step_fn is None:
        if reuse_jit:
            backend = cfg.solver
            step_fn = lambda st: _jit_step(spec, st, backend=backend)  # noqa: E731
        else:
            step_fn = jax.jit(partial(step, spec, backend=cfg.solver))
    state, history, res = state0, [], np.inf
    it = 0
    for it in range(1, cfg.max_iters + 1):
        state, res = step_fn(state)
        res = float(res)
        if it % cfg.check_every == 0 or it == 1:
            history.append((it, res, float(state.X[0][-1])))
            if cfg.verbose:
                tag = "admm-het" if spec.hetero else "admm-homo"
                print(f"[{tag}] it={it} res={res:.3e} lam~={float(state.X[0][-1]):.4f}")
        if res < cfg.eps:
            break
        if cfg.abort_nonfinite and not np.isfinite(res):
            break  # poisoned state can never recover (core.guard classifies)
    return _result_from(spec, state, it, res, history)


# =========================================================================
# Host-side ILU backend (paper-faithful §V-C) — homogeneous problem
# =========================================================================

def build_sparse_A(n: int, m: int, edges) -> "Any":
    """Materialize the homogeneous constraint operator A (Nc × Nx) as a
    scipy CSC matrix for the ILU-preconditioned KKT backend."""
    import scipy.sparse as sp

    rows, cols, vals = [], [], []

    def vecidx(i, j):  # column-major vec
        return i + j * n

    # B̃⁻ / B̃⁺ blocks (n² rows each) acting on x = [g; λ̃]
    for l, (i, j) in enumerate(edges):
        for (a, b2, v) in ((i, i, 1.0), (j, j, 1.0), (i, j, -1.0), (j, i, -1.0)):
            rows.append(vecidx(a, b2)); cols.append(l); vals.append(v)           # B⁻
            rows.append(n * n + vecidx(a, b2)); cols.append(l); vals.append(v)   # B⁺
    for i in range(n):
        rows.append(vecidx(i, i)); cols.append(m); vals.append(-1.0)   # −λ̃ I
        rows.append(n * n + vecidx(i, i)); cols.append(m); vals.append(1.0)
    # D block: diag(L) rows
    for l, (i, j) in enumerate(edges):
        rows.append(2 * n * n + i); cols.append(l); vals.append(1.0)
        rows.append(2 * n * n + j); cols.append(l); vals.append(1.0)
    Nx = m + 1 + n * n + n + n * n
    Nc = 2 * n * n + n
    Ax = sp.csr_matrix(sp.coo_matrix((vals, (rows, cols)), shape=(Nc, m + 1)))
    A = sp.bmat([
        [Ax[: n * n, :], sp.eye(n * n), sp.coo_matrix((n * n, n)), sp.coo_matrix((n * n, n * n))],
        [Ax[n * n: 2 * n * n, :], sp.coo_matrix((n * n, n * n)), sp.coo_matrix((n * n, n)), sp.eye(n * n)],
        [Ax[2 * n * n:, :], sp.coo_matrix((n, n * n)), sp.eye(n), sp.coo_matrix((n, n * n))],
    ], format="csc")
    assert A.shape == (Nc, Nx)
    return A


def _pack_homo(X) -> np.ndarray:
    x, S, y, T = X
    return np.concatenate([np.asarray(x), np.asarray(S).ravel(order="F"),
                           np.asarray(y), np.asarray(T).ravel(order="F")])


def _unpack_homo(n: int, m: int, v: np.ndarray):
    o = 0
    x = v[o:o + m + 1]; o += m + 1
    S = v[o:o + n * n].reshape(n, n, order="F"); o += n * n
    y = v[o:o + n]; o += n
    T = v[o:o + n * n].reshape(n, n, order="F")
    return (jnp.asarray(x), jnp.asarray(S), jnp.asarray(y), jnp.asarray(T))


def make_ilu_step(spec: ProblemSpec, ilu: ILUKKTSolver | None = None):
    """Host-side step closure behind the same ``(state) → (state, res)``
    interface as the jitted unified step. Homogeneous problem only."""
    if spec.hetero:
        raise ValueError("the ILU backend supports the homogeneous problem only")
    if spec.dtype != "float64":
        raise ValueError("the scipy-ILU backend requires dtype='float64'")
    if ilu is None:
        edges = all_edges(spec.n)
        ilu = ILUKKTSolver(build_sparse_A(spec.n, spec.m, edges))
    b = b_rhs(spec)
    bp = np.concatenate([np.asarray(b[0]).ravel(order="F"),
                         np.asarray(b[1]).ravel(order="F"), np.asarray(b[2])])
    rho = float(spec.rho)

    def step_ilu(state: ADMMState):
        U = tuple(jax.tree.map(lambda x, d: x + d / rho, state.X, state.D))
        Y = _project_blocks(spec, U)
        V = _xstep_target(spec, Y, state.D)
        Xv, _ = ilu.solve(_pack_homo(V), bp, tol=spec.cg_tol)
        Xn = _unpack_homo(spec.n, spec.m, Xv)
        D = tuple(jax.tree.map(lambda d, xn, y1: d + rho * (xn - y1),
                               state.D, Xn, Y))
        res = sum(float(jnp.sum((xn - y1) ** 2)) for xn, y1 in zip(Xn, Y))
        return ADMMState(X=Xn, Y=Y, D=D, lam=state.lam,
                         res=jnp.asarray(res, jnp.float64), cg=state.cg), res

    return step_ilu
