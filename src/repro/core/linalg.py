"""Linear-system backends for the ADMM X-step (§V-C).

The X-step solves the KKT system (Eq. 27 / 31):

    [[I, Aᵀ], [A, 0]] [X; λ] = [V; b]        ⇔    X = V − Aᵀλ,  (A Aᵀ) λ = A V − b

Backends:
  - ``schur_cg``        (default, beyond paper): matrix-free CG on the SPD
    Schur complement A Aᵀ — pure JAX, jittable, O(n² + |E|) per matvec.
  - ``kkt_bicgstab``    : matrix-free Bi-CGSTAB on the indefinite KKT system,
    pure JAX — the paper's iterative method without preconditioning.
  - ``kkt_bicgstab_ilu``: paper-faithful — materialize the sparse KKT matrix
    once (CSC), precompute ILU (scipy ``spilu``), use it as a Bi-CGSTAB
    preconditioner [37, 38, 39].
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

__all__ = ["schur_cg_solve", "kkt_bicgstab_solve", "ILUKKTSolver"]


def schur_cg_solve(
    A_op: Callable,
    AT_op: Callable,
    V,
    b,
    lam0,
    tol: float = 1e-10,
    maxiter: int = 2000,
):
    """Solve X = V − Aᵀλ with (A Aᵀ)λ = A V − b via CG. Returns (X, λ)."""

    def matvec(lam):
        return A_op(AT_op(lam))

    rhs = jax.tree.map(lambda av, bb: av - bb, A_op(V), b)
    lam, _ = jax.scipy.sparse.linalg.cg(matvec, rhs, x0=lam0, tol=tol, maxiter=maxiter)
    AtL = AT_op(lam)
    X = jax.tree.map(lambda v, a: v - a, V, AtL)
    return X, lam


def kkt_bicgstab_solve(
    A_op: Callable,
    AT_op: Callable,
    V,
    b,
    X0,
    lam0,
    tol: float = 1e-10,
    maxiter: int = 4000,
):
    """Matrix-free Bi-CGSTAB on [[I, Aᵀ],[A, 0]] [X; λ] = [V; b]."""

    def matvec(Xlam):
        X, lam = Xlam
        top = jax.tree.map(lambda x, a: x + a, X, AT_op(lam))
        bot = A_op(X)
        return (top, bot)

    sol, _ = jax.scipy.sparse.linalg.bicgstab(
        matvec, (V, b), x0=(X0, lam0), tol=tol, maxiter=maxiter
    )
    return sol


class ILUKKTSolver:
    """Paper-faithful backend: sparse KKT assembled once, ILU-preconditioned
    Bi-CGSTAB per ADMM iteration (Algorithm 2 lines 3/6 and 12/15).

    ``A_rows``: scipy.sparse matrix of the constraint operator A (Nc × Nx).
    """

    def __init__(self, A_sparse, drop_tol: float = 1e-4, fill_factor: float = 10.0):
        import scipy.sparse as sp
        import scipy.sparse.linalg as spla

        self.sp = sp
        self.spla = spla
        A = sp.csc_matrix(A_sparse)
        Nc, Nx = A.shape
        self.Nx, self.Nc = Nx, Nc
        KKT = sp.bmat([[sp.eye(Nx), A.T], [A, None]], format="csc")
        self.KKT = KKT
        # ILU of the (indefinite) KKT matrix — §V-C: computed once, reused.
        self.ilu = spla.spilu(KKT, drop_tol=drop_tol, fill_factor=fill_factor)
        self.M = spla.LinearOperator(KKT.shape, self.ilu.solve)
        self._last = np.zeros(Nx + Nc)

    def solve(self, V: np.ndarray, b: np.ndarray, tol: float = 1e-10, maxiter: int = 2000):
        rhs = np.concatenate([V, b])
        sol, info = self.spla.bicgstab(
            self.KKT, rhs, x0=self._last, rtol=tol, atol=0.0, maxiter=maxiter, M=self.M
        )
        if info != 0:  # fall back to a direct solve — keeps ADMM robust
            sol = self.spla.spsolve(self.KKT, rhs)
        self._last = sol
        return sol[: self.Nx], sol[self.Nx :]
