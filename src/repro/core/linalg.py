"""Linear-system backends for the ADMM X-step (§V-C).

The X-step solves the KKT system (Eq. 27 / 31):

    [[I, Aᵀ], [A, 0]] [X; λ] = [V; b]        ⇔    X = V − Aᵀλ,  (A Aᵀ) λ = A V − b

Backends:
  - ``pcg_solve``       (default, beyond paper): matrix-free preconditioned
    CG on the SPD Schur complement A Aᵀ — pure JAX, jittable, O(n² + |E|)
    per matvec, optional Jacobi (diagonal) preconditioner and a traced
    relative tolerance (the inexact-ADMM schedule feeds it). Returns the
    iteration count so drivers can account CG work.
  - ``schur_cg_solve``  : the PR-1 wrapper over ``jax.scipy`` CG, kept for
    API compatibility (no preconditioner, no iteration count).
  - ``kkt_bicgstab``    : matrix-free Bi-CGSTAB on the indefinite KKT system,
    pure JAX — the paper's iterative method without preconditioning.
  - ``kkt_bicgstab_ilu``: paper-faithful — materialize the sparse KKT matrix
    once (CSC), precompute ILU (scipy ``spilu``), use it as a Bi-CGSTAB
    preconditioner [37, 38, 39].

This module performs no global precision mutation: the solve runs in
whatever dtype the operand pytrees carry (``ProblemSpec.dtype`` decides),
while CG inner products/norms accumulate in float64 for a trustworthy
stopping rule even in the float32 mode. The ``jax_enable_x64`` switch
lives with the engine (it only widens the available dtype set; per-spec
dtypes pick what is actually used).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = ["pcg_solve", "schur_cg_solve", "kkt_bicgstab_solve", "ILUKKTSolver"]


def _tdot(a, b) -> jnp.ndarray:
    """Pytree inner product, accumulated in float64 (stable fp32-mode CG)."""
    parts = jax.tree.leaves(
        jax.tree.map(lambda x, y: jnp.sum(x.astype(jnp.float64) * y.astype(jnp.float64)), a, b)
    )
    return sum(parts[1:], parts[0])


def _axpy(alpha, x, y):
    """x + alpha·y with the scalar cast to each leaf dtype (no f64 upcast
    of an fp32 tree through scalar promotion)."""
    return jax.tree.map(lambda xl, yl: xl + alpha.astype(xl.dtype) * yl, x, y)


def pcg_solve(
    A_op: Callable,
    AT_op: Callable,
    V,
    b,
    lam0,
    jd=None,
    tol=1e-10,
    maxiter: int = 2000,
):
    """Solve X = V − Aᵀλ with (A Aᵀ)λ = A V − b via preconditioned CG.

    ``jd``: optional pytree matching the constraint space holding
    diag(A Aᵀ) (the analytic Jacobi diagonal from the edge incidence
    structure — see ``engine.jacobi_diag``); ``None`` disables
    preconditioning. ``tol`` is a *relative* residual tolerance and may be
    a traced scalar (the inexact-ADMM schedule). Stops when
    ‖r‖ ≤ tol·‖rhs‖ or after ``maxiter`` iterations.

    Returns ``(X, λ, iters)``.
    """

    def matvec(lam):
        return A_op(AT_op(lam))

    if jd is None:
        Minv = lambda r: r  # noqa: E731
    else:
        Minv = lambda r: jax.tree.map(lambda rl, dl: rl / dl, r, jd)  # noqa: E731

    rhs = jax.tree.map(lambda av, bb: av - bb, A_op(V), b)
    bb = _tdot(rhs, rhs)
    r0 = jax.tree.map(lambda rh, ax: rh - ax, rhs, matvec(lam0))
    z0 = Minv(r0)
    rz0 = _tdot(r0, z0)
    rr0 = _tdot(r0, r0)
    tol2bb = jnp.asarray(tol, jnp.float64) ** 2 * bb

    def cond(carry):
        _, r, _, _, rr, rz, k = carry
        return (rr > tol2bb) & (k < maxiter)

    def body(carry):
        x, r, z, p, rr, rz, k = carry
        Ap = matvec(p)
        alpha = rz / _tdot(p, Ap)
        x = _axpy(alpha, x, p)
        r = _axpy(-alpha, r, Ap)
        z = Minv(r)
        rz_new = _tdot(r, z)
        beta = rz_new / rz
        p = _axpy(beta, z, p)  # p ← z + beta·p (axpy on swapped args)
        return (x, r, z, p, _tdot(r, r), rz_new, k + 1)

    init = (lam0, r0, z0, z0, rr0, rz0, jnp.asarray(0, jnp.int32))
    lam, _, _, _, _, _, iters = lax.while_loop(cond, body, init)
    AtL = AT_op(lam)
    X = jax.tree.map(lambda v, a: v - a, V, AtL)
    return X, lam, iters


def schur_cg_solve(
    A_op: Callable,
    AT_op: Callable,
    V,
    b,
    lam0,
    tol: float = 1e-10,
    maxiter: int = 2000,
):
    """Solve X = V − Aᵀλ with (A Aᵀ)λ = A V − b via CG. Returns (X, λ)."""

    def matvec(lam):
        return A_op(AT_op(lam))

    rhs = jax.tree.map(lambda av, bb: av - bb, A_op(V), b)
    lam, _ = jax.scipy.sparse.linalg.cg(matvec, rhs, x0=lam0, tol=tol, maxiter=maxiter)
    AtL = AT_op(lam)
    X = jax.tree.map(lambda v, a: v - a, V, AtL)
    return X, lam


def kkt_bicgstab_solve(
    A_op: Callable,
    AT_op: Callable,
    V,
    b,
    X0,
    lam0,
    tol: float = 1e-10,
    maxiter: int = 4000,
):
    """Matrix-free Bi-CGSTAB on [[I, Aᵀ],[A, 0]] [X; λ] = [V; b]."""

    def matvec(Xlam):
        X, lam = Xlam
        top = jax.tree.map(lambda x, a: x + a, X, AT_op(lam))
        bot = A_op(X)
        return (top, bot)

    sol, _ = jax.scipy.sparse.linalg.bicgstab(
        matvec, (V, b), x0=(X0, lam0), tol=tol, maxiter=maxiter
    )
    return sol


class ILUKKTSolver:
    """Paper-faithful backend: sparse KKT assembled once, ILU-preconditioned
    Bi-CGSTAB per ADMM iteration (Algorithm 2 lines 3/6 and 12/15).

    ``A_rows``: scipy.sparse matrix of the constraint operator A (Nc × Nx).
    """

    def __init__(self, A_sparse, drop_tol: float = 1e-4, fill_factor: float = 10.0):
        import scipy.sparse as sp
        import scipy.sparse.linalg as spla

        self.sp = sp
        self.spla = spla
        A = sp.csc_matrix(A_sparse)
        Nc, Nx = A.shape
        self.Nx, self.Nc = Nx, Nc
        KKT = sp.bmat([[sp.eye(Nx), A.T], [A, None]], format="csc")
        self.KKT = KKT
        # ILU of the (indefinite) KKT matrix — §V-C: computed once, reused.
        self.ilu = spla.spilu(KKT, drop_tol=drop_tol, fill_factor=fill_factor)
        self.M = spla.LinearOperator(KKT.shape, self.ilu.solve)
        self._last = np.zeros(Nx + Nc)

    def solve(self, V: np.ndarray, b: np.ndarray, tol: float = 1e-10, maxiter: int = 2000):
        rhs = np.concatenate([V, b])
        sol, info = self.spla.bicgstab(
            self.KKT, rhs, x0=self._last, rtol=tol, atol=0.0, maxiter=maxiter, M=self.M
        )
        if info != 0:  # fall back to a direct solve — keeps ADMM robust
            sol = self.spla.spsolve(self.KKT, rhs)
        self._last = sol
        return sol[: self.Nx], sol[self.Nx :]
