"""Device-resident simulated-annealing warm start (§VI), batched restarts.

The host `anneal.anneal_topology` pays an O(n·m) Python BFS for ASPL plus
constraint re-checks for every one of its ~1500 candidate moves — at the
ROADMAP's target scales that makes the warm start, not the ADMM, the outer
pipeline's dominant phase. This module is the device mirror, following the
PR-1/PR-2 engine architecture:

  - state is the adjacency *matrix* plus a fixed-size endpoint array (a
    degree-preserving 2-swap never changes the edge count),
  - ASPL and connectivity are computed together by matmul-BFS hop
    accumulation: ``reach ← reach ∨ (reach @ Adj)`` under a bounded
    ``lax.while_loop``, hop counts summed on the fly from the reach-count
    deltas (``kernels/hop_bfs`` fuses the matmul + count per row band; the
    pure-JAX path is the default exactly like ``edge_laplacian``),
  - heterogeneous capacity rows are checked as incremental ``M @ z``
    updates — four gathered M columns per candidate move, never the full
    product,
  - the whole SA loop is ``lax.scan``-compiled and ``vmap``ped over
    restarts (and, via `sweep` grouping in the API layer, over sweep
    instances that share an edge count).

The host implementation stays as the ``warmstart="host"`` fallback and the
parity oracle (see DESIGN.md §10); the device SA keeps the host's
invariants (degree preservation, feasibility, connectivity) but not its
RNG stream — trajectories differ, qualities match.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels.hop_bfs import ops as _hop_ops
from ..kernels.hop_bfs import ref as _hop_ref
from . import engine as _engine  # noqa: F401 — owns the global x64 enable
from .constraints import ConstraintSet
from .graph import all_edges

__all__ = ["aspl_matmul", "anneal_topology_batched", "anneal_topology_stream"]


def _packed_index(n, i, j):
    """Analytic packed index of edge {i, j} in ``all_edges(n)`` order:
    l = lo·n − lo(lo+1)/2 + (hi−lo−1) (same closed form as the
    ``edge_laplacian`` kernel uses)."""
    lo = jnp.minimum(i, j)
    hi = jnp.maximum(i, j)
    return lo * n - (lo * (lo + 1)) // 2 + (hi - lo - 1)


def _hop(reach, adj, use_kernel: bool):
    if use_kernel:
        return _hop_ops.hop_step(reach, adj, use_kernel=True)
    return _hop_ref.hop_step(reach, adj)


def _aspl_total(adj, use_kernel: bool):
    """All-sources BFS by reach expansion. Returns ``(total, connected)``
    with ``total`` = Σ_{s≠t} dist(s, t) as int32 (exact) and ``connected``
    a bool scalar. Runs at most diameter hops — the while loop stops as
    soon as the reach matrix is full or stops growing (disconnected)."""
    n = adj.shape[0]
    reach0 = jnp.eye(n, dtype=bool) | adj
    cnt0 = jnp.sum(reach0, dtype=jnp.int32)
    # distance-1 pairs contribute 1 each: count = cnt0 − n diagonal entries
    total0 = cnt0 - n

    def cond_fn(c):
        _, _, cnt, k, grew = c
        return (cnt < n * n) & grew & (k < n)

    def body_fn(c):
        reach, total, cnt, k, _ = c
        new_reach, new_cnt = _hop(reach, adj, use_kernel)
        newly = new_cnt - cnt          # pairs first reached at distance k+1
        total = total + (k + 1) * newly
        return (new_reach, total, new_cnt, k + 1, newly > 0)

    _, total, cnt, _, _ = lax.while_loop(
        cond_fn, body_fn,
        (reach0, total0, cnt0, jnp.asarray(1, jnp.int32), cnt0 > n))
    return total, cnt == n * n


@partial(jax.jit, static_argnames=("use_kernel",))
def _aspl_cost(adj, use_kernel: bool = False):
    """In-graph SA move cost: ASPL as fp64, +inf if disconnected."""
    n = adj.shape[0]
    total, connected = _aspl_total(adj, use_kernel)
    denom = n * (n - 1)
    return jnp.where(connected,
                     total.astype(jnp.float64) / denom,
                     jnp.asarray(jnp.inf, jnp.float64))


_aspl_total_jit = jax.jit(_aspl_total, static_argnames=("use_kernel",))


def aspl_matmul(adj, use_kernel: bool = False) -> float:
    """Average shortest path length of a boolean adjacency matrix; +inf if
    disconnected. Bit-identical to ``graph.aspl``: the hop total is an
    exact integer and the one division happens on host (XLA would fold a
    constant divisor into a multiply-by-reciprocal, which rounds
    differently)."""
    n = int(adj.shape[0])
    total, connected = _aspl_total_jit(jnp.asarray(adj), use_kernel)
    if not bool(connected):
        return float("inf")
    return int(total) / (n * (n - 1))


def _sa_move(spec, carry, t):
    """One SA step: propose a degree-preserving 2-swap, validate it with
    cheap O(1)/O(q) checks, price the survivor with one matmul-BFS, accept
    by Metropolis. All branches are data-dependent selects — the step is
    scan- and vmap-compatible."""
    n, E, T0, iters, use_kernel, equality, has_cs = spec["static"]
    okm, M, e_cap = spec["okm"], spec["M"], spec["e_cap"]
    adj, eps, usage, cur_cost, best_adj, best_eps, best_cost, key = carry

    kq = jax.random.fold_in(key, t)
    k_a, k_b, k_o, k_u = jax.random.split(kq, 4)
    T = T0 * jnp.exp(-3.0 * t / max(iters, 1))

    a_i = jax.random.randint(k_a, (), 0, E)
    b_i = jax.random.randint(k_b, (), 0, E)
    a, b = eps[a_i, 0], eps[a_i, 1]
    c, d = eps[b_i, 0], eps[b_i, 1]

    # the two degree-preserving rewirings {(a,c),(b,d)} / {(a,d),(b,c)},
    # tried in random order: option B is considered only when A fails the
    # cheap/feasibility checks. Known divergence from the host oracle: the
    # host also falls through to B when A prices as *disconnected*; here
    # connectivity is only learned from the (expensive) BFS, and pricing
    # both options would double the per-move cost — a disconnecting A
    # simply rejects the move. Quality parity is covered by tests.
    flip = jax.random.bernoulli(k_o)
    vA1, vA2 = jnp.where(flip, d, c), jnp.where(flip, c, d)
    vB1, vB2 = jnp.where(flip, c, d), jnp.where(flip, d, c)

    def cheap_valid(p1a, p1b, p2a, p2b):
        s1a, s1b = jnp.minimum(p1a, p1b), jnp.maximum(p1a, p1b)
        s2a, s2b = jnp.minimum(p2a, p2b), jnp.maximum(p2a, p2b)
        ok = (p1a != p1b) & (p2a != p2b)                    # no self loops
        ok &= ~((s1a == s2a) & (s1b == s2b))                # p1 != p2
        ok &= ~adj[s1a, s1b] & ~adj[s2a, s2b]               # not existing
        ok &= okm[s1a, s1b] & okm[s2a, s2b]                 # admissible
        return ok, (s1a, s1b, s2a, s2b)

    def usage_delta(s1a, s1b, s2a, s2b):
        l_ab = _packed_index(n, a, b)
        l_cd = _packed_index(n, c, d)
        l_p1 = _packed_index(n, s1a, s1b)
        l_p2 = _packed_index(n, s2a, s2b)
        return usage - M[:, l_ab] - M[:, l_cd] + M[:, l_p1] + M[:, l_p2]

    okA, sA = cheap_valid(a, vA1, b, vA2)
    okB, sB = cheap_valid(a, vB1, b, vB2)
    if has_cs:
        uA = usage_delta(*sA)
        uB = usage_delta(*sB)
        feasA = jnp.all(uA == e_cap) if equality else jnp.all(uA <= e_cap)
        feasB = jnp.all(uB == e_cap) if equality else jnp.all(uB <= e_cap)
        okA &= feasA
        okB &= feasB
    use_A = okA
    valid = (okA | okB) & (a_i != b_i)
    s1a, s1b, s2a, s2b = jax.tree.map(
        lambda xa, xb: jnp.where(use_A, xa, xb), sA, sB)
    if has_cs:
        new_usage = jnp.where(use_A, uA, uB)
    else:
        new_usage = usage

    F, Tr = jnp.asarray(False), jnp.asarray(True)
    adj2 = (adj.at[a, b].set(F).at[b, a].set(F)
               .at[c, d].set(F).at[d, c].set(F)
               .at[s1a, s1b].set(Tr).at[s1b, s1a].set(Tr)
               .at[s2a, s2b].set(Tr).at[s2b, s2a].set(Tr))
    eps2 = (eps.at[a_i, 0].set(s1a).at[a_i, 1].set(s1b)
               .at[b_i, 0].set(s2a).at[b_i, 1].set(s2b))

    # connectivity + ASPL in one BFS; disconnected → +inf → never accepted
    new_cost = _aspl_cost(adj2, use_kernel=use_kernel)
    accept_p = jnp.exp(-(new_cost - cur_cost) / jnp.maximum(T, 1e-9))
    accept = valid & ((new_cost <= cur_cost)
                      | (jax.random.uniform(k_u) < accept_p))

    adj = jnp.where(accept, adj2, adj)
    eps = jnp.where(accept, eps2, eps)
    usage = jnp.where(accept, new_usage, usage)
    cur_cost = jnp.where(accept, new_cost, cur_cost)
    better = accept & (new_cost < best_cost)
    best_adj = jnp.where(better, adj2, best_adj)
    best_eps = jnp.where(better, eps2, best_eps)
    best_cost = jnp.where(better, new_cost, best_cost)
    return (adj, eps, usage, cur_cost, best_adj, best_eps, best_cost, key), None


@partial(jax.jit, static_argnames=("n", "E", "iters", "use_kernel",
                                   "equality", "has_cs"))
def _sa_run(adj0, eps0, usage0, keys, okm, M, e_cap, T0,
            n, E, iters, use_kernel, equality, has_cs):
    """vmap over restarts of the scan-compiled SA loop."""
    spec = {"static": (n, E, T0, iters, use_kernel, equality, has_cs),
            "okm": okm, "M": M, "e_cap": e_cap}

    def one(adj0_b, eps0_b, usage0_b, key_b):
        cost0 = _aspl_cost(adj0_b, use_kernel=use_kernel)
        carry0 = (adj0_b, eps0_b, usage0_b, cost0,
                  adj0_b, eps0_b, cost0, key_b)
        carry, _ = lax.scan(partial(_sa_move, spec), carry0,
                            jnp.arange(iters, dtype=jnp.int32))
        _, _, _, _, best_adj, best_eps, best_cost, _ = carry
        return best_eps, best_cost

    return jax.vmap(one)(adj0, eps0, usage0, keys)


def _pack_sa_batch(n, edges0, cs, seeds):
    """Host-side packing shared by the one-shot and streaming SA drivers:
    adjacency matrices, endpoint arrays, constraint usage rows, the
    admissibility mask and PRNG keys for a batch of start graphs."""
    B = len(edges0)
    E = len(edges0[0])
    adj0 = np.zeros((B, n, n), dtype=bool)
    eps0 = np.zeros((B, E, 2), dtype=np.int32)
    for k, edges in enumerate(edges0):
        for l, (i, j) in enumerate(edges):
            i, j = (i, j) if i < j else (j, i)
            adj0[k, i, j] = adj0[k, j, i] = True
            eps0[k, l] = (i, j)

    m = len(all_edges(n))
    okm = np.zeros((n, n), dtype=bool)
    iu = np.triu_indices(n, 1)
    ok_vec = (np.ones(m, dtype=bool) if cs is None
              else np.asarray(cs.edge_ok, dtype=bool))
    okm[iu] = ok_vec
    okm |= okm.T

    has_cs = cs is not None
    if has_cs:
        M = jnp.asarray(cs.M, dtype=jnp.int32)
        e_cap = jnp.asarray(cs.e_cap, dtype=jnp.int32)
        usage0 = np.zeros((B, cs.q), dtype=np.int32)
        M_host = np.asarray(cs.M, dtype=np.int32)
        from .graph import edge_index
        eidx = edge_index(n)
        for k, edges in enumerate(edges0):
            z = np.zeros(m, dtype=np.int32)
            for e in edges:
                z[eidx[tuple(sorted(e))]] = 1
            usage0[k] = M_host @ z
        equality = bool(cs.equality)
    else:
        M = jnp.zeros((0, m), dtype=jnp.int32)
        e_cap = jnp.zeros((0,), dtype=jnp.int32)
        usage0 = np.zeros((B, 0), dtype=np.int32)
        equality = False

    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    return (jnp.asarray(adj0), jnp.asarray(eps0), jnp.asarray(usage0), keys,
            jnp.asarray(okm), M, e_cap, equality, has_cs)


def _eps_to_edges(best_eps):
    out = []
    for k in range(best_eps.shape[0]):
        ep = np.asarray(best_eps[k])
        out.append(sorted((int(i), int(j)) for i, j in ep))
    return out


def anneal_topology_batched(
    n: int,
    edges0: list[list[tuple[int, int]]],
    cs: ConstraintSet | None = None,
    iters: int = 2000,
    T0: float = 0.5,
    seeds: list[int] | None = None,
    use_kernel: bool = False,
) -> list[list[tuple[int, int]]]:
    """SA over degree-preserving 2-swaps for a *batch* of start graphs in
    one vmapped, scan-compiled device call. Mirrors ``anneal_topology``'s
    objective and invariants (ASPL minimization, degree preservation,
    capacity feasibility, connectivity).

    Every element of ``edges0`` must have the same edge count (a 2-swap
    preserves it, so the endpoint array is a fixed-shape state leaf);
    callers group heterogeneous batches by edge count.
    """
    B = len(edges0)
    assert B > 0
    E = len(edges0[0])
    assert all(len(e) == E for e in edges0), "edge counts must match in a batch"
    if E < 2 or iters <= 0:  # host loop also bails: no 2-swap is possible
        return [sorted(e) for e in edges0]
    seeds = list(range(B)) if seeds is None else list(seeds)
    assert len(seeds) == B

    adj0, eps0, usage0, keys, okm, M, e_cap, equality, has_cs = \
        _pack_sa_batch(n, edges0, cs, seeds)
    best_eps, _ = _sa_run(
        adj0, eps0, usage0, keys, okm, M, e_cap, jnp.asarray(float(T0)),
        n=n, E=E, iters=int(iters), use_kernel=bool(use_kernel),
        equality=equality, has_cs=has_cs)
    return _eps_to_edges(best_eps)


@partial(jax.jit, static_argnames=("use_kernel",))
def _sa_init(adj0, eps0, usage0, keys, use_kernel):
    """Initial SA carry for the streaming driver (batched)."""

    def one(adj_b, eps_b, usage_b, key_b):
        cost0 = _aspl_cost(adj_b, use_kernel=use_kernel)
        return (adj_b, eps_b, usage_b, cost0, adj_b, eps_b, cost0, key_b)

    return jax.vmap(one)(adj0, eps0, usage0, keys)


@partial(jax.jit, static_argnames=("n", "E", "chunk", "iters", "use_kernel",
                                   "equality", "has_cs"))
def _sa_chunk(carry, t_start, okm, M, e_cap, T0,
              n, E, chunk, iters, use_kernel, equality, has_cs):
    """Advance the batched SA carry by ``chunk`` moves starting at absolute
    step ``t_start``. Because `_sa_move` derives its per-step key by
    ``fold_in(key, t)`` with the *absolute* step index and its temperature
    from the *static total* ``iters``, chunked execution visits the exact
    same (key, temperature) sequence as `_sa_run`'s single scan — streaming
    is bit-equal to one-shot at exhaustion (tested)."""
    spec = {"static": (n, E, T0, iters, use_kernel, equality, has_cs),
            "okm": okm, "M": M, "e_cap": e_cap}
    ts = t_start + jnp.arange(chunk, dtype=jnp.int32)

    def one(carry_b):
        out, _ = lax.scan(partial(_sa_move, spec), carry_b, ts)
        return out

    return jax.vmap(one)(carry)


def anneal_topology_stream(
    n: int,
    edges0: list[list[tuple[int, int]]],
    cs: ConstraintSet | None = None,
    iters: int = 2000,
    T0: float = 0.5,
    seeds: list[int] | None = None,
    use_kernel: bool = False,
    chunk: int | None = None,
):
    """Generator variant of `anneal_topology_batched` for the anytime outer
    pipeline: yields ``(edge_lists, best_costs, t_done)`` after every chunk
    of moves, so a budgeted caller can stop between chunks and adopt the
    best-so-far graphs. Exhausting the generator produces edge lists
    bit-identical to `anneal_topology_batched` with the same arguments
    (same absolute fold_in step indices, same static-total temperature
    schedule — see `_sa_chunk`).
    """
    B = len(edges0)
    assert B > 0
    E = len(edges0[0])
    assert all(len(e) == E for e in edges0), "edge counts must match in a batch"
    if E < 2 or iters <= 0:
        yield [sorted(e) for e in edges0], [float("inf")] * B, 0
        return
    seeds = list(range(B)) if seeds is None else list(seeds)
    assert len(seeds) == B
    iters = int(iters)
    if chunk is None:
        chunk = max(1, -(-iters // 8))  # default: ~8 poll points
    chunk = int(chunk)

    adj0, eps0, usage0, keys, okm, M, e_cap, equality, has_cs = \
        _pack_sa_batch(n, edges0, cs, seeds)
    carry = _sa_init(adj0, eps0, usage0, keys, use_kernel=bool(use_kernel))
    T0j = jnp.asarray(float(T0))
    t = 0
    while t < iters:
        step = min(chunk, iters - t)
        carry = _sa_chunk(
            carry, jnp.asarray(t, jnp.int32), okm, M, e_cap, T0j,
            n=n, E=E, chunk=step, iters=iters, use_kernel=bool(use_kernel),
            equality=equality, has_cs=has_cs)
        t += step
        best_eps, best_cost = carry[5], carry[6]
        yield (_eps_to_edges(best_eps),
               [float(c) for c in np.asarray(best_cost)], t)
