"""Consensus-speed evaluation (§VI-A).

Simulates x_{k+1} = W x_k from standard-Gaussian initial values and tracks the
consensus error ‖x_k − x̄‖₂ per iteration, then converts iterations to wall
clock with the bandwidth model (Eq. 34). Implemented in JAX (scan) so the same
code path is exercised by tests and benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .bandwidth import PaperConstants, t_iter
from .graph import Topology

__all__ = ["ConsensusTrace", "simulate_consensus", "time_to_error"]


@dataclass
class ConsensusTrace:
    errors: np.ndarray        # (iters+1,) consensus error per iteration
    t_iter_ms: float          # wall-clock per iteration (Eq. 34)
    times_ms: np.ndarray      # (iters+1,)
    topology: str


def simulate_consensus(
    topo: Topology,
    iters: int = 200,
    dim: int = 16,
    seed: int = 0,
    b_min: float | None = None,
    const: PaperConstants = PaperConstants(),
) -> ConsensusTrace:
    W = jnp.asarray(topo.W, dtype=jnp.float64)
    n = topo.n
    key = jax.random.PRNGKey(seed)
    x0 = jax.random.normal(key, (n, dim), dtype=jnp.float64)

    def step(x, _):
        xn = W @ x
        xbar = jnp.mean(xn, axis=0, keepdims=True)
        err = jnp.linalg.norm(xn - xbar)
        return xn, err

    xbar0 = jnp.mean(x0, axis=0, keepdims=True)
    e0 = jnp.linalg.norm(x0 - xbar0)
    _, errs = jax.lax.scan(step, x0, None, length=iters)
    errors = np.concatenate([[float(e0)], np.asarray(errs)])
    ti = t_iter(b_min, const) if b_min is not None else float("nan")
    times = np.arange(iters + 1) * (ti if np.isfinite(ti) else 1.0)
    return ConsensusTrace(errors=errors, t_iter_ms=ti, times_ms=times, topology=topo.name)


def time_to_error(trace: ConsensusTrace, target: float = 1e-4) -> float:
    """First wall-clock time (ms) at which the consensus error ≤ target
    (relative to the initial error). inf if never reached."""
    rel = trace.errors / max(trace.errors[0], 1e-300)
    hit = np.nonzero(rel <= target)[0]
    if hit.size == 0:
        return float("inf")
    return float(trace.times_ms[hit[0]])
