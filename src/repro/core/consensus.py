"""Consensus-speed evaluation (§VI-A).

Simulates x_{k+1} = W x_k from standard-Gaussian initial values and tracks the
consensus error ‖x_k − x̄‖₂ per iteration, then converts iterations to wall
clock with the bandwidth model (Eq. 34). Implemented in JAX (scan) so the same
code path is exercised by tests and benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .bandwidth import PaperConstants, t_iter
from .graph import Topology

__all__ = ["ConsensusTrace", "simulate_consensus", "simulate_consensus_batched",
           "time_to_error"]


@dataclass
class ConsensusTrace:
    errors: np.ndarray        # (iters+1,) consensus error per iteration
    t_iter_ms: float          # wall-clock per iteration (Eq. 34)
    times_ms: np.ndarray      # (iters+1,)
    topology: str


def simulate_consensus(
    topo: Topology,
    iters: int = 200,
    dim: int = 16,
    seed: int = 0,
    b_min: float | None = None,
    const: PaperConstants = PaperConstants(),
) -> ConsensusTrace:
    W = jnp.asarray(topo.W, dtype=jnp.float64)
    n = topo.n
    key = jax.random.PRNGKey(seed)
    x0 = jax.random.normal(key, (n, dim), dtype=jnp.float64)

    def step(x, _):
        xn = W @ x
        xbar = jnp.mean(xn, axis=0, keepdims=True)
        err = jnp.linalg.norm(xn - xbar)
        return xn, err

    xbar0 = jnp.mean(x0, axis=0, keepdims=True)
    e0 = jnp.linalg.norm(x0 - xbar0)
    _, errs = jax.lax.scan(step, x0, None, length=iters)
    errors = np.concatenate([[float(e0)], np.asarray(errs)])
    ti = t_iter(b_min, const) if b_min is not None else float("nan")
    times = np.arange(iters + 1) * (ti if np.isfinite(ti) else 1.0)
    return ConsensusTrace(errors=errors, t_iter_ms=ti, times_ms=times, topology=topo.name)


@partial(jax.jit, static_argnames=("iters",))
def _consensus_errors_batched(Ws, x0, iters: int):
    """Stacked Ws (T, n, n), shared x0 (n, dim) → errors (T, iters+1).

    The per-topology scan is the SAME step body as :func:`simulate_consensus`
    vmapped over the leading topology axis — the whole baseline set runs as
    one device dispatch instead of T serial scans."""
    def one(W):
        def step(x, _):
            xn = W @ x
            xbar = jnp.mean(xn, axis=0, keepdims=True)
            return xn, jnp.linalg.norm(xn - xbar)
        _, errs = jax.lax.scan(step, x0, None, length=iters)
        return errs

    e0 = jnp.linalg.norm(x0 - jnp.mean(x0, axis=0, keepdims=True))
    errs = jax.vmap(one)(Ws)                       # (T, iters)
    e0s = jnp.broadcast_to(e0[None, None], (Ws.shape[0], 1))
    return jnp.concatenate([e0s, errs], axis=1)


def simulate_consensus_batched(
    topos: Sequence[Topology],
    iters: int = 200,
    dim: int = 16,
    seed: int = 0,
    b_mins: Sequence[float | None] | None = None,
    const: PaperConstants = PaperConstants(),
) -> list[ConsensusTrace]:
    """Vmapped :func:`simulate_consensus` over a same-``n`` topology set.

    All topologies share the initial values (one seed, like calling the
    serial version with the same seed per topology), so traces match the
    serial path to fp64 round-off. Returns one :class:`ConsensusTrace` per
    topology, in order."""
    if not topos:
        return []
    n = topos[0].n
    if any(t.n != n for t in topos):
        raise ValueError("simulate_consensus_batched requires equal n "
                         f"(got {[t.n for t in topos]})")
    Ws = jnp.stack([jnp.asarray(t.W, dtype=jnp.float64) for t in topos])
    key = jax.random.PRNGKey(seed)
    x0 = jax.random.normal(key, (n, dim), dtype=jnp.float64)
    errors = np.asarray(_consensus_errors_batched(Ws, x0, iters))
    traces = []
    for k, topo in enumerate(topos):
        bm = None if b_mins is None else b_mins[k]
        ti = t_iter(bm, const) if bm is not None else float("nan")
        times = np.arange(iters + 1) * (ti if np.isfinite(ti) else 1.0)
        traces.append(ConsensusTrace(errors=errors[k], t_iter_ms=ti,
                                     times_ms=times, topology=topo.name))
    return traces


def time_to_error(trace: ConsensusTrace, target: float = 1e-4) -> float:
    """First wall-clock time (ms) at which the consensus error ≤ target
    (relative to the initial error). inf if never reached."""
    rel = trace.errors / max(trace.errors[0], 1e-300)
    hit = np.nonzero(rel <= target)[0]
    if hit.size == 0:
        return float("inf")
    return float(trace.times_ms[hit[0]])
