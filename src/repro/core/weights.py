"""Edge-weight assignment schemes for a *fixed* graph support.

- ``metropolis_weights``: the degree-based convention [17] the paper uses for
  intuition-designed baselines.
- ``uniform_neighbor_weights``: W_ij = 1/(d_max+1)-style uniform mixing.
- ``best_constant_weights``: Xiao–Boyd best constant edge weight
  α* = 2/(λ₁(L₁)+λ_{n−1}(L₁)) for unweighted Laplacian L₁ [22].
- ``polish_weights``: projected-subgradient minimization of the *convex*
  objective max(λ_max(L)−1, 1−λ₂(L)) over g ≥ 0 for fixed support — recovers
  the Xiao–Boyd SDP optimum without an SDP solver (beyond-paper; used both to
  polish ADMM output and to give baselines their optimal weights when we want
  a harder comparison).
"""
from __future__ import annotations

import numpy as np

from .graph import degrees, laplacian_from_weights

__all__ = [
    "metropolis_weights",
    "uniform_neighbor_weights",
    "best_constant_weights",
    "polish_weights",
    "polish_weights_batched",
    "asym_factor_from_g",
]


def metropolis_weights(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    d = degrees(n, edges)
    return np.array([1.0 / (1.0 + max(d[i], d[j])) for i, j in edges])


def uniform_neighbor_weights(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    d = degrees(n, edges)
    dmax = int(d.max()) if len(edges) else 0
    return np.full(len(edges), 1.0 / (dmax + 1.0))


def _unweighted_laplacian_eigs(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    L1 = laplacian_from_weights(n, edges, np.ones(len(edges)))
    return np.linalg.eigvalsh(L1)


def best_constant_weights(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    ev = _unweighted_laplacian_eigs(n, edges)
    lam_max, lam_2 = ev[-1], ev[1]
    alpha = 2.0 / (lam_max + lam_2)
    return np.full(len(edges), alpha)


def asym_factor_from_g(n: int, edges: list[tuple[int, int]], g: np.ndarray,
                       fast: bool | None = None) -> float:
    """max(λ_max(L)−1, 1−λ₂(L)) — identically r_asym(I−L): both equal
    max_{i≥2} |1 − λ_i(L)| (the extremes of L bound the magnitude max, and
    λ₂ > 1 forces λ_max > 1). Above ``FAST_SPECTRAL_MIN_N`` (or with
    ``fast=True``) the Lanczos largest-magnitude path is used; the
    ``eigvalsh`` path is the exact oracle."""
    from .graph import FAST_SPECTRAL_MIN_N, r_asym_fast

    if fast is None:
        fast = n >= FAST_SPECTRAL_MIN_N
    L = laplacian_from_weights(n, edges, g)
    if fast:
        return r_asym_fast(np.eye(n) - L, symmetric=True)
    ev = np.linalg.eigvalsh(L)
    return float(max(ev[-1] - 1.0, 1.0 - ev[1]))


def polish_weights(
    n: int,
    edges: list[tuple[int, int]],
    g0: np.ndarray | None = None,
    iters: int = 400,
    enforce_diag: bool = True,
    seed: int = 0,
) -> np.ndarray:
    """Projected subgradient descent on f(g) = max(λ_max(L(g))−1, 1−λ₂(L(g))).

    f is convex in g (max of a convex max-eigenvalue term and a concave-negated
    second-smallest-eigenvalue term). Subgradients come from eigenvector outer
    products: ∂λ(L)/∂g_l = (u_i − u_j)² for edge l = {i, j} and eigvec u.
    Projection: g ≥ 0, optionally diag(L) ≤ 1 (scale down if violated) so the
    resulting W = I − L stays entrywise-nonnegative, matching Eq. (9).
    """
    m = len(edges)
    if m == 0:
        return np.zeros(0)
    if g0 is None:
        g0 = best_constant_weights(n, edges)
    g = np.asarray(g0, dtype=np.float64).copy()
    ei = np.array([i for i, _ in edges])
    ej = np.array([j for _, j in edges])

    def project(g: np.ndarray) -> np.ndarray:
        g = np.maximum(g, 0.0)
        if enforce_diag:
            # diag(L)_i = sum of incident weights; scale all down if any exceeds 1
            diag = np.zeros(n)
            np.add.at(diag, ei, g)
            np.add.at(diag, ej, g)
            mx = diag.max() if n else 0.0
            if mx > 1.0:
                g = g / mx
        return g

    g = project(g)
    best_g, best_f = g.copy(), asym_factor_from_g(n, edges, g)
    step0 = 0.05
    for t in range(iters):
        L = laplacian_from_weights(n, edges, g)
        evals, evecs = np.linalg.eigh(L)
        f_max = evals[-1] - 1.0
        f_gap = 1.0 - evals[1]
        if f_max >= f_gap:
            u = evecs[:, -1]
            sub = (u[ei] - u[ej]) ** 2  # ∂(λ_max − 1)
        else:
            u = evecs[:, 1]
            sub = -((u[ei] - u[ej]) ** 2)  # ∂(1 − λ₂)
        f = max(f_max, f_gap)
        if f < best_f:
            best_f, best_g = f, g.copy()
        step = step0 / np.sqrt(1.0 + t)
        nrm = np.linalg.norm(sub)
        if nrm < 1e-14:
            break
        g = project(g - step * sub / nrm)
    return best_g


# =========================================================================
# Device polish: the same projected-subgradient loop, scan-compiled and
# vmapped across every candidate support of a solve (DESIGN.md §10)
# =========================================================================

def _polish_scan_factory():
    """Build the jitted scan loop lazily so importing ``weights`` does not
    pull in JAX for numpy-only callers."""
    import jax
    import jax.numpy as jnp
    from functools import partial
    from jax import lax

    from . import engine as _engine  # noqa: F401 — owns the global x64 enable

    def project(g, ei, ej, mask, n, enforce_diag):
        g = jnp.where(mask, jnp.maximum(g, 0.0), 0.0)
        if enforce_diag:
            diag = jnp.zeros(n, dtype=g.dtype).at[ei].add(g).at[ej].add(g)
            mx = jnp.max(diag)
            g = jnp.where(mx > 1.0, g / mx, g)
        return g

    @partial(jax.jit, static_argnames=("n", "iters", "enforce_diag"))
    def polish_scan(ei, ej, mask, g0, n, iters, enforce_diag):
        """One candidate: (Emax,) padded edge arrays (padding = edge (0,0)
        with ``mask`` False — its weight is pinned to 0 and its subgradient
        masked, so it never touches the Laplacian). The eigensolve runs in
        the input dtype (fp32 by default); objective bookkeeping (best-f
        comparisons) is fp64 per the PR-2 convention."""
        dt = g0.dtype
        g = project(g0, ei, ej, mask, n, enforce_diag)

        def body(carry, t):
            g, best_g, best_f, done = carry
            L = jnp.zeros((n, n), dtype=dt)
            L = L.at[ei, ej].add(-g).at[ej, ei].add(-g)
            L = L.at[ei, ei].add(g).at[ej, ej].add(g)
            evals, evecs = jnp.linalg.eigh(L)
            f_max = evals[-1] - 1.0
            f_gap = 1.0 - evals[1]
            use_max = f_max >= f_gap
            u = jnp.where(use_max, evecs[:, -1], evecs[:, 1])
            sub = (u[ei] - u[ej]) ** 2 * jnp.where(use_max, 1.0, -1.0)
            sub = jnp.where(mask, sub, 0.0)
            f = jnp.maximum(f_max, f_gap).astype(jnp.float64)
            improved = (~done) & (f < best_f)
            best_f = jnp.where(improved, f, best_f)
            best_g = jnp.where(improved, g, best_g)
            step = 0.05 / jnp.sqrt(1.0 + t)
            nrm = jnp.sqrt(jnp.sum(sub * sub))
            done = done | (nrm < 1e-14)
            g_new = project(g - step * sub / jnp.maximum(nrm, 1e-30),
                            ei, ej, mask, n, enforce_diag)
            g = jnp.where(done, g, g_new)
            return (g, best_g, best_f, done), None

        carry0 = (g, g, jnp.asarray(jnp.inf, jnp.float64), jnp.asarray(False))
        (g, best_g, best_f, _), _ = lax.scan(
            body, carry0, jnp.arange(iters, dtype=dt))
        return best_g, best_f

    return jax.vmap(polish_scan, in_axes=(0, 0, 0, 0, None, None, None))


_POLISH_VMAP = None


def polish_weights_batched(
    n: int,
    edge_lists: list[list[tuple[int, int]]],
    g0s: list[np.ndarray] | None = None,
    iters: int = 400,
    enforce_diag: bool = True,
    dtype: str = "float32",
) -> list[np.ndarray]:
    """``polish_weights`` for every candidate support of a solve in ONE
    vmapped, scan-compiled device call (restarts × {admm, warm} × classics
    used to polish serially — ~500 host ``eigh`` calls *per candidate*).

    Candidates are padded to a common edge count with masked zero-weight
    dummy edges; fp32 loop with fp64 objective bookkeeping by default
    (``dtype="float64"`` reproduces the host loop's arithmetic exactly,
    modulo LAPACK backend differences in degenerate eigenspaces).
    """
    global _POLISH_VMAP
    import jax.numpy as jnp

    B = len(edge_lists)
    if B == 0:
        return []
    if g0s is None:
        g0s = [best_constant_weights(n, e) for e in edge_lists]
    Emax = max(len(e) for e in edge_lists)
    if Emax == 0:
        return [np.zeros(0) for _ in edge_lists]
    dt = np.float32 if dtype == "float32" else np.float64
    ei = np.zeros((B, Emax), dtype=np.int32)
    ej = np.zeros((B, Emax), dtype=np.int32)
    mask = np.zeros((B, Emax), dtype=bool)
    g0p = np.zeros((B, Emax), dtype=dt)
    for k, (edges, g0) in enumerate(zip(edge_lists, g0s)):
        E = len(edges)
        if E:
            ei[k, :E] = [i for i, _ in edges]
            ej[k, :E] = [j for _, j in edges]
            mask[k, :E] = True
            g0p[k, :E] = np.asarray(g0, dtype=dt)
    if _POLISH_VMAP is None:
        _POLISH_VMAP = _polish_scan_factory()
    best_g, _ = _POLISH_VMAP(
        jnp.asarray(ei), jnp.asarray(ej), jnp.asarray(mask), jnp.asarray(g0p),
        n, int(iters), bool(enforce_diag))
    best_g = np.asarray(best_g, dtype=np.float64)
    return [best_g[k, : len(edge_lists[k])] for k in range(B)]
