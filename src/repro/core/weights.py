"""Edge-weight assignment schemes for a *fixed* graph support.

- ``metropolis_weights``: the degree-based convention [17] the paper uses for
  intuition-designed baselines.
- ``uniform_neighbor_weights``: W_ij = 1/(d_max+1)-style uniform mixing.
- ``best_constant_weights``: Xiao–Boyd best constant edge weight
  α* = 2/(λ₁(L₁)+λ_{n−1}(L₁)) for unweighted Laplacian L₁ [22].
- ``polish_weights``: projected-subgradient minimization of the *convex*
  objective max(λ_max(L)−1, 1−λ₂(L)) over g ≥ 0 for fixed support — recovers
  the Xiao–Boyd SDP optimum without an SDP solver (beyond-paper; used both to
  polish ADMM output and to give baselines their optimal weights when we want
  a harder comparison).
"""
from __future__ import annotations

import numpy as np

from .graph import degrees, laplacian_from_weights

__all__ = [
    "metropolis_weights",
    "uniform_neighbor_weights",
    "best_constant_weights",
    "polish_weights",
    "asym_factor_from_g",
]


def metropolis_weights(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    d = degrees(n, edges)
    return np.array([1.0 / (1.0 + max(d[i], d[j])) for i, j in edges])


def uniform_neighbor_weights(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    d = degrees(n, edges)
    dmax = int(d.max()) if len(edges) else 0
    return np.full(len(edges), 1.0 / (dmax + 1.0))


def _unweighted_laplacian_eigs(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    L1 = laplacian_from_weights(n, edges, np.ones(len(edges)))
    return np.linalg.eigvalsh(L1)


def best_constant_weights(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    ev = _unweighted_laplacian_eigs(n, edges)
    lam_max, lam_2 = ev[-1], ev[1]
    alpha = 2.0 / (lam_max + lam_2)
    return np.full(len(edges), alpha)


def asym_factor_from_g(n: int, edges: list[tuple[int, int]], g: np.ndarray) -> float:
    """max(λ_max(L)−1, 1−λ₂(L)) — equals r_asym(I−L) when both λ bounds hold."""
    L = laplacian_from_weights(n, edges, g)
    ev = np.linalg.eigvalsh(L)
    return float(max(ev[-1] - 1.0, 1.0 - ev[1]))


def polish_weights(
    n: int,
    edges: list[tuple[int, int]],
    g0: np.ndarray | None = None,
    iters: int = 400,
    enforce_diag: bool = True,
    seed: int = 0,
) -> np.ndarray:
    """Projected subgradient descent on f(g) = max(λ_max(L(g))−1, 1−λ₂(L(g))).

    f is convex in g (max of a convex max-eigenvalue term and a concave-negated
    second-smallest-eigenvalue term). Subgradients come from eigenvector outer
    products: ∂λ(L)/∂g_l = (u_i − u_j)² for edge l = {i, j} and eigvec u.
    Projection: g ≥ 0, optionally diag(L) ≤ 1 (scale down if violated) so the
    resulting W = I − L stays entrywise-nonnegative, matching Eq. (9).
    """
    m = len(edges)
    if m == 0:
        return np.zeros(0)
    if g0 is None:
        g0 = best_constant_weights(n, edges)
    g = np.asarray(g0, dtype=np.float64).copy()
    ei = np.array([i for i, _ in edges])
    ej = np.array([j for _, j in edges])

    def project(g: np.ndarray) -> np.ndarray:
        g = np.maximum(g, 0.0)
        if enforce_diag:
            # diag(L)_i = sum of incident weights; scale all down if any exceeds 1
            diag = np.zeros(n)
            np.add.at(diag, ei, g)
            np.add.at(diag, ej, g)
            mx = diag.max() if n else 0.0
            if mx > 1.0:
                g = g / mx
        return g

    g = project(g)
    best_g, best_f = g.copy(), asym_factor_from_g(n, edges, g)
    step0 = 0.05
    for t in range(iters):
        L = laplacian_from_weights(n, edges, g)
        evals, evecs = np.linalg.eigh(L)
        f_max = evals[-1] - 1.0
        f_gap = 1.0 - evals[1]
        if f_max >= f_gap:
            u = evecs[:, -1]
            sub = (u[ei] - u[ej]) ** 2  # ∂(λ_max − 1)
        else:
            u = evecs[:, 1]
            sub = -((u[ei] - u[ej]) ** 2)  # ∂(1 − λ₂)
        f = max(f_max, f_gap)
        if f < best_f:
            best_f, best_g = f, g.copy()
        step = step0 / np.sqrt(1.0 + t)
        nrm = np.linalg.norm(sub)
        if nrm < 1e-14:
            break
        g = project(g - step * sub / nrm)
    return best_g
