"""Algorithm 2 — ADMM solvers for the network-topology optimization problems.

Thin object-oriented wrappers over the functional solver engine in
``engine.py``: each class builds a :class:`~repro.core.engine.ProblemSpec`
once and delegates to the shared ``step``/driver functions. The splitting,
projections, X-step KKT system and dual updates follow §V of the paper
exactly; the X-step linear system is dispatched to one of the backends in
``linalg.py`` (see DESIGN.md §3).

Drivers (``ADMMConfig.driver``):
  - ``"scan"``   (default) — device-resident chunked ``lax.scan`` loop,
    convergence checked on-device every ``check_every`` iterations.
  - ``"python"`` — the seed per-iteration host loop (one sync per
    iteration); also the carrier for the scipy-ILU backend.

``solve_batched`` vmaps the scan driver over a batch of warm starts so
restarts share one compiled device call.
"""
from __future__ import annotations

from dataclasses import replace

import jax.numpy as jnp
import numpy as np

from .engine import (
    ADMMConfig,
    ADMMResult,
    ADMMState,
    ProblemSpec,
    init_state,
    make_hetero_spec,
    make_homo_spec,
    make_ilu_step,
    proj_binary_topr,
    proj_card_nonneg,
    proj_psd,
    solve_batched_spec,
    solve_python,
    solve_spec,
)

__all__ = ["ADMMConfig", "ADMMResult", "HomogeneousADMM", "HeterogeneousADMM"]

# Backwards-compatible aliases (pre-engine private names).
_proj_psd = proj_psd
_proj_card_nonneg = proj_card_nonneg
_proj_binary_topr = proj_binary_topr


class _ADMMBase:
    """Shared driver dispatch for both scenarios."""

    spec: ProblemSpec
    cfg: ADMMConfig

    @property
    def m(self) -> int:
        return self.spec.m

    @property
    def r(self) -> int:
        return int(self.spec.r)

    def _device_cfg(self) -> ADMMConfig:
        """Config with a device backend. The scipy-ILU backend exists only
        for the homogeneous problem; like the seed, the heterogeneous
        solver falls back to schur_cg when it is requested."""
        if self.cfg.driver not in ("scan", "python"):
            raise ValueError(
                f"unknown driver {self.cfg.driver!r}; expected 'scan' or 'python'")
        if self.spec.hetero and self.cfg.solver == "kkt_bicgstab_ilu":
            return replace(self.cfg, solver="schur_cg")
        if self.cfg.solver == "kkt_bicgstab_ilu" and self.cfg.dtype != "float64":
            raise ValueError(
                "the scipy-ILU backend is float64-only; use solver='schur_cg' "
                "with dtype='float32'")
        return self.cfg

    def _solve_state(self, state: ADMMState) -> ADMMResult:
        cfg = self._device_cfg()
        if cfg.solver == "kkt_bicgstab_ilu":
            return solve_python(self.spec, state, cfg, step_fn=self._ilu_step())
        if cfg.driver == "python":
            return solve_python(self.spec, state, cfg)
        from .shard import resolve_partition, solve_spec_sharded

        # a single solve has no instance batch — "instances" degenerates
        if resolve_partition(cfg.partition, self.spec.n) == "edges":
            return solve_spec_sharded(self.spec, state, cfg)
        return solve_spec(self.spec, state, cfg)

    def _solve_states_batched(self, states: ADMMState,
                              batch: int) -> list[ADMMResult]:
        cfg = self._batched_cfg()
        from .shard import (
            resolve_partition, solve_batched_spec_sharded, solve_spec_sharded)

        part = resolve_partition(cfg.partition, self.spec.n, batch=batch)
        if part == "instances":
            return solve_batched_spec_sharded(self.spec, states, cfg)
        if part == "edges":
            import jax

            return [solve_spec_sharded(
                self.spec, jax.tree.map(lambda a, b=b: a[b], states), cfg)
                for b in range(batch)]
        return solve_batched_spec(self.spec, states, cfg)

    def _batched_cfg(self) -> ADMMConfig:
        """Validated config for solve_batched (always the scan driver)."""
        cfg = self._device_cfg()
        if cfg.solver == "kkt_bicgstab_ilu":
            raise ValueError(
                "solve_batched needs a device backend (schur_cg or "
                "kkt_bicgstab); the scipy-ILU backend is host-side")
        return cfg

    def _ilu_step(self):
        raise ValueError("the ILU backend supports the homogeneous problem only")


class HomogeneousADMM(_ADMMBase):
    """Eq. (20) solver. ``r`` is the cardinality budget on the edge set."""

    def __init__(self, n: int, r: int, cfg: ADMMConfig = ADMMConfig(),
                 edge_ok: np.ndarray | None = None):
        self.n, self.cfg = n, cfg
        self.spec = make_homo_spec(n, r, cfg, edge_ok)
        self._ilu_step_fn = None

    def init_state(self, g0: np.ndarray | None = None, lam0: float = 0.5) -> ADMMState:
        g = jnp.zeros(self.spec.m) if g0 is None else jnp.asarray(g0, dtype=jnp.float64)
        return init_state(self.spec, g, lam0)

    def solve(self, g0=None, lam0: float = 0.5) -> ADMMResult:
        return self._solve_state(self.init_state(g0, lam0))

    def solve_batched(self, g0s: np.ndarray, lam0s: np.ndarray) -> list[ADMMResult]:
        """Solve a batch of warm starts in one vmapped device call.

        ``g0s``: (B, m) edge-weight warm starts; ``lam0s``: (B,) λ̃ starts.
        """
        import jax

        self._batched_cfg()
        g0s = jnp.asarray(g0s, dtype=jnp.float64)
        lam0s = jnp.asarray(lam0s, dtype=jnp.float64)
        states = jax.vmap(lambda g, l: init_state(self.spec, g, l))(g0s, lam0s)
        return self._solve_states_batched(states, int(g0s.shape[0]))

    def _ilu_step(self):
        if self._ilu_step_fn is None:
            self._ilu_step_fn = make_ilu_step(self.spec)
        return self._ilu_step_fn


class HeterogeneousADMM(_ADMMBase):
    """Eq. (28) solver with binary edge selection z and capacity rows M z = e
    (equality) or M z + s = e, s ≥ 0 (inequality capacities).
    """

    def __init__(self, n: int, r: int, M: np.ndarray, e_cap: np.ndarray,
                 cfg: ADMMConfig = ADMMConfig(), equality: bool = True,
                 edge_ok: np.ndarray | None = None):
        self.n, self.cfg = n, cfg
        self.spec = make_hetero_spec(n, r, np.asarray(M), np.asarray(e_cap),
                                     cfg, equality=equality, edge_ok=edge_ok)
        self.equality = equality

    def init_state(self, g0=None, z0=None, lam0: float = 0.5) -> ADMMState:
        g = jnp.zeros(self.spec.m) if g0 is None else jnp.asarray(g0, dtype=jnp.float64)
        z = None if z0 is None else jnp.asarray(z0, dtype=jnp.float64)
        return init_state(self.spec, g, lam0, z=z)

    def solve(self, g0=None, z0=None, lam0: float = 0.5) -> ADMMResult:
        return self._solve_state(self.init_state(g0, z0, lam0))

    def solve_batched(self, g0s: np.ndarray, z0s: np.ndarray,
                      lam0s: np.ndarray) -> list[ADMMResult]:
        """Batched restarts: (B, m) g0s, (B, m) z0s, (B,) lam0s."""
        import jax

        self._batched_cfg()
        g0s = jnp.asarray(g0s, dtype=jnp.float64)
        z0s = jnp.asarray(z0s, dtype=jnp.float64)
        lam0s = jnp.asarray(lam0s, dtype=jnp.float64)
        states = jax.vmap(lambda g, z, l: init_state(self.spec, g, l, z=z))(
            g0s, z0s, lam0s)
        return self._solve_states_batched(states, int(g0s.shape[0]))
