"""Algorithm 2 — ADMM framework for the network-topology optimization problems.

Solves the homogeneous problem (Eq. 20) and the heterogeneous Mixed-Integer
SDP (Eq. 28). Splitting, projections, X-step KKT system and dual updates
follow §V of the paper exactly; the X-step linear system is dispatched to one
of the backends in ``linalg.py``.

Variable layout (homogeneous, Eq. 20):
  X = (x, S, y, T)     with x = [g; λ̃] ∈ R^{m+1}
  Y = (x₁, S₁, y₁, T₁)
  duals D = (μ, Λ, σ, Γ)
Constraints C_X (Eq. 23):
  L(g) − λ̃I + S = −B₀,   L(g) + λ̃I + T = 2I,   diag(L(g)) + y = 1
Heterogeneous adds (z, ν[, s]) with M z (+ s) = e and g − z + ν = 0.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .graph import all_edges
from .linalg import ILUKKTSolver, kkt_bicgstab_solve, schur_cg_solve

jax.config.update("jax_enable_x64", True)

__all__ = ["ADMMConfig", "ADMMResult", "HomogeneousADMM", "HeterogeneousADMM"]


@dataclass
class ADMMConfig:
    rho: float = 5.0  # tuned on n=16, r=32: see EXPERIMENTS.md (ρ=5 → 0.517 vs paper 0.52)
    alpha: float = 2.0  # Lemma 1 shift; any α ≥ λ_{n−1}(L) works, and λ < 2 always (Eq. 7)
    max_iters: int = 1500
    eps: float = 1e-7  # threshold on the summed squared primal residual (Alg. 2 line 4)
    solver: str = "schur_cg"  # schur_cg | kkt_bicgstab | kkt_bicgstab_ilu
    cg_tol: float = 1e-11
    cg_maxiter: int = 3000
    check_every: int = 10
    verbose: bool = False


@dataclass
class ADMMResult:
    g: np.ndarray          # edge weights (candidate-edge order), from x₁
    g_raw: np.ndarray      # from x (pre-projection side)
    lam_tilde: float
    z: np.ndarray | None   # binary edge selection (hetero only)
    iters: int
    residual: float
    history: list = field(default_factory=list)


def _proj_psd(M: jnp.ndarray, sign: float) -> jnp.ndarray:
    """Eq. 25: eigenvalue clipping. sign=+1 → PSD (T₁ ≽ 0), −1 → NSD (S₁ ≼ 0)."""
    Msym = (M + M.T) / 2.0
    ev, U = jnp.linalg.eigh(Msym)
    ev = jnp.maximum(ev, 0.0) if sign > 0 else jnp.minimum(ev, 0.0)
    return (U * ev) @ U.T


def _proj_card_nonneg(v: jnp.ndarray, r: int, ok: jnp.ndarray) -> jnp.ndarray:
    """Project onto {g ≥ 0, Card(g) ≤ r} ∩ {g_l = 0 for inadmissible l}.

    Keep the largest r nonnegative entries (Eq. 24 discussion), zero the rest.
    """
    v = jnp.where(ok, jnp.maximum(v, 0.0), 0.0)
    m = v.shape[0]
    if r >= m:
        return v
    thresh = jax.lax.top_k(v, r + 1)[0][r]  # (r+1)-th largest
    keep = v > jnp.maximum(thresh, 0.0)
    # tie-break: if fewer than r kept due to exact ties/zeros that is fine
    return jnp.where(keep, v, 0.0)


def _proj_binary_topr(v: jnp.ndarray, r: int, ok: jnp.ndarray) -> jnp.ndarray:
    """Heterogeneous z₁ projection: largest r entries → 1, others → 0 (§V-B)."""
    v = jnp.where(ok, v, -jnp.inf)
    m = v.shape[0]
    idx = jax.lax.top_k(v, r)[1]
    z = jnp.zeros(m, dtype=v.dtype).at[idx].set(1.0)
    return z


class _TopoOperators:
    """Shared edge-indexed operators: L(g), A, Aᵀ (matrix-free)."""

    def __init__(self, n: int, alpha: float):
        self.n = n
        self.edges = all_edges(n)
        self.m = len(self.edges)
        self.ei = jnp.array([i for i, _ in self.edges])
        self.ej = jnp.array([j for _, j in self.edges])
        self.alpha = alpha
        self.B0 = alpha * jnp.ones((n, n)) / n
        self.I = jnp.eye(n)

    def L_of_g(self, g: jnp.ndarray) -> jnp.ndarray:
        n, ei, ej = self.n, self.ei, self.ej
        L = jnp.zeros((n, n), dtype=g.dtype)
        L = L.at[ei, ej].add(-g).at[ej, ei].add(-g)
        L = L.at[ei, ei].add(g).at[ej, ej].add(g)
        return L

    def edge_quadform(self, P: jnp.ndarray) -> jnp.ndarray:
        """⟨∂L/∂g_l, P⟩ = P_ii + P_jj − P_ij − P_ji per edge l = {i, j}."""
        ei, ej = self.ei, self.ej
        return P[ei, ei] + P[ej, ej] - P[ei, ej] - P[ej, ei]

    def deg_sum(self, w: jnp.ndarray) -> jnp.ndarray:
        """(Dᵀ w)_l = w_i + w_j."""
        return w[self.ei] + w[self.ej]


class HomogeneousADMM:
    """Eq. (20) solver. ``r`` is the cardinality budget on the edge set."""

    def __init__(self, n: int, r: int, cfg: ADMMConfig = ADMMConfig(),
                 edge_ok: np.ndarray | None = None):
        self.n, self.cfg = n, cfg
        self.ops = _TopoOperators(n, cfg.alpha)
        m = self.ops.m
        self.edge_ok = jnp.ones(m, dtype=bool) if edge_ok is None else jnp.asarray(edge_ok)
        self.r = min(r, int(np.asarray(self.edge_ok).sum()))
        # objective coefficient c: minimize −λ̃  (Eq. 9 → Eq. 20)
        self.c = jnp.zeros(m + 1).at[m].set(-1.0)
        self._step = jax.jit(self._step_impl)
        self._ilu: ILUKKTSolver | None = None

    # ---- matrix-free constraint operator and its adjoint -------------------
    def A_op(self, X):
        x, S, y, T = X
        g, lam = x[:-1], x[-1]
        L = self.ops.L_of_g(g)
        I = self.ops.I
        return (L - lam * I + S, L + lam * I + T, jnp.diag(L) + y)

    def AT_op(self, lamv):
        P, Q, w = lamv
        xg = self.ops.edge_quadform(P + Q) + self.ops.deg_sum(w)
        xl = -jnp.trace(P) + jnp.trace(Q)
        x_adj = jnp.concatenate([xg, xl[None]])
        return (x_adj, P, w, Q)

    def b_rhs(self):
        n, I = self.n, self.ops.I
        return (-self.ops.B0, 2.0 * I, jnp.ones(n))

    # ---- one ADMM iteration (Alg. 2 lines 5–8) -----------------------------
    def _step_impl(self, state):
        (x, S, y, T, x1, S1, y1, T1, mu, Lam, sig, Gam, lam_ws) = state
        rho = self.cfg.rho
        m = self.ops.m
        # Y-update (Eq. 24)
        x1n_g = _proj_card_nonneg((x + mu / rho)[:m], self.r, self.edge_ok)
        x1n_l = jnp.maximum((x + mu / rho)[m], 0.0)
        x1n = jnp.concatenate([x1n_g, x1n_l[None]])
        S1n = _proj_psd(S + Lam / rho, sign=-1.0)
        y1n = jnp.maximum(y + sig / rho, 0.0)
        T1n = _proj_psd(T + Gam / rho, sign=+1.0)
        # X-update (Eq. 27): min cᵀx + ρ/2‖X − Y₁ + D/ρ‖² s.t. A X = b
        V = (x1n - (mu + self.c) / rho, S1n - Lam / rho, y1n - sig / rho, T1n - Gam / rho)
        Xn, lam_new = schur_cg_solve(
            self.A_op, self.AT_op, V, self.b_rhs(), lam_ws,
            tol=self.cfg.cg_tol, maxiter=self.cfg.cg_maxiter,
        )
        xn, Sn, yn, Tn = Xn
        # dual update (Eq. 22)
        mun = mu + rho * (xn - x1n)
        Lamn = Lam + rho * (Sn - S1n)
        sign_ = sig + rho * (yn - y1n)
        Gamn = Gam + rho * (Tn - T1n)
        res = (jnp.sum((xn - x1n) ** 2) + jnp.sum((Sn - S1n) ** 2)
               + jnp.sum((yn - y1n) ** 2) + jnp.sum((Tn - T1n) ** 2))
        new_state = (xn, Sn, yn, Tn, x1n, S1n, y1n, T1n, mun, Lamn, sign_, Gamn, lam_new)
        return new_state, res

    # ---- scipy ILU path (paper-faithful §V-C) -------------------------------
    def _sparse_A(self):
        import scipy.sparse as sp

        n, m = self.n, self.ops.m
        edges = self.ops.edges
        rows, cols, vals = [], [], []

        def vecidx(i, j):  # column-major vec
            return i + j * n

        # B̃⁻ / B̃⁺ blocks (n² rows each) acting on x = [g; λ̃]
        for l, (i, j) in enumerate(edges):
            for (a, b2, v) in ((i, i, 1.0), (j, j, 1.0), (i, j, -1.0), (j, i, -1.0)):
                rows.append(vecidx(a, b2)); cols.append(l); vals.append(v)           # B⁻
                rows.append(n * n + vecidx(a, b2)); cols.append(l); vals.append(v)   # B⁺
        for i in range(n):
            rows.append(vecidx(i, i)); cols.append(m); vals.append(-1.0)   # −λ̃ I
            rows.append(n * n + vecidx(i, i)); cols.append(m); vals.append(1.0)
        # D block: diag(L) rows
        for l, (i, j) in enumerate(edges):
            rows.append(2 * n * n + i); cols.append(l); vals.append(1.0)
            rows.append(2 * n * n + j); cols.append(l); vals.append(1.0)
        Nx = m + 1 + n * n + n + n * n
        Nc = 2 * n * n + n
        Ax = sp.csr_matrix(sp.coo_matrix((vals, (rows, cols)), shape=(Nc, m + 1)))
        IS = sp.hstack([sp.coo_matrix((n * n, 0)), sp.eye(n * n)])
        A = sp.bmat([
            [Ax[: n * n, :], sp.eye(n * n), sp.coo_matrix((n * n, n)), sp.coo_matrix((n * n, n * n))],
            [Ax[n * n: 2 * n * n, :], sp.coo_matrix((n * n, n * n)), sp.coo_matrix((n * n, n)), sp.eye(n * n)],
            [Ax[2 * n * n:, :], sp.coo_matrix((n, n * n)), sp.eye(n), sp.coo_matrix((n, n * n))],
        ], format="csc")
        assert A.shape == (Nc, Nx)
        _ = IS
        return A

    def _pack(self, X):
        x, S, y, T = X
        return np.concatenate([np.asarray(x), np.asarray(S).ravel(order="F"),
                               np.asarray(y), np.asarray(T).ravel(order="F")])

    def _unpack(self, v):
        n, m = self.n, self.ops.m
        o = 0
        x = v[o:o + m + 1]; o += m + 1
        S = v[o:o + n * n].reshape(n, n, order="F"); o += n * n
        y = v[o:o + n]; o += n
        T = v[o:o + n * n].reshape(n, n, order="F")
        return (jnp.asarray(x), jnp.asarray(S), jnp.asarray(y), jnp.asarray(T))

    def _step_ilu(self, state):
        (x, S, y, T, x1, S1, y1, T1, mu, Lam, sig, Gam, lam_ws) = state
        rho = self.cfg.rho
        m = self.ops.m
        x1n_g = _proj_card_nonneg((x + mu / rho)[:m], self.r, self.edge_ok)
        x1n = jnp.concatenate([x1n_g, jnp.maximum((x + mu / rho)[m], 0.0)[None]])
        S1n = _proj_psd(S + Lam / rho, -1.0)
        y1n = jnp.maximum(y + sig / rho, 0.0)
        T1n = _proj_psd(T + Gam / rho, +1.0)
        V = (x1n - (mu + self.c) / rho, S1n - Lam / rho, y1n - sig / rho, T1n - Gam / rho)
        b = self.b_rhs()
        bp = np.concatenate([np.asarray(b[0]).ravel(order="F"),
                             np.asarray(b[1]).ravel(order="F"), np.asarray(b[2])])
        if self._ilu is None:
            self._ilu = ILUKKTSolver(self._sparse_A())
        Xv, _ = self._ilu.solve(self._pack(V), bp, tol=self.cfg.cg_tol)
        xn, Sn, yn, Tn = self._unpack(Xv)
        mun = mu + rho * (xn - x1n)
        Lamn = Lam + rho * (Sn - S1n)
        sign_ = sig + rho * (yn - y1n)
        Gamn = Gam + rho * (Tn - T1n)
        res = float(jnp.sum((xn - x1n) ** 2) + jnp.sum((Sn - S1n) ** 2)
                    + jnp.sum((yn - y1n) ** 2) + jnp.sum((Tn - T1n) ** 2))
        return (xn, Sn, yn, Tn, x1n, S1n, y1n, T1n, mun, Lamn, sign_, Gamn, lam_ws), res

    # ---- driver -------------------------------------------------------------
    def init_state(self, g0: np.ndarray | None = None, lam0: float = 0.5):
        n, m = self.n, self.ops.m
        g = jnp.zeros(m) if g0 is None else jnp.asarray(g0, dtype=jnp.float64)
        x = jnp.concatenate([g, jnp.array([lam0])])
        L = self.ops.L_of_g(g)
        S = -(L - lam0 * self.ops.I + self.ops.B0)
        T = 2 * self.ops.I - (L + lam0 * self.ops.I)
        y = 1.0 - jnp.diag(L)
        z0 = jnp.zeros((n, n))
        lam_ws = (z0, z0, jnp.zeros(n))
        return (x, S, y, T, x, S, y, T,
                jnp.zeros(m + 1), z0, jnp.zeros(n), z0, lam_ws)

    def solve(self, g0=None, lam0: float = 0.5) -> ADMMResult:
        state = self.init_state(g0, lam0)
        step = {"schur_cg": self._step, "kkt_bicgstab": self._step_kkt,
                "kkt_bicgstab_ilu": self._step_ilu}[self.cfg.solver]
        history, res = [], np.inf
        it = 0
        for it in range(1, self.cfg.max_iters + 1):
            state, res = step(state)
            res = float(res)
            if it % self.cfg.check_every == 0 or it == 1:
                history.append((it, res, float(state[0][-1])))
                if self.cfg.verbose:
                    print(f"[admm-homo] it={it} res={res:.3e} lam~={float(state[0][-1]):.4f}")
            if res < self.cfg.eps:
                break
        x, x1 = state[0], state[4]
        m = self.ops.m
        return ADMMResult(
            g=np.asarray(x1[:m]), g_raw=np.asarray(x[:m]), lam_tilde=float(x1[m]),
            z=None, iters=it, residual=res, history=history,
        )

    def _step_kkt(self, state):
        (x, S, y, T, x1, S1, y1, T1, mu, Lam, sig, Gam, lam_ws) = state
        rho = self.cfg.rho
        m = self.ops.m
        x1n_g = _proj_card_nonneg((x + mu / rho)[:m], self.r, self.edge_ok)
        x1n = jnp.concatenate([x1n_g, jnp.maximum((x + mu / rho)[m], 0.0)[None]])
        S1n = _proj_psd(S + Lam / rho, -1.0)
        y1n = jnp.maximum(y + sig / rho, 0.0)
        T1n = _proj_psd(T + Gam / rho, +1.0)
        V = (x1n - (mu + self.c) / rho, S1n - Lam / rho, y1n - sig / rho, T1n - Gam / rho)
        Xn, lam_new = kkt_bicgstab_solve(
            self.A_op, self.AT_op, V, self.b_rhs(), (x, S, y, T), lam_ws,
            tol=self.cfg.cg_tol, maxiter=self.cfg.cg_maxiter,
        )
        xn, Sn, yn, Tn = Xn
        mun = mu + rho * (xn - x1n)
        Lamn = Lam + rho * (Sn - S1n)
        sign_ = sig + rho * (yn - y1n)
        Gamn = Gam + rho * (Tn - T1n)
        res = (jnp.sum((xn - x1n) ** 2) + jnp.sum((Sn - S1n) ** 2)
               + jnp.sum((yn - y1n) ** 2) + jnp.sum((Tn - T1n) ** 2))
        return (xn, Sn, yn, Tn, x1n, S1n, y1n, T1n, mun, Lamn, sign_, Gamn, lam_new), res


class HeterogeneousADMM:
    """Eq. (28) solver with binary edge selection z and capacity rows M z = e
    (equality) or M z + s = e, s ≥ 0 (inequality capacities).
    """

    def __init__(self, n: int, r: int, M: np.ndarray, e_cap: np.ndarray,
                 cfg: ADMMConfig = ADMMConfig(), equality: bool = True,
                 edge_ok: np.ndarray | None = None):
        self.n, self.cfg = n, cfg
        self.ops = _TopoOperators(n, cfg.alpha)
        m = self.ops.m
        self.edge_ok = jnp.ones(m, dtype=bool) if edge_ok is None else jnp.asarray(edge_ok)
        self.r = min(r, int(np.asarray(self.edge_ok).sum()))
        assert M.shape[1] == m, f"M must cover all {m} candidate edges"
        self.M = jnp.asarray(M, dtype=jnp.float64)
        self.e_cap = jnp.asarray(e_cap, dtype=jnp.float64)
        self.q = M.shape[0]
        self.equality = equality
        self.c = jnp.zeros(m + 1).at[m].set(-1.0)
        self._step = jax.jit(self._step_impl)

    # X' = (x, S, y, T, z, ν, s); constraint space λ' = (P, Q, w, u, v)
    def A_op(self, X):
        x, S, y, T, z, nu, s = X
        g, lam = x[:-1], x[-1]
        L = self.ops.L_of_g(g)
        I = self.ops.I
        r4 = self.M @ z + (s if not self.equality else 0.0)
        r5 = g - z + nu
        return (L - lam * I + S, L + lam * I + T, jnp.diag(L) + y, r4, r5)

    def AT_op(self, lamv):
        P, Q, w, u, v = lamv
        xg = self.ops.edge_quadform(P + Q) + self.ops.deg_sum(w) + v
        xl = -jnp.trace(P) + jnp.trace(Q)
        x_adj = jnp.concatenate([xg, xl[None]])
        z_adj = self.M.T @ u - v
        nu_adj = v
        s_adj = u if not self.equality else jnp.zeros_like(u)
        return (x_adj, P, w, Q, z_adj, nu_adj, s_adj)

    def b_rhs(self):
        n = self.n
        return (-self.ops.B0, 2.0 * self.ops.I, jnp.ones(n), self.e_cap,
                jnp.zeros(self.ops.m))

    def _step_impl(self, state):
        (x, S, y, T, z, nu, s,
         x1, S1, y1, T1, z1, nu1, s1,
         mu, Lam, sig, Gam, iota, kap, psi, lam_ws) = state
        rho = self.cfg.rho
        m = self.ops.m
        # Y'-update (Eq. 30): per-block projections
        x1n_g = _proj_card_nonneg((x + mu / rho)[:m], self.r, self.edge_ok)
        x1n = jnp.concatenate([x1n_g, jnp.maximum((x + mu / rho)[m], 0.0)[None]])
        S1n = _proj_psd(S + Lam / rho, -1.0)
        y1n = jnp.maximum(y + sig / rho, 0.0)
        T1n = _proj_psd(T + Gam / rho, +1.0)
        z1n = _proj_binary_topr(z + iota / rho, self.r, self.edge_ok)
        nu1n = jnp.maximum(nu + kap / rho, 0.0)
        s1n = jnp.maximum(s + psi / rho, 0.0) if not self.equality else jnp.zeros_like(s)
        # X'-update (Eq. 31)
        V = (x1n - (mu + self.c) / rho, S1n - Lam / rho, y1n - sig / rho,
             T1n - Gam / rho, z1n - iota / rho, nu1n - kap / rho,
             s1n - psi / rho)
        if self.equality:
            # without a slack variable the s-block must stay pinned at 0
            V = V[:6] + (jnp.zeros_like(s),)
        Xn, lam_new = schur_cg_solve(
            self.A_op, self.AT_op, V, self.b_rhs(), lam_ws,
            tol=self.cfg.cg_tol, maxiter=self.cfg.cg_maxiter,
        )
        xn, Sn, yn, Tn, zn, nun, sn = Xn
        if self.equality:
            sn = jnp.zeros_like(s)
        # dual update (Eq. 33)
        mun = mu + rho * (xn - x1n)
        Lamn = Lam + rho * (Sn - S1n)
        sign_ = sig + rho * (yn - y1n)
        Gamn = Gam + rho * (Tn - T1n)
        iotan = iota + rho * (zn - z1n)
        kapn = kap + rho * (nun - nu1n)
        psin = psi + rho * (sn - s1n) if not self.equality else psi
        res = (jnp.sum((xn - x1n) ** 2) + jnp.sum((Sn - S1n) ** 2)
               + jnp.sum((yn - y1n) ** 2) + jnp.sum((Tn - T1n) ** 2)
               + jnp.sum((zn - z1n) ** 2) + jnp.sum((nun - nu1n) ** 2)
               + jnp.sum((sn - s1n) ** 2))
        new_state = (xn, Sn, yn, Tn, zn, nun, sn,
                     x1n, S1n, y1n, T1n, z1n, nu1n, s1n,
                     mun, Lamn, sign_, Gamn, iotan, kapn, psin, lam_new)
        return new_state, res

    def init_state(self, g0=None, z0=None, lam0: float = 0.5):
        n, m, q = self.n, self.ops.m, self.q
        g = jnp.zeros(m) if g0 is None else jnp.asarray(g0, dtype=jnp.float64)
        z = (g > 0).astype(jnp.float64) if z0 is None else jnp.asarray(z0, dtype=jnp.float64)
        x = jnp.concatenate([g, jnp.array([lam0])])
        L = self.ops.L_of_g(g)
        S = -(L - lam0 * self.ops.I + self.ops.B0)
        T = 2 * self.ops.I - (L + lam0 * self.ops.I)
        y = 1.0 - jnp.diag(L)
        nu = z - g
        s = jnp.maximum(self.e_cap - self.M @ z, 0.0) if not self.equality else jnp.zeros(q)
        zn2 = jnp.zeros((n, n))
        lam_ws = (zn2, zn2, jnp.zeros(n), jnp.zeros(q), jnp.zeros(m))
        return (x, S, y, T, z, nu, s,
                x, S, y, T, z, nu, s,
                jnp.zeros(m + 1), zn2, jnp.zeros(n), zn2,
                jnp.zeros(m), jnp.zeros(m), jnp.zeros(q), lam_ws)

    def solve(self, g0=None, z0=None, lam0: float = 0.5) -> ADMMResult:
        state = self.init_state(g0, z0, lam0)
        history, res = [], np.inf
        it = 0
        for it in range(1, self.cfg.max_iters + 1):
            state, res = self._step(state)
            res = float(res)
            if it % self.cfg.check_every == 0 or it == 1:
                history.append((it, res, float(state[0][-1])))
                if self.cfg.verbose:
                    print(f"[admm-het] it={it} res={res:.3e} lam~={float(state[0][-1]):.4f}")
            if res < self.cfg.eps:
                break
        x1, z1 = state[7], state[11]
        x = state[0]
        m = self.ops.m
        return ADMMResult(
            g=np.asarray(x1[:m]), g_raw=np.asarray(x[:m]), lam_tilde=float(x1[m]),
            z=np.asarray(z1), iters=it, residual=res, history=history,
        )
