"""Anytime outer pipeline + unified request/result API (DESIGN.md §17).

The phase-barriered pipeline (``api.optimize_topology``: all SA restarts →
all ADMM → all polish → eval) produces nothing until everything finishes.
This module refactors it into a *pipelined anytime* design:

  - :class:`TopologyRequest` / :class:`TopologyResult` — ONE dataclass pair
    unifying the three previously-divergent entrypoints (``optimize_topology``,
    ``sweep_topologies``, the service's ``TopoRequest``), with a single
    validation path (:func:`validate_request`) and a single scenario→
    ConstraintSet resolution (:func:`resolve_scenario`).
  - :class:`AnytimeSolver` — runs the same stages as the barrier pipeline
    but emission-ordered: feasible classics polish+evaluate first, then per
    restart the chain init → SA → warm candidate → ADMM → rounding → ADMM
    candidate, each candidate entering a monotone best-so-far *incumbent*
    ``(support, W, r_asym, quality_tier, elapsed_ms)`` the moment it is
    evaluated. ``solve(budget_ms=...)`` returns the incumbent at the
    deadline; ``next_improvement()`` is the step/poll handle for
    in-training use. Stage scheduling reuses the PR-3 per-phase profile
    timings: every stage keeps an EMA cost estimate (seedable from tracked
    bench rows via ``seed_profile``) and is skipped once an incumbent
    exists and the estimate no longer fits the remaining budget.
  - :class:`PhaseProfile` — the documented profile schema (phase → seconds,
    ``merge()``/``ms()`` helpers, legacy ``*_s`` dict round-trip), ending
    the ad-hoc mix of ``queue_s``/``solve_s`` seconds vs per-phase keys.

Parity contract: with ``budget_ms=None`` the candidate set, the candidate
*order* used for tie-breaking, and every numeric kernel call (single-item
batched SA / ADMM / polish — bit-equal to their batched forms on this
backend, tested) match the barrier pipeline exactly, so the unbudgeted
anytime result is support- and weight-identical to pre-refactor
``optimize_topology``. With a budget, cheap *previews* (Metropolis-weighted
SA best-so-far graphs) additionally enter the incumbent race so a usable
topology exists within milliseconds; an expired budget with no incumbent
still answers via ``guard.classic_fallback`` with a reason — never an
exception, mirroring the service invariant.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field, replace
from typing import Iterator

import numpy as np

from .constraints import ConstraintSet
from .graph import Topology, all_edges, is_connected
from .weights import metropolis_weights, polish_weights, polish_weights_batched

__all__ = [
    "TopologyRequest", "TopologyResult", "PhaseProfile", "Incumbent",
    "AnytimeSolver", "solve_topology", "solve_topologies",
    "validate_request", "resolve_scenario",
]

_req_counter = itertools.count(1)

_SCENARIOS = ("homo", "node", "constraint")

#: Context-pinned messages for the two scenario-requirement errors. The
#: "api" and "reopt" texts predate this module and are asserted on by
#: tests — byte-identical here so the shims stay drop-in.
_MISSING_BW = {
    "api": ("scenario='node' requires node_bandwidths "
            "(per-node GB/s profile for Algorithm 1)"),
    "reopt": ("scenario='node' re-optimization requires the drifted "
              "node_bandwidths profile"),
    "service": "scenario='node' requires node_bandwidths",
}
_MISSING_CS = {
    "api": "scenario='constraint' requires a ConstraintSet (cs=...)",
    "reopt": ("scenario='constraint' re-optimization requires the drifted "
              "ConstraintSet"),
    "service": "scenario='constraint' requires a ConstraintSet",
}


# ---------------------------------------------------------------------------
# request / result
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TopologyRequest:
    """One topology-optimization problem, shared by the library API, the
    sweep, the service and re-optimization: (n, r, scenario, constraint
    set, bandwidth profile, budget/deadline, restarts/seed overrides).

    ``deadline_ms`` doubles as the anytime budget; ``restarts``/``seed``
    override the config's values when set (None = use config). Field order
    up to ``deadline_ms`` matches the former ``serve.TopoRequest`` so
    positional construction keeps working.
    """

    n: int
    r: int
    scenario: str = "homo"
    node_bandwidths: np.ndarray | None = None
    cs: ConstraintSet | None = None
    deadline_ms: float | None = None
    restarts: int | None = None
    seed: int | None = None
    request_id: int = field(default_factory=lambda: next(_req_counter))


def validate_request(req: TopologyRequest) -> str | None:
    """First malformed field of ``req``, or None — THE validation path for
    every entrypoint (service admission uses the returned string verbatim;
    the library API raises it as a ValueError)."""
    try:
        n, r = int(req.n), int(req.r)
    except (TypeError, ValueError):
        return "n and r must be integers"
    if n < 2:
        return f"n={req.n} (need n >= 2)"
    if r < n - 1:
        return (f"r={req.r} can never connect n={n} nodes "
                f"(need r >= n-1)")
    if req.scenario not in _SCENARIOS:
        return f"unknown scenario {req.scenario!r}"
    if req.scenario == "node":
        if req.node_bandwidths is None:
            return _MISSING_BW["service"]
        bw = np.asarray(req.node_bandwidths, dtype=np.float64)
        if bw.shape != (n,):
            return (f"node_bandwidths shape {bw.shape} != ({n},)")
        if not np.all(np.isfinite(bw)) or not np.all(bw > 0):
            return "node_bandwidths must be finite and positive"
    if req.scenario == "constraint":
        if req.cs is None:
            return _MISSING_CS["service"]
        if req.cs.n != n:
            return f"ConstraintSet.n={req.cs.n} != n={n}"
    if req.deadline_ms is not None and not (req.deadline_ms > 0):
        return f"deadline_ms={req.deadline_ms} (need > 0)"
    if req.restarts is not None and int(req.restarts) < 1:
        return f"restarts={req.restarts} (need >= 1)"
    return None


def resolve_scenario(n: int, r: int, scenario: str,
                     cs: ConstraintSet | None,
                     node_bandwidths: np.ndarray | None,
                     context: str = "api"):
    """Scenario → (ConstraintSet, degree targets, base meta): the phase-0
    block formerly replicated across ``optimize_topology``,
    ``reoptimize_topology`` and the service warm tier. ``context`` selects
    the historical (test-pinned) error text for the two missing-argument
    cases."""
    meta: dict = {"scenario": scenario, "r": r}
    if scenario == "node":
        if node_bandwidths is None:
            raise ValueError(_MISSING_BW[context])
        from .allocation import allocate_edge_capacity, graphical_repair
        from .constraints import node_level_constraints

        alloc = allocate_edge_capacity(np.asarray(node_bandwidths), r)
        e_alloc = graphical_repair(alloc.e)
        cs = node_level_constraints(n, e_alloc, np.asarray(node_bandwidths))
        meta["b_unit"] = alloc.b_unit
        meta["alloc_e"] = e_alloc.tolist()
        return cs, e_alloc, meta
    if scenario == "constraint":
        if cs is None:
            raise ValueError(_MISSING_CS[context])
        return cs, None, meta
    from .api import _homo_degree_targets

    return cs, _homo_degree_targets(n, r), meta


@dataclass
class PhaseProfile:
    """Documented per-phase wall-time profile: phase name → SECONDS.

    Canonical phases: ``prep`` (validation + scenario resolution),
    ``warm`` (greedy init + SA), ``admm``, ``round`` (support extraction +
    repair), ``polish``, ``eval`` (invariants + spectral), ``classic``
    (fallback construction), ``queue``/``solve`` (service-side). Seconds
    everywhere; use :meth:`ms` for milliseconds — this replaces the old
    ad-hoc mix of ``*_s`` dict keys and per-phase ms values.
    """

    phases: dict[str, float] = field(default_factory=dict)

    def add(self, phase: str, seconds: float) -> None:
        self.phases[phase] = self.phases.get(phase, 0.0) + float(seconds)

    def merge(self, other: "PhaseProfile | dict") -> "PhaseProfile":
        """New profile with the phase times of both operands summed."""
        out = PhaseProfile(dict(self.phases))
        src = other.phases if isinstance(other, PhaseProfile) else \
            PhaseProfile.from_dict(other).phases
        for k, v in src.items():
            out.add(k, v)
        return out

    def ms(self, phase: str) -> float:
        return 1e3 * self.phases.get(phase, 0.0)

    @property
    def total_s(self) -> float:
        return float(sum(self.phases.values()))

    @classmethod
    def from_dict(cls, d: dict) -> "PhaseProfile":
        """Parse a legacy profile dict: ``<phase>_s`` values are seconds,
        ``<phase>_ms`` milliseconds, bare numeric keys seconds."""
        out = cls()
        for k, v in d.items():
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                continue
            if k.endswith("_ms"):
                out.add(k[:-3], v / 1e3)
            elif k.endswith("_s"):
                out.add(k[:-2], v)
            else:
                out.add(k, v)
        return out

    def to_dict(self) -> dict:
        """Legacy ``<phase>_s`` dict view (seconds), for consumers of the
        pre-§17 profile plumbing."""
        return {f"{k}_s": v for k, v in self.phases.items()}


@dataclass(frozen=True)
class Incumbent:
    """One best-so-far point of an anytime solve."""

    support: np.ndarray          # bool over all_edges(n)
    W: np.ndarray                # gossip matrix of the incumbent topology
    r_asym: float
    quality_tier: str            # classic | sa_only | warm (pre-completion)
    elapsed_ms: float
    topology: Topology = field(repr=False, compare=False, default=None)
    source: str = ""
    order: int = 0               # barrier candidate-order index (ties)


@dataclass
class TopologyResult:
    """Uniform solve answer: the topology plus quality/latency provenance."""

    topology: Topology | None
    r_asym: float
    quality_tier: str            # full | warm | sa_only | classic
    elapsed_ms: float
    profile: PhaseProfile
    complete: bool               # every stage ran (no budget curtailment)
    reason: str | None = None    # degradation trail, None when clean
    request: TopologyRequest | None = None
    improvements: int = 0        # number of incumbent updates observed

    @property
    def ok(self) -> bool:
        return self.topology is not None


# ---------------------------------------------------------------------------
# the anytime solver
# ---------------------------------------------------------------------------

#: Preview candidates (Metropolis-weighted SA best-so-far graphs) sit
#: outside the barrier candidate set; this order index makes them lose
#: every tie against a real candidate, preserving barrier tie-breaking.
_PREVIEW_ORDER = 1 << 30

#: A stage is skipped (once an incumbent exists) when its EMA cost
#: estimate × this safety factor exceeds the remaining budget — same
#: semantics as ``ServicePolicy.deadline_safety``.
_SAFETY = 1.5

#: EMA smoothing for the per-stage cost estimates.
_EST_ALPHA = 0.5


class AnytimeSolver:
    """Budgeted best-so-far topology solver (see module docstring).

    Usage::

        solver = AnytimeSolver(TopologyRequest(n=64, r=128), cfg)
        res = solver.solve(budget_ms=200)          # incumbent at deadline
        # or poll:
        while (inc := solver.next_improvement()) is not None:
            adopt(inc)                             # r_asym monotone ↓
        res = solver.result()

    The budget clock starts at construction. With no budget the solve runs
    every stage and the result is bit-identical to the barrier pipeline.
    """

    def __init__(self, request: TopologyRequest, cfg=None, *,
                 seed_profile: PhaseProfile | None = None,
                 previews: bool | None = None,
                 clock=time.perf_counter):
        from . import api as _api

        bad = validate_request(request)
        if bad is not None:
            raise ValueError(bad)
        cfg = cfg or _api.BATopoConfig()
        if request.restarts is not None:
            cfg = replace(cfg, restarts=int(request.restarts))
        if request.seed is not None:
            cfg = replace(cfg, seed=int(request.seed))
        _api._validate_pipeline_cfg(cfg)
        self.request = request
        self.cfg = cfg
        self.profile = PhaseProfile()
        self.incumbent: Incumbent | None = None
        self.complete = False
        self.reasons: list[str] = []
        self._previews = previews
        self._clock = clock
        self._t0 = clock()
        self._deadline: float | None = None
        if request.deadline_ms is not None:
            self._deadline = self._t0 + float(request.deadline_ms) / 1e3
        # seed_profile carries PER-STAGE-INVOCATION priors (per restart /
        # per candidate), e.g. a tracked bench row's phase totals divided
        # by its restart count — see TopologyService._seed_ema.
        self._est: dict[str, float] = {}
        if seed_profile is not None:
            for stage in ("warm", "admm", "polish", "eval"):
                v = seed_profile.phases.get(stage)
                if v:
                    self._est[stage] = float(v)
        self._best_val = np.inf
        self._best_order = _PREVIEW_ORDER + 1
        self._n_improvements = 0
        self._curtailed = False
        self._failures: list[str] = []
        self._g_cache: dict[bytes, np.ndarray] = {}      # polished weights
        self._val_cache: dict[tuple, float] = {}
        self._inv_cache: dict[tuple, str | None] = {}
        self._cs: ConstraintSet | None = None
        self._gen: Iterator[Incumbent] = self._stages()

    # -- clocks ----------------------------------------------------------

    @property
    def elapsed_ms(self) -> float:
        return (self._clock() - self._t0) * 1e3

    def _remaining_s(self) -> float | None:
        if self._deadline is None:
            return None
        return self._deadline - self._clock()

    def _expired(self) -> bool:
        rem = self._remaining_s()
        return rem is not None and rem <= 0.0

    def _fits(self, stage: str) -> bool:
        """Budget gate: always run while there is no incumbent (an answer
        beats a deadline); afterwards skip stages whose EMA estimate ×
        safety no longer fits."""
        rem = self._remaining_s()
        if rem is None or self.incumbent is None:
            return True
        est = self._est.get(stage)
        if est is None:
            return True
        return est * _SAFETY <= max(rem, 0.0)

    def _observe(self, stage: str, phase: str, dt: float) -> None:
        self.profile.add(phase, dt)
        prev = self._est.get(stage)
        self._est[stage] = (dt if prev is None
                            else (1 - _EST_ALPHA) * prev + _EST_ALPHA * dt)

    # -- public handle ---------------------------------------------------

    def next_improvement(self) -> Incumbent | None:
        """Advance the solve until the incumbent improves (or everything
        finishes → None). Each returned incumbent has r_asym ≤ the previous
        one's — monotone non-increasing over polls."""
        return next(self._gen, None)

    def solve(self, budget_ms: float | None = None) -> TopologyResult:
        """Drain the solve (optionally tightening/setting the budget, still
        measured from construction) and return the final result."""
        if budget_ms is not None:
            self._deadline = self._t0 + float(budget_ms) / 1e3
        for _ in self._gen:
            pass
        return self.result()

    def result(self) -> TopologyResult:
        inc = self.incumbent
        if inc is None:
            raise RuntimeError(
                "no incumbent yet — call solve() or drain next_improvement()")
        topo = inc.topology
        topo.meta["r_asym"] = inc.r_asym
        tier = "full" if self.complete else inc.quality_tier
        return TopologyResult(
            topology=topo, r_asym=inc.r_asym, quality_tier=tier,
            elapsed_ms=self.elapsed_ms, profile=self.profile,
            complete=self.complete, reason="; ".join(self.reasons) or None,
            request=self.request, improvements=self._n_improvements)

    # -- candidate machinery --------------------------------------------

    def _offer(self, sel: np.ndarray, topo: Topology, order: int, tier: str,
               source: str, polished: bool) -> Incumbent | None:
        """Evaluate a candidate (one invariant check + one r_asym per
        distinct (support, weighting), like ``api._pick_best``) and install
        it as incumbent when it wins the lexicographic (r_asym, candidate
        order) comparison — exactly the barrier's first-strict-minimum
        selection."""
        from .guard import check_invariants

        key = (np.asarray(sel, dtype=bool).tobytes(), polished)
        t0 = self._clock()
        if key not in self._inv_cache:
            self._inv_cache[key] = check_invariants(topo)
        bad = self._inv_cache[key]
        if bad is not None:
            self._observe("eval", "eval", self._clock() - t0)
            self._failures.append(f"{topo.name}: {bad}")
            return None
        if key not in self._val_cache:
            self._val_cache[key] = topo.r_asym()
        val = self._val_cache[key]
        self._observe("eval", "eval", self._clock() - t0)
        if val < self._best_val or (val == self._best_val
                                    and order < self._best_order):
            topo.meta["selected_from"] = source
            self._best_val, self._best_order = val, order
            self._n_improvements += 1
            self.incumbent = Incumbent(
                support=np.asarray(sel, dtype=bool).copy(), W=topo.W,
                r_asym=float(val), quality_tier=tier,
                elapsed_ms=self.elapsed_ms, topology=topo,
                source=source, order=order)
            return self.incumbent
        return None

    def _polish_and_offer(self, sel: np.ndarray, name: str, meta: dict,
                          order: int, tier: str, source: str,
                          ) -> Incumbent | None:
        """Connectivity-check + polish + evaluate one candidate selection —
        the single-item mirror of ``api._finalize_batch`` (bit-equal: the
        device polish is batch-size invariant), with polished weights
        cached per distinct support like the barrier's dedup."""
        n = int(self.request.n)
        cfg = self.cfg
        edges_full = all_edges(n)
        edges = [edges_full[ln] for ln in np.nonzero(sel)[0]]
        if not edges or not is_connected(n, edges):
            return None                      # barrier skips these silently
        skey = np.asarray(sel, dtype=bool).tobytes()
        g = self._g_cache.get(skey)
        if g is None:
            t0 = self._clock()
            g0 = metropolis_weights(n, edges)
            if cfg.polish == "device":
                g = polish_weights_batched(n, [edges], [g0],
                                           iters=cfg.polish_iters,
                                           dtype=cfg.polish_dtype)[0]
            else:
                g = polish_weights(n, edges, g0, iters=cfg.polish_iters)
            self._observe("polish", "polish", self._clock() - t0)
            self._g_cache[skey] = g
        topo = Topology(n, edges, g, name=name,
                        meta={**meta, "connected": True})
        return self._offer(sel, topo, order, tier, source, polished=True)

    def _preview(self, edges: list, order: int, tier: str, source: str,
                 name: str) -> Incumbent | None:
        """Budget-mode-only cheap candidate: Metropolis weights, no polish."""
        n = int(self.request.n)
        if not edges or not is_connected(n, edges):
            return None
        eidx_sel = np.zeros(len(all_edges(n)), dtype=bool)
        from .graph import edge_index
        eidx = edge_index(n)
        for e in edges:
            eidx_sel[eidx[tuple(sorted(e))]] = True
        g = metropolis_weights(n, edges)
        topo = Topology(n, edges, g, name=name, meta={"connected": True})
        return self._offer(eidx_sel, topo, order, tier, source,
                           polished=False)

    # -- the stage graph -------------------------------------------------

    def _stages(self) -> Iterator[Incumbent]:
        from .guard import TopologyInvariantError, classic_fallback

        req = self.request
        n, r, scenario = int(req.n), int(req.r), req.scenario
        yield from self._plan()
        if self.incumbent is None:
            if self._deadline is None:
                # unbudgeted: same terminal errors as the barrier pipeline
                if self._failures:
                    bad = self._failures[0].rsplit(": ", 1)[-1]
                    raise TopologyInvariantError(
                        f"no candidate topology for n={n}, r={r}, "
                        f"scenario={scenario!r} passed release validation — "
                        f"first failure: {self._failures[0]!r} "
                        f"(all: {self._failures})",
                        invariant=bad, failures=self._failures)
                raise ValueError(
                    f"failed to construct any connected topology for n={n}, "
                    f"r={r}, scenario={scenario!r} — every candidate (ADMM, "
                    "warm starts, classics) was disconnected under the "
                    "constraints; raise r or relax the ConstraintSet")
            # budgeted and empty-handed: the guaranteed closed-form answer
            t0 = self._clock()
            fb = classic_fallback(n, r,
                                  self._cs if scenario != "homo" else None)
            self.profile.add("classic", self._clock() - t0)
            self.reasons.append("budget expired — classic fallback")
            sel = np.zeros(len(all_edges(n)), dtype=bool)
            from .graph import edge_index
            eidx = edge_index(n)
            for e in fb.edges:
                sel[eidx[tuple(sorted(e))]] = True
            inc = self._offer(sel, fb, _PREVIEW_ORDER + 1, "classic",
                              "classic-fallback", polished=False)
            if inc is not None:
                yield inc
        self.complete = self.incumbent is not None and not self._curtailed

    def _plan(self) -> Iterator[Incumbent]:
        from . import api as _api

        req, cfg = self.request, self.cfg
        n, r, scenario = int(req.n), int(req.r), req.scenario
        t0 = self._clock()
        cs, deg_targets, meta = resolve_scenario(
            n, r, scenario, req.cs, req.node_bandwidths, context="api")
        self._cs = cs
        self.profile.add("prep", self._clock() - t0)
        R = max(1, cfg.restarts)
        use_z = scenario != "homo"
        sa_cs = cs if scenario != "homo" else None

        # ---- classics first: cheapest path to a polished incumbent ------
        for j, (base_name, sel) in enumerate(_api._classic_candidates(n, r, cs)):
            if self._expired():
                self._note_expiry("classics")
                return
            inc = self._polish_and_offer(
                sel, f"ba-topo(n={n},r={r},{base_name})", dict(meta),
                order=2 * R + j, tier="classic", source=f"classic:{base_name}")
            if inc is not None:
                yield inc

        solver = _api._make_solver(n, r, scenario, cs, cfg)
        previews = (self._previews if self._previews is not None
                    else self._deadline is not None)

        # ---- per-restart chains: init → SA → warm cand → ADMM → cand ----
        for k in range(R):
            if self._expired():
                self._note_expiry(f"restart {k}")
                return
            if not self._fits("warm"):
                self._skip(f"restart {k}", "warm")
                continue
            t0 = self._clock()
            edges0, seed = _api._init_graph(n, r, scenario, cs, deg_targets,
                                            cfg, k)
            annealed = yield from self._anneal(
                n, edges0, seed, sa_cs, cfg, k, previews)
            self._observe("warm", "warm", self._clock() - t0)
            if self._expired():
                self._note_expiry(f"restart {k} (post-SA)")
                return
            warm = _api._pack_warm(n, annealed)
            # warm-start candidate (barrier order 2k+1) — available before
            # the ADMM solve, so it is offered first
            if self._fits("polish"):
                inc = self._polish_and_offer(
                    warm[1].astype(bool), f"ba-topo(n={n},r={r},warm)",
                    dict(meta), order=2 * k + 1, tier="warm",
                    source="warm-start")
                if inc is not None:
                    yield inc
            else:
                self._skip(f"restart {k} warm candidate", "polish")
            if not self._fits("admm"):
                self._skip(f"restart {k}", "admm")
                continue
            if self._expired():
                self._note_expiry(f"restart {k} (pre-ADMM)")
                return
            t0 = self._clock()
            g0, z0, lam0 = warm
            if scenario == "homo":
                res = solver.solve(g0=g0, lam0=lam0)
            else:
                res = solver.solve(g0=g0, z0=z0, lam0=lam0)
            self._observe("admm", "admm", self._clock() - t0)
            t0 = self._clock()
            items, _ = _api._candidate_items(n, r, [warm], [res], cs, cfg,
                                             meta, use_z=use_z)
            self.profile.add("round", self._clock() - t0)
            admm_sel, admm_name, admm_meta = items[0]
            if self._fits("polish") or self.incumbent is None:
                inc = self._polish_and_offer(
                    admm_sel, admm_name, admm_meta, order=2 * k,
                    tier="warm", source="admm")
                if inc is not None:
                    yield inc
            else:
                self._skip(f"restart {k} admm candidate", "polish")

    def _anneal(self, n, edges0, seed, sa_cs, cfg, k, previews):
        """SA for one restart. Unbudgeted: the exact barrier call
        (``_anneal_edges``, one-shot). Budgeted: the chunked stream —
        bit-equal at exhaustion — checking the deadline between chunks and
        adopting the best-so-far graph on expiry; with previews on, each
        improving chunk offers a Metropolis-weighted incumbent."""
        from . import api as _api

        if self._deadline is None:
            return _api._anneal_edges(n, [edges0], [seed], sa_cs, cfg)[0]
        from .warmstart import anneal_topology_stream

        best_edges, last_cost = edges0, np.inf
        t_prev = self._clock()
        for edges_b, costs, t in anneal_topology_stream(
                n, [edges0], sa_cs, iters=cfg.sa_iters, seeds=[seed],
                use_kernel=cfg.sa_kernel):
            dt = self._clock() - t_prev
            prev = self._est.get("warm_chunk")
            self._est["warm_chunk"] = (
                dt if prev is None
                else (1 - _EST_ALPHA) * prev + _EST_ALPHA * dt)
            best_edges = edges_b[0]
            if previews and costs[0] < last_cost:
                last_cost = costs[0]
                inc = self._preview(
                    best_edges, _PREVIEW_ORDER, "sa_only",
                    f"sa-preview:restart{k}",
                    f"ba-topo(n={n},r={int(self.request.r)},sa@{t})")
                if inc is not None:
                    yield inc
            if self._expired() or not self._fits("warm_chunk"):
                if t < cfg.sa_iters:
                    self._curtailed = True
                    self.reasons.append(
                        f"restart {k}: SA curtailed at {t}/{cfg.sa_iters}")
                break
            t_prev = self._clock()
        return best_edges

    def _note_expiry(self, where: str) -> None:
        self._curtailed = True
        self.reasons.append(f"budget expired at {where}")

    def _skip(self, what: str, stage: str) -> None:
        self._curtailed = True
        est = self._est.get(stage)
        self.reasons.append(
            f"{what}: skipped ({stage} est {est * 1e3:.1f}ms does not fit)"
            if est is not None else f"{what}: skipped ({stage})")


# ---------------------------------------------------------------------------
# module-level entrypoints
# ---------------------------------------------------------------------------


def solve_topology(request: TopologyRequest, *, cfg=None,
                   budget_ms: float | None = None,
                   profile: dict | None = None,
                   seed_profile: PhaseProfile | None = None,
                   engine: str = "anytime") -> TopologyResult:
    """Solve one :class:`TopologyRequest`.

    ``engine="anytime"`` (default) runs the :class:`AnytimeSolver` — with
    ``budget_ms`` (or ``request.deadline_ms``) set it returns the best
    incumbent at the deadline, otherwise the barrier-identical full solve.
    ``engine="barrier"`` runs the preserved phase-barriered pipeline
    (exactly the pre-§17 ``optimize_topology``) — benchmarks use it as the
    comparison arm. ``profile``, when a dict, receives the legacy
    ``<phase>_s`` keys in both engines.
    """
    if engine == "barrier":
        from . import api as _api

        prof: dict = {} if profile is None else profile
        t0 = time.perf_counter()
        topo = _api._optimize_request(
            int(request.n), int(request.r), scenario=request.scenario,
            cs=request.cs, node_bandwidths=request.node_bandwidths,
            cfg=cfg, profile=prof)
        return TopologyResult(
            topology=topo, r_asym=float(topo.meta["r_asym"]),
            quality_tier="full",
            elapsed_ms=(time.perf_counter() - t0) * 1e3,
            profile=PhaseProfile.from_dict(prof), complete=True,
            request=request)
    if engine != "anytime":
        raise ValueError(f"unknown engine {engine!r}; "
                         "expected 'anytime' or 'barrier'")
    solver = AnytimeSolver(request, cfg, seed_profile=seed_profile)
    res = solver.solve(budget_ms=budget_ms)
    if profile is not None:
        profile.update(res.profile.to_dict())
    return res


def solve_topologies(requests, *, cfg=None) -> list[TopologyResult]:
    """Solve many requests, amortizing where the problem shape allows: for
    homogeneous unbudgeted requests on the default solver path, all
    same-n instances run as ONE vmapped sweep dispatch (the former
    ``sweep_topologies`` engine); everything else solves individually.
    Results align with the input order."""
    from . import api as _api

    requests = list(requests)
    cfg = cfg or _api.BATopoConfig()
    _api._validate_pipeline_cfg(cfg)
    results: list[TopologyResult | None] = [None] * len(requests)
    groups: dict[int, list[int]] = {}
    for i, q in enumerate(requests):
        if (q.scenario == "homo" and q.deadline_ms is None
                and q.restarts is None and q.seed is None
                and cfg.admm.driver == "scan"
                and cfg.admm.solver != "kkt_bicgstab_ilu"):
            groups.setdefault(int(q.n), []).append(i)
    for n, idxs in groups.items():
        t0 = time.perf_counter()
        out = _api._sweep_one_n(n, [int(requests[i].r) for i in idxs], cfg)
        dt_ms = (time.perf_counter() - t0) * 1e3
        for i in idxs:
            topo = out[(n, int(requests[i].r))]
            results[i] = TopologyResult(
                topology=topo,
                r_asym=(float(topo.meta["r_asym"]) if topo is not None
                        else float("inf")),
                quality_tier="full", elapsed_ms=dt_ms,
                profile=PhaseProfile(), complete=True,
                reason=None if topo is not None
                else "no connected candidate under the constraints",
                request=requests[i])
    for i, q in enumerate(requests):
        if results[i] is None:
            results[i] = solve_topology(q, cfg=cfg)
    return results
