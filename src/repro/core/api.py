"""High-level BA-Topo API: one call per paper scenario.

Pipeline (the paper's full recipe, §IV–§VI):
  1. scenario → ConstraintSet (M, e) and candidate-edge admissibility,
  2. Algorithm 1 (node scenarios) → per-node edge capacities maximizing b_unit,
  3. simulated-annealing warm start (low ASPL, feasible) [§VI],
  4. Algorithm 2 ADMM (homogeneous Eq. 20 / heterogeneous Eq. 28) — with
     ``cfg.restarts > 1`` all restarts are solved in one batched,
     vmapped device call (engine ``solve_batched``, DESIGN.md §4),
  5. support extraction + greedy feasibility repair (beyond paper, see
     DESIGN.md §6) + convex weight polish,
  6. keep the better of {warm start polished, ADMM polished} — the ADMM is
     non-convex (cardinality / binary constraints), so this guards against
     bad local points, mirroring the paper's initialization-sensitivity note.

``sweep_topologies`` amortizes step 4 across many (n, r) scenarios: for a
fixed n the whole cardinality sweep runs as one vmapped solve.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .admm import ADMMConfig, HeterogeneousADMM, HomogeneousADMM
from .allocation import allocate_edge_capacity
from .anneal import anneal_topology, greedy_degree_graph
from .constraints import ConstraintSet
from .graph import Topology, all_edges, edge_index, is_connected, r_asym, weight_matrix_from_weights
from .weights import metropolis_weights, polish_weights

__all__ = ["BATopoConfig", "optimize_topology", "sweep_topologies",
           "extract_support", "repair_selection"]


@dataclass
class BATopoConfig:
    admm: ADMMConfig = field(default_factory=ADMMConfig)
    sa_iters: int = 1500
    polish_iters: int = 500
    support_tol: float = 1e-6
    seed: int = 0
    restarts: int = 1


def extract_support(
    n: int, g: np.ndarray, r: int, tol: float, z: np.ndarray | None = None,
    edge_ok: np.ndarray | None = None,
) -> np.ndarray:
    """Boolean selection over the full candidate edge list: top-r weights
    (optionally gated by the binary z of the heterogeneous solver)."""
    m = len(g)
    score = np.asarray(g, dtype=np.float64).copy()
    if z is not None:
        score = score + 1e-3 * np.asarray(z)  # prefer z-selected edges on ties
    if edge_ok is not None:
        score[~edge_ok] = -np.inf
    score[score <= tol] = -np.inf
    k = min(r, int(np.isfinite(score).sum()))
    sel = np.zeros(m, dtype=bool)
    if k > 0:
        idx = np.argpartition(-score, k - 1)[:k]
        sel[idx] = True
    return sel


def repair_selection(n: int, sel: np.ndarray, g: np.ndarray, cs: ConstraintSet | None) -> np.ndarray:
    """Greedy feasibility + connectivity repair of a rounded edge selection.

    1. While a capacity row is violated (M z > e), drop the lowest-weight
       selected edge contributing to the most-violated row.
    2. While the graph is disconnected, add the highest-weight admissible
       edge joining two components that does not violate capacities.

    Capacity usage ``M @ sel`` is computed once per phase and updated
    incrementally as edges are dropped/added (it used to be recomputed per
    candidate edge, a quadratic hot spot on dense candidate sets).
    """
    edges_full = all_edges(n)
    sel = sel.copy()
    g = np.asarray(g, dtype=np.float64)
    usage = cs.M @ sel.astype(np.int64) if cs is not None else None

    if cs is not None:
        while True:
            over = usage - cs.e_cap
            if np.all(over <= 0):
                break
            row = int(np.argmax(over))
            members = [l for l in np.nonzero(sel)[0] if cs.M[row, l]]
            drop = min(members, key=lambda l: g[l])
            sel[drop] = False
            usage = usage - cs.M[:, drop]

    def comps(sel_mask):
        parent = list(range(n))

        def find(a):
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        for l in np.nonzero(sel_mask)[0]:
            i, j = edges_full[l]
            ri, rj = find(i), find(j)
            if ri != rj:
                parent[ri] = rj
        return [find(i) for i in range(n)]

    for _ in range(n):
        c = comps(sel)
        if len(set(c)) == 1:
            break
        cands = []
        for l, (i, j) in enumerate(edges_full):
            if sel[l] or c[i] == c[j]:
                continue
            if cs is not None:
                if not cs.edge_ok[l]:
                    continue
                if np.any(usage + cs.M[:, l] > cs.e_cap):
                    continue
            cands.append(l)
        if not cands:
            break  # cannot connect under capacities — caller handles r_asym=1
        best = max(cands, key=lambda l: g[l])
        sel[best] = True
        if cs is not None:
            usage = usage + cs.M[:, best]
    return sel


def _homo_degree_targets(n: int, r: int) -> np.ndarray:
    """Balanced degree sequence with Σd = 2r (homogeneous Algorithm-1 limit)."""
    base = (2 * r) // n
    extra = (2 * r) % n
    d = np.full(n, base, dtype=np.int64)
    d[:extra] += 1
    return np.minimum(d, n - 1)


def _finalize(n: int, sel: np.ndarray, cfg: BATopoConfig, name: str,
              cs: ConstraintSet | None, meta: dict) -> Topology:
    edges_full = all_edges(n)
    edges = [edges_full[l] for l in np.nonzero(sel)[0]]
    if not edges or not is_connected(n, edges):
        g = metropolis_weights(n, edges) if edges else np.zeros(0)
        t = Topology(n, edges, g, name=name, meta={**meta, "connected": False})
        return t
    g0 = metropolis_weights(n, edges)
    g = polish_weights(n, edges, g0, iters=cfg.polish_iters)
    t = Topology(n, edges, g, name=name, meta={**meta, "connected": True})
    return t


def _warm_start(n: int, r: int, scenario: str, cs: ConstraintSet | None,
                deg_targets, cfg: BATopoConfig, restart: int):
    """Host-side warm start: greedy feasible graph + simulated annealing.
    Returns (g0, z0, lam0)."""
    seed = cfg.seed + 1000 * restart
    rng = np.random.default_rng(seed)
    if deg_targets is not None:
        warm_cs = cs if scenario == "node" else None
        edges0 = greedy_degree_graph(n, deg_targets, rng, warm_cs)
    else:
        edges0 = _greedy_constraint_graph(n, r, cs, rng)
    edges0 = anneal_topology(n, edges0, cs if scenario != "homo" else None,
                             iters=cfg.sa_iters, seed=seed)
    eidx = edge_index(n)
    m = len(all_edges(n))
    z0 = np.zeros(m)
    for e in edges0:
        z0[eidx[e]] = 1.0
    g0 = np.zeros(m)
    gm = metropolis_weights(n, edges0)
    for k, e in enumerate(edges0):
        g0[eidx[e]] = gm[k]
    W0 = weight_matrix_from_weights(n, edges0, gm)
    lam0 = max(1.0 - r_asym(W0), 0.05)
    return g0, z0, lam0


def _make_solver(n: int, r: int, scenario: str, cs: ConstraintSet | None,
                 cfg: BATopoConfig):
    if scenario == "homo":
        return HomogeneousADMM(n, r, cfg.admm)
    return HeterogeneousADMM(
        n, r, np.asarray(cs.M, dtype=np.float64), np.asarray(cs.e_cap, dtype=np.float64),
        cfg.admm, equality=cs.equality, edge_ok=np.asarray(cs.edge_ok),
    )


def optimize_topology(
    n: int,
    r: int,
    scenario: str = "homo",
    cs: ConstraintSet | None = None,
    node_bandwidths: np.ndarray | None = None,
    cfg: BATopoConfig | None = None,
) -> Topology:
    """Produce a BA-Topo for the given scenario.

    scenario ∈ {"homo", "node", "constraint"}:
      - "homo": Eq. (9) with Card(g) ≤ r.
      - "node": §IV-B1 — requires ``node_bandwidths``; Algorithm 1 allocates
        per-node capacities, then the heterogeneous ADMM runs with equality
        degree rows.
      - "constraint": any ConstraintSet (intra-server, BCube, pod-boundary)
        with inequality capacities.

    With ``cfg.restarts > 1`` and a JAX backend, all restarts are solved by
    one batched device call; the best candidate (lowest ``r_asym`` after
    repair + polish) wins.
    """
    cfg = cfg or BATopoConfig()
    meta: dict = {"scenario": scenario, "r": r}

    if scenario == "node":
        assert node_bandwidths is not None
        alloc = allocate_edge_capacity(np.asarray(node_bandwidths), r)
        from .allocation import graphical_repair
        from .constraints import node_level_constraints

        e_alloc = graphical_repair(alloc.e)
        cs = node_level_constraints(n, e_alloc, np.asarray(node_bandwidths))
        meta["b_unit"] = alloc.b_unit
        meta["alloc_e"] = e_alloc.tolist()
        deg_targets = e_alloc
    elif scenario == "constraint":
        assert cs is not None
        deg_targets = None
    else:
        deg_targets = _homo_degree_targets(n, r)

    # ---- warm starts (host) + one solver for every restart ------------------
    n_restarts = max(1, cfg.restarts)
    warms = [_warm_start(n, r, scenario, cs, deg_targets, cfg, k)
             for k in range(n_restarts)]
    warm_topos = [_finalize(n, z0.astype(bool), cfg, f"ba-topo(n={n},r={r},warm)",
                            cs, dict(meta)) for _, z0, _ in warms]

    solver = _make_solver(n, r, scenario, cs, cfg)

    # ---- ADMM: batched restarts in one device call (scan driver only; an
    # explicit driver="python" request keeps the per-restart loop) ----------
    if (n_restarts > 1 and cfg.admm.solver != "kkt_bicgstab_ilu"
            and cfg.admm.driver == "scan"):
        g0s = np.stack([w[0] for w in warms])
        lam0s = np.asarray([w[2] for w in warms])
        if scenario == "homo":
            results = solver.solve_batched(g0s, lam0s)
        else:
            results = solver.solve_batched(g0s, np.stack([w[1] for w in warms]), lam0s)
    elif scenario == "homo":
        results = [solver.solve(g0=g0, lam0=lam0) for g0, _, lam0 in warms]
    else:
        results = [solver.solve(g0=g0, z0=z0, lam0=lam0) for g0, z0, lam0 in warms]

    best_topo: Topology | None = None
    for (g0, z0, lam0), warm_topo, res in zip(warms, warm_topos, results):
        if scenario == "homo":
            sel = extract_support(n, res.g + res.g_raw, r, cfg.support_tol)
        else:
            sel = extract_support(n, res.g + res.g_raw, r, cfg.support_tol, z=res.z,
                                  edge_ok=np.asarray(cs.edge_ok))
        sel = repair_selection(n, sel, res.g + res.g_raw, cs)
        admm_topo = _finalize(n, sel, cfg, f"ba-topo(n={n},r={r})", cs, {**meta,
                              "admm_iters": res.iters, "admm_residual": res.residual,
                              "lam_tilde": res.lam_tilde})
        for cand in (admm_topo, warm_topo):
            if not cand.meta.get("connected", False):
                continue
            if best_topo is None or cand.r_asym() < best_topo.r_asym():
                src = "admm" if cand is admm_topo else "warm-start"
                cand.meta["selected_from"] = src
                best_topo = cand

    best_topo = _consider_classics(n, r, cfg, cs, meta, best_topo)

    assert best_topo is not None, "failed to construct any connected topology"
    best_topo.meta["r_asym"] = best_topo.r_asym()
    return best_topo


def _consider_classics(n: int, r: int, cfg: BATopoConfig,
                       cs: ConstraintSet | None, meta: dict,
                       best_topo: Topology | None) -> Topology | None:
    """Classic-topology candidates: the ADMM is non-convex, and on small
    tightly-budgeted instances a known-good structure (ring / torus) that
    happens to be feasible can beat a weak local optimum. Polish their
    weights with the same convex step so the comparison is fair."""
    from .topologies import make_baseline
    classic: list = []
    for kind in ("ring", "torus", "hypercube"):
        try:
            classic.append(make_baseline(kind, n))
        except Exception:
            continue
    eidx = edge_index(n)
    for base in classic:
        if len(base.edges) > r or base.meta.get("directed"):
            continue
        sel = np.zeros(len(all_edges(n)), dtype=bool)
        for e in base.edges:
            sel[eidx[tuple(sorted(e))]] = True
        if cs is not None and not cs.feasible(sel):
            continue
        cand = _finalize(n, sel, cfg, f"ba-topo(n={n},r={r},{base.name})", cs,
                         dict(meta))
        if cand.meta.get("connected") and (
                best_topo is None or cand.r_asym() < best_topo.r_asym()):
            cand.meta["selected_from"] = f"classic:{base.name}"
            best_topo = cand
    return best_topo


def sweep_topologies(
    ns, rs, cfg: BATopoConfig | None = None,
) -> dict:
    """Homogeneous multi-scenario sweep: a BA-Topo for every (n, r) pair.

    For each node count n, the whole cardinality sweep ``rs`` runs as ONE
    vmapped, scan-compiled ADMM call (engine ``solve_sweep_spec`` — the
    budget r is a data leaf of the ProblemSpec, so instances with different
    budgets share a compilation). Warm starts and post-processing (support
    extraction, repair, polish, warm-start and classic-baseline comparison)
    stay per-instance on host. Returns ``{(n, r): Topology}``, keyed by the
    *requested* r (budgets above the candidate-edge count are clamped for
    the solve); a value is ``None`` if no connected candidate was found.
    Unlike ``optimize_topology``, the sweep uses one warm start per (n, r)
    — ``cfg.restarts`` is not consulted — and, like ``solve_batched``, it
    always runs the vmapped scan driver: a ``driver="python"`` preference
    applies only to ``optimize_topology``/``solve``.
    """
    import jax
    import jax.numpy as jnp

    from .engine import init_state, make_homo_spec, solve_sweep_spec

    cfg = cfg or BATopoConfig()
    if cfg.admm.driver not in ("scan", "python"):
        raise ValueError(
            f"unknown driver {cfg.admm.driver!r}; expected 'scan' or 'python'")
    if cfg.admm.solver == "kkt_bicgstab_ilu":
        raise ValueError(
            "sweep_topologies needs a device backend (schur_cg or "
            "kkt_bicgstab); the scipy-ILU backend is host-side")
    out: dict = {}
    for n in ns:
        m = len(all_edges(n))
        rs_req = [int(r) for r in rs]
        rs_n = [min(r, m) for r in rs_req]  # solve with the clamped budget
        spec = make_homo_spec(n, max(rs_n), cfg.admm)
        warms = []
        for k, r in enumerate(rs_n):
            deg_targets = _homo_degree_targets(n, r)
            warms.append(_warm_start(n, r, "homo", None, deg_targets, cfg, k))
        states = [init_state(spec, jnp.asarray(g0), lam0) for g0, _, lam0 in warms]
        batched = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        results = solve_sweep_spec(spec, np.asarray(rs_n), batched, cfg.admm)
        for (r_req, r, (g0, z0, lam0), res) in zip(rs_req, rs_n, warms, results):
            meta = {"scenario": "homo", "r": r}
            sel = extract_support(n, res.g + res.g_raw, r, cfg.support_tol)
            sel = repair_selection(n, sel, res.g + res.g_raw, None)
            admm_topo = _finalize(n, sel, cfg, f"ba-topo(n={n},r={r})", None,
                                  {**meta, "admm_iters": res.iters,
                                   "admm_residual": res.residual,
                                   "lam_tilde": res.lam_tilde})
            warm_topo = _finalize(n, z0.astype(bool), cfg,
                                  f"ba-topo(n={n},r={r},warm)", None, dict(meta))
            best = None
            for cand, src in ((admm_topo, "admm"), (warm_topo, "warm-start")):
                if not cand.meta.get("connected", False):
                    continue
                if best is None or cand.r_asym() < best.r_asym():
                    cand.meta["selected_from"] = src
                    best = cand
            best = _consider_classics(n, r, cfg, None, meta, best)
            if best is not None:
                best.meta["r_asym"] = best.r_asym()
            out[(n, r_req)] = best  # keyed by the *requested* budget
    return out


def _greedy_constraint_graph(n: int, r: int, cs: ConstraintSet, rng) -> list[tuple[int, int]]:
    """Random feasible connected graph with ≤ r edges under ``cs`` capacities."""
    edges_full = all_edges(n)
    m = len(edges_full)
    order = [l for l in range(m) if cs.edge_ok[l]]
    for _ in range(256):
        rng.shuffle(order)
        usage = np.zeros(cs.q, dtype=np.int64)
        sel = np.zeros(m, dtype=bool)
        count = 0
        # first pass: spanning-tree bias for connectivity
        comp = list(range(n))

        def find(a):
            while comp[a] != a:
                comp[a] = comp[comp[a]]
                a = comp[a]
            return a

        for phase in (0, 1):
            for l in order:
                if count >= r:
                    break
                if sel[l]:
                    continue
                i, j = edges_full[l]
                if phase == 0 and find(i) == find(j):
                    continue
                col = cs.M[:, l]
                if np.any(usage + col > cs.e_cap):
                    continue
                sel[l] = True
                usage += col
                count += 1
                comp[find(i)] = find(j)
        edges = [edges_full[l] for l in np.nonzero(sel)[0]]
        if is_connected(n, edges):
            return edges
    raise RuntimeError("could not build a feasible connected warm start")
