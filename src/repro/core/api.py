"""High-level BA-Topo API: one call per paper scenario.

Pipeline (the paper's full recipe, §IV–§VI):
  1. scenario → ConstraintSet (M, e) and candidate-edge admissibility,
  2. Algorithm 1 (node scenarios) → per-node edge capacities maximizing b_unit,
  3. simulated-annealing warm start (low ASPL, feasible) [§VI],
  4. Algorithm 2 ADMM (homogeneous Eq. 20 / heterogeneous Eq. 28),
  5. support extraction + greedy feasibility repair (beyond paper, see
     DESIGN.md §6) + convex weight polish,
  6. keep the better of {warm start polished, ADMM polished} — the ADMM is
     non-convex (cardinality / binary constraints), so this guards against
     bad local points, mirroring the paper's initialization-sensitivity note.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .admm import ADMMConfig, HeterogeneousADMM, HomogeneousADMM
from .allocation import allocate_edge_capacity
from .anneal import anneal_topology, greedy_degree_graph
from .constraints import ConstraintSet
from .graph import Topology, all_edges, edge_index, is_connected, r_asym, weight_matrix_from_weights
from .weights import metropolis_weights, polish_weights

__all__ = ["BATopoConfig", "optimize_topology", "extract_support", "repair_selection"]


@dataclass
class BATopoConfig:
    admm: ADMMConfig = field(default_factory=ADMMConfig)
    sa_iters: int = 1500
    polish_iters: int = 500
    support_tol: float = 1e-6
    seed: int = 0
    restarts: int = 1


def extract_support(
    n: int, g: np.ndarray, r: int, tol: float, z: np.ndarray | None = None,
    edge_ok: np.ndarray | None = None,
) -> np.ndarray:
    """Boolean selection over the full candidate edge list: top-r weights
    (optionally gated by the binary z of the heterogeneous solver)."""
    m = len(g)
    score = np.asarray(g, dtype=np.float64).copy()
    if z is not None:
        score = score + 1e-3 * np.asarray(z)  # prefer z-selected edges on ties
    if edge_ok is not None:
        score[~edge_ok] = -np.inf
    score[score <= tol] = -np.inf
    k = min(r, int(np.isfinite(score).sum()))
    sel = np.zeros(m, dtype=bool)
    if k > 0:
        idx = np.argpartition(-score, k - 1)[:k]
        sel[idx] = True
    return sel


def repair_selection(n: int, sel: np.ndarray, g: np.ndarray, cs: ConstraintSet | None) -> np.ndarray:
    """Greedy feasibility + connectivity repair of a rounded edge selection.

    1. While a capacity row is violated (M z > e), drop the lowest-weight
       selected edge contributing to the most-violated row.
    2. While the graph is disconnected, add the highest-weight admissible
       edge joining two components that does not violate capacities.
    """
    edges_full = all_edges(n)
    eidx = edge_index(n)
    sel = sel.copy()
    g = np.asarray(g, dtype=np.float64)

    if cs is not None:
        while True:
            usage = cs.M @ sel.astype(np.int64)
            over = usage - cs.e_cap
            if np.all(over <= 0):
                break
            row = int(np.argmax(over))
            members = [l for l in np.nonzero(sel)[0] if cs.M[row, l]]
            drop = min(members, key=lambda l: g[l])
            sel[drop] = False

    def comps(sel_mask):
        parent = list(range(n))

        def find(a):
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        for l in np.nonzero(sel_mask)[0]:
            i, j = edges_full[l]
            ri, rj = find(i), find(j)
            if ri != rj:
                parent[ri] = rj
        return [find(i) for i in range(n)]

    for _ in range(n):
        c = comps(sel)
        if len(set(c)) == 1:
            break
        cands = []
        for l, (i, j) in enumerate(edges_full):
            if sel[l] or c[i] == c[j]:
                continue
            if cs is not None:
                if not cs.edge_ok[l]:
                    continue
                usage = cs.M @ sel.astype(np.int64)
                if np.any(usage + cs.M[:, l] > cs.e_cap):
                    continue
            cands.append(l)
        if not cands:
            break  # cannot connect under capacities — caller handles r_asym=1
        best = max(cands, key=lambda l: g[l])
        sel[best] = True
    return sel


def _homo_degree_targets(n: int, r: int) -> np.ndarray:
    """Balanced degree sequence with Σd = 2r (homogeneous Algorithm-1 limit)."""
    base = (2 * r) // n
    extra = (2 * r) % n
    d = np.full(n, base, dtype=np.int64)
    d[:extra] += 1
    return np.minimum(d, n - 1)


def _finalize(n: int, sel: np.ndarray, cfg: BATopoConfig, name: str,
              cs: ConstraintSet | None, meta: dict) -> Topology:
    edges_full = all_edges(n)
    edges = [edges_full[l] for l in np.nonzero(sel)[0]]
    if not edges or not is_connected(n, edges):
        g = metropolis_weights(n, edges) if edges else np.zeros(0)
        t = Topology(n, edges, g, name=name, meta={**meta, "connected": False})
        return t
    g0 = metropolis_weights(n, edges)
    g = polish_weights(n, edges, g0, iters=cfg.polish_iters)
    t = Topology(n, edges, g, name=name, meta={**meta, "connected": True})
    return t


def optimize_topology(
    n: int,
    r: int,
    scenario: str = "homo",
    cs: ConstraintSet | None = None,
    node_bandwidths: np.ndarray | None = None,
    cfg: BATopoConfig | None = None,
) -> Topology:
    """Produce a BA-Topo for the given scenario.

    scenario ∈ {"homo", "node", "constraint"}:
      - "homo": Eq. (9) with Card(g) ≤ r.
      - "node": §IV-B1 — requires ``node_bandwidths``; Algorithm 1 allocates
        per-node capacities, then the heterogeneous ADMM runs with equality
        degree rows.
      - "constraint": any ConstraintSet (intra-server, BCube, pod-boundary)
        with inequality capacities.
    """
    cfg = cfg or BATopoConfig()
    rng = np.random.default_rng(cfg.seed)
    meta: dict = {"scenario": scenario, "r": r}

    if scenario == "node":
        assert node_bandwidths is not None
        alloc = allocate_edge_capacity(np.asarray(node_bandwidths), r)
        from .allocation import graphical_repair
        from .constraints import node_level_constraints

        e_alloc = graphical_repair(alloc.e)
        cs = node_level_constraints(n, e_alloc, np.asarray(node_bandwidths))
        meta["b_unit"] = alloc.b_unit
        meta["alloc_e"] = e_alloc.tolist()
        deg_targets = e_alloc
    elif scenario == "constraint":
        assert cs is not None
        deg_targets = None
    else:
        deg_targets = _homo_degree_targets(n, r)

    # ---- warm start ---------------------------------------------------------
    best_topo: Topology | None = None

    for restart in range(max(1, cfg.restarts)):
        seed = cfg.seed + 1000 * restart
        rng = np.random.default_rng(seed)
        if deg_targets is not None:
            warm_cs = cs if scenario == "node" else None
            edges0 = greedy_degree_graph(n, deg_targets, rng, warm_cs)
        else:
            edges0 = _greedy_constraint_graph(n, r, cs, rng)
        edges0 = anneal_topology(n, edges0, cs if scenario != "homo" else None,
                                 iters=cfg.sa_iters, seed=seed)
        eidx = edge_index(n)
        m = len(all_edges(n))
        z0 = np.zeros(m)
        for e in edges0:
            z0[eidx[e]] = 1.0
        g0 = np.zeros(m)
        gm = metropolis_weights(n, edges0)
        for k, e in enumerate(edges0):
            g0[eidx[e]] = gm[k]
        W0 = weight_matrix_from_weights(n, edges0, gm)
        lam0 = max(1.0 - r_asym(W0), 0.05)

        warm_sel = z0.astype(bool)
        warm_topo = _finalize(n, warm_sel, cfg, f"ba-topo(n={n},r={r},warm)", cs, dict(meta))

        # ---- ADMM ------------------------------------------------------------
        if scenario == "homo":
            solver = HomogeneousADMM(n, r, cfg.admm)
            res = solver.solve(g0=g0, lam0=lam0)
            sel = extract_support(n, res.g + res.g_raw, r, cfg.support_tol)
        else:
            solver = HeterogeneousADMM(
                n, r, np.asarray(cs.M, dtype=np.float64), np.asarray(cs.e_cap, dtype=np.float64),
                cfg.admm, equality=cs.equality, edge_ok=np.asarray(cs.edge_ok),
            )
            res = solver.solve(g0=g0, z0=z0, lam0=lam0)
            sel = extract_support(n, res.g + res.g_raw, r, cfg.support_tol, z=res.z,
                                  edge_ok=np.asarray(cs.edge_ok))
        sel = repair_selection(n, sel, res.g + res.g_raw, cs)
        admm_topo = _finalize(n, sel, cfg, f"ba-topo(n={n},r={r})", cs, {**meta,
                              "admm_iters": res.iters, "admm_residual": res.residual,
                              "lam_tilde": res.lam_tilde})

        for cand in (admm_topo, warm_topo):
            if not cand.meta.get("connected", False):
                continue
            if best_topo is None or cand.r_asym() < best_topo.r_asym():
                src = "admm" if cand is admm_topo else "warm-start"
                cand.meta["selected_from"] = src
                best_topo = cand

    # classic-topology candidates: the ADMM is non-convex, and on small
    # tightly-budgeted instances a known-good structure (ring / torus) that
    # happens to be feasible can beat a weak local optimum. Polish their
    # weights with the same convex step so the comparison is fair.
    from .topologies import make_baseline
    classic: list = []
    for kind in ("ring", "torus", "hypercube"):
        try:
            classic.append(make_baseline(kind, n))
        except Exception:
            continue
    eidx = edge_index(n)
    for base in classic:
        if len(base.edges) > r or base.meta.get("directed"):
            continue
        sel = np.zeros(len(all_edges(n)), dtype=bool)
        for e in base.edges:
            sel[eidx[tuple(sorted(e))]] = True
        if cs is not None and not cs.feasible(sel):
            continue
        cand = _finalize(n, sel, cfg, f"ba-topo(n={n},r={r},{base.name})", cs,
                         dict(meta))
        if cand.meta.get("connected") and (
                best_topo is None or cand.r_asym() < best_topo.r_asym()):
            cand.meta["selected_from"] = f"classic:{base.name}"
            best_topo = cand

    assert best_topo is not None, "failed to construct any connected topology"
    best_topo.meta["r_asym"] = best_topo.r_asym()
    return best_topo


def _greedy_constraint_graph(n: int, r: int, cs: ConstraintSet, rng) -> list[tuple[int, int]]:
    """Random feasible connected graph with ≤ r edges under ``cs`` capacities."""
    edges_full = all_edges(n)
    m = len(edges_full)
    order = [l for l in range(m) if cs.edge_ok[l]]
    for _ in range(256):
        rng.shuffle(order)
        usage = np.zeros(cs.q, dtype=np.int64)
        sel = np.zeros(m, dtype=bool)
        count = 0
        # first pass: spanning-tree bias for connectivity
        comp = list(range(n))

        def find(a):
            while comp[a] != a:
                comp[a] = comp[comp[a]]
                a = comp[a]
            return a

        for phase in (0, 1):
            for l in order:
                if count >= r:
                    break
                if sel[l]:
                    continue
                i, j = edges_full[l]
                if phase == 0 and find(i) == find(j):
                    continue
                col = cs.M[:, l]
                if np.any(usage + col > cs.e_cap):
                    continue
                sel[l] = True
                usage += col
                count += 1
                comp[find(i)] = find(j)
        edges = [edges_full[l] for l in np.nonzero(sel)[0]]
        if is_connected(n, edges):
            return edges
    raise RuntimeError("could not build a feasible connected warm start")
