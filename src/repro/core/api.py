"""High-level BA-Topo API: one call per paper scenario.

Pipeline (the paper's full recipe, §IV–§VI):
  1. scenario → ConstraintSet (M, e) and candidate-edge admissibility,
  2. Algorithm 1 (node scenarios) → per-node edge capacities maximizing b_unit,
  3. simulated-annealing warm start (low ASPL, feasible) [§VI] — by
     default the *device* SA (``core.warmstart``): all restarts annealed
     in one vmapped, scan-compiled call with matmul-BFS ASPL,
  4. Algorithm 2 ADMM (homogeneous Eq. 20 / heterogeneous Eq. 28) — with
     ``cfg.restarts > 1`` all restarts are solved in one batched,
     vmapped device call (engine ``solve_batched``, DESIGN.md §4),
  5. support extraction + greedy feasibility repair (beyond paper, see
     DESIGN.md §6) + convex weight polish — every candidate of the solve
     (restarts × {admm, warm} × classics) polished in one vmapped,
     scan-compiled call (``weights.polish_weights_batched``),
  6. keep the best of {ADMM, warm start, feasible classics}, each
     evaluated by ONE ``r_asym`` (Lanczos above ``FAST_SPECTRAL_MIN_N``)
     — the ADMM is non-convex (cardinality / binary constraints), so this
     guards against bad local points, mirroring the paper's
     initialization-sensitivity note.

The host warm start / polish survive as ``warmstart="host"`` /
``polish="host"`` — the ``driver="python"``-style fallback and parity
oracle for the device outer pipeline (DESIGN.md §10). Pass ``profile={}``
to ``optimize_topology`` to collect the per-phase wall-time breakdown
(warm start / ADMM / round+repair / polish / eval).

``sweep_topologies`` amortizes step 4 across many (n, r) scenarios: for a
fixed n the whole cardinality sweep runs as one vmapped solve.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field

import numpy as np

from .admm import ADMMConfig, HeterogeneousADMM, HomogeneousADMM
from .anneal import anneal_topology, greedy_degree_graph
from .constraints import ConstraintSet
from .graph import Topology, all_edges, edge_index, is_connected, r_asym, weight_matrix_from_weights
from .weights import metropolis_weights, polish_weights, polish_weights_batched

__all__ = ["BATopoConfig", "optimize_topology", "sweep_topologies",
           "extract_support", "repair_selection", "large_n_admm_config"]


def _pipeline_admm_default() -> ADMMConfig:
    """Pipeline-default ADMM stack (DESIGN.md §10/§13): the PR-2 measured-fast
    solver options (inexact CG tied to the primal residual, fp32 loop with
    fp64 residuals) plus a 600-iteration budget. The pipeline consumes only
    the solver's *support decision* — weights are re-derived by the convex
    polish, and the warm-start/classic candidates compete on equal footing —
    and that decision saturates long before the eps-residual does: measured
    drift vs the exact 1500-iteration solve is 0.0 on every paper scenario
    at n≤32 and ≤7e-4 at n=64/4 restarts (committed bench_pipeline rows).
    ``psd_backend``/``partition`` are the "auto" selectors: on a
    single-device CPU they resolve to the previous eigh/unsharded behavior;
    on multi-device or accelerator backends they engage the measured large-n
    stack (core.shard, engine.NS_MIN_N). Direct ``HomogeneousADMM``/
    ``HeterogeneousADMM`` use keeps the exact paper-faithful
    ``ADMMConfig()`` defaults."""
    return ADMMConfig(max_iters=600, cg_inexact=True, dtype="float32",
                      psd_backend="auto", partition="auto")


def large_n_admm_config(max_iters: int = 600) -> ADMMConfig:
    """The measured large-n solver stack (DESIGN.md §13), as an explicit
    factory for direct solver use and benchmarks: fp32 loop with fp64
    residuals, inexact CG tied to the primal residual, platform/size-resolved
    PSD backend (``engine.resolve_psd_backend``) and device layout
    (``shard.resolve_partition``). The spectral-evaluation side pairs with
    it automatically: ``Topology.r_asym`` routes through the Lanczos
    ``r_asym_fast`` above ``graph.FAST_SPECTRAL_MIN_N`` (= 192, measured in
    PR 3). This equals the pipeline default stack — named so callers and
    benches can request it without relying on the pipeline default staying
    identical."""
    return ADMMConfig(max_iters=max_iters, cg_inexact=True, dtype="float32",
                      psd_backend="auto", partition="auto")


@dataclass
class BATopoConfig:
    admm: ADMMConfig = field(default_factory=_pipeline_admm_default)
    sa_iters: int = 1500
    polish_iters: int = 500
    support_tol: float = 1e-6
    seed: int = 0
    restarts: int = 1
    # -- outer-pipeline performance stack (DESIGN.md §10) -------------------
    warmstart: str = "device"     # device (batched SA) | host (parity oracle)
    polish: str = "device"        # device (vmapped scan) | host
    polish_dtype: str = "float32"  # device polish loop dtype (f64 bookkeeping)
    sa_kernel: bool = False       # route matmul-BFS through the hop_bfs Pallas pair


def _validate_pipeline_cfg(cfg: BATopoConfig) -> None:
    """Reject typo'd backend selectors (a silently-ignored
    ``warmstart="Device"`` would benchmark the wrong pipeline)."""
    if cfg.warmstart not in ("device", "host"):
        raise ValueError(f"unknown warmstart {cfg.warmstart!r}; "
                         "expected 'device' or 'host'")
    if cfg.polish not in ("device", "host"):
        raise ValueError(f"unknown polish {cfg.polish!r}; "
                         "expected 'device' or 'host'")
    if cfg.polish_dtype not in ("float32", "float64"):
        raise ValueError(f"unknown polish_dtype {cfg.polish_dtype!r}; "
                         "expected 'float32' or 'float64'")


def extract_support(
    n: int, g: np.ndarray, r: int, tol: float, z: np.ndarray | None = None,
    edge_ok: np.ndarray | None = None,
) -> np.ndarray:
    """Boolean selection over the full candidate edge list: top-r weights
    (optionally gated by the binary z of the heterogeneous solver)."""
    m = len(g)
    score = np.asarray(g, dtype=np.float64).copy()
    if z is not None:
        score = score + 1e-3 * np.asarray(z)  # prefer z-selected edges on ties
    if edge_ok is not None:
        score[~edge_ok] = -np.inf
    score[score <= tol] = -np.inf
    k = min(r, int(np.isfinite(score).sum()))
    sel = np.zeros(m, dtype=bool)
    if k > 0:
        idx = np.argpartition(-score, k - 1)[:k]
        sel[idx] = True
    return sel


def repair_selection(n: int, sel: np.ndarray, g: np.ndarray, cs: ConstraintSet | None) -> np.ndarray:
    """Greedy feasibility + connectivity repair of a rounded edge selection.

    1. While a capacity row is violated (M z > e), drop the lowest-weight
       selected edge contributing to the most-violated row.
    2. While the graph is disconnected, add the highest-weight admissible
       edge joining two components that does not violate capacities.

    Capacity usage ``M @ sel`` is computed once per phase and updated
    incrementally as edges are dropped/added (it used to be recomputed per
    candidate edge, a quadratic hot spot on dense candidate sets).
    """
    edges_full = all_edges(n)
    sel = sel.copy()
    g = np.asarray(g, dtype=np.float64)
    usage = cs.M @ sel.astype(np.int64) if cs is not None else None

    if cs is not None:
        while True:
            over = usage - cs.e_cap
            if np.all(over <= 0):
                break
            row = int(np.argmax(over))
            members = [l for l in np.nonzero(sel)[0] if cs.M[row, l]]
            drop = min(members, key=lambda l: g[l])
            sel[drop] = False
            usage = usage - cs.M[:, drop]

    def comps(sel_mask):
        parent = list(range(n))

        def find(a):
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        for l in np.nonzero(sel_mask)[0]:
            i, j = edges_full[l]
            ri, rj = find(i), find(j)
            if ri != rj:
                parent[ri] = rj
        return [find(i) for i in range(n)]

    for _ in range(n):
        c = comps(sel)
        if len(set(c)) == 1:
            break
        cands = []
        for l, (i, j) in enumerate(edges_full):
            if sel[l] or c[i] == c[j]:
                continue
            if cs is not None:
                if not cs.edge_ok[l]:
                    continue
                if np.any(usage + cs.M[:, l] > cs.e_cap):
                    continue
            cands.append(l)
        if not cands:
            break  # cannot connect under capacities — caller handles r_asym=1
        best = max(cands, key=lambda l: g[l])
        sel[best] = True
        if cs is not None:
            usage = usage + cs.M[:, best]
    return sel


def _homo_degree_targets(n: int, r: int) -> np.ndarray:
    """Balanced degree sequence with Σd = 2r (homogeneous Algorithm-1 limit)."""
    base = (2 * r) // n
    extra = (2 * r) % n
    d = np.full(n, base, dtype=np.int64)
    d[:extra] += 1
    return np.minimum(d, n - 1)


def _finalize_batch(n: int, items: list[tuple[np.ndarray, str, dict]],
                    cfg: BATopoConfig, cs: ConstraintSet | None) -> list[Topology]:
    """Connectivity-check + weight-polish a batch of candidate selections.

    Every connected candidate of a solve (restarts × {admm, warm} ×
    classics) is polished in ONE vmapped, scan-compiled device call
    (``cfg.polish="host"`` keeps the serial host loop as parity oracle).
    """
    edges_full = all_edges(n)
    topos: list[Topology | None] = [None] * len(items)
    # identical supports (a warm-started ADMM frequently rounds back to
    # exactly its warm-start support; restarts can coincide too) polish to
    # identical weights — solve each distinct support once
    support_of: dict[bytes, list[int]] = {}
    for k, (sel, name, meta) in enumerate(items):
        edges = [edges_full[l] for l in np.nonzero(sel)[0]]
        if not edges or not is_connected(n, edges):
            g = metropolis_weights(n, edges) if edges else np.zeros(0)
            topos[k] = Topology(n, edges, g, name=name,
                                meta={**meta, "connected": False})
            continue
        support_of.setdefault(np.asarray(sel, dtype=bool).tobytes(),
                              []).append(k)
    if support_of:
        pending = []
        for ks in support_of.values():
            edges = [edges_full[l] for l in np.nonzero(items[ks[0]][0])[0]]
            pending.append((ks, edges, metropolis_weights(n, edges)))
        if cfg.polish == "device":
            gs = polish_weights_batched(
                n, [e for _, e, _ in pending], [g0 for _, _, g0 in pending],
                iters=cfg.polish_iters, dtype=cfg.polish_dtype)
        else:
            gs = [polish_weights(n, e, g0, iters=cfg.polish_iters)
                  for _, e, g0 in pending]
        for (ks, edges, _), g in zip(pending, gs):
            for k in ks:
                _, name, meta = items[k]
                topos[k] = Topology(n, edges, g, name=name,
                                    meta={**meta, "connected": True})
    return topos


def _candidate_items(n: int, r: int, warms, results, cs: ConstraintSet | None,
                     cfg: BATopoConfig, meta: dict, use_z: bool,
                     ) -> tuple[list[tuple[np.ndarray, str, dict]], list[str]]:
    """Phase 3 shared by ``optimize_topology`` / ``sweep_topologies`` /
    ``serve.topo_service``: round every ADMM result (top-r support + greedy
    feasibility repair), and enter the annealed warm starts and the feasible
    classic baselines as competing candidates. Returns the ``(sel, name,
    meta)`` items for ``_finalize_batch`` plus a parallel provenance list."""
    items: list[tuple[np.ndarray, str, dict]] = []
    sources: list[str] = []
    edge_ok = (np.asarray(cs.edge_ok)
               if (use_z and cs is not None) else None)
    for (g0, z0, lam0), res in zip(warms, results):
        score = res.g + res.g_raw
        if use_z:
            sel = extract_support(n, score, r, cfg.support_tol, z=res.z,
                                  edge_ok=edge_ok)
        else:
            sel = extract_support(n, score, r, cfg.support_tol)
        sel = repair_selection(n, sel, score, cs)
        items.append((sel, f"ba-topo(n={n},r={r})", {**meta,
                      "admm_iters": res.iters, "admm_residual": res.residual,
                      "lam_tilde": res.lam_tilde}))
        sources.append("admm")
        items.append((z0.astype(bool), f"ba-topo(n={n},r={r},warm)",
                      dict(meta)))
        sources.append("warm-start")
    for base_name, sel in _classic_candidates(n, r, cs):
        items.append((sel, f"ba-topo(n={n},r={r},{base_name})", dict(meta)))
        sources.append(f"classic:{base_name}")
    return items, sources


def _pick_best(n: int, items, topos, sources,
               ) -> tuple[Topology | None, float, list[str]]:
    """Phase 5 shared by ``optimize_topology`` / ``sweep_topologies`` /
    ``serve.topo_service``: release-validate each connected candidate
    against the ``core.guard`` invariant checklist (finite W, symmetry,
    row-stochasticity, connectivity) and pick the lowest r_asym among the
    survivors, one spectral/invariant evaluation per distinct support.
    Returns ``(best, best_val, failures)`` — ``failures`` names the
    invariant each flunked candidate violated, so callers can raise a
    structured error when nothing survives."""
    from .guard import check_invariants

    best: Topology | None = None
    best_val = np.inf
    val_cache: dict[bytes, float] = {}
    inv_cache: dict[bytes, str | None] = {}
    failures: list[str] = []
    for (sel, _, _), cand, src in zip(items, topos, sources):
        if not cand.meta.get("connected", False):
            continue
        key = np.asarray(sel, dtype=bool).tobytes()
        if key not in inv_cache:
            inv_cache[key] = check_invariants(cand)
        bad = inv_cache[key]
        if bad is not None:
            failures.append(f"{cand.name}: {bad}")
            continue
        if key not in val_cache:
            val_cache[key] = cand.r_asym()
        val = val_cache[key]
        if best is None or val < best_val:
            cand.meta["selected_from"] = src
            best, best_val = cand, val
    return best, best_val, failures


def _init_graph(n: int, r: int, scenario: str, cs: ConstraintSet | None,
                deg_targets, cfg: BATopoConfig, restart: int):
    """Greedy feasible start graph for one restart. Returns (edges0, seed)."""
    seed = cfg.seed + 1000 * restart
    rng = np.random.default_rng(seed)
    if deg_targets is not None:
        warm_cs = cs if scenario == "node" else None
        return greedy_degree_graph(n, deg_targets, rng, warm_cs), seed
    return _greedy_constraint_graph(n, r, cs, rng), seed


def _pack_warm(n: int, edges0: list[tuple[int, int]]):
    """Annealed edge list → (g0, z0, lam0) ADMM warm start."""
    eidx = edge_index(n)
    m = len(all_edges(n))
    z0 = np.zeros(m)
    for e in edges0:
        z0[eidx[e]] = 1.0
    g0 = np.zeros(m)
    gm = metropolis_weights(n, edges0)
    for k, e in enumerate(edges0):
        g0[eidx[e]] = gm[k]
    W0 = weight_matrix_from_weights(n, edges0, gm)
    lam0 = max(1.0 - r_asym(W0, symmetric=True), 0.05)
    return g0, z0, lam0


def _anneal_edges(n: int, inits: list[list[tuple[int, int]]], seeds: list[int],
                  sa_cs: ConstraintSet | None, cfg: BATopoConfig) -> list:
    """Anneal a batch of start graphs. ``cfg.warmstart="device"`` runs one
    vmapped, scan-compiled SA call per distinct edge count (a 2-swap
    preserves the count, so restarts — or sweep instances — with
    equal-size init graphs share a call and a compilation);
    ``"host"`` keeps the seed per-graph Python SA as the parity oracle."""
    if cfg.warmstart == "device":
        from .warmstart import anneal_topology_batched

        groups: dict[int, list[int]] = {}
        for k, e in enumerate(inits):
            groups.setdefault(len(e), []).append(k)
        annealed: list = [None] * len(inits)
        for idxs in groups.values():
            outs = anneal_topology_batched(
                n, [inits[i] for i in idxs], sa_cs, iters=cfg.sa_iters,
                seeds=[seeds[i] for i in idxs], use_kernel=cfg.sa_kernel)
            for i, out in zip(idxs, outs):
                annealed[i] = out
        return annealed
    return [anneal_topology(n, e0, sa_cs, iters=cfg.sa_iters, seed=sd)
            for e0, sd in zip(inits, seeds)]


def _warm_starts(n: int, r: int, scenario: str, cs: ConstraintSet | None,
                 deg_targets, cfg: BATopoConfig, n_restarts: int):
    """Warm starts for every restart: greedy init (host) + simulated
    annealing (batched on device by default). Returns (g0, z0, lam0)s."""
    inits, seeds = [], []
    for k in range(n_restarts):
        edges0, seed = _init_graph(n, r, scenario, cs, deg_targets, cfg, k)
        inits.append(edges0)
        seeds.append(seed)
    sa_cs = cs if scenario != "homo" else None
    annealed = _anneal_edges(n, inits, seeds, sa_cs, cfg)
    return [_pack_warm(n, e) for e in annealed]


def _make_solver(n: int, r: int, scenario: str, cs: ConstraintSet | None,
                 cfg: BATopoConfig):
    if scenario == "homo":
        return HomogeneousADMM(n, r, cfg.admm)
    return HeterogeneousADMM(
        n, r, np.asarray(cs.M, dtype=np.float64), np.asarray(cs.e_cap, dtype=np.float64),
        cfg.admm, equality=cs.equality, edge_ok=np.asarray(cs.edge_ok),
    )


def optimize_topology(
    n: int,
    r: int,
    scenario: str = "homo",
    cs: ConstraintSet | None = None,
    node_bandwidths: np.ndarray | None = None,
    cfg: BATopoConfig | None = None,
    profile: dict | None = None,
) -> Topology:
    """Deprecated signature-compatible wrapper around the unified request
    API (DESIGN.md §17): build a :class:`~repro.core.anytime.TopologyRequest`
    and call :func:`~repro.core.anytime.solve_topology` instead. Behavior
    (including the barrier execution order, profile keys and error
    messages) is unchanged.
    """
    warnings.warn(
        "optimize_topology(n, r, ...) is deprecated; build a "
        "TopologyRequest and call repro.core.anytime.solve_topology(...)",
        DeprecationWarning, stacklevel=2)
    return _optimize_request(n, r, scenario=scenario, cs=cs,
                             node_bandwidths=node_bandwidths, cfg=cfg,
                             profile=profile)


def _optimize_request(
    n: int,
    r: int,
    scenario: str = "homo",
    cs: ConstraintSet | None = None,
    node_bandwidths: np.ndarray | None = None,
    cfg: BATopoConfig | None = None,
    profile: dict | None = None,
) -> Topology:
    """Produce a BA-Topo for the given scenario — the phase-barriered
    pipeline (``solve_topology(engine="barrier")`` and the unbudgeted
    anytime parity oracle).

    scenario ∈ {"homo", "node", "constraint"}:
      - "homo": Eq. (9) with Card(g) ≤ r.
      - "node": §IV-B1 — requires ``node_bandwidths``; Algorithm 1 allocates
        per-node capacities, then the heterogeneous ADMM runs with equality
        degree rows.
      - "constraint": any ConstraintSet (intra-server, BCube, pod-boundary)
        with inequality capacities.

    With ``cfg.restarts > 1`` and a JAX backend, all restarts are solved by
    one batched device call; the best candidate (lowest ``r_asym`` after
    repair + polish) wins. Pass ``profile={}`` to collect the per-phase
    wall-time breakdown (keys ``warm_s/admm_s/round_s/polish_s/eval_s``).
    """
    from .anytime import resolve_scenario

    cfg = cfg or BATopoConfig()
    _validate_pipeline_cfg(cfg)
    prof = {} if profile is None else profile
    cs, deg_targets, meta = resolve_scenario(n, r, scenario, cs,
                                             node_bandwidths, context="api")

    # ---- phase 1: warm starts (device SA by default) ----------------------
    t0 = time.perf_counter()
    n_restarts = max(1, cfg.restarts)
    warms = _warm_starts(n, r, scenario, cs, deg_targets, cfg, n_restarts)
    prof["warm_s"] = prof.get("warm_s", 0.0) + time.perf_counter() - t0

    solver = _make_solver(n, r, scenario, cs, cfg)

    # ---- phase 2: ADMM — batched restarts in one device call (scan driver
    # only; an explicit driver="python" request keeps the per-restart loop)
    t0 = time.perf_counter()
    if (n_restarts > 1 and cfg.admm.solver != "kkt_bicgstab_ilu"
            and cfg.admm.driver == "scan"):
        g0s = np.stack([w[0] for w in warms])
        lam0s = np.asarray([w[2] for w in warms])
        if scenario == "homo":
            results = solver.solve_batched(g0s, lam0s)
        else:
            results = solver.solve_batched(g0s, np.stack([w[1] for w in warms]), lam0s)
    elif scenario == "homo":
        results = [solver.solve(g0=g0, lam0=lam0) for g0, _, lam0 in warms]
    else:
        results = [solver.solve(g0=g0, z0=z0, lam0=lam0) for g0, z0, lam0 in warms]
    prof["admm_s"] = prof.get("admm_s", 0.0) + time.perf_counter() - t0

    # ---- phase 3: rounding + greedy feasibility repair --------------------
    t0 = time.perf_counter()
    items, sources = _candidate_items(n, r, warms, results, cs, cfg, meta,
                                      use_z=(scenario != "homo"))
    prof["round_s"] = prof.get("round_s", 0.0) + time.perf_counter() - t0

    # ---- phase 4: weight polish, all candidates in one batched call -------
    t0 = time.perf_counter()
    topos = _finalize_batch(n, items, cfg, cs)
    prof["polish_s"] = prof.get("polish_s", 0.0) + time.perf_counter() - t0

    # ---- phase 5: release validation + spectral evaluation (one invariant
    # check and one r_asym per distinct support) ----------------------------
    t0 = time.perf_counter()
    best_topo, best_val, failures = _pick_best(n, items, topos, sources)
    if best_topo is None:
        if failures:
            from .guard import TopologyInvariantError

            bad = failures[0].rsplit(": ", 1)[-1]
            raise TopologyInvariantError(
                f"no candidate topology for n={n}, r={r}, "
                f"scenario={scenario!r} passed release validation — first "
                f"failure: {failures[0]!r} (all: {failures})",
                invariant=bad, failures=failures)
        raise ValueError(
            f"failed to construct any connected topology for n={n}, r={r}, "
            f"scenario={scenario!r} — every candidate (ADMM, warm starts, "
            "classics) was disconnected under the constraints; raise r or "
            "relax the ConstraintSet")
    best_topo.meta["r_asym"] = best_val
    prof["eval_s"] = prof.get("eval_s", 0.0) + time.perf_counter() - t0
    return best_topo


def _classic_candidates(n: int, r: int,
                        cs: ConstraintSet | None) -> list[tuple[str, np.ndarray]]:
    """Classic-topology candidates: the ADMM is non-convex, and on small
    tightly-budgeted instances a known-good structure (ring / torus) that
    happens to be feasible can beat a weak local optimum. Their weights get
    the same convex polish as the ADMM output so the comparison is fair.

    Returns (name, selection) pairs for the feasible classics. Only
    ``ValueError`` — the documented "n not expressible for this family"
    signal (e.g. hypercube needs a power of two) — skips a baseline; any
    other exception is a real construction bug and propagates.
    """
    from .topologies import make_baseline
    eidx = edge_index(n)
    out: list[tuple[str, np.ndarray]] = []
    for kind in ("ring", "torus", "hypercube"):
        try:
            base = make_baseline(kind, n)
        except ValueError:
            continue
        if len(base.edges) > r or base.meta.get("directed"):
            continue
        sel = np.zeros(len(all_edges(n)), dtype=bool)
        for e in base.edges:
            sel[eidx[tuple(sorted(e))]] = True
        if cs is not None and not cs.feasible(sel):
            continue
        out.append((base.name, sel))
    return out


def sweep_topologies(
    ns, rs, cfg: BATopoConfig | None = None,
) -> dict:
    """Deprecated signature-compatible wrapper: build
    :class:`~repro.core.anytime.TopologyRequest` objects and call
    :func:`~repro.core.anytime.solve_topologies` instead (same vmapped
    per-n sweep engine underneath). Returns ``{(n, r): Topology}`` exactly
    as before."""
    warnings.warn(
        "sweep_topologies(ns, rs, ...) is deprecated; build TopologyRequest "
        "objects and call repro.core.anytime.solve_topologies(...)",
        DeprecationWarning, stacklevel=2)
    return _sweep_requests(ns, rs, cfg)


def _sweep_requests(ns, rs, cfg: BATopoConfig | None = None) -> dict:
    """Homogeneous multi-scenario sweep: a BA-Topo for every (n, r) pair.

    For each node count n, the whole cardinality sweep ``rs`` runs as ONE
    vmapped, scan-compiled ADMM call (engine ``solve_sweep_spec`` — the
    budget r is a data leaf of the ProblemSpec, so instances with different
    budgets share a compilation). Warm starts and post-processing (support
    extraction, repair, polish, warm-start and classic-baseline comparison)
    stay per-instance on host. Returns ``{(n, r): Topology}``, keyed by the
    *requested* r (budgets above the candidate-edge count are clamped for
    the solve); a value is ``None`` if no connected candidate was found.
    Unlike the one-shot pipeline, the sweep uses one warm start per (n, r)
    — ``cfg.restarts`` is not consulted — and, like ``solve_batched``, it
    always runs the vmapped scan driver: a ``driver="python"`` preference
    applies only to the one-shot solve.
    """
    cfg = cfg or BATopoConfig()
    if cfg.admm.driver not in ("scan", "python"):
        raise ValueError(
            f"unknown driver {cfg.admm.driver!r}; expected 'scan' or 'python'")
    if cfg.admm.solver == "kkt_bicgstab_ilu":
        raise ValueError(
            "sweep_topologies needs a device backend (schur_cg or "
            "kkt_bicgstab); the scipy-ILU backend is host-side")
    _validate_pipeline_cfg(cfg)
    out: dict = {}
    for n in ns:
        out.update(_sweep_one_n(int(n), [int(r) for r in rs], cfg))
    return out


def _sweep_one_n(n: int, rs_req: list[int], cfg: BATopoConfig) -> dict:
    """One node count of the sweep: all budgets in ``rs_req`` solved as one
    vmapped dispatch. Shared by ``_sweep_requests`` and
    ``anytime.solve_topologies``."""
    import jax
    import jax.numpy as jnp

    from .engine import init_state, make_homo_spec, solve_sweep_spec

    out: dict = {}
    m = len(all_edges(n))
    rs_n = [min(r, m) for r in rs_req]  # solve with the clamped budget
    spec = make_homo_spec(n, max(rs_n), cfg.admm)
    # one warm start per (n, r); sweep instance k plays the role of
    # restart k, and the device SA batches instances whose warm graphs
    # share an edge count into one vmapped call
    inits, seeds = [], []
    for k, r in enumerate(rs_n):
        deg_targets = _homo_degree_targets(n, r)
        edges0, seed = _init_graph(n, r, "homo", None, deg_targets, cfg, k)
        inits.append(edges0)
        seeds.append(seed)
    warms = [_pack_warm(n, e)
             for e in _anneal_edges(n, inits, seeds, None, cfg)]
    states = [init_state(spec, jnp.asarray(g0), lam0) for g0, _, lam0 in warms]
    batched = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    from .shard import (
        resolve_partition, solve_spec_sharded, solve_sweep_spec_sharded)

    part = resolve_partition(cfg.admm.partition, n, batch=len(rs_n))
    if part == "instances":
        results = solve_sweep_spec_sharded(
            spec, np.asarray(rs_n), batched, cfg.admm)
    elif part == "edges":
        results = [solve_spec_sharded(
            spec.replace(r=jnp.asarray(rn, dtype=jnp.int64)),
            jax.tree.map(lambda a, k=k: a[k], batched), cfg.admm,
            r_cap=max(rs_n)) for k, rn in enumerate(rs_n)]
    else:
        results = solve_sweep_spec(spec, np.asarray(rs_n), batched, cfg.admm)
    for (r_req, r, warm, res) in zip(rs_req, rs_n, warms, results):
        meta = {"scenario": "homo", "r": r}
        items, sources = _candidate_items(n, r, [warm], [res], None, cfg,
                                          meta, use_z=False)
        topos = _finalize_batch(n, items, cfg, None)
        best, best_val, failures = _pick_best(n, items, topos, sources)
        if best is None and failures:
            from .guard import TopologyInvariantError

            bad = failures[0].rsplit(": ", 1)[-1]
            raise TopologyInvariantError(
                f"no candidate topology for n={n}, r={r} passed release "
                f"validation — first failure: {failures[0]!r} "
                f"(all: {failures})", invariant=bad, failures=failures)
        if best is not None:
            best.meta["r_asym"] = best_val
        out[(n, r_req)] = best  # keyed by the *requested* budget
    return out


def _greedy_constraint_graph(n: int, r: int, cs: ConstraintSet, rng) -> list[tuple[int, int]]:
    """Random feasible connected graph with ≤ r edges under ``cs`` capacities."""
    edges_full = all_edges(n)
    m = len(edges_full)
    order = [l for l in range(m) if cs.edge_ok[l]]
    for _ in range(256):
        rng.shuffle(order)
        usage = np.zeros(cs.q, dtype=np.int64)
        sel = np.zeros(m, dtype=bool)
        count = 0
        # first pass: spanning-tree bias for connectivity
        comp = list(range(n))

        def find(a):
            while comp[a] != a:
                comp[a] = comp[comp[a]]
                a = comp[a]
            return a

        for phase in (0, 1):
            for l in order:
                if count >= r:
                    break
                if sel[l]:
                    continue
                i, j = edges_full[l]
                if phase == 0 and find(i) == find(j):
                    continue
                col = cs.M[:, l]
                if np.any(usage + col > cs.e_cap):
                    continue
                sel[l] = True
                usage += col
                count += 1
                comp[find(i)] = find(j)
        edges = [edges_full[l] for l in np.nonzero(sel)[0]]
        if is_connected(n, edges):
            return edges
    raise RuntimeError("could not build a feasible connected warm start")
