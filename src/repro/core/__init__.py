"""BA-Topo core: the paper's contribution as a composable library."""
from .admm import ADMMConfig, ADMMResult, HeterogeneousADMM, HomogeneousADMM
from .allocation import AllocationResult, allocate_edge_capacity
from .anytime import (
    AnytimeSolver,
    PhaseProfile,
    TopologyRequest,
    TopologyResult,
    solve_topologies,
    solve_topology,
)
from .api import BATopoConfig, large_n_admm_config, optimize_topology, sweep_topologies
from .engine import ADMMState, ProblemSpec, resolve_psd_backend
from .shard import resolve_partition
from .bandwidth import PaperConstants, homo_edge_bandwidth, min_edge_bandwidth, node_hetero_edge_bandwidth, t_epoch, t_iter
from .constraints import ConstraintSet, bcube_constraints, intra_server_constraints, node_level_constraints, pod_boundary_constraints
from .graph import Topology, all_edges, aspl, incidence_matrix, is_connected, laplacian_from_weights, r_asym, r_asym_fast, weight_matrix_from_weights
from .guard import GuardPolicy, LadderResult, SolveFailure, SolveOutcome, TopologyInvariantError, check_invariants, classic_fallback, classify_result, run_ladder, validate_topology
from .reopt import DriftDetector, DriftPolicy, ReoptResult, first_drift, reoptimize_topology
from .topologies import BASELINES, exponential, grid2d, hypercube, make_baseline, random_graph, ring, torus2d, u_equistatic
from .warmstart import anneal_topology_batched, aspl_matmul
from .weights import best_constant_weights, metropolis_weights, polish_weights, polish_weights_batched

__all__ = [
    "ADMMConfig", "ADMMResult", "HeterogeneousADMM", "HomogeneousADMM",
    "ADMMState", "ProblemSpec",
    "AllocationResult", "allocate_edge_capacity",
    "AnytimeSolver", "PhaseProfile", "TopologyRequest", "TopologyResult",
    "solve_topology", "solve_topologies",
    "BATopoConfig", "large_n_admm_config", "optimize_topology",
    "sweep_topologies", "resolve_psd_backend", "resolve_partition",
    "PaperConstants", "homo_edge_bandwidth", "min_edge_bandwidth",
    "node_hetero_edge_bandwidth", "t_epoch", "t_iter",
    "ConstraintSet", "bcube_constraints", "intra_server_constraints",
    "node_level_constraints", "pod_boundary_constraints",
    "Topology", "all_edges", "aspl", "incidence_matrix", "is_connected",
    "laplacian_from_weights", "r_asym", "r_asym_fast",
    "weight_matrix_from_weights",
    "GuardPolicy", "LadderResult", "SolveFailure", "SolveOutcome",
    "TopologyInvariantError", "check_invariants", "classic_fallback",
    "classify_result", "run_ladder", "validate_topology",
    "DriftPolicy", "DriftDetector", "ReoptResult", "first_drift",
    "reoptimize_topology",
    "BASELINES", "exponential", "grid2d", "hypercube", "make_baseline",
    "random_graph", "ring", "torus2d", "u_equistatic",
    "anneal_topology_batched", "aspl_matmul",
    "best_constant_weights", "metropolis_weights", "polish_weights",
    "polish_weights_batched",
]
