"""Solver guard layer: outcome classification, topology invariants and the
shared retry/fallback ladder (DESIGN.md §15).

The paper's MISDP pipeline (ADMM + rounding, §IV) is non-convex and can fail
in exactly four ways, and every consumer — ``optimize_topology``'s release
validation, ``core.reopt``'s online re-solve, the request-level
``serve.topo_service`` — needs the same classification and the same recovery
policy. This module is that one code path:

  * :class:`SolveOutcome` — {converged, non_convergent, non_finite,
    disconnected_rounding}: the structured verdict on one ADMM attempt.
    ``non_finite`` pairs with the engine's on-device early-abort
    (``ADMMConfig.abort_nonfinite``): a NaN/Inf squared primal residual
    marks the chunked scan done so the remaining iteration budget is not
    burned on poisoned state; the surviving non-finite residual is what
    :func:`classify_result` keys on.
  * :func:`check_invariants` — the release checklist every topology handed
    to a caller must pass: finite W, symmetry, row-stochasticity,
    connectivity. :class:`TopologyInvariantError` names the failed
    invariant when no candidate survives.
  * :func:`run_ladder` — the generalized retry ladder. Rungs are (name,
    thunk) pairs tried in order; a rung may return a Topology (validated
    here), return None, or raise — :class:`SolveFailure` carries a
    classified outcome, anything else is recorded as an error. The ladder
    never re-raises: the result reports what happened at every rung.
    ``core.reopt`` runs [warm → cold] with keep-incumbent as its caller's
    fallback; the topology service runs [warm ± ρ-jittered retries → cold →
    sa_only → classic].
  * :func:`attempt_admm` / :func:`jittered_warm_rungs` — one classified,
    rounded ADMM attempt from a warm start, and the reseeded ρ-jitter retry
    rungs built from it.
  * :func:`classic_fallback` — the closed-form last resort (ring / torus /
    hypercube via ``api._classic_candidates``, else an unconditional ring):
    Song et al. / Takezawa et al. (PAPERS.md) show such topologies are
    strong fallbacks, and a valid-but-suboptimal graph beats an exception.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .constraints import ConstraintSet
from .graph import Topology, all_edges, is_connected

__all__ = [
    "SolveOutcome", "GuardPolicy", "SolveFailure", "TopologyInvariantError",
    "RungReport", "LadderResult", "run_ladder", "check_invariants",
    "validate_topology", "classify_result", "round_result", "attempt_admm",
    "jittered_warm_rungs", "classic_fallback",
]


class SolveOutcome(str, enum.Enum):
    """Structured verdict on one ADMM solve + rounding attempt."""

    CONVERGED = "converged"
    NON_CONVERGENT = "non_convergent"
    NON_FINITE = "non_finite"
    DISCONNECTED_ROUNDING = "disconnected_rounding"


@dataclass(frozen=True)
class GuardPolicy:
    """Knobs of the retry ladder.

    ``max_residual``: an ADMM attempt whose final summed-squared primal
    residual exceeds this is ``non_convergent`` (same meaning as
    ``reopt.DriftPolicy.max_residual``).
    ``warm_retries``: reseeded warm-start retries with jittered ρ after the
    first warm attempt fails (0 = straight to the next rung).
    ``rho_jitter``: multiplicative jitter span — retry k uses
    ρ·(1 + rho_jitter)^±k alternating up/down, a cheap deterministic sweep
    around the tuned penalty (a bad ρ is the common non-convergence cause).
    """

    max_residual: float = 1.0
    warm_retries: int = 1
    rho_jitter: float = 0.5


class SolveFailure(RuntimeError):
    """A classified solver failure — raised by rung thunks so the ladder
    records *why* (outcome) rather than just *that* the rung failed."""

    def __init__(self, outcome: SolveOutcome, detail: str = ""):
        super().__init__(f"{outcome.value}" + (f": {detail}" if detail else ""))
        self.outcome = outcome
        self.detail = detail


class TopologyInvariantError(ValueError):
    """No candidate topology passed the release checklist; ``invariant``
    names the (last) failed check, ``failures`` the full per-candidate
    breakdown."""

    def __init__(self, message: str, invariant: str,
                 failures: list[str] | None = None):
        super().__init__(message)
        self.invariant = invariant
        self.failures = failures or []


# =========================================================================
# Release invariants (the checklist every served topology must pass)
# =========================================================================

def check_invariants(topo: Topology, atol: float = 1e-8) -> str | None:
    """First violated release invariant of ``topo``, or None if all hold.

    Checks, in order: ``finite`` (every W entry), ``symmetric`` (W = Wᵀ —
    skipped for directed ``W_override`` baselines), ``row_stochastic``
    (W·1 = 1), ``connected`` (the selected edge set spans all n nodes).
    The order is the debugging order: a NaN W fails ``finite`` rather than
    cascading into meaningless symmetry/stochasticity failures.
    """
    W = np.asarray(topo.W)
    n = topo.n
    if W.shape != (n, n):
        return "shape"
    if not np.all(np.isfinite(W)):
        return "finite"
    directed = bool(topo.meta.get("directed")) or "W_override" in topo.meta
    if not directed and not np.allclose(W, W.T, atol=atol):
        return "symmetric"
    if not np.allclose(W.sum(axis=1), 1.0, atol=max(atol, 1e-6)):
        return "row_stochastic"
    if not directed and not is_connected(n, topo.edges):
        return "connected"
    return None


def validate_topology(topo: Topology, context: str = "",
                      atol: float = 1e-8) -> Topology:
    """Raise :class:`TopologyInvariantError` naming the failed invariant,
    else return ``topo`` unchanged (release-validation entry point)."""
    bad = check_invariants(topo, atol=atol)
    if bad is not None:
        raise TopologyInvariantError(
            f"topology {topo.name!r} violates the {bad!r} invariant"
            + (f" ({context})" if context else ""),
            invariant=bad, failures=[f"{topo.name}: {bad}"])
    return topo


# =========================================================================
# Outcome classification + rounding
# =========================================================================

def classify_result(res, max_residual: float = 1.0) -> SolveOutcome:
    """Classify a raw :class:`~repro.core.engine.ADMMResult` (pre-rounding).

    ``non_finite`` — the residual or any returned iterate entry is NaN/Inf
    (the engine's early-abort leaves the poisoned residual in place exactly
    so this check sees it); ``non_convergent`` — finite but above
    ``max_residual``; else ``converged``. ``disconnected_rounding`` is
    assigned later, by :func:`round_result` callers, because it is a
    property of the rounded support, not of the solve.
    """
    vals = [np.asarray(res.residual), np.asarray(res.g), np.asarray(res.g_raw)]
    if res.z is not None:
        vals.append(np.asarray(res.z))
    if not all(np.all(np.isfinite(v)) for v in vals):
        return SolveOutcome.NON_FINITE
    if float(res.residual) > max_residual:
        return SolveOutcome.NON_CONVERGENT
    return SolveOutcome.CONVERGED


def round_result(n: int, r: int, res, cs: ConstraintSet | None, cfg,
                 name: str) -> Topology | None:
    """ADMM result → rounded, repaired, polished Topology (None if the
    repaired support is disconnected — the ``disconnected_rounding``
    signal). Shared by reopt and the service; the cold pipeline inlines the
    same sequence in its batched form (``api._finalize_batch``)."""
    from .api import extract_support, repair_selection
    from .weights import metropolis_weights, polish_weights

    score = res.g + res.g_raw
    edge_ok = np.asarray(cs.edge_ok) if cs is not None else None
    sel = extract_support(n, score, r, cfg.support_tol, z=res.z,
                          edge_ok=edge_ok)
    sel = repair_selection(n, sel, score, cs)
    edges_full = all_edges(n)
    edges = [edges_full[ln] for ln in np.nonzero(sel)[0]]
    if not edges or not is_connected(n, edges):
        return None
    g = polish_weights(n, edges, metropolis_weights(n, edges),
                       iters=cfg.polish_iters)
    return Topology(n, edges, g, name=name,
                    meta={"connected": True, "admm_iters": res.iters,
                          "admm_residual": res.residual})


def attempt_admm(n: int, r: int, scenario: str, cs: ConstraintSet | None,
                 cfg, warm: tuple, name: str,
                 policy: GuardPolicy | None = None,
                 rho_scale: float = 1.0) -> Topology:
    """One guarded ADMM attempt: solve from the warm start, classify, round.

    Returns the rounded topology on success; raises :class:`SolveFailure`
    with the classified outcome otherwise. ``rho_scale`` multiplies the
    configured penalty (the ρ-jitter retry hook); ``warm`` is the
    ``(g0, z0, lam0)`` triple of ``api._pack_warm``.
    """
    import dataclasses

    from .api import _make_solver

    policy = policy or GuardPolicy()
    g0, z0, lam0 = warm
    if rho_scale != 1.0:
        cfg = dataclasses.replace(
            cfg, admm=dataclasses.replace(cfg.admm,
                                          rho=cfg.admm.rho * rho_scale))
    solver = _make_solver(n, r, scenario, cs, cfg)
    if scenario == "homo":
        res = solver.solve(g0=g0, lam0=lam0)
    else:
        res = solver.solve(g0=g0, z0=z0, lam0=lam0)
    outcome = classify_result(res, policy.max_residual)
    if outcome is not SolveOutcome.CONVERGED:
        raise SolveFailure(outcome, f"residual={res.residual:.3g}")
    topo = round_result(n, r, res, cs, cfg, name)
    if topo is None:
        raise SolveFailure(SolveOutcome.DISCONNECTED_ROUNDING,
                           "rounded+repaired support is disconnected")
    return topo


def jittered_warm_rungs(n: int, r: int, scenario: str,
                        cs: ConstraintSet | None, cfg, warm: tuple,
                        name: str, policy: GuardPolicy) -> list[tuple]:
    """The warm rung plus ``policy.warm_retries`` reseeded ρ-jittered
    retries, as (rung_name, thunk) pairs for :func:`run_ladder`. Retry k
    alternates the penalty up/down by (1 + rho_jitter)^⌈k/2⌉."""
    rungs = [("warm", lambda: attempt_admm(n, r, scenario, cs, cfg, warm,
                                           name, policy))]
    for k in range(1, policy.warm_retries + 1):
        scale = (1.0 + policy.rho_jitter) ** (-(k + 1) // 2 if k % 2 else
                                              (k + 1) // 2)
        rungs.append((
            f"warm-retry{k}(rho×{scale:.3g})",
            lambda s=scale: attempt_admm(n, r, scenario, cs, cfg, warm,
                                         name, policy, rho_scale=s)))
    return rungs


# =========================================================================
# The ladder
# =========================================================================

@dataclass
class RungReport:
    """What one rung did: ``outcome`` is "ok", a SolveOutcome value, an
    ``invalid:<invariant>`` release-check failure, or ``error:<Type>``."""

    rung: str
    outcome: str
    detail: str = ""


@dataclass
class LadderResult:
    topology: Topology | None
    rung: str | None                       # winning rung name (None = all failed)
    attempts: int                          # rungs actually attempted
    reports: list[RungReport] = field(default_factory=list)

    @property
    def reason(self) -> str:
        """Human-readable trail of every non-ok rung (the structured
        ``fallback_reason`` / degradation reason consumers report)."""
        return "; ".join(f"{r.rung}: {r.outcome}"
                         + (f" ({r.detail})" if r.detail else "")
                         for r in self.reports if r.outcome != "ok")


def run_ladder(rungs: list[tuple[str, Callable[[], Topology | None]]],
               validate: bool = True, atol: float = 1e-8) -> LadderResult:
    """Try ``rungs`` in order until one returns a topology that passes the
    release checklist. Never raises: classified failures
    (:class:`SolveFailure`), None returns, unexpected exceptions and
    invariant violations are all recorded in ``reports`` and the ladder
    moves on. ``LadderResult.topology`` is None iff every rung failed —
    the caller decides the terminal fallback (keep the incumbent, reject
    the request, …)."""
    reports: list[RungReport] = []
    for k, (name, thunk) in enumerate(rungs):
        try:
            topo = thunk()
        except SolveFailure as sf:
            reports.append(RungReport(name, sf.outcome.value, sf.detail))
            continue
        except Exception as exc:  # noqa: BLE001 — any rung failure → next rung
            reports.append(RungReport(name, f"error:{type(exc).__name__}",
                                      str(exc)))
            continue
        if topo is None:
            reports.append(RungReport(name, "none", "rung produced no topology"))
            continue
        if validate:
            bad = check_invariants(topo, atol=atol)
            if bad is not None:
                reports.append(RungReport(name, f"invalid:{bad}"))
                continue
        reports.append(RungReport(name, "ok"))
        return LadderResult(topology=topo, rung=name, attempts=k + 1,
                            reports=reports)
    return LadderResult(topology=None, rung=None, attempts=len(rungs),
                        reports=reports)


# =========================================================================
# Classic-topology fallback (the ladder's closed-form last rung)
# =========================================================================

def classic_fallback(n: int, r: int, cs: ConstraintSet | None = None,
                     polish_iters: int = 0) -> Topology:
    """Best feasible classic topology (ring / torus / hypercube), or an
    unconditional ring when none fits the budget/constraints.

    The feasible classics come from ``api._classic_candidates`` (same
    candidates the cold pipeline competes against) with Metropolis weights
    (optionally polished); ties break on r_asym. The terminal ring ignores
    ``r``/``cs`` — a valid connected topology that overshoots the budget
    beats no topology at all — and records that in ``meta["violates"]``.
    """
    from .api import _classic_candidates
    from .topologies import make_baseline
    from .weights import metropolis_weights, polish_weights

    edges_full = all_edges(n)
    best: Topology | None = None
    best_val = np.inf
    for base_name, sel in _classic_candidates(n, r, cs):
        edges = [edges_full[ln] for ln in np.nonzero(sel)[0]]
        g = metropolis_weights(n, edges)
        if polish_iters > 0:
            g = polish_weights(n, edges, g, iters=polish_iters)
        cand = Topology(n, edges, g, name=f"classic-{base_name}(n={n})",
                        meta={"connected": True, "classic": base_name})
        val = cand.r_asym()
        if val < best_val:
            best, best_val = cand, val
    if best is not None:
        best.meta["r_asym"] = best_val
        return best
    ring = make_baseline("ring", n)
    topo = Topology(n, ring.edges, metropolis_weights(n, ring.edges),
                    name=f"classic-ring(n={n})",
                    meta={"connected": True, "classic": "ring"})
    violates = []
    if len(ring.edges) > r:
        violates.append(f"edge budget r={r}")
    if cs is not None:
        sel = np.zeros(len(edges_full), dtype=bool)
        from .graph import edge_index
        eidx = edge_index(n)
        for e in ring.edges:
            sel[eidx[tuple(sorted(e))]] = True
        if not cs.feasible(sel):
            violates.append("constraint set")
    if violates:
        topo.meta["violates"] = ", ".join(violates)
    topo.meta["r_asym"] = topo.r_asym()
    return topo
