"""Bandwidth → wall-clock models of §VI (Eqs. 34–35).

The paper measures, on its 8×2080Ti testbed:
  - b_avail = 9.76 GB/s  (max per-node bandwidth, PCIe measurement [42, 43]),
  - t_comm  = 5.01 ms    (ResNet-18 parameter exchange at 9.76 GB/s),
  - t_comp  = 15.21 ms   (ResNet-18 iteration compute on one 2080Ti),
then scales per-iteration time by the *minimum* per-edge bandwidth:
  t_iter  = b_avail / b_min × t_comm                      (Eq. 34)
  t_epoch = (b_avail / b_min × t_comm + t_comp) × c_iter  (Eq. 35)
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .graph import Topology, degrees

__all__ = ["PaperConstants", "homo_edge_bandwidth", "node_hetero_edge_bandwidth",
           "min_edge_bandwidth", "t_iter", "t_epoch"]


@dataclass(frozen=True)
class PaperConstants:
    b_avail: float = 9.76  # GB/s
    t_comm_ms: float = 5.01
    t_comp_ms: float = 15.21


def homo_edge_bandwidth(topo: Topology, b: float = 9.76) -> np.ndarray:
    """§VI-A1: bandwidth of edge {i,j} = min(b/d_i, b/d_j).

    For the directed exponential graph the paper uses out-degree; we honor
    ``meta['out_degree']`` when present.
    """
    n = topo.n
    if topo.meta.get("directed"):
        d = np.full(n, topo.meta["out_degree"], dtype=np.float64)
    else:
        d = degrees(n, topo.edges).astype(np.float64)
    d = np.maximum(d, 1.0)
    return np.array([min(b / d[i], b / d[j]) for i, j in topo.edges])


def node_hetero_edge_bandwidth(topo: Topology, b_nodes: np.ndarray) -> np.ndarray:
    """§VI-A2: bandwidth of edge {i,j} = min(b_i/d_i, b_j/d_j)."""
    n = topo.n
    if topo.meta.get("directed"):
        d = np.full(n, topo.meta["out_degree"], dtype=np.float64)
    else:
        d = degrees(n, topo.edges).astype(np.float64)
    d = np.maximum(d, 1.0)
    b = np.asarray(b_nodes, dtype=np.float64)
    return np.array([min(b[i] / d[i], b[j] / d[j]) for i, j in topo.edges])


def min_edge_bandwidth(edge_bw: np.ndarray) -> float:
    finite = edge_bw[np.isfinite(edge_bw)]
    return float(finite.min()) if finite.size else float("inf")


def t_iter(b_min: float, const: PaperConstants = PaperConstants()) -> float:
    """Eq. (34), in milliseconds."""
    return const.b_avail / b_min * const.t_comm_ms


def t_epoch(b_min: float, c_iter: int, const: PaperConstants = PaperConstants()) -> float:
    """Eq. (35), in milliseconds."""
    return (const.b_avail / b_min * const.t_comm_ms + const.t_comp_ms) * c_iter
