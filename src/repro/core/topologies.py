"""Benchmark topologies from the paper's experiment section (§VI).

ring, 2D grid, 2D torus [17], hypercube [18], (static) exponential [16],
U-EquiStatic (EquiTopo) [19], and uniform-random graphs [20, 21].

Weight assignment for the undirected baselines follows the degree-based
convention the paper attributes to [17]: we use Metropolis–Hastings weights
(symmetric, doubly stochastic, nonnegative) unless a topology defines its own
canonical weights (exponential, hypercube, EquiTopo use uniform 1/(d+1)).
"""
from __future__ import annotations

import math

import numpy as np

from .graph import Topology, all_edges, r_asym
from .weights import metropolis_weights, uniform_neighbor_weights

__all__ = [
    "ring",
    "grid2d",
    "torus2d",
    "hypercube",
    "exponential",
    "u_equistatic",
    "random_graph",
    "BASELINES",
    "make_baseline",
]


def ring(n: int) -> Topology:
    edges = [(i, (i + 1) % n) for i in range(n)]
    edges = [(min(a, b), max(a, b)) for a, b in edges]
    edges = sorted(set(edges))
    g = metropolis_weights(n, edges)
    return Topology(n, edges, g, name=f"ring(n={n})")


def _grid_edges(rows: int, cols: int, wrap: bool) -> list[tuple[int, int]]:
    def nid(r, c):
        return r * cols + c

    edges = set()
    for r in range(rows):
        for c in range(cols):
            if c + 1 < cols:
                edges.add((nid(r, c), nid(r, c + 1)))
            elif wrap and cols > 2:
                edges.add(tuple(sorted((nid(r, c), nid(r, 0)))))
            if r + 1 < rows:
                edges.add((nid(r, c), nid(r + 1, c)))
            elif wrap and rows > 2:
                edges.add(tuple(sorted((nid(r, c), nid(0, c)))))
    return sorted(edges)


def _factor_near_square(n: int) -> tuple[int, int]:
    r = int(math.isqrt(n))
    while n % r:
        r -= 1
    return r, n // r


def grid2d(n: int) -> Topology:
    rows, cols = _factor_near_square(n)
    edges = _grid_edges(rows, cols, wrap=False)
    g = metropolis_weights(n, edges)
    return Topology(n, edges, g, name=f"2d-grid(n={n},{rows}x{cols})")


def torus2d(n: int) -> Topology:
    rows, cols = _factor_near_square(n)
    edges = _grid_edges(rows, cols, wrap=True)
    g = metropolis_weights(n, edges)
    return Topology(n, edges, g, name=f"2d-torus(n={n},{rows}x{cols})")


def hypercube(n: int) -> Topology:
    k = int(round(math.log2(n)))
    if 2**k != n:
        raise ValueError(f"hypercube requires n to be a power of 2, got {n}")
    edges = sorted({(min(i, i ^ (1 << b)), max(i, i ^ (1 << b))) for i in range(n) for b in range(k)})
    g = uniform_neighbor_weights(n, edges)
    return Topology(n, edges, g, name=f"hypercube(n={n})")


def exponential(n: int) -> Topology:
    """Static exponential graph [16]: i → (i + 2^k) mod n, k = 0..⌈log2 n⌉−1.

    Directed but circulant, hence doubly stochastic with uniform weights
    1/(⌈log2 n⌉ + 1). W is stored as an override; ``edges`` hold the
    undirected support (used for degree/bandwidth accounting — the paper
    counts its degree sum as 2·n·⌈log2 n⌉ worth of directed links, i.e.
    out-degree = in-degree = ⌈log2 n⌉).
    """
    tau = max(1, math.ceil(math.log2(n)))
    hops = [2**k for k in range(tau)]
    W = np.zeros((n, n))
    coef = 1.0 / (tau + 1)
    W += np.eye(n) * coef
    for h in hops:
        for i in range(n):
            W[i, (i + h) % n] += coef
    edges = sorted({tuple(sorted((i, (i + h) % n))) for h in hops for i in range(n) if (i + h) % n != i})
    g = np.zeros(len(edges))
    t = Topology(n, edges, g, name=f"exponential(n={n})")
    t.meta["W_override"] = W
    t.meta["directed"] = True
    t.meta["out_degree"] = tau
    return t


def u_equistatic(n: int, M: int, seed: int = 0, trials: int = 64) -> Topology:
    """U-EquiStatic [19]: average of M symmetrized cyclic-shift basis graphs.

    W = (I + Σ_k (P^{s_k} + P^{−s_k})/2) / (M + 1) with distinct random shifts
    s_k ∈ {1,…,n−1}. Degree = 2M per node (or 2M−1 when a shift is n/2),
    edges ≈ n·M. EquiTopo samples shifts randomly; we draw ``trials`` samples
    and keep the best r_asym — same spirit, slightly stronger baseline.
    """
    rng = np.random.default_rng(seed)
    best: Topology | None = None
    best_r = np.inf
    for _ in range(trials):
        avail = list(range(1, n))
        shifts = list(rng.choice(avail, size=min(M, len(avail)), replace=False))
        W = np.eye(n)
        for s in shifts:
            P = np.zeros((n, n))
            for i in range(n):
                P[i, (i + s) % n] = 1.0
            W = W + (P + P.T) / 2.0
        W /= M + 1
        edges = sorted({tuple(sorted((i, (i + s) % n))) for s in shifts for i in range(n) if (i + s) % n != i})
        val = r_asym(W)
        if val < best_r:
            best_r = val
            t = Topology(n, edges, np.zeros(len(edges)), name=f"u-equistatic(n={n},M={M})")
            t.meta["W_override"] = W
            t.meta["shifts"] = shifts
            best = t
    assert best is not None
    return best


def random_graph(n: int, r: int, seed: int = 0) -> Topology:
    """Uniform random connected graph with r edges, Metropolis weights [20, 21]."""
    rng = np.random.default_rng(seed)
    cand = all_edges(n)
    for _ in range(512):
        sel = sorted(rng.choice(len(cand), size=r, replace=False).tolist())
        edges = [cand[k] for k in sel]
        from .graph import is_connected

        if is_connected(n, edges):
            g = metropolis_weights(n, edges)
            return Topology(n, edges, g, name=f"random(n={n},r={r})")
    raise RuntimeError(f"could not sample a connected random graph n={n}, r={r}")


BASELINES = ("ring", "grid", "torus", "hypercube", "exponential", "equistatic")


def make_baseline(kind: str, n: int, **kw) -> Topology:
    if kind == "ring":
        return ring(n)
    if kind == "grid":
        return grid2d(n)
    if kind == "torus":
        return torus2d(n)
    if kind == "hypercube":
        return hypercube(n)
    if kind == "exponential":
        return exponential(n)
    if kind == "equistatic":
        M = kw.pop("M", max(1, round(math.ceil(math.log2(n)) / 2)))
        return u_equistatic(n, M, **kw)
    if kind == "random":
        return random_graph(n, **kw)
    raise ValueError(f"unknown baseline topology: {kind}")
