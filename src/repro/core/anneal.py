"""Simulated-annealing warm start (§VI): construct an initial topology with
small average shortest path length (ASPL), optionally honoring a per-node
degree sequence and a heterogeneous ConstraintSet.

The paper notes the ADMM problem is initialization-sensitive and warm-starts
from an SA-optimized low-ASPL graph [40, 41]. Moves are degree-preserving
2-swaps ({a,b},{c,d} → {a,c},{b,d}), so a feasible degree sequence stays
feasible; constraint feasibility (M z ≤/= e) is re-checked per move.
"""
from __future__ import annotations

import math

import numpy as np

from .constraints import ConstraintSet
from .graph import all_edges, aspl, edge_index, is_connected

__all__ = ["greedy_degree_graph", "anneal_topology"]


def greedy_degree_graph(
    n: int,
    deg_target: np.ndarray,
    rng: np.random.Generator,
    cs: ConstraintSet | None = None,
    tries: int = 256,
) -> list[tuple[int, int]]:
    """Havel–Hakimi-style randomized construction of a connected graph whose
    degree sequence matches ``deg_target`` and which satisfies ``cs`` if given.
    """
    eidx = edge_index(n)
    edges_full = all_edges(n)
    m = len(edges_full)
    ok = cs.edge_ok if cs is not None else np.ones(m, dtype=bool)

    for _ in range(tries):
        residual = np.asarray(deg_target, dtype=np.int64).copy()
        z = np.zeros(m, dtype=bool)
        usage = np.zeros(cs.q, dtype=np.int64) if cs is not None else None
        failed = False
        order = list(range(n))
        while residual.sum() > 0:
            rng.shuffle(order)
            i = max(order, key=lambda u: residual[u])
            if residual[i] <= 0:
                break
            # candidate partners: positive residual, edge admissible & unused
            cands = []
            for j in order:
                if j == i or residual[j] <= 0:
                    continue
                l = eidx[(min(i, j), max(i, j))]
                if z[l] or not ok[l]:
                    continue
                if cs is not None:
                    col = cs.M[:, l]
                    if np.any(usage + col > cs.e_cap):
                        continue
                cands.append((j, l))
            if not cands:
                failed = True
                break
            # prefer the highest-residual partner (classic Havel–Hakimi)
            cands.sort(key=lambda t: -residual[t[0]])
            take = cands[0] if rng.random() < 0.7 else cands[rng.integers(len(cands))]
            j, l = take
            z[l] = True
            residual[i] -= 1
            residual[j] -= 1
            if cs is not None:
                usage += cs.M[:, l]
        if failed:
            continue
        edges = [edges_full[l] for l in np.nonzero(z)[0]]
        if is_connected(n, edges):
            return edges
    raise RuntimeError(f"could not realize degree sequence {deg_target} under constraints")


def anneal_topology(
    n: int,
    edges0: list[tuple[int, int]],
    cs: ConstraintSet | None = None,
    iters: int = 2000,
    T0: float = 0.5,
    seed: int = 0,
) -> list[tuple[int, int]]:
    """SA over degree-preserving 2-swaps, minimizing ASPL. Returns best edges."""
    rng = np.random.default_rng(seed)
    eidx = edge_index(n)
    edges_full = all_edges(n)
    m = len(edges_full)
    ok = cs.edge_ok if cs is not None else np.ones(m, dtype=bool)

    cur = sorted(edges0)
    cur_set = set(cur)
    cur_cost = aspl(n, cur)
    best, best_cost = list(cur), cur_cost

    # Capacity usage M z is maintained incrementally per accepted move (like
    # ``repair_selection`` does with ``usage``) instead of rebuilding the
    # O(m) selection mask from scratch for every candidate move.
    usage = None
    if cs is not None:
        z = np.zeros(m, dtype=np.int64)
        for e in cur:
            z[eidx[e]] = 1
        usage = cs.M @ z

    for t in range(iters):
        if len(cur) < 2:
            break
        T = T0 * math.exp(-3.0 * t / max(iters, 1))
        a_i = rng.integers(len(cur))
        b_i = rng.integers(len(cur))
        if a_i == b_i:
            continue
        (a, b), (c, d) = cur[a_i], cur[b_i]
        # two rewiring options preserve degrees
        opts = [((a, c), (b, d)), ((a, d), (b, c))]
        rng.shuffle(opts)
        for (p1, p2) in opts:
            p1 = (min(p1), max(p1))
            p2 = (min(p2), max(p2))
            if p1[0] == p1[1] or p2[0] == p2[1]:
                continue
            if p1 in cur_set or p2 in cur_set or p1 == p2:
                continue
            if not (ok[eidx[p1]] and ok[eidx[p2]]):
                continue
            new_usage = None
            if cs is not None:
                new_usage = (usage - cs.M[:, eidx[(a, b)]] - cs.M[:, eidx[(c, d)]]
                             + cs.M[:, eidx[p1]] + cs.M[:, eidx[p2]])
                feasible = (np.all(new_usage == cs.e_cap) if cs.equality
                            else np.all(new_usage <= cs.e_cap))
                if not feasible:
                    continue
            new = [e for k, e in enumerate(cur) if k not in (a_i, b_i)] + [p1, p2]
            if not is_connected(n, new):
                continue
            new_cost = aspl(n, new)
            if new_cost <= cur_cost or rng.random() < math.exp(-(new_cost - cur_cost) / max(T, 1e-9)):
                cur = sorted(new)
                cur_set = set(cur)
                cur_cost = new_cost
                usage = new_usage
                if cur_cost < best_cost:
                    best, best_cost = list(cur), cur_cost
            break
    return sorted(best)
