"""Three-term roofline analysis from compiled dry-run artifacts."""
from .analysis import (
    HW,
    RooflineReport,
    analytic_flops_bytes,
    collective_bytes_from_hlo,
    model_flops,
    roofline_report,
)

__all__ = ["HW", "RooflineReport", "analytic_flops_bytes", "collective_bytes_from_hlo",
           "model_flops", "roofline_report"]
