"""Roofline terms per (arch × shape × mesh) from the compiled dry-run.

    compute    = FLOPs            / (chips × peak FLOP/s)
    memory     = HBM bytes        / (chips × HBM bandwidth)
    collective = collective bytes / (chips × ICI link bandwidth)

Sources:
  · collective bytes — parsed from ``compiled.as_text()`` with while-loop
    trip-count multipliers (XLA annotates ``known_trip_count``; a layer scan
    executes its body L times, so summing the body once — what
    ``cost_analysis()`` does — undercounts by ~L×. We walk the HLO call graph
    and multiply through, which the tests validate against unrolled HLO).
  · compute / memory terms — ANALYTIC operation counts (documented below).
    ``compiled.cost_analysis()`` has the same body-counted-once limitation
    plus CPU-backend layouts, so the raw numbers are recorded alongside for
    transparency but the roofline uses the analytic terms; the dry-run
    cross-validates analytic vs unrolled-HLO flops on a small arch.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the brief), 25 GB/s/link assumed for the inter-pod DCI hop.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field


__all__ = ["HW", "RooflineReport", "collective_bytes_from_hlo", "model_flops",
           "analytic_flops_bytes", "roofline_report"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per ICI link
    dci_bw: float = 25e9            # bytes/s per pod-interconnect link


V5E = HW()

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, incl. tuples: '(f32[2,3], s32[4])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_computations(hlo: str) -> dict[str, list[str]]:
    """Split HLO text into {computation_name: [op lines]}."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    # greedy param match — while-body signatures carry tuple-typed params
    # with nested parens: %body (p: (s32[], f32[64])) -> (...)
    header = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
    simple = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\{")
    for line in hlo.splitlines():
        if cur is None:
            m = header.match(line) or simple.match(line)
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _multipliers(comps: dict[str, list[str]]) -> dict[str, float]:
    """Execution-count multiplier per computation, propagating while trip
    counts down the call graph (calls=/to_apply= ×1, body=/condition= ×n)."""
    edges: dict[str, list[tuple[str, float]]] = {name: [] for name in comps}
    trip_re = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
    while_re = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
    call_re = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
    for name, lines in comps.items():
        for line in lines:
            wm = while_re.search(line)
            if wm:
                tm = trip_re.search(line)
                n = float(tm.group(1)) if tm else 1.0
                for target in wm.groups():
                    if target in comps:
                        edges[name].append((target, n))
            else:
                for target in call_re.findall(line):
                    if target in comps:
                        edges[name].append((target, 1.0))

    # roots: computations nobody calls (the entry)
    called = {t for outs in edges.values() for t, _ in outs}
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        mult[name] = max(mult.get(name, 0.0), m)
        for target, k in edges[name]:
            if mult.get(target, 0.0) < m * k:
                visit(target, m * k)

    for name in comps:
        if name not in called:
            visit(name, 1.0)
    return mult


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum collective-op bytes (max of result/operand sizes), trip-corrected.

    Returns {"total": bytes, "by_op": {op: bytes}, "count": ops found}.
    """
    comps = _parse_computations(hlo)
    mult = _multipliers(comps)
    by_op: dict[str, float] = {}
    count = 0
    op_re = re.compile(
        r"%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) +
        r")(?:-start)?\((.*?)\)")
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        symbols: dict[str, int] = {}
        for line in lines:
            dm = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}]+)", line)
            if dm:
                symbols[dm.group(1)] = _shape_bytes(dm.group(2))
            om = op_re.search(line)
            if om is None or "-done" in line.split("=")[1][:40]:
                continue
            _, result_type, op, operands = om.groups()
            rbytes = _shape_bytes(result_type)
            obytes = 0
            for ref in re.findall(r"%([\w.\-]+)", operands):
                obytes = max(obytes, symbols.get(ref, 0))
            moved = max(rbytes, obytes)
            by_op[op] = by_op.get(op, 0.0) + moved * m
            count += 1
    return {"total": float(sum(by_op.values())), "by_op": by_op, "count": count}


# ---------------------------------------------------------------------------
# analytic operation counts
# ---------------------------------------------------------------------------

def model_flops(cfg, n_tokens: int, mode: str, param_count: int,
                active_param_count: int | None = None) -> float:
    """The brief's MODEL_FLOPS: 6·N·D (train) / 2·N·D (inference), N = active
    params for MoE."""
    n = active_param_count if active_param_count is not None else param_count
    return (6.0 if mode == "train" else 2.0) * n * n_tokens


def active_param_count(cfg, param_count: int, moe_param_count: int) -> int:
    """MoE: only top-k of E experts run per token."""
    if not cfg.num_experts:
        return param_count
    dense = param_count - moe_param_count
    return dense + moe_param_count * cfg.experts_per_token // cfg.num_experts


def _attn_flops(cfg, B: int, S: int, kv_len: int | None = None) -> float:
    """Score+PV matmul flops (the part 6ND misses), per forward."""
    if not cfg.num_heads:
        return 0.0
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    kv = kv_len if kv_len is not None else S
    # windows cap the effective kv length
    if cfg.sliding_window:
        kv = min(kv, cfg.sliding_window) if S == 1 else kv
    return 2.0 * 2.0 * B * S * kv * cfg.num_heads * hd * L


def analytic_flops_bytes(cfg, shape, mode: str, counts: dict) -> dict:
    """FLOPs + HBM bytes for one step of ``mode`` on the GLOBAL problem.

    counts: {"params": int, "active": int, "param_bytes": int,
             "cache_bytes": int (decode)}.
    Formulas (standard accounting, e.g. PaLM appendix / MaxText):
      train:   6·N_active·D matmul + attention scores ×3 (fwd+2bwd)
      prefill: 2·N_active·D + attention scores
      decode:  2·N_active·B (one token) + B·kv·heads·hd score flops
      bytes:   weights + activations (train ≈ 2× remat) + caches (decode)
    """
    B, S = shape.global_batch, shape.seq_len
    N = counts["active"]
    pb = counts["param_bytes"]
    if mode == "train":
        D = B * S
        flops = 6.0 * N * D + 3.0 * _attn_flops(cfg, B, S)
        # fwd read + bwd read + grad write (f32) + momentum rw (f32)
        act_bytes = 2.0 * B * S * cfg.d_model * 2 * cfg.num_layers * 2  # remat’d
        mem = 2.0 * pb + 2.0 * (pb * 2) + 2.0 * (pb * 2) + act_bytes
    elif mode == "prefill":
        D = B * S
        flops = 2.0 * N * D + _attn_flops(cfg, B, S)
        mem = pb + 2.0 * B * S * cfg.d_model * 2 * cfg.num_layers + counts.get("cache_bytes", 0)
    else:  # decode: one token per request, kv cache of S
        D = B
        kv = S if not cfg.sliding_window else min(S, cfg.sliding_window)
        flops = 2.0 * N * D + _attn_flops(cfg, B, 1, kv_len=kv)
        mem = pb + counts.get("cache_bytes", 0)
    return {"flops": flops, "hbm_bytes": mem, "tokens": float(B * (S if mode != "decode" else 1))}


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    mode: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float
    hlo_flops_raw: float
    extras: dict = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline lower bound: overlapped terms → max."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        d = {k: getattr(self, k) for k in (
            "arch", "shape", "mesh", "mode", "chips", "compute_s", "memory_s",
            "collective_s", "flops", "hbm_bytes", "collective_bytes",
            "model_flops", "hlo_flops_raw")}
        d["dominant"] = self.dominant
        d["useful_ratio"] = self.model_flops / max(self.flops, 1.0)
        d.update(self.extras)
        return d


def roofline_report(*, arch: str, shape, mesh_name: str, mode: str, chips: int,
                    analytic: dict, mflops: float, collective: dict,
                    hlo_flops_raw: float = 0.0, cross_pod: bool = False,
                    hw: HW = V5E, extras: dict | None = None) -> RooflineReport:
    """collective["total"] comes from the compiled SPMD module, whose shapes
    are PER-PARTITION — it is already the per-chip traffic (each chip runs
    the same program), so the collective term divides by link bandwidth
    only. Compute/memory terms are global analytic totals → divide by chips."""
    link_bw = hw.dci_bw if cross_pod else hw.ici_bw
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, mode=mode, chips=chips,
        compute_s=analytic["flops"] / (chips * hw.peak_flops),
        memory_s=analytic["hbm_bytes"] / (chips * hw.hbm_bw),
        collective_s=collective["total"] / link_bw,
        flops=analytic["flops"], hbm_bytes=analytic["hbm_bytes"],
        collective_bytes=collective["total"], model_flops=mflops,
        hlo_flops_raw=hlo_flops_raw, extras=extras or {})
