"""Batched KV-cache serving engine (prefill + single-token decode steps)."""
from .engine import (
    DecodeState,
    ServeConfig,
    ServingEngine,
    greedy_sample,
    make_functional_serve_step,
    make_serve_step,
)

__all__ = ["DecodeState", "ServeConfig", "ServingEngine", "greedy_sample",
           "make_functional_serve_step", "make_serve_step"]
