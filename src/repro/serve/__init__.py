"""Serving runtimes: the batched KV-cache decode engine and the
fault-tolerant topology-optimization service (DESIGN.md §15)."""
from .engine import (
    DecodeState,
    ServeConfig,
    ServingEngine,
    greedy_sample,
    make_functional_serve_step,
    make_serve_step,
)
from .topo_service import (
    QUALITY_TIERS,
    ServiceHooks,
    ServicePolicy,
    TopologyService,
    TopoRequest,
    TopoResponse,
)

__all__ = ["DecodeState", "ServeConfig", "ServingEngine", "greedy_sample",
           "make_functional_serve_step", "make_serve_step",
           "QUALITY_TIERS", "ServiceHooks", "ServicePolicy",
           "TopologyService", "TopoRequest", "TopoResponse"]
