"""Fault-tolerant topology-optimization service (DESIGN.md §15).

ROADMAP item 1: topology-optimization-as-a-service over the existing
batched/vmapped solver machinery. A :class:`TopologyService` admits
``(n, r, scenario, bandwidth profile, deadline_ms)`` requests through a
bounded queue and guarantees the service invariant: **every admitted
request gets either a valid topology (finite, symmetric, connected,
row-stochastic W — the ``core.guard`` release checklist) or a structured
rejection with a reason — never an exception, never an invalid matrix.**

Architecture (one request's life):

  submit ──► validate spec ──► bounded queue ──► canonical cache key
     │            │ malformed       │ full            │
     │            ▼                 ▼                 ▼ hit (drift-checked)
     │        rejection         rejection          tier "cache"
     ▼ miss
  deadline ladder: full pipeline → warm-started guarded ADMM → SA-only
  topology → classic fallback, each rung EMA-cost-gated against the
  remaining deadline budget and tagged ``quality_tier`` + reason.

* **Admission control** — the queue is bounded (``ServicePolicy.max_queue``);
  overload is answered with a structured rejection (backpressure), not an
  exception. Malformed specs (bad n/r/scenario, missing or non-finite
  bandwidth profiles, infeasible budgets) are rejected at submit time with
  the offending field named.
* **Canonical cache** — specs canonicalize to ``(n, min(r, |E|), scenario,
  quantized bandwidth profile, ConstraintSet fingerprint)``; the cache is
  LRU over ``ServicePolicy.cache_capacity``. A ``core.reopt.DriftDetector``
  guards every hit: if the entry's solve-time bandwidth profile has drifted
  past ``ServicePolicy.drift`` thresholds relative to the request's current
  profile, the entry is invalidated and the request re-solves
  (:meth:`TopologyService.observe` feeds live telemetry the same way).
* **Bucketed misses** — compatible cache misses (same n, homogeneous
  scenario, no deadline pressure) are solved in ONE vmapped sweep dispatch
  (``engine.solve_sweep_spec`` — r is a data leaf), with per-request warm
  starts annealed through the ``api._anneal_edges`` edge-count grouping and
  the instance axis padded to a power of two so repeat batch sizes reuse
  compilations. Restart indices match ``optimize_topology`` exactly, so a
  bucketed solve rounds to the same support as the one-shot pipeline.
* **Deadline degradation** — per-(tier, n) EMA latency estimates decide
  which rungs still fit the remaining budget; an expired deadline jumps
  straight to the closed-form classic fallback (Song et al. / Takezawa et
  al., PAPERS.md: cheap topologies are strong fallbacks). Responses carry
  ``quality_tier`` ∈ {cache, full, warm, sa_only, classic} and the reason
  trail of every skipped/failed rung.
* **Fault injection** — :class:`ServiceHooks` lets tests and
  ``benchmarks/bench_service.py`` replace any tier's solver with a stub
  (NaN-returning, slow, raising); the guard ladder and the service
  invariant are exercised, not mocked.

Per-phase latency rides the PR-3 ``profile`` dict: the full tier passes it
straight into ``optimize_topology`` (``warm_s/admm_s/round_s/polish_s/
eval_s``) and the service adds ``queue_s``/``solve_s``.
"""
from __future__ import annotations

import hashlib
import json
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable

import numpy as np

from ..core.anytime import (
    PhaseProfile, TopologyRequest, resolve_scenario, solve_topology,
    validate_request,
)
from ..core.api import (
    BATopoConfig, _anneal_edges, _candidate_items, _finalize_batch,
    _homo_degree_targets, _init_graph, _pack_warm, _pick_best,
)
from ..core.constraints import ConstraintSet  # noqa: F401 — public re-export
from ..core.graph import Topology, all_edges, is_connected
from ..core.guard import (
    GuardPolicy, check_invariants, classic_fallback, jittered_warm_rungs,
    run_ladder,
)
from ..core.reopt import DriftDetector, DriftPolicy
from ..core.weights import metropolis_weights

__all__ = ["ServicePolicy", "ServiceHooks", "TopoRequest", "TopoResponse",
           "TopologyService", "QUALITY_TIERS"]

#: Degradation order: best answer first, closed-form last resort last.
QUALITY_TIERS = ("cache", "full", "warm", "sa_only", "classic")

#: The service request IS the unified request dataclass (DESIGN.md §17) —
#: same fields, same auto-assigned ``request_id``, one validation path.
TopoRequest = TopologyRequest


@dataclass(frozen=True)
class ServicePolicy:
    """Service knobs.

    ``max_queue``: admitted-but-unprocessed requests beyond this are
    rejected with reason ``overloaded`` (bounded queue = backpressure).
    ``cache_capacity``: LRU entry cap of the canonical topology cache.
    ``bw_quant``: relative quantization step for bandwidth profiles in the
    cache key — profiles within one step of each other share an entry.
    ``drift``: DriftDetector thresholds for hit-time cache invalidation.
    ``guard``: retry-ladder policy for the warm tier (ρ jitter, retries).
    ``deadline_safety``: a tier is skipped when its EMA latency estimate ×
    this factor exceeds the remaining deadline budget.
    ``ema_alpha``: EMA smoothing for the per-(tier, n) latency estimates.
    ``pad_pow2``: pad bucketed solve batches to the next power of two so
    recurring bucket sizes reuse vmap compilations.
    ``ema_seed``: seed the per-(tier, n) latency EMAs and the anytime
    per-phase estimates from the tracked BENCH_admm.json pipeline rows at
    construction, so the first requests after process start don't
    mispredict the full tier (they previously started cold).
    """

    max_queue: int = 32
    cache_capacity: int = 128
    bw_quant: float = 0.05
    drift: DriftPolicy = field(default_factory=DriftPolicy)
    guard: GuardPolicy = field(default_factory=GuardPolicy)
    deadline_safety: float = 1.5
    ema_alpha: float = 0.3
    pad_pow2: bool = True
    ema_seed: bool = True


@dataclass
class ServiceHooks:
    """Per-tier solver overrides — the fault-injection surface.

    Each hook, when set, replaces that tier's solve with
    ``hook(request, profile) -> Topology`` (may raise, may return garbage:
    the service still release-validates whatever comes back, so a
    NaN-returning stub exercises the real invariant checklist and ladder).
    ``full`` set also disables miss bucketing (the stub sees every request).
    """

    full: Callable | None = None
    warm: Callable | None = None
    sa: Callable | None = None
    classic: Callable | None = None


@dataclass
class TopoResponse:
    """Structured answer: a topology with a quality tier, or a rejection."""

    request_id: int
    status: str                        # "ok" | "rejected"
    topology: Topology | None = None
    quality_tier: str | None = None    # one of QUALITY_TIERS when ok
    reason: str | None = None          # rejection reason / degradation trail
    cache_hit: bool = False
    latency_ms: float = 0.0
    profile: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def degraded(self) -> bool:
        return self.ok and self.quality_tier not in ("cache", "full")


@dataclass
class _CacheEntry:
    topology: Topology
    bandwidth: np.ndarray | None       # profile at solve time (drift baseline)
    hits: int = 0


def _load_bench_rows() -> list[dict] | None:
    """Tracked BENCH_admm.json rows (repo root), or None outside a checkout
    / on any read problem — EMA seeding is best-effort."""
    path = Path(__file__).resolve().parents[3] / "BENCH_admm.json"
    try:
        rows = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    return rows if isinstance(rows, list) else None


class TopologyService:
    """Admission-controlled, deadline-aware, fault-tolerant topology oracle.

    Synchronous single-owner engine (like ``dsgd``'s simulators): callers
    :meth:`submit` requests — each submit returns either a queued request id
    or an immediate structured rejection — then :meth:`drain` processes the
    queue (bucketing compatible misses into one vmapped dispatch) and
    returns the responses. :meth:`request` is the submit-and-drain
    convenience for one spec.
    """

    def __init__(self, cfg: BATopoConfig | None = None,
                 policy: ServicePolicy | None = None,
                 hooks: ServiceHooks | None = None,
                 bench_rows: list[dict] | None = None):
        self.cfg = cfg or BATopoConfig()
        self.policy = policy or ServicePolicy()
        self.hooks = hooks or ServiceHooks()
        self._queue: list[tuple[TopoRequest, float]] = []   # (req, t_submit)
        self._cache: OrderedDict[tuple, _CacheEntry] = OrderedDict()
        self._ema_ms: dict[tuple[str, int], float] = {}
        self._seed_profiles: dict[int, PhaseProfile] = {}
        self.stats = {"submitted": 0, "admitted": 0, "rejected_overload": 0,
                      "rejected_malformed": 0, "cache_hits": 0, "misses": 0,
                      "invalidations": 0, "bucketed_solves": 0,
                      "degraded": 0, "failed": 0, "ema_seeded": 0}
        if self.policy.ema_seed:
            if bench_rows is None:
                bench_rows = _load_bench_rows()
            self._seed_ema(bench_rows or [])

    def _seed_ema(self, rows: list[dict]) -> None:
        """Prime the cold-start latency estimates from tracked pipeline
        bench rows: the device-pipeline ``total_s`` becomes the full-tier
        EMA prior for that n, and the per-phase breakdown becomes the
        anytime solver's stage-scheduling seed profile."""
        for row in rows:
            if row.get("pipeline") != "device" or "n" not in row:
                continue
            n = int(row["n"])
            if "total_s" in row:
                self._ema_ms.setdefault(("full", n),
                                        float(row["total_s"]) * 1e3)
                self.stats["ema_seeded"] += 1
            prof = PhaseProfile.from_dict(
                {k: row[k] for k in ("warm_s", "admm_s", "round_s",
                                     "polish_s", "eval_s") if k in row})
            if prof.phases:
                restarts = max(1, int(row.get("restarts", 1)))
                self._seed_profiles[n] = PhaseProfile(
                    {k: v / restarts for k, v in prof.phases.items()})

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, req: TopoRequest) -> TopoResponse | int:
        """Admit ``req`` into the bounded queue.

        Returns the request id when admitted, or an immediate
        :class:`TopoResponse` rejection (malformed spec / overload). Never
        raises.
        """
        self.stats["submitted"] += 1
        bad = self._validate(req)
        if bad is not None:
            self.stats["rejected_malformed"] += 1
            return TopoResponse(req.request_id, "rejected",
                                reason=f"malformed: {bad}")
        if len(self._queue) >= self.policy.max_queue:
            self.stats["rejected_overload"] += 1
            return TopoResponse(
                req.request_id, "rejected",
                reason=f"overloaded: queue full "
                       f"({len(self._queue)}/{self.policy.max_queue})")
        self.stats["admitted"] += 1
        self._queue.append((req, time.perf_counter()))
        return req.request_id

    def request(self, n: int, r: int, scenario: str = "homo",
                node_bandwidths: np.ndarray | None = None,
                cs: ConstraintSet | None = None,
                deadline_ms: float | None = None) -> TopoResponse:
        """Submit one spec and process it to completion."""
        req = TopoRequest(n=n, r=r, scenario=scenario,
                          node_bandwidths=node_bandwidths, cs=cs,
                          deadline_ms=deadline_ms)
        out = self.submit(req)
        if isinstance(out, TopoResponse):
            return out
        return self.drain()[-1]

    def _validate(self, req: TopoRequest) -> str | None:
        """First malformed field of ``req``, or None — delegated to the
        unified ``anytime.validate_request`` path (the service-level twin of
        the topology release checklist: bad requests die here, named)."""
        return validate_request(req)

    # ------------------------------------------------------------------
    # canonical cache
    # ------------------------------------------------------------------

    def _cache_key(self, req: TopoRequest) -> tuple:
        n = int(req.n)
        r_eff = min(int(req.r), len(all_edges(n)))
        bw_key: tuple | None = None
        if req.node_bandwidths is not None:
            bw = np.asarray(req.node_bandwidths, dtype=np.float64)
            step = self.policy.bw_quant * max(float(bw.mean()), 1e-12)
            bw_key = tuple(np.round(bw / step).astype(np.int64).tolist())
        cs_key: str | None = None
        if req.cs is not None:
            h = hashlib.sha1()
            h.update(np.ascontiguousarray(req.cs.M).tobytes())
            h.update(np.ascontiguousarray(req.cs.e_cap).tobytes())
            h.update(np.ascontiguousarray(req.cs.edge_ok).tobytes())
            h.update(b"eq" if req.cs.equality else b"ineq")
            cs_key = h.hexdigest()
        return (n, r_eff, req.scenario, bw_key, cs_key)

    def _cache_lookup(self, req: TopoRequest, key: tuple) -> Topology | None:
        """Drift-checked LRU hit: the entry's solve-time bandwidth profile
        must still be within ``policy.drift`` of the request's current
        profile, else the entry is invalidated (stale world)."""
        entry = self._cache.get(key)
        if entry is None:
            return None
        if (entry.bandwidth is not None
                and req.node_bandwidths is not None):
            det = DriftDetector.from_profile(
                entry.bandwidth, np.ones(len(entry.bandwidth)),
                self.policy.drift)
            if det.check(1, np.asarray(req.node_bandwidths, np.float64),
                         np.ones(len(entry.bandwidth))) is not None:
                del self._cache[key]
                self.stats["invalidations"] += 1
                return None
        entry.hits += 1
        self._cache.move_to_end(key)
        return entry.topology

    def _cache_store(self, req: TopoRequest, key: tuple,
                     topo: Topology) -> None:
        bw = (np.asarray(req.node_bandwidths, np.float64).copy()
              if req.node_bandwidths is not None else None)
        self._cache[key] = _CacheEntry(topo, bw)
        self._cache.move_to_end(key)
        while len(self._cache) > self.policy.cache_capacity:
            self._cache.popitem(last=False)

    def observe(self, node_bandwidths: np.ndarray) -> int:
        """Feed live bandwidth telemetry: invalidate every cached entry
        whose solve-time profile has drifted past ``policy.drift`` relative
        to the observed world. Returns the number of entries evicted."""
        bw_t = np.asarray(node_bandwidths, np.float64)
        dead = []
        for key, entry in self._cache.items():
            if entry.bandwidth is None or len(entry.bandwidth) != len(bw_t):
                continue
            det = DriftDetector.from_profile(
                entry.bandwidth, np.ones(len(bw_t)), self.policy.drift)
            if det.check(1, bw_t, np.ones(len(bw_t))) is not None:
                dead.append(key)
        for key in dead:
            del self._cache[key]
        self.stats["invalidations"] += len(dead)
        return len(dead)

    def _nearest_warm(self, req: TopoRequest) -> tuple | None:
        """Nearest-neighbor warm start: the cached same-(n, scenario) entry
        with the closest (r, bandwidth) spec, packed into an ADMM
        ``(g0, z0, lam0)`` start from its support. None if no neighbor."""
        n = int(req.n)
        bw = (np.asarray(req.node_bandwidths, np.float64)
              if req.node_bandwidths is not None else None)
        best_key, best_d = None, np.inf
        for key, entry in self._cache.items():
            kn, kr, kscen, _, _ = key
            if kn != n or kscen != req.scenario:
                continue
            d = abs(kr - min(int(req.r), len(all_edges(n))))
            if bw is not None and entry.bandwidth is not None:
                rel = np.abs(entry.bandwidth - bw) / np.maximum(bw, 1e-12)
                d += float(rel.mean())
            if d < best_d:
                best_key, best_d = key, d
        if best_key is None:
            return None
        return _pack_warm(n, self._cache[best_key].topology.edges)

    # ------------------------------------------------------------------
    # deadline accounting
    # ------------------------------------------------------------------

    def _remaining_ms(self, req: TopoRequest, t_submit: float) -> float | None:
        if req.deadline_ms is None:
            return None
        return req.deadline_ms - (time.perf_counter() - t_submit) * 1e3

    def _estimate_ms(self, tier: str, n: int) -> float | None:
        return self._ema_ms.get((tier, n))

    def _record_ms(self, tier: str, n: int, elapsed_ms: float) -> None:
        key = (tier, n)
        prev = self._ema_ms.get(key)
        a = self.policy.ema_alpha
        self._ema_ms[key] = (elapsed_ms if prev is None
                             else (1 - a) * prev + a * elapsed_ms)

    # ------------------------------------------------------------------
    # tiers
    # ------------------------------------------------------------------

    def _tier_full(self, req: TopoRequest, prof: dict) -> Topology:
        """The unabridged pipeline — the same barrier execution the library
        API runs, so a fault-free full-tier answer is bit-equal to what
        one-shot ``solve_topology`` returns."""
        if self.hooks.full is not None:
            return self.hooks.full(req, prof)
        return solve_topology(req, cfg=self.cfg, profile=prof,
                              engine="barrier").topology

    def _tier_warm(self, req: TopoRequest, prof: dict) -> Topology | None:
        """Guarded warm-started ADMM from the nearest cached support (greedy
        init when the cache has no neighbor): skips SA and restarts, runs
        the ``core.guard`` ρ-jitter retry ladder."""
        if self.hooks.warm is not None:
            return self.hooks.warm(req, prof)
        n, r = int(req.n), int(req.r)
        scenario = req.scenario
        cs, _, _ = resolve_scenario(n, r, scenario, req.cs,
                                    req.node_bandwidths, context="service")
        t0 = time.perf_counter()
        warm = self._nearest_warm(req)
        if warm is None:
            deg = _homo_degree_targets(n, r) if scenario == "homo" else None
            edges0, _ = _init_graph(n, r, scenario, cs, deg, self.cfg, 0)
            warm = _pack_warm(n, edges0)
        prof["warm_s"] = prof.get("warm_s", 0.0) + time.perf_counter() - t0
        t0 = time.perf_counter()
        ladder = run_ladder(jittered_warm_rungs(
            n, r, scenario, cs, self.cfg, warm,
            f"ba-topo(n={n},r={r},svc-warm)", self.policy.guard))
        prof["admm_s"] = prof.get("admm_s", 0.0) + time.perf_counter() - t0
        if ladder.topology is None:
            raise RuntimeError(f"warm ladder exhausted ({ladder.reason})")
        ladder.topology.meta["ladder_rung"] = ladder.rung
        return ladder.topology

    def _tier_sa(self, req: TopoRequest, prof: dict) -> Topology | None:
        """SA-only topology: greedy init + simulated annealing, Metropolis
        weights, NO ADMM and NO polish — the cheap-but-principled rung for
        tight deadlines."""
        if self.hooks.sa is not None:
            return self.hooks.sa(req, prof)
        n, r = int(req.n), int(req.r)
        t0 = time.perf_counter()
        deg = _homo_degree_targets(n, r) if req.scenario == "homo" else None
        cs = req.cs if req.scenario != "homo" else None
        edges0, seed = _init_graph(n, r, req.scenario, cs, deg, self.cfg, 0)
        edges = _anneal_edges(n, [edges0], [seed], cs, self.cfg)[0]
        prof["warm_s"] = prof.get("warm_s", 0.0) + time.perf_counter() - t0
        if not edges or not is_connected(n, edges):
            return None
        g = metropolis_weights(n, edges)
        return Topology(n, edges, g, name=f"ba-topo(n={n},r={r},svc-sa)",
                        meta={"connected": True, "sa_only": True})

    def _tier_classic(self, req: TopoRequest, prof: dict) -> Topology:
        """Closed-form last resort — always answers."""
        if self.hooks.classic is not None:
            return self.hooks.classic(req, prof)
        return classic_fallback(int(req.n), int(req.r),
                                req.cs if req.scenario != "homo" else None)

    _TIER_ORDER = ("full", "warm", "sa_only", "classic")

    # ------------------------------------------------------------------
    # processing
    # ------------------------------------------------------------------

    def drain(self) -> list[TopoResponse]:
        """Process every queued request; responses in submit order.

        Cache hits answer immediately; compatible misses (homogeneous
        scenario, no deadline, default solver path, no full-tier hook) are
        bucketed per n into one vmapped sweep dispatch; everything else
        walks the deadline ladder individually. Never raises.
        """
        batch, self._queue = self._queue, []
        responses: dict[int, TopoResponse] = {}
        buckets: dict[int, list[tuple[TopoRequest, float, tuple]]] = {}
        singles: list[tuple[TopoRequest, float]] = []

        for req, t_sub in batch:
            key = self._cache_key(req)
            t0 = time.perf_counter()
            hit = self._cache_lookup(req, key)
            if hit is not None:
                self.stats["cache_hits"] += 1
                responses[req.request_id] = TopoResponse(
                    req.request_id, "ok", topology=hit, quality_tier="cache",
                    reason=None, cache_hit=True,
                    latency_ms=(time.perf_counter() - t_sub) * 1e3,
                    profile={"cache_s": time.perf_counter() - t0})
                continue
            self.stats["misses"] += 1
            if (req.scenario == "homo" and req.deadline_ms is None
                    and self.hooks.full is None
                    and self.cfg.admm.driver == "scan"
                    and self.cfg.admm.solver != "kkt_bicgstab_ilu"):
                buckets.setdefault(int(req.n), []).append((req, t_sub, key))
            else:
                singles.append((req, t_sub))

        for n, group in buckets.items():
            if len(group) < 2:           # nothing to amortize — go individual
                singles.extend((req, t_sub) for req, t_sub, _ in group)
                continue
            try:
                topos = self._solve_bucket(n, [req for req, _, _ in group])
                self.stats["bucketed_solves"] += 1
            except Exception as exc:  # noqa: BLE001 — bucket failure → singles
                singles.extend((req, t_sub) for req, t_sub, _ in group)
                topos = None
                _ = exc
            if topos is None:
                continue
            for (req, t_sub, key), topo in zip(group, topos):
                if topo is None or check_invariants(topo) is not None:
                    singles.append((req, t_sub))   # ladder rescues it
                    continue
                self._cache_store(req, key, topo)
                responses[req.request_id] = TopoResponse(
                    req.request_id, "ok", topology=topo, quality_tier="full",
                    reason=None,
                    latency_ms=(time.perf_counter() - t_sub) * 1e3,
                    profile={"bucketed": True, "bucket_size": len(group)})

        for req, t_sub in singles:
            responses[req.request_id] = self._process_single(req, t_sub)

        out = [responses[req.request_id] for req, _ in batch]
        self.stats["degraded"] += sum(r.degraded for r in out)
        return out

    def _process_anytime(self, req: TopoRequest, t_sub: float) -> TopoResponse:
        """Deadline-driven miss on the anytime pipeline (DESIGN.md §17): the
        former full→warm→sa_only ladder rungs collapse into ONE budgeted
        best-so-far solve that degrades continuously — the budget is the
        remaining deadline, the stage scheduler is seeded from tracked
        bench phase timings when available, and an expired budget still
        answers via the solver's internal classic fallback. Never raises."""
        n = int(req.n)
        key = self._cache_key(req)
        queue_s = time.perf_counter() - t_sub
        remaining = self._remaining_ms(req, t_sub)
        t0 = time.perf_counter()
        try:
            res = solve_topology(req, cfg=self.cfg,
                                 budget_ms=max(float(remaining), 0.0),
                                 seed_profile=self._seed_profiles.get(n))
            topo, tier, reason = res.topology, res.quality_tier, res.reason
            prof = {"queue_s": queue_s, **res.profile.to_dict()}
        except Exception as exc:  # noqa: BLE001 — terminal guard, never raise
            topo, tier = None, None
            reason = f"anytime: {type(exc).__name__}: {exc}"
            prof = {"queue_s": queue_s}
        solve_s = time.perf_counter() - t0
        self._record_ms(tier or "full", n, solve_s * 1e3)
        if topo is not None and check_invariants(topo) is None:
            prof["solve_s"] = solve_s
            self._cache_store(req, key, topo)
            return TopoResponse(
                req.request_id, "ok", topology=topo, quality_tier=tier,
                reason=reason,
                latency_ms=(time.perf_counter() - t_sub) * 1e3, profile=prof)
        if topo is not None:
            bad = check_invariants(topo)
            reason = f"{reason}; anytime: invalid topology ({bad} violated)" \
                if reason else f"anytime: invalid topology ({bad} violated)"
        # terminal rescue: the closed-form classic (always answers)
        try:
            topo = (self.hooks.classic(req, prof) if self.hooks.classic
                    else classic_fallback(
                        n, int(req.r),
                        req.cs if req.scenario != "homo" else None))
            if check_invariants(topo) is None:
                prof["solve_s"] = time.perf_counter() - t0
                self._cache_store(req, key, topo)
                return TopoResponse(
                    req.request_id, "ok", topology=topo,
                    quality_tier="classic", reason=reason,
                    latency_ms=(time.perf_counter() - t_sub) * 1e3,
                    profile=prof)
        except Exception as exc:  # noqa: BLE001
            reason = f"{reason}; classic: {type(exc).__name__}: {exc}"
        self.stats["failed"] += 1
        return TopoResponse(
            req.request_id, "rejected",
            reason=f"all tiers failed: {reason}",
            latency_ms=(time.perf_counter() - t_sub) * 1e3, profile=prof)

    def _process_single(self, req: TopoRequest, t_sub: float) -> TopoResponse:
        """Walk the deadline ladder for one cache miss (fault-injection
        hooks and undeadlined requests); deadlined requests without
        optimizer hooks route through :meth:`_process_anytime` instead.
        Never raises: every tier failure is recorded in the reason trail
        and the next rung runs; if even the classic fallback fails, the
        request is rejected with the full trail."""
        if (req.deadline_ms is not None and self.hooks.full is None
                and self.hooks.warm is None and self.hooks.sa is None):
            return self._process_anytime(req, t_sub)
        n = int(req.n)
        key = self._cache_key(req)
        prof: dict = {"queue_s": time.perf_counter() - t_sub}
        reasons: list[str] = []
        tiers = {"full": self._tier_full, "warm": self._tier_warm,
                 "sa_only": self._tier_sa, "classic": self._tier_classic}
        for tier in self._TIER_ORDER:
            remaining = self._remaining_ms(req, t_sub)
            if tier != "classic" and remaining is not None:
                if remaining <= 0:
                    reasons.append(f"{tier}: skipped (deadline expired)")
                    continue
                est = self._estimate_ms(tier, n)
                if (est is not None
                        and est * self.policy.deadline_safety > remaining):
                    reasons.append(
                        f"{tier}: skipped (est {est:.1f}ms * "
                        f"{self.policy.deadline_safety:g} > "
                        f"{remaining:.1f}ms left)")
                    continue
            t0 = time.perf_counter()
            try:
                topo = tiers[tier](req, prof)
            except Exception as exc:  # noqa: BLE001 — any tier failure → next rung
                self._record_ms(tier, n, (time.perf_counter() - t0) * 1e3)
                reasons.append(f"{tier}: {type(exc).__name__}: {exc}")
                continue
            self._record_ms(tier, n, (time.perf_counter() - t0) * 1e3)
            if topo is None:
                reasons.append(f"{tier}: produced no topology")
                continue
            bad = check_invariants(topo)
            if bad is not None:
                reasons.append(f"{tier}: invalid topology ({bad} violated)")
                continue
            prof["solve_s"] = time.perf_counter() - t0
            self._cache_store(req, key, topo)
            return TopoResponse(
                req.request_id, "ok", topology=topo, quality_tier=tier,
                reason="; ".join(reasons) or None,
                latency_ms=(time.perf_counter() - t_sub) * 1e3,
                profile=prof)
        self.stats["failed"] += 1
        return TopoResponse(
            req.request_id, "rejected",
            reason="all tiers failed: " + "; ".join(reasons),
            latency_ms=(time.perf_counter() - t_sub) * 1e3, profile=prof)

    # ------------------------------------------------------------------
    # bucketed miss solve
    # ------------------------------------------------------------------

    def _solve_bucket(self, n: int, reqs: list[TopoRequest],
                      ) -> list[Topology | None]:
        """Solve a bucket of same-n homogeneous misses in one vmapped sweep.

        Mirrors ``optimize_topology`` request-by-request — same restart
        indices, same SA warm starts (annealed together through the
        ``_anneal_edges`` edge-count grouping), same rounding/polish/
        selection helpers — but runs ALL (request × restart) ADMM instances
        as ONE ``solve_sweep_spec`` call (r is a data leaf), padded to a
        power of two so recurring bucket sizes share a compilation.
        """
        import jax
        import jax.numpy as jnp

        from ..core.engine import init_state, make_homo_spec, solve_sweep_spec

        cfg = self.cfg
        m = len(all_edges(n))
        n_restarts = max(1, cfg.restarts)
        inits, seeds, rs_vec = [], [], []
        for req in reqs:
            r_eff = min(int(req.r), m)
            deg = _homo_degree_targets(n, r_eff)
            for k in range(n_restarts):
                edges0, seed = _init_graph(n, r_eff, "homo", None, deg,
                                           cfg, k)
                inits.append(edges0)
                seeds.append(seed)
                rs_vec.append(r_eff)
        warms = [_pack_warm(n, e)
                 for e in _anneal_edges(n, inits, seeds, None, cfg)]

        spec = make_homo_spec(n, max(rs_vec), cfg.admm)
        states = [init_state(spec, jnp.asarray(g0), lam0)
                  for g0, _, lam0 in warms]
        b = len(states)
        if self.policy.pad_pow2:
            target = 1 << (b - 1).bit_length()
            pad_rs = list(rs_vec) + [rs_vec[-1]] * (target - b)
            states = states + [states[-1]] * (target - b)
        else:
            pad_rs = rs_vec
        batched = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        results = solve_sweep_spec(spec, np.asarray(pad_rs), batched,
                                   cfg.admm)[:b]

        out: list[Topology | None] = []
        for i, req in enumerate(reqs):
            sl = slice(i * n_restarts, (i + 1) * n_restarts)
            r_eff = rs_vec[i * n_restarts]
            meta = {"scenario": "homo", "r": r_eff}
            items, sources = _candidate_items(
                n, r_eff, warms[sl], results[sl], None, cfg, meta,
                use_z=False)
            topos = _finalize_batch(n, items, cfg, None)
            best, best_val, _ = _pick_best(n, items, topos, sources)
            if best is not None:
                best.meta["r_asym"] = best_val
                best.meta["bucketed"] = True
            out.append(best)
        return out
