"""Serving runtime.

``make_serve_step`` builds the jit-able one-token decode step the decode
input shapes (decode_32k, long_500k) lower in the dry-run: ONE new token per
request against a KV/SSM cache of ``seq_len`` past positions.

``ServingEngine`` is the host-side loop: admit a batch of prompts, prefill,
then decode greedily/with temperature until max_new_tokens — the end-to-end
"serve a small model with batched requests" example builds on it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import transformer

__all__ = ["ServeConfig", "DecodeState", "make_serve_step", "greedy_sample",
           "ServingEngine"]


@dataclass(frozen=True)
class ServeConfig:
    batch_size: int
    cache_len: int                 # past-context capacity (= shape.seq_len)
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 → greedy
    long_context: bool = False     # ring/SWA caches + SSM state path
    use_kernel: bool = False       # Pallas decode_attention


class DecodeState(NamedTuple):
    tokens: jnp.ndarray            # (B, 1) last emitted token
    caches: Any                    # transformer.Caches
    pos: jnp.ndarray               # scalar int32 absolute position
    rng: jnp.ndarray
    done: jnp.ndarray              # (B,) bool — hit EOS


def greedy_sample(logits: jnp.ndarray, rng, temperature: float):
    """logits (B, 1, V) → (B, 1) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    g = -jnp.log(-jnp.log(jax.random.uniform(rng, logits[:, -1].shape) + 1e-9) + 1e-9)
    return jnp.argmax(logits[:, -1] / temperature + g, axis=-1)[:, None].astype(jnp.int32)


def make_serve_step(cfg, scfg: ServeConfig, *, eos_id: int = 0, donate: bool = True):
    """One-token decode step: (DecodeState) → DecodeState. jit'd with cache
    donation so the KV cache updates in place (the serving memory invariant)."""

    def step(state: DecodeState) -> DecodeState:
        logits, caches = transformer.decode_step(
            cfg_params_holder["params"], cfg, state.tokens, state.caches, state.pos,
            long_context=scfg.long_context, use_kernel=scfg.use_kernel)
        rng, sub = jax.random.split(state.rng)
        nxt = greedy_sample(logits, sub, scfg.temperature)
        done = state.done | (nxt[:, 0] == eos_id)
        nxt = jnp.where(done[:, None], jnp.full_like(nxt, eos_id), nxt)
        return DecodeState(nxt, caches, state.pos + 1, rng, done)

    # Params are closed over (weights are servable constants); the holder lets
    # the engine swap checkpoints without retracing.
    cfg_params_holder: dict = {}

    def bind(params):
        cfg_params_holder["params"] = params
        return jax.jit(step, donate_argnums=(0,) if donate else ())

    return bind


def make_functional_serve_step(cfg, scfg: ServeConfig, *, eos_id: int = 0):
    """(params, state) → state, params as a traced argument — the form the
    dry-run lowers (params are sharded inputs there, not constants)."""

    def step(params, state: DecodeState) -> DecodeState:
        logits, caches = transformer.decode_step(
            params, cfg, state.tokens, state.caches, state.pos,
            long_context=scfg.long_context, use_kernel=scfg.use_kernel)
        if scfg.temperature > 0.0:
            rng, sub = jax.random.split(state.rng)
            nxt = greedy_sample(logits, sub, scfg.temperature)
        else:  # greedy — keep rng inert (lowers with a raw uint32 stand-in)
            rng = state.rng
            nxt = greedy_sample(logits, rng, 0.0)
        done = state.done | (nxt[:, 0] == eos_id)
        nxt = jnp.where(done[:, None], jnp.full_like(nxt, eos_id), nxt)
        return DecodeState(nxt, caches, state.pos + 1, rng, done)

    return step


class ServingEngine:
    """Host loop: admit → prefill → decode until done/max_new_tokens."""

    def __init__(self, cfg, params, scfg: ServeConfig, *, eos_id: int = 0):
        self.cfg, self.scfg, self.eos_id = cfg, scfg, eos_id
        self.params = params
        self._step = make_serve_step(cfg, scfg, eos_id=eos_id, donate=False)(params)
        self._prefill = jax.jit(
            lambda p, batch: transformer.prefill(p, cfg, batch,
                                                 cache_cap=scfg.cache_len,
                                                 long_context=scfg.long_context))

    def generate(self, prompts: np.ndarray, extra_inputs: dict | None = None,
                 seed: int = 0) -> np.ndarray:
        """prompts: (B, S) int32 (right-aligned, no padding support needed for
        the fixed-shape engine). Returns (B, max_new_tokens) int32."""
        B, S = prompts.shape
        if B != self.scfg.batch_size:
            raise ValueError(
                f"prompts batch shape {(B, S)} does not match the engine's "
                f"fixed batch_size={self.scfg.batch_size}; this engine "
                f"compiles one (batch_size, S) shape — pad or re-batch the "
                f"prompts, or build a ServeConfig with batch_size={B}")
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            batch.update(extra_inputs)
        logits, caches = self._prefill(self.params, batch)
        rng = jax.random.PRNGKey(seed)
        first = greedy_sample(logits, rng, self.scfg.temperature)
        pos = S + (self.cfg.frontend_tokens if self.cfg.arch_type == "vlm" else 0)
        state = DecodeState(first, caches, jnp.asarray(pos, jnp.int32), rng,
                            jnp.zeros((B,), bool))
        out = [np.asarray(first[:, 0])]
        for _ in range(self.scfg.max_new_tokens - 1):
            state = self._step(state)
            out.append(np.asarray(state.tokens[:, 0]))
            if bool(state.done.all()):
                break
        return np.stack(out, axis=1)
