"""SGD+momentum (the paper's DSGD setting: lr 0.05, momentum 0.9, wd 1e-4)
and AdamW, as (init, update) pairs over parameter pytrees.

Optimizer state lives in NamedTuples of pytrees so it shards with the
parameters under pjit (state inherits each leaf's PartitionSpec).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["SGDState", "AdamWState", "OptState", "sgd_momentum", "adamw",
           "apply_updates", "global_norm", "clip_by_global_norm", "make_optimizer"]


class SGDState(NamedTuple):
    momentum: dict  # pytree like params
    step: jnp.ndarray


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    step: jnp.ndarray


OptState = SGDState | AdamWState


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), grads), g


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def sgd_momentum(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
                 momentum: float = 0.9, weight_decay: float = 1e-4,
                 nesterov: bool = False):
    """Paper §VI-B hyper-parameters by default. Returns (init, update).

    update(grads, state, params) -> (updates, new_state)
    """
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params) -> SGDState:
        return SGDState(jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                        jnp.zeros((), jnp.int32))

    def update(grads, state: SGDState, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m + g
            d = (g + momentum * m_new) if nesterov else m_new
            return -lr_t * d, m_new

        flat = jax.tree.map(upd, grads, state.momentum, params)
        updates = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
        m_new = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
        return updates, SGDState(m_new, step)

    return init, update


def adamw(lr: Callable[[jnp.ndarray], jnp.ndarray] | float, b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1):
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(jax.tree.map(zeros, params), jax.tree.map(zeros, params),
                          jnp.zeros((), jnp.int32))

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu_new = b1 * mu + (1 - b1) * g
            nu_new = b2 * nu + (1 - b2) * jnp.square(g)
            mhat = mu_new / c1
            nhat = nu_new / c2
            d = mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32)
            return -lr_t * d, mu_new, nu_new

        flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
        first = lambda t: t[0]
        is_t = lambda t: isinstance(t, tuple)
        updates = jax.tree.map(first, flat, is_leaf=is_t)
        mu_new = jax.tree.map(lambda t: t[1], flat, is_leaf=is_t)
        nu_new = jax.tree.map(lambda t: t[2], flat, is_leaf=is_t)
        return updates, AdamWState(mu_new, nu_new, step)

    return init, update


def make_optimizer(name: str, lr, **kw):
    """Registry used by the launcher (--optimizer sgd|adamw)."""
    if name == "sgd":
        return sgd_momentum(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise KeyError(f"unknown optimizer {name!r}")
