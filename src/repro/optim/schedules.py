"""Learning-rate schedules as step -> lr callables (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant_schedule", "linear_warmup", "cosine_schedule", "warmup_cosine"]


def constant_schedule(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)
    return fn


def linear_warmup(lr: float, warmup_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return lr * jnp.minimum(1.0, s / max(warmup_steps, 1))
    return fn


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        s = jnp.minimum(step.astype(jnp.float32), total_steps)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * s / max(total_steps, 1)))
        return lr * (final_frac + (1.0 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        decay = final_frac + (1.0 - final_frac) * cos
        return lr * jnp.where(s < warmup_steps, warm, decay)
    return fn
