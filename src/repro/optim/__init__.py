"""Optimizers + LR schedules (pure JAX, optax-free — offline container)."""
from .optimizers import (
    AdamWState,
    OptState,
    SGDState,
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
    sgd_momentum,
)
from .schedules import constant_schedule, cosine_schedule, linear_warmup, warmup_cosine

__all__ = [
    "AdamWState", "OptState", "SGDState", "adamw", "apply_updates",
    "clip_by_global_norm", "global_norm", "make_optimizer", "sgd_momentum",
    "constant_schedule", "cosine_schedule", "linear_warmup", "warmup_cosine",
]
