"""Pytree checkpoints as flat .npz archives.

Leaves are addressed by their pytree key-path string, so any nest of
dict/NamedTuple/tuple round-trips without pickling (safe + portable). The
tree *structure* is restored from a template (the freshly-initialized
state), which is how production JAX trainers (orbax restore w/ item arg)
behave.
"""
from __future__ import annotations

import os
import re
import tempfile

import numpy as np

import jax

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree, step: int | None = None) -> None:
    """Atomic write (tmp + rename) of a pytree to ``path`` (.npz)."""
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str, template):
    """Restore a pytree saved by save_checkpoint into ``template``'s structure.
    Returns (tree, step|None)."""
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}
    step = int(data.pop("__step__")) if "__step__" in data else None
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(template)
    paths, treedef = leaves_with_paths[0], leaves_with_paths[1]
    new_leaves = []
    for path_k, leaf in paths:
        key = jax.tree_util.keystr(path_k)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if arr.shape != np.shape(leaf):
            raise ValueError(f"shape mismatch at {key}: ckpt {arr.shape} vs template {np.shape(leaf)}")
        new_leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


class CheckpointManager:
    """Rolling checkpoints: ckpt_<step>.npz under a directory, keep last k."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.directory):
            m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, tree, step: int) -> str:
        path = os.path.join(self.directory, f"ckpt_{step}.npz")
        save_checkpoint(path, tree, step=step)
        for s in self._steps()[:-self.keep]:
            os.unlink(os.path.join(self.directory, f"ckpt_{s}.npz"))
        return path

    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.directory, f"ckpt_{step}.npz")
        return load_checkpoint(path, template)
