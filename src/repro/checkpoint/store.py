"""Pytree checkpoints as flat .npz archives.

Leaves are addressed by their pytree key-path string, so any nest of
dict/NamedTuple/tuple round-trips without pickling (safe + portable). The
tree *structure* is restored from a template (the freshly-initialized
state), which is how production JAX trainers (orbax restore w/ item arg)
behave.

Beyond the model/optimizer pytree, a checkpoint can carry an ``extra``
payload of named numpy arrays (``__extra__<name>`` keys in the archive):
PRNG keys, data-stream positions, drift-detector baselines, elastic
membership state — everything a crash-safe ``--resume`` needs to reproduce
the uninterrupted run bit-exactly (DESIGN.md §16). Extras are restored
*without* template shape-matching, because their shapes legitimately change
across a run (a re-optimized topology has a different edge count).

Failure handling (the restore path of a run that just crashed): a truncated
or unreadable archive, or one whose leaf set no longer matches the template,
raises :class:`CheckpointError`; ``CheckpointManager.restore`` catches it,
emits a :class:`CheckpointCorruptionWarning` naming the file and the cause,
and falls back to the newest older checkpoint that loads cleanly.
"""
from __future__ import annotations

import os
import re
import tempfile
import warnings
import zipfile

import numpy as np

import jax

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager",
           "CheckpointError", "CheckpointCorruptionWarning"]

_EXTRA_PREFIX = "__extra__"


class CheckpointError(ValueError):
    """A checkpoint file that cannot be restored: unreadable/truncated
    archive, or a leaf set that mismatches the restore template."""


class CheckpointCorruptionWarning(UserWarning):
    """Emitted when ``CheckpointManager.restore`` skips an unusable
    checkpoint and falls back to an older one."""


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree, step: int | None = None,
                    extra: dict[str, np.ndarray] | None = None) -> None:
    """Atomic write (tmp + rename) of a pytree to ``path`` (.npz).

    ``extra``: named side-state arrays stored under reserved
    ``__extra__<name>`` keys (restored shape-free by ``load_checkpoint``)."""
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    for k, v in (extra or {}).items():
        flat[_EXTRA_PREFIX + k] = np.asarray(v)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str, template, *, with_extra: bool = False):
    """Restore a pytree saved by save_checkpoint into ``template``'s structure.

    Returns ``(tree, step|None)``, or ``(tree, step|None, extras)`` when
    ``with_extra`` is True. Raises :class:`CheckpointError` for a truncated/
    unreadable archive, a leaf set that mismatches the template (missing OR
    unexpected leaves — a template drift is as unrestorable as a truncation),
    or a per-leaf shape mismatch."""
    try:
        with np.load(path, allow_pickle=False) as z:
            data = {k: z[k] for k in z.files}
    except (OSError, EOFError, ValueError, zipfile.BadZipFile) as exc:
        raise CheckpointError(
            f"unreadable checkpoint {path!r}: {type(exc).__name__}: {exc}"
        ) from exc
    step = int(data.pop("__step__")) if "__step__" in data else None
    extras = {k[len(_EXTRA_PREFIX):]: data.pop(k)
              for k in list(data) if k.startswith(_EXTRA_PREFIX)}
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    tmpl_keys = [jax.tree_util.keystr(p) for p, _ in paths]
    missing = [k for k in tmpl_keys if k not in data]
    unexpected = [k for k in data if k not in set(tmpl_keys)]
    if missing or unexpected:
        raise CheckpointError(
            f"checkpoint {path!r} leaf set mismatches the template: "
            f"missing={missing or '[]'} unexpected={unexpected or '[]'}")
    new_leaves = []
    for (path_k, leaf), key in zip(paths, tmpl_keys):
        arr = data[key]
        if arr.shape != np.shape(leaf):
            raise CheckpointError(f"shape mismatch at {key} in {path!r}: "
                                  f"ckpt {arr.shape} vs template {np.shape(leaf)}")
        new_leaves.append(arr.astype(np.asarray(leaf).dtype))
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return (tree, step, extras) if with_extra else (tree, step)


class CheckpointManager:
    """Rolling checkpoints: ckpt_<step>.npz under a directory, keep last k."""

    def __init__(self, directory: str, keep: int = 3):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.directory):
            m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step}.npz")

    def save(self, tree, step: int,
             extra: dict[str, np.ndarray] | None = None) -> str:
        path = self._path(step)
        save_checkpoint(path, tree, step=step, extra=extra)
        for s in self._steps()[:-self.keep]:
            if s != step:            # never prune what we just wrote
                os.unlink(self._path(s))
        return path

    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None, *,
                with_extra: bool = False):
        """Restore the checkpoint at ``step`` (raises on a bad file — an
        explicit step is an explicit ask), or the newest restorable one:
        corrupt/truncated/mismatched archives are skipped with a
        :class:`CheckpointCorruptionWarning` and the next older checkpoint
        is tried. Returns ``(None, None[, {}])`` when nothing restores."""
        none = (None, None, {}) if with_extra else (None, None)
        if step is not None:
            return load_checkpoint(self._path(step), template,
                                   with_extra=with_extra)
        for s in reversed(self._steps()):
            try:
                return load_checkpoint(self._path(s), template,
                                       with_extra=with_extra)
            except CheckpointError as exc:
                warnings.warn(
                    f"skipping unusable checkpoint {self._path(s)!r} ({exc}); "
                    "falling back to the previous one",
                    CheckpointCorruptionWarning, stacklevel=2)
        return none
