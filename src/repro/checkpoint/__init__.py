"""Checkpointing (npz-based — offline container has no orbax/msgpack)."""
from .store import CheckpointManager, load_checkpoint, save_checkpoint

__all__ = ["CheckpointManager", "load_checkpoint", "save_checkpoint"]
