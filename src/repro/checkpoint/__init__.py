"""Checkpointing (npz-based — offline container has no orbax/msgpack)."""
from .store import (
    CheckpointCorruptionWarning,
    CheckpointError,
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)

__all__ = ["CheckpointManager", "load_checkpoint", "save_checkpoint",
           "CheckpointError", "CheckpointCorruptionWarning"]
