"""Pallas TPU kernels for the ADMM constraint-operator hot pair (§V-C).

Every ``A_op``/``AT_op`` matvec inside the X-step CG spends its time in two
index-shuffling primitives:

  - ``L(g)``: m = n(n−1)/2 edge weights scattered into an n×n Laplacian —
    the naive lowering is 4 scatter-adds (two off-diagonal, two diagonal),
    each a serialized HBM read-modify-write pass over the matrix.
  - ``⟨∂L/∂g_l, P⟩``: 4 gathers of m elements each from an n×n dual block.

The kernels fuse each group into ONE pass over the output:

  - ``edge_laplacian_2d`` exploits that the engine's candidate-edge list is
    the *complete* lexicographic list (all pairs i < j), so the packed edge
    index of entry (a, b) is analytic: l = lo·n − lo(lo+1)/2 + (hi−lo−1)
    with lo = min(a,b), hi = max(a,b). Each grid step materializes one
    (SUBLANE, n_pad) row-band of L directly from g — off-diagonals are a
    gather, the diagonal is the row-sum reduction of the same tile — so the
    Laplacian is written exactly once, with no read-modify-write.
  - ``edge_quadform_2d`` streams (SUBLANE, LANE) tiles of the packed edge
    index arrays (ei, ej) and gathers the 4 matrix entries per edge from a
    VMEM-resident P, writing the packed result once.

TPU adaptation notes (mirroring ``gossip_mix``):
  - tiles are VPU-aligned (last dim multiple of 128, sublane multiple of 8);
    wrappers in ``ops.py`` pad n and m up and slice the result back.
  - P / the L row-band stay whole in VMEM: n ≤ ~1500 keeps n² f32 within
    the ~16 MB budget, far above the paper's regime.
  - the per-tile dynamic gathers lower through Mosaic's gather support on
    recent toolchains; ``interpret=True`` (the repo default on CPU) is the
    reference execution mode, as for the other kernels in this tree.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128     # last-dim tile (multiple of 128)
SUBLANE = 8    # second-to-last dim tile


def _edge_laplacian_kernel(n, g_ref, out_ref):
    """g: (m_pad,); out: one (SUBLANE, n_pad) row-band of L."""
    band = pl.program_id(0)
    cols = out_ref.shape[1]
    a = band * SUBLANE + jax.lax.broadcasted_iota(jnp.int32, (SUBLANE, cols), 0)
    b = jax.lax.broadcasted_iota(jnp.int32, (SUBLANE, cols), 1)
    lo = jnp.minimum(a, b)
    hi = jnp.maximum(a, b)
    l = lo * n - (lo * (lo + 1)) // 2 + (hi - lo - 1)
    valid = (a < n) & (b < n) & (a != b)
    g = g_ref[...]
    G = jnp.where(valid, g[jnp.where(valid, l, 0)], jnp.zeros((), g.dtype))
    deg = jnp.sum(G, axis=1, keepdims=True)  # row degree: Σ_b g_{ab}
    out_ref[...] = jnp.where(a == b, deg, jnp.zeros((), g.dtype)) - G


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def edge_laplacian_2d(g, n: int, *, interpret: bool = True):
    """g: (m_pad,) packed complete-graph edge weights; returns L (r_pad, c_pad)
    with r_pad = ceil(n/SUBLANE)·SUBLANE, c_pad = ceil(n/LANE)·LANE."""
    r_pad = -(-n // SUBLANE) * SUBLANE
    c_pad = -(-n // LANE) * LANE
    m_pad = g.shape[0]
    return pl.pallas_call(
        functools.partial(_edge_laplacian_kernel, n),
        grid=(r_pad // SUBLANE,),
        in_specs=[pl.BlockSpec((m_pad,), lambda i: (0,))],
        out_specs=pl.BlockSpec((SUBLANE, c_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, c_pad), g.dtype),
        interpret=interpret,
    )(g)


def _edge_quadform_kernel(P_ref, ei_ref, ej_ref, out_ref):
    """P: (n_pad, n_pad); ei/ej/out: (SUBLANE, LANE) packed edge tiles."""
    P = P_ref[...]
    ii = ei_ref[...]
    jj = ej_ref[...]
    out_ref[...] = P[ii, ii] + P[jj, jj] - P[ii, jj] - P[jj, ii]


@functools.partial(jax.jit, static_argnames=("interpret",))
def edge_quadform_2d(P, ei, ej, *, interpret: bool = True):
    """P: (n_pad, n_pad); ei/ej: (R, LANE) int32 edge endpoints (R % SUBLANE
    == 0, padding entries 0 — they read P[0,0] terms that cancel to 0)."""
    R, L = ei.shape
    assert L == LANE and R % SUBLANE == 0, (R, L)
    nr, nc = P.shape
    return pl.pallas_call(
        _edge_quadform_kernel,
        grid=(R // SUBLANE,),
        in_specs=[
            pl.BlockSpec((nr, nc), lambda i: (0, 0)),
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANE), P.dtype),
        interpret=interpret,
    )(P, ei, ej)
