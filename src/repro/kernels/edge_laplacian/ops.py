"""jit'd public wrappers: pad the packed edge vector / the n×n block to the
kernel tiling, dispatch, slice the result back to logical shape."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import LANE, SUBLANE, edge_laplacian_2d, edge_quadform_2d

_TILE = LANE * SUBLANE


def _pad_to(x, size):
    return jnp.pad(x, (0, size - x.shape[0]))


@functools.partial(jax.jit, static_argnames=("n", "use_kernel", "interpret"))
def edge_laplacian(g, ei, ej, n: int, *, use_kernel: bool = True,
                   interpret: bool = True):
    """Laplacian L(g) of the complete candidate-edge list.

    g: (m,) edge weights in ``all_edges(n)`` (lexicographic) order; ei/ej:
    (m,) edge endpoints — used by the oracle path (the kernel derives the
    packed index analytically, which *requires* the complete lexicographic
    edge list; the wrapper asserts m = n(n−1)/2).
    """
    m = g.shape[0]
    assert m == n * (n - 1) // 2, (
        f"edge_laplacian kernel needs the complete edge list: m={m}, n={n}")
    if not use_kernel or n < 2:
        return ref.edge_laplacian(g, ei, ej, n)
    m_pad = max(-(-m // LANE) * LANE, LANE)
    L = edge_laplacian_2d(_pad_to(g, m_pad), n, interpret=interpret)
    return L[:n, :n]


def edge_laplacian_window(g_loc, lidx, offset):
    """Per-device additive Laplacian contribution of one packed-edge window
    (see ``ref.edge_laplacian_window``). Pure gather — no Pallas variant:
    the 2-D kernel derives the packed index analytically, which requires
    the complete lexicographic edge list, while the window form is what the
    edge-partitioned ADMM (``core.shard``) runs per device before the
    cross-device ``psum``. Not jit-wrapped: it is always called inside an
    already-traced ``shard_map``/``jit`` region."""
    return ref.edge_laplacian_window(g_loc, lidx, offset)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def edge_quadform(P, ei, ej, *, use_kernel: bool = True,
                  interpret: bool = True):
    """Per-edge quadratic forms ⟨∂L/∂g_l, P⟩ = P_ii + P_jj − P_ij − P_ji.

    P: (n, n); ei/ej: (m,) edge endpoints (any edge list — the gather is
    index-driven). Returns (m,) in edge order.
    """
    m = ei.shape[0]
    if not use_kernel or m == 0:
        return ref.edge_quadform(P, ei, ej)
    n = P.shape[0]
    r_pad = -(-n // SUBLANE) * SUBLANE
    c_pad = -(-n // LANE) * LANE
    Pp = jnp.pad(P, ((0, r_pad - n), (0, c_pad - n)))
    m_pad = max(-(-m // _TILE) * _TILE, _TILE)
    R = m_pad // LANE
    ei2 = _pad_to(ei.astype(jnp.int32), m_pad).reshape(R, LANE)
    ej2 = _pad_to(ej.astype(jnp.int32), m_pad).reshape(R, LANE)
    q = edge_quadform_2d(Pp, ei2, ej2, interpret=interpret)
    return q.reshape(-1)[:m]
