"""Pure-jnp oracles for the edge_laplacian kernel pair."""
from __future__ import annotations

import jax.numpy as jnp


def edge_laplacian(g, ei, ej, n: int):
    """L(g) = A Diag(g) Aᵀ (Eq. 5) by scatter-add: for each candidate edge
    l = {i, j}, add g_l to (i,i), (j,j) and −g_l to (i,j), (j,i)."""
    L = jnp.zeros((n, n), dtype=g.dtype)
    L = L.at[ei, ej].add(-g).at[ej, ei].add(-g)
    L = L.at[ei, ei].add(g).at[ej, ej].add(g)
    return L


def edge_quadform(P, ei, ej):
    """⟨∂L/∂g_l, P⟩ = P_ii + P_jj − P_ij − P_ji per edge l = {i, j}."""
    return P[ei, ei] + P[ej, ej] - P[ei, ej] - P[ej, ei]
