"""Pure-jnp oracles for the edge_laplacian kernel pair."""
from __future__ import annotations

import jax.numpy as jnp


def edge_laplacian(g, ei, ej, n: int):
    """L(g) = A Diag(g) Aᵀ (Eq. 5) by scatter-add: for each candidate edge
    l = {i, j}, add g_l to (i,i), (j,j) and −g_l to (i,j), (j,i)."""
    L = jnp.zeros((n, n), dtype=g.dtype)
    L = L.at[ei, ej].add(-g).at[ej, ei].add(-g)
    L = L.at[ei, ei].add(g).at[ej, ej].add(g)
    return L


def edge_quadform(P, ei, ej):
    """⟨∂L/∂g_l, P⟩ = P_ii + P_jj − P_ij − P_ji per edge l = {i, j}."""
    return P[ei, ei] + P[ej, ej] - P[ei, ej] - P[ej, ei]


def edge_laplacian_window(g_loc, lidx, offset):
    """Additive Laplacian contribution of one packed-edge window.

    The edge-partitioned ADMM layer (``core.shard``) gives each device a
    contiguous block ``[offset, offset + m_loc)`` of the packed edge-weight
    vector. Remapping the global packed-index map ``lidx`` into the window
    (out-of-window entries hit the appended zero slot, like the diagonal
    does in the full-vector gather) assembles that device's additive
    contribution to L(g); a ``psum`` over the mesh axis completes it.
    """
    m_loc = g_loc.shape[0]
    idx = lidx - offset
    valid = (idx >= 0) & (idx < m_loc)
    g_ext = jnp.concatenate([g_loc, jnp.zeros(1, dtype=g_loc.dtype)])
    G = g_ext[jnp.where(valid, idx, m_loc)]
    return jnp.diag(jnp.sum(G, axis=1)) - G
