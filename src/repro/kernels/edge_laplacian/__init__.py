"""edge_laplacian — fused Pallas kernels for the ADMM constraint matvec.

``edge_laplacian``: candidate-edge weights g → n×n Laplacian L(g) (the
scatter-heavy half of every ``A_op``); ``edge_quadform``: n×n dual block →
per-edge quadratic forms ⟨∂L/∂g_l, P⟩ (the gather-heavy half of ``AT_op``).
Layout follows ``kernels/gossip_mix``: ``ref.py`` pure-jnp oracle,
``kernel.py`` the Pallas bodies, ``ops.py`` jitted public wrappers with an
interpret-mode default.
"""
from . import kernel, ops, ref  # noqa: F401

__all__ = ["kernel", "ops", "ref"]
