"""Pallas TPU kernel for the gossip mixing hot spot.

The paper's parameter synchronization (Eq. 1) on each worker is
``x_i ← W_ii·x_i + Σ_{j∈N_i} W_ij·x_j``. After the ppermute schedule lands
the ``deg`` neighbor copies in HBM, the naive lowering is ``deg`` separate
HBM-round-trip axpys over the flattened parameter vector (~(deg+1)·2·|params|
bytes of HBM traffic). This kernel fuses the weighted accumulation into ONE
pass: each grid step streams a VMEM tile of the self vector plus the matching
tile of every neighbor buffer and writes the mixed tile once —
(deg+2)·|params| bytes total, the streaming minimum.

TPU adaptation notes (vs a GPU axpy chain):
  - tile = (8, 1024) f32 — VPU lane-aligned (last dim multiple of 128,
    sublane multiple of 8); the flattened parameter vector is reshaped to
    (R, 1024) by the ops wrapper.
  - neighbors arrive stacked as (deg, R, 1024) so a single BlockSpec covers
    all neighbor tiles; ``deg`` is a compile-time constant of the topology,
    so the accumulation unrolls into VPU fmas.
  - per-edge weights (one row of the BA-Topo W matrix) are a tiny vector,
    broadcast to every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024     # last-dim tile (multiple of 128)
SUBLANE = 8     # second-to-last dim tile


def _gossip_mix_batched_kernel(w_ref, self_ref, nbrs_ref, out_ref):
    """w: (1, deg+1); self/out: (1, SUBLANE, LANE); nbrs: (1, deg, SUBLANE, LANE).

    One grid step = one worker's tile. The worker axis is a grid dimension,
    so the WHOLE stacked (n, ...) parameter tensor is mixed by a single
    ``pallas_call`` — n× fewer dispatches than the per-row path, and ``deg``
    is the topology's max degree (padded rows carry weight 0).
    """
    deg = nbrs_ref.shape[1]
    acc = self_ref[0].astype(jnp.float32) * w_ref[0, 0]
    for d in range(deg):  # static max degree — unrolls to VPU fmas
        acc = acc + nbrs_ref[0, d].astype(jnp.float32) * w_ref[0, d + 1]
    out_ref[0] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gossip_mix_batched_2d(x, nbrs, weights, *, interpret: bool = True):
    """All-workers mix: x (n, R, LANE); nbrs (n, deg, R, LANE) — neighbor
    copies pre-gathered per worker; weights (n, deg+1), w[:, 0] = self.

    Grid is (n, R // SUBLANE): one dispatch covers every worker row."""
    n, R, L = x.shape
    deg = nbrs.shape[1]
    assert L == LANE and R % SUBLANE == 0, (n, R, L)
    return pl.pallas_call(
        _gossip_mix_batched_kernel,
        grid=(n, R // SUBLANE),
        in_specs=[
            pl.BlockSpec((1, deg + 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, SUBLANE, LANE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, deg, SUBLANE, LANE), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, SUBLANE, LANE), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, R, L), x.dtype),
        interpret=interpret,
    )(weights.astype(jnp.float32), x, nbrs)


def _gossip_mix_kernel(w_ref, self_ref, nbrs_ref, out_ref):
    """w: (deg+1,); self/out: (SUBLANE, LANE); nbrs: (deg, SUBLANE, LANE)."""
    deg = nbrs_ref.shape[0]
    acc = self_ref[...].astype(jnp.float32) * w_ref[0]
    for d in range(deg):  # static deg — unrolls to VPU fmas on the tile
        acc = acc + nbrs_ref[d].astype(jnp.float32) * w_ref[d + 1]
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gossip_mix_2d(x, nbrs, weights, *, interpret: bool = True):
    """x: (R, LANE); nbrs: (deg, R, LANE); weights: (deg+1,), w[0] = self."""
    R, L = x.shape
    deg = nbrs.shape[0]
    assert L == LANE and R % SUBLANE == 0, (R, L)
    return pl.pallas_call(
        _gossip_mix_kernel,
        grid=(R // SUBLANE,),
        in_specs=[
            pl.BlockSpec((deg + 1,), lambda i: (0,)),
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
            pl.BlockSpec((deg, SUBLANE, LANE), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, L), x.dtype),
        interpret=interpret,
    )(weights.astype(jnp.float32), x, nbrs)
