"""Pure-jnp oracle for the gossip mixing kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gossip_mix(x, nbrs, weights):
    """x: any shape; nbrs: (deg,) + x.shape; weights: (deg+1,), w[0] = self.

    Returns w[0]·x + Σ_d w[d+1]·nbrs[d], accumulated in f32.
    """
    w = weights.astype(jnp.float32)
    acc = x.astype(jnp.float32) * w[0]
    acc = acc + jnp.tensordot(w[1:], nbrs.astype(jnp.float32), axes=(0, 0))
    return acc.astype(x.dtype)
