"""Pure-jnp oracle for the gossip mixing kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gossip_mix(x, nbrs, weights):
    """x: any shape; nbrs: (deg,) + x.shape; weights: (deg+1,), w[0] = self.

    Returns w[0]·x + Σ_d w[d+1]·nbrs[d], accumulated in f32.
    """
    w = weights.astype(jnp.float32)
    acc = x.astype(jnp.float32) * w[0]
    acc = acc + jnp.tensordot(w[1:], nbrs.astype(jnp.float32), axes=(0, 0))
    return acc.astype(x.dtype)


def gossip_mix_batched(x, nbr_idx, weights):
    """All workers at once: x (n, ...) stacked copies; nbr_idx (n, deg) padded
    neighbor row indices (pad = own row); weights (n, deg+1) with w[:, 0] the
    self weight and 0 in padded slots.

    Returns w[i,0]·x[i] + Σ_d w[i,d+1]·x[nbr_idx[i,d]] for every i, in f32.
    """
    w = weights.astype(jnp.float32)
    tail = (1,) * (x.ndim - 1)
    nbrs = x[nbr_idx].astype(jnp.float32)              # (n, deg) + x.shape[1:]
    acc = x.astype(jnp.float32) * w[:, 0].reshape((-1,) + tail)
    acc = acc + jnp.sum(nbrs * w[:, 1:].reshape(nbr_idx.shape + tail), axis=1)
    return acc.astype(x.dtype)
