"""jit'd public wrapper: flatten arbitrary parameter shapes to the kernel's
(R, 1024) tiling, pad the tail, dispatch, restore shape."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import LANE, SUBLANE, gossip_mix_2d, gossip_mix_batched_2d

_TILE = LANE * SUBLANE


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def gossip_mix(x, nbrs, weights, *, use_kernel: bool = True, interpret: bool = True):
    """Mix one worker's parameter tensor with its neighbors' copies.

    x: (...,) any shape; nbrs: (deg, ...) stacked neighbor copies;
    weights: (deg+1,) with w[0] the self weight (a BA-Topo W row).
    """
    if not use_kernel:
        return ref.gossip_mix(x, nbrs, weights)
    shape = x.shape
    deg = nbrs.shape[0]
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _TILE
    flat = jnp.pad(flat, (0, pad))
    nflat = jnp.pad(nbrs.reshape(deg, -1), ((0, 0), (0, pad)))
    R = flat.shape[0] // LANE
    out = gossip_mix_2d(flat.reshape(R, LANE), nflat.reshape(deg, R, LANE),
                        weights, interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def gossip_mix_batched(x, nbr_idx, weights, *, use_kernel: bool = True,
                       interpret: bool = True):
    """Mix ALL workers' copies of one parameter tensor in a single dispatch.

    x: (n, ...) stacked worker copies; nbr_idx: (n, deg) int32 padded
    neighbor row indices (pad = own row); weights: (n, deg+1) with
    weights[:, 0] the self weight and 0.0 in padded slots (see
    ``repro.dsgd.gossip.padded_neighbors``).

    Neighbor tiles are pre-gathered by one XLA gather (x[nbr_idx]); the
    weighted accumulation then runs as ONE ``pallas_call`` whose grid spans
    (workers × row tiles) — versus n dispatches (one per worker row, each
    recompiled per neighbor count) for the per-row path. Trace-safe: no
    host reads of the weight matrix.
    """
    if not use_kernel:
        return ref.gossip_mix_batched(x, nbr_idx, weights)
    n = x.shape[0]
    shape = x.shape
    flat = x.reshape(n, -1)
    m = flat.shape[1]
    pad = (-m) % _TILE
    flat = jnp.pad(flat, ((0, 0), (0, pad)))
    R = flat.shape[1] // LANE
    xr = flat.reshape(n, R, LANE)
    nbrs = xr[nbr_idx]                       # (n, deg, R, LANE), one gather
    out = gossip_mix_batched_2d(xr, nbrs, weights, interpret=interpret)
    return out.reshape(n, -1)[:, :m].reshape(shape)


def gossip_mix_tree(params, nbr_params, weights, *, use_kernel: bool = True,
                    interpret: bool = True):
    """Apply gossip_mix leaf-wise over a parameter pytree.

    params: pytree of arrays; nbr_params: same pytree with a leading (deg,)
    axis on every leaf; weights: (deg+1,).
    """
    return jax.tree.map(
        lambda x, nb: gossip_mix(x, nb, weights, use_kernel=use_kernel,
                                 interpret=interpret),
        params, nbr_params)
