"""Pure-jnp oracle for single-token GQA decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention(q, k, v, valid, *, attn_softcap: float = 0.0):
    """q: (B, Hq, hd); k/v: (B, C, Hkv, hd); valid: (C,). Returns (B, Hq, hd)."""
    B, Hq, hd = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bhgd,bchd->bhgc", qg, kf) / jnp.sqrt(hd).astype(jnp.float32)
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgc,bchd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)
