"""Pallas TPU flash-decode kernel: single-token GQA attention over a KV cache.

serve_step's hot spot is one query token attending to a C-position cache
(decode_32k: C = 32768). The HBM-bound term is streaming K and V once; the
kernel tiles the cache into (BLOCK_K, hd) VMEM blocks and keeps the online-
softmax running (m, l, acc) state in VMEM scratch across the KV grid axis.

TPU adaptation notes (vs a CUDA flash-decode):
  - grid = (B, Hkv, C/BLOCK_K); the GQA query group (group = Hq/Hkv rows)
    rides along the sublane dim so the q·kᵀ product is an MXU
    (group × hd) · (hd × BLOCK_K) matmul per step — the systolic array
    replaces CUDA's per-warp reduction tree; no warp-shuffle analogue needed.
  - BLOCK_K = 512 keys per step (512·hd·2 tensors ≈ 0.5 MiB VMEM at
    hd=128/f32 — far inside the ~16 MiB budget, deep enough to amortize the
    HBM→VMEM DMA).
  - the validity mask (ring-buffer occupancy) streams as an int32 block;
    attention-score softcap (gemma2) is applied in-kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_K = 512


def _decode_attn_kernel(q_ref, k_ref, v_ref, valid_ref, out_ref, m_ref, l_ref, acc_ref,
                        *, softcap: float, scale: float):
    """One (batch, kv-head, kv-block) grid step.

    q/out: (1, 1, group, hd); k/v: (1, 1, BLOCK_K, hd); valid: (1, BLOCK_K);
    scratch m/l: (group, 1), acc: (group, hd) — carried across grid axis 2.
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (g, hd)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (g, bk)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid_ref[0, :][None, :] > 0, s, -1e30)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                                  # (g, bk)
    corr = jnp.exp(m_prev - m_new)                          # (g, 1)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)                     # (bk, hd)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_new = acc_prev * corr + pv
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        out_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def decode_attention_kernel(q, k, v, valid, *, softcap: float = 0.0,
                            interpret: bool = True):
    """q: (B, Hq, hd); k/v: (B, C, Hkv, hd); valid: (C,) bool/int32.

    Returns (B, Hq, hd). C must be a multiple of BLOCK_K (ops.py pads)."""
    B, Hq, hd = q.shape
    C, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    assert C % BLOCK_K == 0, C
    qg = q.reshape(B, Hkv, group, hd)
    kt = k.transpose(0, 2, 1, 3)    # (B, Hkv, C, hd)
    vt = v.transpose(0, 2, 1, 3)
    valid2 = valid.astype(jnp.int32).reshape(1, C)
    scale = 1.0 / float(hd) ** 0.5

    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, softcap=softcap, scale=scale),
        grid=(B, Hkv, C // BLOCK_K),
        in_specs=[
            pl.BlockSpec((1, 1, group, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, BLOCK_K, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, BLOCK_K, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, BLOCK_K), lambda b, h, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, valid2)
    return out.reshape(B, Hq, hd)
