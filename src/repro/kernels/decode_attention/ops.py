"""jit'd public wrapper: pads the cache to BLOCK_K, handles softcap plumbing,
and exposes the same signature the model decode path uses."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import BLOCK_K, decode_attention_kernel


@functools.partial(jax.jit, static_argnames=("attn_softcap", "use_kernel", "interpret"))
def decode_attention(q, k, v, valid, *, attn_softcap: float = 0.0,
                     use_kernel: bool = True, interpret: bool = True):
    """q: (B, Hq, hd); k/v: (B, C, Hkv, hd); valid: (C,) — see ref.py."""
    if not use_kernel:
        return ref.decode_attention(q, k, v, valid, attn_softcap=attn_softcap)
    C = k.shape[1]
    pad = (-C) % BLOCK_K
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        valid = jnp.pad(valid.astype(jnp.int32), (0, pad))
    return decode_attention_kernel(q, k, v, valid, softcap=attn_softcap,
                                   interpret=interpret)
