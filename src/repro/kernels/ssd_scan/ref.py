"""Pure-jnp oracle for the SSD intra-chunk dual form (mirrors ssm.py)."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_intra_chunk(xc, dtc, la, Bc, Cc):
    """xc: (B,nc,Q,H,P); dtc/la: (B,nc,Q,H); Bc/Cc: (B,nc,Q,N).

    Returns (y_intra (B,nc,Q,H,P), chunk_states (B,nc,H,P,N)), both f32."""
    Q = xc.shape[2]
    xf = xc.astype(jnp.float32)
    dtf = dtc.astype(jnp.float32)
    laf = la.astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)
    Ldec = jnp.exp(laf[:, :, :, None, :] - laf[:, :, None, :, :])   # (B,nc,Qt,Qs,H)
    causal = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    Ldec = jnp.where(causal[None, None, :, :, None], Ldec, 0.0)
    CB = jnp.einsum("bctn,bcsn->bcts", Cf, Bf)
    y_intra = jnp.einsum("bcts,bctsh,bcsh,bcshp->bcthp", CB, Ldec, dtf, xf)
    decay_out = jnp.exp(laf[:, :, -1:, :] - laf)                    # (B,nc,Q,H)
    chunk_states = jnp.einsum("bcsh,bcsh,bcsn,bcshp->bchpn",
                              decay_out, dtf, Bf, xf)
    return y_intra, chunk_states
