"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk dual form.

The SSD chunked scan (arXiv:2405.21060 §6) splits the linear recurrence into
an intra-chunk *quadratic dual form* (this kernel — all MXU matmuls) and a
cheap inter-chunk state scan (left in jax.lax.scan). Per (batch, chunk, head)
grid cell, with chunk length Q, state N, head dim P:

  G    = C · Bᵀ                        (Q×N)·(N×Q)  MXU
  M    = G ⊙ exp(la_t − la_s) ⊙ dt_s   causal-masked decay
  y    = M · x                          (Q×Q)·(Q×P)  MXU
  st   = (B ⊙ exp(la_Q − la) ⊙ dt)ᵀ·x  (N×Q)·(Q×P)  MXU → outgoing state

TPU adaptation notes (vs the paper's Triton kernel):
  - one grid cell = one head's whole chunk; Q=256, N=128, P=64 keeps every
    operand MXU-shaped (≥128 on contracting dims where possible) and the
    VMEM working set at ~Q² + 2·Q·N + 2·Q·P floats ≈ 0.5 MiB.
  - the decay matrix is built in-VMEM from the la cumsum (computed once
    outside) — exp(la_t − la_s) ≤ 1 under causal masking since la is
    non-increasing, so no extra max-subtraction is needed.
  - B/C blocks are shared across heads (G=1 groups): the (b, c, :) BlockSpec
    re-streams them per head, trading a little DMA for zero layout shuffles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(x_ref, dt_ref, la_ref, b_ref, c_ref, y_ref, st_ref):
    """x: (1,1,Q,1,P); dt/la: (1,1,Q,1); b/c: (1,1,Q,N);
    y: (1,1,Q,1,P); st: (1,1,1,P,N)."""
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)         # (Q,)
    la = la_ref[0, 0, :, 0].astype(jnp.float32)         # (Q,)
    B = b_ref[0, 0].astype(jnp.float32)                 # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)                 # (Q, N)
    Q = x.shape[0]

    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)      # (Q, Q)
    decay = jnp.exp(la[:, None] - la[None, :])                       # (Q_t, Q_s)
    causal = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.where(causal, G * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)      # (Q, P)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    decay_out = jnp.exp(la[-1] - la) * dt                            # (Q,)
    Bw = B * decay_out[:, None]                                      # (Q, N)
    st = jax.lax.dot_general(x, Bw, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)     # (P, N)
    st_ref[0, 0, 0] = st.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_kernel(xc, dtc, la, Bc, Cc, *, interpret: bool = True):
    """xc: (B,nc,Q,H,P); dtc/la: (B,nc,Q,H); Bc/Cc: (B,nc,Q,N).

    Returns (y_intra (B,nc,Q,H,P) f32, chunk_states (B,nc,H,P,N) f32)."""
    Bsz, nc, Q, H, P = xc.shape
    N = Bc.shape[-1]
    grid = (Bsz * nc, H)
    xg = xc.reshape(Bsz * nc, Q, H, P)[:, None]          # (BC,1,Q,H,P)
    dtg = dtc.reshape(Bsz * nc, Q, H)[:, None]
    lag = la.reshape(Bsz * nc, Q, H)[:, None]
    Bg = Bc.reshape(Bsz * nc, Q, N)[:, None]
    Cg = Cc.reshape(Bsz * nc, Q, N)[:, None]

    y, st = pl.pallas_call(
        _ssd_intra_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda bc, h: (bc, 0, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda bc, h: (bc, 0, 0, h)),
            pl.BlockSpec((1, 1, Q, 1), lambda bc, h: (bc, 0, 0, h)),
            pl.BlockSpec((1, 1, Q, N), lambda bc, h: (bc, 0, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bc, h: (bc, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda bc, h: (bc, 0, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda bc, h: (bc, 0, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz * nc, 1, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz * nc, 1, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xg, dtg, lag, Bg, Cg)
    return (y.reshape(Bsz, nc, Q, H, P), st.reshape(Bsz, nc, H, P, N))
