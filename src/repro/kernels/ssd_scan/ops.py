"""jit'd public wrapper used by repro.models.ssm (use_kernel=True path)."""
from __future__ import annotations

import functools

import jax

from . import ref
from .kernel import ssd_intra_chunk_kernel


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def ssd_intra_chunk(xc, dtc, la, Bc, Cc, *, use_kernel: bool = True,
                    interpret: bool = True):
    if not use_kernel:
        return ref.ssd_intra_chunk(xc, dtc, la, Bc, Cc)
    return ssd_intra_chunk_kernel(xc, dtc, la, Bc, Cc, interpret=interpret)
