"""Pure-jnp oracle for the hop_bfs kernel."""
from __future__ import annotations

import jax.numpy as jnp


def hop_step(reach, adj):
    """One matmul-BFS hop: ``new = reach ∨ (reach @ Adj)``, plus the total
    number of reached (src, dst) pairs in ``new``.

    ``reach``/``adj``: (n, n) bool. The boolean matmul runs as f32 on the
    MXU-friendly path — counts stay ≤ n, exact in f32 for any relevant n.
    Returns ``(new_reach: bool (n, n), count: int32 scalar)``.
    """
    prod = jnp.dot(reach.astype(jnp.float32), adj.astype(jnp.float32))
    new = reach | (prod > 0)
    return new, jnp.sum(new, dtype=jnp.int32)
