"""hop_bfs — fused reach-expansion step for matmul-BFS (warm-start SA).

One BFS hop over every source at once: ``reach ← reach ∨ (reach @ Adj)``
plus the row-wise reach count the ASPL accumulation needs, fused into a
single pass. See ``kernel.py`` for the Pallas TPU implementation and
``ref.py`` for the pure-jnp oracle (the default execution path, exactly
like ``edge_laplacian``).
"""
from . import ops, ref  # noqa: F401
