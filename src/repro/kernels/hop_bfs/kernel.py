"""Pallas TPU kernel for the matmul-BFS hop of the SA warm start (§VI).

The device simulated-annealing loop evaluates every candidate 2-swap by
re-running an all-sources BFS on the proposed adjacency matrix: hop k
expands the boolean reach matrix by one step, and the ASPL accumulator
needs only *how many* (src, dst) pairs became reachable (`hop counts
summed on the fly`). The naive lowering is three passes over n²: the
matmul, the OR-combine, and the count reduction.

``hop_step_2d`` fuses them into ONE pass per (SUBLANE, n_pad) row band:

  - the band of ``reach @ Adj`` is one MXU matmul (f32 0/1 operands —
    exact, since row counts are ≤ n ≪ 2²⁴),
  - the OR with the incoming band and the threshold happen in-register,
  - the band's per-row reach counts are the row-sum reduction of the same
    tile, written to a (SUBLANE, LANE) count block (column 0 carries the
    value; the broadcast keeps the store lane-aligned).

TPU adaptation notes (mirroring ``edge_laplacian``/``gossip_mix``):
  - tiles are VPU/MXU-aligned (last dim multiple of 128, sublane multiple
    of 8); wrappers in ``ops.py`` pad n up and slice the result back.
    Padded rows/columns are all-zero, so they contribute nothing to the
    matmul, the OR, or the counts.
  - Adj stays whole in VMEM: n ≤ ~1500 keeps n² f32 within the ~16 MB
    budget, far above the paper's regime.
  - ``interpret=True`` (the repo default on CPU) is the reference
    execution mode, as for the other kernels in this tree.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128     # last-dim tile (multiple of 128)
SUBLANE = 8    # second-to-last dim tile


def _hop_step_kernel(reach_ref, adj_ref, out_ref, cnt_ref):
    """reach band: (SUBLANE, n_pad) f32 0/1; adj: (n_pad, n_pad) f32 0/1;
    out: (SUBLANE, n_pad) f32 0/1; cnt: (SUBLANE, LANE) f32 row counts."""
    R = reach_ref[...]
    A = adj_ref[...]
    prod = jnp.dot(R, A, preferred_element_type=jnp.float32)
    new = jnp.where(prod + R > 0, 1.0, 0.0).astype(R.dtype)
    out_ref[...] = new
    rows = jnp.sum(new, axis=1, keepdims=True)  # per-source reach count
    cnt_ref[...] = jnp.broadcast_to(rows, cnt_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hop_step_2d(reach, adj, *, interpret: bool = True):
    """reach: (r_pad, c_pad) f32 0/1 with r_pad % SUBLANE == 0 and
    c_pad % LANE == 0; adj: (c_pad, c_pad) f32 0/1 (symmetric, zero
    padding). Returns ``(new_reach (r_pad, c_pad), counts (r_pad, LANE))``
    where ``counts[:, 0]`` is the per-source reach count."""
    r_pad, c_pad = reach.shape
    assert r_pad % SUBLANE == 0 and c_pad % LANE == 0, (r_pad, c_pad)
    assert adj.shape == (c_pad, c_pad), (adj.shape, c_pad)
    return pl.pallas_call(
        _hop_step_kernel,
        grid=(r_pad // SUBLANE,),
        in_specs=[
            pl.BlockSpec((SUBLANE, c_pad), lambda i: (i, 0)),
            pl.BlockSpec((c_pad, c_pad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANE, c_pad), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r_pad, c_pad), reach.dtype),
            jax.ShapeDtypeStruct((r_pad, LANE), reach.dtype),
        ],
        interpret=interpret,
    )(reach, adj)
