"""jit'd public wrapper: pad the boolean reach/adjacency matrices to the
kernel tiling, dispatch, slice the result back to logical shape."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import LANE, SUBLANE, hop_step_2d


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def hop_step(reach, adj, *, use_kernel: bool = True, interpret: bool = True):
    """One matmul-BFS hop: ``new = reach ∨ (reach @ Adj)`` plus the total
    reached-pair count of ``new``.

    reach/adj: (n, n) bool. Returns ``(new_reach bool (n, n), count int32)``.
    The kernel path fuses the boolean matmul, the OR, and the count
    reduction into one pass per row band; zero padding is inert in all
    three (see kernel.py).
    """
    n = reach.shape[0]
    if not use_kernel or n < 2:
        return ref.hop_step(reach, adj)
    r_pad = -(-n // SUBLANE) * SUBLANE
    c_pad = -(-n // LANE) * LANE
    Rp = jnp.pad(reach.astype(jnp.float32), ((0, r_pad - n), (0, c_pad - n)))
    Ap = jnp.pad(adj.astype(jnp.float32), ((0, c_pad - n), (0, c_pad - n)))
    new, cnt = hop_step_2d(Rp, Ap, interpret=interpret)
    return new[:n, :n] > 0, jnp.sum(cnt[:n, 0]).astype(jnp.int32)
