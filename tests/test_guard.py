"""Solver guard layer: outcome classification, invariants, the shared
retry ladder, and the engine's non-finite early-abort (DESIGN.md §15)."""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.api import BATopoConfig, _pack_warm, optimize_topology
from repro.core.engine import ADMMConfig, init_state, make_homo_spec, solve_spec
from repro.core.graph import Topology
from repro.core.guard import (
    GuardPolicy, LadderResult, SolveFailure, SolveOutcome,
    TopologyInvariantError, attempt_admm, check_invariants, classic_fallback,
    classify_result, jittered_warm_rungs, run_ladder, validate_topology,
)
from repro.core.topologies import ring
from repro.core.weights import metropolis_weights

FAST_ADMM = ADMMConfig(max_iters=120, check_every=30)
NAN_ADMM = dataclasses.replace(FAST_ADMM, rho=float("nan"))


def _ring_topo(n: int = 8) -> Topology:
    base = ring(n)
    return Topology(n, base.edges, metropolis_weights(n, base.edges),
                    name="ring", meta={"connected": True})


def _solve(n: int, r: int, cfg: ADMMConfig):
    """One homogeneous engine solve from a ring warm start."""
    g0, _, lam0 = _pack_warm(n, ring(n).edges)
    spec = make_homo_spec(n, r, cfg)
    return solve_spec(spec, init_state(spec, jnp.asarray(g0), lam0), cfg)


# =========================================================================
# invariant checklist
# =========================================================================

def test_check_invariants_accepts_valid_topology():
    assert check_invariants(_ring_topo()) is None


@pytest.mark.parametrize("mutate,expected", [
    (lambda W: np.full_like(W, np.nan), "finite"),
    (lambda W: W + np.triu(np.ones_like(W), 1) * 0.3, "symmetric"),
    (lambda W: W * 0.5, "row_stochastic"),
])
def test_check_invariants_names_violation(mutate, expected):
    # Topology.W is derived from (edges, g); matrix-level violations are
    # tested through a shim exposing the attributes check_invariants reads.
    topo = _ring_topo()

    class Shim:
        n = topo.n
        edges = topo.edges
        meta: dict = {}
        W = mutate(np.array(topo.W))

    assert check_invariants(Shim()) == expected


def test_check_invariants_disconnected():
    n = 6
    edges = [(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)]  # two triangles
    topo = Topology(n, edges, metropolis_weights(n, edges), name="split",
                    meta={"connected": False})
    assert check_invariants(topo) == "connected"


def test_validate_topology_raises_structured_error():
    n = 6
    edges = [(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)]
    topo = Topology(n, edges, metropolis_weights(n, edges), name="split")
    with pytest.raises(TopologyInvariantError) as ei:
        validate_topology(topo, context="unit test")
    assert ei.value.invariant == "connected"
    assert "connected" in str(ei.value)


# =========================================================================
# non-finite early-abort + classification
# =========================================================================

def test_nan_solve_classified_non_finite_and_aborts_early():
    """A NaN ρ poisons the first chunk; the scan driver must stop at the
    first convergence check instead of burning the full budget, and the
    classifier must call the result non_finite."""
    res = _solve(8, 12, NAN_ADMM)
    assert classify_result(res) is SolveOutcome.NON_FINITE
    assert res.iters <= NAN_ADMM.check_every  # early-abort, not max_iters


def test_abort_nonfinite_fault_free_paths_bit_exact():
    """With finite inputs the abort predicate never fires: trajectories with
    the guard on and off are bit-identical."""
    cfg_on = dataclasses.replace(FAST_ADMM, abort_nonfinite=True)
    cfg_off = dataclasses.replace(FAST_ADMM, abort_nonfinite=False)
    res_on = _solve(10, 16, cfg_on)
    res_off = _solve(10, 16, cfg_off)
    assert res_on.iters == res_off.iters
    np.testing.assert_array_equal(res_on.g, res_off.g)
    np.testing.assert_array_equal(res_on.g_raw, res_off.g_raw)
    assert res_on.residual == res_off.residual


def test_classify_result_thresholds():
    res = _solve(8, 12, FAST_ADMM)
    assert classify_result(res, max_residual=np.inf) is SolveOutcome.CONVERGED
    assert classify_result(res, max_residual=0.0) is SolveOutcome.NON_CONVERGENT


def test_attempt_admm_nan_raises_classified_failure():
    n, r = 8, 12
    cfg = BATopoConfig(sa_iters=50, polish_iters=50,
                       admm=NAN_ADMM)
    warm = _pack_warm(n, ring(n).edges)
    with pytest.raises(SolveFailure) as ei:
        attempt_admm(n, r, "homo", None, cfg, warm, "t")
    assert ei.value.outcome is SolveOutcome.NON_FINITE


# =========================================================================
# the ladder
# =========================================================================

def test_run_ladder_falls_through_to_valid_rung():
    calls = []

    def bad():
        calls.append("bad")
        raise SolveFailure(SolveOutcome.NON_FINITE, "injected")

    def none_rung():
        calls.append("none")
        return None

    def good():
        calls.append("good")
        return _ring_topo()

    res = run_ladder([("nan", bad), ("empty", none_rung), ("classic", good)])
    assert isinstance(res, LadderResult)
    assert res.rung == "classic" and res.attempts == 3
    assert calls == ["bad", "none", "good"]
    assert [r.outcome for r in res.reports] == ["non_finite", "none", "ok"]
    assert "non_finite" in res.reason and "injected" in res.reason


def test_run_ladder_rejects_invalid_topology_and_never_raises():
    n = 6
    split = Topology(n, [(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)],
                     metropolis_weights(n, [(0, 1), (1, 2), (0, 2),
                                            (3, 4), (4, 5), (3, 5)]),
                     name="split", meta={"connected": True})

    def explode():
        raise RuntimeError("boom")

    res = run_ladder([("invalid", lambda: split), ("raise", explode)])
    assert res.topology is None and res.rung is None
    assert res.reports[0].outcome == "invalid:connected"
    assert res.reports[1].outcome == "error:RuntimeError"


def test_nan_solve_rescued_by_ladder_fallback():
    """The ISSUE acceptance path: a NaN-injected solve is classified
    non_finite and the ladder still delivers a valid topology."""
    n, r = 8, 12
    cfg = BATopoConfig(sa_iters=50, polish_iters=50, admm=NAN_ADMM)
    warm = _pack_warm(n, ring(n).edges)
    policy = GuardPolicy(warm_retries=1)
    rungs = jittered_warm_rungs(n, r, "homo", None, cfg, warm, "t", policy)
    rungs.append(("classic", lambda: classic_fallback(n, r)))
    res = run_ladder(rungs)
    assert res.rung == "classic"
    assert all(rep.outcome == "non_finite" for rep in res.reports[:-1])
    assert check_invariants(res.topology) is None


def test_jittered_warm_rungs_rescue_without_fallback():
    """With a finite ρ the first warm rung already succeeds — the retries
    never run."""
    n, r = 8, 12
    cfg = BATopoConfig(sa_iters=50, polish_iters=50, admm=FAST_ADMM)
    warm = _pack_warm(n, ring(n).edges)
    rungs = jittered_warm_rungs(n, r, "homo", None, cfg, warm, "t",
                                GuardPolicy(warm_retries=2))
    assert len(rungs) == 3
    res = run_ladder(rungs)
    assert res.rung == "warm" and res.attempts == 1
    assert check_invariants(res.topology) is None


# =========================================================================
# classic fallback + release validation
# =========================================================================

def test_classic_fallback_valid_and_budgeted():
    topo = classic_fallback(8, 12)
    assert check_invariants(topo) is None
    assert len(topo.edges) <= 12


def test_classic_fallback_ring_of_last_resort_notes_violation():
    # r below any classic's edge count: the terminal ring still answers
    # but records what it violates.
    topo = classic_fallback(8, 7)
    assert check_invariants(topo) is None
    assert "violates" in topo.meta


def test_optimize_topology_release_validated():
    """The happy path passes release validation (the checklist runs inside
    phase 5 now) and the returned matrix satisfies every invariant."""
    topo = optimize_topology(12, 18, cfg=BATopoConfig(sa_iters=50,
                                                      polish_iters=50))
    assert check_invariants(topo) is None
    W = np.asarray(topo.W)
    assert np.all(np.isfinite(W))
    np.testing.assert_allclose(W, W.T, atol=1e-8)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-6)
