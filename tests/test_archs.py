"""Per-architecture smoke tests (brief deliverable f): reduced variant of the
same family (2 layers, d_model ≤ 512, ≤ 4 experts), one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill→decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCHS, INPUT_SHAPES, get_arch, reduced_for_smoke,
                           shape_supported)
from repro.models import decode_step, init_params, param_count, prefill, train_loss

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, key, B=2, S=64):
    kt, ke = jax.random.split(key)
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend:
        batch["embeds"] = jax.random.normal(ke, (B, cfg.frontend_tokens, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_reduced_config(arch, key):
    cfg = reduced_for_smoke(ARCHS[arch])
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)
    loss = jax.jit(lambda p, b: train_loss(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step_updates(arch, key):
    """One SGD step on the reduced config changes params and reduces no NaN."""
    cfg = reduced_for_smoke(ARCHS[arch])
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)

    @jax.jit
    def step(p, b):
        loss, g = jax.value_and_grad(lambda p: train_loss(p, cfg, b))(p)
        p = jax.tree.map(lambda w, gw: w - 0.01 * gw.astype(w.dtype), p, g)
        return loss, p

    loss, new_params = step(params, batch)
    assert not bool(jnp.isnan(loss))
    flat_old = jax.tree.leaves(params)
    flat_new = jax.tree.leaves(new_params)
    assert any(not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
               for a, b in zip(flat_old, flat_new))
    for leaf in flat_new:
        assert not bool(jnp.any(jnp.isnan(leaf.astype(jnp.float32)))), f"{arch}: NaN param"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch, key):
    """logits(prefill S+1)[-1] == logits(prefill S → decode token S)."""
    cfg = reduced_for_smoke(ARCHS[arch])
    if cfg.num_experts:
        # capacity-based token dropping is batch-dependent (a prefill in a
        # 66-token batch may drop what a 2-token decode keeps); disable drops
        # so the test isolates cache correctness
        from dataclasses import replace
        cfg = replace(cfg, moe_capacity_factor=float(cfg.num_experts))
    params = init_params(key, cfg)
    B, S = 2, 32
    batch = make_batch(cfg, key, B=B, S=S + 1)
    full_logits, _ = jax.jit(lambda p, b: prefill(p, cfg, b))(params, batch)

    n_prefix = cfg.frontend_tokens if cfg.arch_type == "vlm" else 0
    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :S]
    short["labels"] = batch["labels"][:, :S]
    _, caches = jax.jit(lambda p, b: prefill(p, cfg, b, cache_cap=S + 1 + n_prefix))(
        params, short)
    step_logits, _ = jax.jit(
        lambda p, t, c: decode_step(p, cfg, t, c, jnp.int32(S + n_prefix)))(
        params, batch["tokens"][:, S:S + 1], caches)

    # decode_step consumes the token at position S (prefix offset for vlm)
    np.testing.assert_allclose(np.asarray(full_logits[:, -1]),
                               np.asarray(step_logits[:, -1]), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_shape_matrix_declared(arch):
    """Every (arch × shape) pair resolves to run-or-documented-skip."""
    for shape in INPUT_SHAPES:
        supported = shape_supported(arch, shape)
        if shape != "long_500k":
            assert supported
        elif not supported:
            cfg = ARCHS[arch]
            # only pure full-attention archs may skip long_500k
            assert cfg.arch_type not in ("ssm", "hybrid") and cfg.sliding_window == 0


def test_get_arch_unknown_lists_the_zoo():
    """A typo'd --arch fails with the config zoo spelled out, not a KeyError."""
    with pytest.raises(ValueError, match="unknown arch") as exc:
        get_arch("smollm-135M")
    for name in ALL_ARCHS:
        assert name in str(exc.value)
    assert get_arch(ALL_ARCHS[0]) is ARCHS[ALL_ARCHS[0]]


def test_full_configs_match_assignment():
    """Exact figures from the assignment table."""
    c = ARCHS["gemma2-9b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (42, 3584, 16, 8, 14336, 256000)
    assert c.logit_softcap == 30.0 and c.attn_pattern == "local_global"
    c = ARCHS["mixtral-8x22b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size,
            c.num_experts, c.experts_per_token) == (56, 6144, 48, 8, 16384, 32768, 8, 2)
    c = ARCHS["granite-moe-1b-a400m"]
    assert (c.num_layers, c.d_model, c.num_experts, c.experts_per_token) == (24, 1024, 32, 8)
    c = ARCHS["mamba2-780m"]
    assert (c.num_layers, c.d_model, c.ssm_state, c.vocab_size) == (48, 1536, 128, 50280)
    c = ARCHS["internvl2-1b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.vocab_size) == \
        (24, 896, 14, 2, 151655)
    c = ARCHS["whisper-tiny"]
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) == \
        (4, 384, 6, 1536, 51865)
    c = ARCHS["smollm-135m"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == \
        (30, 576, 9, 3, 1536, 49152)
    c = ARCHS["minitron-8b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == \
        (32, 4096, 32, 8, 16384, 256000)
    c = ARCHS["qwen1.5-0.5b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size,
            c.qkv_bias) == (24, 1024, 16, 16, 2816, 151936, True)
    c = ARCHS["zamba2-2.7b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size,
            c.ssm_state) == (54, 2560, 32, 32, 10240, 32000, 64)
    assert c.shared_attn_every > 0


def test_param_count_order_of_magnitude():
    """The reduced smollm config is ~0.3M params (sanity anchor)."""
    cfg = reduced_for_smoke(ARCHS["smollm-135m"])
    p = init_params(jax.random.PRNGKey(1), cfg)
    assert 1e5 < param_count(p) < 2e6
