"""Roofline analysis: HLO collective parser + trip-count correction."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.roofline import collective_bytes_from_hlo, model_flops
from repro.roofline.analysis import _multipliers, _parse_computations, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[2,3]{1,0}") == 24
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[4], s32[2])") == 24
    assert _shape_bytes("pred[8]") == 8
    assert _shape_bytes("f32[]") == 4


SYNTH = """
HloModule m

%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %x = f32[64]{0} get-tuple-element(%p), index=1
  %ar = f32[64]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[64]) tuple(%x, %ar)
}

%cond (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]) parameter(0)
  ROOT %lt = pred[] compare(%p, %p), direction=LT
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64]{0} parameter(0)
  %big = f32[128]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[64]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[64]{0} get-tuple-element(%w), index=1
}
"""


def test_parser_trip_count_multiplier():
    res = collective_bytes_from_hlo(SYNTH)
    # all-gather once: 128*4 = 512; all-reduce in body ×5: 5*256 = 1280
    assert res["by_op"]["all-gather"] == 512
    assert res["by_op"]["all-reduce"] == 1280
    assert res["total"] == 512 + 1280


def test_multipliers_nested():
    comps = _parse_computations(SYNTH)
    assert set(comps) >= {"body", "cond", "main"}
    mult = _multipliers(comps)
    assert mult["main"] == 1.0
    assert mult["body"] == 5.0


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >1 device")
def test_parser_matches_unrolled():
    pass  # exercised by test_sharded_runtime.py in a multi-device subprocess


def test_parser_on_real_compiled_module():
    """Scan body collectives must be multiplied by the trip count: compare a
    scanned loop against its unrolled twin on a single device (all-reduce
    appears only with >1 device, so use a gather-free psum-of-shard trick:
    just validate parser runs and finds zero collectives single-device)."""
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ x), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out.sum()

    x = jnp.ones((16, 16))
    txt = jax.jit(f).lower(x).compile().as_text()
    res = collective_bytes_from_hlo(txt)
    assert res["total"] == 0.0 and res["count"] == 0


def test_model_flops():
    class Cfg:
        num_experts = 0
    assert model_flops(Cfg(), 10, "train", 100) == 6000
    assert model_flops(Cfg(), 10, "decode", 100) == 2000
    assert model_flops(Cfg(), 10, "prefill", 100, active_param_count=50) == 1000


def test_analytic_flops_vs_hlo_single_layer():
    """Cross-validate the analytic compute term against XLA's own count on a
    1-layer model (while-trip = 1, so cost_analysis has no body-once bias).
    The analytic 2·N·D form counts the embedding gather and full-seq lm-head
    as matmuls, so it over-estimates on tiny-vocab reduced configs; assert
    the ratio stays within the roofline-estimate envelope."""
    from dataclasses import replace
    from repro.configs import get_arch, reduced_for_smoke
    from repro.configs.base import InputShape
    from repro.models import transformer
    from repro.roofline.analysis import analytic_flops_bytes

    cfg = replace(reduced_for_smoke(get_arch("smollm-135m")), num_layers=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 4, 256
    batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
    compiled = jax.jit(
        lambda p, b: transformer.prefill(p, cfg, b, cache_cap=S)).lower(
        params, batch).compile()
    # capability shim: jax < 0.5 returns a one-element list of dicts from
    # cost_analysis(), newer jax returns the dict directly
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo_flops = cost["flops"]
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    a = analytic_flops_bytes(
        cfg, InputShape("probe", S, B, "prefill"), "prefill",
        {"params": n, "active": n, "param_bytes": 4 * n, "cache_bytes": 0})
    ratio = a["flops"] / hlo_flops
    assert 0.7 < ratio < 1.6, ratio
