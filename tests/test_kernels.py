"""Per-kernel validation vs the pure-jnp oracles (brief requirement):
sweep shapes/dtypes, assert_allclose against ref.py, in interpret mode."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp


# --- gossip_mix -------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128,), (1024,), (2048, 64), (257,), (1000, 131),
                                   (3, 5, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix_shapes_dtypes(shape, dtype):
    from repro.kernels.gossip_mix import ops, ref
    deg = 3
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    nbrs = jax.random.normal(jax.random.PRNGKey(1), (deg,) + shape).astype(dtype)
    w = jnp.asarray([0.4, 0.2, 0.2, 0.2], jnp.float32)
    out = ops.gossip_mix(x, nbrs, w, use_kernel=True)
    expect = ref.gossip_mix(x, nbrs, w)
    assert out.shape == shape and out.dtype == x.dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 3000), deg=st.integers(1, 5), seed=st.integers(0, 99))
def test_gossip_mix_property_any_length(n, deg, seed):
    from repro.kernels.gossip_mix import ops, ref
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n,))
    nbrs = jax.random.normal(jax.random.PRNGKey(seed + 1), (deg, n))
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 2), (deg + 1,)))
    w = w / w.sum()
    np.testing.assert_allclose(np.asarray(ops.gossip_mix(x, nbrs, w)),
                               np.asarray(ref.gossip_mix(x, nbrs, w)), atol=1e-5)


def test_gossip_mix_is_convex_combination():
    """Property: with convex weights, output stays in the convex hull."""
    from repro.kernels.gossip_mix import ops
    x = jnp.full((256,), 2.0)
    nbrs = jnp.stack([jnp.full((256,), 1.0), jnp.full((256,), 3.0)])
    w = jnp.asarray([0.5, 0.25, 0.25])
    out = ops.gossip_mix(x, nbrs, w)
    assert float(out.min()) >= 1.0 - 1e-5 and float(out.max()) <= 3.0 + 1e-5


# --- decode_attention --------------------------------------------------------

@pytest.mark.parametrize("B,C,Hkv,g,hd", [(1, 128, 1, 1, 64), (2, 512, 2, 2, 64),
                                          (4, 1024, 4, 1, 128), (2, 384, 3, 3, 64)])
def test_decode_attention_shapes(B, C, Hkv, g, hd):
    from repro.kernels.decode_attention import ops, ref
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Hkv * g, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, C, Hkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, C, Hkv, hd))
    valid = jnp.arange(C) < (2 * C // 3)
    out = ops.decode_attention(q, k, v, valid)
    expect = ref.decode_attention(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_softcap_and_masks():
    from repro.kernels.decode_attention import ops, ref
    B, C, Hkv, g, hd = 2, 256, 2, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Hkv * g, hd)) * 3
    k = jax.random.normal(jax.random.PRNGKey(1), (B, C, Hkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, C, Hkv, hd))
    for frac in (1, 4, C):  # single valid slot up to fully valid
        valid = jnp.arange(C) < frac
        out = ops.decode_attention(q, k, v, valid, attn_softcap=50.0)
        expect = ref.decode_attention(q, k, v, valid, attn_softcap=50.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=2e-5, rtol=2e-5)


# --- ssd_scan ----------------------------------------------------------------

@pytest.mark.parametrize("B,nc,Q,H,P,N", [(1, 1, 64, 2, 32, 16),
                                          (2, 2, 64, 4, 32, 32),
                                          (1, 4, 128, 8, 64, 64)])
def test_ssd_intra_chunk_shapes(B, nc, Q, H, P, N):
    from repro.kernels.ssd_scan import ops, ref
    k = jax.random.PRNGKey(0)
    xc = jax.random.normal(k, (B, nc, Q, H, P)) * 0.3
    dtc = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, nc, Q, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
    la = jnp.cumsum(A[None, None, None, :] * dtc, axis=2)
    Bc = jax.random.normal(jax.random.PRNGKey(3), (B, nc, Q, N)) * 0.3
    Cc = jax.random.normal(jax.random.PRNGKey(4), (B, nc, Q, N)) * 0.3
    yk, sk = ops.ssd_intra_chunk(xc, dtc, la, Bc, Cc)
    yr, sr = ref.ssd_intra_chunk(xc, dtc, la, Bc, Cc)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=5e-5, rtol=5e-5)


def test_ssd_scan_matches_sequential_recurrence():
    """The chunked dual form must equal the plain SSM recurrence."""
    from repro.models.ssm import ssd_chunk_scan
    B, S, H, P, N = 1, 64, 2, 16, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.2)
    Bm = jax.random.normal(jax.random.PRNGKey(3), (B, S, N)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(4), (B, S, N)) * 0.5
    y, hT = ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=16)

    # sequential oracle
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dec = np.exp(np.asarray(A)[None] * np.asarray(dt[:, t]))  # (B,H)
        h = dec[:, :, None, None] * h + np.einsum(
            "bh,bn,bhp->bhpn", np.asarray(dt[:, t]), np.asarray(Bm[:, t]),
            np.asarray(x[:, t]))
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(Cm[:, t]), h))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hT), h, atol=1e-3, rtol=1e-3)
