"""Time-varying (round-robin matching) gossip — beyond-paper extension."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import make_baseline
from repro.dsgd.dynamic import (
    cycle_contraction,
    cycle_weight_matrices,
    round_robin_schedules,
)
from tests.test_dsgd import _random_topology


def test_each_round_is_doubly_stochastic_psd():
    # hypercube has real symmetric weights; the directed exponential graph
    # is rejected by round_robin_schedules (asymmetric W, all-zero g would
    # silently decompose into identity rounds)
    topo = make_baseline("hypercube", 8)
    for W in cycle_weight_matrices(round_robin_schedules(topo)):
        np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
        np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-12)
        np.testing.assert_allclose(W, W.T, atol=1e-12)
        ev = np.linalg.eigvalsh(W)
        assert ev.min() >= -1e-12  # lazy pairwise averages are PSD


@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 16), extra=st.integers(0, 10), seed=st.integers(0, 1000))
def test_cycle_contracts_for_connected_graphs(n, extra, seed):
    topo = _random_topology(n, extra, seed)
    scheds = round_robin_schedules(topo)
    rho = cycle_contraction(scheds)
    assert rho < 1.0 - 1e-9  # connected ⇒ one cycle strictly contracts
    # covering property: every edge appears in exactly one round
    counted = sorted(e for s in scheds for p in s.perms for e in p if e[0] < e[1])
    assert counted == sorted(map(tuple, topo.edges))


def test_cycle_preserves_mean():
    topo = make_baseline("ring", 6)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(6, 4))
    for W in cycle_weight_matrices(round_robin_schedules(topo)):
        x2 = W @ x
        np.testing.assert_allclose(x2.mean(0), x.mean(0), atol=1e-12)
        x = x2
