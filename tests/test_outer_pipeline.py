"""Outer-pipeline performance stack (DESIGN.md §10): Lanczos spectral
evaluation parity, batched device polish parity, and the device pipeline
end-to-end against the host parity oracle."""
import numpy as np
import pytest

from repro.core import ADMMConfig, BATopoConfig, optimize_topology
from repro.core.graph import (
    FAST_SPECTRAL_MIN_N, Topology, r_asym, r_asym_fast,
    weight_matrix_from_weights,
)
from repro.core.topologies import hypercube, random_graph, ring, torus2d
from repro.core.weights import (
    asym_factor_from_g, metropolis_weights, polish_weights,
    polish_weights_batched,
)

_FAST = BATopoConfig(admm=ADMMConfig(max_iters=200), sa_iters=300,
                     polish_iters=200)


# ---------------------------------------------------------------------------
# Lanczos r_asym_fast vs the exact eigvalsh oracle
# ---------------------------------------------------------------------------

def _bcube_like_topology():
    """A feasible graph on BCube-admissible edges only."""
    from repro.core.api import _greedy_constraint_graph
    from repro.core.constraints import bcube_constraints

    cs = bcube_constraints(4, 2)  # n = 16
    edges = _greedy_constraint_graph(16, 24, cs, np.random.default_rng(0))
    g = metropolis_weights(16, edges)
    return Topology(16, edges, g, name="bcube-like")


@pytest.mark.parametrize("topo_fn", [
    lambda: ring(16), lambda: ring(129), lambda: torus2d(64),
    lambda: torus2d(225), lambda: hypercube(64), _bcube_like_topology,
    lambda: random_graph(48, 100, seed=2),
    lambda: random_graph(200, 500, seed=3),
])
def test_r_asym_fast_matches_eigvalsh(topo_fn):
    W = topo_fn().W
    assert abs(r_asym_fast(W) - r_asym(W)) <= 1e-8


def test_r_asym_symmetric_hint_matches_detection():
    W = torus2d(36).W
    assert r_asym(W, symmetric=True) == pytest.approx(r_asym(W), abs=1e-14)


def test_r_asym_non_doubly_stochastic_fallback():
    rng = np.random.default_rng(0)
    A = rng.standard_normal((12, 12))
    A = (A + A.T) / 2  # symmetric but NOT doubly stochastic
    n = A.shape[0]
    expected = float(np.max(np.abs(
        np.linalg.eigvalsh(A - np.ones((n, n)) / n))))
    assert r_asym(A) == pytest.approx(expected, abs=1e-12)
    assert r_asym_fast(A) == pytest.approx(expected, abs=1e-12)


def test_topology_r_asym_routes_through_fast_path():
    n = FAST_SPECTRAL_MIN_N + 8
    t = random_graph(n, int(2.5 * n), seed=1)
    exact = r_asym(t.W, symmetric=True)
    assert abs(t.r_asym() - exact) <= 1e-8


def test_asym_factor_fast_equals_exact():
    t = random_graph(40, 90, seed=4)
    exact = asym_factor_from_g(t.n, t.edges, t.g, fast=False)
    fast = asym_factor_from_g(t.n, t.edges, t.g, fast=True)
    assert abs(fast - exact) <= 1e-8
    # identically r_asym(I − L)
    assert exact == pytest.approx(
        r_asym(weight_matrix_from_weights(t.n, t.edges, t.g)), abs=1e-12)


# ---------------------------------------------------------------------------
# Batched device polish vs the host loop
# ---------------------------------------------------------------------------

def test_polish_batched_fp64_matches_host():
    n = 20
    cands = [random_graph(n, 36, seed=s).edges for s in (0, 1)] + [ring(n).edges]
    g0s = [metropolis_weights(n, e) for e in cands]
    host = [polish_weights(n, e, g0, iters=150) for e, g0 in zip(cands, g0s)]
    dev = polish_weights_batched(n, cands, g0s, iters=150, dtype="float64")
    for e, h, d in zip(cands, host, dev):
        fh = asym_factor_from_g(n, e, h, fast=False)
        fd = asym_factor_from_g(n, e, d, fast=False)
        assert abs(fd - fh) < 1e-7


def test_polish_batched_fp32_objective_close():
    n = 16
    cands = [random_graph(n, 30, seed=s).edges for s in (2, 3)]
    g0s = [metropolis_weights(n, e) for e in cands]
    host = [polish_weights(n, e, g0, iters=150) for e, g0 in zip(cands, g0s)]
    dev = polish_weights_batched(n, cands, g0s, iters=150, dtype="float32")
    for e, h, d in zip(cands, host, dev):
        fh = asym_factor_from_g(n, e, h, fast=False)
        fd = asym_factor_from_g(n, e, d, fast=False)
        assert abs(fd - fh) < 2e-3
        assert np.all(d >= 0)


def test_polish_batched_improves_metropolis():
    n = 18
    edges = random_graph(n, 34, seed=5).edges
    g0 = metropolis_weights(n, edges)
    (g,) = polish_weights_batched(n, [edges], [g0], iters=300)
    assert (asym_factor_from_g(n, edges, g, fast=False)
            <= asym_factor_from_g(n, edges, g0, fast=False) + 1e-9)


def test_polish_batched_empty_inputs():
    assert polish_weights_batched(5, []) == []


# ---------------------------------------------------------------------------
# End-to-end: device pipeline vs host parity oracle
# ---------------------------------------------------------------------------

def test_device_pipeline_matches_host_quality():
    host_cfg = BATopoConfig(admm=ADMMConfig(max_iters=200), sa_iters=300,
                            polish_iters=200, restarts=2,
                            warmstart="host", polish="host")
    dev_cfg = BATopoConfig(admm=ADMMConfig(max_iters=200), sa_iters=300,
                           polish_iters=200, restarts=2)
    t_host = optimize_topology(12, 20, "homo", cfg=host_cfg)
    t_dev = optimize_topology(12, 20, "homo", cfg=dev_cfg)
    t_host.validate()
    t_dev.validate()
    assert t_dev.r <= 20
    assert abs(t_dev.meta["r_asym"] - t_host.meta["r_asym"]) < 0.1


def test_profile_collects_phase_breakdown():
    prof: dict = {}
    optimize_topology(10, 16, "homo", cfg=_FAST, profile=prof)
    assert set(prof) == {"warm_s", "admm_s", "round_s", "polish_s", "eval_s"}
    assert all(v >= 0.0 for v in prof.values())


def test_pipeline_cfg_validation():
    with pytest.raises(ValueError):
        optimize_topology(8, 12, cfg=BATopoConfig(warmstart="gpu"))
    with pytest.raises(ValueError):
        optimize_topology(8, 12, cfg=BATopoConfig(polish="Device"))
    with pytest.raises(ValueError):
        optimize_topology(8, 12, cfg=BATopoConfig(polish_dtype="bf16"))


def test_classic_candidates_skip_only_value_errors():
    from repro.core.api import _classic_candidates

    # n=6: hypercube raises ValueError (not a power of two) and must be
    # skipped; ring/torus exist. All returned selections are boolean masks.
    cands = _classic_candidates(6, 10, None)
    names = [name for name, _ in cands]
    assert any("ring" in s for s in names)
    assert not any("hypercube" in s for s in names)
    for _, sel in cands:
        assert sel.dtype == bool and sel.sum() <= 10
