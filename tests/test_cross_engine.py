"""Parity tests for the cross-product gossip engines (DESIGN.md §12):
dynamic-cycle scan vs the host matrix sequence (bit-equal) and host-loop
curves, CHOCO scan vs the ``choco_gossip_step`` loop, and the vmapped
cross product vs serial single runs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import make_baseline
from repro.data import class_balanced_partition, make_classification_data
from repro.dsgd.compression import choco_gossip_init, choco_gossip_step
from repro.dsgd.dynamic import (
    cycle_tensor,
    cycle_weight_matrices,
    round_robin_schedules,
    stack_cycles,
    static_cycle,
)
from repro.dsgd.gossip import select_cycle_matrix
from repro.dsgd.schedule import reconstruct_weight_matrix
from repro.dsgd.sim import (
    CommSpec,
    DSGDSimConfig,
    accuracy_curve_host_cross,
    accuracy_curves,
    consensus_curve_host_cross,
    consensus_curves_cross,
    train_curves_cross,
)

N = 8
CFG = DSGDSimConfig(epochs=2, batch=16, hidden=32, seed=0)


@pytest.fixture(scope="module")
def topologies():
    return [make_baseline("ring", N), make_baseline("equistatic", N, M=2)]


@pytest.fixture(scope="module")
def cycles(topologies):
    out = []
    for t in topologies:
        out += [static_cycle(t.W), cycle_tensor(t)]
    return out


@pytest.fixture(scope="module")
def x0():
    return np.random.default_rng(0).normal(size=(N, 24))


@pytest.fixture(scope="module")
def dataset():
    X, y = make_classification_data(num_classes=6, dim=24,
                                    samples_per_class=80, seed=0)
    Xte, yte = make_classification_data(num_classes=6, dim=24,
                                        samples_per_class=24, seed=0,
                                        noise_seed=10_001)
    parts = class_balanced_partition(y, N, seed=0)
    return (jnp.asarray(X), jnp.asarray(y), parts,
            jnp.asarray(Xte), jnp.asarray(yte))


# --- cycle tensors ----------------------------------------------------------

def test_cycle_tensor_is_schedule_reconstruction(topologies):
    """The stacked tensor IS the matrix sequence gossip_shard_dynamic
    realizes: entry c reconstructs schedule c."""
    for topo in topologies:
        scheds = round_robin_schedules(topo)
        Wc = cycle_tensor(topo)
        assert Wc.shape[0] == len(scheds)
        for c, s in enumerate(scheds):
            np.testing.assert_array_equal(Wc[c], reconstruct_weight_matrix(s))


def test_select_cycle_matrix_bit_equal_sequence(topologies):
    """Acceptance: the engine's step-index gather reproduces the host rule
    ``Ws[t % R]`` (gossip_shard_dynamic's ``step % R`` switch) bit-exactly,
    including when the cycle is padded for vmapping."""
    for topo in topologies:
        Ws = cycle_weight_matrices(round_robin_schedules(topo))
        R = len(Ws)
        Wc_pad, lens = stack_cycles([np.stack(Ws)])
        Wc = jnp.asarray(Wc_pad[0])
        for t in range(2 * R + 3):
            got = np.asarray(select_cycle_matrix(Wc, jnp.int32(lens[0]),
                                                 jnp.int32(t)))
            np.testing.assert_array_equal(got, Ws[t % R])


def test_stack_cycles_pads_with_identity(cycles):
    Wc, lens = stack_cycles(cycles)
    r_max = max(c.shape[0] for c in cycles)
    assert Wc.shape == (len(cycles), r_max, N, N)
    for b, c in enumerate(cycles):
        assert lens[b] == c.shape[0]
        np.testing.assert_array_equal(Wc[b, :lens[b]], c)
        for r in range(lens[b], r_max):
            np.testing.assert_array_equal(Wc[b, r], np.eye(N))


def test_round_robin_uses_realized_W_not_g(topologies):
    """Regression: U-EquiStatic stores its mixing matrix as a W override
    (g is all-zero) — the decomposition must read topo.W, not topo.g,
    instead of silently producing identity rounds."""
    equi = topologies[1]
    Wc = cycle_tensor(equi)
    for c in range(Wc.shape[0]):
        assert np.abs(Wc[c] - np.eye(N)).max() > 0.1


# --- consensus engine -------------------------------------------------------

def test_dynamic_consensus_scan_matches_host(cycles, x0):
    """Acceptance: dense {static, round-robin} consensus curves from the
    vmapped scan match the per-iteration host loops ≤ 1e-6 (relative)."""
    errs = consensus_curves_cross(cycles, np.ones(len(cycles)), CommSpec(),
                                  x0, 60, seed=0)
    for b, c in enumerate(cycles):
        host = consensus_curve_host_cross(c, 1.0, CommSpec(), x0, 60, seed=0)
        np.testing.assert_allclose(errs[b], host, atol=1e-6 * host[0])


def test_dynamic_consensus_matches_numpy_loop(topologies, x0):
    """The engine also reproduces the seed bench's raw numpy loop
    x ← Ws[t % R] x (the pre-engine host path)."""
    for topo in topologies:
        Ws = cycle_weight_matrices(round_robin_schedules(topo))
        errs = consensus_curves_cross([np.stack(Ws)], [1.0], CommSpec(),
                                      x0, 40, seed=0)[0]
        x = x0.copy()
        ref = [np.linalg.norm(x - x.mean(0))]
        for t in range(40):
            x = Ws[t % len(Ws)] @ x
            ref.append(np.linalg.norm(x - x.mean(0)))
        np.testing.assert_allclose(errs, ref, atol=1e-6 * ref[0])


@pytest.mark.parametrize("spec,gamma", [(CommSpec("top_k", 0.25), 0.4),
                                        (CommSpec("random_k", 0.25), 0.3)])
def test_choco_consensus_scan_matches_step_loop(topologies, x0, spec, gamma):
    """Acceptance: the CHOCO scan engine matches a per-iteration
    ``choco_gossip_step`` loop (same key stream) ≤ 1e-6."""
    W = jnp.asarray(static_cycle(topologies[0].W)[0])
    errs = consensus_curves_cross([static_cycle(topologies[0].W)], [gamma],
                                  spec, x0, 50, seed=0)[0]
    comp = spec.to_compressor()
    step = jax.jit(lambda s, key: choco_gossip_step(s, W, comp, gamma, key))
    state = choco_gossip_init(jnp.asarray(x0))
    key = jax.random.PRNGKey(1)                 # seed + 1, the engine stream
    ref = [float(jnp.linalg.norm(x0 - x0.mean(0)))]
    for _ in range(50):
        key, sub = jax.random.split(key)
        state = step(state, jax.random.fold_in(sub, 0))
        ref.append(float(jnp.linalg.norm(
            state.x - state.x.mean(axis=0, keepdims=True))))
    np.testing.assert_allclose(errs, ref, atol=1e-6 * ref[0])


def test_choco_dynamic_cross_matches_host(cycles, x0):
    """Compressed × time-varying — the full cross product — against the
    host loop."""
    spec = CommSpec("top_k", 0.1)
    gammas = [0.3, 0.5, 0.3, 0.5]
    errs = consensus_curves_cross(cycles, gammas, spec, x0, 50, seed=0)
    for b, (c, g) in enumerate(zip(cycles, gammas)):
        host = consensus_curve_host_cross(c, g, spec, x0, 50, seed=0)
        np.testing.assert_allclose(errs[b], host, atol=1e-6 * host[0])


def test_consensus_vmapped_matches_serial_runs(cycles, x0):
    """Acceptance: the vmapped cross product equals serial single-run
    dispatches of the same engine."""
    spec = CommSpec("random_k", 0.5)
    gammas = np.array([0.2, 0.4, 0.6, 0.8])
    batched = consensus_curves_cross(cycles, gammas, spec, x0, 30, seed=0)
    for b, c in enumerate(cycles):
        single = consensus_curves_cross([c], [gammas[b]], spec, x0, 30,
                                        seed=0)[0]
        np.testing.assert_allclose(batched[b], single, rtol=1e-12, atol=0)


def test_choco_preserves_mean_on_cycles(cycles, x0):
    """CHOCO on a time-varying cycle still conserves the network mean (every
    W_c is doubly stochastic; the x̂-gossip adds a zero-column-sum update)."""
    spec = CommSpec("top_k", 0.25)
    errs = consensus_curves_cross(cycles, np.full(len(cycles), 0.4), spec,
                                  x0, 200, seed=0)
    assert np.all(errs[:, -1] < errs[:, 0])      # contracts toward consensus


# --- training engine --------------------------------------------------------

ACC_TOL = 1.0 / 144 + 1e-7          # one borderline test sample of 144


def test_train_dynamic_scan_matches_host(cycles, dataset):
    X, y, parts, Xte, yte = dataset
    accs, iters = train_curves_cross(cycles, np.ones(len(cycles)), CommSpec(),
                                     X, y, parts, Xte, yte, CFG)
    accs = np.asarray(accs)
    assert accs.shape == (len(cycles), CFG.epochs)
    for b, c in enumerate(cycles):
        host, ih = accuracy_curve_host_cross(c, 1.0, CommSpec(), X, y, parts,
                                             Xte, yte, CFG)
        assert ih == iters
        assert np.abs(accs[b] - host).max() <= ACC_TOL


@pytest.mark.parametrize("spec,gamma", [(CommSpec("top_k", 0.25), 0.6),
                                        (CommSpec("random_k", 0.5), 0.6)])
def test_train_choco_scan_matches_host(topologies, dataset, spec, gamma):
    X, y, parts, Xte, yte = dataset
    cycles = [static_cycle(topologies[0].W), cycle_tensor(topologies[0])]
    accs, _ = train_curves_cross(cycles, np.full(2, gamma), spec,
                                 X, y, parts, Xte, yte, CFG)
    accs = np.asarray(accs)
    for b, c in enumerate(cycles):
        host, _ = accuracy_curve_host_cross(c, gamma, spec, X, y, parts,
                                            Xte, yte, CFG)
        assert np.abs(accs[b] - host).max() <= ACC_TOL


def test_train_static_dense_equals_pr4_engine(topologies, dataset):
    """The cross engine collapses to the PR-4 static engine for {static,
    dense}: identical curves from the same data/init/batch order."""
    X, y, parts, Xte, yte = dataset
    W = jnp.asarray(topologies[0].W, jnp.float32)
    ref, _ = accuracy_curves(W, X, y, parts, Xte, yte, CFG)
    got, _ = train_curves_cross([static_cycle(topologies[0].W)], [1.0],
                                CommSpec(), X, y, parts, Xte, yte, CFG)
    np.testing.assert_allclose(np.asarray(got)[0], np.asarray(ref), atol=1e-7)


# --- compressor primitives --------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "float64"])
@pytest.mark.parametrize("shape,frac", [((16, 512), 0.1), ((8, 130), 0.3),
                                        ((4, 7), 0.5)])
def test_topk_bitselect_bit_equal_to_lax_topk(dtype, shape, frac):
    """The radix-select threshold path is bit-identical to lax.top_k —
    including ties and zeros — so engine numerics never depend on which
    backend-optimal method `compress_top_k(method="auto")` picks."""
    from repro.dsgd.compression import _kth_largest_bitselect, compress_top_k
    rng = np.random.default_rng(3)
    x = rng.standard_normal(shape).astype(dtype)
    x[0, :3] = 0.0
    x[1, 1] = x[1, 2]                            # exact tie
    a = np.asarray(compress_top_k(jnp.asarray(x), frac, method="bitselect"))
    b = np.asarray(compress_top_k(jnp.asarray(x), frac, method="top_k"))
    np.testing.assert_array_equal(a, b)
    k = max(int(np.ceil(frac * shape[1])), 1)
    t_np = np.sort(np.abs(x), axis=1)[:, shape[1] - k]
    t_bs = np.asarray(_kth_largest_bitselect(jnp.abs(jnp.asarray(x)), k))
    np.testing.assert_array_equal(t_bs[:, 0], t_np)


# --- CommSpec ---------------------------------------------------------------

def test_commspec_validation_and_ratio():
    with pytest.raises(ValueError):
        CommSpec("quantize")
    assert CommSpec().ratio == 1.0
    assert CommSpec("top_k", 0.1).ratio == pytest.approx(0.15)
    assert CommSpec("random_k", 0.8).ratio == 1.0   # index cost caps at dense
    assert CommSpec("top_k", 0.1).name == "top10%"
    assert CommSpec("random_k", 0.25).to_compressor().name == "rand25%"
