"""Launch layer: distribution plans, spec assignment, serve/dryrun plumbing."""
import numpy as np

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_arch, shape_supported
from repro.launch.mesh import MULTIPOD_SHAPE, POD_SHAPE
from repro.launch.sharding import DistPlan, _leaf_spec, params_bytes, plan_for


class FakeMesh:
    """Shape-only stand-in (plan_for/_leaf_spec never touch devices)."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)
        self.shape = dict(zip(names, shape))


SINGLE = FakeMesh(POD_SHAPE, ("data", "model"))
MULTI = FakeMesh(MULTIPOD_SHAPE, ("pod", "data", "model"))


def test_plan_standard_arch_train():
    plan = plan_for(get_arch("smollm-135m"), SINGLE, mode="train")
    assert plan.gossip_axes == ("data",) and plan.n_workers == 16
    plan = plan_for(get_arch("smollm-135m"), MULTI, mode="train")
    assert plan.gossip_axes == ("pod", "data") and plan.n_workers == 32


def test_plan_big_arch_promotes_to_pod_worker():
    plan = plan_for(get_arch("mixtral-8x22b"), SINGLE, mode="train")
    assert plan.gossip_axes == () and plan.tensor_axes == ("data", "model")
    plan = plan_for(get_arch("mixtral-8x22b"), MULTI, mode="train")
    assert plan.gossip_axes == ("pod",) and plan.n_workers == 2


def test_plan_inference_tp_only_auto():
    # 9B fits a 16-chip slice → TP-only; 141B does not → 2-D FSDP
    assert plan_for(get_arch("gemma2-9b"), SINGLE, mode="prefill").tensor_axes == ("model",)
    assert plan_for(get_arch("mixtral-8x22b"), SINGLE,
                    mode="prefill").tensor_axes == ("data", "model")


def test_leaf_spec_megatron_pattern():
    sizes = {"data": 16, "model": 16}
    plan = DistPlan((), ("model",), ("data",), 1)
    # granite regression: d_model(1024) > d_ff(512) must still shard d_ff
    assert _leaf_spec("['layers']['moe']['w_gate']", (24, 32, 1024, 512),
                      plan, sizes) == P(None, None, None, "model")
    assert _leaf_spec("['layers']['moe']['w_down']", (24, 32, 512, 1024),
                      plan, sizes) == P(None, None, "model")
    # attention: heads out (column), wo in (row)
    assert _leaf_spec("['layers']['attn']['wq']", (24, 1024, 2048),
                      plan, sizes) == P(None, None, "model")
    assert _leaf_spec("['layers']['attn']['wo']", (24, 2048, 1024),
                      plan, sizes) == P(None, "model")
    # layer-stacked dim 0 is never sharded
    spec = _leaf_spec("['layers']['mlp']['w_up']", (30, 576, 1536), plan, sizes)
    assert spec[0] is None


def test_leaf_spec_respects_divisibility():
    sizes = {"data": 16, "model": 16}
    plan = DistPlan((), ("model",), ("data",), 1)
    # 9 heads × 64 = 576: divisible; a 7-dim vector is not
    assert _leaf_spec("['final_norm']", (7,), plan, sizes) == P()


def test_params_bytes_orders_of_magnitude():
    assert 0.2e9 < params_bytes(get_arch("smollm-135m")) < 0.8e9   # 135M f32… bf16
    assert 250e9 < params_bytes(get_arch("mixtral-8x22b")) < 350e9


def test_supported_matrix_counts():
    runnable = sum(shape_supported(a, s) for a in ARCHS for s in INPUT_SHAPES)
    assert runnable == 34  # 40 − 6 long_500k policy skips
    assert all(shape_supported(a, "train_4k") for a in ARCHS)


def test_topology_cache_roundtrip(tmp_path, monkeypatch):
    import repro.launch.steps as steps
    monkeypatch.setattr(steps, "TOPO_CACHE", str(tmp_path / "cache.json"))
    steps._MEM_CACHE.clear()
    t1 = steps.topology_for(8, kind="ba", r=12)
    steps._MEM_CACHE.clear()
    t2 = steps.topology_for(8, kind="ba", r=12)  # from disk cache
    assert t1.edges == t2.edges
    np.testing.assert_allclose(t1.g, t2.g)


def test_trivial_topologies():
    from repro.launch.steps import topology_for
    t1 = topology_for(1)
    assert t1.n == 1 and not t1.edges
    t2 = topology_for(2)
    W = np.eye(2) - np.array([[0.5, -0.5], [-0.5, 0.5]])
    from repro.core.graph import weight_matrix_from_weights
    np.testing.assert_allclose(
        weight_matrix_from_weights(2, t2.edges, t2.g), W)


def test_accum_grad_equivalence():
    """Gradient accumulation must equal the full-batch gradient."""
    from repro.dsgd.trainer import _accum_value_and_grad
    from repro.configs import reduced_for_smoke
    from repro.models import transformer
    from repro.data import DataConfig, synthetic_lm_batch

    cfg = reduced_for_smoke(get_arch("qwen1.5-0.5b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=4)
    batch = synthetic_lm_batch(dc, 0)

    loss_fn = lambda p, b: transformer.train_loss(p, cfg, b)
    l1, g1 = _accum_value_and_grad(loss_fn, params, batch, 1)
    l2, g2 = _accum_value_and_grad(loss_fn, params, batch, 2)
    # microbatch loss mean == full mean only when valid counts match per
    # microbatch (true here: every row has the same label layout)
    assert abs(float(l1) - float(l2)) < 1e-4
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)
