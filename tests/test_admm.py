"""Algorithm 2 (ADMM) — solver correctness, backend agreement, solution quality."""
import numpy as np
import pytest

from repro.core.admm import ADMMConfig, HeterogeneousADMM, HomogeneousADMM, _proj_card_nonneg, _proj_psd
from repro.core.constraints import intra_server_constraints, node_level_constraints
from repro.core.graph import all_edges, edge_index, is_connected, r_asym, weight_matrix_from_weights
from repro.core.weights import metropolis_weights, polish_weights

import jax.numpy as jnp


def _warm(n, deg):
    from repro.core.anneal import greedy_degree_graph

    rng = np.random.default_rng(0)
    edges = greedy_degree_graph(n, np.full(n, deg), rng)
    eidx = edge_index(n)
    m = len(all_edges(n))
    g0 = np.zeros(m)
    gm = metropolis_weights(n, edges)
    for k, e in enumerate(edges):
        g0[eidx[e]] = gm[k]
    return g0, edges


def test_proj_psd_nsd():
    rng = np.random.default_rng(0)
    M = rng.normal(size=(6, 6))
    P = np.asarray(_proj_psd(jnp.asarray(M), +1.0))
    Nn = np.asarray(_proj_psd(jnp.asarray(M), -1.0))
    assert np.linalg.eigvalsh(P).min() > -1e-10
    assert np.linalg.eigvalsh(Nn).max() < 1e-10
    # projection of an already-PSD matrix is (the symmetrization of) itself
    S = M @ M.T
    np.testing.assert_allclose(np.asarray(_proj_psd(jnp.asarray(S), +1.0)), S, atol=1e-8)


def test_proj_card():
    v = jnp.asarray(np.array([0.5, -1.0, 0.3, 0.2, 0.9]))
    ok = jnp.ones(5, dtype=bool)
    out = np.asarray(_proj_card_nonneg(v, 2, ok))
    assert (out > 0).sum() == 2
    assert out[4] == pytest.approx(0.9) and out[0] == pytest.approx(0.5)
    # inadmissible edges always zero
    ok2 = jnp.asarray(np.array([False, True, True, True, True]))
    out2 = np.asarray(_proj_card_nonneg(v, 2, ok2))
    assert out2[0] == 0.0


def test_homo_admm_feasibility_and_quality():
    """n=8, r=12: ADMM + support extraction yields a connected topology whose
    polished factor beats the Metropolis ring (the weakest baseline)."""
    n, r = 8, 12
    g0, _ = _warm(n, 3)
    solver = HomogeneousADMM(n, r, ADMMConfig(max_iters=400))
    res = solver.solve(g0=g0, lam0=0.4)
    assert res.iters <= 400
    score = res.g + res.g_raw
    sel = np.argsort(-score)[:r]
    edges = [all_edges(n)[l] for l in sorted(sel)]
    assert is_connected(n, edges)
    g = polish_weights(n, edges, iters=200)
    v = r_asym(weight_matrix_from_weights(n, edges, g))
    from repro.core.topologies import ring

    assert v < ring(n).r_asym()
    # cardinality respected on the projected side
    assert int((res.g > 1e-8).sum()) <= r


def test_homo_admm_lambda_consistency():
    """λ̃ from the solver must match 1 − r_asym of the implied W within slack."""
    n, r = 8, 12
    g0, _ = _warm(n, 3)
    solver = HomogeneousADMM(n, r, ADMMConfig(max_iters=600))
    res = solver.solve(g0=g0, lam0=0.4)
    W = weight_matrix_from_weights(n, all_edges(n), np.maximum(res.g, 0))
    # the ADMM iterate is not exactly feasible (residual > 0), allow slack
    assert abs((1.0 - res.lam_tilde) - r_asym(W)) < 0.2


def test_backend_agreement_one_step():
    """schur_cg and kkt_bicgstab_ilu produce the same X-step solution."""
    from repro.core import engine as E

    n, r = 6, 8
    g0, _ = _warm(n, 2)
    s1 = HomogeneousADMM(n, r, ADMMConfig(max_iters=1, solver="schur_cg"))
    s2 = HomogeneousADMM(n, r, ADMMConfig(max_iters=1, solver="kkt_bicgstab_ilu"))
    st1 = s1.init_state(jnp.asarray(g0), 0.4)
    st2 = s2.init_state(jnp.asarray(g0), 0.4)
    out1, _ = E.step(s1.spec, st1, "schur_cg")
    out2, _ = E.make_ilu_step(s2.spec)(st2)
    np.testing.assert_allclose(np.asarray(out1.X[0]), np.asarray(out2.X[0]), atol=1e-6)  # x
    np.testing.assert_allclose(np.asarray(out1.X[1]), np.asarray(out2.X[1]), atol=1e-6)  # S
    np.testing.assert_allclose(np.asarray(out1.X[3]), np.asarray(out2.X[3]), atol=1e-6)  # T


def test_backend_agreement_kkt_bicgstab():
    from repro.core import engine as E

    n, r = 6, 8
    g0, _ = _warm(n, 2)
    s1 = HomogeneousADMM(n, r, ADMMConfig(max_iters=1))
    st1 = s1.init_state(jnp.asarray(g0), 0.4)
    out1, _ = E.step(s1.spec, st1, "schur_cg")
    out2, _ = E.step(s1.spec, st1, "kkt_bicgstab")
    np.testing.assert_allclose(np.asarray(out1.X[0]), np.asarray(out2.X[0]), atol=1e-5)


def test_hetero_admm_node_level():
    """Node-level equality constraints: z respects cardinality; solution usable."""
    n, r = 8, 12
    e_cap = np.full(n, 3)
    b = np.full(n, 9.76)
    cs = node_level_constraints(n, e_cap, b)
    g0, edges0 = _warm(n, 3)
    z0 = (g0 > 0).astype(np.float64)
    solver = HeterogeneousADMM(n, r, np.asarray(cs.M, float), np.asarray(cs.e_cap, float),
                               ADMMConfig(max_iters=300), equality=True)
    res = solver.solve(g0=g0, z0=z0, lam0=0.4)
    assert res.z is not None
    assert int(res.z.sum()) == r  # binary projection keeps exactly r edges


def test_hetero_admm_inequality_slack():
    cs = intra_server_constraints()
    n, r = 8, 12
    g0, edges0 = _warm(n, 3)
    z0 = (g0 > 0).astype(np.float64)
    solver = HeterogeneousADMM(n, r, np.asarray(cs.M, float), np.asarray(cs.e_cap, float),
                               ADMMConfig(max_iters=300), equality=False,
                               edge_ok=np.asarray(cs.edge_ok))
    res = solver.solve(g0=g0, z0=z0, lam0=0.4)
    assert int(res.z.sum()) == r


def test_admm_residual_decreases_from_cold_start():
    """From a cold start the primal residual must drop by orders of magnitude.
    (From a warm start it starts tiny and can oscillate — the cardinality set
    is nonconvex — so monotonicity is only asserted for the cold start.)
    Uses the per-iteration driver: the assertion is about the iteration-1
    residual, which the scan driver's chunk-granular history does not log."""
    n, r = 8, 12
    solver = HomogeneousADMM(n, r, ADMMConfig(max_iters=300, check_every=10,
                                              driver="python"))
    res = solver.solve(g0=None, lam0=0.4)
    first = res.history[0][1]
    best = min(h[1] for h in res.history)
    # nonconvex splitting → limit cycles are expected; the best residual along
    # the trajectory must still drop well below the cold-start residual.
    assert best < 0.15 * first
