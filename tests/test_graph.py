"""Graph primitive invariants (Eqs. 3, 5–7) — unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import (
    Topology, all_edges, aspl, incidence_matrix, is_connected,
    laplacian_from_weights, r_asym, weight_matrix_from_weights,
)


def test_all_edges_count():
    for n in (2, 5, 16):
        assert len(all_edges(n)) == n * (n - 1) // 2


def test_incidence_laplacian_consistency():
    n = 6
    edges = all_edges(n)
    rng = np.random.default_rng(0)
    g = rng.uniform(0, 0.3, len(edges))
    A = incidence_matrix(n, edges)
    L_explicit = A @ np.diag(g) @ A.T  # Eq. (5)
    L_fast = laplacian_from_weights(n, edges, g)
    np.testing.assert_allclose(L_explicit, L_fast, atol=1e-12)


@settings(max_examples=50, deadline=None)
@given(st.integers(3, 10), st.integers(0, 10_000))
def test_weight_matrix_doubly_stochastic(n, seed):
    """W = I − A Diag(g) Aᵀ is symmetric & doubly stochastic for any g (§IV-A)."""
    rng = np.random.default_rng(seed)
    edges = all_edges(n)
    g = rng.uniform(0, 1.0 / n, len(edges))
    W = weight_matrix_from_weights(n, edges, g)
    ones = np.ones(n)
    np.testing.assert_allclose(W @ ones, ones, atol=1e-10)
    np.testing.assert_allclose(ones @ W, ones, atol=1e-10)
    np.testing.assert_allclose(W, W.T, atol=1e-12)


@settings(max_examples=30, deadline=None)
@given(st.integers(3, 8), st.integers(0, 10_000))
def test_laplacian_eigenvalue_bounds(n, seed):
    """Eq. (7): 0 = λ_n(L) and, when diag(L) ≤ 1, λ_1(L) < 2."""
    rng = np.random.default_rng(seed)
    edges = all_edges(n)
    g = rng.uniform(0, 1.0, len(edges))
    L = laplacian_from_weights(n, edges, g)
    # normalize to diag(L) ≤ 1 as enforced by Eq. (9)'s last constraint
    scale = max(np.max(np.diag(L)), 1.0)
    L = L / scale
    ev = np.linalg.eigvalsh(L)
    assert abs(ev[0]) < 1e-9
    assert ev[-1] < 2.0 + 1e-9


def test_r_asym_complete_graph():
    """Complete graph with uniform weights 1/n reaches consensus in one step."""
    n = 8
    edges = all_edges(n)
    g = np.full(len(edges), 1.0 / n)
    W = weight_matrix_from_weights(n, edges, g)
    assert r_asym(W) < 1e-10


def test_r_asym_known_ring4():
    # 4-ring with uniform weight 1/3: W eigenvalues {1, 1/3, 1/3, -1/3}
    n = 4
    edges = [(0, 1), (1, 2), (2, 3), (0, 3)]
    g = np.full(4, 1.0 / 3.0)
    W = weight_matrix_from_weights(n, edges, g)
    assert abs(r_asym(W) - 1.0 / 3.0) < 1e-12


def test_aspl_ring_and_connectivity():
    n = 6
    ring_edges = [(i, (i + 1) % n) for i in range(n)]
    ring_edges = [(min(a, b), max(a, b)) for a, b in ring_edges]
    # ring ASPL for n=6: distances 1,2,3,2,1 → mean 1.8
    assert abs(aspl(n, ring_edges) - 1.8) < 1e-12
    assert is_connected(n, ring_edges)
    assert not is_connected(n, ring_edges[:-2])
    assert aspl(n, ring_edges[:-2]) == float("inf")


def test_topology_validate_rejects_bad():
    n = 4
    t = Topology(n, [(0, 1), (2, 3)], np.array([0.5, 0.5]), name="disconnected")
    with pytest.raises(AssertionError):
        t.validate()  # r_asym == 1 for disconnected graphs
