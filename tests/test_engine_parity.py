"""Solver-engine parity: X-step backends, scan vs seed driver, batching,
and the dynamic-cardinality projections (DESIGN.md §2–§4)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine as E
from repro.core.admm import ADMMConfig, HeterogeneousADMM, HomogeneousADMM
from repro.core.anneal import greedy_degree_graph
from repro.core.constraints import node_level_constraints
from repro.core.graph import all_edges, edge_index
from repro.core.weights import metropolis_weights


def _warm(n, deg, seed=0):
    rng = np.random.default_rng(seed)
    edges = greedy_degree_graph(n, np.full(n, deg), rng)
    eidx = edge_index(n)
    m = len(all_edges(n))
    g0 = np.zeros(m)
    gm = metropolis_weights(n, edges)
    for k, e in enumerate(edges):
        g0[eidx[e]] = gm[k]
    return g0


def test_xstep_backend_parity():
    """schur_cg, kkt_bicgstab and kkt_bicgstab_ilu produce the same X-step
    solution (warm start; tol 1e-6 — measured agreement is ~1e-12)."""
    n, r = 6, 8
    g0 = _warm(n, 2)
    solver = HomogeneousADMM(n, r, ADMMConfig())
    st = solver.init_state(g0, 0.4)
    out_cg, _ = E.step(solver.spec, st, "schur_cg")
    out_kkt, _ = E.step(solver.spec, st, "kkt_bicgstab")
    out_ilu, _ = E.make_ilu_step(solver.spec)(st)
    for blk in range(4):  # x, S, y, T
        a = np.asarray(out_cg.X[blk])
        np.testing.assert_allclose(a, np.asarray(out_kkt.X[blk]), atol=1e-6)
        np.testing.assert_allclose(a, np.asarray(out_ilu.X[blk]), atol=1e-6)


def test_scan_driver_reproduces_seed_result():
    """The scan-compiled driver reproduces the seed per-iteration driver's
    ADMMResult (g, λ̃, support) on n=8, r=12. The python driver + unified
    step IS the seed solver (the step is bit-identical to the seed step
    bodies), so this pins the refactor against seed behaviour."""
    n, r = 8, 12
    g0 = _warm(n, 3)
    scan = HomogeneousADMM(n, r, ADMMConfig(max_iters=600)).solve(g0=g0, lam0=0.4)
    seed = HomogeneousADMM(n, r, ADMMConfig(max_iters=600, driver="python")).solve(
        g0=g0, lam0=0.4)
    assert scan.lam_tilde == pytest.approx(seed.lam_tilde, abs=1e-3)
    np.testing.assert_allclose(scan.g, seed.g, atol=1e-4)
    sup_scan = set(np.nonzero(scan.g > 1e-6)[0].tolist())
    sup_seed = set(np.nonzero(seed.g > 1e-6)[0].tolist())
    assert sup_scan == sup_seed
    # chunk-granular history: same logging cadence as the seed driver
    assert all(it % 10 == 0 for it, _, _ in scan.history)


def test_batched_solve_matches_single():
    """vmapped restarts return what per-restart solves return.

    Warm starts are tie-free (distinct random weights): with tied weights
    the nonconvex top-k projection makes trajectories sensitive to the
    last-bit float differences between the vmapped and single compilations
    (DESIGN.md §4), which is not what this test pins down.
    """
    n, r = 8, 12
    cfg = ADMMConfig(max_iters=100)
    solver = HomogeneousADMM(n, r, cfg)
    m = len(all_edges(n))
    rng = np.random.default_rng(1)
    g0s = 0.3 * rng.random((3, m))
    lam0s = np.array([0.3, 0.4, 0.5])
    batched = solver.solve_batched(g0s, lam0s)
    for b in range(3):
        single = solver.solve(g0=g0s[b], lam0=lam0s[b])
        np.testing.assert_allclose(batched[b].g, single.g, atol=1e-9)
        assert batched[b].lam_tilde == pytest.approx(single.lam_tilde, abs=1e-9)
        assert batched[b].iters == single.iters
        # history belongs to THIS restart (chunk axis, not batch axis)
        assert len(batched[b].history) == len(single.history)
        for (it_b, res_b, lam_b), (it_s, res_s, lam_s) in zip(
                batched[b].history, single.history):
            assert it_b == it_s
            assert res_b == pytest.approx(res_s, abs=1e-9)
            assert lam_b == pytest.approx(lam_s, abs=1e-9)


def test_batched_solve_hetero():
    n, r = 8, 12
    cs = node_level_constraints(n, np.full(n, 3), np.full(n, 9.76))
    solver = HeterogeneousADMM(n, r, np.asarray(cs.M, float),
                               np.asarray(cs.e_cap, float),
                               ADMMConfig(max_iters=80), equality=True)
    m = len(all_edges(n))
    rng = np.random.default_rng(3)
    base = np.stack([_warm(n, 3, seed=s) for s in range(2)])
    g0s = base + 1e-4 * rng.random((2, m)) * (base > 0)  # break weight ties
    z0s = (g0s > 0).astype(np.float64)
    lam0s = np.array([0.4, 0.4])
    batched = solver.solve_batched(g0s, z0s, lam0s)
    single = solver.solve(g0=g0s[1], z0=z0s[1], lam0=lam0s[1])
    np.testing.assert_allclose(batched[1].g, single.g, atol=1e-9)
    np.testing.assert_allclose(batched[1].z, single.z, atol=1e-12)
    assert all(int(res.z.sum()) == r for res in batched)


def test_sweep_over_budgets():
    """One vmapped call solves instances with different cardinality budgets
    (r is a data leaf, not a static top-k arg)."""
    n = 8
    cfg = ADMMConfig(max_iters=60)
    g0 = _warm(n, 3)
    spec = E.make_homo_spec(n, 14, cfg)
    states = [E.init_state(spec, jnp.asarray(g0), 0.4) for _ in range(2)]
    batched = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    rs = [10, 14]
    outs = E.solve_sweep_spec(spec, np.asarray(rs), batched, cfg)
    for r, out in zip(rs, outs):
        assert int((out.g > 1e-8).sum()) <= r
        single = HomogeneousADMM(n, r, cfg).solve(g0=g0, lam0=0.4)
        assert out.lam_tilde == pytest.approx(single.lam_tilde, abs=1e-3)


def test_dynamic_r_projections_match_static():
    """The sort-based projections equal the seed's static top-k semantics,
    with r either a Python int or a traced scalar."""
    rng = np.random.default_rng(2)
    v = jnp.asarray(rng.normal(size=40))
    ok = jnp.asarray(rng.random(40) > 0.2)
    for r in (1, 5, 39, 40, 60):
        ref = np.asarray(E.proj_card_nonneg(v, r, ok))
        traced = np.asarray(jax.jit(E.proj_card_nonneg)(v, jnp.asarray(r), ok))
        np.testing.assert_allclose(ref, traced)
        # top-k semantics: kept entries are the largest admissible positives
        kept = np.nonzero(ref > 0)[0]
        assert len(kept) <= r
        vv = np.where(np.asarray(ok), np.maximum(np.asarray(v), 0.0), 0.0)
        top = set(np.argsort(-vv)[:min(r, 40)].tolist())
        assert set(kept.tolist()) <= top
    r_sel = 6
    z = np.asarray(jax.jit(E.proj_binary_topr)(v, jnp.asarray(r_sel), ok))
    assert int(z.sum()) == r_sel
    assert set(np.unique(z)) <= {0.0, 1.0}
