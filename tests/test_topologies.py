"""Benchmark-topology checks against the paper's reported spectral factors."""
import math

import pytest

from repro.core.topologies import exponential, grid2d, hypercube, make_baseline, random_graph, ring, torus2d, u_equistatic


@pytest.mark.parametrize("kind", ["ring", "grid", "torus", "hypercube", "exponential", "equistatic"])
def test_baselines_valid(kind):
    t = make_baseline(kind, 16)
    t.validate()
    assert t.r_asym() < 1.0


@pytest.mark.parametrize("n,expected", [(4, 1 / 3), (8, 0.5), (16, 0.6), (32, 2 / 3), (64, 5 / 7), (128, 0.75)])
def test_exponential_matches_paper_table1(n, expected):
    """Table I row 'exponential': 1 − 2/(log2(n) + 2)."""
    t = exponential(n)
    assert abs(t.r_asym() - expected) < 5e-3


def test_exponential_degree():
    t = exponential(16)
    assert t.meta["out_degree"] == 4  # log2(16)


def test_hypercube_factor():
    # W = (I + sum_dims)/ (k+1): second eigenvalue (k−1)/(k+1)
    for n in (8, 16, 32):
        k = int(math.log2(n))
        t = hypercube(n)
        assert abs(t.r_asym() - (k - 1) / (k + 1)) < 1e-9


def test_torus_structure():
    t = torus2d(16)
    assert t.r == 32
    assert t.max_degree == 4


def test_grid_structure():
    t = grid2d(16)
    assert t.r == 24


def test_ring_scaling():
    # ring consensus degrades with n (paper §I motivation)
    assert ring(32).r_asym() > ring(8).r_asym()


def test_u_equistatic_edge_budget():
    t = u_equistatic(16, M=2, trials=16)
    assert t.r <= 32
    t.validate()


def test_random_graph_connected():
    t = random_graph(12, 18, seed=3)
    t.validate()
    assert t.r == 18
