"""Fault-path tests (DESIGN.md §14): ``degrade_matrix`` invariants, the
fault-free no-op guarantee, chaos scan-vs-host parity, churn freeze/rejoin
semantics, the drift detector, and the reopt retry/fallback ladder."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    BATopoConfig,
    make_baseline,
    optimize_topology,
    pod_boundary_constraints,
)
from repro.core.reopt import (
    DriftDetector,
    DriftPolicy,
    first_drift,
    reoptimize_topology,
)
from repro.data import class_balanced_partition, make_classification_data
from repro.dsgd.chaos import ChaosSpec, degrade_matrix, make_chaos, no_chaos
from repro.dsgd.dynamic import cycle_tensor, static_cycle
from repro.dsgd.sim import (
    CommSpec,
    DSGDSimConfig,
    accuracy_curve_host_chaos,
    consensus_curve_host_chaos,
    consensus_curves_chaos,
    consensus_curves_cross,
    train_curves_chaos,
    train_curves_cross,
)

N = 8
CFG = DSGDSimConfig(epochs=2, batch=16, hidden=32, seed=0)
DENSE = CommSpec()


@pytest.fixture(scope="module")
def ring():
    return make_baseline("ring", N)


@pytest.fixture(scope="module")
def cycles(ring):
    return [static_cycle(ring.W), cycle_tensor(ring)]


@pytest.fixture(scope="module")
def x0():
    return np.random.default_rng(0).normal(size=(N, 24))


@pytest.fixture(scope="module")
def dataset():
    X, y = make_classification_data(num_classes=6, dim=24,
                                    samples_per_class=80, seed=0)
    Xte, yte = make_classification_data(num_classes=6, dim=24,
                                        samples_per_class=24, seed=0,
                                        noise_seed=10_001)
    parts = class_balanced_partition(y, N, seed=0)
    return (jnp.asarray(X), jnp.asarray(y), parts,
            jnp.asarray(Xte), jnp.asarray(yte))


# --- ChaosSpec construction -------------------------------------------------

def test_make_chaos_shapes_and_validation():
    ch = make_chaos(20, N, seed=1, churn=[(2, 3, 9)], p_drop=0.2,
                    straggler_prob=0.3, straggler_mult=2.5)
    assert ch.steps == 20 and ch.n == N
    assert not ch.faultless
    np.testing.assert_array_equal(ch.link_up,
                                  np.swapaxes(ch.link_up, 1, 2))
    assert ch.alive[2, 2] == 1.0 and ch.alive[5, 2] == 0.0 \
        and ch.alive[9, 2] == 1.0
    assert no_chaos(20, N).faultless
    with pytest.raises(ValueError, match="out of range"):
        make_chaos(10, N, churn=[(0, 5, 12)])
    with pytest.raises(ValueError, match="symmetric"):
        bad = no_chaos(4, N)
        lu = bad.link_up.copy()
        lu[0, 0, 1] = 0.0  # break symmetry on one side only
        ChaosSpec(bad.alive, lu, bad.straggler, bad.bandwidth).validate()
    # stragglers/bandwidth never touch the training-math fault flag
    assert make_chaos(8, N, straggler_prob=1.0, straggler_mult=4.0).faultless


# --- degrade_matrix invariants ----------------------------------------------

def test_degrade_matrix_identity_when_no_faults(ring):
    W = jnp.asarray(ring.W)
    alive = jnp.ones(N)
    link = jnp.ones((N, N))
    np.testing.assert_array_equal(np.asarray(degrade_matrix(W, alive, link)),
                                  np.asarray(W))


def test_degrade_matrix_dead_rows_cols_and_stochasticity(ring):
    W = jnp.asarray(ring.W)
    alive = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0])
    link = jnp.ones((N, N)).at[2, 3].set(0.0).at[3, 2].set(0.0)
    Wd = np.asarray(degrade_matrix(W, alive, link))
    dead = np.nonzero(np.asarray(alive) == 0)[0]
    live = np.nonzero(np.asarray(alive) == 1)[0]
    np.testing.assert_array_equal(Wd[dead], 0.0)        # dead rows zeroed
    np.testing.assert_array_equal(Wd[:, dead], 0.0)     # dead cols zeroed
    np.testing.assert_allclose(Wd[live].sum(axis=1), 1.0, atol=1e-12)
    assert Wd[2, 3] == 0.0 and Wd[3, 2] == 0.0          # dropped link
    np.testing.assert_allclose(Wd, Wd.T, atol=0)        # symmetry preserved
    # doubly stochastic on the alive set ⇒ mean preserved across live nodes
    np.testing.assert_allclose(Wd[np.ix_(live, live)].sum(axis=0), 1.0,
                               atol=1e-12)


def test_degrade_matrix_broadcasts_batch_axes(ring):
    W = jnp.asarray(ring.W)
    alive = jnp.ones((5, N)).at[3, 0].set(0.0)
    link = jnp.ones((5, N, N))
    Wd = np.asarray(degrade_matrix(W[None], alive, link))
    assert Wd.shape == (5, N, N)
    np.testing.assert_array_equal(Wd[0], np.asarray(W))
    np.testing.assert_array_equal(Wd[3, 0], 0.0)


# --- fault-free no-op (bit-exact) -------------------------------------------

def test_faultless_chaos_train_bit_equal_to_cross_engine(cycles, dataset):
    X, y, parts, Xte, yte = dataset
    gammas = np.ones(len(cycles))
    ref, it = train_curves_cross(cycles, gammas, DENSE, X, y, parts,
                                 Xte, yte, CFG)
    ch = no_chaos(CFG.epochs * it, N)
    accs, it2 = train_curves_chaos(cycles, gammas, DENSE, ch, X, y, parts,
                                   Xte, yte, CFG)
    assert it2 == it
    np.testing.assert_array_equal(np.asarray(accs), np.asarray(ref))


def test_faultless_chaos_choco_train_bit_equal(cycles, dataset):
    X, y, parts, Xte, yte = dataset
    spec = CommSpec("top_k", 0.5)
    gammas = np.full(len(cycles), 0.6)
    ref, it = train_curves_cross(cycles, gammas, spec, X, y, parts,
                                 Xte, yte, CFG)
    ch = no_chaos(CFG.epochs * it, N)
    accs, _ = train_curves_chaos(cycles, gammas, spec, ch, X, y, parts,
                                 Xte, yte, CFG)
    np.testing.assert_array_equal(np.asarray(accs), np.asarray(ref))


def test_faultless_chaos_consensus_bit_equal(cycles, x0):
    iters = 40
    gammas = np.ones(len(cycles))
    ref = consensus_curves_cross(cycles, gammas, DENSE, x0, iters, seed=0)
    errs = consensus_curves_chaos(cycles, gammas, DENSE, no_chaos(iters, N),
                                  x0, iters, seed=0)
    np.testing.assert_array_equal(np.asarray(errs), np.asarray(ref))


# --- scan vs host parity under faults ---------------------------------------

ACC_TOL = 1.0 / 144 + 1e-7          # one borderline test sample of 144


def test_chaos_train_scan_matches_host(cycles, dataset):
    X, y, parts, Xte, yte = dataset
    _, it = train_curves_cross(cycles[:1], np.ones(1), DENSE, X, y, parts,
                               Xte, yte, CFG)
    ch = make_chaos(CFG.epochs * it, N, seed=3, churn=[(1, 2, 5)], p_drop=0.1)
    accs, _ = train_curves_chaos(cycles, np.ones(len(cycles)), DENSE, ch,
                                 X, y, parts, Xte, yte, CFG)
    accs = np.asarray(accs)
    for b, cyc in enumerate(cycles):
        host, _ = accuracy_curve_host_chaos(cyc, 1.0, DENSE, ch, X, y, parts,
                                            Xte, yte, CFG)
        assert np.abs(accs[b] - host).max() <= ACC_TOL


def test_chaos_choco_consensus_scan_matches_host(cycles, x0):
    iters = 50
    spec = CommSpec("top_k", 0.25)
    ch = make_chaos(iters, N, seed=4, churn=[(0, 10, 35)], p_drop=0.05)
    errs = consensus_curves_chaos(cycles, np.full(len(cycles), 0.4), spec,
                                  ch, x0, iters, seed=0)
    errs = np.asarray(errs)
    for b, cyc in enumerate(cycles):
        host = consensus_curve_host_chaos(cyc, 0.4, spec, ch, x0, iters,
                                          seed=0)
        rel = np.abs(errs[b] - host) / host[0]
        assert rel.max() <= 1e-6


# --- churn freeze/rejoin semantics ------------------------------------------

def test_churned_node_freezes_and_rejoins(ring, x0):
    """While node k is dead its value must not move; the live nodes keep
    contracting toward the mean of the full network state."""
    iters = 30
    t0, t1, k = 5, 20, 3
    ch = make_chaos(iters, N, churn=[(k, t0, t1)])
    alive, link = ch.device_leaves()
    x = jnp.asarray(x0)
    W = jnp.asarray(ring.W)
    frozen = None
    for t in range(iters):
        Wd = degrade_matrix(W, alive[t], link[t])
        x_new = Wd @ x
        keep = alive[t].reshape(-1, 1) > 0
        x = jnp.where(keep, x_new, x)
        if t == t0:
            frozen = np.asarray(x[k]).copy()
        if t0 < t < t1:
            np.testing.assert_array_equal(np.asarray(x[k]), frozen)
    # after rejoin the node is pulled back toward consensus
    err_k = np.linalg.norm(np.asarray(x[k]) - x0.mean(axis=0))
    assert err_k < np.linalg.norm(frozen - x0.mean(axis=0))


# --- drift detector ----------------------------------------------------------

def test_drift_detector_thresholds_and_cooldown():
    n, T = 4, 30
    bw = np.full((T, n), 10.0)
    bw[10:, 0] = 5.0                       # 50% drop at t=10
    ch = make_chaos(T, n, churn=[(2, 20, 25)], bandwidth=bw)
    assert first_drift(ch) == (10, "bandwidth")
    # a higher threshold ignores the bandwidth move and fires on churn
    pol = DriftPolicy(bw_rel_threshold=0.9)
    assert first_drift(ch, pol) == (20, "churn")
    det = DriftDetector.from_profile(ch.bandwidth[0], ch.alive[0],
                                     DriftPolicy(cooldown_steps=100))
    assert det.check(10, ch.bandwidth[10], ch.alive[10]) == "bandwidth"
    assert det.check(20, ch.bandwidth[20], ch.alive[20]) is None  # cooldown
    det.rebase(ch.bandwidth[10], ch.alive[10])
    det.last_trigger = None
    assert det.check(11, ch.bandwidth[11], ch.alive[11]) is None  # rebased


# --- reopt retry/fallback ladder --------------------------------------------

REOPT_CFG = BATopoConfig(sa_iters=150, polish_iters=150)


@pytest.fixture(scope="module")
def incumbent():
    return optimize_topology(16, 32, "homo", cfg=REOPT_CFG)


def test_reopt_improves_or_keeps_connected(incumbent):
    bw = np.array([9.76] * 8 + [3.25] * 8)
    bw[:4] = 1.0                           # drifted profile
    res = reoptimize_topology(incumbent, scenario="node",
                              node_bandwidths=bw, cfg=REOPT_CFG)
    assert res.reoptimized
    assert res.topology.meta.get("connected", True)
    assert res.time_to_reopt_s > 0
    assert np.isfinite(res.r_asym_after) and res.r_asym_after < 1.0


def test_reopt_nonconvergent_falls_through_ladder(incumbent):
    """max_residual=0 declares every warm solve non-convergent: the ladder
    must go to attempt 2 (cold pipeline) instead of adopting it."""
    res = reoptimize_topology(incumbent, scenario="homo", cfg=REOPT_CFG,
                              policy=DriftPolicy(max_residual=0.0))
    assert res.attempts == 2
    assert res.reoptimized            # cold pipeline rescued it
    assert res.fallback_reason is None


def test_reopt_disconnected_keeps_incumbent(incumbent):
    """A constraint set whose only connected supports are impossible
    (zero inter-pod capacity) must keep the incumbent and say why."""
    cs = pod_boundary_constraints(16, pods=2, dci_cap_total=0)
    res = reoptimize_topology(incumbent, scenario="constraint", cs=cs,
                              cfg=REOPT_CFG)
    assert not res.reoptimized
    assert res.topology is incumbent
    assert res.fallback_reason is not None
    assert res.r_asym_after == res.r_asym_before


def test_reopt_requires_scenario_inputs(incumbent):
    with pytest.raises(ValueError, match="node_bandwidths"):
        reoptimize_topology(incumbent, scenario="node")
    with pytest.raises(ValueError, match="ConstraintSet"):
        reoptimize_topology(incumbent, scenario="constraint")
