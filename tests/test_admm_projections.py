"""Property tests for the ADMM Y-step projections (Alg. 2 / Eq. 24–25)."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.admm import _proj_binary_topr, _proj_card_nonneg, _proj_psd


@settings(max_examples=30, deadline=None)
@given(m=st.integers(2, 60), r=st.integers(1, 20), seed=st.integers(0, 1000))
def test_card_nonneg_projection(m, r, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=m))
    ok = jnp.ones(m, bool)
    p = np.asarray(_proj_card_nonneg(v, r, ok))
    # feasibility: nonnegative, cardinality ≤ r
    assert (p >= 0).all()
    assert (p > 0).sum() <= r
    # optimality (Euclidean projection): kept entries are the largest
    # positives of v
    kept = set(np.nonzero(p > 0)[0].tolist())
    pos = [i for i in range(m) if float(v[i]) > 0]
    top = set(sorted(pos, key=lambda i: -float(v[i]))[:r])
    assert kept <= top
    for i in kept:
        np.testing.assert_allclose(p[i], float(v[i]))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 12), seed=st.integers(0, 1000),
       sign=st.sampled_from([+1.0, -1.0]))
def test_psd_nsd_projection(n, seed, sign):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n))
    A = (A + A.T) / 2
    P = np.asarray(_proj_psd(jnp.asarray(A), sign))
    ev = np.linalg.eigvalsh(P)
    if sign > 0:
        assert ev.min() >= -1e-8           # PSD cone
    else:
        assert ev.max() <= 1e-8            # NSD cone
    # idempotent
    P2 = np.asarray(_proj_psd(jnp.asarray(P), sign))
    np.testing.assert_allclose(P2, P, atol=1e-8)
    # Euclidean-optimal: distance equals the norm of clipped eigenvalues
    lam = np.linalg.eigvalsh(A)
    clipped = np.minimum(lam, 0) if sign > 0 else np.maximum(lam, 0)
    np.testing.assert_allclose(np.linalg.norm(P - A), np.linalg.norm(clipped),
                               atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(m=st.integers(2, 60), r=st.integers(1, 20), seed=st.integers(0, 1000))
def test_binary_topr_projection(m, r, seed):
    r = min(r, m)  # the solver always has r ≤ |E| by construction
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=m))
    ok = jnp.ones(m, bool)
    z = np.asarray(_proj_binary_topr(v, r, ok))
    assert set(np.unique(z)).issubset({0.0, 1.0})
    assert z.sum() <= r
    # selected entries dominate non-selected
    if 0 < z.sum() < m:
        assert float(np.asarray(v)[z > 0].min()) >= float(np.asarray(v)[z == 0].max()) - 1e-9


@settings(max_examples=15, deadline=None)
@given(m=st.integers(4, 40), r=st.integers(1, 10), seed=st.integers(0, 500))
def test_card_projection_respects_edge_ok(m, r, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(np.abs(rng.normal(size=m)) + 0.1)
    ok = jnp.asarray(rng.random(m) < 0.5)
    p = np.asarray(_proj_card_nonneg(v, r, ok))
    assert (p[~np.asarray(ok)] == 0).all()
