"""CHOCO compressed gossip — beyond-paper extension."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import make_baseline
from repro.core.graph import weight_matrix_from_weights
from repro.dsgd import (
    choco_gamma,
    choco_gossip_init,
    choco_gossip_step,
    identity_compressor,
    random_k_compressor,
    top_k_compressor,
)


def _W(name, n):
    t = make_baseline(name, n)
    return jnp.asarray(weight_matrix_from_weights(n, t.edges, t.g), jnp.float32), t


def test_identity_choco_gamma1_equals_plain_gossip():
    W, _ = _W("ring", 6)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (6, 32))
    state = choco_gossip_init(x0)
    state = choco_gossip_step(state, W, identity_compressor(), 1.0,
                              jax.random.PRNGKey(1))
    # x̂ = x0 after one innovation; x ← x + (W−I)x̂ = W x0
    np.testing.assert_allclose(np.asarray(state.x), np.asarray(W @ x0), atol=1e-5)


def test_choco_preserves_mean():
    W, _ = _W("exponential", 8)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    state = choco_gossip_init(x0)
    key = jax.random.PRNGKey(1)
    for _ in range(30):
        key, sub = jax.random.split(key)
        state = choco_gossip_step(state, W, top_k_compressor(0.2), 0.3, sub)
    np.testing.assert_allclose(np.asarray(state.x.mean(0)),
                               np.asarray(x0.mean(0)), atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(frac=st.sampled_from([0.1, 0.25, 0.5]), seed=st.integers(0, 50))
def test_choco_converges_with_topk(frac, seed):
    W, topo = _W("hypercube", 8)
    lam2 = 1.0 - float(np.sort(np.abs(np.linalg.eigvals(np.asarray(W))))[-2])
    gamma = max(choco_gamma(topo, lam2), 0.2)
    x0 = jax.random.normal(jax.random.PRNGKey(seed), (8, 64))
    e0 = float(jnp.linalg.norm(x0 - x0.mean(0)))
    state = choco_gossip_init(x0)
    key = jax.random.PRNGKey(seed + 1)
    for _ in range(300):
        key, sub = jax.random.split(key)
        state = choco_gossip_step(state, W, top_k_compressor(frac), gamma, sub)
    e = float(jnp.linalg.norm(state.x - state.x.mean(0)))
    assert e < 0.05 * e0, (e, e0)


def test_random_k_is_unbiased():
    comp = random_k_compressor(0.25)
    x = jnp.ones((1, 4000))
    keys = jax.random.split(jax.random.PRNGKey(0), 64)
    mean = jnp.stack([comp.fn(x, k) for k in keys]).mean()
    assert abs(float(mean) - 1.0) < 0.05
