"""Algorithm 1 invariants + the paper's §VI-A2 worked example."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.allocation import allocate_edge_capacity


def test_paper_node_hetero_example():
    """§VI-A2: n=16, bandwidths 3:1 (9.76 vs 3.25 GB/s), r=32 edges →
    fast nodes get 6 edges, slow nodes 2, b_unit = 3.25/2 = 1.625."""
    b = np.array([9.76] * 8 + [3.25] * 8)
    res = allocate_edge_capacity(b, 32)
    assert int(res.e.sum()) // 2 == 32
    np.testing.assert_array_equal(res.e[:8], 6)
    np.testing.assert_array_equal(res.e[8:], 2)
    # unit bandwidth = min over nodes of b_i/e_i = min(9.76/6, 3.25/2) = 1.625
    assert abs(res.b_unit - 3.25 / 2) < 1e-9


def test_homogeneous_allocation():
    b = np.full(16, 9.76)
    res = allocate_edge_capacity(b, 32)
    assert int(res.e.sum()) // 2 == 32
    assert np.all(res.e <= 15)


@settings(max_examples=60, deadline=None)
@given(
    st.integers(4, 20),
    st.integers(0, 10_000),
)
def test_allocation_invariants(n, seed):
    """Invariants: e ≤ ē, Σe/2 == r when feasible, per-edge bandwidth ≥ b_unit."""
    rng = np.random.default_rng(seed)
    b = rng.uniform(1.0, 10.0, n)
    cap = n - 1
    max_edges = n * cap // 2
    r = int(rng.integers(n // 2, max_edges // 2 + 1))
    res = allocate_edge_capacity(b, r)
    assert np.all(res.e >= 0)
    assert np.all(res.e <= cap)
    assert int(res.e.sum()) // 2 <= r
    # every allocated node can serve its edges at ≥ b_unit:
    mask = res.e > 0
    assert np.all(b[mask] / res.e[mask] >= res.b_unit - 1e-9)


def test_allocation_trim_branch():
    # force edge_count > r so lines 6–8 (trim) execute
    b = np.array([10.0, 10.0, 10.0, 1.0])
    res = allocate_edge_capacity(b, 2)
    assert int(res.e.sum()) // 2 <= 2
