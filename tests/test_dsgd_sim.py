"""Parity tests for the device-resident DSGD evaluation engine (DESIGN §11):
scan/vmapped training vs the host-loop oracle, the batched gossip_mix path
vs the per-row oracle, and vmapped vs serial consensus simulation."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import make_baseline
from repro.core.consensus import simulate_consensus, simulate_consensus_batched
from repro.data import (
    class_balanced_partition,
    epoch_permutations,
    make_classification_data,
)
from repro.dsgd.gossip import (
    gossip_sim_tree,
    gossip_sim_tree_rowloop,
    padded_neighbors,
)
from repro.dsgd.sim import (
    DSGDSimConfig,
    accuracy_curve_host,
    accuracy_curves,
    accuracy_curves_seeds,
)

N = 8
CFG = DSGDSimConfig(epochs=3, batch=16, hidden=32, seed=0)


@pytest.fixture(scope="module")
def dataset():
    X, y = make_classification_data(num_classes=6, dim=24,
                                    samples_per_class=80, seed=0)
    Xte, yte = make_classification_data(num_classes=6, dim=24,
                                        samples_per_class=24, seed=0,
                                        noise_seed=10_001)
    parts = class_balanced_partition(y, N, seed=0)
    return (jnp.asarray(X), jnp.asarray(y), parts,
            jnp.asarray(Xte), jnp.asarray(yte))


@pytest.fixture(scope="module")
def topologies():
    return [make_baseline("ring", N), make_baseline("exponential", N),
            make_baseline("equistatic", N, M=2)]


# --- data pipeline ----------------------------------------------------------

def test_epoch_permutations_matches_host_loop_stream(dataset):
    """Identical batch order given a seed: the helper consumes the numpy
    stream exactly like the seed benchmark's per-epoch permutation loop."""
    parts = dataset[2]
    epochs, batch = 3, 16
    perm = epoch_permutations(parts, epochs, batch, seed=5)
    per = min(len(p) for p in parts)
    iters = per // batch
    assert perm.shape == (epochs, iters, N, batch)
    rng = np.random.default_rng(5)
    for e in range(epochs):
        orders = [rng.permutation(p)[: iters * batch] for p in parts]
        for it in range(iters):
            for w in range(N):
                np.testing.assert_array_equal(
                    perm[e, it, w], orders[w][it * batch:(it + 1) * batch])


def test_epoch_permutations_indices_stay_in_partition(dataset):
    parts = dataset[2]
    perm = epoch_permutations(parts, 2, 16, seed=1)
    for w in range(N):
        assert set(perm[:, :, w, :].ravel()) <= set(parts[w].tolist())


def test_make_classification_data_matches_per_class_loop():
    """The vectorized sampler is bit-identical to the seed per-class loop."""
    X, y = make_classification_data(num_classes=5, dim=12,
                                    samples_per_class=40, seed=3,
                                    noise_seed=77, class_sep=2.0)
    rng = np.random.default_rng(3)
    means = rng.normal(size=(5, 12)) * 2.0 / np.sqrt(12)
    rng = np.random.default_rng(77)
    Xs, ys = [], []
    for c in range(5):
        Xs.append(means[c] + rng.normal(size=(40, 12)))
        ys.append(np.full(40, c, np.int32))
    Xs = np.concatenate(Xs).astype(np.float32)
    ys = np.concatenate(ys)
    p = rng.permutation(len(ys))
    np.testing.assert_array_equal(X, Xs[p])
    np.testing.assert_array_equal(y, ys[p])


# --- batched gossip_mix -----------------------------------------------------

def test_padded_neighbors_layout(topologies):
    W = np.asarray(topologies[0].W)  # ring: degree 2 everywhere
    nbr_idx, weights = padded_neighbors(W)
    assert nbr_idx.shape == (N, 2) and weights.shape == (N, 3)
    for i in range(N):
        assert float(weights[i, 0]) == pytest.approx(W[i, i])
        assert sorted(np.asarray(nbr_idx[i]).tolist()) == \
            sorted(np.nonzero(W[i] * (1 - np.eye(N)[i]))[0].tolist())


def test_padded_neighbors_pad_is_self_with_zero_weight():
    # star graph: hub degree n-1, leaves degree 1 → heavy padding
    W = np.eye(6) * 0.5
    for j in range(1, 6):
        W[0, j] = W[j, 0] = 0.1
    nbr_idx, weights = padded_neighbors(W)
    assert nbr_idx.shape == (6, 5)
    for i in range(1, 6):
        assert np.all(np.asarray(nbr_idx[i, 1:]) == i)       # pad = own row
        assert np.all(np.asarray(weights[i, 2:]) == 0.0)      # pad weight 0


@pytest.mark.parametrize("shape", [(130,), (4, 7), (8, 130)])
def test_gossip_batched_matches_rowloop(topologies, shape):
    """Acceptance: batched gossip_mix vs the per-row oracle ≤ 1e-6."""
    for topo in topologies:
        W = jnp.asarray(topo.W, jnp.float32)
        tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (N,) + shape)}
        batched = gossip_sim_tree(tree, W, use_kernel=True)
        rowloop = gossip_sim_tree_rowloop(tree, W)
        np.testing.assert_allclose(np.asarray(batched["a"]),
                                   np.asarray(rowloop["a"]), atol=1e-6)


def test_gossip_batched_matches_dense(topologies):
    for topo in topologies:
        W = jnp.asarray(topo.W, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (N, 33, 5))
        batched = gossip_sim_tree({"p": x}, W, use_kernel=True)["p"]
        dense = gossip_sim_tree({"p": x}, W)["p"]
        np.testing.assert_allclose(np.asarray(batched), np.asarray(dense),
                                   atol=1e-5)


def test_gossip_batched_trace_safe_under_jit(topologies):
    """With precomputed padded indices the batched path jits — the per-row
    path's host read of W made this impossible."""
    W = jnp.asarray(topologies[0].W, jnp.float32)
    nbr = padded_neighbors(W)

    @jax.jit
    def mix(tree):
        return gossip_sim_tree(tree, W, use_kernel=True, nbr=nbr)

    tree = {"a": jax.random.normal(jax.random.PRNGKey(2), (N, 50))}
    np.testing.assert_allclose(np.asarray(mix(tree)["a"]),
                               np.asarray(gossip_sim_tree(tree, W)["a"]),
                               atol=1e-5)


# --- scan/vmapped training engine ------------------------------------------

def test_scan_engine_matches_host_oracle(dataset, topologies):
    """Same accuracy curve as the per-iteration host loop (fp32 tolerance),
    identical batch order by construction."""
    X, y, parts, Xte, yte = dataset
    for topo in topologies[:2]:
        W = jnp.asarray(topo.W, jnp.float32)
        accs_scan, iters_s = accuracy_curves(W, X, y, parts, Xte, yte, CFG)
        accs_host, iters_h = accuracy_curve_host(W, X, y, parts, Xte, yte, CFG)
        assert iters_s == iters_h
        assert accs_scan.shape == accs_host.shape
        # accuracy is a discrete mean over the test set: fp32 drift can only
        # flip borderline samples, so allow at most one of 144
        assert np.abs(np.asarray(accs_scan) - accs_host).max() <= 1.0 / 144 + 1e-7


def test_vmapped_topologies_match_single_runs(dataset, topologies):
    X, y, parts, Xte, yte = dataset
    Ws = jnp.stack([jnp.asarray(t.W, jnp.float32) for t in topologies])
    accs_b, iters = accuracy_curves(Ws, X, y, parts, Xte, yte, CFG)
    assert accs_b.shape == (len(topologies), CFG.epochs)
    for k in range(len(topologies)):
        accs_1, _ = accuracy_curves(Ws[k], X, y, parts, Xte, yte, CFG)
        np.testing.assert_allclose(np.asarray(accs_b[k]), np.asarray(accs_1),
                                   atol=1e-6)


def test_seed_vmap_matches_per_seed_runs(dataset, topologies):
    X, y, parts, Xte, yte = dataset
    Ws = jnp.stack([jnp.asarray(t.W, jnp.float32) for t in topologies[:2]])
    accs_s, _ = accuracy_curves_seeds(Ws, X, y, parts, Xte, yte, [0, 3], CFG)
    assert accs_s.shape == (2, 2, CFG.epochs)
    for si, seed in enumerate([0, 3]):
        cfg = DSGDSimConfig(epochs=CFG.epochs, batch=CFG.batch,
                            hidden=CFG.hidden, seed=seed)
        accs_1, _ = accuracy_curves(Ws, X, y, parts, Xte, yte, cfg)
        np.testing.assert_allclose(np.asarray(accs_s[si]), np.asarray(accs_1),
                                   atol=1e-6)


def test_training_actually_learns(dataset, topologies):
    X, y, parts, Xte, yte = dataset
    W = jnp.asarray(topologies[0].W, jnp.float32)
    accs, _ = accuracy_curves(W, X, y, parts, Xte, yte, CFG)
    assert float(accs[-1]) > 0.5


# --- vmapped consensus ------------------------------------------------------

def test_consensus_batched_matches_serial(topologies):
    traces = simulate_consensus_batched(topologies, iters=60, dim=8, seed=2,
                                        b_mins=[2.0, 1.0, None])
    for topo, tr in zip(topologies, traces):
        st = simulate_consensus(topo, iters=60, dim=8, seed=2)
        np.testing.assert_allclose(tr.errors, st.errors, rtol=1e-12, atol=0)
        assert tr.topology == st.topology
    assert traces[0].t_iter_ms == pytest.approx(
        simulate_consensus(topologies[0], iters=1, b_min=2.0).t_iter_ms)


def test_consensus_batched_rejects_mixed_n():
    topos = [make_baseline("ring", 8), make_baseline("ring", 12)]
    with pytest.raises(ValueError):
        simulate_consensus_batched(topos, iters=10)


def test_consensus_batched_empty():
    assert simulate_consensus_batched([], iters=10) == []
